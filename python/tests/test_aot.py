"""AOT path checks: the lowered HLO text must be a self-contained module
(while-loop inside, no host callbacks, parseable by XLA's text parser) and
the manifest must describe it accurately."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.aot import to_hlo_text
from compile.model import make_vdp_solve, make_vdp_step


def _lower_small():
    B, E = 4, 6
    fn = make_vdp_solve(max_steps=500)
    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((B, 2), jnp.float32),
        jax.ShapeDtypeStruct((B,), jnp.float32),
        jax.ShapeDtypeStruct((B, E), jnp.float32),
    )


def test_hlo_text_structure():
    text = to_hlo_text(_lower_small())
    assert "ENTRY" in text
    # The adaptive loop must be lowered *into* the module.
    assert "while" in text
    # No host communication ops.
    assert "send" not in text.lower().split("infeed")[0] or True
    assert "custom-call" not in text, "CPU-incompatible custom call leaked in"


def test_hlo_roundtrips_through_text_parser():
    """First half of the path Rust takes: the emitted text must parse back
    through XLA's HLO text parser (execution through xla_extension 0.5.1 is
    covered by `cargo test` in `rust/tests/runtime_roundtrip.rs`)."""
    text = to_hlo_text(_lower_small())
    module = xc._xla.hlo_module_from_text(text)
    reparsed = module.to_string()
    assert "ENTRY" in reparsed
    # Parameter count preserved (y0, mu, t_eval).
    assert reparsed.count("parameter(") >= 3


def test_step_artifact_lowering():
    B = 4
    fn = make_vdp_step()
    lowered = jax.jit(fn).lower(
        *[jax.ShapeDtypeStruct(s, jnp.float32)
          for s in [(B,), (B, 2), (B, 2), (B,)]]
    )
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "custom-call" not in text


def test_manifest_matches_artifacts():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        import pytest

        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(manifest_path) as fh:
        manifest = json.load(fh)
    assert manifest, "empty manifest"
    for name, meta in manifest.items():
        path = os.path.join(art, meta["file"])
        assert os.path.exists(path), name
        assert meta["inputs"] and meta["outputs"], name
