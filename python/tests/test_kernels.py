"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracles in
`compile.kernels.ref`, swept over shapes/values with hypothesis.

This is the core L1 correctness signal: the kernels lower into every AOT
artifact, so a mismatch here is a miscompiled solver.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dopri5_eval, error_norm, ref, rk_combine, stage_accum

# Keep hypothesis fast and deterministic: interpret-mode Pallas is slow to
# trace, so we bound the example count and shapes.
COMMON = dict(max_examples=20, deadline=None)


def _arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@st.composite
def combine_case(draw):
    s = draw(st.sampled_from([4, 7]))
    b = draw(st.sampled_from([1, 2, 8]))
    d = draw(st.sampled_from([1, 2, 16]))
    seed = draw(st.integers(0, 2**31 - 1))
    return s, b, d, seed


@given(combine_case())
@settings(**COMMON)
def test_rk_combine_matches_ref(case):
    s, b, d, seed = case
    rng = np.random.default_rng(seed)
    k = _arr(rng, (s, b, d))
    y = _arr(rng, (b, d))
    dt = jnp.asarray(rng.uniform(1e-3, 0.5, size=(b,)), jnp.float32)
    bw = tuple(rng.normal(size=s).tolist())
    ew = tuple(rng.normal(size=s).tolist())
    y_new, err = rk_combine(k, y, dt, bw, ew)
    y_ref, e_ref = ref.rk_combine_ref(k, y, dt, jnp.asarray(bw), jnp.asarray(ew))
    np.testing.assert_allclose(y_new, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(err, e_ref, rtol=1e-5, atol=1e-5)


@given(combine_case())
@settings(**COMMON)
def test_stage_accum_matches_ref(case):
    s, b, d, seed = case
    rng = np.random.default_rng(seed)
    k = _arr(rng, (s, b, d))
    y = _arr(rng, (b, d))
    dt = jnp.asarray(rng.uniform(1e-3, 0.5, size=(b,)), jnp.float32)
    a_row = rng.normal(size=s)
    a_row[rng.integers(0, s)] = 0.0  # exercise the zero-skip path
    got = stage_accum(k, y, dt, tuple(a_row.tolist()))
    want = ref.stage_accum_ref(k, y, dt, jnp.asarray(a_row, jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 4, 16]), st.sampled_from([1, 2, 8]))
@settings(**COMMON)
def test_error_norm_matches_ref(seed, b, d):
    rng = np.random.default_rng(seed)
    err = _arr(rng, (b, d), scale=1e-4)
    y0 = _arr(rng, (b, d))
    y1 = _arr(rng, (b, d))
    got = error_norm(err, y0, y1, 1e-6, 1e-5)
    want = ref.error_norm_ref(err, y0, y1, 1e-6, 1e-5)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 4]), st.sampled_from([2, 8]),
       st.sampled_from([1, 5, 20]))
@settings(**COMMON)
def test_dopri5_eval_matches_ref(seed, b, d, e):
    rng = np.random.default_rng(seed)
    rcont = _arr(rng, (5, b, d))
    theta = jnp.asarray(rng.uniform(0, 1, size=(b, e)), jnp.float32)
    got = dopri5_eval(rcont, theta)
    want = ref.dopri5_eval_ref(rcont, theta)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_error_norm_exact_value():
    # err == scale everywhere => norm exactly 1.
    b, d = 2, 3
    y0 = jnp.zeros((b, d), jnp.float32)
    err = jnp.full((b, d), 1e-6, jnp.float32)
    n = error_norm(err, y0, y0, 1e-6, 0.0)
    np.testing.assert_allclose(n, np.ones(b), rtol=1e-6)


def test_rk_combine_blocked_grid():
    # block_b smaller than B exercises the multi-block grid path.
    rng = np.random.default_rng(7)
    s, b, d = 7, 8, 4
    k = _arr(rng, (s, b, d))
    y = _arr(rng, (b, d))
    dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(b,)), jnp.float32)
    bw = tuple(rng.normal(size=s).tolist())
    ew = tuple(rng.normal(size=s).tolist())
    full, _ = rk_combine(k, y, dt, bw, ew)
    blocked, _ = rk_combine(k, y, dt, bw, ew, block_b=2)
    np.testing.assert_allclose(full, blocked, rtol=1e-6)


def test_interp_endpoints():
    # θ=0 must return r1, θ=1 must return r1 + r2 (the step endpoints by
    # construction of the rcont coefficients).
    rng = np.random.default_rng(3)
    rcont = _arr(rng, (5, 2, 3))
    theta = jnp.asarray([[0.0, 1.0]] * 2, jnp.float32)
    out = np.asarray(dopri5_eval(rcont, theta))
    np.testing.assert_allclose(out[:, 0, :], rcont[0], rtol=1e-6)
    np.testing.assert_allclose(out[:, 1, :], rcont[0] + rcont[1], rtol=1e-5, atol=1e-6)


def test_hermite_ref_endpoints():
    rng = np.random.default_rng(4)
    b, d = 3, 2
    y0 = _arr(rng, (b, d))
    y1 = _arr(rng, (b, d))
    f0 = _arr(rng, (b, d))
    f1 = _arr(rng, (b, d))
    dt = jnp.asarray(rng.uniform(0.1, 1.0, size=(b,)), jnp.float32)
    theta = jnp.asarray([[0.0, 1.0]] * b, jnp.float32)
    out = np.asarray(ref.hermite_eval_ref(y0, f0, y1, f1, dt, theta))
    np.testing.assert_allclose(out[:, 0, :], y0, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out[:, 1, :], y1, rtol=1e-4, atol=1e-5)
