"""Tableau sanity: order conditions and structural invariants (the JSON
export consumed by the Rust golden test is also checked)."""

import json

import numpy as np

from compile import tableaus


def test_stage_consistency():
    for t in tableaus.ALL.values():
        for i in range(1, t.stages):
            assert abs(t.a[i, :i].sum() - t.c[i]) < 1e-12, t.name


def test_b_sums_to_one():
    for t in tableaus.ALL.values():
        assert abs(t.b.sum() - 1.0) < 1e-12, t.name


def test_b_err_sums_to_zero():
    for t in tableaus.ALL.values():
        assert abs(t.b_err.sum()) < 1e-12, t.name


def test_order_conditions():
    for t in tableaus.ALL.values():
        if t.order >= 2:
            assert abs((t.b * t.c).sum() - 0.5) < 1e-9, t.name
        if t.order >= 3:
            assert abs((t.b * t.c**2).sum() - 1 / 3) < 1e-9, t.name
            assert abs(t.b @ t.a @ t.c - 1 / 6) < 1e-9, t.name
        if t.order >= 4:
            assert abs((t.b * t.c**3).sum() - 0.25) < 1e-9, t.name


def test_fsal_structure():
    for t in tableaus.ALL.values():
        if t.fsal:
            np.testing.assert_allclose(t.a[-1, :-1], t.b[:-1], atol=1e-15)
            assert t.b[-1] == 0.0
            assert t.c[-1] == 1.0


def test_json_roundtrip():
    payload = json.loads(tableaus.to_json())
    assert set(payload) == set(tableaus.ALL)
    d5 = payload["dopri5"]
    assert d5["stages"] == 7
    assert len(d5["a"]) == 21
    assert d5["fsal"] is True


def test_a_flat_layout():
    t = tableaus.DOPRI5
    flat = t.a_flat()
    # Row 2 (0-indexed) starts at offset 1 and holds [3/40, 9/40].
    assert abs(flat[1] - 3 / 40) < 1e-15
    assert abs(flat[2] - 9 / 40) < 1e-15
