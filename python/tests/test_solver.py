"""Layer-2 correctness: the JAX batched solver against closed-form
solutions and torchode's behavioral contract (per-instance state)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.controller import Controller
from compile.model import make_vdp_step, mlp_dynamics, mlp_init, vdp_dynamics
from compile.solver import SolverConfig, make_solver, solve_ivp


def expdec(t, y):
    return -y


def grid(batch, t0, t1, e):
    return jnp.broadcast_to(jnp.linspace(t0, t1, e), (batch, e)).astype(jnp.float32)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_exponential_decay(use_pallas):
    b, e = 3, 9
    y0 = jnp.asarray([[1.0], [2.0], [-0.5]], jnp.float32)
    te = grid(b, 0.0, 2.0, e)
    ys, stats = solve_ivp(expdec, y0, te, atol=1e-6, rtol=1e-6, use_pallas=use_pallas)
    exact = np.asarray(y0)[:, None, :] * np.exp(-np.asarray(te))[:, :, None]
    np.testing.assert_allclose(np.asarray(ys), exact, atol=5e-5)
    assert (np.asarray(stats["status"]) == 0).all()


@pytest.mark.parametrize("method", ["dopri5", "tsit5", "bosh3"])
def test_methods_agree(method):
    b, e = 2, 6
    y0 = jnp.asarray([[1.0, 0.5], [0.3, -0.2]], jnp.float32)
    te = grid(b, 0.0, 1.5, e)
    ys, stats = solve_ivp(
        expdec, y0, te, method=method, atol=1e-6, rtol=1e-6, use_pallas=False
    )
    exact = np.asarray(y0)[:, None, :] * np.exp(-np.asarray(te))[:, :, None]
    np.testing.assert_allclose(np.asarray(ys), exact, atol=2e-4)


def test_per_instance_steps_vdp():
    """Stiffer instances take more steps — the parallel-solving signature."""
    b, e = 4, 21
    mu = jnp.asarray([1.0, 2.0, 5.0, 10.0], jnp.float32)
    y0 = jnp.tile(jnp.asarray([[2.0, 0.0]], jnp.float32), (b, 1))
    te = grid(b, 0.0, 10.0, e)
    ys, stats = solve_ivp(vdp_dynamics(mu), y0, te, atol=1e-5, rtol=1e-5,
                          use_pallas=False)
    steps = np.asarray(stats["n_steps"])
    assert (np.diff(steps) > 0).all(), steps
    assert (np.asarray(stats["status"]) == 0).all()
    # n_f_evals uniform across the batch (torchode Listing 1 semantics).
    assert len(set(np.asarray(stats["n_f_evals"]).tolist())) == 1


def test_stiff_instance_does_not_change_easy_instance():
    """§4.1: the easy instance's answer must not depend on its batchmates."""
    e = 11
    y0_solo = jnp.asarray([[2.0, 0.0]], jnp.float32)
    te1 = grid(1, 0.0, 5.0, e)
    ys_solo, st_solo = solve_ivp(
        vdp_dynamics(jnp.asarray([1.0])), y0_solo, te1, atol=1e-5, rtol=1e-5,
        use_pallas=False,
    )
    mu = jnp.asarray([1.0, 30.0], jnp.float32)
    y0 = jnp.asarray([[2.0, 0.0], [2.0, 0.0]], jnp.float32)
    te2 = grid(2, 0.0, 5.0, e)
    ys_mix, st_mix = solve_ivp(vdp_dynamics(mu), y0, te2, atol=1e-5, rtol=1e-5,
                               use_pallas=False)
    # Identical controller state machine => identical trajectory and steps.
    np.testing.assert_allclose(np.asarray(ys_mix)[0], np.asarray(ys_solo)[0],
                               rtol=1e-6, atol=1e-6)
    assert int(st_mix["n_steps"][0]) == int(st_solo["n_steps"][0])


def test_pallas_and_ref_paths_agree():
    b, e = 4, 11
    mu = jnp.asarray([1.0, 2.0, 4.0, 8.0], jnp.float32)
    y0 = jnp.tile(jnp.asarray([[2.0, 0.0]], jnp.float32), (b, 1))
    te = grid(b, 0.0, 5.0, e)
    ys_a, st_a = solve_ivp(vdp_dynamics(mu), y0, te, use_pallas=True)
    ys_b, st_b = solve_ivp(vdp_dynamics(mu), y0, te, use_pallas=False)
    np.testing.assert_allclose(np.asarray(ys_a), np.asarray(ys_b), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(st_a["n_steps"]),
                                  np.asarray(st_b["n_steps"]))


def test_max_steps_status():
    cfg_kw = dict(atol=1e-9, rtol=1e-9, max_steps=5, use_pallas=False)
    b, e = 1, 5
    mu = jnp.asarray([50.0], jnp.float32)
    y0 = jnp.asarray([[2.0, 0.0]], jnp.float32)
    te = grid(b, 0.0, 20.0, e)
    _, stats = solve_ivp(vdp_dynamics(mu), y0, te, **cfg_kw)
    assert int(stats["status"][0]) == 1  # MAX_STEPS


def test_pid_controller_changes_step_count():
    b, e = 1, 11
    mu = jnp.asarray([25.0], jnp.float32)
    y0 = jnp.asarray([[2.0, 0.0]], jnp.float32)
    te = grid(b, 0.0, 40.0, e)
    f = vdp_dynamics(mu)
    ys_i, st_i = solve_ivp(f, y0, te, atol=1e-5, rtol=1e-5, use_pallas=False)
    cfg = SolverConfig(atol=1e-5, rtol=1e-5, use_pallas=False,
                       controller=Controller(pcoeff=0.2, icoeff=0.4))
    ys_p, st_p = make_solver(f, cfg)(y0, te)
    assert (np.asarray(st_i["status"]) == 0).all()
    assert (np.asarray(st_p["status"]) == 0).all()
    # Both must solve correctly; counts differ (the App. C effect).
    np.testing.assert_allclose(np.asarray(ys_i), np.asarray(ys_p), rtol=0.05,
                               atol=0.05)
    assert int(st_p["n_steps"][0]) != int(st_i["n_steps"][0])


def test_mlp_dynamics_solve():
    d = 3
    params = mlp_init([d + 1, 16, d], jax.random.PRNGKey(1))
    f = mlp_dynamics(params)
    b, e = 2, 5
    y0 = jnp.asarray(np.random.default_rng(0).normal(size=(b, d)), jnp.float32)
    te = grid(b, 0.0, 1.0, e)
    ys, stats = solve_ivp(f, y0, te, atol=1e-4, rtol=1e-4, use_pallas=False)
    assert np.isfinite(np.asarray(ys)).all()
    assert (np.asarray(stats["status"]) == 0).all()


def test_single_step_matches_solver_first_step():
    """The step artifact computes the same proposal the full solver makes."""
    b = 4
    mu = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    y0 = jnp.tile(jnp.asarray([[2.0, 0.0]], jnp.float32), (b, 1))
    f = vdp_dynamics(mu)
    t = jnp.zeros((b,), jnp.float32)
    dt = jnp.full((b,), 0.01, jnp.float32)
    k0 = f(t, y0)
    step = make_vdp_step(use_pallas=False)
    y_new, en, k_last = step(dt, y0, k0, mu)
    # 5th-order check against a tiny-step "truth" via the full solver.
    te = jnp.stack([jnp.zeros(b), jnp.full((b,), 0.01)], axis=1).astype(jnp.float32)
    ys, _ = solve_ivp(f, y0, te, atol=1e-9, rtol=1e-9, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_new), np.asarray(ys)[:, -1, :],
                               rtol=1e-4, atol=1e-6)
    assert np.asarray(en).shape == (b,)
    # FSAL: k_last == f(t+dt, y_new).
    np.testing.assert_allclose(np.asarray(k_last),
                               np.asarray(f(t + dt, y_new)), rtol=1e-5, atol=1e-6)


def test_jit_compiles_whole_solver():
    """The entire loop must be jit-able with zero host callbacks."""
    b, e = 2, 5
    y0 = jnp.ones((b, 1), jnp.float32)
    te = grid(b, 0.0, 1.0, e)
    fn = jax.jit(lambda y0, te: solve_ivp(expdec, y0, te, use_pallas=False))
    ys1, st1 = fn(y0, te)
    ys2, st2 = fn(y0, te)  # cached executable
    np.testing.assert_array_equal(np.asarray(ys1), np.asarray(ys2))
