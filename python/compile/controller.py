"""Vectorized integral / PID step-size controller (Layer 2).

Mirror of `rust/src/solver/controller.rs` — the same Söderlind/diffrax
formulation, vectorized over the batch so every instance carries its own
error history inside the lowered while-loop.
"""

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class Controller:
    pcoeff: float = 0.0
    icoeff: float = 1.0
    dcoeff: float = 0.0
    safety: float = 0.9
    factor_min: float = 0.2
    factor_max: float = 10.0

    def betas(self, err_order: int):
        k = err_order + 1.0
        return (
            (self.pcoeff + self.icoeff + self.dcoeff) / k,
            -(self.pcoeff + 2.0 * self.dcoeff) / k,
            self.dcoeff / k,
        )

    def decide(self, err_norm, err_prev, err_prev2, err_order: int):
        """Vectorized accept/factor. All inputs (B,). Returns
        (accept (B,) bool, factor (B,))."""
        b1, b2, b3 = self.betas(err_order)
        finite = jnp.isfinite(err_norm)
        accept = (err_norm <= 1.0) & finite
        e0 = jnp.maximum(jnp.where(finite, err_norm, 1.0), 1e-10)
        factor = self.safety * e0**-b1 * err_prev**-b2 * err_prev2**-b3
        factor = jnp.clip(factor, self.factor_min, self.factor_max)
        factor = jnp.where(accept, factor, jnp.minimum(factor, 1.0))
        factor = jnp.where(finite, factor, self.factor_min)
        return accept, factor


INTEGRAL = Controller()
