"""Butcher tableaus for the JAX (Layer-2) solver.

Single source of truth shared with the Rust core: `python -m
compile.tableaus out.json` dumps every tableau to JSON, and the Rust test
`tests/tableau_cross_check.rs` asserts the static tables in
`rust/src/solver/tableau.rs` match to 1e-15.
"""

import json
import sys
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class Tableau:
    name: str
    order: int
    err_order: int
    # Full (stages, stages) strictly-lower-triangular stage matrix.
    a: np.ndarray
    b: np.ndarray
    b_err: np.ndarray  # b - b_hat; empty array if fixed-step only
    c: np.ndarray
    fsal: bool
    dense: str = "hermite"  # or "dopri5"
    d: np.ndarray = field(default_factory=lambda: np.zeros(0))

    @property
    def stages(self) -> int:
        return len(self.b)

    def a_flat(self) -> list:
        """Strictly-lower-triangular entries, row by row (Rust layout)."""
        out = []
        for i in range(1, self.stages):
            out.extend(self.a[i, :i].tolist())
        return out


def _tri(rows):
    """Build a dense (s, s) matrix from ragged lower-triangular rows."""
    s = len(rows) + 1
    a = np.zeros((s, s))
    for i, row in enumerate(rows, start=1):
        a[i, : len(row)] = row
    return a


DOPRI5 = Tableau(
    name="dopri5",
    order=5,
    err_order=4,
    a=_tri(
        [
            [1 / 5],
            [3 / 40, 9 / 40],
            [44 / 45, -56 / 15, 32 / 9],
            [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729],
            [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656],
            [35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84],
        ]
    ),
    b=np.array([35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0]),
    b_err=np.array(
        [
            71 / 57600,
            0.0,
            -71 / 16695,
            71 / 1920,
            -17253 / 339200,
            22 / 525,
            -1 / 40,
        ]
    ),
    c=np.array([0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0]),
    fsal=True,
    dense="dopri5",
    d=np.array(
        [
            -12715105075 / 11282082432,
            0.0,
            87487479700 / 32700410799,
            -10690763975 / 1880347072,
            701980252875 / 199316789632,
            -1453857185 / 822651844,
            69997945 / 29380423,
        ]
    ),
)

TSIT5 = Tableau(
    name="tsit5",
    order=5,
    err_order=4,
    a=_tri(
        [
            [0.161],
            [-0.008480655492356989, 0.335480655492357],
            [2.8971530571054935, -6.359448489975075, 4.3622954328695815],
            [
                5.325864828439257,
                -11.748883564062828,
                7.4955393428898365,
                -0.09249506636175525,
            ],
            [
                5.86145544294642,
                -12.92096931784711,
                8.159367898576159,
                -0.071584973281401,
                -0.028269050394068383,
            ],
            [
                0.09646076681806523,
                0.01,
                0.4798896504144996,
                1.379008574103742,
                -3.290069515436081,
                2.324710524099774,
            ],
        ]
    ),
    b=np.array(
        [
            0.09646076681806523,
            0.01,
            0.4798896504144996,
            1.379008574103742,
            -3.290069515436081,
            2.324710524099774,
            0.0,
        ]
    ),
    b_err=np.array(
        [
            -0.00178001105222577714,
            -0.0008164344596567469,
            0.007880878010261995,
            -0.1447110071732629,
            0.5823571654525552,
            -0.45808210592918697,
            0.015151515151515152,
        ]
    ),
    c=np.array([0.0, 0.161, 0.327, 0.9, 0.9800255409045097, 1.0, 1.0]),
    fsal=True,
)

BOSH3 = Tableau(
    name="bosh3",
    order=3,
    err_order=2,
    a=_tri([[0.5], [0.0, 0.75], [2 / 9, 1 / 3, 4 / 9]]),
    b=np.array([2 / 9, 1 / 3, 4 / 9, 0.0]),
    b_err=np.array([2 / 9 - 7 / 24, 1 / 3 - 1 / 4, 4 / 9 - 1 / 3, -1 / 8]),
    c=np.array([0.0, 0.5, 0.75, 1.0]),
    fsal=True,
)

ALL = {t.name: t for t in (DOPRI5, TSIT5, BOSH3)}


def get(name: str) -> Tableau:
    return ALL[name]


def to_json() -> str:
    """Dump all tableaus for the Rust golden test."""
    payload = {}
    for name, t in ALL.items():
        payload[name] = {
            "order": t.order,
            "err_order": t.err_order,
            "stages": t.stages,
            "a": t.a_flat(),
            "b": t.b.tolist(),
            "b_err": t.b_err.tolist(),
            "c": t.c.tolist(),
            "fsal": t.fsal,
        }
    return json.dumps(payload, indent=1)


if __name__ == "__main__":
    out = sys.argv[1] if len(sys.argv) > 1 else "/dev/stdout"
    with open(out, "w") as f:
        f.write(to_json())
