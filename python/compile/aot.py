"""AOT lowering: JAX (L2 + L1) → HLO text → `artifacts/`.

HLO *text* is the interchange format, not `.serialize()`d protos: jax ≥0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is described in `artifacts/manifest.json` (shapes, dtypes,
outputs) so the Rust runtime can build input literals without guessing.

Usage: `python -m compile.aot --out ../artifacts` (the Makefile target).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .controller import Controller
from .model import make_mlp_solve, make_vdp_solve, make_vdp_step, mlp_init

SOLVE_OUTPUTS = ["ys", "n_steps", "n_accepted", "n_f_evals", "status"]
STEP_OUTPUTS = ["y_new", "err_norm", "k_last"]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust's
    `to_tuple` unpacking)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def build_artifacts(out_dir: str, *, small_only: bool = False):
    """Lower every artifact; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}

    def emit(name, lowered, inputs, outputs, extra=None):
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
            **(extra or {}),
        }
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO text")

    f32 = jnp.float32

    # --- full-solve VdP artifacts -------------------------------------------
    # (paper Table 3 setup: B=256, E=200, dopri5, tol 1e-5; plus a small
    # variant for tests and the serve example.)
    sizes = [(8, 20)] if small_only else [(8, 20), (64, 50), (256, 200)]
    for B, E in sizes:
        name = f"solve_vdp_b{B}_e{E}"
        fn = make_vdp_solve(atol=1e-5, rtol=1e-5, max_steps=5_000)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((B, 2), f32),
            jax.ShapeDtypeStruct((B,), f32),
            jax.ShapeDtypeStruct((B, E), f32),
        )
        emit(
            name,
            lowered,
            inputs=[_spec((B, 2)), _spec((B,)), _spec((B, E))],
            outputs=[
                {"name": "ys", **_spec((B, E, 2))},
                {"name": "n_steps", **_spec((B,), "s32")},
                {"name": "n_accepted", **_spec((B,), "s32")},
                {"name": "n_f_evals", **_spec((B,), "s32")},
                {"name": "status", **_spec((B,), "s32")},
            ],
            extra={"kind": "solve", "problem": "vdp", "batch": B, "n_eval": E},
        )

    # PID-controller variant (Appendix C ablation through the AOT path).
    if not small_only:
        B, E = 8, 20
        name = f"solve_vdp_pid_b{B}_e{E}"
        fn = make_vdp_solve(
            atol=1e-5, rtol=1e-5, max_steps=5_000,
            controller=Controller(pcoeff=0.2, icoeff=0.4, dcoeff=0.0),
        )
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((B, 2), f32),
            jax.ShapeDtypeStruct((B,), f32),
            jax.ShapeDtypeStruct((B, E), f32),
        )
        emit(
            name,
            lowered,
            inputs=[_spec((B, 2)), _spec((B,)), _spec((B, E))],
            outputs=[
                {"name": "ys", **_spec((B, E, 2))},
                {"name": "n_steps", **_spec((B,), "s32")},
                {"name": "n_accepted", **_spec((B,), "s32")},
                {"name": "n_f_evals", **_spec((B,), "s32")},
                {"name": "status", **_spec((B,), "s32")},
            ],
            extra={"kind": "solve", "problem": "vdp", "batch": B, "n_eval": E,
                   "controller": "pid(0.2,0.4,0)"},
        )

    # --- single-step VdP artifact (L3-driven stepping engine) ---------------
    for B in ([8] if small_only else [8, 256]):
        name = f"step_vdp_b{B}"
        fn = make_vdp_step()
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((B,), f32),
            jax.ShapeDtypeStruct((B, 2), f32),
            jax.ShapeDtypeStruct((B, 2), f32),
            jax.ShapeDtypeStruct((B,), f32),
        )
        emit(
            name,
            lowered,
            inputs=[_spec((B,)), _spec((B, 2)), _spec((B, 2)), _spec((B,))],
            outputs=[
                {"name": "y_new", **_spec((B, 2))},
                {"name": "err_norm", **_spec((B,))},
                {"name": "k_last", **_spec((B, 2))},
            ],
            extra={"kind": "step", "problem": "vdp", "batch": B},
        )

    # --- MLP-dynamics full solve (learned-model serving demo) ---------------
    if not small_only:
        B, D, E = 16, 4, 10
        params = mlp_init([D + 1, 32, D], jax.random.PRNGKey(0))
        name = f"solve_mlp_b{B}_d{D}_e{E}"
        fn = make_mlp_solve(params, atol=1e-4, rtol=1e-4, max_steps=1_000)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((B, D), f32),
            jax.ShapeDtypeStruct((B, E), f32),
        )
        emit(
            name,
            lowered,
            inputs=[_spec((B, D)), _spec((B, E))],
            outputs=[
                {"name": "ys", **_spec((B, E, D))},
                {"name": "n_steps", **_spec((B,), "s32")},
                {"name": "n_accepted", **_spec((B,), "s32")},
                {"name": "n_f_evals", **_spec((B,), "s32")},
                {"name": "status", **_spec((B,), "s32")},
            ],
            extra={"kind": "solve", "problem": "mlp", "batch": B, "n_eval": E,
                   "dim": D},
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--small-only", action="store_true",
                    help="only the quick test artifacts (CI mode)")
    args = ap.parse_args()
    print(f"lowering artifacts to {args.out} ...")
    manifest = build_artifacts(args.out, small_only=args.small_only)
    print(f"wrote {len(manifest)} artifacts + manifest.json")


if __name__ == "__main__":
    main()
