"""Pallas kernel: dense-output evaluation via Horner's rule.

The paper: "fast polynomial evaluation via Horner's rule that saves half
of the multiplications over the naive evaluation method". The dopri5
interpolant in Hairer's rcont form is evaluated for *all* E evaluation
points of a block in one kernel:

    y(θ) = r1 + θ·(r2 + (1−θ)·(r3 + θ·(r4 + (1−θ)·r5)))

(4 multiplies per point instead of the 8 a naive power-basis evaluation
needs). The solver masks out points not inside the current step — the
TPU-friendly replacement for torchode's boolean-tensor indexing.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _interp_kernel(rcont_ref, theta_ref, o_ref):
    rc = rcont_ref[...]  # (5, bB, D)
    th = theta_ref[...][:, :, None]  # (bB, E, 1)
    th1 = 1.0 - th
    r1 = rc[0][:, None, :]
    r2 = rc[1][:, None, :]
    r3 = rc[2][:, None, :]
    r4 = rc[3][:, None, :]
    r5 = rc[4][:, None, :]
    o_ref[...] = r1 + th * (r2 + th1 * (r3 + th * (r4 + th1 * r5)))


@functools.partial(jax.jit, static_argnames=("block_b",))
def dopri5_eval(rcont, theta, block_b=None):
    """Evaluate the interpolant at all points.

    rcont: (5, B, D); theta: (B, E). Returns (B, E, D).
    """
    _, bsz, d = rcont.shape
    e = theta.shape[1]
    if block_b is None or block_b > bsz:
        block_b = bsz
    assert bsz % block_b == 0
    grid = (bsz // block_b,)
    return pl.pallas_call(
        _interp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, block_b, d), lambda i: (0, i, 0)),
            pl.BlockSpec((block_b, e), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, e, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, e, d), rcont.dtype),
        interpret=True,
    )(rcont, theta)
