"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has its semantics defined *here*; pytest
asserts `kernel(x) == ref(x)` to float tolerance over hypothesis-generated
shapes and inputs. The refs are also used directly by the solver when
`use_pallas=False` (the L2 ablation of DESIGN.md §Perf).
"""

import jax.numpy as jnp


def rk_combine_ref(k, y, dt, b, b_err):
    """Fused solution/error combination.

    k:     (S, B, D) stage slopes
    y:     (B, D)    step-start state
    dt:    (B,)      per-instance step size
    b:     (S,)      solution weights
    b_err: (S,)      error weights (b - b_hat)

    Returns (y_new (B, D), err (B, D)).
    """
    acc = jnp.einsum("s,sbd->bd", b, k)
    acc_err = jnp.einsum("s,sbd->bd", b_err, k)
    y_new = y + dt[:, None] * acc
    err = dt[:, None] * acc_err
    return y_new, err


def stage_accum_ref(k, y, dt, a_row):
    """Stage-input accumulation `y + dt * Σ_j a_j k_j` over the first
    `len(a_row)` stages.

    k: (S, B, D), a_row: (S,) zero-padded. Returns (B, D).
    """
    acc = jnp.einsum("s,sbd->bd", a_row, k)
    return y + dt[:, None] * acc


def error_norm_ref(err, y0, y1, atol, rtol):
    """Tolerance-scaled RMS norm per instance.

    err, y0, y1: (B, D); atol, rtol: scalars. Returns (B,).
    """
    scale = atol + rtol * jnp.maximum(jnp.abs(y0), jnp.abs(y1))
    r = err / scale
    return jnp.sqrt(jnp.mean(r * r, axis=-1))


def dopri5_coeffs_ref(k, y0, y1, dt, d):
    """Dopri5 dense-output rcont coefficients.

    k: (7, B, D), y0/y1: (B, D), dt: (B,), d: (7,) the Hairer d-weights.
    Returns rcont (5, B, D).
    """
    ydiff = y1 - y0
    bspl = dt[:, None] * k[0] - ydiff
    r1 = y0
    r2 = ydiff
    r3 = bspl
    r4 = ydiff - dt[:, None] * k[6] - bspl
    r5 = dt[:, None] * jnp.einsum("s,sbd->bd", d, k)
    return jnp.stack([r1, r2, r3, r4, r5])


def dopri5_eval_ref(rcont, theta):
    """Evaluate the dopri5 interpolant (Horner-nested form).

    rcont: (5, B, D), theta: (B, E). Returns (B, E, D).
    """
    th = theta[:, :, None]  # (B, E, 1)
    th1 = 1.0 - th
    r1, r2, r3, r4, r5 = (rcont[i][:, None, :] for i in range(5))
    return r1 + th * (r2 + th1 * (r3 + th * (r4 + th1 * r5)))


def hermite_eval_ref(y0, f0, y1, f1, dt, theta):
    """Cubic Hermite dense output in Horner form.

    y0/f0/y1/f1: (B, D), dt: (B,), theta: (B, E). Returns (B, E, D).
    """
    d = y1 - y0
    a = dt[:, None] * f0
    b = 3.0 * d - dt[:, None] * (2.0 * f0 + f1)
    c = -2.0 * d + dt[:, None] * (f0 + f1)
    th = theta[:, :, None]
    return y0[:, None, :] + th * (a[:, None, :] + th * (b[:, None, :] + th * c[:, None, :]))
