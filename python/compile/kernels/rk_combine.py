"""Pallas kernel: fused RK solution + error combination.

The torchode optimization this reproduces: the PyTorch version fuses the
stage combination into few kernels (`einsum`/`addcmul`); here the whole
combine — `y_new = y + dt·(b·K)` and `err = dt·(e·K)` — is **one** Pallas
kernel, so K, y and both outputs make exactly one HBM→VMEM round trip.

TPU mapping (DESIGN.md §Hardware-Adaptation): the batch dimension is tiled
by `block_b`; each block holds `(S, block_b, D)` of K plus `(block_b, D)`
of y in VMEM. The coefficient vectors are compile-time constants (stage
counts are tiny), so the stage reduction unrolls into S fused
multiply-adds on the VPU — no MXU needed at these operand shapes, and no
intermediate ever leaves VMEM.

Kernels are lowered with `interpret=True`: the CPU PJRT plugin cannot run
Mosaic custom-calls (see /opt/xla-example/README.md); on a real TPU the
same `pallas_call` compiles natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(k_ref, y_ref, dt_ref, o_y_ref, o_err_ref, *, b, b_err):
    """One batch block: K (S, bB, D), y (bB, D), dt (bB,)."""
    k = k_ref[...]
    y = y_ref[...]
    dt = dt_ref[...]
    s = k.shape[0]
    # Unrolled stage reduction; coefficients are python floats (constants).
    acc = jnp.zeros_like(y)
    acc_err = jnp.zeros_like(y)
    for j in range(s):
        bj = float(b[j])
        ej = float(b_err[j])
        if bj != 0.0:
            acc = acc + bj * k[j]
        if ej != 0.0:
            acc_err = acc_err + ej * k[j]
    o_y_ref[...] = y + dt[:, None] * acc
    o_err_ref[...] = dt[:, None] * acc_err


@functools.partial(jax.jit, static_argnames=("b", "b_err", "block_b"))
def rk_combine(k, y, dt, b, b_err, block_b=None):
    """Fused `(y_new, err)` from stage slopes.

    k: (S, B, D); y: (B, D); dt: (B,); b, b_err: length-S tuples of floats
    (static). Returns (y_new (B, D), err (B, D)).
    """
    s, bsz, d = k.shape
    if block_b is None or block_b > bsz:
        block_b = bsz
    assert bsz % block_b == 0, "batch must divide by block_b"
    grid = (bsz // block_b,)
    kernel = functools.partial(_combine_kernel, b=b, b_err=b_err)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, block_b, d), lambda i: (0, i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, d), k.dtype),
            jax.ShapeDtypeStruct((bsz, d), k.dtype),
        ],
        interpret=True,
    )(k, y, dt)


def _stage_accum_kernel(k_ref, y_ref, dt_ref, o_ref, *, a_row):
    k = k_ref[...]
    y = y_ref[...]
    dt = dt_ref[...]
    acc = jnp.zeros_like(y)
    for j, aj in enumerate(a_row):
        aj = float(aj)
        if aj != 0.0:
            acc = acc + aj * k[j]
    o_ref[...] = y + dt[:, None] * acc


@functools.partial(jax.jit, static_argnames=("a_row", "block_b"))
def stage_accum(k, y, dt, a_row, block_b=None):
    """Fused stage-input accumulation `y + dt Σ_j a_j k_j`.

    k: (S, B, D) (only the first len-nonzero entries of `a_row` are read);
    a_row: length-S tuple (static, zero-padded). Returns (B, D).
    """
    s, bsz, d = k.shape
    if block_b is None or block_b > bsz:
        block_b = bsz
    assert bsz % block_b == 0
    grid = (bsz // block_b,)
    kernel = functools.partial(_stage_accum_kernel, a_row=a_row)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, block_b, d), lambda i: (0, i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, d), k.dtype),
        interpret=True,
    )(k, y, dt)
