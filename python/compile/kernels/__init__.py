"""Layer-1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""

from . import ref
from .error_norm import error_norm
from .interp import dopri5_eval
from .rk_combine import rk_combine, stage_accum

__all__ = ["ref", "error_norm", "dopri5_eval", "rk_combine", "stage_accum"]
