"""Pallas kernel: fused tolerance-scaled RMS error norm.

torchode computes `|err| / (atol + rtol·max(|y0|,|y1|))` and its RMS with a
chain of elementwise kernels; here the whole reduction is one Pallas kernel
— abs, max, scale, divide, square, mean and sqrt never materialize
intermediates in HBM. Per batch block the VMEM footprint is 3·block_b·D
inputs + block_b outputs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _norm_kernel(err_ref, y0_ref, y1_ref, o_ref, *, atol, rtol):
    err = err_ref[...]
    y0 = y0_ref[...]
    y1 = y1_ref[...]
    scale = atol + rtol * jnp.maximum(jnp.abs(y0), jnp.abs(y1))
    r = err / scale
    o_ref[...] = jnp.sqrt(jnp.mean(r * r, axis=-1))


@functools.partial(jax.jit, static_argnames=("atol", "rtol", "block_b"))
def error_norm(err, y0, y1, atol, rtol, block_b=None):
    """Per-instance scaled RMS norm. err/y0/y1: (B, D) → (B,)."""
    bsz, d = err.shape
    if block_b is None or block_b > bsz:
        block_b = bsz
    assert bsz % block_b == 0
    grid = (bsz // block_b,)
    kernel = functools.partial(_norm_kernel, atol=float(atol), rtol=float(rtol))
    spec = pl.BlockSpec((block_b, d), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), err.dtype),
        interpret=True,
    )(err, y0, y1)
