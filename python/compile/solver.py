"""Layer 2: the batched parallel ODE solver as a single JAX computation.

This is torchode's solver loop re-expressed for AOT compilation: the
entire adaptive loop — per-instance time, step size, controller history,
accept/reject, dense output and statistics — is one `lax.while_loop`
inside one lowered HLO module. There is **no host round trip anywhere**:
where the PyTorch implementation works to avoid CPU↔GPU syncs, the AOT
module makes them impossible by construction (DESIGN.md
§Hardware-Adaptation).

Static shapes throughout: batch B, state dim D, evaluation points E. The
eval-point bookkeeping of torchode (boolean-tensor indexing) becomes a
masked interpolation over all E points per accepted step — statically
shaped and TPU-friendly.

The hot spots call the Layer-1 Pallas kernels (`use_pallas=True`) or their
jnp references (`use_pallas=False`, the L2 ablation).
"""

from dataclasses import dataclass
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import tableaus
from .controller import Controller
from .kernels import ref
from .kernels.error_norm import error_norm as pallas_error_norm
from .kernels.interp import dopri5_eval as pallas_dopri5_eval
from .kernels.rk_combine import rk_combine as pallas_rk_combine

STATUS_SUCCESS = 0
STATUS_MAX_STEPS = 1


class SolverState(NamedTuple):
    t: jnp.ndarray  # (B,)
    dt: jnp.ndarray  # (B,)
    y: jnp.ndarray  # (B, D)
    k0: jnp.ndarray  # (B, D) FSAL cache
    finished: jnp.ndarray  # (B,) bool
    err_prev: jnp.ndarray  # (B,)
    err_prev2: jnp.ndarray  # (B,)
    ys: jnp.ndarray  # (B, E, D) dense outputs
    n_steps: jnp.ndarray  # (B,) int32
    n_accepted: jnp.ndarray  # (B,) int32
    n_fevals: jnp.ndarray  # (B,) int32
    iters: jnp.ndarray  # () int32


@dataclass(frozen=True)
class SolverConfig:
    method: str = "dopri5"
    atol: float = 1e-6
    rtol: float = 1e-5
    max_steps: int = 10_000
    controller: Controller = Controller()
    use_pallas: bool = True


def _hairer_dt0(f, t0, y0, f0, order, atol, rtol):
    """Vectorized Hairer initial-step heuristic (one extra f eval)."""
    scale = atol + rtol * jnp.abs(y0)
    d0 = jnp.sqrt(jnp.mean((y0 / scale) ** 2, axis=-1))
    d1 = jnp.sqrt(jnp.mean((f0 / scale) ** 2, axis=-1))
    h0 = jnp.where((d0 < 1e-5) | (d1 < 1e-5), 1e-6, 0.01 * d0 / d1)
    y1 = y0 + h0[:, None] * f0
    f1 = f(t0 + h0, y1)
    d2 = jnp.sqrt(jnp.mean(((f1 - f0) / scale) ** 2, axis=-1)) / h0
    dmax = jnp.maximum(d1, d2)
    h1 = jnp.where(
        dmax <= 1e-15,
        jnp.maximum(h0 * 1e-3, 1e-6),
        (0.01 / dmax) ** (1.0 / (order + 1.0)),
    )
    return jnp.minimum(100.0 * h0, h1)


def make_solver(
    f: Callable,
    cfg: SolverConfig,
) -> Callable:
    """Build `solve(y0, t_eval) -> (ys, stats)` for dynamics `f(t, y)`.

    `f` maps `(t (B,), y (B, D)) -> (B, D)` — evaluated on the whole batch
    with per-instance times, exactly like a learned model under vmap.

    Returns a jit-able function with static shapes:
      ys:     (B, E, D) dense outputs at `t_eval`
      stats:  dict of per-instance statistics + status
    """
    tab = tableaus.get(cfg.method)
    S = tab.stages
    b_tuple = tuple(float(x) for x in tab.b)
    berr_tuple = tuple(float(x) for x in tab.b_err)

    def combine(k, y, dt):
        if cfg.use_pallas:
            return pallas_rk_combine(k, y, dt, b_tuple, berr_tuple)
        return ref.rk_combine_ref(k, y, dt, jnp.asarray(tab.b), jnp.asarray(tab.b_err))

    def norm(err, y0, y1):
        if cfg.use_pallas:
            return pallas_error_norm(err, y0, y1, cfg.atol, cfg.rtol)
        return ref.error_norm_ref(err, y0, y1, cfg.atol, cfg.rtol)

    def interp(rcont, theta):
        if cfg.use_pallas:
            return pallas_dopri5_eval(rcont, theta)
        return ref.dopri5_eval_ref(rcont, theta)

    use_dopri_dense = tab.dense == "dopri5"
    d_weights = jnp.asarray(tab.d) if use_dopri_dense else None
    a_rows = [jnp.asarray(tab.a[s, :]) for s in range(S)]
    c_nodes = [float(c) for c in tab.c]

    def solve(y0, t_eval):
        B, D = y0.shape
        E = t_eval.shape[1]
        t0 = t_eval[:, 0]
        t1 = t_eval[:, -1]

        f0 = f(t0, y0)
        dt0 = _hairer_dt0(f, t0, y0, f0, tab.order, cfg.atol, cfg.rtol)
        dt0 = jnp.minimum(dt0, t1 - t0)

        ys = jnp.zeros((B, E, D), y0.dtype)
        ys = ys.at[:, 0, :].set(y0)

        trivial = (t1 - t0) <= 0.0
        state = SolverState(
            t=t0,
            dt=dt0,
            y=y0,
            k0=f0,
            finished=trivial,
            err_prev=jnp.ones((B,), y0.dtype),
            err_prev2=jnp.ones((B,), y0.dtype),
            ys=ys,
            n_steps=jnp.zeros((B,), jnp.int32),
            n_accepted=jnp.zeros((B,), jnp.int32),
            n_fevals=jnp.full((B,), 2, jnp.int32),  # f0 + dt0 probe
            iters=jnp.asarray(0, jnp.int32),
        )

        def cond(st: SolverState):
            return (~jnp.all(st.finished)) & (st.iters < cfg.max_steps)

        def body(st: SolverState):
            active = ~st.finished
            remaining = t1 - st.t
            clamp = st.dt >= remaining
            dt = jnp.where(clamp, remaining, st.dt)

            # --- stages (k0 from the FSAL cache) --------------------------
            ks = [st.k0]
            for s in range(1, S):
                ytmp = ref.stage_accum_ref(jnp.stack(ks + [jnp.zeros_like(st.y)] * (S - s)),
                                           st.y, dt, a_rows[s])
                ks.append(f(st.t + c_nodes[s] * dt, ytmp))
            k = jnp.stack(ks)  # (S, B, D)

            # --- fused combine + error norm (Pallas) ----------------------
            y_new, err = combine(k, st.y, dt)
            en = norm(err, st.y, y_new)

            accept, factor = cfg.controller.decide(
                en, st.err_prev, st.err_prev2, tab.err_order
            )
            accept = accept & active
            t_new = jnp.where(clamp, t1, st.t + dt)

            # --- dense output ---------------------------------------------
            # Mask of eval points inside (t, t_new] per instance.
            mask = (
                (t_eval > st.t[:, None])
                & (t_eval <= t_new[:, None])
                & accept[:, None]
            )
            theta = jnp.clip(
                (t_eval - st.t[:, None]) / jnp.maximum(dt, 1e-30)[:, None], 0.0, 1.0
            )
            if use_dopri_dense:
                rcont = ref.dopri5_coeffs_ref(k, st.y, y_new, dt, d_weights)
                interp_vals = interp(rcont, theta)
            else:
                f_end = k[-1] if tab.fsal else k[0]
                interp_vals = ref.hermite_eval_ref(st.y, k[0], y_new, f_end, dt, theta)
            ys = jnp.where(mask[:, :, None], interp_vals, st.ys)

            # --- state update -----------------------------------------------
            acc_f = accept[:, None]
            y_next = jnp.where(acc_f, y_new, st.y)
            t_next = jnp.where(accept, t_new, st.t)
            k0_next = jnp.where(acc_f, k[-1] if tab.fsal else st.k0, st.k0)
            dt_next = jnp.where(active, dt * factor, st.dt)
            err_prev = jnp.where(accept, jnp.maximum(en, 1e-10), st.err_prev)
            err_prev2 = jnp.where(accept, st.err_prev, st.err_prev2)
            finished = st.finished | (accept & (t_new >= t1))

            return SolverState(
                t=t_next,
                dt=dt_next,
                y=y_next,
                k0=k0_next,
                finished=finished,
                err_prev=err_prev,
                err_prev2=err_prev2,
                ys=ys,
                n_steps=st.n_steps + active.astype(jnp.int32),
                n_accepted=st.n_accepted + accept.astype(jnp.int32),
                # S-1 batched stage evals per iteration (k0 cached).
                n_fevals=st.n_fevals + jnp.asarray(S - 1, jnp.int32),
                iters=st.iters + 1,
            )

        st = lax.while_loop(cond, body, state)
        status = jnp.where(st.finished, STATUS_SUCCESS, STATUS_MAX_STEPS).astype(
            jnp.int32
        )
        stats = {
            "n_steps": st.n_steps,
            "n_accepted": st.n_accepted,
            "n_f_evals": st.n_fevals,
            "status": status,
        }
        return st.ys, stats

    return solve


def solve_ivp(f, y0, t_eval, **kwargs):
    """Convenience one-shot API mirroring torchode's `solve_ivp`."""
    cfg = SolverConfig(**kwargs)
    return make_solver(f, cfg)(y0, t_eval)
