"""Layer 2 entry points: the dynamics + assembled solve/step functions
that `aot.py` lowers to HLO artifacts.

Two execution granularities are exported, matching the two PJRT engines in
`rust/src/runtime/`:

- **full-solve** (`make_vdp_solve`, `make_mlp_solve`): the entire adaptive
  loop in one module — the torchode-JIT analogue. Rust calls it once per
  batch.
- **single-step** (`make_vdp_step`): one RK attempt (stages + fused
  combine + error norm); Rust owns accept/reject and the controller — the
  eager-engine analogue, used to measure what host-side loop control
  costs.
"""

from functools import partial

import jax
import jax.numpy as jnp

from . import tableaus
from .controller import Controller
from .kernels import ref
from .kernels.error_norm import error_norm as pallas_error_norm
from .kernels.rk_combine import rk_combine as pallas_rk_combine
from .solver import SolverConfig, make_solver


def vdp_dynamics(mu):
    """Van der Pol with per-instance damping `mu (B,)`."""

    def f(t, y):
        x, v = y[:, 0], y[:, 1]
        return jnp.stack([v, mu * (1.0 - x * x) * v - x], axis=-1)

    return f


def mlp_init(sizes, key):
    """Glorot-initialized MLP parameters as a flat list of (w, b)."""
    params = []
    for n_in, n_out in zip(sizes[:-1], sizes[1:]):
        key, sub = jax.random.split(key)
        lim = (6.0 / (n_in + n_out)) ** 0.5
        w = jax.random.uniform(sub, (n_out, n_in), jnp.float32, -lim, lim)
        params.append((w, jnp.zeros((n_out,), jnp.float32)))
    return params


def mlp_dynamics(params):
    """tanh-MLP dynamics `f(t, y) = MLP([y, t])` (CNF-style)."""

    def f(t, y):
        h = jnp.concatenate([y, t[:, None]], axis=-1)
        for i, (w, b) in enumerate(params):
            h = h @ w.T + b
            if i + 1 < len(params):
                h = jnp.tanh(h)
        return h

    return f


def make_vdp_solve(atol=1e-5, rtol=1e-5, max_steps=10_000, method="dopri5",
                   use_pallas=True, controller=Controller()):
    """`(y0 (B,2), mu (B,), t_eval (B,E)) -> (ys, n_steps, n_accepted,
    n_f_evals, status)` — the full-solve artifact."""

    cfg = SolverConfig(
        method=method,
        atol=atol,
        rtol=rtol,
        max_steps=max_steps,
        use_pallas=use_pallas,
        controller=controller,
    )

    def solve(y0, mu, t_eval):
        ys, stats = make_solver(vdp_dynamics(mu), cfg)(y0, t_eval)
        return (
            ys,
            stats["n_steps"],
            stats["n_accepted"],
            stats["n_f_evals"],
            stats["status"],
        )

    return solve


def make_mlp_solve(params, atol=1e-5, rtol=1e-5, max_steps=1_000,
                   method="dopri5", use_pallas=True):
    """Full-solve artifact for MLP dynamics with baked parameters."""

    cfg = SolverConfig(
        method=method, atol=atol, rtol=rtol, max_steps=max_steps, use_pallas=use_pallas
    )

    def solve(y0, t_eval):
        ys, stats = make_solver(mlp_dynamics(params), cfg)(y0, t_eval)
        return (
            ys,
            stats["n_steps"],
            stats["n_accepted"],
            stats["n_f_evals"],
            stats["status"],
        )

    return solve


def make_vdp_step(method="dopri5", atol=1e-5, rtol=1e-5, use_pallas=True):
    """Single RK attempt: `(dt, y, k0, mu) -> (y_new, err_norm, k_last)`.

    VdP is autonomous, so `t` does not appear in the signature — XLA would
    prune an unused parameter from the entry computation and desynchronize
    the manifest. The FSAL cache `k0 = f(y)` comes in from the caller (Rust
    keeps it across accepted steps); `k_last = f(y_new)` goes back out so
    the caller can reuse it on acceptance.
    """
    tab = tableaus.get(method)
    S = tab.stages
    b_tuple = tuple(float(x) for x in tab.b)
    berr_tuple = tuple(float(x) for x in tab.b_err)
    a_rows = [jnp.asarray(tab.a[s, :]) for s in range(S)]

    def step(dt, y, k0, mu):
        zero_t = jnp.zeros_like(dt)
        f = vdp_dynamics(mu)
        ks = [k0]
        for s in range(1, S):
            stack = jnp.stack(ks + [jnp.zeros_like(y)] * (S - s))
            ytmp = ref.stage_accum_ref(stack, y, dt, a_rows[s])
            ks.append(f(zero_t, ytmp))
        k = jnp.stack(ks)
        if use_pallas:
            y_new, err = pallas_rk_combine(k, y, dt, b_tuple, berr_tuple)
            en = pallas_error_norm(err, y, y_new, atol, rtol)
        else:
            y_new, err = ref.rk_combine_ref(
                k, y, dt, jnp.asarray(tab.b), jnp.asarray(tab.b_err)
            )
            en = ref.error_norm_ref(err, y, y_new, atol, rtol)
        return y_new, en, k[-1]

    return step
