//! End-to-end driver (Table 4 stand-in): train a graph-ODE "FEN" on a
//! synthetic advection–diffusion field, discretize-then-optimize (exact
//! backprop through the solver), log the loss curve, and report the MAE
//! plus the parallel-vs-joint solver statistics at evaluation time.
//!
//! This is the system-proving run of DESIGN.md: teacher data generation
//! (native adaptive solver) → training loop (fixed-step RK tape + Adam) →
//! evaluation (parallel and joint engines on the learned dynamics).
//!
//! ```text
//! cargo run --release --example fen_train [-- --steps 300]
//! ```

use rode::nn::{Adam, Parameterized, Rng64};
use rode::prelude::*;
use rode::problems::{FenDynamics, Mesh};
use rode::solver::backprop::{rk_backward, rk_forward_tape};
use std::fs;
use std::io::Write;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let train_steps: usize = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);

    fs::create_dir_all("results").expect("mkdir results");
    let mut rng = Rng64::new(7);

    // --- mesh + teacher data --------------------------------------------------
    let n_nodes = 24;
    let n_feat = 1;
    let mesh = Mesh::random_geometric(n_nodes, 0.35, &mut rng);
    println!(
        "mesh: {} nodes, {} directed edges",
        mesh.n_nodes(),
        mesh.graph.n_edges_directed()
    );
    let teacher = FenDynamics::teacher(&mesh, n_feat, 0.8, 0.3);
    let dim = n_nodes * n_feat;

    // Trajectories: random smooth initial fields, 10 snapshots over [0, 1].
    let n_train = 8;
    let n_test = 4;
    let horizon = 1.0;
    let snapshots = 10;
    let make_fields = |rng: &mut Rng64, n: usize| -> BatchVec {
        BatchVec::from_rows(
            &(0..n)
                .map(|_| {
                    // Smooth-ish random field: position-correlated values.
                    let (cx, cy) = (rng.uniform(), rng.uniform());
                    mesh.positions
                        .iter()
                        .map(|p| {
                            let d2 = (p[0] - cx).powi(2) + (p[1] - cy).powi(2);
                            2.0 * (-4.0 * d2).exp() + 0.3 * rng.normal()
                        })
                        .collect()
                })
                .collect::<Vec<_>>(),
        )
    };
    let solve_teacher = |y0: &BatchVec| -> Solution {
        let grid = TimeGrid::linspace_shared(y0.batch(), 0.0, horizon, snapshots);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8);
        let sol = solve_ivp_parallel(&teacher, y0, &grid, &opts);
        assert!(sol.all_success());
        sol
    };
    let y0_train = make_fields(&mut rng, n_train);
    let y0_test = make_fields(&mut rng, n_test);
    let truth_train = solve_teacher(&y0_train);
    let truth_test = solve_teacher(&y0_test);

    // --- model + training -----------------------------------------------------
    let mut model = FenDynamics::new(mesh.clone(), n_feat, 32, &mut rng);
    let n_params = rode::nn::Parameterized::n_params(&model);
    println!("FEN stand-in: dim {dim}, {n_params} parameters");
    let mut params = vec![0.0; n_params];
    model.params(&mut params);
    let mut opt = Adam::new(n_params, 3e-3);

    // Discretize-then-optimize: fixed-step RK4 tape over the horizon,
    // loss = MSE against the teacher snapshots.
    let steps_per_snap = 4;
    let n_rk = steps_per_snap * (snapshots - 1);
    let dt = horizon / n_rk as f64;

    let mut logf = fs::File::create("results/fen_loss.csv").unwrap();
    writeln!(logf, "step,train_mse").unwrap();
    let t_start = std::time::Instant::now();
    for step in 0..train_steps {
        let tape = rk_forward_tape(&model, &y0_train, 0.0, dt, n_rk, MethodId::RK4);
        // Loss gradient at each snapshot, accumulated by walking segments
        // backwards: here we use the terminal-sum formulation — seed the
        // gradient at the end and add snapshot seeds as the tape unwinds.
        // For simplicity and exactness we instead run one tape per snapshot
        // segment is wasteful; the standard trick: MSE over ALL snapshots
        // equals backprop through the full tape with seeds injected at
        // snapshot steps. rk_backward seeds only the terminal state, so we
        // backprop per snapshot suffix and sum (cost: snapshots × backward).
        let mut mse = 0.0;
        let mut grad = vec![0.0; n_params];
        let mut count: f64 = 0.0;
        for s in 1..snapshots {
            let step_idx = s * steps_per_snap;
            let y_s = tape.y_step(step_idx);
            // dL/dy at this snapshot: 2(y - target)/N
            let mut seed = BatchVec::zeros(n_train, dim);
            for i in 0..n_train {
                let target = truth_train.y(i, s);
                let got = y_s.row(i);
                let sr = seed.row_mut(i);
                for d in 0..dim {
                    let diff = got[d] - target[d];
                    mse += diff * diff;
                    sr[d] = 2.0 * diff;
                    count += 1.0;
                }
            }
            // Backprop through the tape prefix [0, step_idx]: re-tape the
            // prefix (cheap: share the same forward trajectory).
            let prefix = rk_forward_tape(&model, &y0_train, 0.0, dt, step_idx, MethodId::RK4);
            let (_, dp) = rk_backward(&model, &prefix, &seed);
            for (g, d) in grad.iter_mut().zip(&dp) {
                *g += d / count.max(1.0);
            }
        }
        mse /= count;
        opt.step(&mut params, &grad);
        model.set_params(&params);
        if step % 25 == 0 || step + 1 == train_steps {
            println!("step {step:>4}: train MSE {mse:.5}");
        }
        writeln!(logf, "{step},{mse}").unwrap();
    }
    println!(
        "trained {train_steps} steps in {:.1}s",
        t_start.elapsed().as_secs_f64()
    );

    // --- evaluation (the Table-4 metrics) --------------------------------------
    let grid = TimeGrid::linspace_shared(n_test, 0.0, horizon, snapshots);
    let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5);
    let par = solve_ivp_parallel(&model, &y0_test, &grid, &opts);
    let joint = solve_ivp_joint(&model, &y0_test, &grid, &opts);
    assert!(par.all_success() && joint.all_success());

    let mut mae = 0.0;
    let mut n = 0.0;
    for i in 0..n_test {
        for s in 0..snapshots {
            for d in 0..dim {
                mae += (par.y(i, s)[d] - truth_test.y(i, s)[d]).abs();
                n += 1.0;
            }
        }
    }
    mae /= n;
    // Baseline MAE: predicting the initial field forever.
    let mut mae0 = 0.0;
    for i in 0..n_test {
        for s in 0..snapshots {
            for d in 0..dim {
                mae0 += (y0_test.row(i)[d] - truth_test.y(i, s)[d]).abs();
            }
        }
    }
    mae0 /= n;

    println!("\n=== evaluation (test set) ===");
    println!("MAE (learned dynamics, parallel solve): {mae:.4}");
    println!("MAE (persistence baseline):             {mae0:.4}");
    println!(
        "solver steps — parallel per instance: {:?}, joint shared: {}",
        par.stats.iter().map(|s| s.n_steps).collect::<Vec<_>>(),
        joint.stats[0].n_steps
    );
    assert!(
        mae < 0.5 * mae0,
        "training failed to beat the persistence baseline ({mae} vs {mae0})"
    );
    println!("\nwrote results/fen_loss.csv");
}
