//! Quickstart — mirrors Listing 1 of the paper: solve a batch of Van der
//! Pol problems and inspect per-instance status + statistics.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use rode::prelude::*;

fn main() {
    let batch_size = 5;
    let mu = 10.0;

    // y0 = torch.randn((batch_size, 2))
    let mut rng = rode::nn::Rng64::new(42);
    let y0 = BatchVec::from_rows(
        &(0..batch_size)
            .map(|_| vec![rng.normal(), rng.normal()])
            .collect::<Vec<_>>(),
    );

    // t_eval = torch.linspace(0.0, 10.0, steps=50)
    let t_eval = TimeGrid::linspace_shared(batch_size, 0.0, 10.0, 50);

    // sol = solve_ivp(vdp, y0, t_eval, method="tsit5", args=mu)
    let sys = rode::problems::VdP::uniform(batch_size, mu);
    let opts = SolveOptions::new(MethodId::TSIT5).with_tols(1e-6, 1e-5);
    let sol = solve_ivp_parallel(&sys, &y0, &t_eval, &opts);

    // print(sol.status)  # => tensor([0, 0, 0, 0, 0])
    println!(
        "status: {:?}",
        sol.status.iter().map(|s| *s as u8).collect::<Vec<_>>()
    );
    assert!(sol.all_success());

    // print(sol.stats)
    println!("stats:");
    println!(
        "  n_f_evals:     {:?}",
        sol.stats.iter().map(|s| s.n_f_evals).collect::<Vec<_>>()
    );
    println!(
        "  n_steps:       {:?}",
        sol.stats.iter().map(|s| s.n_steps).collect::<Vec<_>>()
    );
    println!(
        "  n_accepted:    {:?}",
        sol.stats.iter().map(|s| s.n_accepted).collect::<Vec<_>>()
    );
    println!(
        "  n_initialized: {:?}",
        sol.stats.iter().map(|s| s.n_initialized).collect::<Vec<_>>()
    );

    // The torchode signature: n_f_evals is equal across the batch (the
    // dynamics are evaluated on the whole batch until everyone finishes),
    // while n_steps/n_accepted differ per instance.
    let f_evals: Vec<u64> = sol.stats.iter().map(|s| s.n_f_evals).collect();
    assert!(f_evals.windows(2).all(|w| w[0] == w[1]));

    println!("\nfinal states:");
    for i in 0..batch_size {
        let y = sol.y_final(i);
        println!("  instance {i}: x = {:+.4}, v = {:+.4}", y[0], y[1]);
    }
}
