//! Figure 1 + §4.1 — what goes wrong when ODEs are batched jointly.
//!
//! Solves batches of Van der Pol oscillators (μ = 25, varying initial
//! phase) with the parallel and the joint loop, dumps the per-step
//! step-size traces (`results/fig1_*.csv`) and prints the §4.1 step-count
//! blow-up across batch sizes.
//!
//! ```text
//! cargo run --release --example vdp_batching
//! ```

use rode::prelude::*;
use std::fs;
use std::io::Write;

fn phase_shifted_y0(batch: usize, rng: &mut rode::nn::Rng64) -> BatchVec {
    // Different points on / near the limit cycle => step-size needs are
    // out of phase across the batch (the Fig. 1 construction).
    BatchVec::from_rows(
        &(0..batch)
            .map(|_| vec![rng.range(-2.0, 2.0), rng.range(-1.0, 1.0)])
            .collect::<Vec<_>>(),
    )
}

fn main() {
    fs::create_dir_all("results").expect("mkdir results");
    let mu = 25.0;
    let t1 = rode::problems::VdP::approx_period(mu);
    println!("Van der Pol μ = {mu}, one cycle ≈ {t1:.1} time units\n");

    // --- Fig. 1: step-size traces --------------------------------------------
    let batch = 4;
    let mut rng = rode::nn::Rng64::new(1);
    let y0 = phase_shifted_y0(batch, &mut rng);
    let grid = TimeGrid::linspace_shared(batch, 0.0, t1, 200);
    let opts = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-5, 1e-5)
        .with_max_steps(100_000)
        .with_trace();

    let sys = rode::problems::VdP::uniform(batch, mu);
    let par = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    let joint = solve_ivp_joint(&sys, &y0, &grid, &opts);
    assert!(par.all_success() && joint.all_success());

    let mut f = fs::File::create("results/fig1_parallel.csv").unwrap();
    writeln!(f, "instance,t,dt").unwrap();
    for (i, trace) in par.trace.as_ref().unwrap().iter().enumerate() {
        for (t, dt) in trace {
            writeln!(f, "{i},{t},{dt}").unwrap();
        }
    }
    let mut f = fs::File::create("results/fig1_joint.csv").unwrap();
    writeln!(f, "instance,t,dt").unwrap();
    for (t, dt) in &joint.trace.as_ref().unwrap()[0] {
        writeln!(f, "shared,{t},{dt}").unwrap();
    }
    println!("wrote results/fig1_parallel.csv and results/fig1_joint.csv");
    println!(
        "parallel steps per instance: {:?}",
        par.stats.iter().map(|s| s.n_steps).collect::<Vec<_>>()
    );
    println!("joint steps (shared):        {}", joint.stats[0].n_steps);
    let joint_min = joint.trace.as_ref().unwrap()[0]
        .iter()
        .map(|&(_, dt)| dt)
        .fold(f64::INFINITY, f64::min);
    println!("joint min dt = {joint_min:.2e} (the stiffest instance's need)\n");

    // --- §4.1: step blow-up vs batch size ------------------------------------
    println!("§4.1 — steps(joint) / steps(parallel-max) by batch size:");
    println!("{:>6} {:>10} {:>14} {:>8}", "batch", "joint", "parallel-max", "ratio");
    let mut csv = fs::File::create("results/sec41_steps.csv").unwrap();
    writeln!(csv, "batch,joint_steps,parallel_max_steps,parallel_mean_steps,ratio").unwrap();
    for &batch in &[1usize, 2, 4, 8, 16, 32, 64] {
        let mut rng = rode::nn::Rng64::new(123);
        let y0 = phase_shifted_y0(batch, &mut rng);
        let grid = TimeGrid::linspace_shared(batch, 0.0, t1, 200);
        let opts = SolveOptions::new(MethodId::DOPRI5)
            .with_tols(1e-5, 1e-5)
            .with_max_steps(100_000);
        let sys = rode::problems::VdP::uniform(batch, mu);
        let par = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        let joint = solve_ivp_joint(&sys, &y0, &grid, &opts);
        assert!(par.all_success() && joint.all_success(), "batch={batch}");
        let joint_steps = joint.stats[0].n_steps;
        let par_max = par.stats.iter().map(|s| s.n_steps).max().unwrap();
        let par_mean =
            par.stats.iter().map(|s| s.n_steps).sum::<u64>() as f64 / batch as f64;
        let ratio = joint_steps as f64 / par_max as f64;
        println!("{batch:>6} {joint_steps:>10} {par_max:>14} {ratio:>8.2}");
        writeln!(csv, "{batch},{joint_steps},{par_max},{par_mean},{ratio}").unwrap();
    }
    println!("\nwrote results/sec41_steps.csv");
    println!(
        "(the paper reports joint batching taking up to 4x as many steps as\n\
         the parallel solver on stacked VdP problems — the ratio above should\n\
         grow with batch size and plateau in that regime)"
    );
}
