//! Figure 2 / Appendix C — PID vs integral step-size control.
//!
//! Solves one cycle of Van der Pol's oscillator across a damping sweep
//! μ ∈ [0, 50] with several PID coefficient sets (taken, like the paper,
//! from diffrax's documentation) and compares the number of solver steps
//! against an integral controller.
//!
//! ```text
//! cargo run --release --example pid_sweep
//! ```

use rode::prelude::*;
use std::fs;
use std::io::Write;

fn steps_for(mu: f64, controller: Controller) -> u64 {
    let sys = rode::problems::VdP::uniform(1, mu);
    let y0 = BatchVec::from_rows(&[vec![2.0, 0.0]]);
    let t1 = rode::problems::VdP::approx_period(mu.max(0.1));
    let grid = TimeGrid::linspace_shared(1, 0.0, t1, 100);
    let opts = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-5, 1e-5)
        .with_controller(controller)
        .with_max_steps(1_000_000);
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert!(sol.all_success(), "mu={mu}: {:?}", sol.status);
    sol.stats[0].n_steps
}

fn main() {
    fs::create_dir_all("results").expect("mkdir results");
    // PID coefficient sets from diffrax's documentation (the paper's
    // footnote 3 uses the same source).
    let pid_sets: &[(&str, f64, f64, f64)] = &[
        ("pid-0.4/0.3/0", 0.4, 0.3, 0.0),
        ("pid-0.3/0.3/0", 0.3, 0.3, 0.0),
        ("pid-0.2/0.4/0", 0.2, 0.4, 0.0),
        ("pid-1/6,1/6,0 (H211PI)", 1.0 / 6.0, 1.0 / 6.0, 0.0),
        ("pid-1/18,1/9,1/18 (H312PID)", 1.0 / 18.0, 1.0 / 9.0, 1.0 / 18.0),
    ];
    let mus: Vec<f64> = (0..=25).map(|k| 2.0 * k as f64).collect();

    let mut csv = fs::File::create("results/fig2_pid_sweep.csv").unwrap();
    write!(csv, "mu,integral").unwrap();
    for (name, ..) in pid_sets {
        write!(csv, ",{}", name.replace(',', ";")).unwrap();
    }
    writeln!(csv).unwrap();

    println!(
        "{:>5} {:>9} {}",
        "mu",
        "integral",
        pid_sets.iter().map(|s| format!("{:>22}", s.0)).collect::<String>()
    );
    let mut best_saving: f64 = 0.0;
    let mut small_mu_penalty = false;
    for &mu in &mus {
        let base = steps_for(mu, Controller::integral());
        write!(csv, "{mu},{base}").unwrap();
        print!("{mu:>5.0} {base:>9}");
        for &(_, p, i, d) in pid_sets {
            let steps = steps_for(mu, Controller::pid(p, i, d));
            write!(csv, ",{steps}").unwrap();
            let rel = 100.0 * (1.0 - steps as f64 / base as f64);
            print!("{:>18} ({rel:+.1}%)", steps);
            if mu >= 25.0 {
                best_saving = best_saving.max(rel);
            }
            if mu <= 10.0 && rel < -0.5 {
                small_mu_penalty = true;
            }
        }
        writeln!(csv).unwrap();
        println!();
    }
    println!("\nwrote results/fig2_pid_sweep.csv");
    println!("best PID saving at μ ≥ 25: {best_saving:.1}% (paper: 3–5%)");
    println!(
        "PID worse than integral somewhere at μ ≤ 10: {small_mu_penalty} \
         (paper: PID takes MORE steps for small step-size variance)"
    );
}
