//! CNF training via the adjoint equation (Table 5 stand-in): fit a 2-D
//! continuous normalizing flow to a mixture of Gaussians,
//! optimize-then-discretize, comparing the **per-instance** and **joint**
//! adjoint backward passes — the axis of Table 5.
//!
//! ```text
//! cargo run --release --example cnf_adjoint [-- --steps 120]
//! ```

use rode::nn::{Adam, Parameterized, Rng64};
use rode::prelude::*;
use rode::problems::CnfDynamics;
use rode::solver::{adjoint_backward_joint, adjoint_backward_parallel, AdjointOptions};
use std::fs;
use std::io::Write;

const D: usize = 2;
const T1: f64 = 1.0;

/// Mixture of two Gaussians in 2-D.
fn sample_data(rng: &mut Rng64, n: usize) -> Vec<[f64; D]> {
    (0..n)
        .map(|_| {
            let c = if rng.uniform() < 0.5 { [-1.5, 0.0] } else { [1.5, 0.0] };
            [c[0] + 0.4 * rng.normal(), c[1] + 0.4 * rng.normal()]
        })
        .collect()
}

fn log_standard_normal(z: &[f64]) -> f64 {
    let mut acc = -(D as f64) * 0.5 * (2.0 * std::f64::consts::PI).ln();
    for zi in z.iter().take(D) {
        acc -= 0.5 * zi * zi;
    }
    acc
}

/// Forward solve data→base: returns final augmented states and NLL.
fn forward(model: &CnfDynamics, batch: &[[f64; D]]) -> (BatchVec, f64) {
    let b = batch.len();
    let mut y0 = BatchVec::zeros(b, D + 1);
    for (i, x) in batch.iter().enumerate() {
        y0.row_mut(i)[..D].copy_from_slice(x);
        // logp channel starts at 0: accumulates -∫div.
    }
    let grid = TimeGrid::linspace_shared(b, 0.0, T1, 2);
    let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5).with_max_steps(2_000);
    let sol = solve_ivp_parallel(model, &y0, &grid, &opts);
    assert!(sol.all_success(), "{:?}", sol.status);
    let mut y1 = BatchVec::zeros(b, D + 1);
    let mut nll = 0.0;
    for i in 0..b {
        y1.row_mut(i).copy_from_slice(sol.y_final(i));
        let z = sol.y_final(i);
        // log p(x) = log N(z(T)) + Δlogp where Δlogp = -∫ div = z[D]... sign:
        // dlogp/dt = -div, logp(T)-logp(0) = -∫div, and change of variables
        // gives log p_x(x) = log p_z(z(T)) + ∫ div dt computed backwards —
        // with our convention: log p_x(x) = log N(z(T)) - y1[D].
        nll -= log_standard_normal(&z[..D]) - z[D];
    }
    (y1, nll / b as f64)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let train_steps: usize = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);

    fs::create_dir_all("results").expect("mkdir results");
    let mut rng = Rng64::new(11);
    let mut model = CnfDynamics::new(D, &[32, 32], &mut rng);
    let n_params = rode::problems::OdeSystem::n_params(&model);
    println!("CNF stand-in: d = {D}, {n_params} parameters, adjoint backward");
    let mut params = vec![0.0; n_params];
    model.params(&mut params);
    let mut opt = Adam::new(n_params, 2e-3);

    let batch_size = 32;
    let adj_opts = AdjointOptions::new(
        SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(5_000),
    );

    let mut logf = fs::File::create("results/cnf_loss.csv").unwrap();
    writeln!(logf, "step,nll_per_dim").unwrap();
    let t_start = std::time::Instant::now();
    let mut first_nll = None;
    let mut last_nll = 0.0;
    for step in 0..train_steps {
        let data = sample_data(&mut rng, batch_size);
        let (y1, nll) = forward(&model, &data);
        first_nll.get_or_insert(nll);
        last_nll = nll;

        // dL/dy(T): L = mean_i [ -log N(z_i(T)) + logp_acc_i ]
        let mut dl = BatchVec::zeros(batch_size, D + 1);
        for i in 0..batch_size {
            let z = y1.row(i);
            let row = dl.row_mut(i);
            for d in 0..D {
                row[d] = z[d] / batch_size as f64; // -∂logN/∂z = z
            }
            row[D] = 1.0 / batch_size as f64;
        }
        // Joint adjoint (the fast variant the paper recommends for training).
        let res = adjoint_backward_joint(&model, &y1, &dl, 0.0, T1, &adj_opts);
        assert!(res.status.iter().all(|s| *s == Status::Success));
        opt.step(&mut params, &res.dl_dparams);
        model.set_params(&params);

        if step % 20 == 0 || step + 1 == train_steps {
            println!("step {step:>4}: NLL/dim {:.4}", nll / D as f64);
        }
        writeln!(logf, "{step},{}", nll / D as f64).unwrap();
    }
    println!(
        "trained {train_steps} steps in {:.1}s; NLL/dim {:.3} -> {:.3}",
        t_start.elapsed().as_secs_f64(),
        first_nll.unwrap() / D as f64,
        last_nll / D as f64
    );
    assert!(
        last_nll < first_nll.unwrap(),
        "training did not reduce the NLL"
    );

    // --- Table 5 axis: per-instance vs joint backward ------------------------
    println!("\n=== adjoint variants on one batch (Table 5 axis) ===");
    let data = sample_data(&mut rng, batch_size);
    let (y1, _) = forward(&model, &data);
    let mut dl = BatchVec::zeros(batch_size, D + 1);
    for i in 0..batch_size {
        dl.row_mut(i)[0] = 1.0;
    }
    let t0s = vec![0.0; batch_size];
    let t1s = vec![T1; batch_size];

    let t = std::time::Instant::now();
    let par = adjoint_backward_parallel(&model, &y1, &dl, &t0s, &t1s, &adj_opts);
    let par_time = t.elapsed().as_secs_f64() * 1e3;
    let t = std::time::Instant::now();
    let joint = adjoint_backward_joint(&model, &y1, &dl, 0.0, T1, &adj_opts);
    let joint_time = t.elapsed().as_secs_f64() * 1e3;

    let par_steps: u64 = par.stats.iter().map(|s| s.n_steps).sum();
    let joint_steps: u64 = joint.stats.iter().map(|s| s.n_steps).sum();
    println!(
        "per-instance adjoint: {par_time:9.1} ms, {par_steps:>5} total steps, state size b(2f+p) = {}",
        batch_size * (2 * (D + 1) + n_params)
    );
    println!(
        "joint adjoint:        {joint_time:9.1} ms, {joint_steps:>5} total steps, state size b·2f+p  = {}",
        batch_size * 2 * (D + 1) + n_params
    );
    println!(
        "(paper Table 5: torchode per-instance bw loop 58.1 ms vs torchode-joint 2.38 ms —\n\
         the joint variant must be dramatically cheaper; gradient agreement below)"
    );
    let mut max_diff = 0.0f64;
    for (a, b) in par.dl_dparams.iter().zip(&joint.dl_dparams) {
        max_diff = max_diff.max((a - b).abs());
    }
    println!("max |Δ dL/dθ| between variants: {max_diff:.2e}");
    println!("\nwrote results/cnf_loss.csv");
}
