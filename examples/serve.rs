//! Serving demo: the L3 coordinator fronting both engines.
//!
//! Submits a mixed synthetic workload (varying μ, eval grids, ranges) to a
//! native-engine service and — when `artifacts/` is built — to an
//! AOT-engine service, and prints throughput/latency/batching metrics.
//!
//! ```text
//! cargo run --release --example serve [-- --requests 500]
//! ```

use rode::coordinator::{
    AotEngine, Coordinator, NativeEngine, ProblemSpec, ServiceConfig, SolveRequest,
};
use rode::nn::Rng64;
use std::time::{Duration, Instant};

fn workload(rng: &mut Rng64, n: usize) -> Vec<SolveRequest> {
    (0..n)
        .map(|_| {
            let mu = rng.range(0.5, 12.0);
            let n_eval = [10usize, 20][rng.below(2)];
            let t1 = rng.range(3.0, 6.0);
            SolveRequest::new(
                ProblemSpec::Vdp { mu },
                vec![rng.normal() * 1.5, rng.normal() * 0.5],
                (0..n_eval).map(|k| t1 * k as f64 / (n_eval - 1) as f64).collect(),
            )
        })
        .collect()
}

fn drive(name: &str, coord: &Coordinator, reqs: Vec<SolveRequest>) {
    let n = reqs.len();
    let t0 = Instant::now();
    let rxs: Vec<_> = reqs.into_iter().map(|r| coord.submit(r)).collect();
    let mut ok = 0;
    for rx in rxs {
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(300)) {
            if resp.is_success() {
                ok += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("[{name}] {ok}/{n} ok in {wall:.2}s = {:.0} req/s", n as f64 / wall);
    println!("[{name}] {}", coord.metrics().summary());
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);

    let cfg = ServiceConfig {
        max_batch: 32,
        max_wait: Duration::from_millis(2),
        ..ServiceConfig::default()
    };

    // Native engine service.
    let mut rng = Rng64::new(99);
    let native = Coordinator::spawn(cfg.clone(), || Box::new(NativeEngine::default()));
    drive("native", &native, workload(&mut rng, n_requests));
    drop(native);

    // AOT engine service (skipped if artifacts are missing).
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let mut rng = Rng64::new(99);
        let aot = Coordinator::spawn(cfg, || {
            Box::new(AotEngine::open("artifacts").expect("open artifacts"))
        });
        drive("aot-pjrt", &aot, workload(&mut rng, n_requests));
    } else {
        println!("[aot-pjrt] skipped: run `make artifacts` first");
    }
}
