//! # rode — a parallel ODE solver stack
//!
//! `rode` is a reproduction of *torchode: A Parallel ODE Solver for PyTorch*
//! (Lienen & Günnemann, 2022) as a three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3** (this crate): a Rust coordinator — request router, dynamic
//!   batcher and solver engines — plus a complete native batched
//!   Runge–Kutta core that tracks *per-instance* solver state (step size,
//!   accept/reject, status, dense-output progress), the paper's central
//!   contribution.
//! - **Layer 2**: the same batched solver loop written in JAX
//!   (`python/compile/solver.py`), AOT-lowered to HLO text and executed
//!   from Rust via PJRT ([`runtime`]). This plays the role of torchode's
//!   JIT-compiled loop.
//! - **Layer 1**: Pallas kernels for the loop's hot spots (fused RK stage
//!   combination, tolerance-scaled error norm, Horner dense-output
//!   evaluation), lowered into the same HLO module.
//!
//! ## Quick start
//!
//! ```no_run
//! use rode::prelude::*;
//!
//! // A batch of 4 independent Van der Pol oscillators.
//! let sys = rode::problems::VdP::new(vec![2.0; 4]);
//! let y0 = BatchVec::broadcast(&[1.0, 0.0], 4);
//! let t_eval = TimeGrid::linspace_shared(4, 0.0, 6.0, 20);
//! let opts = SolveOptions::new(Method::Dopri5).with_tols(1e-5, 1e-5);
//! let sol = solve_ivp_parallel(&sys, &y0, &t_eval, &opts);
//! assert!(sol.all_success());
//! ```
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod experiments;
pub mod nn;
pub mod problems;
pub mod prop;
pub mod runtime;
pub mod solver;
pub mod tensor;

/// Convenience re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::config::ExecPolicy;
    pub use crate::exec::{solve_ivp_joint_pooled, solve_ivp_parallel_pooled};
    pub use crate::problems::OdeSystem;
    pub use crate::solver::{
        solve_ivp_joint, solve_ivp_naive, solve_ivp_parallel, Controller, Method, SolveOptions,
        Solution, Status, TimeGrid,
    };
    pub use crate::tensor::BatchVec;
}
