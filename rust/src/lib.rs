//! # rode — a parallel ODE solver stack
//!
//! `rode` is a reproduction of *torchode: A Parallel ODE Solver for PyTorch*
//! (Lienen & Günnemann, 2022) as a three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3** (this crate): a Rust coordinator — request router, dynamic
//!   batcher and solver engines — plus a complete native batched
//!   Runge–Kutta core that tracks *per-instance* solver state (step size,
//!   accept/reject, status, dense-output progress), the paper's central
//!   contribution.
//! - **Layer 2**: the same batched solver loop written in JAX
//!   (`python/compile/solver.py`), AOT-lowered to HLO text and executed
//!   from Rust via PJRT ([`runtime`]). This plays the role of torchode's
//!   JIT-compiled loop.
//! - **Layer 1**: Pallas kernels for the loop's hot spots (fused RK stage
//!   combination, tolerance-scaled error norm, Horner dense-output
//!   evaluation), lowered into the same HLO module.
//!
//! ## Quick start
//!
//! ```no_run
//! use rode::prelude::*;
//!
//! // A batch of 4 independent Van der Pol oscillators.
//! let sys = rode::problems::VdP::new(vec![2.0; 4]);
//! let y0 = BatchVec::broadcast(&[1.0, 0.0], 4);
//! let t_eval = TimeGrid::linspace_shared(4, 0.0, 6.0, 20);
//! let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5);
//! let sol = solve_ivp_parallel(&sys, &y0, &t_eval, &opts);
//! assert!(sol.all_success());
//! ```
//!
//! See the repository's `README.md` for the crate layout, the CLI/config
//! reference and the benchmark workflow, and `docs/architecture.md` for
//! a step-lifecycle walkthrough of the solve loops.

// Documentation ratchet: every public item in the modules below must be
// documented (`cargo doc --no-deps` runs warning-free in CI). Modules
// that predate the ratchet opt out with `#[allow(missing_docs)]` at
// their declaration; remove the allow when documenting one — never add
// a new allow.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod bench;
pub mod config;
#[allow(missing_docs)]
pub mod coordinator;
pub mod exec;
#[allow(missing_docs)]
pub mod experiments;
#[allow(missing_docs)]
pub mod nn;
#[allow(missing_docs)]
pub mod problems;
#[allow(missing_docs)]
pub mod prop;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod solver;
#[allow(missing_docs)]
pub mod tensor;

/// Convenience re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::config::{ExecPolicy, PoolKind};
    pub use crate::exec::{solve_ivp_joint_pooled, solve_ivp_parallel_pooled};
    pub use crate::problems::{JacStructure, OdeSystem};
    pub use crate::solver::{
        register_method, register_method_with_aliases, solve_ivp_joint, solve_ivp_naive,
        solve_ivp_parallel, Controller, ExecStats, MethodId, RegisterError, SolveOptions,
        Solution, Status, TimeGrid,
    };
    pub use crate::tensor::{BatchVec, Layout};
}
