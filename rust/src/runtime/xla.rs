//! Offline stub of the `xla` PJRT binding.
//!
//! The vendored crate set has no XLA/PJRT binding, so the runtime layer
//! compiles against this API-compatible stub: every entry point that would
//! touch a device returns an error at *runtime* while keeping the exact
//! call surface `runtime/mod.rs` uses. Artifact-dependent tests and
//! benches already skip when `artifacts/manifest.json` is absent, so the
//! stub never actually executes in CI.
//!
//! Swapping in a real binding is a one-line change in `runtime/mod.rs`
//! (replace `mod xla` with the external crate).

use anyhow::{anyhow, Result};

fn unavailable(what: &str) -> anyhow::Error {
    anyhow!("PJRT backend unavailable in this build: {what} needs a real XLA binding")
}

/// Stub of a host literal (an n-d array handed to/from the device).
#[derive(Debug, Clone, Default)]
pub struct Literal;

/// Conversions supported by [`Literal::to_vec`].
pub trait NativeType: Sized {}
impl NativeType for f32 {}
impl NativeType for i32 {}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Stub of an on-device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of a compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub of the PJRT client.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub of a parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of an XLA computation.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
