//! `artifacts/manifest.json` — artifact metadata written by
//! `python/compile/aot.py` and consumed here to build input literals.

use super::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Shape + dtype of one input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT artifact.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    /// "solve" (full loop) or "step" (single RK attempt).
    pub kind: String,
    /// "vdp", "mlp", ...
    pub problem: String,
    pub batch: usize,
    pub n_eval: usize,
    pub dim: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, Artifact>,
}

fn io_spec(j: &Json, idx: usize) -> Result<IoSpec> {
    let shape = j
        .get("shape")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("io spec missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    Ok(IoSpec {
        name: j
            .get("name")
            .and_then(|n| n.as_str())
            .unwrap_or(&format!("arg{idx}"))
            .to_string(),
        shape,
        dtype: j.get("dtype").and_then(|d| d.as_str()).unwrap_or("f32").to_string(),
    })
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let obj = j.as_obj().ok_or_else(|| anyhow!("manifest must be an object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, meta) in obj {
            let get_str =
                |k: &str| meta.get(k).and_then(|v| v.as_str()).unwrap_or("").to_string();
            let get_n = |k: &str| meta.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            let parse_specs = |k: &str| -> Result<Vec<IoSpec>> {
                meta.get(k)
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .enumerate()
                    .map(|(i, s)| io_spec(s, i))
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                Artifact {
                    name: name.clone(),
                    file: get_str("file"),
                    kind: get_str("kind"),
                    problem: get_str("problem"),
                    batch: get_n("batch"),
                    n_eval: get_n("n_eval"),
                    dim: if get_n("dim") > 0 { get_n("dim") } else { 2 },
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        Ok(Self { artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "solve_vdp_b8_e20": {
        "file": "solve_vdp_b8_e20.hlo.txt",
        "inputs": [
          {"shape": [8, 2], "dtype": "f32"},
          {"shape": [8], "dtype": "f32"},
          {"shape": [8, 20], "dtype": "f32"}
        ],
        "outputs": [
          {"name": "ys", "shape": [8, 20, 2], "dtype": "f32"},
          {"name": "status", "shape": [8], "dtype": "s32"}
        ],
        "kind": "solve", "problem": "vdp", "batch": 8, "n_eval": 20
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["solve_vdp_b8_e20"];
        assert_eq!(a.kind, "solve");
        assert_eq!(a.batch, 8);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![8, 2]);
        assert_eq!(a.outputs[1].dtype, "s32");
        assert_eq!(a.outputs[0].name, "ys");
        assert_eq!(a.dim, 2);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in m.artifacts.values() {
                assert!(!a.inputs.is_empty());
                assert!(!a.outputs.is_empty());
            }
        }
    }
}
