//! The PJRT runtime: loads AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and executes them from the Rust hot path.
//!
//! Python never runs at request time — the HLO text is compiled by the
//! PJRT CPU client on startup (and cached per artifact), after which the
//! coordinator is a self-contained native binary.

pub mod json;
mod manifest;
mod xla;

pub use manifest::{Artifact, IoSpec, Manifest};

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact plus its manifest metadata.
pub struct LoadedArtifact {
    pub meta: Artifact,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with row-major `f32` input buffers matching the manifest
    /// input specs; returns one `Vec<f32>` per manifest output (integer
    /// outputs are converted).
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(anyhow!(
                "artifact {} expects {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&self.meta.inputs) {
            let expect: usize = spec.shape.iter().product();
            if buf.len() != expect {
                return Err(anyhow!(
                    "input size mismatch for {}: {} vs {:?}",
                    self.meta.name,
                    buf.len(),
                    spec.shape
                ));
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&self.meta.outputs) {
            let v: Vec<f32> = match spec.dtype.as_str() {
                "s32" => lit.to_vec::<i32>()?.into_iter().map(|x| x as f32).collect(),
                _ => lit.to_vec::<f32>()?,
            };
            out.push(v);
        }
        Ok(out)
    }
}

/// PJRT client + compiled-executable cache, keyed by artifact name.
///
/// Compilation happens lazily on first use and is then reused for the
/// lifetime of the runtime ("one compiled executable per model variant").
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, std::rc::Rc<LoadedArtifact>>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`, creates the CPU
    /// PJRT client).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, dir, manifest, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact names available in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.keys().cloned().collect()
    }

    /// Load (compile) an artifact, cached.
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<LoadedArtifact>> {
        if let Some(a) = self.cache.get(name) {
            return Ok(a.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let loaded = std::rc::Rc::new(LoadedArtifact { meta, exe });
        self.cache.insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Pick the smallest full-solve VdP artifact with `batch >= n` (shape
    /// bucketing for the coordinator).
    pub fn pick_vdp_solve(&self, n: usize, n_eval: usize) -> Option<String> {
        self.manifest
            .artifacts
            .iter()
            .filter(|(_, a)| {
                a.kind == "solve"
                    && a.problem == "vdp"
                    && a.batch >= n
                    && a.n_eval >= n_eval
                    && !a.name.contains("pid")
            })
            .min_by_key(|(_, a)| (a.batch, a.n_eval))
            .map(|(name, _)| name.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(kind: &str, problem: &str, b: usize, e: usize) -> Artifact {
        Artifact {
            name: format!("{kind}_{problem}_b{b}_e{e}"),
            file: String::new(),
            kind: kind.into(),
            problem: problem.into(),
            batch: b,
            n_eval: e,
            dim: 2,
            inputs: vec![],
            outputs: vec![],
        }
    }

    #[test]
    fn pick_prefers_smallest_fit() {
        let mut m = Manifest::default();
        for (b, e) in [(8, 20), (64, 50), (256, 200)] {
            let a = fake("solve", "vdp", b, e);
            m.artifacts.insert(a.name.clone(), a);
        }
        // Reimplement pick over the bare manifest (Runtime needs a client).
        let pick = |n: usize, e: usize| {
            m.artifacts
                .iter()
                .filter(|(_, a)| a.kind == "solve" && a.batch >= n && a.n_eval >= e)
                .min_by_key(|(_, a)| (a.batch, a.n_eval))
                .map(|(k, _)| k.clone())
        };
        assert_eq!(pick(5, 10).unwrap(), "solve_vdp_b8_e20");
        assert_eq!(pick(8, 30).unwrap(), "solve_vdp_b64_e50");
        assert_eq!(pick(100, 10).unwrap(), "solve_vdp_b256_e200");
        assert!(pick(1000, 10).is_none());
    }
}
