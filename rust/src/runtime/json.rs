//! Minimal JSON parser for `artifacts/manifest.json` (serde is not in the
//! vendored crate set). Supports the full JSON grammar minus exotic number
//! forms; good enough for tool-generated manifests.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".to_string()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    let start = self.i;
                    while self.peek().map_or(false, |c| c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
                None => return Err("unterminated string".to_string()),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"solve_vdp_b8_e20": {"file": "solve_vdp_b8_e20.hlo.txt",
                "inputs": [{"shape": [8, 2], "dtype": "f32"}],
                "outputs": [{"name": "ys", "shape": [8, 20, 2], "dtype": "f32"}],
                "kind": "solve", "batch": 8, "n_eval": 20}}"#,
        )
        .unwrap();
        let m = j.get("solve_vdp_b8_e20").unwrap();
        assert_eq!(m.get("batch").unwrap().as_usize(), Some(8));
        let ins = m.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(
            ins[0].get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
            Some(2)
        );
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
