//! The exec layer: batch sharding across CPU worker pools.
//!
//! torchode's core claim is that per-instance solver state is almost
//! free because the dynamics are evaluated in one batched call per
//! stage. On CPU that batched call is a row loop — and because every
//! row's state machine is independent, the loop is embarrassingly
//! shardable. This module splits a batched solve into contiguous row
//! ranges, runs them on a dependency-free worker pool and
//! deterministically merges the results:
//!
//! - [`solve_ivp_parallel_pooled`] runs each range's **full per-instance
//!   state machine** on its own worker (the ranges share nothing), then
//!   merges the per-range [`Solution`] buffers, `Stats`, traces and
//!   `Status` back into one result.
//! - [`solve_ivp_joint_pooled`] shards only the **row-update passes**
//!   (stage accumulation, dynamics evaluation, solution/error
//!   combination) of each step; the joint loop's shared controller
//!   reduction stays on the coordinator thread. The fused error-norm
//!   partials ride along with the sharded passes on the persistent pool
//!   (whose workers are already parked and cheap to wake) and run
//!   inline on the coordinator under the scoped pool, where a thread
//!   spawn per step would cost more than the fill.
//!
//! ## Pool kinds
//!
//! Two pool implementations carry the shards, selected by
//! [`crate::config::PoolKind`] on `SolveOptions::exec`:
//!
//! - **Scoped** ([`ScopedPool`]): one contiguous near-equal shard per
//!   worker ([`shard_bounds`]), fanned out over freshly spawned scoped
//!   threads on every scatter. Static assignment — a shard that owns the
//!   batch's stiff rows keeps its worker busy long after the others went
//!   idle.
//! - **Persistent** ([`PersistentPool`] + [`steal`]): workers are spawned
//!   once per solve and parked between passes, so the joint loop's
//!   several-passes-per-step fan-out stops paying thread spawn/join
//!   cost. The batch is cut into many small chunks
//!   (`ExecPolicy::steal_chunk` rows each) scheduled through per-worker
//!   work-stealing deques: each worker drains its own chunk block, then
//!   steals the back half of the most-loaded peer's deque, so
//!   straggler-heavy batches rebalance dynamically at chunk granularity.
//!
//! Which pool ran (and how much stealing happened) is recorded in
//! [`Solution::exec_stats`] — including the quiet degradations to the
//! serial path (`threads = 1`, one-row batches, `PoolKind::Serial`).
//!
//! ## Determinism
//!
//! Every combination of pool kind, thread count and steal-chunk size is
//! **bitwise-identical** to the serial path — `ys`, `Stats`, `Status`
//! and traces (`tests/pool_determinism.rs`). The contract rests on three
//! invariants, not on scheduling:
//!
//! 1. A row's state machine depends only on that row's data, so *which*
//!    worker computes a row (and when) cannot change its values.
//! 2. Every output lands in a slot keyed by row index or chunk id, and
//!    every reduction over per-chunk or per-row partials runs on the
//!    coordinator **in index order, never arrival order** (see
//!    [`merge_sharded`] and the fused joint norm in
//!    [`crate::solver::joint`]).
//! 3. The only cross-row quantity — torchode's uniform `n_f_evals`
//!    accounting — is reconstructed from per-range call ledgers in
//!    [`merge_sharded`], whose per-iteration max is invariant to how the
//!    batch was partitioned.
//!
//! ## Interaction with the active set and compaction
//!
//! Each parallel-range worker runs the full active-set loop of
//! [`crate::solver::parallel`] on its row range, including state
//! compaction when `SolveOptions::compact_threshold` is set: a range
//! whose stragglers are all that remain packs its own state
//! independently, and the [`OffsetSystem`] wrapper composes the range
//! base offset with the loop's slot → row map
//! ([`crate::problems::OdeSystem::f_rows_indexed`]). Compaction changes
//! neither per-row values nor the per-iteration semantic call counts the
//! ledgers record, so the merged result — including `n_f_evals` — stays
//! bitwise-identical to the serial loop whatever the threshold. The same
//! holds for `eval_inactive = false`: skipped rows simply never appear
//! in a worker's index lists.
//!
//! ## Interaction with the workspace layout
//!
//! `SolveOptions::layout` composes freely with every pool kind. Each
//! parallel-range worker builds its own workspace in the configured
//! layout, so a dim-major solve shards like any other. The pooled
//! *joint* executors ([`PooledExec`]/[`StealExec`]) drive the row-range
//! kernel (`rk_attempt_rows`) over disjoint workspace views, which is
//! the row-major path regardless of layout — they report
//! `workspace_layout() = RowMajor` so the joint loop never allocates
//! SoA mirrors no pass would touch. Legal because both layouts compute
//! bit-identical per-element results (`tests/kernel_parity.rs`), so
//! pooled joint solves still match the serial dim-major loop bitwise. The fused error-norm partials are likewise layout-blind:
//! the lane-tree reduction of `scaled_sumsq` has a fixed shape per row
//! length wherever it runs.
//!
//! Sharded entry points require `S: OdeSystem + Sync` (the system is
//! shared read-only across workers); systems with `RefCell` scratch
//! (CNF/FEN) keep using the serial `solve_ivp_*` functions.

pub mod pool;
pub(crate) mod steal;

pub use pool::{PersistentPool, ScopedPool};

use crate::config::PoolKind;
use crate::problems::OdeSystem;
use crate::solver::init::initial_step_batch;
use crate::solver::norm::scaled_sumsq_rows;
use crate::solver::parallel::{solve_ivp_parallel_core, CallLedger};
use crate::solver::step::{
    attempt_call_count, rk_attempt_rows, CompiledTableau, RkRows, RkWorkspace, StageExec,
};
use crate::solver::{
    joint, solve_ivp_joint, solve_ivp_parallel, ExecStats, SolveOptions, Solution, TimeGrid,
    Tolerances,
};
use crate::tensor::{BatchVec, Layout};
use std::sync::Mutex;
use steal::{chunk_bounds, ChunkQueues};

/// A system view that maps local shard rows onto the global instance
/// range `[offset, offset + rows)` of the wrapped system.
struct OffsetSystem<'a, S: OdeSystem + ?Sized> {
    inner: &'a S,
    offset: usize,
}

impl<S: OdeSystem + ?Sized> OdeSystem for OffsetSystem<'_, S> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn n_params(&self) -> usize {
        self.inner.n_params()
    }

    fn f_inst(&self, inst: usize, t: f64, y: &[f64], dy: &mut [f64]) {
        self.inner.f_inst(self.offset + inst, t, y, dy)
    }

    fn f_rows(
        &self,
        offset: usize,
        n: usize,
        t: &[f64],
        y: &[f64],
        dy: &mut [f64],
        active: Option<&[bool]>,
    ) {
        self.inner.f_rows(self.offset + offset, n, t, y, dy, active)
    }

    fn f_rows_indexed(
        &self,
        offset: usize,
        inst: &[usize],
        rows: &[usize],
        t: &[f64],
        y: &[f64],
        dy: &mut [f64],
    ) {
        // The shard's slot → row map composes with the shard base offset,
        // so the active-set loop works unchanged inside a shard worker.
        self.inner.f_rows_indexed(self.offset + offset, inst, rows, t, y, dy)
    }

    fn f_batch(
        &self,
        t: &[f64],
        y: &BatchVec,
        dy: &mut BatchVec,
        active: Option<&[bool]>,
    ) {
        self.inner.f_rows(self.offset, y.batch(), t, y.flat(), dy.flat_mut(), active)
    }

    fn has_jac(&self) -> bool {
        self.inner.has_jac()
    }

    fn jac_inst(&self, inst: usize, t: f64, y: &[f64], jac: &mut [f64]) {
        self.inner.jac_inst(self.offset + inst, t, y, jac)
    }

    fn jac_rows(
        &self,
        offset: usize,
        n: usize,
        t: &[f64],
        y: &[f64],
        jac: &mut [f64],
        rows: Option<&[usize]>,
    ) {
        // Composes like `f_rows`, so the implicit solver's analytic
        // Jacobian hook works unchanged inside a shard worker.
        self.inner.jac_rows(self.offset + offset, n, t, y, jac, rows)
    }

    fn jac_structure(&self) -> crate::problems::JacStructure {
        self.inner.jac_structure()
    }

    fn jac_band_inst(&self, inst: usize, t: f64, y: &[f64], jac: &mut [f64]) {
        self.inner.jac_band_inst(self.offset + inst, t, y, jac)
    }

    fn jac_band_rows(
        &self,
        offset: usize,
        n: usize,
        t: &[f64],
        y: &[f64],
        jac: &mut [f64],
        rows: Option<&[usize]>,
    ) {
        self.inner.jac_band_rows(self.offset + offset, n, t, y, jac, rows)
    }
}

/// Contiguous near-equal row shards: `min(shards, batch)` ranges whose
/// first `batch % n` members carry one extra row. An oversubscribed pool
/// (threads > batch) simply produces one shard per row.
pub(crate) fn shard_bounds(batch: usize, shards: usize) -> Vec<(usize, usize)> {
    let n = shards.max(1).min(batch.max(1));
    let base = batch / n;
    let rem = batch % n;
    let mut out = Vec::with_capacity(n);
    let mut lo = 0;
    for i in 0..n {
        let len = base + usize::from(i < rem);
        out.push((lo, lo + len));
        lo += len;
    }
    debug_assert_eq!(lo, batch);
    out
}

/// Split a flat buffer into consecutive chunks of the given sizes.
fn split_chunks<'a, T>(mut s: &'a mut [T], sizes: &[usize]) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let (chunk, rest) = s.split_at_mut(n);
        out.push(chunk);
        s = rest;
    }
    out
}

/// Disjoint per-range [`RkRows`] views of a workspace, one per entry of
/// `bounds` — the unit of work a pool worker owns during a sharded
/// attempt. Shared by the scoped and work-stealing executors so both
/// drive the identical per-row kernel over identical views.
fn workspace_views<'w>(
    ws: &'w mut RkWorkspace,
    bounds: &[(usize, usize)],
    dim: usize,
) -> Vec<RkRows<'w>> {
    let sizes: Vec<usize> = bounds.iter().map(|&(lo, hi)| (hi - lo) * dim).collect();
    let row_sizes: Vec<usize> = bounds.iter().map(|&(lo, hi)| hi - lo).collect();

    let mut k_chunks: Vec<std::vec::IntoIter<&mut [f64]>> = ws
        .k
        .iter_mut()
        .map(|k| split_chunks(k.flat_mut(), &sizes).into_iter())
        .collect();
    let mut ytmp_it = split_chunks(ws.ytmp.flat_mut(), &sizes).into_iter();
    let mut y_new_it = split_chunks(ws.y_new.flat_mut(), &sizes).into_iter();
    let mut err_it = split_chunks(ws.err.flat_mut(), &sizes).into_iter();
    let mut ts_it = split_chunks(&mut ws.t_stage[..], &row_sizes).into_iter();
    let mut cold_it = split_chunks(&mut ws.cold[..], &row_sizes).into_iter();
    // Implicit workspaces carry per-slot Newton state; each view gets its
    // own disjoint range of it (per-row Jacobian/LU blocks shard exactly
    // like the stage buffers).
    let mut newton_it = ws.newton.as_mut().map(|nw| nw.split_views(bounds).into_iter());

    let mut views: Vec<RkRows<'w>> = Vec::with_capacity(bounds.len());
    for &(lo, hi) in bounds {
        views.push(RkRows {
            offset: lo,
            rows: hi - lo,
            dim,
            k: std::array::from_fn(|s| {
                k_chunks.get_mut(s).map_or_else(Default::default, |it| it.next().unwrap())
            }),
            ytmp: ytmp_it.next().unwrap(),
            y_new: y_new_it.next().unwrap(),
            err: err_it.next().unwrap(),
            t_stage: ts_it.next().unwrap(),
            cold: cold_it.next().unwrap(),
            newton: newton_it.as_mut().map(|it| it.next().unwrap()),
        });
    }
    views
}

/// [`crate::solver::solve_ivp_parallel`] sharded across
/// `opts.exec.effective_threads()` workers on the pool kind selected by
/// `opts.exec.pool`: each row range runs the full per-instance state
/// machine on a worker; results are bitwise identical to the serial path
/// (including `Stats` — see [`merge_sharded`]) for every pool kind,
/// thread count and steal-chunk size. Falls back to the serial loop for
/// one thread, a one-row batch or [`PoolKind::Serial`]; the path taken
/// is recorded in [`Solution::exec_stats`].
pub fn solve_ivp_parallel_pooled<S: OdeSystem + Sync>(
    sys: &S,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> Solution {
    let batch = y0.batch();
    opts.tols.validate(batch);
    let threads = opts.exec.effective_threads();
    if threads <= 1 || batch <= 1 || opts.exec.pool == PoolKind::Serial {
        return solve_ivp_parallel(sys, y0, grid, opts);
    }
    match opts.exec.pool {
        PoolKind::Scoped => parallel_scoped(sys, y0, grid, opts, threads),
        PoolKind::Persistent => parallel_stealing(sys, y0, grid, opts, threads),
        PoolKind::Serial => unreachable!("serial handled above"),
    }
}

/// The scoped path: one contiguous shard per worker, one scoped-thread
/// scatter for the whole solve.
fn parallel_scoped<S: OdeSystem + Sync>(
    sys: &S,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
    threads: usize,
) -> Solution {
    let batch = y0.batch();
    let bounds = shard_bounds(batch, threads);
    let pool = ScopedPool::new(bounds.len());
    let jobs: Vec<_> = bounds
        .iter()
        .map(|&(lo, hi)| {
            let y0_shard = y0.rows_range(lo, hi);
            let grid_shard = grid.rows_range(lo, hi);
            let opts_shard = opts.shard_rows(lo, hi);
            move || {
                let view = OffsetSystem { inner: sys, offset: lo };
                solve_ivp_parallel_core(&view, &y0_shard, &grid_shard, &opts_shard)
            }
        })
        .collect();
    let results = pool.scatter(jobs);
    let mut sol =
        merge_sharded(&bounds, &results, batch, grid.n_eval(), y0.dim(), opts.record_trace);
    sol.exec_stats = ExecStats {
        pool_kind: PoolKind::Scoped,
        threads: bounds.len(),
        shards: bounds.len(),
        steal_count: 0,
    };
    sol
}

/// The persistent path: the batch is cut into steal-chunks, each chunk's
/// full sub-solve is claimed dynamically from the work-stealing queues,
/// and each result lands in its chunk-indexed slot — so the merge below
/// sees results in chunk order no matter which worker produced them.
fn parallel_stealing<S: OdeSystem + Sync>(
    sys: &S,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
    threads: usize,
) -> Solution {
    let batch = y0.batch();
    let bounds = chunk_bounds(batch, opts.exec.effective_steal_chunk(batch));
    let threads = threads.min(bounds.len());
    let pool = PersistentPool::new(threads);
    let queues = ChunkQueues::new(threads, bounds.len());
    let slots: Vec<Mutex<Option<(Solution, CallLedger)>>> =
        (0..bounds.len()).map(|_| Mutex::new(None)).collect();
    pool.run(&|w| {
        while let Some(c) = queues.pop(w) {
            let (lo, hi) = bounds[c];
            let y0_shard = y0.rows_range(lo, hi);
            let grid_shard = grid.rows_range(lo, hi);
            let opts_shard = opts.shard_rows(lo, hi);
            let view = OffsetSystem { inner: sys, offset: lo };
            let r = solve_ivp_parallel_core(&view, &y0_shard, &grid_shard, &opts_shard);
            *slots[c].lock().unwrap() = Some(r);
        }
    });
    let results: Vec<(Solution, CallLedger)> = slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("every chunk produces a result"))
        .collect();
    let mut sol =
        merge_sharded(&bounds, &results, batch, grid.n_eval(), y0.dim(), opts.record_trace);
    sol.exec_stats = ExecStats {
        pool_kind: PoolKind::Persistent,
        threads,
        shards: bounds.len(),
        steal_count: queues.steals(),
    };
    sol
}

/// Merge per-range solutions back into one batch-shaped [`Solution`].
///
/// `ys`, `status`, `n_steps`, `n_accepted`, `n_initialized` and traces
/// are purely per-row and copy over directly. `n_f_evals` is torchode's
/// uniform "the whole batch experiences every batched call" count: the
/// global loop would have made, at iteration `n`, the *maximum* of the
/// per-range call counts at `n` (all ranges pay the `stages - 1` stage
/// calls; the non-FSAL refresh fires iff any range had an accepted row
/// — a per-row property, so the max is invariant to the partition), so
/// the merged count is `base + Σ_n max_ranges per_iter[n]` — exactly the
/// serial loop's number, whether the ranges came from [`shard_bounds`]
/// or [`chunk_bounds`]. Under an implicit method each row's `n_f_evals`
/// additionally carries its own Newton/FD evaluations on top of the
/// shard's uniform count; that excess is a pure per-row property
/// (`n_jac_evals`/`n_lu_factor` likewise), so the merge re-bases it onto
/// the global uniform count and the result is exactly the serial
/// loop's, whatever the partition. Ranges are always iterated in index
/// order, so the merge itself is scheduling-independent.
fn merge_sharded(
    bounds: &[(usize, usize)],
    results: &[(Solution, CallLedger)],
    batch: usize,
    n_eval: usize,
    dim: usize,
    record_trace: bool,
) -> Solution {
    let mut sol = Solution::new_buffer(batch, n_eval, dim);
    let mut trace: Option<Vec<Vec<(f64, f64)>>> =
        if record_trace { Some(vec![Vec::new(); batch]) } else { None };

    // Uniform batched-call reconstruction: the global loop's count is
    // base + Σ_iter max over ranges.
    let base = results.first().map_or(0, |(_, l)| l.base);
    debug_assert!(
        results.iter().all(|(_, l)| l.base == base),
        "shards disagree on pre-loop calls"
    );
    let max_iters = results.iter().map(|(_, l)| l.per_iter.len()).max().unwrap_or(0);
    let mut total = base;
    for n in 0..max_iters {
        total += results
            .iter()
            .filter_map(|(_, l)| l.per_iter.get(n).copied())
            .max()
            .unwrap_or(0);
    }

    for (&(lo, _hi), (shard, ledger)) in bounds.iter().zip(results) {
        // A shard's own uniform count; anything a row's `n_f_evals`
        // carries beyond it is per-row Newton work (implicit methods),
        // which is partition-invariant and rides the merge unchanged on
        // top of the globally reconstructed uniform count.
        let shard_total: u64 = ledger.base + ledger.per_iter.iter().sum::<u64>();
        for r in 0..shard.batch() {
            let i = lo + r;
            for e in 0..n_eval {
                sol.y_mut(i, e).copy_from_slice(shard.y(r, e));
            }
            sol.status[i] = shard.status[r];
            let mut st = shard.stats[r].clone();
            st.n_f_evals = total + (st.n_f_evals - shard_total);
            sol.stats[i] = st;
            if let (Some(tr), Some(stt)) = (trace.as_mut(), shard.trace.as_ref()) {
                tr[i] = stt[r].clone();
            }
        }
    }

    sol.trace = trace;
    sol
}

/// [`crate::solver::solve_ivp_joint`] with the row-update passes of every
/// step sharded across `opts.exec.effective_threads()` workers on the
/// selected pool kind. The shared step-size controller and the scalar
/// error-norm reduction stay on the coordinator thread (the per-row norm
/// partials are fused into the sharded error pass); results are bitwise
/// identical to the serial joint loop for every pool kind, thread count
/// and steal-chunk size.
pub fn solve_ivp_joint_pooled<S: OdeSystem + Sync>(
    sys: &S,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> Solution {
    let batch = y0.batch();
    opts.tols.validate(batch);
    let threads = opts.exec.effective_threads();
    if threads <= 1 || batch <= 1 || opts.exec.pool == PoolKind::Serial {
        return solve_ivp_joint(sys, y0, grid, opts);
    }
    match opts.exec.pool {
        PoolKind::Scoped => {
            let bounds = shard_bounds(batch, threads);
            let pool = ScopedPool::new(bounds.len());
            let exec = PooledExec { sys, pool, bounds };
            let mut sol = joint::joint_core(&exec, y0, grid, opts);
            sol.exec_stats = ExecStats {
                pool_kind: PoolKind::Scoped,
                threads: exec.bounds.len(),
                shards: exec.bounds.len(),
                steal_count: 0,
            };
            sol
        }
        PoolKind::Persistent => {
            let bounds = chunk_bounds(batch, opts.exec.effective_steal_chunk(batch));
            let threads = threads.min(bounds.len());
            let exec = StealExec {
                sys,
                pool: PersistentPool::new(threads),
                queues: ChunkQueues::new(threads, bounds.len()),
                bounds,
            };
            let mut sol = joint::joint_core(&exec, y0, grid, opts);
            sol.exec_stats = ExecStats {
                pool_kind: PoolKind::Persistent,
                threads,
                shards: exec.bounds.len(),
                steal_count: exec.queues.steals(),
            };
            sol
        }
        PoolKind::Serial => unreachable!("serial handled above"),
    }
}

/// The scoped [`StageExec`]: shards each batched pass over one
/// contiguous row range per worker via scoped-thread scatters.
struct PooledExec<'a, S: OdeSystem + Sync> {
    sys: &'a S,
    pool: ScopedPool,
    bounds: Vec<(usize, usize)>,
}

impl<S: OdeSystem + Sync> StageExec for PooledExec<'_, S> {
    fn dim(&self) -> usize {
        self.sys.dim()
    }

    fn workspace_layout(&self, _requested: Layout) -> Layout {
        // The sharded passes drive the row-range kernel over workspace
        // views — always row-major — so never allocate SoA mirrors no
        // pass would touch. Bitwise-identical either way.
        Layout::RowMajor
    }

    fn jac_structure(&self) -> crate::problems::JacStructure {
        self.sys.jac_structure()
    }

    fn eval(&self, t: &[f64], y: &BatchVec, dy: &mut BatchVec, active: Option<&[bool]>) {
        let dim = y.dim();
        let sizes: Vec<usize> = self.bounds.iter().map(|&(lo, hi)| (hi - lo) * dim).collect();
        let dy_chunks = split_chunks(dy.flat_mut(), &sizes);
        let sys = self.sys;
        let y_flat = y.flat();
        let jobs: Vec<_> = self
            .bounds
            .iter()
            .zip(dy_chunks)
            .map(|(&(lo, hi), chunk)| {
                let t_s = &t[lo..hi];
                let y_s = &y_flat[lo * dim..hi * dim];
                let act_s = active.map(|m| &m[lo..hi]);
                move || sys.f_rows(lo, hi - lo, t_s, y_s, chunk, act_s)
            })
            .collect();
        self.pool.scatter(jobs);
    }

    fn attempt(
        &self,
        ct: &CompiledTableau,
        t: &[f64],
        dt: &[f64],
        y: &BatchVec,
        ws: &mut RkWorkspace,
        k0_ready: &[bool],
        active: Option<&[bool]>,
        eval_inactive: bool,
    ) -> u64 {
        let dim = y.dim();
        let shards = workspace_views(ws, &self.bounds, dim);
        let sys = self.sys;
        let y_flat = y.flat();
        let jobs: Vec<_> = shards
            .into_iter()
            .map(|mut rr| {
                let (lo, rows) = (rr.offset, rr.rows);
                let t_s = &t[lo..lo + rows];
                let dt_s = &dt[lo..lo + rows];
                let y_s = &y_flat[lo * dim..(lo + rows) * dim];
                let k0_s = &k0_ready[lo..lo + rows];
                let act_s = active.map(|m| &m[lo..lo + rows]);
                move || {
                    rk_attempt_rows(ct, sys, t_s, dt_s, y_s, &mut rr, k0_s, act_s, eval_inactive)
                }
            })
            .collect();
        self.pool.scatter(jobs);

        // One *semantic* batched call per stage, however many shards
        // physically carried it (torchode accounting).
        attempt_call_count(ct, k0_ready)
    }

    fn initial_step(
        &self,
        t0: &[f64],
        y0: &BatchVec,
        f0: &BatchVec,
        order: usize,
        tols: &Tolerances,
        span: &[f64],
        scratch_y: &mut BatchVec,
        scratch_f: &mut BatchVec,
    ) -> Vec<f64> {
        // One-time cost; runs serially (and bitwise-identically).
        initial_step_batch(self.sys, t0, y0, f0, order, tols, span, scratch_y, scratch_f)
    }

    fn error_sumsq(
        &self,
        err: &BatchVec,
        y0: &BatchVec,
        y1: &BatchVec,
        tols: &Tolerances,
        out: &mut [f64],
    ) {
        // The scoped pool would pay a thread spawn/join round for this
        // O(batch · dim) fill — more than the fill itself costs — so the
        // partials run inline on the coordinator here. Same arithmetic,
        // same row order; only the parked persistent pool ships this
        // pass to workers.
        scaled_sumsq_rows(err, y0, y1, tols, 0, out);
    }
}

/// The work-stealing [`StageExec`]: one persistent pool per solve, one
/// queue refill per sharded pass. Workers claim row chunks dynamically;
/// every output is written through a chunk-indexed slot, so scheduling
/// never leaks into results (see the module docs' determinism
/// invariants).
struct StealExec<'a, S: OdeSystem + Sync> {
    sys: &'a S,
    pool: PersistentPool,
    queues: ChunkQueues,
    bounds: Vec<(usize, usize)>,
}

impl<S: OdeSystem + Sync> StealExec<'_, S> {
    /// Run one sharded pass: refill the chunk queues, then let every
    /// worker claim chunk ids and consume the matching per-chunk task
    /// (each task is taken exactly once).
    fn run_chunks<T: Send>(&self, tasks: Vec<T>, run: impl Fn(usize, T) + Sync) {
        debug_assert_eq!(tasks.len(), self.bounds.len());
        let slots: Vec<_> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        self.queues.reset(slots.len());
        self.pool.run(&|w| {
            while let Some(c) = self.queues.pop(w) {
                let task = slots[c].lock().unwrap().take().expect("chunk delivered once");
                run(c, task);
            }
        });
    }
}

impl<S: OdeSystem + Sync> StageExec for StealExec<'_, S> {
    fn dim(&self) -> usize {
        self.sys.dim()
    }

    fn workspace_layout(&self, _requested: Layout) -> Layout {
        // Same reasoning as `PooledExec`: chunked passes are row-major.
        Layout::RowMajor
    }

    fn jac_structure(&self) -> crate::problems::JacStructure {
        self.sys.jac_structure()
    }

    fn eval(&self, t: &[f64], y: &BatchVec, dy: &mut BatchVec, active: Option<&[bool]>) {
        let dim = y.dim();
        let sizes: Vec<usize> = self.bounds.iter().map(|&(lo, hi)| (hi - lo) * dim).collect();
        let dy_chunks = split_chunks(dy.flat_mut(), &sizes);
        let sys = self.sys;
        let y_flat = y.flat();
        let bounds = &self.bounds;
        self.run_chunks(dy_chunks, |c, chunk| {
            let (lo, hi) = bounds[c];
            let act_s = active.map(|m| &m[lo..hi]);
            sys.f_rows(lo, hi - lo, &t[lo..hi], &y_flat[lo * dim..hi * dim], chunk, act_s);
        });
    }

    fn attempt(
        &self,
        ct: &CompiledTableau,
        t: &[f64],
        dt: &[f64],
        y: &BatchVec,
        ws: &mut RkWorkspace,
        k0_ready: &[bool],
        active: Option<&[bool]>,
        eval_inactive: bool,
    ) -> u64 {
        let dim = y.dim();
        let views = workspace_views(ws, &self.bounds, dim);
        let sys = self.sys;
        let y_flat = y.flat();
        self.run_chunks(views, |_, mut rr| {
            let (lo, rows) = (rr.offset, rr.rows);
            let t_s = &t[lo..lo + rows];
            let dt_s = &dt[lo..lo + rows];
            let y_s = &y_flat[lo * dim..(lo + rows) * dim];
            let k0_s = &k0_ready[lo..lo + rows];
            let act_s = active.map(|m| &m[lo..lo + rows]);
            rk_attempt_rows(ct, sys, t_s, dt_s, y_s, &mut rr, k0_s, act_s, eval_inactive);
        });

        // One *semantic* batched call per stage, however many chunks
        // physically carried it (torchode accounting).
        attempt_call_count(ct, k0_ready)
    }

    fn initial_step(
        &self,
        t0: &[f64],
        y0: &BatchVec,
        f0: &BatchVec,
        order: usize,
        tols: &Tolerances,
        span: &[f64],
        scratch_y: &mut BatchVec,
        scratch_f: &mut BatchVec,
    ) -> Vec<f64> {
        // One-time cost; runs serially (and bitwise-identically).
        initial_step_batch(self.sys, t0, y0, f0, order, tols, span, scratch_y, scratch_f)
    }

    fn error_sumsq(
        &self,
        err: &BatchVec,
        y0: &BatchVec,
        y1: &BatchVec,
        tols: &Tolerances,
        out: &mut [f64],
    ) {
        let row_sizes: Vec<usize> = self.bounds.iter().map(|&(lo, hi)| hi - lo).collect();
        let out_chunks = split_chunks(out, &row_sizes);
        let bounds = &self.bounds;
        self.run_chunks(out_chunks, |c, chunk| {
            let (lo, _hi) = bounds[c];
            scaled_sumsq_rows(err, y0, y1, tols, lo, chunk);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_bounds_cover_contiguously() {
        for (batch, shards) in [(10, 3), (4, 4), (3, 8), (64, 4), (1, 2), (7, 1)] {
            let b = shard_bounds(batch, shards);
            assert!(b.len() <= shards.max(1));
            assert!(b.len() <= batch);
            assert_eq!(b[0].0, 0);
            assert_eq!(b.last().unwrap().1, batch);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            // Near-equal: sizes differ by at most one row.
            let sizes: Vec<usize> = b.iter().map(|&(lo, hi)| hi - lo).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "{sizes:?}");
        }
    }

    #[test]
    fn split_chunks_partitions() {
        let mut data = [0u8; 10];
        let chunks = split_chunks(&mut data, &[3, 0, 7]);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].len(), 3);
        assert_eq!(chunks[1].len(), 0);
        assert_eq!(chunks[2].len(), 7);
    }

    #[test]
    fn workspace_views_are_disjoint_and_aligned() {
        let mut ws = RkWorkspace::new(3, 7, 2);
        let bounds = [(0usize, 3usize), (3, 5), (5, 7)];
        let mut views = workspace_views(&mut ws, &bounds, 2);
        assert_eq!(views.len(), 3);
        for (v, &(lo, hi)) in views.iter().zip(&bounds) {
            assert_eq!(v.offset, lo);
            assert_eq!(v.rows, hi - lo);
            assert_eq!(v.ytmp.len(), (hi - lo) * 2);
            assert_eq!(v.t_stage.len(), hi - lo);
            assert_eq!(v.k[0].len(), (hi - lo) * 2);
            // Unused stage slots are empty, not aliased.
            assert_eq!(v.k[3].len(), 0);
        }
        // Writes through one view land in the right workspace rows.
        views[1].y_new[0] = 42.0;
        drop(views);
        assert_eq!(ws.y_new.row(3)[0], 42.0);
    }
}
