//! The exec layer: batch sharding across a CPU worker pool.
//!
//! torchode's core claim is that per-instance solver state is almost
//! free because the dynamics are evaluated in one batched call per
//! stage. On CPU that batched call is a row loop — and because every
//! row's state machine is independent, the loop is embarrassingly
//! shardable. This module splits a batched solve into contiguous row
//! shards, runs them on a dependency-free scoped-thread pool
//! ([`ScopedPool`]) and deterministically merges the results:
//!
//! - [`solve_ivp_parallel_pooled`] runs each shard's **full per-instance
//!   state machine** on its own worker (the shards share nothing), then
//!   merges the per-shard [`Solution`] buffers, `Stats`, traces and
//!   `Status` back into one result.
//! - [`solve_ivp_joint_pooled`] shards only the **row-update passes**
//!   (stage accumulation, dynamics evaluation, solution/error
//!   combination) of each step; the joint loop's shared controller
//!   reduction stays on the coordinator thread.
//!
//! Both paths are **bitwise-identical** to their serial counterparts:
//! the shard workers execute the same per-row code over the same values
//! (see [`crate::solver::step::rk_attempt_rows`]), and the only
//! cross-row quantity — torchode's uniform `n_f_evals` accounting — is
//! reconstructed exactly from per-shard call ledgers in
//! [`merge_sharded`].
//!
//! ## Interaction with the active set and compaction
//!
//! Each parallel-shard worker runs the full active-set loop of
//! [`crate::solver::parallel`] on its row range, including state
//! compaction when `SolveOptions::compact_threshold` is set: a shard
//! whose stragglers are all that remain packs its own state
//! independently, and the [`OffsetSystem`] wrapper composes the shard
//! base offset with the loop's slot → row map
//! ([`crate::problems::OdeSystem::f_rows_indexed`]). Compaction changes
//! neither per-row values nor the per-iteration semantic call counts the
//! ledgers record, so the merged result — including `n_f_evals` — stays
//! bitwise-identical to the serial loop whatever the threshold. The same
//! holds for `eval_inactive = false`: skipped rows simply never appear
//! in a worker's index lists.
//!
//! Sharded entry points require `S: OdeSystem + Sync` (the system is
//! shared read-only across workers); systems with `RefCell` scratch
//! (CNF/FEN) keep using the serial `solve_ivp_*` functions.

pub mod pool;

pub use pool::ScopedPool;

use crate::problems::OdeSystem;
use crate::solver::init::initial_step_batch;
use crate::solver::parallel::{solve_ivp_parallel_core, CallLedger};
use crate::solver::step::{
    attempt_call_count, rk_attempt_rows, CompiledTableau, RkRows, RkWorkspace, StageExec,
};
use crate::solver::{
    joint, solve_ivp_joint, solve_ivp_parallel, SolveOptions, Solution, TimeGrid, Tolerances,
};
use crate::tensor::BatchVec;

/// A system view that maps local shard rows onto the global instance
/// range `[offset, offset + rows)` of the wrapped system.
struct OffsetSystem<'a, S: OdeSystem + ?Sized> {
    inner: &'a S,
    offset: usize,
}

impl<S: OdeSystem + ?Sized> OdeSystem for OffsetSystem<'_, S> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn n_params(&self) -> usize {
        self.inner.n_params()
    }

    fn f_inst(&self, inst: usize, t: f64, y: &[f64], dy: &mut [f64]) {
        self.inner.f_inst(self.offset + inst, t, y, dy)
    }

    fn f_rows(
        &self,
        offset: usize,
        n: usize,
        t: &[f64],
        y: &[f64],
        dy: &mut [f64],
        active: Option<&[bool]>,
    ) {
        self.inner.f_rows(self.offset + offset, n, t, y, dy, active)
    }

    fn f_rows_indexed(
        &self,
        offset: usize,
        inst: &[usize],
        rows: &[usize],
        t: &[f64],
        y: &[f64],
        dy: &mut [f64],
    ) {
        // The shard's slot → row map composes with the shard base offset,
        // so the active-set loop works unchanged inside a shard worker.
        self.inner.f_rows_indexed(self.offset + offset, inst, rows, t, y, dy)
    }

    fn f_batch(
        &self,
        t: &[f64],
        y: &BatchVec,
        dy: &mut BatchVec,
        active: Option<&[bool]>,
    ) {
        self.inner.f_rows(self.offset, y.batch(), t, y.flat(), dy.flat_mut(), active)
    }
}

/// Contiguous near-equal row shards: `min(shards, batch)` ranges whose
/// first `batch % n` members carry one extra row. An oversubscribed pool
/// (threads > batch) simply produces one shard per row.
pub(crate) fn shard_bounds(batch: usize, shards: usize) -> Vec<(usize, usize)> {
    let n = shards.max(1).min(batch.max(1));
    let base = batch / n;
    let rem = batch % n;
    let mut out = Vec::with_capacity(n);
    let mut lo = 0;
    for i in 0..n {
        let len = base + usize::from(i < rem);
        out.push((lo, lo + len));
        lo += len;
    }
    debug_assert_eq!(lo, batch);
    out
}

/// Split a flat buffer into consecutive chunks of the given sizes.
fn split_chunks<'a, T>(mut s: &'a mut [T], sizes: &[usize]) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let (chunk, rest) = s.split_at_mut(n);
        out.push(chunk);
        s = rest;
    }
    out
}

/// [`crate::solver::solve_ivp_parallel`] sharded across
/// `opts.exec.effective_threads()` workers: each shard runs the full
/// per-instance state machine on its own worker; results are bitwise
/// identical to the serial path (including `Stats` — see
/// [`merge_sharded`]). Falls back to the serial loop for one thread or a
/// one-row batch.
pub fn solve_ivp_parallel_pooled<S: OdeSystem + Sync>(
    sys: &S,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> Solution {
    let batch = y0.batch();
    opts.tols.validate(batch);
    let bounds = shard_bounds(batch, opts.exec.effective_threads());
    if bounds.len() <= 1 {
        return solve_ivp_parallel(sys, y0, grid, opts);
    }
    let pool = ScopedPool::new(bounds.len());
    let jobs: Vec<_> = bounds
        .iter()
        .map(|&(lo, hi)| {
            let y0_shard = y0.rows_range(lo, hi);
            let grid_shard = grid.rows_range(lo, hi);
            let opts_shard = opts.shard_rows(lo, hi);
            move || {
                let view = OffsetSystem { inner: sys, offset: lo };
                solve_ivp_parallel_core(&view, &y0_shard, &grid_shard, &opts_shard)
            }
        })
        .collect();
    let results = pool.scatter(jobs);
    merge_sharded(&bounds, &results, batch, grid.n_eval(), y0.dim(), opts.record_trace)
}

/// Merge per-shard solutions back into one batch-shaped [`Solution`].
///
/// `ys`, `status`, `n_steps`, `n_accepted`, `n_initialized` and traces
/// are purely per-row and copy over directly. `n_f_evals` is torchode's
/// uniform "the whole batch experiences every batched call" count: the
/// global loop would have made, at iteration `n`, the *maximum* of the
/// per-shard call counts at `n` (all shards pay the `stages - 1` stage
/// calls; the non-FSAL refresh fires iff any shard had an accepted row),
/// so the merged count is `base + Σ_n max_shards per_iter[n]` — exactly
/// the serial loop's number.
fn merge_sharded(
    bounds: &[(usize, usize)],
    results: &[(Solution, CallLedger)],
    batch: usize,
    n_eval: usize,
    dim: usize,
    record_trace: bool,
) -> Solution {
    let mut sol = Solution::new_buffer(batch, n_eval, dim);
    let mut trace: Option<Vec<Vec<(f64, f64)>>> =
        if record_trace { Some(vec![Vec::new(); batch]) } else { None };

    for (&(lo, _hi), (shard, _)) in bounds.iter().zip(results) {
        for r in 0..shard.batch() {
            let i = lo + r;
            for e in 0..n_eval {
                sol.y_mut(i, e).copy_from_slice(shard.y(r, e));
            }
            sol.status[i] = shard.status[r];
            sol.stats[i] = shard.stats[r].clone();
            if let (Some(tr), Some(st)) = (trace.as_mut(), shard.trace.as_ref()) {
                tr[i] = st[r].clone();
            }
        }
    }

    let base = results.first().map_or(0, |(_, l)| l.base);
    debug_assert!(
        results.iter().all(|(_, l)| l.base == base),
        "shards disagree on pre-loop calls"
    );
    let max_iters = results.iter().map(|(_, l)| l.per_iter.len()).max().unwrap_or(0);
    let mut total = base;
    for n in 0..max_iters {
        total += results
            .iter()
            .filter_map(|(_, l)| l.per_iter.get(n).copied())
            .max()
            .unwrap_or(0);
    }
    for st in sol.stats.iter_mut() {
        st.n_f_evals = total;
    }

    sol.trace = trace;
    sol
}

/// [`crate::solver::solve_ivp_joint`] with the row-update passes of every
/// step sharded across `opts.exec.effective_threads()` workers. The
/// shared step-size controller, error-norm reduction and dense-output
/// bookkeeping stay on the coordinator thread; results are bitwise
/// identical to the serial joint loop.
pub fn solve_ivp_joint_pooled<S: OdeSystem + Sync>(
    sys: &S,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> Solution {
    let batch = y0.batch();
    opts.tols.validate(batch);
    let bounds = shard_bounds(batch, opts.exec.effective_threads());
    if bounds.len() <= 1 {
        return solve_ivp_joint(sys, y0, grid, opts);
    }
    let pool = ScopedPool::new(bounds.len());
    let exec = PooledExec { sys, pool, bounds };
    joint::joint_core(&exec, y0, grid, opts)
}

/// The pooled [`StageExec`]: shards each batched pass over row ranges.
struct PooledExec<'a, S: OdeSystem + Sync> {
    sys: &'a S,
    pool: ScopedPool,
    bounds: Vec<(usize, usize)>,
}

impl<S: OdeSystem + Sync> StageExec for PooledExec<'_, S> {
    fn dim(&self) -> usize {
        self.sys.dim()
    }

    fn eval(&self, t: &[f64], y: &BatchVec, dy: &mut BatchVec, active: Option<&[bool]>) {
        let dim = y.dim();
        let sizes: Vec<usize> = self.bounds.iter().map(|&(lo, hi)| (hi - lo) * dim).collect();
        let dy_chunks = split_chunks(dy.flat_mut(), &sizes);
        let sys = self.sys;
        let y_flat = y.flat();
        let jobs: Vec<_> = self
            .bounds
            .iter()
            .zip(dy_chunks)
            .map(|(&(lo, hi), chunk)| {
                let t_s = &t[lo..hi];
                let y_s = &y_flat[lo * dim..hi * dim];
                let act_s = active.map(|m| &m[lo..hi]);
                move || sys.f_rows(lo, hi - lo, t_s, y_s, chunk, act_s)
            })
            .collect();
        self.pool.scatter(jobs);
    }

    fn attempt(
        &self,
        ct: &CompiledTableau,
        t: &[f64],
        dt: &[f64],
        y: &BatchVec,
        ws: &mut RkWorkspace,
        k0_ready: &[bool],
        active: Option<&[bool]>,
        eval_inactive: bool,
    ) -> u64 {
        let dim = y.dim();
        let sizes: Vec<usize> = self.bounds.iter().map(|&(lo, hi)| (hi - lo) * dim).collect();
        let row_sizes: Vec<usize> = self.bounds.iter().map(|&(lo, hi)| hi - lo).collect();

        // Disjoint row-range views of every workspace buffer.
        let mut k_chunks: Vec<std::vec::IntoIter<&mut [f64]>> = ws
            .k
            .iter_mut()
            .map(|k| split_chunks(k.flat_mut(), &sizes).into_iter())
            .collect();
        let mut ytmp_it = split_chunks(ws.ytmp.flat_mut(), &sizes).into_iter();
        let mut y_new_it = split_chunks(ws.y_new.flat_mut(), &sizes).into_iter();
        let mut err_it = split_chunks(ws.err.flat_mut(), &sizes).into_iter();
        let mut ts_it = split_chunks(&mut ws.t_stage[..], &row_sizes).into_iter();
        let mut cold_it = split_chunks(&mut ws.cold[..], &row_sizes).into_iter();

        let mut shards: Vec<RkRows<'_>> = Vec::with_capacity(self.bounds.len());
        for &(lo, hi) in &self.bounds {
            shards.push(RkRows {
                offset: lo,
                rows: hi - lo,
                dim,
                k: std::array::from_fn(|s| {
                    k_chunks.get_mut(s).map_or_else(Default::default, |it| it.next().unwrap())
                }),
                ytmp: ytmp_it.next().unwrap(),
                y_new: y_new_it.next().unwrap(),
                err: err_it.next().unwrap(),
                t_stage: ts_it.next().unwrap(),
                cold: cold_it.next().unwrap(),
            });
        }

        let sys = self.sys;
        let y_flat = y.flat();
        let jobs: Vec<_> = shards
            .into_iter()
            .map(|mut rr| {
                let (lo, rows) = (rr.offset, rr.rows);
                let t_s = &t[lo..lo + rows];
                let dt_s = &dt[lo..lo + rows];
                let y_s = &y_flat[lo * dim..(lo + rows) * dim];
                let k0_s = &k0_ready[lo..lo + rows];
                let act_s = active.map(|m| &m[lo..lo + rows]);
                move || {
                    rk_attempt_rows(ct, sys, t_s, dt_s, y_s, &mut rr, k0_s, act_s, eval_inactive)
                }
            })
            .collect();
        self.pool.scatter(jobs);

        // One *semantic* batched call per stage, however many shards
        // physically carried it (torchode accounting).
        attempt_call_count(ct, k0_ready)
    }

    fn initial_step(
        &self,
        t0: &[f64],
        y0: &BatchVec,
        f0: &BatchVec,
        order: usize,
        tols: &Tolerances,
        span: &[f64],
        scratch_y: &mut BatchVec,
        scratch_f: &mut BatchVec,
    ) -> Vec<f64> {
        // One-time cost; runs serially (and bitwise-identically).
        initial_step_batch(self.sys, t0, y0, f0, order, tols, span, scratch_y, scratch_f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_bounds_cover_contiguously() {
        for (batch, shards) in [(10, 3), (4, 4), (3, 8), (64, 4), (1, 2), (7, 1)] {
            let b = shard_bounds(batch, shards);
            assert!(b.len() <= shards.max(1));
            assert!(b.len() <= batch);
            assert_eq!(b[0].0, 0);
            assert_eq!(b.last().unwrap().1, batch);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            // Near-equal: sizes differ by at most one row.
            let sizes: Vec<usize> = b.iter().map(|&(lo, hi)| hi - lo).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "{sizes:?}");
        }
    }

    #[test]
    fn split_chunks_partitions() {
        let mut data = [0u8; 10];
        let chunks = split_chunks(&mut data, &[3, 0, 7]);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].len(), 3);
        assert_eq!(chunks[1].len(), 0);
        assert_eq!(chunks[2].len(), 7);
    }
}
