//! Work-stealing chunk queues for the persistent pool.
//!
//! The scoped pool's contiguous shards have a straggler pathology: with
//! one stiff row and many easy rows, the shard that owns the stiff row
//! keeps working long after its peers went idle — exactly the
//! within-batch interaction torchode's per-instance state is meant to
//! avoid. The persistent pool therefore schedules **chunks** instead:
//! the batch's row range is cut into many small contiguous chunks
//! ([`chunk_bounds`]), each worker starts with a contiguous block of
//! chunk ids in its own deque, drains it front-to-back, and when it runs
//! dry **steals the back half** of the most-loaded peer's deque
//! ([`ChunkQueues::pop`]). A straggler-heavy batch thus rebalances at
//! chunk granularity instead of serializing on one shard.
//!
//! ## Determinism
//!
//! Stealing randomizes *which worker* processes a chunk and *when* — it
//! must never change results. The exec layer guarantees that by
//! construction:
//!
//! - a chunk's work depends only on the chunk's own rows (the per-row
//!   state machines are independent; see [`crate::exec`]), and
//! - every output is written to a location keyed by **chunk id or row
//!   index**, never by worker or completion order, and reductions over
//!   chunk results always iterate in chunk order on the coordinator.
//!
//! The steal counter is the one intentionally nondeterministic output;
//! it is surfaced as scheduling observability in
//! [`crate::solver::ExecStats`] and excluded from the bitwise contract.

use super::shard_bounds;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Contiguous row chunks of (at most) `chunk` rows covering `0..batch`:
/// the scheduling grain of the work-stealing pool. Unlike
/// [`shard_bounds`], the number of chunks grows with the batch, so a
/// queue of them can rebalance; the partition never affects results,
/// only scheduling.
pub(crate) fn chunk_bounds(batch: usize, chunk: usize) -> Vec<(usize, usize)> {
    let chunk = chunk.max(1);
    let mut out = Vec::with_capacity(batch.div_ceil(chunk));
    let mut lo = 0;
    while lo < batch {
        let hi = (lo + chunk).min(batch);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Per-worker deques of chunk ids with steal-half rebalancing. All
/// methods take `&self`; the deques are individually locked so workers
/// only contend when stealing.
pub(crate) struct ChunkQueues {
    queues: Vec<Mutex<VecDeque<usize>>>,
    steals: AtomicU64,
}

impl ChunkQueues {
    /// Queues for `workers` workers over `chunks` chunk ids, each worker
    /// initially owning a contiguous block of ids (the same partition
    /// shape the scoped pool uses, so with zero steals the assignment
    /// degenerates to contiguous shards).
    pub fn new(workers: usize, chunks: usize) -> Self {
        let q = Self {
            queues: (0..workers.max(1)).map(|_| Mutex::new(VecDeque::new())).collect(),
            steals: AtomicU64::new(0),
        };
        q.reset(chunks);
        q
    }

    /// Refill the deques with `chunks` chunk ids for a fresh pass,
    /// keeping the cumulative steal counter. The joint loop calls this
    /// once per sharded pass; the parallel loop once per solve.
    pub fn reset(&self, chunks: usize) {
        let blocks = shard_bounds(chunks, self.queues.len());
        for (w, q) in self.queues.iter().enumerate() {
            let mut q = q.lock().unwrap();
            q.clear();
            if let Some(&(lo, hi)) = blocks.get(w) {
                q.extend(lo..hi);
            }
        }
    }

    /// Next chunk id for worker `w`: its own deque's front, else the
    /// back half of the most-loaded peer's deque (one steal operation),
    /// else `None` — every queue is empty and the pass is over. Chunks
    /// are delivered exactly once per [`ChunkQueues::reset`].
    pub fn pop(&self, w: usize) -> Option<usize> {
        if let Some(c) = self.queues[w].lock().unwrap().pop_front() {
            return Some(c);
        }
        self.steal_into(w)
    }

    /// Steal operations performed since construction.
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    fn steal_into(&self, w: usize) -> Option<usize> {
        loop {
            // Pick the most-loaded peer at this instant (racy by nature;
            // re-checked under the victim's lock below).
            let mut victim = None;
            let mut best = 0usize;
            for (p, q) in self.queues.iter().enumerate() {
                if p == w {
                    continue;
                }
                let len = q.lock().unwrap().len();
                if len > best {
                    best = len;
                    victim = Some(p);
                }
            }
            let victim = victim?;
            let stolen = {
                let mut vq = self.queues[victim].lock().unwrap();
                let n = vq.len();
                if n == 0 {
                    // Raced with the victim (or another thief); rescan.
                    continue;
                }
                // Victim keeps the front floor(n/2); thief takes the rest.
                vq.split_off(n / 2)
            };
            self.steals.fetch_add(1, Ordering::Relaxed);
            let mut own = self.queues[w].lock().unwrap();
            own.extend(stolen);
            return own.pop_front();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn chunk_bounds_cover_contiguously() {
        for (batch, chunk) in [(256, 16), (10, 3), (5, 8), (7, 1), (1, 1), (64, 64)] {
            let b = chunk_bounds(batch, chunk);
            assert_eq!(b[0].0, 0);
            assert_eq!(b.last().unwrap().1, batch);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            assert!(b.iter().all(|&(lo, hi)| hi - lo <= chunk && hi > lo));
            assert_eq!(b.len(), batch.div_ceil(chunk.max(1)));
        }
        // Degenerate chunk size is clamped, not divided by zero.
        assert_eq!(chunk_bounds(3, 0).len(), 3);
        assert!(chunk_bounds(0, 4).is_empty());
    }

    /// Every chunk id is delivered exactly once, whichever worker asks.
    #[test]
    fn all_chunks_delivered_exactly_once() {
        for (workers, chunks) in [(1usize, 5usize), (3, 8), (4, 3), (2, 0)] {
            let q = ChunkQueues::new(workers, chunks);
            let mut seen = Vec::new();
            // Round-robin polling from all workers exercises both own-pops
            // and steals.
            let mut w = 0;
            while let Some(c) = q.pop(w) {
                seen.push(c);
                w = (w + 1) % workers;
            }
            // Drain any stragglers from the other workers' perspectives.
            for w in 0..workers {
                while let Some(c) = q.pop(w) {
                    seen.push(c);
                }
            }
            let set: BTreeSet<usize> = seen.iter().copied().collect();
            assert_eq!(seen.len(), chunks, "workers={workers}");
            assert_eq!(set.len(), chunks, "no duplicates");
            assert_eq!(set, (0..chunks).collect::<BTreeSet<usize>>(), "workers={workers}");
        }
    }

    /// A worker with an empty deque steals from the loaded peer, and the
    /// steal counter records it.
    #[test]
    fn empty_worker_steals_half() {
        let q = ChunkQueues::new(2, 8);
        // Worker 0 owns 0..4, worker 1 owns 4..8. Drain worker 1 dry,
        // then one more pop must steal from worker 0.
        for _ in 0..4 {
            q.pop(1).unwrap();
        }
        assert_eq!(q.steals(), 0);
        let c = q.pop(1).unwrap();
        assert_eq!(q.steals(), 1);
        // The thief takes the *back* half of 0's remaining deque.
        assert!(c >= 2, "stole {c}, expected a back-half chunk");
        // Reset refills chunks but keeps the cumulative counter.
        q.reset(8);
        assert_eq!(q.steals(), 1);
        assert_eq!(q.pop(0), Some(0));
    }

    /// reset() restores a clean assignment after a partial drain.
    #[test]
    fn reset_restores_block_assignment() {
        let q = ChunkQueues::new(3, 9);
        q.pop(0).unwrap();
        q.pop(2).unwrap();
        q.reset(6);
        let mut all = Vec::new();
        for w in 0..3 {
            while let Some(c) = q.pop(w) {
                all.push(c);
            }
        }
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
    }
}
