//! A dependency-free scoped-thread worker pool.
//!
//! The vendored crate set has no rayon/crossbeam, and the solve loops
//! need workers that can borrow non-`'static` data (the system, shard
//! views of a workspace), so the pool is built on `std::thread::scope`:
//! every [`ScopedPool::scatter`] call fans a set of jobs out over fresh
//! scoped threads and joins them before returning. The coordinator
//! thread runs the first job itself, so `n` jobs cost `n - 1` spawns —
//! for the batch-sharded solves that is one spawn per worker per *solve*
//! (the parallel loop) or per *step* (the joint loop's row-update
//! passes), both far below the work they carry at the batch sizes the
//! pool is built for.

/// A worker pool of a fixed size; see the module docs for the execution
/// model.
#[derive(Debug, Clone)]
pub struct ScopedPool {
    threads: usize,
}

impl ScopedPool {
    /// A pool of `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `jobs` concurrently and return their results in job order.
    /// Callers size `jobs` to at most [`ScopedPool::threads`] (one shard
    /// per worker); a serial pool or a single job short-circuits to the
    /// calling thread. A panicking job propagates its panic.
    pub fn scatter<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        if self.threads <= 1 || jobs.len() <= 1 {
            return jobs.into_iter().map(|j| j()).collect();
        }
        let mut rest = jobs.into_iter();
        let first = rest.next().expect("scatter over at least one job");
        std::thread::scope(|s| {
            let handles: Vec<_> = rest.map(|job| s.spawn(job)).collect();
            let mut out = Vec::with_capacity(handles.len() + 1);
            out.push(first());
            for h in handles {
                out.push(h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)));
            }
            out
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_preserves_job_order() {
        let pool = ScopedPool::new(4);
        let jobs: Vec<_> = (0..7).map(|i| move || i * 10).collect();
        assert_eq!(pool.scatter(jobs), vec![0, 10, 20, 30, 40, 50, 60]);
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = ScopedPool::new(1);
        let tid = std::thread::current().id();
        let jobs: Vec<_> = (0..3).map(|_| move || std::thread::current().id()).collect();
        assert!(pool.scatter(jobs).into_iter().all(|t| t == tid));
    }

    #[test]
    fn workers_can_borrow_caller_data() {
        let data: Vec<u64> = (0..100).collect();
        let pool = ScopedPool::new(3);
        let slices: Vec<&[u64]> = data.chunks(34).collect();
        let jobs: Vec<_> = slices
            .into_iter()
            .map(|chunk| move || chunk.iter().sum::<u64>())
            .collect();
        let total: u64 = pool.scatter(jobs).into_iter().sum();
        assert_eq!(total, 99 * 100 / 2);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates() {
        let pool = ScopedPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
        ];
        pool.scatter(jobs);
    }
}
