//! Dependency-free CPU worker pools.
//!
//! The vendored crate set has no rayon/crossbeam, and the solve loops
//! need workers that can borrow non-`'static` data (the system, shard
//! views of a workspace), so two pools are built on `std`:
//!
//! - [`ScopedPool`] wraps `std::thread::scope`: every
//!   [`ScopedPool::scatter`] call fans a set of jobs out over *freshly
//!   spawned* scoped threads and joins them before returning. The
//!   coordinator thread runs the first job itself, so `n` jobs cost
//!   `n - 1` spawns — fine once per solve, wasteful once per step.
//! - [`PersistentPool`] spawns its workers **once** and parks them on a
//!   condvar between passes. Each [`PersistentPool::run`] call publishes
//!   one shared job under a bumped generation counter, wakes the
//!   workers, runs the job as worker 0 itself, and waits until every
//!   worker has finished the generation. For the joint loop — several
//!   row passes per step, thousands of steps per solve — this replaces
//!   per-pass thread spawn/join with a park/unpark round trip.
//!
//! Neither pool schedules anything by itself: callers either pre-split
//! the work (scoped: one job per shard) or pull chunks from the
//! work-stealing queues in [`crate::exec::steal`] (persistent). Both
//! pools therefore leave *results* untouched — determinism is decided by
//! how callers partition rows and reduce outputs, not by which thread
//! ran what.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A worker pool of a fixed size; see the module docs for the execution
/// model.
#[derive(Debug, Clone)]
pub struct ScopedPool {
    threads: usize,
}

impl ScopedPool {
    /// A pool of `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// The worker count this pool was built with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `jobs` concurrently and return their results in job order.
    /// Callers size `jobs` to at most [`ScopedPool::threads`] (one shard
    /// per worker); a serial pool or a single job short-circuits to the
    /// calling thread. A panicking job propagates its panic.
    pub fn scatter<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        if self.threads <= 1 || jobs.len() <= 1 {
            return jobs.into_iter().map(|j| j()).collect();
        }
        let mut rest = jobs.into_iter();
        let first = rest.next().expect("scatter over at least one job");
        std::thread::scope(|s| {
            let handles: Vec<_> = rest.map(|job| s.spawn(job)).collect();
            let mut out = Vec::with_capacity(handles.len() + 1);
            out.push(first());
            for h in handles {
                out.push(h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)));
            }
            out
        })
    }
}

/// A long-lived worker pool: `threads - 1` OS threads spawned at
/// construction, parked on a condvar between passes, woken by a
/// generation-counter barrier. See the module docs.
///
/// One [`PersistentPool::run`] call is one *pass*: the same job closure
/// runs once on every worker (the coordinator doubles as worker 0), and
/// `run` returns only after all of them finished — which is what makes
/// handing borrowed data to the workers sound (see the safety comment in
/// `run`). Workers pull their actual work items from a shared source
/// (e.g. [`crate::exec::steal::ChunkQueues`]) keyed by the worker index
/// the job receives.
pub struct PersistentPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

/// State shared between the coordinator and the parked workers.
struct PoolShared {
    state: Mutex<PoolState>,
    /// Wakes workers when a new generation (or shutdown) is published.
    work: Condvar,
    /// Wakes the coordinator when the last worker finishes a generation.
    done: Condvar,
}

struct PoolState {
    /// The current pass's job. Only valid while `remaining > 0` for the
    /// matching `generation`; the lifetime is erased in `run`, which does
    /// not return before every worker is done with it.
    job: Option<&'static (dyn Fn(usize) + Sync)>,
    /// Barrier counter: bumped once per `run` call.
    generation: u64,
    /// Workers that have not yet finished the current generation.
    remaining: usize,
    shutdown: bool,
    /// First worker panic of the current generation, rethrown by `run`.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl PersistentPool {
    /// Spawn a pool of `threads` workers (clamped to at least 1). The
    /// coordinator thread counts as worker 0, so `threads - 1` OS
    /// threads are created; they park immediately and live until the
    /// pool is dropped.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                job: None,
                generation: 0,
                remaining: 0,
                shutdown: false,
                panic: None,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|idx| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rode-pool-{idx}"))
                    .spawn(move || worker_loop(&shared, idx))
                    .expect("spawn persistent pool worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Total workers, including the coordinator as worker 0.
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Run one pass: `job(w)` executes once per worker index
    /// `w ∈ 0..threads()`, concurrently, and `run` returns after all of
    /// them completed. A panic in any worker (or in the coordinator's own
    /// share) is re-raised here after the barrier.
    // The transmute below changes only the lifetime — which is the point.
    #[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
    pub fn run(&self, job: &(dyn Fn(usize) + Sync)) {
        if self.workers.is_empty() {
            job(0);
            return;
        }
        // SAFETY: the only consumers of this lifetime-erased reference
        // are the pool's own workers, and the barrier below (`remaining`
        // reaching 0) guarantees every worker is done with the job — and
        // holds no copy of it — before `run` returns. The borrow it was
        // created from outlives `run`, so no worker ever observes a
        // dangling reference.
        let job_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(job) };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(job_static);
            st.generation += 1;
            st.remaining = self.workers.len();
            st.panic = None;
            self.shared.work.notify_all();
        }
        // The coordinator is worker 0. If its share panics, the workers
        // must still be awaited before unwinding — they may be borrowing
        // the same data the panic would free.
        let own = catch_unwind(AssertUnwindSafe(|| job(0)));
        let worker_panic = {
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = None;
            st.panic.take()
        };
        if let Err(p) = own {
            resume_unwind(p);
        }
        if let Some(p) = worker_panic {
            resume_unwind(p);
        }
    }
}

impl Drop for PersistentPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for PersistentPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PersistentPool").field("threads", &self.threads()).finish()
    }
}

/// The parked-worker loop: wait for a generation bump (or shutdown), run
/// the published job, report completion, park again.
fn worker_loop(shared: &PoolShared, idx: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen {
                    seen = st.generation;
                    break st.job.expect("a bumped generation always publishes a job");
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        let res = catch_unwind(AssertUnwindSafe(|| job(idx)));
        let mut st = shared.state.lock().unwrap();
        if let Err(p) = res {
            if st.panic.is_none() {
                st.panic = Some(p);
            }
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_preserves_job_order() {
        let pool = ScopedPool::new(4);
        let jobs: Vec<_> = (0..7).map(|i| move || i * 10).collect();
        assert_eq!(pool.scatter(jobs), vec![0, 10, 20, 30, 40, 50, 60]);
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = ScopedPool::new(1);
        let tid = std::thread::current().id();
        let jobs: Vec<_> = (0..3).map(|_| move || std::thread::current().id()).collect();
        assert!(pool.scatter(jobs).into_iter().all(|t| t == tid));
    }

    #[test]
    fn workers_can_borrow_caller_data() {
        let data: Vec<u64> = (0..100).collect();
        let pool = ScopedPool::new(3);
        let slices: Vec<&[u64]> = data.chunks(34).collect();
        let jobs: Vec<_> = slices
            .into_iter()
            .map(|chunk| move || chunk.iter().sum::<u64>())
            .collect();
        let total: u64 = pool.scatter(jobs).into_iter().sum();
        assert_eq!(total, 99 * 100 / 2);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates() {
        let pool = ScopedPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
        ];
        pool.scatter(jobs);
    }

    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn persistent_pool_runs_every_worker_once_per_pass() {
        let pool = PersistentPool::new(4);
        assert_eq!(pool.threads(), 4);
        let hits = AtomicU64::new(0);
        let mask = AtomicU64::new(0);
        pool.run(&|w| {
            hits.fetch_add(1, Ordering::SeqCst);
            mask.fetch_or(1 << w, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        assert_eq!(mask.load(Ordering::SeqCst), 0b1111);
    }

    #[test]
    fn persistent_pool_is_reusable_across_passes() {
        // The whole point: many passes over one set of parked workers,
        // each pass borrowing fresh stack data.
        let pool = PersistentPool::new(3);
        for round in 0u64..50 {
            let acc = AtomicU64::new(0);
            pool.run(&|w| {
                acc.fetch_add(round * 10 + w as u64, Ordering::SeqCst);
            });
            assert_eq!(acc.load(Ordering::SeqCst), 3 * round * 10 + 3);
        }
    }

    #[test]
    fn persistent_pool_of_one_runs_inline() {
        let pool = PersistentPool::new(1);
        let tid = std::sync::Mutex::new(None);
        pool.run(&|w| {
            assert_eq!(w, 0);
            *tid.lock().unwrap() = Some(std::thread::current().id());
        });
        assert_eq!(tid.lock().unwrap().unwrap(), std::thread::current().id());
    }

    #[test]
    fn persistent_pool_workers_can_borrow_caller_data() {
        let data: Vec<u64> = (0..90).collect();
        let pool = PersistentPool::new(3);
        let partial = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
        pool.run(&|w| {
            let s: u64 = data[w * 30..(w + 1) * 30].iter().sum();
            partial[w].store(s, Ordering::SeqCst);
        });
        let total: u64 = partial.iter().map(|a| a.load(Ordering::SeqCst)).sum();
        assert_eq!(total, 89 * 90 / 2);
    }

    #[test]
    #[should_panic(expected = "pool boom")]
    fn persistent_pool_worker_panic_propagates() {
        let pool = PersistentPool::new(2);
        pool.run(&|w| {
            if w == 1 {
                panic!("pool boom");
            }
        });
    }

    /// A panic in one pass must not wedge the pool: later passes still
    /// run on every worker.
    #[test]
    fn persistent_pool_survives_a_panicked_pass() {
        let pool = PersistentPool::new(2);
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(&|w| {
                if w == 1 {
                    panic!("first pass");
                }
            });
        }));
        assert!(res.is_err());
        let hits = AtomicU64::new(0);
        pool.run(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }
}
