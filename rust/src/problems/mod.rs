//! The ODE problem zoo: every dynamical system used by the paper's
//! experiments (or our documented stand-ins for them).
//!
//! A system describes a *batch* of structurally identical ODEs that may
//! differ in per-instance parameters (e.g. one damping μ per Van der Pol
//! instance). The solver always evaluates the dynamics through
//! [`OdeSystem::f_batch`] — one call per RK stage for the whole batch —
//! mirroring how a learned model is evaluated on a GPU. Systems with a
//! batched fast path (neural dynamics doing one matmul for all instances)
//! override `f_batch`; everything else gets the row-loop default.

mod cnf;
mod fen;
mod linear;
mod lotka;
mod oscillators;
mod reaction_diffusion;
mod robertson;
mod vdp;

pub use cnf::CnfDynamics;
pub use fen::{FenDynamics, Mesh};
pub use linear::{ExponentialDecay, LinearSystem};
pub use lotka::LotkaVolterra;
pub use oscillators::{Brusselator, Pendulum};
pub use reaction_diffusion::ReactionDiffusion;
pub use robertson::Robertson;
pub use vdp::VdP;

use crate::tensor::BatchVec;

/// Sparsity structure of a system's Jacobian `∂f/∂y`, used by the
/// implicit solver ([`crate::solver::implicit`]) to pick the
/// factorization for the Newton iteration matrix `I − hγJ` and to size
/// its per-row scratch.
///
/// `Dense` stores and factors the full `dim × dim` matrix — O(dim²)
/// storage, O(dim³) factor. `Banded { lower, upper }` declares that
/// every instance's Jacobian satisfies `J[i][j] = 0` outside
/// `−upper ≤ i − j ≤ lower`, and switches the Newton path to the banded
/// storage and LU of [`crate::solver::linalg`] — O(dim·bandwidth)
/// storage, O(dim·bandwidth²) factor — which is what makes implicit
/// steps feasible on method-of-lines discretizations at dim 10²–10⁴
/// (e.g. [`ReactionDiffusion`], tridiagonal: `lower = upper = 1`).
///
/// The structure is a *promise about zeros*, not a different operator:
/// solving a banded system through the banded path performs the same
/// nonzero arithmetic as the dense path (the dense elimination's extra
/// work touches only structural zeros), so banded and dense solves of
/// the same problem produce bitwise-identical trajectories — the banded
/// path is purely a cost win.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JacStructure {
    /// Full `dim × dim` Jacobian (the default).
    Dense,
    /// Banded Jacobian: `lower` subdiagonals and `upper` superdiagonals.
    Banded {
        /// Number of nonzero subdiagonals (`i − j ≤ lower`).
        lower: usize,
        /// Number of nonzero superdiagonals (`j − i ≤ upper`).
        upper: usize,
    },
}

impl JacStructure {
    /// The `(lower, upper)` bandwidths, treating `Dense` over `dim` as
    /// the full band `(dim − 1, dim − 1)` and clamping declared banded
    /// widths to `dim − 1` (a band can't extend past the matrix edge).
    pub fn bandwidths(&self, dim: usize) -> (usize, usize) {
        let full = dim.saturating_sub(1);
        match *self {
            JacStructure::Dense => (full, full),
            JacStructure::Banded { lower, upper } => (lower.min(full), upper.min(full)),
        }
    }

    /// Canonicalize for a concrete `dim`: `Banded` widths are clamped to
    /// `dim − 1` so two structures that describe the same set of
    /// in-matrix positions compare equal. [`crate::solver::implicit`]
    /// stores the resolved structure in its scratch and compares a
    /// system's resolved declaration against it when deciding whether
    /// the analytic band hook applies.
    pub fn resolved(self, dim: usize) -> JacStructure {
        match self {
            JacStructure::Dense => JacStructure::Dense,
            JacStructure::Banded { lower, upper } => {
                let full = dim.saturating_sub(1);
                JacStructure::Banded { lower: lower.min(full), upper: upper.min(full) }
            }
        }
    }

    /// Parse a config/CLI spelling: `dense` or `banded:KL,KU` (e.g.
    /// `banded:1,1` for tridiagonal).
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("dense") {
            return Some(JacStructure::Dense);
        }
        let rest = s.strip_prefix("banded:").or_else(|| s.strip_prefix("banded="))?;
        let (kl, ku) = rest.split_once(',')?;
        Some(JacStructure::Banded {
            lower: kl.trim().parse().ok()?,
            upper: ku.trim().parse().ok()?,
        })
    }
}

/// A batch of independent ODEs `dy/dt = f(t, y)` with shared structure.
///
/// Not `Send + Sync` by design: systems may hold per-call scratch buffers
/// (`RefCell`) for allocation-free evaluation. The coordinator gives each
/// worker thread its own system instance.
pub trait OdeSystem {
    /// State dimension of a single instance.
    fn dim(&self) -> usize;

    /// Number of trainable parameters (0 for analytic systems).
    fn n_params(&self) -> usize {
        0
    }

    /// Evaluate the dynamics of instance `inst` at time `t`.
    fn f_inst(&self, inst: usize, t: f64, y: &[f64], dy: &mut [f64]);

    /// Evaluate the contiguous instance range `[offset, offset + n)` into
    /// flat row-major slices. `t`, `y`, `dy` and `active` are indexed
    /// *locally* (`t[r]` belongs to instance `offset + r`); only `active`
    /// rows may be written. This is the primitive the sharded executor
    /// ([`crate::exec`]) drives — [`OdeSystem::f_batch`] is the
    /// whole-batch special case. Systems with batched kernels should
    /// override this method (not `f_batch`) and must keep rows
    /// independent, so a sharded solve stays bitwise-identical to a
    /// serial one.
    fn f_rows(
        &self,
        offset: usize,
        n: usize,
        t: &[f64],
        y: &[f64],
        dy: &mut [f64],
        active: Option<&[bool]>,
    ) {
        let dim = self.dim();
        for r in 0..n {
            if active.map_or(true, |m| m[r]) {
                self.f_inst(
                    offset + r,
                    t[r],
                    &y[r * dim..(r + 1) * dim],
                    &mut dy[r * dim..(r + 1) * dim],
                );
            }
        }
    }

    /// Evaluate a subset of the rows of a packed `(n, dim)` buffer through
    /// an explicit slot → instance map: for each local row `r` in `rows`,
    /// `dy[r] = f(offset + inst[r], t[r], y[r])`. Rows not listed are
    /// untouched and cost **zero** per-row work — this is the eval
    /// primitive of the active-set parallel loop
    /// ([`crate::solver::parallel`]): with `eval_inactive = false` the
    /// finished rows are skipped outright, and after state compaction the
    /// live rows are dense in the buffers but map to non-contiguous
    /// instances. Systems that override [`OdeSystem::f_rows`] with a
    /// batched kernel should override this too, and must keep per-row
    /// results bitwise-identical to `f_inst` so compacted, masked and
    /// serial solves all agree.
    fn f_rows_indexed(
        &self,
        offset: usize,
        inst: &[usize],
        rows: &[usize],
        t: &[f64],
        y: &[f64],
        dy: &mut [f64],
    ) {
        let dim = self.dim();
        for &r in rows {
            self.f_inst(
                offset + inst[r],
                t[r],
                &y[r * dim..(r + 1) * dim],
                &mut dy[r * dim..(r + 1) * dim],
            );
        }
    }

    /// Evaluate the whole batch, one time per instance. `active` masks the
    /// rows that still need values; `None` means all rows. Delegates to
    /// [`OdeSystem::f_rows`] over the full row range.
    fn f_batch(&self, t: &[f64], y: &BatchVec, dy: &mut BatchVec, active: Option<&[bool]>) {
        self.f_rows(0, y.batch(), t, y.flat(), dy.flat_mut(), active);
    }

    /// Whether an analytic Jacobian is available through
    /// [`OdeSystem::jac_rows`]. When `false` (the default) the implicit
    /// solver ([`crate::solver::implicit`]) builds Jacobians by forward
    /// differences against the step-start slope instead.
    fn has_jac(&self) -> bool {
        false
    }

    /// Analytic Jacobian `∂f/∂y` of instance `inst` at `(t, y)`, written
    /// row-major into `jac` (`dim × dim`). Only required when
    /// [`OdeSystem::has_jac`] returns `true`; the default panics.
    fn jac_inst(&self, _inst: usize, _t: f64, _y: &[f64], _jac: &mut [f64]) {
        unimplemented!("system does not provide an analytic Jacobian (has_jac() is false)")
    }

    /// Jacobians for the contiguous instance range `[offset, offset+n)`:
    /// block `r` of `jac` (a `dim²` row-major block) receives `∂f/∂y` at
    /// `(t[r], y[r])` for instance `offset + r`. `rows` restricts the
    /// fill to the listed local rows (`None` = all). This is the analytic
    /// hook the implicit solver drives — per-row results must be
    /// independent and deterministic so sharded implicit solves stay
    /// bitwise-identical to serial ones. Delegates to
    /// [`OdeSystem::jac_inst`] by default.
    fn jac_rows(
        &self,
        offset: usize,
        n: usize,
        t: &[f64],
        y: &[f64],
        jac: &mut [f64],
        rows: Option<&[usize]>,
    ) {
        let dim = self.dim();
        let dd = dim * dim;
        let mut fill = |r: usize| {
            self.jac_inst(
                offset + r,
                t[r],
                &y[r * dim..(r + 1) * dim],
                &mut jac[r * dd..(r + 1) * dd],
            )
        };
        match rows {
            Some(idx) => {
                for &r in idx {
                    fill(r);
                }
            }
            None => {
                for r in 0..n {
                    fill(r);
                }
            }
        }
    }

    /// Declared sparsity structure of this system's Jacobian. The
    /// implicit solver selects its factorization (dense vs banded LU)
    /// and sizes its per-row Newton scratch from this; see
    /// [`JacStructure`]. Must be a *valid* promise: with
    /// `Banded { lower, upper }` every entry outside the band must be
    /// identically zero for every instance, time and state. Defaults to
    /// [`JacStructure::Dense`].
    fn jac_structure(&self) -> JacStructure {
        JacStructure::Dense
    }

    /// Analytic *banded* Jacobian of instance `inst` at `(t, y)`, for
    /// systems whose [`OdeSystem::jac_structure`] is
    /// `Banded { lower, upper }` and whose [`OdeSystem::has_jac`] is
    /// `true`. `jac` is `dim * (lower + upper + 1)` long in column-major
    /// band layout: column `j` occupies the `lower + upper + 1` slots
    /// starting at `j * (lower + upper + 1)`, with entry `(i, j)` at
    /// offset `upper + i − j` (the [`crate::solver::linalg`] layout
    /// without the pivot-fill headroom). **Every** slot must be written —
    /// corner slots whose `(i, j)` falls outside the matrix get `0.0` —
    /// because the solver reuses the buffer across steps without
    /// re-zeroing it. The default panics.
    fn jac_band_inst(&self, _inst: usize, _t: f64, _y: &[f64], _jac: &mut [f64]) {
        unimplemented!(
            "system declares a banded Jacobian structure but does not implement jac_band_inst"
        )
    }

    /// Banded Jacobians for the contiguous instance range
    /// `[offset, offset + n)`: block `r` of `jac` (one
    /// `dim * (lower + upper + 1)` band block, see
    /// [`OdeSystem::jac_band_inst`]) receives the band of `∂f/∂y` at
    /// `(t[r], y[r])` for instance `offset + r`. `rows` restricts the
    /// fill to the listed local rows (`None` = all). Per-row results
    /// must be independent and deterministic, like
    /// [`OdeSystem::jac_rows`]. Delegates to
    /// [`OdeSystem::jac_band_inst`] by default.
    fn jac_band_rows(
        &self,
        offset: usize,
        n: usize,
        t: &[f64],
        y: &[f64],
        jac: &mut [f64],
        rows: Option<&[usize]>,
    ) {
        let dim = self.dim();
        let (kl, ku) = self.jac_structure().bandwidths(dim);
        let block = dim * (kl + ku + 1);
        let mut fill = |r: usize| {
            self.jac_band_inst(
                offset + r,
                t[r],
                &y[r * dim..(r + 1) * dim],
                &mut jac[r * block..(r + 1) * block],
            )
        };
        match rows {
            Some(idx) => {
                for &r in idx {
                    fill(r);
                }
            }
            None => {
                for r in 0..n {
                    fill(r);
                }
            }
        }
    }

    /// Vector-Jacobian products for the adjoint method:
    /// `out_y = aᵀ ∂f/∂y` and `out_p = aᵀ ∂f/∂θ` at `(t, y)` for instance
    /// `inst`. Required only for systems used with
    /// [`crate::solver::adjoint`]; the default panics.
    fn vjp_inst(
        &self,
        _inst: usize,
        _t: f64,
        _y: &[f64],
        _a: &[f64],
        _out_y: &mut [f64],
        _out_p: &mut [f64],
    ) {
        unimplemented!("system does not provide VJPs (needed for the adjoint backward pass)")
    }

    /// Whether [`OdeSystem::vjp_inst`] is implemented.
    fn has_vjp(&self) -> bool {
        false
    }
}

/// Finite-difference check utility shared by the VJP tests: compares
/// `aᵀ ∂f/∂y` against central differences.
#[cfg(test)]
pub(crate) fn check_vjp_y(sys: &dyn OdeSystem, inst: usize, t: f64, y: &[f64], a: &[f64]) {
    let d = sys.dim();
    let p = sys.n_params();
    let mut out_y = vec![0.0; d];
    let mut out_p = vec![0.0; p];
    sys.vjp_inst(inst, t, y, a, &mut out_y, &mut out_p);
    let h = 1e-6;
    let mut fp = vec![0.0; d];
    let mut fm = vec![0.0; d];
    let mut yy = y.to_vec();
    for j in 0..d {
        yy[j] = y[j] + h;
        sys.f_inst(inst, t, &yy, &mut fp);
        yy[j] = y[j] - h;
        sys.f_inst(inst, t, &yy, &mut fm);
        yy[j] = y[j];
        let mut fd = 0.0;
        for i in 0..d {
            fd += a[i] * (fp[i] - fm[i]) / (2.0 * h);
        }
        assert!(
            (out_y[j] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
            "vjp_y[{j}] = {} but finite diff = {fd}",
            out_y[j]
        );
    }
}
