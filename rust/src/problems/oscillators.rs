//! Additional nonlinear test systems: the mathematical pendulum and the
//! Brusselator (a chemical oscillator whose stiffness is tunable through
//! its `b` parameter, complementing Van der Pol for controller studies).

use super::OdeSystem;

/// Mathematical pendulum `θ̈ = -(g/L) sin θ` in `y = (θ, θ̇)`.
#[derive(Debug, Clone)]
pub struct Pendulum {
    g_over_l: Vec<f64>,
}

impl Pendulum {
    pub fn new(g_over_l: Vec<f64>) -> Self {
        assert!(!g_over_l.is_empty());
        Self { g_over_l }
    }

    pub fn uniform(batch: usize, g_over_l: f64) -> Self {
        Self { g_over_l: vec![g_over_l; batch] }
    }

    fn w2(&self, inst: usize) -> f64 {
        self.g_over_l[inst.min(self.g_over_l.len() - 1)]
    }

    /// Total energy (conserved): `θ̇²/2 − ω² cos θ`.
    pub fn energy(&self, inst: usize, y: &[f64]) -> f64 {
        0.5 * y[1] * y[1] - self.w2(inst) * y[0].cos()
    }
}

impl OdeSystem for Pendulum {
    fn dim(&self) -> usize {
        2
    }

    #[inline]
    fn f_inst(&self, inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
        dy[0] = y[1];
        dy[1] = -self.w2(inst) * y[0].sin();
    }

    fn vjp_inst(
        &self,
        inst: usize,
        _t: f64,
        y: &[f64],
        a: &[f64],
        out_y: &mut [f64],
        _out_p: &mut [f64],
    ) {
        out_y[0] = -a[1] * self.w2(inst) * y[0].cos();
        out_y[1] = a[0];
    }

    fn has_vjp(&self) -> bool {
        true
    }
}

/// Brusselator: `ẋ = a + x²y − (b+1)x`, `ẏ = bx − x²y`. For `b > 1 + a²`
/// the fixed point is unstable and a limit cycle appears; large `b` makes
/// the cycle strongly relaxational (stiff in phases), like VdP at large μ.
#[derive(Debug, Clone)]
pub struct Brusselator {
    ab: Vec<[f64; 2]>,
}

impl Brusselator {
    pub fn new(ab: Vec<[f64; 2]>) -> Self {
        assert!(!ab.is_empty());
        Self { ab }
    }

    pub fn uniform(batch: usize, a: f64, b: f64) -> Self {
        Self { ab: vec![[a, b]; batch] }
    }

    fn p(&self, inst: usize) -> [f64; 2] {
        self.ab[inst.min(self.ab.len() - 1)]
    }
}

impl OdeSystem for Brusselator {
    fn dim(&self) -> usize {
        2
    }

    #[inline]
    fn f_inst(&self, inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
        let [a, b] = self.p(inst);
        let (x, z) = (y[0], y[1]);
        dy[0] = a + x * x * z - (b + 1.0) * x;
        dy[1] = b * x - x * x * z;
    }

    fn vjp_inst(
        &self,
        inst: usize,
        _t: f64,
        y: &[f64],
        a_vec: &[f64],
        out_y: &mut [f64],
        _out_p: &mut [f64],
    ) {
        let [_a, b] = self.p(inst);
        let (x, z) = (y[0], y[1]);
        // J = [[2xz - (b+1), x²], [b - 2xz, -x²]]
        out_y[0] = a_vec[0] * (2.0 * x * z - (b + 1.0)) + a_vec[1] * (b - 2.0 * x * z);
        out_y[1] = a_vec[0] * x * x - a_vec[1] * x * x;
    }

    fn has_vjp(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::check_vjp_y;

    #[test]
    fn pendulum_small_angle_is_harmonic() {
        let sys = Pendulum::uniform(1, 4.0);
        let mut dy = [0.0; 2];
        let th = 1e-8;
        sys.f_inst(0, 0.0, &[th, 0.0], &mut dy);
        assert!((dy[1] + 4.0 * th).abs() < 1e-18);
    }

    #[test]
    fn brusselator_fixed_point() {
        // Fixed point at (a, b/a).
        let sys = Brusselator::uniform(1, 1.0, 3.0);
        let mut dy = [0.0; 2];
        sys.f_inst(0, 0.0, &[1.0, 3.0], &mut dy);
        assert!(dy[0].abs() < 1e-12 && dy[1].abs() < 1e-12);
    }

    #[test]
    fn vjps_match_fd() {
        check_vjp_y(&Pendulum::uniform(1, 2.5), 0, 0.0, &[0.8, -0.4], &[1.0, 0.3]);
        check_vjp_y(&Brusselator::uniform(1, 1.0, 3.0), 0, 0.0, &[1.2, 2.1], &[0.5, -0.7]);
    }
}
