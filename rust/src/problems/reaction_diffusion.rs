//! Fisher–KPP reaction–diffusion, discretized by the 1-D method of
//! lines — the PDE-scale stiff workload that motivates the banded
//! Newton path.
//!
//! The PDE on `x ∈ [0, 1]` with no-flux (Neumann) boundaries:
//!
//! ```text
//! u_t = D u_xx + r u (1 − u)
//! ```
//!
//! Second-order central differences on `n` grid points (`dx = 1/(n−1)`,
//! ghost points for the boundaries) turn it into an `n`-dimensional ODE
//! system:
//!
//! ```text
//! u₀'    = 2c (u₁ − u₀)            + r u₀ (1 − u₀)
//! uᵢ'    = c (uᵢ₋₁ − 2uᵢ + uᵢ₊₁)   + r uᵢ (1 − uᵢ)     0 < i < n−1
//! uₙ₋₁'  = 2c (uₙ₋₂ − uₙ₋₁)        + r uₙ₋₁ (1 − uₙ₋₁)
//! ```
//!
//! with `c = D/dx²`. The diffusion operator's spectrum reaches `−4c ≈
//! −4D(n−1)²`, so stiffness grows quadratically with resolution — at
//! `n = 1024` the stable explicit step is ~10⁻⁷ of the front's time
//! scale while an L-stable implicit method steps at the accuracy limit.
//! The Jacobian is tridiagonal ([`JacStructure::Banded`] with
//! `lower = upper = 1`), which is exactly what the banded Newton path
//! exploits: O(n) storage and factor work instead of O(n²)/O(n³).
//!
//! The diffusion coefficient `D` is *per-instance* (like Van der Pol's
//! μ): one batch spans a range of stiffnesses, torchode's
//! independent-step-size stress test at PDE scale. Both the dense
//! ([`OdeSystem::jac_inst`]) and banded ([`OdeSystem::jac_band_inst`])
//! analytic Jacobian hooks are implemented, so the same problem drives
//! either factorization — the banded-vs-dense bitwise-identity and
//! speedup benches lean on that.

use super::{JacStructure, OdeSystem};

/// A batch of Fisher–KPP method-of-lines instances with per-instance
/// diffusion coefficients on a shared `n`-point grid.
#[derive(Debug, Clone)]
pub struct ReactionDiffusion {
    d: Vec<f64>,
    n: usize,
    r: f64,
}

impl ReactionDiffusion {
    /// Instances with the given per-instance diffusion coefficients on
    /// an `n`-point grid (`n ≥ 3`), reaction rate `r = 1`.
    pub fn new(d: Vec<f64>, n: usize) -> Self {
        assert!(!d.is_empty());
        assert!(n >= 3, "method-of-lines grid needs at least 3 points, got {n}");
        assert!(d.iter().all(|&v| v > 0.0), "diffusion coefficients must be positive");
        Self { d, n, r: 1.0 }
    }

    /// `batch` identical instances with a shared diffusion coefficient.
    pub fn uniform(batch: usize, d: f64, n: usize) -> Self {
        Self::new(vec![d; batch], n)
    }

    /// `batch` instances with diffusion coefficients spread
    /// geometrically over a decade (`0.1 … 1.0`) — mixed stiffness in
    /// one batch, the PDE analogue of the mixed-μ Van der Pol sweep.
    pub fn sweep(batch: usize, n: usize) -> Self {
        assert!(batch >= 1);
        let d = (0..batch)
            .map(|i| {
                let f = if batch == 1 { 1.0 } else { i as f64 / (batch - 1) as f64 };
                0.1 * 10f64.powf(f)
            })
            .collect();
        Self::new(d, n)
    }

    /// Override the reaction rate `r` (default `1.0`).
    pub fn with_reaction(mut self, r: f64) -> Self {
        self.r = r;
        self
    }

    /// Diffusion coefficient of instance `inst`.
    pub fn d(&self, inst: usize) -> f64 {
        self.d[inst.min(self.d.len() - 1)]
    }

    /// Reaction rate `r`.
    pub fn r(&self) -> f64 {
        self.r
    }

    /// The grid spacing `dx = 1/(n−1)`.
    pub fn dx(&self) -> f64 {
        1.0 / (self.n - 1) as f64
    }

    /// A travelling-front initial profile shared by every instance:
    /// `u(x) = 1 / (1 + exp((x − 0.3)/0.05))` — the invaded state `u = 1`
    /// on the left relaxing to `u = 0` on the right, which Fisher–KPP
    /// dynamics propagate rightward. One row per instance.
    pub fn front_y0(&self, batch: usize) -> Vec<Vec<f64>> {
        let row: Vec<f64> = (0..self.n)
            .map(|i| {
                let x = i as f64 * self.dx();
                1.0 / (1.0 + ((x - 0.3) / 0.05).exp())
            })
            .collect();
        vec![row; batch]
    }

    /// `c = D/dx²` for instance `inst` — the discrete diffusion scale
    /// (the Jacobian's off-diagonal entries; its spectrum reaches −4c).
    fn c(&self, inst: usize) -> f64 {
        self.d(inst) / (self.dx() * self.dx())
    }
}

impl OdeSystem for ReactionDiffusion {
    fn dim(&self) -> usize {
        self.n
    }

    #[inline]
    fn f_inst(&self, inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
        let n = self.n;
        let c = self.c(inst);
        let r = self.r;
        dy[0] = 2.0 * c * (y[1] - y[0]) + r * y[0] * (1.0 - y[0]);
        for i in 1..n - 1 {
            dy[i] = c * (y[i - 1] - 2.0 * y[i] + y[i + 1]) + r * y[i] * (1.0 - y[i]);
        }
        dy[n - 1] = 2.0 * c * (y[n - 2] - y[n - 1]) + r * y[n - 1] * (1.0 - y[n - 1]);
    }

    fn has_jac(&self) -> bool {
        true
    }

    fn jac_structure(&self) -> JacStructure {
        JacStructure::Banded { lower: 1, upper: 1 }
    }

    /// Dense row-major Jacobian — the oracle for the banded hook and
    /// what a forced-`Dense` solve factors. Writes all `n²` slots.
    fn jac_inst(&self, inst: usize, _t: f64, y: &[f64], jac: &mut [f64]) {
        let n = self.n;
        let c = self.c(inst);
        let r = self.r;
        jac.fill(0.0);
        jac[0] = -2.0 * c + r * (1.0 - 2.0 * y[0]);
        jac[1] = 2.0 * c;
        for i in 1..n - 1 {
            jac[i * n + (i - 1)] = c;
            jac[i * n + i] = -2.0 * c + r * (1.0 - 2.0 * y[i]);
            jac[i * n + (i + 1)] = c;
        }
        jac[(n - 1) * n + (n - 2)] = 2.0 * c;
        jac[(n - 1) * n + (n - 1)] = -2.0 * c + r * (1.0 - 2.0 * y[n - 1]);
    }

    /// Tridiagonal band: column `j` holds `(super, diag, sub)` =
    /// `(∂f_{j−1}, ∂f_j, ∂f_{j+1})/∂y_j`, corners zeroed (see
    /// [`OdeSystem::jac_band_inst`] for the layout).
    fn jac_band_inst(&self, inst: usize, _t: f64, y: &[f64], jac: &mut [f64]) {
        let n = self.n;
        let c = self.c(inst);
        let r = self.r;
        for j in 0..n {
            let col = j * 3;
            // ∂f_{j−1}/∂y_j: 2c into the left boundary row, c elsewhere.
            jac[col] = if j == 0 {
                0.0 // corner (row −1)
            } else if j == 1 {
                2.0 * c
            } else {
                c
            };
            jac[col + 1] = -2.0 * c + r * (1.0 - 2.0 * y[j]);
            // ∂f_{j+1}/∂y_j: 2c into the right boundary row, c elsewhere.
            jac[col + 2] = if j == n - 1 {
                0.0 // corner (row n)
            } else if j == n - 2 {
                2.0 * c
            } else {
                c
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_states_are_reaction_fixed_points() {
        // u ≡ 0 and u ≡ 1 are spatially flat (no diffusion flux) fixed
        // points of the reaction term.
        let sys = ReactionDiffusion::uniform(1, 0.7, 9);
        let mut dy = vec![f64::NAN; 9];
        for u in [0.0, 1.0] {
            sys.f_inst(0, 0.0, &vec![u; 9], &mut dy);
            assert!(dy.iter().all(|&v| v == 0.0), "u ≡ {u}: dy = {dy:?}");
        }
    }

    #[test]
    fn jacobian_matches_finite_differences() {
        let n = 7;
        let sys = ReactionDiffusion::new(vec![0.35], n);
        let y = &sys.front_y0(1)[0];
        let mut jac = vec![0.0; n * n];
        sys.jac_inst(0, 0.0, y, &mut jac);
        let mut fp = vec![0.0; n];
        let mut fm = vec![0.0; n];
        let mut yy = y.clone();
        for j in 0..n {
            let h = 1e-7 * (1.0 + y[j].abs());
            yy[j] = y[j] + h;
            sys.f_inst(0, 0.0, &yy, &mut fp);
            yy[j] = y[j] - h;
            sys.f_inst(0, 0.0, &yy, &mut fm);
            yy[j] = y[j];
            for i in 0..n {
                let fd = (fp[i] - fm[i]) / (2.0 * h);
                let scale = 1.0 + fd.abs();
                assert!(
                    (jac[i * n + j] - fd).abs() < 1e-3 * scale,
                    "J[{i}][{j}] = {} vs fd {fd}",
                    jac[i * n + j]
                );
            }
        }
    }

    #[test]
    fn band_layout_matches_dense_jacobian() {
        let n = 8;
        let sys = ReactionDiffusion::new(vec![1.3, 0.2], n);
        for inst in 0..2 {
            let y = &sys.front_y0(2)[inst];
            let mut dense = vec![0.0; n * n];
            let mut band = vec![f64::NAN; n * 3];
            sys.jac_inst(inst, 0.0, y, &mut dense);
            sys.jac_band_inst(inst, 0.0, y, &mut band);
            for j in 0..n {
                for (slot, i) in [(0usize, j as isize - 1), (1, j as isize), (2, j as isize + 1)]
                {
                    let b = band[j * 3 + slot];
                    if i < 0 || i >= n as isize {
                        assert_eq!(b, 0.0, "corner ({i}, {j}) must be written as 0");
                    } else {
                        let d = dense[i as usize * n + j];
                        assert_eq!(b, d, "band ({i}, {j}) = {b} vs dense {d}");
                    }
                }
            }
            // Everything outside the band really is zero in the dense
            // oracle — the structure declaration is a valid promise.
            for i in 0..n {
                for j in 0..n {
                    if (i as isize - j as isize).abs() > 1 {
                        assert_eq!(dense[i * n + j], 0.0, "({i}, {j}) outside the band");
                    }
                }
            }
        }
    }

    #[test]
    fn sweep_spans_a_decade() {
        let sys = ReactionDiffusion::sweep(5, 16);
        assert!((sys.d(0) - 0.1).abs() < 1e-12);
        assert!((sys.d(4) - 1.0).abs() < 1e-12);
        for i in 1..5 {
            assert!(sys.d(i) > sys.d(i - 1));
        }
    }

    #[test]
    fn front_profile_is_monotone_in_unit_interval() {
        let sys = ReactionDiffusion::uniform(3, 1.0, 64);
        let y0 = sys.front_y0(3);
        assert_eq!(y0.len(), 3);
        for row in &y0 {
            assert_eq!(row.len(), 64);
            assert!(row.windows(2).all(|w| w[1] < w[0]), "front must decay rightward");
            assert!(row.iter().all(|&u| (0.0..=1.0).contains(&u)));
            assert!(row[0] > 0.9 && row[63] < 0.1);
        }
    }
}
