//! FEN stand-in: learned graph dynamics on a mesh.
//!
//! The paper's second benchmark trains a Finite Element Network (Lienen &
//! Günnemann, 2022) on the Black Sea dataset. That dataset is not
//! available here, so per DESIGN.md we substitute a *synthetic
//! advection–diffusion field on a random geometric graph* — the identical
//! code path: a graph neural network is the ODE dynamics, the whole mesh
//! field is one problem instance, training is discretize-then-optimize
//! (backprop through the solver), and the metric is MAE.
//!
//! One instance's state is the flattened `(n_nodes, n_feat)` field, so
//! `dim = n_nodes * n_feat`; a batch of instances is a batch of
//! trajectories of the same mesh.

use super::OdeSystem;
use crate::nn::{GraphAgg, Mlp, MlpCache, Parameterized, Rng64};
use std::cell::RefCell;

/// A random geometric mesh with Gaussian edge weights.
#[derive(Debug, Clone)]
pub struct Mesh {
    pub positions: Vec<[f64; 2]>,
    pub graph: GraphAgg,
}

impl Mesh {
    /// Sample `n` nodes uniformly in the unit square and connect pairs
    /// within `radius`, weighting by exp(−(dist/radius)²).
    pub fn random_geometric(n: usize, radius: f64, rng: &mut Rng64) -> Self {
        let positions: Vec<[f64; 2]> = (0..n).map(|_| [rng.uniform(), rng.uniform()]).collect();
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                let dx = positions[i][0] - positions[j][0];
                let dy = positions[i][1] - positions[j][1];
                let d2 = dx * dx + dy * dy;
                if d2 <= radius * radius {
                    edges.push((i, j, (-d2 / (radius * radius)).exp()));
                }
            }
        }
        // Guarantee connectivity of isolated nodes to their nearest
        // neighbor so the diffusion operator acts everywhere.
        let mut deg = vec![0usize; n];
        for &(i, j, _) in &edges {
            deg[i] += 1;
            deg[j] += 1;
        }
        for i in 0..n {
            if deg[i] == 0 {
                let mut best = usize::MAX;
                let mut bd = f64::INFINITY;
                for j in 0..n {
                    if j != i {
                        let dx = positions[i][0] - positions[j][0];
                        let dy = positions[i][1] - positions[j][1];
                        let d2 = dx * dx + dy * dy;
                        if d2 < bd {
                            bd = d2;
                            best = j;
                        }
                    }
                }
                edges.push((i.min(best), i.max(best), 0.1));
                deg[i] += 1;
                deg[best] += 1;
            }
        }
        let graph = GraphAgg::from_edges(n, &edges);
        Self { positions, graph }
    }

    pub fn n_nodes(&self) -> usize {
        self.positions.len()
    }
}

/// Learned graph dynamics: per node `i`,
/// `dx_i = MLP([x_i, Σ_j w_ij (x_j − x_i)])`, with the MLP shared across
/// nodes and batch instances.
pub struct FenDynamics {
    pub mesh: Mesh,
    pub mlp: Mlp,
    pub n_feat: usize,
    // Reusable scratch (RefCell: `f_inst` takes &self).
    scratch: RefCell<FenScratch>,
}

#[derive(Default)]
struct FenScratch {
    agg: Vec<f64>,
    cache: MlpCache,
    inp: Vec<f64>,
}

impl FenDynamics {
    /// `hidden` sizes the MLP: `[2*n_feat, hidden, n_feat]`.
    pub fn new(mesh: Mesh, n_feat: usize, hidden: usize, rng: &mut Rng64) -> Self {
        let mlp = Mlp::new(&[2 * n_feat, hidden, n_feat], rng);
        Self { mesh, mlp, n_feat, scratch: RefCell::new(FenScratch::default()) }
    }

    pub fn n_nodes(&self) -> usize {
        self.mesh.n_nodes()
    }

    /// The "teacher" dynamics used to generate synthetic training data:
    /// diffusion plus a cubic saturation, `dx = κ·agg(x) − γ·x³`.
    pub fn teacher(mesh: &Mesh, n_feat: usize, kappa: f64, gamma: f64) -> TeacherDynamics {
        TeacherDynamics {
            graph: mesh.graph.clone(),
            n_feat,
            kappa,
            gamma,
            agg: RefCell::new(Vec::new()),
        }
    }
}

impl OdeSystem for FenDynamics {
    fn dim(&self) -> usize {
        self.mesh.n_nodes() * self.n_feat
    }

    fn n_params(&self) -> usize {
        self.mlp.n_params()
    }

    fn f_inst(&self, _inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
        let nf = self.n_feat;
        let mut s = self.scratch.borrow_mut();
        let FenScratch { agg, cache, inp } = &mut *s;
        agg.resize(y.len(), 0.0);
        inp.resize(2 * nf, 0.0);
        self.mesh.graph.aggregate(y, nf, agg);
        for i in 0..self.mesh.n_nodes() {
            inp[..nf].copy_from_slice(&y[i * nf..(i + 1) * nf]);
            inp[nf..].copy_from_slice(&agg[i * nf..(i + 1) * nf]);
            self.mlp.forward_cached(inp, cache, &mut dy[i * nf..(i + 1) * nf]);
        }
    }

    fn vjp_inst(
        &self,
        _inst: usize,
        _t: f64,
        y: &[f64],
        a: &[f64],
        out_y: &mut [f64],
        out_p: &mut [f64],
    ) {
        let nf = self.n_feat;
        let n = self.mesh.n_nodes();
        let mut s = self.scratch.borrow_mut();
        let FenScratch { agg, cache, inp } = &mut *s;
        agg.resize(y.len(), 0.0);
        inp.resize(2 * nf, 0.0);
        self.mesh.graph.aggregate(y, nf, agg);
        out_y.iter_mut().for_each(|v| *v = 0.0);
        // dL/d agg accumulated across nodes, then pushed through agg's VJP.
        let mut dagg = vec![0.0; y.len()];
        let mut out = vec![0.0; nf];
        let mut dinp = vec![0.0; 2 * nf];
        for i in 0..n {
            inp[..nf].copy_from_slice(&y[i * nf..(i + 1) * nf]);
            inp[nf..].copy_from_slice(&agg[i * nf..(i + 1) * nf]);
            self.mlp.forward_cached(inp, cache, &mut out);
            dinp.iter_mut().for_each(|v| *v = 0.0);
            self.mlp.backward(cache, &a[i * nf..(i + 1) * nf], &mut dinp, out_p);
            for f in 0..nf {
                out_y[i * nf + f] += dinp[f];
                dagg[i * nf + f] += dinp[nf + f];
            }
        }
        self.mesh.graph.aggregate_vjp(&dagg, nf, out_y);
    }

    fn has_vjp(&self) -> bool {
        true
    }
}

impl Parameterized for FenDynamics {
    fn n_params(&self) -> usize {
        self.mlp.n_params()
    }

    fn params(&self, out: &mut [f64]) {
        self.mlp.params(out)
    }

    fn set_params(&mut self, p: &[f64]) {
        self.mlp.set_params(p)
    }
}

/// Analytic teacher dynamics for synthetic data generation (see
/// [`FenDynamics::teacher`]).
pub struct TeacherDynamics {
    graph: GraphAgg,
    n_feat: usize,
    kappa: f64,
    gamma: f64,
    agg: RefCell<Vec<f64>>,
}

impl OdeSystem for TeacherDynamics {
    fn dim(&self) -> usize {
        self.graph.n_nodes * self.n_feat
    }

    fn f_inst(&self, _inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
        let mut agg = self.agg.borrow_mut();
        agg.resize(y.len(), 0.0);
        self.graph.aggregate(y, self.n_feat, &mut agg);
        for i in 0..y.len() {
            dy[i] = self.kappa * agg[i] - self.gamma * y[i] * y[i] * y[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::check_vjp_y;

    fn small_fen() -> FenDynamics {
        let mut rng = Rng64::new(11);
        let mesh = Mesh::random_geometric(6, 0.6, &mut rng);
        FenDynamics::new(mesh, 2, 8, &mut rng)
    }

    #[test]
    fn dims() {
        let f = small_fen();
        assert_eq!(f.dim(), 12);
        assert!(crate::problems::OdeSystem::n_params(&f) > 0);
    }

    #[test]
    fn mesh_every_node_connected() {
        let mut rng = Rng64::new(3);
        let mesh = Mesh::random_geometric(20, 0.15, &mut rng);
        // aggregate of a linear-in-position field must be nonzero somewhere
        // and every node must participate in at least one edge (checked by
        // construction in random_geometric).
        assert!(mesh.graph.n_edges_directed() >= 2 * 20 - 2);
    }

    #[test]
    fn dynamics_deterministic() {
        let f = small_fen();
        let y: Vec<f64> = (0..12).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut d1 = vec![0.0; 12];
        let mut d2 = vec![0.0; 12];
        f.f_inst(0, 0.0, &y, &mut d1);
        f.f_inst(0, 0.0, &y, &mut d2);
        assert_eq!(d1, d2);
    }

    #[test]
    fn vjp_matches_fd() {
        let f = small_fen();
        let y: Vec<f64> = (0..12).map(|i| (i as f64 * 0.61).cos() * 0.5).collect();
        let a: Vec<f64> = (0..12).map(|i| ((i * 7 % 5) as f64 - 2.0) * 0.3).collect();
        check_vjp_y(&f, 0, 0.0, &y, &a);
    }

    #[test]
    fn vjp_params_matches_fd() {
        let mut f = small_fen();
        let y: Vec<f64> = (0..12).map(|i| (i as f64 * 0.21).sin()).collect();
        let a: Vec<f64> = (0..12).map(|i| (i as f64 * 0.13).cos()).collect();
        let np = crate::problems::OdeSystem::n_params(&f);
        let mut out_y = vec![0.0; 12];
        let mut out_p = vec![0.0; np];
        f.vjp_inst(0, 0.0, &y, &a, &mut out_y, &mut out_p);
        let mut p = vec![0.0; np];
        f.params(&mut p);
        let h = 1e-6;
        for &j in &[0usize, np / 3, np / 2, np - 1] {
            let orig = p[j];
            p[j] = orig + h;
            f.set_params(&p);
            let mut fp = vec![0.0; 12];
            f.f_inst(0, 0.0, &y, &mut fp);
            p[j] = orig - h;
            f.set_params(&p);
            let mut fm = vec![0.0; 12];
            f.f_inst(0, 0.0, &y, &mut fm);
            p[j] = orig;
            f.set_params(&p);
            let fd: f64 = (0..12).map(|i| a[i] * (fp[i] - fm[i]) / (2.0 * h)).sum();
            assert!((out_p[j] - fd).abs() < 1e-5, "dp[{j}]={} fd={fd}", out_p[j]);
        }
    }

    #[test]
    fn teacher_decays_large_values() {
        let mut rng = Rng64::new(5);
        let mesh = Mesh::random_geometric(5, 0.7, &mut rng);
        let t = FenDynamics::teacher(&mesh, 1, 0.1, 0.5);
        let y = vec![10.0; 5];
        let mut dy = vec![0.0; 5];
        t.f_inst(0, 0.0, &y, &mut dy);
        // Constant field: aggregation is 0, cubic damping dominates.
        for v in dy {
            assert!(v < 0.0);
        }
    }
}
