//! The Robertson chemical kinetics problem — the classic stiff benchmark
//! (Robertson 1966; Hairer & Wanner's first "stiff test problem").
//!
//! Three species with reaction rates spanning nine orders of magnitude:
//!
//! ```text
//! y₁' = −k₁ y₁ + k₃ y₂ y₃
//! y₂' =  k₁ y₁ − k₃ y₂ y₃ − k₂ y₂²
//! y₃' =  k₂ y₂²
//! ```
//!
//! with the classic constants `k₁ = 0.04`, `k₂ = 3·10⁷`, `k₃ = 10⁴` and
//! `y(0) = (1, 0, 0)`. The fast transient pulls `y₂` to ~3.6·10⁻⁵ almost
//! immediately; afterwards the Jacobian has an eigenvalue around `−10⁴`,
//! which caps an explicit solver's stable step at ~10⁻⁴ forever while an
//! L-stable implicit method steps right over it. Mass is conserved
//! (`y₁ + y₂ + y₃ ≡ 1`) — a free accuracy check the stiff regression
//! suite asserts.
//!
//! The analytic Jacobian is provided through the
//! [`OdeSystem::jac_rows`] hook, exercising the implicit solver's
//! analytic path (Van der Pol covers it too; systems without the hook
//! fall back to finite differences).

use super::OdeSystem;

/// Classic rate constant k₁ (slow decay of y₁).
pub const K1: f64 = 0.04;
/// Classic rate constant k₂ (fast y₂² recombination).
pub const K2: f64 = 3.0e7;
/// Classic rate constant k₃ (y₂y₃ back-reaction).
pub const K3: f64 = 1.0e4;

/// A batch of identical Robertson kinetics instances (the classic
/// constants; the stiffness lives in the dynamics, not in per-instance
/// parameters).
#[derive(Debug, Clone)]
pub struct Robertson {
    batch: usize,
}

impl Robertson {
    /// `batch` identical instances.
    pub fn new(batch: usize) -> Self {
        assert!(batch >= 1);
        Self { batch }
    }

    /// Number of instances this system was built for (informational —
    /// the dynamics are instance-independent).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The classic initial condition `(1, 0, 0)`.
    pub fn y0() -> [f64; 3] {
        [1.0, 0.0, 0.0]
    }
}

impl OdeSystem for Robertson {
    fn dim(&self) -> usize {
        3
    }

    #[inline]
    fn f_inst(&self, _inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
        let (y1, y2, y3) = (y[0], y[1], y[2]);
        let r1 = K1 * y1;
        let r2 = K2 * y2 * y2;
        let r3 = K3 * y2 * y3;
        dy[0] = -r1 + r3;
        dy[1] = r1 - r3 - r2;
        dy[2] = r2;
    }

    fn has_jac(&self) -> bool {
        true
    }

    fn jac_inst(&self, _inst: usize, _t: f64, y: &[f64], jac: &mut [f64]) {
        let (y2, y3) = (y[1], y[2]);
        // Row-major ∂f_i/∂y_j.
        jac[0] = -K1;
        jac[1] = K3 * y3;
        jac[2] = K3 * y2;
        jac[3] = K1;
        jac[4] = -K3 * y3 - 2.0 * K2 * y2;
        jac[5] = -K3 * y2;
        jac[6] = 0.0;
        jac[7] = 2.0 * K2 * y2;
        jac[8] = 0.0;
    }

    fn has_vjp(&self) -> bool {
        true
    }

    /// `out_y = Jᵀa` with the analytic Jacobian above; the rate constants
    /// are fixed, so there are no parameter gradients (`n_params = 0`).
    /// Makes Robertson usable as a *stiff* adjoint/training workload
    /// (`tests/adjoint_gradients.rs` differentiates through it with both
    /// the tape and the backsolve modes).
    fn vjp_inst(
        &self,
        _inst: usize,
        _t: f64,
        y: &[f64],
        a: &[f64],
        out_y: &mut [f64],
        _out_p: &mut [f64],
    ) {
        let (y2, y3) = (y[1], y[2]);
        out_y[0] = -K1 * a[0] + K1 * a[1];
        out_y[1] = K3 * y3 * a[0] + (-K3 * y3 - 2.0 * K2 * y2) * a[1] + 2.0 * K2 * y2 * a[2];
        out_y[2] = K3 * y2 * a[0] - K3 * y2 * a[1];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynamics_conserve_mass_pointwise() {
        let sys = Robertson::new(1);
        let mut dy = [0.0; 3];
        for y in [[1.0, 0.0, 0.0], [0.7, 3e-5, 0.3], [0.1, 1e-6, 0.9]] {
            sys.f_inst(0, 0.0, &y, &mut dy);
            let s: f64 = dy.iter().sum();
            assert!(s.abs() < 1e-12, "Σdy = {s} for {y:?}");
        }
    }

    #[test]
    fn vjp_matches_finite_differences() {
        let sys = Robertson::new(1);
        assert!(sys.has_vjp());
        let y = [0.7, 3.0e-5, 0.3 - 3.0e-5];
        for a in [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.3, -0.8, 0.5]] {
            crate::problems::check_vjp_y(&sys, 0, 0.0, &y, &a);
        }
    }

    #[test]
    fn jacobian_matches_finite_differences() {
        let sys = Robertson::new(1);
        let y = [0.7, 3.0e-5, 0.3 - 3.0e-5];
        let mut jac = [0.0; 9];
        sys.jac_inst(0, 0.0, &y, &mut jac);
        let mut fp = [0.0; 3];
        let mut fm = [0.0; 3];
        let mut yy = y;
        for j in 0..3 {
            let h = 1e-7 * (1.0 + y[j].abs());
            yy[j] = y[j] + h;
            sys.f_inst(0, 0.0, &yy, &mut fp);
            yy[j] = y[j] - h;
            sys.f_inst(0, 0.0, &yy, &mut fm);
            yy[j] = y[j];
            for i in 0..3 {
                let fd = (fp[i] - fm[i]) / (2.0 * h);
                let scale = 1.0 + fd.abs();
                assert!(
                    (jac[i * 3 + j] - fd).abs() < 1e-4 * scale,
                    "J[{i}][{j}] = {} vs fd {fd}",
                    jac[i * 3 + j]
                );
            }
        }
    }
}
