//! CNF stand-in: a continuous normalizing flow in the FFJORD style.
//!
//! The paper's third benchmark trains an FFJORD CNF on MNIST. Per
//! DESIGN.md we substitute a synthetic density (the caller picks the data;
//! see `examples/cnf_adjoint.rs`) while keeping the identical code path:
//! the ODE state is `[z, log p]` with
//!
//! ```text
//! dz/dt     = f_θ(z, t)                (an MLP)
//! d logp/dt = −tr(∂f_θ/∂z)             (exact or Hutchinson estimate)
//! ```
//!
//! and training is optimize-then-discretize via the adjoint equation. The
//! VJP of the divergence term with respect to `z` is a second-order
//! quantity; we compute it by central finite differences over the
//! first-order trace (documented, and validated against full finite
//! differences in the tests).

use super::OdeSystem;
use crate::nn::{Mlp, MlpCache, Parameterized, Rng64};
use std::cell::RefCell;

/// How the divergence is computed.
#[derive(Debug, Clone)]
pub enum TraceMode {
    /// Exact trace via `d` input-VJPs per evaluation.
    Exact,
    /// Hutchinson estimator with a fixed Rademacher vector per instance
    /// (fixed noise keeps the ODE deterministic, as in FFJORD training).
    Hutchinson { eps: Vec<Vec<f64>> },
}

/// FFJORD-style CNF dynamics over state `[z (d), logp (1)]`.
pub struct CnfDynamics {
    pub mlp: Mlp,
    pub d: usize,
    pub trace: TraceMode,
    scratch: RefCell<CnfScratch>,
}

#[derive(Default)]
struct CnfScratch {
    cache: MlpCache,
    inp: Vec<f64>,
    grad: Vec<f64>,
    seed: Vec<f64>,
}

impl CnfDynamics {
    /// MLP of shape `[d+1, hidden..., d]` (time enters as an extra input).
    pub fn new(d: usize, hidden: &[usize], rng: &mut Rng64) -> Self {
        let mut sizes = vec![d + 1];
        sizes.extend_from_slice(hidden);
        sizes.push(d);
        Self {
            mlp: Mlp::new(&sizes, rng),
            d,
            trace: TraceMode::Exact,
            scratch: RefCell::new(CnfScratch::default()),
        }
    }

    /// Switch to the Hutchinson estimator with per-instance fixed noise.
    pub fn with_hutchinson(mut self, batch: usize, rng: &mut Rng64) -> Self {
        let eps = (0..batch)
            .map(|_| (0..self.d).map(|_| rng.rademacher()).collect())
            .collect();
        self.trace = TraceMode::Hutchinson { eps };
        self
    }

    /// dz and the divergence at `(z, t)`. Fills `dz` (len d) and returns
    /// the divergence (or its Hutchinson estimate).
    fn dz_and_div(&self, inst: usize, t: f64, z: &[f64], dz: &mut [f64]) -> f64 {
        let mut s = self.scratch.borrow_mut();
        let CnfScratch { cache, inp, grad, seed } = &mut *s;
        inp.resize(self.d + 1, 0.0);
        grad.resize(self.d, 0.0);
        inp[..self.d].copy_from_slice(z);
        inp[self.d] = t;
        self.mlp.forward_cached(inp, cache, dz);
        match &self.trace {
            TraceMode::Exact => {
                // tr J = Σ_i (e_iᵀ J) e_i via d input-VJPs.
                seed.resize(self.d, 0.0);
                let mut tr = 0.0;
                for i in 0..self.d {
                    seed.iter_mut().for_each(|v| *v = 0.0);
                    seed[i] = 1.0;
                    grad.iter_mut().for_each(|v| *v = 0.0);
                    let mut full = vec![0.0; self.d + 1];
                    self.mlp.vjp_input(cache, seed, &mut full);
                    tr += full[i];
                }
                tr
            }
            TraceMode::Hutchinson { eps } => {
                let e = &eps[inst.min(eps.len() - 1)];
                // εᵀ J ε = (Jᵀ ε) · ε via one input-VJP.
                let mut full = vec![0.0; self.d + 1];
                self.mlp.vjp_input(cache, e, &mut full);
                (0..self.d).map(|i| full[i] * e[i]).sum()
            }
        }
    }
}

impl OdeSystem for CnfDynamics {
    fn dim(&self) -> usize {
        self.d + 1
    }

    fn n_params(&self) -> usize {
        self.mlp.n_params()
    }

    fn f_inst(&self, inst: usize, t: f64, y: &[f64], dy: &mut [f64]) {
        let d = self.d;
        let div = {
            let (z, _) = y.split_at(d);
            let (dz, _) = dy.split_at_mut(d);
            self.dz_and_div(inst, t, z, dz)
        };
        dy[d] = -div;
    }

    fn vjp_inst(
        &self,
        inst: usize,
        t: f64,
        y: &[f64],
        a: &[f64],
        out_y: &mut [f64],
        out_p: &mut [f64],
    ) {
        let d = self.d;
        let z = &y[..d];
        // First-order part: a_zᵀ ∂(dz)/∂z and parameter gradients.
        {
            let mut s = self.scratch.borrow_mut();
            let CnfScratch { cache, inp, .. } = &mut *s;
            inp.resize(d + 1, 0.0);
            inp[..d].copy_from_slice(z);
            inp[d] = t;
            let mut dz = vec![0.0; d];
            self.mlp.forward_cached(inp, cache, &mut dz);
            let mut dfull = vec![0.0; d + 1];
            self.mlp.backward(cache, &a[..d], &mut dfull, out_p);
            out_y[..d].copy_from_slice(&dfull[..d]);
            out_y[d] = 0.0; // dynamics do not depend on logp
        }
        // Second-order parts. The divergence is a second-order quantity, so
        // both ∂(−div)/∂z and ∂(−div)/∂θ need Hessian information; we get
        // it by central finite differences over first-order quantities
        // (validated against full FD in the tests):
        //
        //   ∂div/∂z_j  ≈ [div(z+h e_j) − div(z−h e_j)] / 2h
        //   ∂div/∂θ    = Σ_i ∂/∂θ (∂f_i/∂z_i)
        //              ≈ Σ_i [∂θ f_i(z+h e_i) − ∂θ f_i(z−h e_i)] / 2h
        //
        // Cost: 2d divergence evals + 2d parameter-backprops per call —
        // fine for the low-dimensional CNFs of the benchmark.
        let a_logp = a[d];
        if a_logp != 0.0 {
            let h = 1e-5;
            let mut zp = z.to_vec();
            let mut dz_scratch = vec![0.0; d];
            for j in 0..d {
                let orig = zp[j];
                zp[j] = orig + h;
                let div_p = self.dz_and_div(inst, t, &zp, &mut dz_scratch);
                zp[j] = orig - h;
                let div_m = self.dz_and_div(inst, t, &zp, &mut dz_scratch);
                zp[j] = orig;
                out_y[j] += a_logp * (-(div_p - div_m) / (2.0 * h));
            }
            // Parameter gradient of −div.
            let mut s = self.scratch.borrow_mut();
            let CnfScratch { cache, inp, seed, .. } = &mut *s;
            inp.resize(d + 1, 0.0);
            seed.resize(d, 0.0);
            let mut out = vec![0.0; d];
            let mut dx_sink = vec![0.0; d + 1];
            let mut dp_dir = vec![0.0; out_p.len()];
            for i in 0..d {
                for (sign, coeff) in [(h, 1.0), (-h, -1.0)] {
                    inp[..d].copy_from_slice(z);
                    inp[i] += sign;
                    inp[d] = t;
                    self.mlp.forward_cached(inp, cache, &mut out);
                    seed.iter_mut().for_each(|v| *v = 0.0);
                    seed[i] = 1.0;
                    dp_dir.iter_mut().for_each(|v| *v = 0.0);
                    dx_sink.iter_mut().for_each(|v| *v = 0.0);
                    self.mlp.backward(cache, seed, &mut dx_sink, &mut dp_dir);
                    // out_p += a_l · (−1) · coeff/(2h) · ∂θ f_i(z ± h e_i)
                    let w = -a_logp * coeff / (2.0 * h);
                    for (p, g) in out_p.iter_mut().zip(&dp_dir) {
                        *p += w * g;
                    }
                }
            }
        }
    }

    fn has_vjp(&self) -> bool {
        true
    }
}

impl Parameterized for CnfDynamics {
    fn n_params(&self) -> usize {
        self.mlp.n_params()
    }

    fn params(&self, out: &mut [f64]) {
        self.mlp.params(out)
    }

    fn set_params(&mut self, p: &[f64]) {
        self.mlp.set_params(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cnf() -> CnfDynamics {
        let mut rng = Rng64::new(21);
        CnfDynamics::new(2, &[16], &mut rng)
    }

    #[test]
    fn dims() {
        let c = cnf();
        assert_eq!(c.dim(), 3);
    }

    #[test]
    fn exact_trace_matches_fd_jacobian() {
        let c = cnf();
        let z = [0.3, -0.8];
        let mut dz = vec![0.0; 2];
        let tr = c.dz_and_div(0, 0.1, &z, &mut dz);
        // FD trace: Σ_i ∂f_i/∂z_i
        let h = 1e-6;
        let mut fd_tr = 0.0;
        for i in 0..2 {
            let (mut zp, mut zm) = (z, z);
            zp[i] += h;
            zm[i] -= h;
            let (mut fp, mut fm) = (vec![0.0; 2], vec![0.0; 2]);
            c.dz_and_div(0, 0.1, &zp, &mut fp);
            c.dz_and_div(0, 0.1, &zm, &mut fm);
            fd_tr += (fp[i] - fm[i]) / (2.0 * h);
        }
        assert!((tr - fd_tr).abs() < 1e-6, "{tr} vs {fd_tr}");
    }

    #[test]
    fn f_inst_fills_logp_channel() {
        let c = cnf();
        let y = [0.3, -0.8, 0.0];
        let mut dy = [0.0; 3];
        c.f_inst(0, 0.0, &y, &mut dy);
        let mut dz = vec![0.0; 2];
        let tr = c.dz_and_div(0, 0.0, &y[..2], &mut dz);
        assert!((dy[2] + tr).abs() < 1e-14);
        assert_eq!(&dy[..2], dz.as_slice());
    }

    #[test]
    fn vjp_z_part_matches_fd() {
        let c = cnf();
        let y = [0.5, 0.2, -0.1];
        let a = [1.0, -0.5, 0.7];
        let mut out_y = [0.0; 3];
        let mut out_p = vec![0.0; crate::problems::OdeSystem::n_params(&c)];
        c.vjp_inst(0, 0.3, &y, &a, &mut out_y, &mut out_p);
        let h = 1e-5;
        for j in 0..2 {
            let (mut yp, mut ym) = (y, y);
            yp[j] += h;
            ym[j] -= h;
            let (mut fp, mut fm) = ([0.0; 3], [0.0; 3]);
            c.f_inst(0, 0.3, &yp, &mut fp);
            c.f_inst(0, 0.3, &ym, &mut fm);
            let fd: f64 = (0..3).map(|i| a[i] * (fp[i] - fm[i]) / (2.0 * h)).sum();
            assert!((out_y[j] - fd).abs() < 1e-4, "out_y[{j}]={} fd={fd}", out_y[j]);
        }
        // logp column: dynamics independent of logp.
        assert_eq!(out_y[2], 0.0);
    }

    #[test]
    fn hutchinson_is_unbiased_over_vectors() {
        // Average the Hutchinson estimate over many fixed vectors; it must
        // approach the exact trace.
        let mut rng = Rng64::new(33);
        let exact = cnf();
        let z = [0.1, 0.6];
        let mut dz = vec![0.0; 2];
        let tr = exact.dz_and_div(0, 0.0, &z, &mut dz);
        let n = 2000;
        let mut acc = 0.0;
        for s in 0..n {
            let c = cnf().with_hutchinson(1, &mut Rng64::new(1000 + s));
            acc += c.dz_and_div(0, 0.0, &z, &mut dz);
        }
        let _ = &mut rng;
        acc /= n as f64;
        assert!((acc - tr).abs() < 0.05, "{acc} vs {tr}");
    }

    #[test]
    fn vjp_params_include_divergence_term() {
        // Full parameter gradient check with a_logp ≠ 0: FD over params of
        // a·f(y) must match vjp_inst's out_p (incl. the −div channel).
        let mut c = cnf();
        let y = [0.4, -0.3, 0.2];
        let a = [0.8, -0.2, 0.6]; // a_logp = 0.6
        let np = crate::problems::OdeSystem::n_params(&c);
        let mut out_y = [0.0; 3];
        let mut out_p = vec![0.0; np];
        c.vjp_inst(0, 0.25, &y, &a, &mut out_y, &mut out_p);

        let mut p = vec![0.0; np];
        c.params(&mut p);
        let h = 1e-5;
        for &j in &[0usize, np / 4, np / 2, 3 * np / 4, np - 1] {
            let orig = p[j];
            p[j] = orig + h;
            c.set_params(&p);
            let mut fp = [0.0; 3];
            c.f_inst(0, 0.25, &y, &mut fp);
            p[j] = orig - h;
            c.set_params(&p);
            let mut fm = [0.0; 3];
            c.f_inst(0, 0.25, &y, &mut fm);
            p[j] = orig;
            c.set_params(&p);
            let fd: f64 = (0..3).map(|i| a[i] * (fp[i] - fm[i]) / (2.0 * h)).sum();
            assert!(
                (out_p[j] - fd).abs() < 5e-4 * (1.0 + fd.abs()),
                "dp[{j}]={} fd={fd}",
                out_p[j]
            );
        }
    }

    #[test]
    fn param_count_scales() {
        let mut rng = Rng64::new(1);
        let big = CnfDynamics::new(8, &[64, 64], &mut rng);
        assert_eq!(
            crate::problems::OdeSystem::n_params(&big),
            (9 * 64 + 64) + (64 * 64 + 64) + (64 * 8 + 8)
        );
    }
}
