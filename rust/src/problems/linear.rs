//! Linear systems with closed-form solutions — the correctness anchors of
//! the test suite (convergence-order measurements need exact references).

use super::OdeSystem;

/// `dy/dt = -λ y` per component, per instance: `y(t) = y0 · exp(-λ t)`.
#[derive(Debug, Clone)]
pub struct ExponentialDecay {
    lambda: Vec<f64>,
    dim: usize,
}

impl ExponentialDecay {
    pub fn new(lambda: Vec<f64>, dim: usize) -> Self {
        assert!(!lambda.is_empty());
        Self { lambda, dim }
    }

    pub fn lambda(&self, inst: usize) -> f64 {
        self.lambda[inst.min(self.lambda.len() - 1)]
    }

    /// Exact solution at time `t` from `y0` at `t0`.
    pub fn exact(&self, inst: usize, t0: f64, y0: &[f64], t: f64, out: &mut [f64]) {
        let s = (-self.lambda(inst) * (t - t0)).exp();
        for i in 0..y0.len() {
            out[i] = y0[i] * s;
        }
    }
}

impl OdeSystem for ExponentialDecay {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_params(&self) -> usize {
        1
    }

    #[inline]
    fn f_inst(&self, inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
        let l = self.lambda(inst);
        for i in 0..y.len() {
            dy[i] = -l * y[i];
        }
    }

    fn vjp_inst(
        &self,
        inst: usize,
        _t: f64,
        y: &[f64],
        a: &[f64],
        out_y: &mut [f64],
        out_p: &mut [f64],
    ) {
        let l = self.lambda(inst);
        for i in 0..y.len() {
            out_y[i] = -l * a[i];
        }
        out_p[0] = -(0..y.len()).map(|i| a[i] * y[i]).sum::<f64>();
    }

    fn has_vjp(&self) -> bool {
        true
    }
}

/// A dense constant-coefficient linear system `dy/dt = A y` (shared `A`
/// across the batch). Used for stiffness-controlled workloads: the
/// eigenvalues of `A` set the stiffness directly.
#[derive(Debug, Clone)]
pub struct LinearSystem {
    /// Row-major `dim × dim`.
    a: Vec<f64>,
    dim: usize,
}

impl LinearSystem {
    pub fn new(a: Vec<f64>, dim: usize) -> Self {
        assert_eq!(a.len(), dim * dim);
        Self { a, dim }
    }

    /// 2-D rotation + decay: eigenvalues `-decay ± i·omega`. Closed form
    /// solution is a damped rotation — handy for tests.
    pub fn damped_rotation(decay: f64, omega: f64) -> Self {
        Self::new(vec![-decay, -omega, omega, -decay], 2)
    }

    /// Exact solution for [`LinearSystem::damped_rotation`] systems.
    pub fn damped_rotation_exact(decay: f64, omega: f64, y0: &[f64], t: f64, out: &mut [f64]) {
        let s = (-decay * t).exp();
        let (c, sn) = ((omega * t).cos(), (omega * t).sin());
        out[0] = s * (c * y0[0] - sn * y0[1]);
        out[1] = s * (sn * y0[0] + c * y0[1]);
    }
}

impl OdeSystem for LinearSystem {
    fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn f_inst(&self, _inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
        for r in 0..self.dim {
            let mut acc = 0.0;
            let row = &self.a[r * self.dim..(r + 1) * self.dim];
            for c in 0..self.dim {
                acc += row[c] * y[c];
            }
            dy[r] = acc;
        }
    }

    fn vjp_inst(
        &self,
        _inst: usize,
        _t: f64,
        _y: &[f64],
        a: &[f64],
        out_y: &mut [f64],
        _out_p: &mut [f64],
    ) {
        // aᵀ A: column sums weighted by a.
        for c in 0..self.dim {
            let mut acc = 0.0;
            for r in 0..self.dim {
                acc += a[r] * self.a[r * self.dim + c];
            }
            out_y[c] = acc;
        }
    }

    fn has_vjp(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::check_vjp_y;

    #[test]
    fn decay_exact() {
        let sys = ExponentialDecay::new(vec![2.0], 3);
        let y0 = [1.0, -1.0, 0.5];
        let mut out = [0.0; 3];
        sys.exact(0, 0.0, &y0, 1.0, &mut out);
        let e = (-2.0f64).exp();
        for i in 0..3 {
            assert!((out[i] - y0[i] * e).abs() < 1e-15);
        }
    }

    #[test]
    fn decay_dynamics() {
        let sys = ExponentialDecay::new(vec![0.5, 4.0], 2);
        let mut dy = [0.0; 2];
        sys.f_inst(1, 0.0, &[2.0, -2.0], &mut dy);
        assert_eq!(dy, [-8.0, 8.0]);
    }

    #[test]
    fn rotation_matrix_layout() {
        let sys = LinearSystem::damped_rotation(0.0, 1.0);
        let mut dy = [0.0; 2];
        // Pure rotation: d/dt (1, 0) = (0, 1)
        sys.f_inst(0, 0.0, &[1.0, 0.0], &mut dy);
        assert_eq!(dy, [0.0, 1.0]);
    }

    #[test]
    fn rotation_exact_consistent_with_dynamics() {
        // Numerically differentiate the exact solution, compare to f.
        let (decay, omega) = (0.3, 2.0);
        let sys = LinearSystem::damped_rotation(decay, omega);
        let y0 = [1.0, 0.5];
        let h = 1e-6;
        let t = 0.7;
        let (mut ya, mut yb, mut y) = ([0.0; 2], [0.0; 2], [0.0; 2]);
        LinearSystem::damped_rotation_exact(decay, omega, &y0, t - h, &mut ya);
        LinearSystem::damped_rotation_exact(decay, omega, &y0, t + h, &mut yb);
        LinearSystem::damped_rotation_exact(decay, omega, &y0, t, &mut y);
        let mut dy = [0.0; 2];
        sys.f_inst(0, t, &y, &mut dy);
        for i in 0..2 {
            assert!(((yb[i] - ya[i]) / (2.0 * h) - dy[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn vjps_match_fd() {
        check_vjp_y(
            &ExponentialDecay::new(vec![1.7], 3),
            0,
            0.0,
            &[1.0, 2.0, -0.5],
            &[0.3, -1.0, 0.8],
        );
        check_vjp_y(
            &LinearSystem::damped_rotation(0.4, 3.0),
            0,
            0.0,
            &[0.9, -0.2],
            &[1.1, 0.7],
        );
    }
}
