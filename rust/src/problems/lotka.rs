//! Lotka–Volterra predator–prey dynamics — a classic nonstiff nonlinear
//! benchmark with a conserved quantity we can test against.

use super::OdeSystem;

/// `dx/dt = αx − βxy`, `dy/dt = δxy − γy` with per-instance parameters.
#[derive(Debug, Clone)]
pub struct LotkaVolterra {
    /// (α, β, δ, γ) per instance.
    params: Vec<[f64; 4]>,
}

impl LotkaVolterra {
    pub fn new(params: Vec<[f64; 4]>) -> Self {
        assert!(!params.is_empty());
        Self { params }
    }

    pub fn uniform(batch: usize, alpha: f64, beta: f64, delta: f64, gamma: f64) -> Self {
        Self { params: vec![[alpha, beta, delta, gamma]; batch] }
    }

    fn p(&self, inst: usize) -> &[f64; 4] {
        &self.params[inst.min(self.params.len() - 1)]
    }

    /// The conserved quantity `V = δx − γ ln x + βy − α ln y` (constant
    /// along trajectories) — used as an invariant check in tests.
    pub fn invariant(&self, inst: usize, y: &[f64]) -> f64 {
        let [alpha, beta, delta, gamma] = *self.p(inst);
        delta * y[0] - gamma * y[0].ln() + beta * y[1] - alpha * y[1].ln()
    }
}

impl OdeSystem for LotkaVolterra {
    fn dim(&self) -> usize {
        2
    }

    #[inline]
    fn f_inst(&self, inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
        let [alpha, beta, delta, gamma] = *self.p(inst);
        dy[0] = alpha * y[0] - beta * y[0] * y[1];
        dy[1] = delta * y[0] * y[1] - gamma * y[1];
    }

    fn vjp_inst(
        &self,
        inst: usize,
        _t: f64,
        y: &[f64],
        a: &[f64],
        out_y: &mut [f64],
        _out_p: &mut [f64],
    ) {
        let [alpha, beta, delta, gamma] = *self.p(inst);
        out_y[0] = a[0] * (alpha - beta * y[1]) + a[1] * delta * y[1];
        out_y[1] = a[0] * (-beta * y[0]) + a[1] * (delta * y[0] - gamma);
    }

    fn has_vjp(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::check_vjp_y;

    #[test]
    fn fixed_point_is_stationary() {
        // Fixed point at (γ/δ, α/β).
        let sys = LotkaVolterra::uniform(1, 1.1, 0.4, 0.1, 0.4);
        let mut dy = [1.0; 2];
        sys.f_inst(0, 0.0, &[4.0, 2.75], &mut dy);
        assert!(dy[0].abs() < 1e-12 && dy[1].abs() < 1e-12);
    }

    #[test]
    fn invariant_gradient_orthogonal_to_flow() {
        // dV/dt = ∇V · f = 0 along trajectories.
        let sys = LotkaVolterra::uniform(1, 1.1, 0.4, 0.1, 0.4);
        let y = [3.0, 1.5];
        let h = 1e-6;
        let mut dy = [0.0; 2];
        sys.f_inst(0, 0.0, &y, &mut dy);
        let v0 = sys.invariant(0, &[y[0] - h * dy[0], y[1] - h * dy[1]]);
        let v1 = sys.invariant(0, &[y[0] + h * dy[0], y[1] + h * dy[1]]);
        assert!((v1 - v0).abs() / (2.0 * h) < 1e-6);
    }

    #[test]
    fn vjp_matches_fd() {
        check_vjp_y(
            &LotkaVolterra::uniform(1, 1.1, 0.4, 0.1, 0.4),
            0,
            0.0,
            &[2.0, 1.0],
            &[0.7, -0.3],
        );
    }
}
