//! Van der Pol's oscillator — the paper's main workload (Eq. 1):
//! `ẍ = μ(1 − x²)ẋ − x`, written as a first-order system in
//! `y = (x, ẋ)`.
//!
//! The damping μ is a *per-instance* parameter: varying μ across a batch is
//! exactly the stress test of §4.1 (the stiffest oscillator dominates the
//! shared step size of a jointly-batched solver).

use super::OdeSystem;

/// A batch of Van der Pol oscillators with per-instance damping μ.
#[derive(Debug, Clone)]
pub struct VdP {
    mu: Vec<f64>,
}

impl VdP {
    pub fn new(mu: Vec<f64>) -> Self {
        assert!(!mu.is_empty());
        Self { mu }
    }

    /// `batch` identical oscillators with a shared μ.
    pub fn uniform(batch: usize, mu: f64) -> Self {
        Self { mu: vec![mu; batch] }
    }

    pub fn mu(&self, inst: usize) -> f64 {
        self.mu[inst.min(self.mu.len() - 1)]
    }

    /// Approximate period of the limit cycle. For μ ≫ 1 the relaxation
    /// oscillation period grows like (3 − 2 ln 2)·μ; for small μ it
    /// approaches 2π.
    pub fn approx_period(mu: f64) -> f64 {
        if mu < 1.5 {
            2.0 * std::f64::consts::PI * (1.0 + mu * mu / 16.0)
        } else {
            (3.0 - 2.0 * (2.0f64).ln()) * mu + 2.0 * std::f64::consts::PI / mu.sqrt()
        }
    }
}

impl OdeSystem for VdP {
    fn dim(&self) -> usize {
        2
    }

    fn n_params(&self) -> usize {
        1 // μ, for adjoint-gradient tests
    }

    #[inline]
    fn f_inst(&self, inst: usize, _t: f64, y: &[f64], dy: &mut [f64]) {
        let mu = self.mu(inst);
        let (x, v) = (y[0], y[1]);
        dy[0] = v;
        dy[1] = mu * (1.0 - x * x) * v - x;
    }

    fn vjp_inst(
        &self,
        inst: usize,
        _t: f64,
        y: &[f64],
        a: &[f64],
        out_y: &mut [f64],
        out_p: &mut [f64],
    ) {
        let mu = self.mu(inst);
        let (x, v) = (y[0], y[1]);
        // J = [[0, 1], [-2μxv - 1, μ(1 - x²)]]; out_y = aᵀ J.
        out_y[0] = a[1] * (-2.0 * mu * x * v - 1.0);
        out_y[1] = a[0] + a[1] * mu * (1.0 - x * x);
        // ∂f/∂μ = (0, (1 - x²)v)
        out_p[0] = a[1] * (1.0 - x * x) * v;
    }

    fn has_vjp(&self) -> bool {
        true
    }

    fn has_jac(&self) -> bool {
        true
    }

    fn jac_inst(&self, inst: usize, _t: f64, y: &[f64], jac: &mut [f64]) {
        let mu = self.mu(inst);
        let (x, v) = (y[0], y[1]);
        // J = [[0, 1], [-2μxv - 1, μ(1 - x²)]] — the matrix the implicit
        // solver's Newton iteration factors; at large μ its stiff
        // eigenvalue ~ μ(1 - x²) is what breaks explicit methods.
        jac[0] = 0.0;
        jac[1] = 1.0;
        jac[2] = -2.0 * mu * x * v - 1.0;
        jac[3] = mu * (1.0 - x * x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::check_vjp_y;
    use crate::tensor::BatchVec;

    #[test]
    fn dynamics_at_origin_shifted() {
        let sys = VdP::uniform(1, 2.0);
        let mut dy = [0.0; 2];
        sys.f_inst(0, 0.0, &[1.0, 0.0], &mut dy);
        // x=1 => (1-x²)=0 => ẍ = -x = -1
        assert_eq!(dy, [0.0, -1.0]);
    }

    #[test]
    fn per_instance_mu() {
        let sys = VdP::new(vec![0.0, 10.0]);
        let mut dy = [0.0; 2];
        sys.f_inst(0, 0.0, &[0.5, 1.0], &mut dy);
        let undamped = dy[1];
        sys.f_inst(1, 0.0, &[0.5, 1.0], &mut dy);
        assert!((dy[1] - (undamped + 10.0 * 0.75)).abs() < 1e-12);
    }

    #[test]
    fn batch_eval_matches_rows() {
        let sys = VdP::new(vec![1.0, 3.0, 5.0]);
        let y = BatchVec::from_rows(&[vec![1.0, 2.0], vec![-0.5, 0.3], vec![0.0, 1.0]]);
        let mut dy = BatchVec::zeros(3, 2);
        sys.f_batch(&[0.0; 3], &y, &mut dy, None);
        for i in 0..3 {
            let mut expect = [0.0; 2];
            sys.f_inst(i, 0.0, y.row(i), &mut expect);
            assert_eq!(dy.row(i), expect);
        }
    }

    #[test]
    fn active_mask_skips_rows() {
        let sys = VdP::uniform(2, 1.0);
        let y = BatchVec::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        let mut dy = BatchVec::zeros(2, 2);
        sys.f_batch(&[0.0; 2], &y, &mut dy, Some(&[false, true]));
        assert_eq!(dy.row(0), [0.0, 0.0]); // untouched
        assert_ne!(dy.row(1), [0.0, 0.0]);
    }

    #[test]
    fn vjp_matches_finite_differences() {
        let sys = VdP::uniform(1, 2.5);
        check_vjp_y(&sys, 0, 0.0, &[0.7, -1.2], &[1.0, 0.5]);
        check_vjp_y(&sys, 0, 0.0, &[-1.5, 0.4], &[-0.3, 2.0]);
    }

    #[test]
    fn vjp_mu_matches_finite_differences() {
        let y = [0.7, -1.2];
        let a = [0.4, 1.3];
        let h = 1e-6;
        let mut out_y = [0.0; 2];
        let mut out_p = [0.0; 1];
        VdP::uniform(1, 2.5).vjp_inst(0, 0.0, &y, &a, &mut out_y, &mut out_p);
        let mut fp = [0.0; 2];
        let mut fm = [0.0; 2];
        VdP::uniform(1, 2.5 + h).f_inst(0, 0.0, &y, &mut fp);
        VdP::uniform(1, 2.5 - h).f_inst(0, 0.0, &y, &mut fm);
        let fd = a[0] * (fp[0] - fm[0]) / (2.0 * h) + a[1] * (fp[1] - fm[1]) / (2.0 * h);
        assert!((out_p[0] - fd).abs() < 1e-5);
    }

    #[test]
    fn jac_matches_vjp_rows() {
        // aᵀJ from vjp_inst must agree with the explicit Jacobian.
        let sys = VdP::uniform(1, 3.5);
        let y = [0.7, -1.2];
        let mut jac = [0.0; 4];
        sys.jac_inst(0, 0.0, &y, &mut jac);
        for a in [[1.0, 0.0], [0.0, 1.0], [0.3, -2.0]] {
            let mut out_y = [0.0; 2];
            let mut out_p = [0.0; 1];
            sys.vjp_inst(0, 0.0, &y, &a, &mut out_y, &mut out_p);
            for j in 0..2 {
                let want = a[0] * jac[j] + a[1] * jac[2 + j];
                assert!((out_y[j] - want).abs() < 1e-12, "col {j}");
            }
        }
    }

    #[test]
    fn period_limits() {
        assert!((VdP::approx_period(0.0) - 2.0 * std::f64::consts::PI).abs() < 1e-9);
        // Large-μ relaxation oscillation: period ≈ 1.614·μ
        assert!((VdP::approx_period(25.0) / 25.0 - 1.614).abs() < 0.1);
    }
}
