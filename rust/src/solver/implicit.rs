//! The implicit (ESDIRK) stage solver — the stiff-capable counterpart of
//! the explicit attempt in [`super::step`].
//!
//! An ESDIRK tableau ([`super::tableau::TRBDF2`]) has an explicit first
//! stage and implicit later stages: stage `s` must satisfy
//!
//! ```text
//! z_s = y + h·Σ_{j<s} a_sj k_j  +  h·γ·f(t + c_s h, z_s),      γ = diag[s]
//! ```
//!
//! solved here by **simplified Newton iteration per row**: the iteration
//! matrix `M = I − hγJ` uses a Jacobian `J ≈ ∂f/∂y` frozen at the step
//! start (finite differences by default, the analytic
//! [`crate::problems::OdeSystem::jac_rows`] hook when provided), and its
//! LU factors are **reused across stages and across steps** until they go
//! stale (Jacobian older than [`JAC_MAX_AGE`] attempts, `hγ` drifted more
//! than [`LU_HG_DRIFT`], or a Newton failure). The converged stage slope
//! is recovered algebraically (`k_s = (z_s − rhs)/(hγ)`) so convergence
//! costs one dynamics evaluation per Newton iteration and none extra.
//!
//! The factorization is **structure-aware**: a system declaring a banded
//! Jacobian ([`crate::problems::JacStructure::Banded`], e.g. the
//! method-of-lines [`crate::problems::ReactionDiffusion`]) gets banded
//! storage and the banded LU of [`super::linalg`] — O(dim·bandwidth)
//! scratch and O(dim·bandwidth²) factorization instead of O(dim²)/
//! O(dim³) — plus Curtis–Powell–Reid colored finite differences
//! (`kl + ku + 1` evaluations per Jacobian instead of `dim`) when no
//! analytic band hook exists. The banded elimination performs the same
//! nonzero arithmetic as the dense one, so banded and dense solves of
//! the same problem are bitwise-identical; the structure is purely a
//! cost win, and it is what opens implicit stepping at dim 10²–10⁴.
//!
//! **Divergence feeds the rejection path, not a dt death spiral**: when
//! the iteration fails ([`NEWTON_MAX_ITERS`] exhausted, the increment
//! growing faster than [`NEWTON_DIV_RATE`], a singular iteration matrix,
//! or a non-finite increment) under a *reused* Jacobian, the attempt
//! first retries once at the same step size with a Jacobian rebuilt at
//! the current `(t, y)` (the RADAU5/CVODE stale-Jacobian recovery).
//! Only a failure with a fresh Jacobian clears the row's `ok` flag, and
//! the solve loops then treat the attempt as a rejected step with the
//! hard shrink factor [`NEWTON_REJECT_FACTOR`] — the controller's
//! `DtUnderflow` safeguard still applies if Newton keeps failing at the
//! minimum step, and fixed-step solves (no controller to recover with)
//! fail outright with `Status::NewtonDiverged`.
//!
//! The embedded error estimate is **filtered** through the same LU
//! (`ê = (I − hγJ)⁻¹ · h·Σ b_err k`, Hosea & Shampine 1996): the raw
//! difference against the 3rd-order companion overestimates the error in
//! the stiff limit and would reject steps the L-stable solution handles
//! fine.
//!
//! ## Determinism and accounting
//!
//! Everything here is **per-row**: each row's Newton history (Jacobian,
//! LU, ages, counters) lives in slot-indexed scratch inside
//! [`super::step::RkWorkspace`], moves with the row under active-set
//! compaction, and depends on nothing outside the row. That is what
//! keeps implicit solves bitwise-identical across pool kinds, thread
//! counts, steal-chunk sizes and workspace layouts (the implicit attempt
//! is layout-blind — there are no lane passes to transpose for).
//!
//! Work is accounted per row too: Newton residual and finite-difference
//! evaluations accumulate into per-slot counters that the solve loops
//! fold into `Stats::n_f_evals` (so `n_f_evals` is *not* uniform across
//! a batch under an implicit method — each row pays for its own
//! iterations), and Jacobian builds / LU factorizations land in the new
//! `Stats::n_jac_evals` / `Stats::n_lu_factor`. All three are per-row
//! properties, so the pooled merges reproduce them exactly whatever the
//! partition (`crate::exec::merge_sharded` reconstructs the uniform
//! batched-call part from the ledger and carries the per-row Newton part
//! through unchanged).

#![warn(missing_docs)]

use super::active::ActiveSet;
use super::linalg;
use super::step::{
    accumulate_stage_row, combine_rows_fused, CompiledTableau, RkRows, RkWorkspace, MAX_STAGES,
};
use super::Tolerances;
use crate::problems::{JacStructure, OdeSystem};
use crate::tensor::BatchVec;

/// Maximum simplified-Newton iterations per implicit stage before the
/// attempt is declared failed for the row.
pub const NEWTON_MAX_ITERS: usize = 10;

/// Convergence threshold on the tolerance-scaled RMS of the Newton
/// increment: iteration stops once
/// `rms(δ_d / (atol + rtol·|z_d|)) ≤ NEWTON_TOL`, keeping the Newton
/// error well below the local truncation error the controller sees.
pub const NEWTON_TOL: f64 = 0.03;

/// Divergence threshold: an increment growing by more than this factor
/// over the previous iteration aborts the stage solve.
pub const NEWTON_DIV_RATE: f64 = 2.0;

/// Attempts a row's Jacobian may age before a forced refresh.
pub const JAC_MAX_AGE: u32 = 20;

/// Relative drift of `hγ` (against the value the LU was factored with)
/// that forces a refactorization; smaller drifts reuse the LU as a
/// quasi-Newton matrix.
pub const LU_HG_DRIFT: f64 = 0.2;

/// Step-size factor the solve loops apply when Newton diverges — the
/// "reject hard and retry smaller" path, mirroring the controller's
/// non-finite-error shrink.
pub const NEWTON_REJECT_FACTOR: f64 = 0.25;

/// Per-solve Newton state: slot-indexed scratch plus the cross-step
/// Jacobian/LU reuse bookkeeping, allocated once by
/// [`RkWorkspace::new_for_tableau`] — the steady state of an implicit
/// solve performs zero heap allocations (`tests/alloc_regression.rs`).
pub(crate) struct NewtonWs {
    dim: usize,
    /// Resolved Jacobian structure the scratch is sized for (bandwidths
    /// clamped to `dim − 1`); selects dense vs banded storage and LU.
    structure: JacStructure,
    /// Per-slot Jacobian block length: `dim²` dense, `dim·(kl+ku+1)`
    /// banded (column-major band, no pivot headroom).
    jac_block: usize,
    /// Per-slot LU block length: `dim²` dense, `dim·(2kl+ku+1)` banded
    /// (band plus the `kl` pivot-fill headroom rows per column).
    lu_block: usize,
    /// Per-slot Jacobian `J ≈ ∂f/∂y`: row-major `dim × dim` blocks when
    /// dense, [`linalg::banded_index`]-layout band blocks (without the
    /// fill headroom) when banded.
    jac: Vec<f64>,
    /// Per-slot LU factors of `I − hγJ` (dense row-major or banded
    /// storage to match `structure`).
    lu: Vec<f64>,
    /// Per-slot pivot indices of the LU.
    piv: Vec<usize>,
    /// The `hγ` each slot's LU was factored with (`NaN` = invalid).
    lu_hg: Vec<f64>,
    /// Whether each slot's Jacobian is usable.
    jac_valid: Vec<bool>,
    /// Attempts since each slot's Jacobian was built.
    jac_age: Vec<u32>,
    /// Newton outcome of each slot's last attempt.
    ok: Vec<bool>,
    /// Per-attempt accumulators, folded into `Stats` (and reset) by the
    /// solve loops after every attempt.
    fevals: Vec<u64>,
    jacs: Vec<u64>,
    lus: Vec<u64>,
    /// Per-slot stage iterate / dynamics / increment / FD scratch rows.
    z: Vec<f64>,
    fz: Vec<f64>,
    del: Vec<f64>,
    pert: Vec<f64>,
    /// Per-slot tolerances (sliced per shard, moved under compaction).
    atol: Vec<f64>,
    rtol: Vec<f64>,
}

impl NewtonWs {
    /// Fresh Newton state for `batch` slots of dimension `dim`, sized
    /// for the given Jacobian structure: O(dim²) per slot for dense,
    /// O(dim·bandwidth) for banded — the storage side of what makes
    /// implicit steps feasible at PDE dimensions.
    pub(crate) fn new(batch: usize, dim: usize, tols: &Tolerances, jac: JacStructure) -> Self {
        let structure = jac.resolved(dim);
        let (jac_block, lu_block) = match structure {
            JacStructure::Dense => (dim * dim, dim * dim),
            JacStructure::Banded { lower, upper } => {
                (dim * (lower + upper + 1), dim * linalg::banded_width(lower, upper))
            }
        };
        Self {
            dim,
            structure,
            jac_block,
            lu_block,
            jac: vec![0.0; batch * jac_block],
            lu: vec![0.0; batch * lu_block],
            piv: vec![0; batch * dim],
            lu_hg: vec![f64::NAN; batch],
            jac_valid: vec![false; batch],
            jac_age: vec![0; batch],
            ok: vec![true; batch],
            fevals: vec![0; batch],
            jacs: vec![0; batch],
            lus: vec![0; batch],
            z: vec![0.0; batch * dim],
            fz: vec![0.0; batch * dim],
            del: vec![0.0; batch * dim],
            pert: vec![0.0; batch * dim],
            atol: (0..batch).map(|i| tols.atol(i)).collect(),
            rtol: (0..batch).map(|i| tols.rtol(i)).collect(),
        }
    }

    /// Whether slot `r`'s last Newton attempt converged.
    #[inline]
    pub(crate) fn newton_ok(&self, r: usize) -> bool {
        self.ok[r]
    }

    /// Whether any slot's last attempt failed (the joint loop's shared
    /// reject condition).
    pub(crate) fn any_failed(&self) -> bool {
        self.ok.iter().any(|&o| !o)
    }

    /// Drain slot `r`'s per-attempt work counters:
    /// `(f_evals, jac_builds, lu_factorizations)`.
    #[inline]
    pub(crate) fn take_work(&mut self, r: usize) -> (u64, u64, u64) {
        let w = (self.fevals[r], self.jacs[r], self.lus[r]);
        self.fevals[r] = 0;
        self.jacs[r] = 0;
        self.lus[r] = 0;
        w
    }

    /// Move slot `src`'s persistent Newton state to `dst` (active-set
    /// compaction). The per-attempt scratch rows (`z`/`fz`/`del`/`pert`)
    /// are never read before being written within an attempt, so only
    /// the cross-step state moves.
    pub(crate) fn compact_move(&mut self, dst: usize, src: usize) {
        let (jb, lb) = (self.jac_block, self.lu_block);
        self.jac.copy_within(src * jb..(src + 1) * jb, dst * jb);
        self.lu.copy_within(src * lb..(src + 1) * lb, dst * lb);
        self.piv.copy_within(src * self.dim..(src + 1) * self.dim, dst * self.dim);
        self.lu_hg[dst] = self.lu_hg[src];
        self.jac_valid[dst] = self.jac_valid[src];
        self.jac_age[dst] = self.jac_age[src];
        self.ok[dst] = self.ok[src];
        self.fevals[dst] = self.fevals[src];
        self.jacs[dst] = self.jacs[src];
        self.lus[dst] = self.lus[src];
        self.atol[dst] = self.atol[src];
        self.rtol[dst] = self.rtol[src];
    }

    /// The whole-batch mutable view (the serial attempt's shape).
    pub(crate) fn view_mut(&mut self) -> NewtonRows<'_> {
        NewtonRows {
            structure: self.structure,
            jac_block: self.jac_block,
            lu_block: self.lu_block,
            jac: &mut self.jac,
            lu: &mut self.lu,
            piv: &mut self.piv,
            lu_hg: &mut self.lu_hg,
            jac_valid: &mut self.jac_valid,
            jac_age: &mut self.jac_age,
            ok: &mut self.ok,
            fevals: &mut self.fevals,
            jacs: &mut self.jacs,
            lus: &mut self.lus,
            z: &mut self.z,
            fz: &mut self.fz,
            del: &mut self.del,
            pert: &mut self.pert,
            atol: &mut self.atol,
            rtol: &mut self.rtol,
        }
    }

    /// Disjoint per-range views for the sharded joint executors, one per
    /// entry of `bounds` — the Newton analogue of
    /// `crate::exec`'s workspace views.
    pub(crate) fn split_views(&mut self, bounds: &[(usize, usize)]) -> Vec<NewtonRows<'_>> {
        let dim = self.dim;
        let (structure, jb, lb) = (self.structure, self.jac_block, self.lu_block);
        let sz_jac: Vec<usize> = bounds.iter().map(|&(lo, hi)| (hi - lo) * jb).collect();
        let sz_lu: Vec<usize> = bounds.iter().map(|&(lo, hi)| (hi - lo) * lb).collect();
        let sz_d: Vec<usize> = bounds.iter().map(|&(lo, hi)| (hi - lo) * dim).collect();
        let sz_r: Vec<usize> = bounds.iter().map(|&(lo, hi)| hi - lo).collect();
        let mut jac = split_mut(&mut self.jac, &sz_jac).into_iter();
        let mut lu = split_mut(&mut self.lu, &sz_lu).into_iter();
        let mut piv = split_mut(&mut self.piv, &sz_d).into_iter();
        let mut lu_hg = split_mut(&mut self.lu_hg, &sz_r).into_iter();
        let mut jac_valid = split_mut(&mut self.jac_valid, &sz_r).into_iter();
        let mut jac_age = split_mut(&mut self.jac_age, &sz_r).into_iter();
        let mut ok = split_mut(&mut self.ok, &sz_r).into_iter();
        let mut fevals = split_mut(&mut self.fevals, &sz_r).into_iter();
        let mut jacs = split_mut(&mut self.jacs, &sz_r).into_iter();
        let mut lus = split_mut(&mut self.lus, &sz_r).into_iter();
        let mut z = split_mut(&mut self.z, &sz_d).into_iter();
        let mut fz = split_mut(&mut self.fz, &sz_d).into_iter();
        let mut del = split_mut(&mut self.del, &sz_d).into_iter();
        let mut pert = split_mut(&mut self.pert, &sz_d).into_iter();
        let mut atol = split_mut(&mut self.atol, &sz_r).into_iter();
        let mut rtol = split_mut(&mut self.rtol, &sz_r).into_iter();
        bounds
            .iter()
            .map(|_| NewtonRows {
                structure,
                jac_block: jb,
                lu_block: lb,
                jac: jac.next().unwrap(),
                lu: lu.next().unwrap(),
                piv: piv.next().unwrap(),
                lu_hg: lu_hg.next().unwrap(),
                jac_valid: jac_valid.next().unwrap(),
                jac_age: jac_age.next().unwrap(),
                ok: ok.next().unwrap(),
                fevals: fevals.next().unwrap(),
                jacs: jacs.next().unwrap(),
                lus: lus.next().unwrap(),
                z: z.next().unwrap(),
                fz: fz.next().unwrap(),
                del: del.next().unwrap(),
                pert: pert.next().unwrap(),
                atol: atol.next().unwrap(),
                rtol: rtol.next().unwrap(),
            })
            .collect()
    }
}

/// Split a flat buffer into consecutive chunks of the given sizes
/// (local twin of `crate::exec`'s `split_chunks`, kept here so the
/// solver layer does not depend on the exec layer).
fn split_mut<'a, T>(mut s: &'a mut [T], sizes: &[usize]) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let (chunk, rest) = s.split_at_mut(n);
        out.push(chunk);
        s = rest;
    }
    out
}

/// A mutable row-range view of [`NewtonWs`]: the Newton state a worker
/// owns during a sharded implicit attempt. Indexed locally — row `r` of
/// the view is slot `offset + r` of the solve.
pub(crate) struct NewtonRows<'a> {
    structure: JacStructure,
    jac_block: usize,
    lu_block: usize,
    jac: &'a mut [f64],
    lu: &'a mut [f64],
    piv: &'a mut [usize],
    lu_hg: &'a mut [f64],
    jac_valid: &'a mut [bool],
    jac_age: &'a mut [u32],
    ok: &'a mut [bool],
    fevals: &'a mut [u64],
    jacs: &'a mut [u64],
    lus: &'a mut [u64],
    z: &'a mut [f64],
    fz: &'a mut [f64],
    del: &'a mut [f64],
    pert: &'a mut [f64],
    atol: &'a mut [f64],
    rtol: &'a mut [f64],
}

impl NewtonRows<'_> {
    /// The per-row working set of local row `r`.
    fn row(&mut self, r: usize, dim: usize) -> RowNewton<'_> {
        let (jb, lb) = (self.jac_block, self.lu_block);
        RowNewton {
            structure: self.structure,
            jac: &mut self.jac[r * jb..(r + 1) * jb],
            lu: &mut self.lu[r * lb..(r + 1) * lb],
            piv: &mut self.piv[r * dim..(r + 1) * dim],
            lu_hg: &mut self.lu_hg[r],
            jac_valid: &mut self.jac_valid[r],
            jac_age: &mut self.jac_age[r],
            ok: &mut self.ok[r],
            fevals: &mut self.fevals[r],
            jacs: &mut self.jacs[r],
            lus: &mut self.lus[r],
            z: &mut self.z[r * dim..(r + 1) * dim],
            fz: &mut self.fz[r * dim..(r + 1) * dim],
            del: &mut self.del[r * dim..(r + 1) * dim],
            pert: &mut self.pert[r * dim..(r + 1) * dim],
            atol: self.atol[r],
            rtol: self.rtol[r],
        }
    }
}

/// One row's Newton working set: mutable borrows of the slot's blocks of
/// [`NewtonWs`].
struct RowNewton<'a> {
    structure: JacStructure,
    jac: &'a mut [f64],
    lu: &'a mut [f64],
    piv: &'a mut [usize],
    lu_hg: &'a mut f64,
    jac_valid: &'a mut bool,
    jac_age: &'a mut u32,
    ok: &'a mut bool,
    fevals: &'a mut u64,
    jacs: &'a mut u64,
    lus: &'a mut u64,
    z: &'a mut [f64],
    fz: &'a mut [f64],
    del: &'a mut [f64],
    pert: &'a mut [f64],
    atol: f64,
    rtol: f64,
}

/// Mark the row's attempt failed. The LU is always invalidated (the
/// retry arrives with a smaller `dt`, so `hγ` changes); the Jacobian is
/// invalidated only when it was *not* built this very attempt — a fresh
/// one was evaluated at the current `(t, y)` and a rebuild on the retry
/// would reproduce it bit for bit, wasting the FD evaluations.
fn fail_row(st: &mut RowNewton<'_>, jac_fresh: bool) {
    *st.ok = false;
    if !jac_fresh {
        *st.jac_valid = false;
    }
    *st.lu_hg = f64::NAN;
}

/// Build the row's Jacobian at the step start `(t, y)` in the storage
/// the workspace's [`JacStructure`] selects.
///
/// Dense: the analytic [`OdeSystem::jac_rows`] hook when the system
/// provides one, forward differences against the warm step-start slope
/// `f0 = k[0]` otherwise (one dynamics evaluation per column).
///
/// Banded: the analytic [`OdeSystem::jac_band_rows`] hook when the
/// system provides one *and* its declared structure matches the
/// workspace structure (a caller override with different bandwidths
/// falls back to differences — the analytic hook's block layout follows
/// the system's own declaration); otherwise forward differences with
/// **Curtis–Powell–Reid coloring**: columns `j ≡ c (mod kl+ku+1)`
/// touch disjoint row ranges, so one perturbed evaluation recovers a
/// whole color — `kl + ku + 1` evaluations total regardless of `dim`,
/// which is what keeps FD Jacobians affordable at PDE dimensions.
/// Each evaluation is accounted to the row's `fevals`; the build
/// itself increments `jacs`.
fn build_jacobian(
    sys: &dyn OdeSystem,
    g: usize,
    dim: usize,
    t: f64,
    yrow: &[f64],
    f0: &[f64],
    st: &mut RowNewton<'_>,
) {
    match st.structure {
        JacStructure::Dense => {
            if sys.has_jac() {
                sys.jac_rows(g, 1, &[t], yrow, st.jac, None);
            } else {
                dense_fd(sys, g, dim, t, yrow, f0, st);
            }
        }
        JacStructure::Banded { lower: kl, upper: ku } => {
            if sys.has_jac() && sys.jac_structure().resolved(dim) == st.structure {
                sys.jac_band_rows(g, 1, &[t], yrow, st.jac, None);
            } else {
                // Curtis–Powell–Reid colored forward differences.
                let wj = kl + ku + 1;
                let nc = wj.min(dim);
                let fd_eps = f64::EPSILON.sqrt();
                st.pert.copy_from_slice(yrow);
                for c in 0..nc {
                    let mut j = c;
                    while j < dim {
                        let dy = fd_eps * (1.0 + yrow[j].abs());
                        st.pert[j] = yrow[j] + dy;
                        j += nc;
                    }
                    sys.f_rows(g, 1, &[t], st.pert, st.fz, None);
                    *st.fevals += 1;
                    let mut j = c;
                    while j < dim {
                        let dy = fd_eps * (1.0 + yrow[j].abs());
                        let lo = j.saturating_sub(ku);
                        let hi = (j + kl).min(dim - 1);
                        for i in lo..=hi {
                            st.jac[j * wj + (ku + i) - j] = (st.fz[i] - f0[i]) / dy;
                        }
                        st.pert[j] = yrow[j];
                        j += nc;
                    }
                }
            }
        }
    }
    *st.jacs += 1;
    *st.jac_valid = true;
    *st.jac_age = 0;
}

/// Plain per-column forward differences into a dense `dim × dim` block.
fn dense_fd(
    sys: &dyn OdeSystem,
    g: usize,
    dim: usize,
    t: f64,
    yrow: &[f64],
    f0: &[f64],
    st: &mut RowNewton<'_>,
) {
    let fd_eps = f64::EPSILON.sqrt();
    st.pert.copy_from_slice(yrow);
    for j in 0..dim {
        let dy = fd_eps * (1.0 + yrow[j].abs());
        st.pert[j] = yrow[j] + dy;
        sys.f_rows(g, 1, &[t], st.pert, st.fz, None);
        *st.fevals += 1;
        for i in 0..dim {
            st.jac[i * dim + j] = (st.fz[i] - f0[i]) / dy;
        }
        st.pert[j] = yrow[j];
    }
}

/// Back-solve one Newton system `M·x = b` in place through the row's
/// current factors, dispatching on the workspace structure.
#[inline]
fn solve_newton_system(
    structure: JacStructure,
    lu: &[f64],
    piv: &[usize],
    dim: usize,
    x: &mut [f64],
) {
    match structure {
        JacStructure::Dense => linalg::lu_solve(lu, piv, dim, x),
        JacStructure::Banded { lower, upper } => {
            linalg::banded_lu_solve(lu, piv, dim, lower, upper, x)
        }
    }
}

/// Assemble and factor the row's iteration matrix `M = I − hγJ` in the
/// structure-matching storage. Returns `false` on a singular pivot.
fn factor_newton_matrix(st: &mut RowNewton<'_>, dim: usize, hg: f64) -> bool {
    match st.structure {
        JacStructure::Dense => {
            for i in 0..dim {
                for j in 0..dim {
                    st.lu[i * dim + j] = -hg * st.jac[i * dim + j];
                }
                st.lu[i * dim + i] += 1.0;
            }
            linalg::lu_factor(st.lu, st.piv, dim)
        }
        JacStructure::Banded { lower: kl, upper: ku } => {
            // The LU storage carries kl pivot-fill headroom rows the
            // band Jacobian does not; zero everything, then write the
            // band — the same −hγ·J and +1 diagonal arithmetic as the
            // dense assembly, entry for entry.
            for v in st.lu.iter_mut() {
                *v = 0.0;
            }
            let wj = kl + ku + 1;
            let wl = linalg::banded_width(kl, ku);
            for j in 0..dim {
                let lo = j.saturating_sub(ku);
                let hi = (j + kl).min(dim - 1);
                for i in lo..=hi {
                    st.lu[j * wl + (kl + ku + i) - j] = -hg * st.jac[j * wj + (ku + i) - j];
                }
                st.lu[j * wl + kl + ku] += 1.0;
            }
            linalg::banded_lu_factor(st.lu, st.piv, dim, kl, ku)
        }
    }
}

/// Run the stage solves of one attempt for one row (stages 1..S over
/// the current LU). Returns `true` when every stage's Newton iteration
/// converged; on `false` the caller decides between a fresh-Jacobian
/// retry at the same step size and a failed attempt. Rerunning is safe:
/// every stage recomputes its `rhs` and predictor from scratch and
/// `k[0]` is never written.
#[allow(clippy::too_many_arguments)]
fn solve_stages_row(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    g: usize,
    r: usize,
    dim: usize,
    t: f64,
    h: f64,
    yrow: &[f64],
    k: &mut [&mut [f64]],
    rhs: &mut [f64],
    st: &mut RowNewton<'_>,
) -> bool {
    let tab = ct.tab;
    // Stages 1..S: explicit accumulation of the known part, then the
    // per-stage Newton solve (or a plain evaluation for an explicit
    // inner stage, diag[s] = 0 — not present in TR-BDF2 but legal EDIRK
    // structure).
    for s in 1..tab.stages {
        let t_s = t + tab.c[s] * h;
        let (kprev, krest) = k.split_at_mut(s);
        accumulate_stage_row(&ct.a_nz[s], kprev, r, dim, h, yrow, rhs);
        let ks = &mut krest[0][r * dim..(r + 1) * dim];
        let d_s = tab.diag[s];
        if d_s == 0.0 {
            sys.f_rows(g, 1, &[t_s], rhs, ks, None);
            *st.fevals += 1;
            continue;
        }
        let hd = h * d_s;

        // Predictor: the stage equation with the previous stage's slope,
        // z₀ = rhs + hγ·k_{s−1} (k₀ = f(t, y) for the first implicit
        // stage). Deterministic and allocation-free.
        let kp = &kprev[s - 1][r * dim..(r + 1) * dim];
        for d in 0..dim {
            st.z[d] = rhs[d] + hd * kp[d];
        }

        // Simplified Newton: M·δ = −(z − rhs − hγ·f(t_s, z)), z += δ.
        let mut prev_eta = f64::INFINITY;
        let mut converged = false;
        for it in 0..NEWTON_MAX_ITERS {
            sys.f_rows(g, 1, &[t_s], st.z, st.fz, None);
            *st.fevals += 1;
            for d in 0..dim {
                st.del[d] = -(st.z[d] - rhs[d] - hd * st.fz[d]);
            }
            solve_newton_system(st.structure, st.lu, st.piv, dim, st.del);
            for d in 0..dim {
                st.z[d] += st.del[d];
            }
            let mut acc = 0.0;
            for d in 0..dim {
                let scale = (st.atol + st.rtol * st.z[d].abs()).max(f64::MIN_POSITIVE);
                let q = st.del[d] / scale;
                acc += q * q;
            }
            let eta = (acc / dim as f64).sqrt();
            if !eta.is_finite() {
                break;
            }
            if eta <= NEWTON_TOL {
                converged = true;
                break;
            }
            if it > 0 && eta > NEWTON_DIV_RATE * prev_eta {
                break;
            }
            prev_eta = eta;
        }
        if !converged {
            return false;
        }

        // Stage slope from the stage equation — exact algebra on the
        // converged z, no extra dynamics evaluation.
        for d in 0..dim {
            ks[d] = (st.z[d] - rhs[d]) / hd;
        }
    }
    true
}

/// Solve every implicit stage of one row and produce its `y_new`/`err`
/// (the fused combine plus the stiff error filter). A Newton failure
/// under a *reused* Jacobian first retries once at the same step size
/// with a Jacobian rebuilt at the current `(t, y)` — the standard
/// stale-Jacobian recovery (RADAU5/CVODE), which saves the step-size
/// loss of a spurious rejection. Only a failure with a fresh Jacobian
/// clears the row's `ok` flag (outputs left untouched); the solve loops
/// then reject the attempt for this row.
#[allow(clippy::too_many_arguments)]
fn implicit_row(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    g: usize,
    r: usize,
    dim: usize,
    t: f64,
    h: f64,
    yrow: &[f64],
    k: &mut [&mut [f64]],
    rhs: &mut [f64],
    y_new_row: &mut [f64],
    err_row: &mut [f64],
    mut st: RowNewton<'_>,
) {
    *st.ok = true;
    let hg = h * ct.gamma;

    // Jacobian refresh (age- or failure-triggered) up front; the LU of
    // I − hγJ is (re)factored when the Jacobian changed or hγ drifted
    // past the reuse window.
    let mut jac_fresh = false;
    if !*st.jac_valid || *st.jac_age >= JAC_MAX_AGE {
        let f0 = &k[0][r * dim..(r + 1) * dim];
        build_jacobian(sys, g, dim, t, yrow, f0, &mut st);
        jac_fresh = true;
    } else {
        *st.jac_age += 1;
    }
    let drifted = !st.lu_hg.is_finite() || (hg - *st.lu_hg).abs() > LU_HG_DRIFT * st.lu_hg.abs();
    let mut need_factor = jac_fresh || drifted;
    loop {
        if need_factor {
            if !factor_newton_matrix(&mut st, dim, hg) {
                if jac_fresh {
                    fail_row(&mut st, true);
                    return;
                }
                // Singular with a reused Jacobian: rebuild and retry.
                let f0 = &k[0][r * dim..(r + 1) * dim];
                build_jacobian(sys, g, dim, t, yrow, f0, &mut st);
                jac_fresh = true;
                continue;
            }
            *st.lus += 1;
            *st.lu_hg = hg;
            need_factor = false;
        }
        if solve_stages_row(ct, sys, g, r, dim, t, h, yrow, k, rhs, &mut st) {
            break;
        }
        if jac_fresh {
            fail_row(&mut st, true);
            return;
        }
        // Newton failed under a reused Jacobian: rebuild at the current
        // (t, y) and retry the whole attempt once at the same h.
        let f0 = &k[0][r * dim..(r + 1) * dim];
        build_jacobian(sys, g, dim, t, yrow, f0, &mut st);
        jac_fresh = true;
        need_factor = true;
    }

    // Solution + raw embedded error through the shared fused combine
    // (bitwise the same arithmetic the explicit kernels use), then the
    // stiff error filter ê = (I − hγJ)⁻¹·err through the step's LU.
    let has_err = !ct.berr_nz.is_empty();
    combine_rows_fused(ct, k, r, dim, h, yrow, y_new_row, err_row, has_err);
    if has_err {
        solve_newton_system(st.structure, st.lu, st.piv, dim, err_row);
    }
}

/// The implicit attempt over a contiguous row-range view — the shape
/// shared by the serial whole-batch attempt ([`super::step::rk_attempt`])
/// and the pooled joint executors, which drive disjoint views of the
/// same workspace from worker threads. `eval_inactive` has no effect on
/// implicit attempts (there are no batched stage evaluations to overhang
/// onto finished rows); inactive rows are simply skipped.
#[allow(clippy::too_many_arguments)]
pub(crate) fn implicit_attempt_rows(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    t: &[f64],
    dt: &[f64],
    y: &[f64],
    rr: &mut RkRows<'_>,
    k0_ready: &[bool],
    active: Option<&[bool]>,
) {
    let rows = rr.rows;
    let dim = rr.dim;

    // Stage 0 (explicit, c₀ = 0): refresh cold slope caches exactly like
    // the explicit kernel. Warm in the solve loops (initial slopes, the
    // non-FSAL end-slope refresh).
    let mut any_cold = false;
    for (r, &ready) in k0_ready.iter().enumerate() {
        let c = !ready && active.map_or(true, |m| m[r]);
        rr.cold[r] = c;
        any_cold |= c;
    }
    if any_cold {
        rr.t_stage.copy_from_slice(t);
        sys.f_rows(rr.offset, rows, &rr.t_stage[..], y, &mut rr.k[0][..], Some(&rr.cold[..]));
    }

    let offset = rr.offset;
    let nw = rr
        .newton
        .as_mut()
        .expect("implicit attempt needs Newton scratch (RkWorkspace::new_for_tableau)");
    for r in 0..rows {
        if !active.map_or(true, |m| m[r]) {
            continue;
        }
        let yrow = &y[r * dim..(r + 1) * dim];
        let rhs = &mut rr.ytmp[r * dim..(r + 1) * dim];
        let ynr = &mut rr.y_new[r * dim..(r + 1) * dim];
        let er = &mut rr.err[r * dim..(r + 1) * dim];
        let st = nw.row(r, dim);
        implicit_row(ct, sys, offset + r, r, dim, t[r], dt[r], yrow, &mut rr.k, rhs, ynr, er, st);
    }
}

/// The implicit attempt driven by the packed [`ActiveSet`] — the
/// parallel loop's shape. Only live slots do any work (`eval_inactive`
/// is a no-op here, as in [`implicit_attempt_rows`]); the per-row
/// arithmetic is the shared [`implicit_row`], so the two entry points
/// cannot diverge. Returns the semantic batched-call count — the same
/// `stages − 1 (+ cold stage-0)` formula as the explicit attempt, which
/// is what keeps the `CallLedger` partition-invariant; the row-local
/// Newton evaluations are accounted separately through the per-slot
/// counters.
#[allow(clippy::too_many_arguments)]
pub(crate) fn implicit_attempt_active(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    act: &ActiveSet,
    t: &[f64],
    dt: &[f64],
    y: &BatchVec,
    ws: &mut RkWorkspace,
    k0_ready: &[bool],
) -> u64 {
    let tab = ct.tab;
    let dim = y.dim();
    let y_flat = y.flat();
    let live = act.live();
    let inst = act.inst_map();

    // Stage 0 refresh among the live slots (warm in the solve loops).
    let mut any_cold = false;
    for &r in live {
        let c = !k0_ready[r];
        ws.cold[r] = c;
        any_cold |= c;
    }
    let mut calls = tab.stages as u64 - 1;
    if any_cold {
        ws.idx.clear();
        for &r in live {
            if ws.cold[r] {
                ws.idx.push(r);
            }
        }
        for &r in &ws.idx {
            ws.t_stage[r] = t[r];
        }
        sys.f_rows_indexed(0, inst, &ws.idx, &ws.t_stage, y_flat, ws.k[0].flat_mut());
        calls += 1;
    }

    let mut k_it = ws.k.iter_mut();
    let mut k_bufs: [&mut [f64]; MAX_STAGES] =
        std::array::from_fn(|_| k_it.next().map_or_else(Default::default, |k| k.flat_mut()));
    let ytmp = ws.ytmp.flat_mut();
    let y_new = ws.y_new.flat_mut();
    let err = ws.err.flat_mut();
    let mut nw = ws
        .newton
        .as_mut()
        .expect("implicit attempt needs Newton scratch (RkWorkspace::new_for_tableau)")
        .view_mut();
    for &r in live {
        let g = inst[r];
        let yrow = &y_flat[r * dim..(r + 1) * dim];
        let rhs = &mut ytmp[r * dim..(r + 1) * dim];
        let ynr = &mut y_new[r * dim..(r + 1) * dim];
        let er = &mut err[r * dim..(r + 1) * dim];
        let st = nw.row(r, dim);
        implicit_row(ct, sys, g, r, dim, t[r], dt[r], yrow, &mut k_bufs, rhs, ynr, er, st);
    }
    calls
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::ExponentialDecay;
    use crate::solver::step::rk_attempt;
    use crate::solver::MethodId;
    use crate::tensor::Layout;

    fn trbdf2_ws(batch: usize, dim: usize) -> RkWorkspace {
        trbdf2_ws_jac(batch, dim, JacStructure::Dense)
    }

    fn trbdf2_ws_jac(batch: usize, dim: usize, jac: JacStructure) -> RkWorkspace {
        let ct = CompiledTableau::cached(MethodId::TRBDF2);
        RkWorkspace::new_for_tableau(
            ct,
            batch,
            dim,
            Layout::RowMajor,
            &Tolerances::scalar(1e-10, 1e-10),
            jac,
        )
    }

    /// One TR-BDF2 step on y' = −y: the one-step error against exp(−h)
    /// must shrink like h³ (local error of a 2nd-order method), with
    /// Newton converging through the finite-difference Jacobian.
    #[test]
    fn trbdf2_single_step_second_order() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::cached(MethodId::TRBDF2);
        assert!(ct.is_implicit());
        let y = BatchVec::from_rows(&[vec![1.0]]);
        let mut errs = Vec::new();
        for &h in &[0.1, 0.05] {
            let mut ws = trbdf2_ws(1, 1);
            rk_attempt(ct, &sys, &[0.0], &[h], &y, &mut ws, &[false], None, true);
            assert!(ws.newton.as_ref().unwrap().newton_ok(0));
            errs.push((ws.y_new.row(0)[0] - (-h).exp()).abs());
        }
        // Local error order 3: halving h shrinks the one-step error ~8×.
        let ratio = errs[0] / errs[1];
        assert!(ratio > 6.0, "one-step error ratio {ratio} too small for order 2");
        assert!(errs[0] < 1e-4, "one-step error {} too large", errs[0]);
    }

    /// L-stability: one huge step on y' = λy with λ = −10⁶ stays bounded
    /// (|y₁| ≤ |y₀|); an explicit method would explode by ~|hλ|^stages.
    #[test]
    fn trbdf2_l_stable_huge_step() {
        let sys = ExponentialDecay::new(vec![1e6], 1);
        let ct = CompiledTableau::cached(MethodId::TRBDF2);
        let y = BatchVec::from_rows(&[vec![1.0]]);
        let mut ws = trbdf2_ws(1, 1);
        rk_attempt(ct, &sys, &[0.0], &[1.0], &y, &mut ws, &[false], None, true);
        let nw = ws.newton.as_ref().unwrap();
        assert!(nw.newton_ok(0), "Newton must converge on a linear problem");
        let y1 = ws.y_new.row(0)[0];
        assert!(y1.is_finite());
        assert!(y1.abs() <= 1.0, "L-stable step left |y1| = {}", y1.abs());
    }

    /// The per-row counters record real work: a finite-difference
    /// Jacobian build, at least one LU factorization and at least one
    /// Newton iteration per implicit stage.
    #[test]
    fn counters_record_newton_work() {
        let sys = ExponentialDecay::new(vec![2.0], 3);
        let ct = CompiledTableau::cached(MethodId::TRBDF2);
        let y = BatchVec::from_rows(&[vec![1.0, -0.5, 2.0]]);
        let mut ws = trbdf2_ws(1, 3);
        rk_attempt(ct, &sys, &[0.0], &[0.05], &y, &mut ws, &[false], None, true);
        let nw = ws.newton.as_mut().unwrap();
        let (fe, je, lu) = nw.take_work(0);
        assert_eq!(je, 1, "one Jacobian build");
        assert_eq!(lu, 1, "one LU factorization");
        // FD build costs dim evals; two implicit stages cost ≥ 1 each.
        assert!(fe >= 3 + 2, "fevals {fe}");
        // Drained after the fold.
        assert_eq!(nw.take_work(0), (0, 0, 0));
    }

    /// A second attempt at the same (t, y, h) reuses the Jacobian and the
    /// LU — the cross-step reuse path.
    #[test]
    fn jacobian_and_lu_are_reused() {
        let sys = ExponentialDecay::new(vec![1.0], 2);
        let ct = CompiledTableau::cached(MethodId::TRBDF2);
        let y = BatchVec::from_rows(&[vec![1.0, 2.0]]);
        let mut ws = trbdf2_ws(1, 2);
        rk_attempt(ct, &sys, &[0.0], &[0.1], &y, &mut ws, &[false], None, true);
        let (_, je1, lu1) = ws.newton.as_mut().unwrap().take_work(0);
        assert_eq!((je1, lu1), (1, 1));
        rk_attempt(ct, &sys, &[0.0], &[0.1], &y, &mut ws, &[true], None, true);
        let (_, je2, lu2) = ws.newton.as_mut().unwrap().take_work(0);
        assert_eq!((je2, lu2), (0, 0), "same h: Jacobian and LU reused");
        // A big dt change refactors the LU but keeps the Jacobian.
        rk_attempt(ct, &sys, &[0.0], &[0.5], &y, &mut ws, &[true], None, true);
        let (_, je3, lu3) = ws.newton.as_mut().unwrap().take_work(0);
        assert_eq!(je3, 0);
        assert_eq!(lu3, 1, "hγ drift forces a refactorization");
    }

    /// A banded-structure workspace over a diagonal system (decay is
    /// `Banded { 0, 0 }`) must reproduce the dense attempt bit for bit:
    /// the banded elimination performs the same nonzero arithmetic, and
    /// the colored FD build recovers the same diagonal entries.
    #[test]
    fn banded_structure_matches_dense_bitwise() {
        let sys = ExponentialDecay::new(vec![2.0], 4);
        let ct = CompiledTableau::cached(MethodId::TRBDF2);
        let y = BatchVec::from_rows(&[vec![1.0, -0.5, 2.0, 0.25]]);
        let mut ws_d = trbdf2_ws(1, 4);
        let mut ws_b = trbdf2_ws_jac(1, 4, JacStructure::Banded { lower: 0, upper: 0 });
        rk_attempt(ct, &sys, &[0.0], &[0.2], &y, &mut ws_d, &[false], None, true);
        rk_attempt(ct, &sys, &[0.0], &[0.2], &y, &mut ws_b, &[false], None, true);
        assert!(ws_d.newton.as_ref().unwrap().newton_ok(0));
        assert!(ws_b.newton.as_ref().unwrap().newton_ok(0));
        for d in 0..4 {
            assert_eq!(
                ws_d.y_new.row(0)[d].to_bits(),
                ws_b.y_new.row(0)[d].to_bits(),
                "y_new[{d}] differs between dense and banded structure"
            );
            assert_eq!(
                ws_d.err.row(0)[d].to_bits(),
                ws_b.err.row(0)[d].to_bits(),
                "err[{d}] differs between dense and banded structure"
            );
        }
        // The colored FD build costs one evaluation per color (1 here)
        // instead of one per column (4).
        let (fe_b, je_b, lu_b) = ws_b.newton.as_mut().unwrap().take_work(0);
        let (fe_d, je_d, lu_d) = ws_d.newton.as_mut().unwrap().take_work(0);
        assert_eq!((je_b, lu_b), (je_d, lu_d));
        assert_eq!(fe_d - fe_b, 3, "colored FD saves dim − colors evaluations");
    }

    /// Newton work is per-row: a two-row batch where only one row is
    /// active leaves the inactive row's counters and `ok` flag alone.
    #[test]
    fn inactive_rows_do_no_newton_work() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::cached(MethodId::TRBDF2);
        let y = BatchVec::from_rows(&[vec![1.0], vec![1.0]]);
        let mut ws = trbdf2_ws(2, 1);
        ws.y_new.row_mut(0)[0] = 123.0;
        rk_attempt(
            ct,
            &sys,
            &[0.0, 0.0],
            &[0.1, 0.1],
            &y,
            &mut ws,
            &[false, false],
            Some(&[false, true]),
            true,
        );
        assert_eq!(ws.y_new.row(0)[0], 123.0, "inactive row untouched");
        let nw = ws.newton.as_mut().unwrap();
        assert_eq!(nw.take_work(0), (0, 0, 0));
        let (fe, je, lu) = nw.take_work(1);
        assert!(fe > 0 && je == 1 && lu == 1);
    }
}
