//! Small dense linear algebra for the implicit (ESDIRK) solver.
//!
//! The simplified-Newton iteration of [`super::implicit`] solves one
//! `dim × dim` system `(I − hγJ)·δ = −F` per iteration per row. State
//! dimensions in this crate are small (VdP: 2, Robertson: 3, neural
//! dynamics: tens), so a textbook LU factorization with partial pivoting
//! is both the fastest and the most predictable choice: no blocking, no
//! allocation, purely sequential arithmetic — the factorization of a
//! given matrix is **bit-for-bit deterministic** wherever it runs, which
//! is what lets implicit solves stay bitwise-identical across pool
//! kinds, thread counts and layouts.
//!
//! Both entry points work in place on caller-provided scratch (the
//! per-row blocks of [`super::step::RkWorkspace`]'s Newton scratch), so
//! the steady state of an implicit solve performs zero heap allocations
//! (`tests/alloc_regression.rs`).

#![warn(missing_docs)]

/// Factor the row-major `n × n` matrix `a` in place as `P·A = L·U` with
/// partial pivoting: on return the strict lower triangle of `a` holds
/// the multipliers of `L` (unit diagonal implied) and the upper triangle
/// holds `U`. `piv[k]` records the row swapped into position `k` at
/// elimination step `k`. Returns `false` when a pivot column is exactly
/// zero (singular to working precision) — callers treat that as a
/// Newton failure, not a panic, because a transiently singular iteration
/// matrix just means "reject the step and retry smaller".
pub fn lu_factor(a: &mut [f64], piv: &mut [usize], n: usize) -> bool {
    debug_assert_eq!(a.len(), n * n);
    debug_assert!(piv.len() >= n);
    for k in 0..n {
        // Pivot: the largest-magnitude entry in column k at or below the
        // diagonal. Deterministic tie-breaking (first maximum wins).
        let mut p = k;
        let mut best = a[k * n + k].abs();
        for i in (k + 1)..n {
            let v = a[i * n + k].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        piv[k] = p;
        if best == 0.0 {
            return false;
        }
        if p != k {
            for j in 0..n {
                a.swap(k * n + j, p * n + j);
            }
        }
        let pivot = a[k * n + k];
        for i in (k + 1)..n {
            let m = a[i * n + k] / pivot;
            a[i * n + k] = m;
            for j in (k + 1)..n {
                a[i * n + j] -= m * a[k * n + j];
            }
        }
    }
    true
}

/// Solve `A·x = b` in place using the factors produced by
/// [`lu_factor`]: `x` enters holding `b` and leaves holding the
/// solution. Applies the recorded row swaps, then forward- and
/// back-substitution.
pub fn lu_solve(a: &[f64], piv: &[usize], n: usize, x: &mut [f64]) {
    debug_assert_eq!(a.len(), n * n);
    debug_assert!(piv.len() >= n && x.len() >= n);
    for k in 0..n {
        let p = piv[k];
        if p != k {
            x.swap(k, p);
        }
    }
    // Forward: L (unit diagonal) — x[i] -= Σ_{j<i} L[i][j]·x[j].
    for i in 1..n {
        let mut s = x[i];
        for j in 0..i {
            s -= a[i * n + j] * x[j];
        }
        x[i] = s;
    }
    // Backward: U — x[i] = (x[i] − Σ_{j>i} U[i][j]·x[j]) / U[i][i].
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= a[i * n + j] * x[j];
        }
        x[i] = s / a[i * n + i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solve(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
        let mut lu = a.to_vec();
        let mut piv = vec![0usize; n];
        if !lu_factor(&mut lu, &mut piv, n) {
            return None;
        }
        let mut x = b.to_vec();
        lu_solve(&lu, &piv, n, &mut x);
        Some(x)
    }

    #[test]
    fn solves_identity() {
        let x = solve(&[1.0, 0.0, 0.0, 1.0], &[3.0, -4.0], 2).unwrap();
        assert_eq!(x, vec![3.0, -4.0]);
    }

    #[test]
    fn solves_2x2_needing_pivot() {
        // First pivot is 0: partial pivoting must swap rows.
        let a = [0.0, 2.0, 3.0, 1.0];
        let x = solve(&a, &[4.0, 11.0], 2).unwrap();
        // 3x0 + x1 = 11, 2x1 = 4 => x1 = 2, x0 = 3.
        assert!((x[0] - 3.0).abs() < 1e-14);
        assert!((x[1] - 2.0).abs() < 1e-14);
    }

    #[test]
    fn solves_3x3_against_known_solution() {
        let a = [2.0, 1.0, -1.0, -3.0, -1.0, 2.0, -2.0, 1.0, 2.0];
        let x = solve(&a, &[8.0, -11.0, -3.0], 3).unwrap();
        let expect = [2.0, 3.0, -1.0];
        for i in 0..3 {
            assert!((x[i] - expect[i]).abs() < 1e-12, "x[{i}] = {}", x[i]);
        }
    }

    #[test]
    fn residual_small_on_illconditioned_newton_shape() {
        // A Newton matrix I − hγJ with a large stiff entry (the Robertson
        // regime): the residual of the computed solution must be tiny.
        let n = 3;
        let a = [
            1.0 + 0.04, -1e4 * 1e-4, -1e4 * 1e-4, //
            -0.04, 1.0 + 1e4 * 1e-4 + 6e7 * 1e-6, 1e4 * 1e-4, //
            0.0, -6e7 * 1e-6, 1.0,
        ];
        let b = [1.0, -2.0, 0.5];
        let x = solve(&a, &b, n).unwrap();
        for i in 0..n {
            let mut r = -b[i];
            for j in 0..n {
                r += a[i * n + j] * x[j];
            }
            let scale: f64 = a[i * n..(i + 1) * n].iter().map(|v| v.abs()).sum();
            assert!(r.abs() < 1e-10 * (1.0 + scale), "row {i} residual {r}");
        }
    }

    #[test]
    fn reports_singular_instead_of_panicking() {
        assert!(solve(&[1.0, 2.0, 2.0, 4.0], &[1.0, 2.0], 2).is_none());
        assert!(solve(&[0.0], &[1.0], 1).is_none());
    }

    #[test]
    fn factorization_is_deterministic() {
        let a = [3.0, -1.0, 2.0, 1.0, 4.0, 0.5, -2.0, 1.5, 1.0];
        let mut lu1 = a.to_vec();
        let mut lu2 = a.to_vec();
        let (mut p1, mut p2) = (vec![0usize; 3], vec![0usize; 3]);
        assert!(lu_factor(&mut lu1, &mut p1, 3));
        assert!(lu_factor(&mut lu2, &mut p2, 3));
        assert_eq!(p1, p2);
        for (x, y) in lu1.iter().zip(&lu2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
