//! Small dense and banded linear algebra for the implicit (ESDIRK)
//! solver.
//!
//! The simplified-Newton iteration of [`super::implicit`] solves one
//! `dim × dim` system `(I − hγJ)·δ = −F` per iteration per row. For
//! small state dimensions (VdP: 2, Robertson: 3, neural dynamics: tens)
//! a textbook dense LU factorization with partial pivoting is both the
//! fastest and the most predictable choice: no blocking, no allocation,
//! purely sequential arithmetic — the factorization of a given matrix
//! is **bit-for-bit deterministic** wherever it runs, which is what
//! lets implicit solves stay bitwise-identical across pool kinds,
//! thread counts and layouts.
//!
//! Method-of-lines discretizations (the reaction–diffusion problems)
//! push `dim` to 10²–10⁴, where dense O(dim³) factorization is
//! infeasible — but their Jacobians are *banded* (`kl` subdiagonals,
//! `ku` superdiagonals). The banded pair [`banded_lu_factor`] /
//! [`banded_lu_solve`] factors the same iteration matrix in
//! O(dim·(kl+ku)²) time and O(dim·(2kl+ku+1)) storage, in the LAPACK
//! `dgbtf2`/`dgbtrs` layout, with the same determinism contract: the
//! pivot choices and every per-element floating-point operation match
//! the dense elimination exactly, so a full-band banded factorization
//! (`kl = ku = n−1`) solves bit-for-bit like the dense one, and on a
//! genuinely banded matrix the banded and dense paths produce
//! bitwise-identical solutions (`tests/linalg_props.rs`).
//!
//! All entry points work in place on caller-provided scratch (the
//! per-row blocks of [`super::step::RkWorkspace`]'s Newton scratch), so
//! the steady state of an implicit solve performs zero heap allocations
//! (`tests/alloc_regression.rs`).

#![warn(missing_docs)]

/// Factor the row-major `n × n` matrix `a` in place as `P·A = L·U` with
/// partial pivoting: on return the strict lower triangle of `a` holds
/// the multipliers of `L` (unit diagonal implied) and the upper triangle
/// holds `U`. `piv[k]` records the row swapped into position `k` at
/// elimination step `k`. Returns `false` when a pivot column is exactly
/// zero (singular to working precision) — callers treat that as a
/// Newton failure, not a panic, because a transiently singular iteration
/// matrix just means "reject the step and retry smaller".
pub fn lu_factor(a: &mut [f64], piv: &mut [usize], n: usize) -> bool {
    debug_assert_eq!(a.len(), n * n);
    debug_assert!(piv.len() >= n);
    for k in 0..n {
        // Pivot: the largest-magnitude entry in column k at or below the
        // diagonal. Deterministic tie-breaking (first maximum wins).
        let mut p = k;
        let mut best = a[k * n + k].abs();
        for i in (k + 1)..n {
            let v = a[i * n + k].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        piv[k] = p;
        if best == 0.0 {
            return false;
        }
        if p != k {
            for j in 0..n {
                a.swap(k * n + j, p * n + j);
            }
        }
        let pivot = a[k * n + k];
        for i in (k + 1)..n {
            let m = a[i * n + k] / pivot;
            a[i * n + k] = m;
            for j in (k + 1)..n {
                a[i * n + j] -= m * a[k * n + j];
            }
        }
    }
    true
}

/// Solve `A·x = b` in place using the factors produced by
/// [`lu_factor`]: `x` enters holding `b` and leaves holding the
/// solution. Applies the recorded row swaps, then forward- and
/// back-substitution.
pub fn lu_solve(a: &[f64], piv: &[usize], n: usize, x: &mut [f64]) {
    debug_assert_eq!(a.len(), n * n);
    debug_assert!(piv.len() >= n && x.len() >= n);
    for k in 0..n {
        let p = piv[k];
        if p != k {
            x.swap(k, p);
        }
    }
    // Forward: L (unit diagonal) — x[i] -= Σ_{j<i} L[i][j]·x[j].
    for i in 1..n {
        let mut s = x[i];
        for j in 0..i {
            s -= a[i * n + j] * x[j];
        }
        x[i] = s;
    }
    // Backward: U — x[i] = (x[i] − Σ_{j>i} U[i][j]·x[j]) / U[i][i].
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= a[i * n + j] * x[j];
        }
        x[i] = s / a[i * n + i];
    }
}

/// Solve `Aᵀ·x = b` in place using the factors produced by
/// [`lu_factor`] for `A` — no transposed copy, no refactorization.
///
/// With `P·A = L·U` the transposed system is `Uᵀ·Lᵀ·P·x = b`:
/// forward-substitute `Uᵀ` (lower triangular, diagonal `U[i][i]`),
/// back-substitute `Lᵀ` (unit upper triangular), then undo the recorded
/// row swaps in reverse order to peel off `P`. This is what backprop
/// through an implicit Newton stage solves: the implicit-function
/// theorem turns a VJP seed `u` on a stage slope into
/// `w = (I − hγJ)⁻ᵀ·u` against the very matrix the forward Newton
/// factored ([`super::backprop`]). Sequential arithmetic only — the
/// same bitwise-determinism contract as [`lu_solve`].
pub fn lu_solve_transposed(a: &[f64], piv: &[usize], n: usize, x: &mut [f64]) {
    debug_assert_eq!(a.len(), n * n);
    debug_assert!(piv.len() >= n && x.len() >= n);
    // Forward: Uᵀ — x[i] = (x[i] − Σ_{j<i} U[j][i]·x[j]) / U[i][i].
    for i in 0..n {
        let mut s = x[i];
        for j in 0..i {
            s -= a[j * n + i] * x[j];
        }
        x[i] = s / a[i * n + i];
    }
    // Backward: Lᵀ (unit diagonal) — x[i] -= Σ_{j>i} L[j][i]·x[j].
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= a[j * n + i] * x[j];
        }
        x[i] = s;
    }
    // Undo P: the swaps were applied k = 0..n during elimination, so
    // invert them in reverse order.
    for k in (0..n).rev() {
        let p = piv[k];
        if p != k {
            x.swap(k, p);
        }
    }
}

/// Width of one column of banded storage for a matrix with `kl`
/// subdiagonals and `ku` superdiagonals: `kl + ku + 1` band rows plus
/// `kl` extra rows of headroom for the fill that partial pivoting can
/// push into the upper triangle (U gains at most `kl` superdiagonals).
pub const fn banded_width(kl: usize, ku: usize) -> usize {
    2 * kl + ku + 1
}

/// Flat index of entry `A[i, j]` in the column-major banded storage of
/// [`banded_lu_factor`]: column `j` occupies the `banded_width(kl, ku)`
/// slots starting at `j * banded_width(kl, ku)`, with the diagonal at
/// offset `kl + ku` and entry `(i, j)` at offset `kl + ku + i − j`.
/// Representable: `j − i ≤ ku + kl` (band plus pivot fill) and
/// `i − j ≤ kl`.
#[inline]
pub fn banded_index(kl: usize, ku: usize, i: usize, j: usize) -> usize {
    debug_assert!(i + ku + kl >= j && j + kl >= i, "({i}, {j}) outside banded storage");
    j * banded_width(kl, ku) + (kl + ku + i) - j
}

/// Factor the `n × n` banded matrix in `ab` in place as `P·A = L·U`
/// with partial pivoting — the banded analogue of [`lu_factor`], in
/// LAPACK `dgbtf2` storage (see [`banded_index`]; `ab` is
/// `n * banded_width(kl, ku)` long, the `kl` headroom rows per column
/// zero on entry). On return the multiplier rows below each column's
/// diagonal hold `L` (attached to their *original* rows — unlike the
/// dense factorization, later pivot swaps do not relabel earlier
/// multipliers; [`banded_lu_solve`] interleaves the recorded swaps
/// instead, which yields bitwise-identical solutions) and the band
/// above holds `U`, widened by pivot fill to at most `kl + ku`
/// superdiagonals. `piv[k]` records the absolute row swapped into
/// position `k`. Returns `false` on an exactly zero pivot column, like
/// the dense path.
///
/// Determinism contract: the pivot search covers exactly the rows the
/// dense search would find nonzero (everything below `k + kl` in a
/// banded matrix is structurally zero), breaks ties identically (first
/// maximum wins), and the elimination performs, for every element, the
/// same single fused `x −= m·u` update per step `k` that the dense
/// loop performs — so factoring with full bandwidth
/// (`kl = ku = n − 1`) reproduces the dense pivots and solutions
/// bit-for-bit, and on a banded matrix the dense path's extra
/// arithmetic touches only structural zeros.
pub fn banded_lu_factor(ab: &mut [f64], piv: &mut [usize], n: usize, kl: usize, ku: usize) -> bool {
    let w = banded_width(kl, ku);
    debug_assert_eq!(ab.len(), n * w);
    debug_assert!(piv.len() >= n);
    // Rightmost column the elimination has filled so far: row swaps and
    // updates at step k must reach every column where row k or the
    // pivot row have entries (monotone, ≤ k + ku + kl).
    let mut ju = 0usize;
    for k in 0..n {
        let km = kl.min(n - 1 - k);
        let col = k * w + kl + ku; // A[k, k]
        // Pivot: largest magnitude in column k on rows k..=k+km
        // (first maximum wins, matching the dense search).
        let mut p = 0usize;
        let mut best = ab[col].abs();
        for i in 1..=km {
            let v = ab[col + i].abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        piv[k] = k + p;
        if best == 0.0 {
            return false;
        }
        ju = ju.max((k + ku + p).min(n - 1));
        if p != 0 {
            for j in k..=ju {
                let idx = j * w + (kl + ku) - (j - k);
                ab.swap(idx, idx + p);
            }
        }
        // Column scale: store the multipliers m_i = A[k+i, k] / pivot —
        // the same single division the dense loop performs.
        let pivot = ab[col];
        for i in 1..=km {
            ab[col + i] /= pivot;
        }
        // Rank-1 update, column-oriented: each element (k+i, j) receives
        // exactly one `x −= m_i · u_kj`, the identical operation (and
        // identical operands) of the dense row-oriented loop — only the
        // traversal order over *independent* elements differs, which
        // cannot change any element's value. No zero-skip on `u_kj`:
        // the dense loop has none, and skipping would break bitwise
        // parity on inf/NaN multipliers.
        for j in (k + 1)..=ju {
            let ucol = j * w + (kl + ku) - (j - k); // A[k, j]
            let ukj = ab[ucol];
            for i in 1..=km {
                ab[ucol + i] -= ab[col + i] * ukj;
            }
        }
    }
    true
}

/// Solve `A·x = b` in place using the factors produced by
/// [`banded_lu_factor`]: `x` enters holding `b` and leaves holding the
/// solution. Row swaps are interleaved with the forward substitution
/// (the multipliers stay attached to their original rows), which
/// applies, per solution component, the same multiplier·x products in
/// the same order as [`lu_solve`]'s permute-then-substitute — the two
/// conventions are bitwise-equivalent relabelings of each other.
pub fn banded_lu_solve(ab: &[f64], piv: &[usize], n: usize, kl: usize, ku: usize, x: &mut [f64]) {
    let w = banded_width(kl, ku);
    debug_assert_eq!(ab.len(), n * w);
    debug_assert!(piv.len() >= n && x.len() >= n);
    // Forward: interleaved swap + column-oriented unit-L elimination.
    for k in 0..n {
        let p = piv[k];
        if p != k {
            x.swap(k, p);
        }
        let km = kl.min(n - 1 - k);
        let col = k * w + kl + ku;
        let xk = x[k];
        for i in 1..=km {
            x[k + i] -= ab[col + i] * xk;
        }
    }
    // Backward: U with up to ku + kl superdiagonals of pivot fill.
    for i in (0..n).rev() {
        let hi = (i + ku + kl).min(n - 1);
        let mut s = x[i];
        for j in (i + 1)..=hi {
            s -= ab[j * w + (kl + ku) - (j - i)] * x[j];
        }
        x[i] = s / ab[i * w + kl + ku];
    }
}

/// Owning banded-storage matrix in the [`banded_lu_factor`] layout —
/// the assembly/test convenience wrapper around the in-place free
/// functions (the solver's Newton scratch uses the free functions on
/// workspace slices directly and never allocates per step).
#[derive(Clone, Debug)]
pub struct BandedMatrix {
    n: usize,
    kl: usize,
    ku: usize,
    ab: Vec<f64>,
}

impl BandedMatrix {
    /// An `n × n` zero matrix with `kl` sub- and `ku` superdiagonals
    /// (storage includes the `kl` pivot-fill headroom rows).
    pub fn zeros(n: usize, kl: usize, ku: usize) -> Self {
        Self { n, kl, ku, ab: vec![0.0; n * banded_width(kl, ku)] }
    }

    /// Build from a row-major dense `n × n` matrix, keeping only the
    /// entries inside the `(kl, ku)` band.
    pub fn from_dense(a: &[f64], n: usize, kl: usize, ku: usize) -> Self {
        assert_eq!(a.len(), n * n);
        let mut m = Self::zeros(n, kl, ku);
        for i in 0..n {
            let jlo = i.saturating_sub(kl);
            let jhi = (i + ku).min(n.saturating_sub(1));
            for j in jlo..=jhi {
                m.ab[banded_index(kl, ku, i, j)] = a[i * n + j];
            }
        }
        m
    }

    /// Matrix order.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Subdiagonal count.
    pub fn kl(&self) -> usize {
        self.kl
    }

    /// Superdiagonal count.
    pub fn ku(&self) -> usize {
        self.ku
    }

    /// Entry `A[i, j]`; zero outside the band.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        assert!(i < self.n && j < self.n);
        if j + self.kl < i || i + self.ku < j {
            0.0
        } else {
            self.ab[banded_index(self.kl, self.ku, i, j)]
        }
    }

    /// Set entry `A[i, j]`; panics outside the band.
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        assert!(i < self.n && j < self.n);
        assert!(
            j + self.kl >= i && i + self.ku >= j,
            "({i}, {j}) outside the ({}, {}) band",
            self.kl,
            self.ku
        );
        self.ab[banded_index(self.kl, self.ku, i, j)] = v;
    }

    /// The raw banded storage (length `n * banded_width(kl, ku)`).
    pub fn as_slice(&self) -> &[f64] {
        &self.ab
    }

    /// Mutable raw banded storage.
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.ab
    }

    /// Factor in place via [`banded_lu_factor`]; `piv` must hold `n`
    /// slots. Returns `false` on a singular pivot column.
    pub fn factor(&mut self, piv: &mut [usize]) -> bool {
        banded_lu_factor(&mut self.ab, piv, self.n, self.kl, self.ku)
    }

    /// Solve against factors produced by [`Self::factor`] via
    /// [`banded_lu_solve`].
    pub fn solve(&self, piv: &[usize], x: &mut [f64]) {
        banded_lu_solve(&self.ab, piv, self.n, self.kl, self.ku, x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solve(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
        let mut lu = a.to_vec();
        let mut piv = vec![0usize; n];
        if !lu_factor(&mut lu, &mut piv, n) {
            return None;
        }
        let mut x = b.to_vec();
        lu_solve(&lu, &piv, n, &mut x);
        Some(x)
    }

    #[test]
    fn solves_identity() {
        let x = solve(&[1.0, 0.0, 0.0, 1.0], &[3.0, -4.0], 2).unwrap();
        assert_eq!(x, vec![3.0, -4.0]);
    }

    #[test]
    fn solves_2x2_needing_pivot() {
        // First pivot is 0: partial pivoting must swap rows.
        let a = [0.0, 2.0, 3.0, 1.0];
        let x = solve(&a, &[4.0, 11.0], 2).unwrap();
        // 3x0 + x1 = 11, 2x1 = 4 => x1 = 2, x0 = 3.
        assert!((x[0] - 3.0).abs() < 1e-14);
        assert!((x[1] - 2.0).abs() < 1e-14);
    }

    #[test]
    fn solves_3x3_against_known_solution() {
        let a = [2.0, 1.0, -1.0, -3.0, -1.0, 2.0, -2.0, 1.0, 2.0];
        let x = solve(&a, &[8.0, -11.0, -3.0], 3).unwrap();
        let expect = [2.0, 3.0, -1.0];
        for i in 0..3 {
            assert!((x[i] - expect[i]).abs() < 1e-12, "x[{i}] = {}", x[i]);
        }
    }

    #[test]
    fn residual_small_on_illconditioned_newton_shape() {
        // A Newton matrix I − hγJ with a large stiff entry (the Robertson
        // regime): the residual of the computed solution must be tiny.
        let n = 3;
        let a = [
            1.0 + 0.04, -1e4 * 1e-4, -1e4 * 1e-4, //
            -0.04, 1.0 + 1e4 * 1e-4 + 6e7 * 1e-6, 1e4 * 1e-4, //
            0.0, -6e7 * 1e-6, 1.0,
        ];
        let b = [1.0, -2.0, 0.5];
        let x = solve(&a, &b, n).unwrap();
        for i in 0..n {
            let mut r = -b[i];
            for j in 0..n {
                r += a[i * n + j] * x[j];
            }
            let scale: f64 = a[i * n..(i + 1) * n].iter().map(|v| v.abs()).sum();
            assert!(r.abs() < 1e-10 * (1.0 + scale), "row {i} residual {r}");
        }
    }

    #[test]
    fn reports_singular_instead_of_panicking() {
        assert!(solve(&[1.0, 2.0, 2.0, 4.0], &[1.0, 2.0], 2).is_none());
        assert!(solve(&[0.0], &[1.0], 1).is_none());
    }

    #[test]
    fn factorization_is_deterministic() {
        let a = [3.0, -1.0, 2.0, 1.0, 4.0, 0.5, -2.0, 1.5, 1.0];
        let mut lu1 = a.to_vec();
        let mut lu2 = a.to_vec();
        let (mut p1, mut p2) = (vec![0usize; 3], vec![0usize; 3]);
        assert!(lu_factor(&mut lu1, &mut p1, 3));
        assert!(lu_factor(&mut lu2, &mut p2, 3));
        assert_eq!(p1, p2);
        for (x, y) in lu1.iter().zip(&lu2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// Banded solve of a dense matrix restricted to its band, compared
    /// against the dense oracle run on the same band-restricted matrix.
    fn banded_vs_dense(a_banded: &[f64], b: &[f64], n: usize, kl: usize, ku: usize) {
        let dense = solve(a_banded, b, n);
        let mut m = BandedMatrix::from_dense(a_banded, n, kl, ku);
        let mut piv = vec![0usize; n];
        let ok = m.factor(&mut piv);
        assert_eq!(ok, dense.is_some(), "banded and dense must agree on singularity");
        let Some(xd) = dense else { return };
        let mut xb = b.to_vec();
        m.solve(&piv, &mut xb);
        for i in 0..n {
            assert!(
                (xb[i] - xd[i]).abs() <= 1e-12 * (1.0 + xd[i].abs()),
                "x[{i}]: banded {} vs dense {}",
                xb[i],
                xd[i]
            );
        }
    }

    #[test]
    fn banded_tridiagonal_matches_dense() {
        // A stiff-looking tridiagonal (the 1-D Laplacian Newton shape).
        let n = 6;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0 + 2.0 * 0.3;
            if i > 0 {
                a[i * n + i - 1] = -0.3;
            }
            if i + 1 < n {
                a[i * n + i + 1] = -0.31;
            }
        }
        let b: Vec<f64> = (0..n).map(|i| (i as f64) - 2.5).collect();
        banded_vs_dense(&a, &b, n, 1, 1);
    }

    #[test]
    fn banded_needs_pivoting() {
        // Zero on the diagonal forces a row swap and pivot fill.
        let n = 4;
        #[rustfmt::skip]
        let a = vec![
            0.0, 2.0, 0.0, 0.0,
            3.0, 1.0, 1.0, 0.0,
            0.0, 4.0, 0.5, 2.0,
            0.0, 0.0, 1.0, 1.0,
        ];
        banded_vs_dense(&a, &[1.0, -2.0, 0.5, 3.0], n, 1, 1);
    }

    #[test]
    fn full_band_is_bitwise_dense() {
        // kl = ku = n−1: every slot representable, the elimination must
        // reproduce the dense pivots and solution bit-for-bit.
        let n = 4;
        #[rustfmt::skip]
        let a = vec![
            0.5, -1.0, 2.0, 0.25,
            3.0, 1.0, -0.5, 1.5,
            -2.0, 4.0, 0.5, 2.0,
            1.0, -3.0, 1.0, 1.0,
        ];
        let b = [1.0, -2.0, 0.5, 3.0];
        let mut lu = a.clone();
        let mut pd = vec![0usize; n];
        assert!(lu_factor(&mut lu, &mut pd, n));
        let mut xd = b.to_vec();
        lu_solve(&lu, &pd, n, &mut xd);

        let mut m = BandedMatrix::from_dense(&a, n, n - 1, n - 1);
        let mut pb = vec![0usize; n];
        assert!(m.factor(&mut pb));
        let mut xb = b.to_vec();
        m.solve(&pb, &mut xb);
        assert_eq!(pd, pb, "pivot sequences must match");
        for i in 0..n {
            assert_eq!(xd[i].to_bits(), xb[i].to_bits(), "x[{i}] differs from dense");
        }
    }

    #[test]
    fn diagonal_only_band() {
        let n = 5;
        let mut m = BandedMatrix::zeros(n, 0, 0);
        for i in 0..n {
            m.set(i, i, (i + 1) as f64);
        }
        let mut piv = vec![0usize; n];
        assert!(m.factor(&mut piv));
        let mut x: Vec<f64> = (0..n).map(|i| (i + 1) as f64 * 3.0).collect();
        m.solve(&piv, &mut x);
        for (i, v) in x.iter().enumerate() {
            assert_eq!(*v, 3.0, "x[{i}]");
            assert_eq!(piv[i], i);
        }
    }

    #[test]
    fn banded_reports_singular() {
        let mut m = BandedMatrix::zeros(3, 1, 1);
        // Column 1 entirely zero within reach of elimination.
        m.set(0, 0, 1.0);
        m.set(2, 2, 1.0);
        let mut piv = vec![0usize; 3];
        assert!(!m.factor(&mut piv));
    }

    #[test]
    fn banded_matrix_get_set_roundtrip() {
        let mut m = BandedMatrix::zeros(5, 2, 1);
        m.set(3, 1, 7.5); // subdiagonal 2
        m.set(2, 3, -1.5); // superdiagonal 1
        m.set(4, 4, 2.0);
        assert_eq!(m.get(3, 1), 7.5);
        assert_eq!(m.get(2, 3), -1.5);
        assert_eq!(m.get(4, 4), 2.0);
        assert_eq!(m.get(0, 4), 0.0); // outside the band
        assert_eq!(m.get(4, 0), 0.0);
    }

    #[test]
    fn transposed_solve_matches_explicit_transpose() {
        // Aᵀx = b through the factors of A must agree with solving the
        // explicitly transposed matrix through its own factorization.
        let mut rng = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            rng ^= rng << 13;
            rng ^= rng >> 7;
            rng ^= rng << 17;
            (rng >> 11) as f64 / (1u64 << 53) as f64 - 0.5
        };
        for n in [1usize, 2, 3, 5, 8, 13] {
            let a: Vec<f64> = (0..n * n).map(|_| next() * 4.0).collect();
            let b: Vec<f64> = (0..n).map(|_| next() * 2.0).collect();

            let mut lu = a.clone();
            let mut piv = vec![0usize; n];
            if !lu_factor(&mut lu, &mut piv, n) {
                continue; // singular draw — skip, the next size re-rolls
            }
            let mut x = b.clone();
            lu_solve_transposed(&lu, &piv, n, &mut x);

            let mut at = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    at[j * n + i] = a[i * n + j];
                }
            }
            let mut lut = at;
            let mut pivt = vec![0usize; n];
            assert!(lu_factor(&mut lut, &mut pivt, n));
            let mut xt = b.clone();
            lu_solve(&lut, &pivt, n, &mut xt);

            // Residual check against the original system, both ways
            // (relative: a badly conditioned draw inflates |x|).
            let scale = 1.0 + x.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            for i in 0..n {
                let mut r = -b[i];
                for j in 0..n {
                    r += a[j * n + i] * x[j]; // (Aᵀ x)_i
                }
                assert!(r.abs() < 1e-8 * scale, "n={n} residual[{i}] = {r}");
                assert!(
                    (x[i] - xt[i]).abs() < 1e-8 * scale,
                    "n={n} x[{i}]: {} vs {}",
                    x[i],
                    xt[i]
                );
            }
        }
    }

    #[test]
    fn transposed_solve_identity_and_permutation() {
        // A pure permutation matrix exercises only the pivot bookkeeping.
        let n = 3;
        let a = [0.0, 1.0, 0.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0];
        let mut lu = a.to_vec();
        let mut piv = vec![0usize; n];
        assert!(lu_factor(&mut lu, &mut piv, n));
        let mut x = vec![1.0, 2.0, 3.0];
        lu_solve_transposed(&lu, &piv, n, &mut x);
        // Aᵀ x = b with A mapping e1→e3, e2→e1, e3→e2: x = A b.
        assert_eq!(x, vec![2.0, 3.0, 1.0]);
    }
}
