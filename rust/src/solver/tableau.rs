//! Butcher tableaus for explicit Runge–Kutta methods.
//!
//! Coefficients are stored as static data. `a` is the strictly
//! lower-triangular stage matrix flattened row by row (row `i` has `i`
//! entries), `b` the solution weights, `b_err` the *error* weights
//! (`b - b̂`, so the embedded error estimate is `dt * Σ b_err[i] * k[i]`),
//! and `c` the nodes.
//!
//! The same coefficients are emitted by `python/compile/tableaus.py`; the
//! golden test `tests/tableau_cross_check.rs` keeps the two in sync.

/// An explicit Runge–Kutta tableau with an optional embedded error estimate.
#[derive(Debug, Clone, Copy)]
pub struct Tableau {
    pub name: &'static str,
    /// Number of stages (incl. the FSAL stage if present).
    pub stages: usize,
    /// Order of the solution polynomial.
    pub order: usize,
    /// Order of the embedded (error-estimating) method; 0 = fixed step only.
    pub err_order: usize,
    /// Strictly lower-triangular stage matrix, flattened: row i has i entries.
    pub a: &'static [f64],
    /// Diagonal stage coefficients `a_ss` for diagonally-implicit (ESDIRK)
    /// tableaus, one entry per stage; **empty for explicit methods**. A
    /// nonzero `diag[s]` makes stage `s` implicit: its stage equation is
    /// `z_s = y + h·Σ_{j<s} a_sj k_j + h·diag[s]·f(t + c_s h, z_s)`,
    /// solved by simplified Newton iteration ([`crate::solver::implicit`]).
    /// All nonzero entries must be equal (single-γ SDIRK structure), so a
    /// step needs one LU factorization of `I − hγJ`, reused across stages.
    pub diag: &'static [f64],
    /// Solution weights (len = stages).
    pub b: &'static [f64],
    /// Error weights `b - b̂` (len = stages, empty if no embedded method).
    pub b_err: &'static [f64],
    /// Nodes (len = stages).
    pub c: &'static [f64],
    /// First-same-as-last: k[last] of an accepted step equals k[0] of the next.
    pub fsal: bool,
    /// Has dedicated dense-output coefficients (otherwise cubic Hermite).
    pub dense: DenseOutput,
}

/// Which dense-output interpolant a tableau provides.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenseOutput {
    /// 3rd-order cubic Hermite from (y0, f0, y1, f1) — always available.
    Hermite,
    /// Dopri5's dedicated 4th-order interpolant (Hairer's `rcont` scheme).
    Dopri5,
}

impl Tableau {
    /// `a[i][j]` for stage `i`, column `j < i`.
    #[inline]
    pub fn a(&self, i: usize, j: usize) -> f64 {
        debug_assert!(j < i);
        self.a[i * (i - 1) / 2 + j]
    }

    /// Row `i` of the stage matrix (the `i` coefficients feeding stage `i`).
    #[inline]
    pub fn a_row(&self, i: usize) -> &'static [f64] {
        let lo = i * (i - 1) / 2;
        &self.a[lo..lo + i]
    }

    /// Whether the tableau carries an embedded error estimate.
    #[inline]
    pub fn adaptive(&self) -> bool {
        !self.b_err.is_empty()
    }
}

// --- Euler (1st order, fixed step) -----------------------------------------
pub static EULER: Tableau = Tableau {
    name: "euler",
    stages: 1,
    order: 1,
    err_order: 0,
    a: &[],
    b: &[1.0],
    b_err: &[],
    c: &[0.0],
    diag: &[],
    fsal: false,
    dense: DenseOutput::Hermite,
};

// --- Explicit midpoint (2nd order, fixed step) ------------------------------
pub static MIDPOINT: Tableau = Tableau {
    name: "midpoint",
    stages: 2,
    order: 2,
    err_order: 0,
    a: &[0.5],
    b: &[0.0, 1.0],
    b_err: &[],
    c: &[0.0, 0.5],
    diag: &[],
    fsal: false,
    dense: DenseOutput::Hermite,
};

// --- Heun 2(1) (trapezoid with embedded Euler) ------------------------------
pub static HEUN21: Tableau = Tableau {
    name: "heun",
    stages: 2,
    order: 2,
    err_order: 1,
    a: &[1.0],
    b: &[0.5, 0.5],
    // b̂ = Euler = [1, 0]  =>  b_err = [-0.5, 0.5]
    b_err: &[-0.5, 0.5],
    c: &[0.0, 1.0],
    diag: &[],
    fsal: false,
    dense: DenseOutput::Hermite,
};

// --- Ralston 2nd order (minimal truncation error) ---------------------------
pub static RALSTON2: Tableau = Tableau {
    name: "ralston",
    stages: 2,
    order: 2,
    err_order: 0,
    a: &[2.0 / 3.0],
    b: &[0.25, 0.75],
    b_err: &[],
    c: &[0.0, 2.0 / 3.0],
    diag: &[],
    fsal: false,
    dense: DenseOutput::Hermite,
};

// --- Bogacki–Shampine 3(2), FSAL --------------------------------------------
pub static BOSH3: Tableau = Tableau {
    name: "bosh3",
    stages: 4,
    order: 3,
    err_order: 2,
    a: &[
        0.5, //
        0.0,
        0.75, //
        2.0 / 9.0,
        1.0 / 3.0,
        4.0 / 9.0,
    ],
    b: &[2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0],
    // b̂ = [7/24, 1/4, 1/3, 1/8]
    b_err: &[
        2.0 / 9.0 - 7.0 / 24.0,
        1.0 / 3.0 - 0.25,
        4.0 / 9.0 - 1.0 / 3.0,
        -0.125,
    ],
    c: &[0.0, 0.5, 0.75, 1.0],
    diag: &[],
    fsal: true,
    dense: DenseOutput::Hermite,
};

// --- Classic RK4 (fixed step) ------------------------------------------------
pub static RK4: Tableau = Tableau {
    name: "rk4",
    stages: 4,
    order: 4,
    err_order: 0,
    a: &[
        0.5, //
        0.0, 0.5, //
        0.0, 0.0, 1.0,
    ],
    b: &[1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0],
    b_err: &[],
    c: &[0.0, 0.5, 0.5, 1.0],
    diag: &[],
    fsal: false,
    dense: DenseOutput::Hermite,
};

// --- Fehlberg 4(5) ------------------------------------------------------------
pub static FEHLBERG45: Tableau = Tableau {
    name: "fehlberg45",
    stages: 6,
    order: 5,
    err_order: 4,
    a: &[
        0.25, //
        3.0 / 32.0,
        9.0 / 32.0, //
        1932.0 / 2197.0,
        -7200.0 / 2197.0,
        7296.0 / 2197.0, //
        439.0 / 216.0,
        -8.0,
        3680.0 / 513.0,
        -845.0 / 4104.0, //
        -8.0 / 27.0,
        2.0,
        -3544.0 / 2565.0,
        1859.0 / 4104.0,
        -11.0 / 40.0,
    ],
    // 5th-order weights
    b: &[
        16.0 / 135.0,
        0.0,
        6656.0 / 12825.0,
        28561.0 / 56430.0,
        -9.0 / 50.0,
        2.0 / 55.0,
    ],
    // b - b̂ with b̂ the 4th-order weights [25/216, 0, 1408/2565, 2197/4104, -1/5, 0]
    b_err: &[
        16.0 / 135.0 - 25.0 / 216.0,
        0.0,
        6656.0 / 12825.0 - 1408.0 / 2565.0,
        28561.0 / 56430.0 - 2197.0 / 4104.0,
        -9.0 / 50.0 + 0.2,
        2.0 / 55.0,
    ],
    c: &[0.0, 0.25, 3.0 / 8.0, 12.0 / 13.0, 1.0, 0.5],
    diag: &[],
    fsal: false,
    dense: DenseOutput::Hermite,
};

// --- Cash–Karp 4(5) -----------------------------------------------------------
pub static CASHKARP45: Tableau = Tableau {
    name: "cashkarp45",
    stages: 6,
    order: 5,
    err_order: 4,
    a: &[
        0.2, //
        3.0 / 40.0,
        9.0 / 40.0, //
        0.3,
        -0.9,
        1.2, //
        -11.0 / 54.0,
        2.5,
        -70.0 / 27.0,
        35.0 / 27.0, //
        1631.0 / 55296.0,
        175.0 / 512.0,
        575.0 / 13824.0,
        44275.0 / 110592.0,
        253.0 / 4096.0,
    ],
    b: &[
        37.0 / 378.0,
        0.0,
        250.0 / 621.0,
        125.0 / 594.0,
        0.0,
        512.0 / 1771.0,
    ],
    // b̂ = [2825/27648, 0, 18575/48384, 13525/55296, 277/14336, 1/4]
    b_err: &[
        37.0 / 378.0 - 2825.0 / 27648.0,
        0.0,
        250.0 / 621.0 - 18575.0 / 48384.0,
        125.0 / 594.0 - 13525.0 / 55296.0,
        -277.0 / 14336.0,
        512.0 / 1771.0 - 0.25,
    ],
    c: &[0.0, 0.2, 0.3, 0.6, 1.0, 7.0 / 8.0],
    diag: &[],
    fsal: false,
    dense: DenseOutput::Hermite,
};

// --- Dormand–Prince 5(4), FSAL -------------------------------------------------
pub static DOPRI5: Tableau = Tableau {
    name: "dopri5",
    stages: 7,
    order: 5,
    err_order: 4,
    a: &[
        0.2, //
        3.0 / 40.0,
        9.0 / 40.0, //
        44.0 / 45.0,
        -56.0 / 15.0,
        32.0 / 9.0, //
        19372.0 / 6561.0,
        -25360.0 / 2187.0,
        64448.0 / 6561.0,
        -212.0 / 729.0, //
        9017.0 / 3168.0,
        -355.0 / 33.0,
        46732.0 / 5247.0,
        49.0 / 176.0,
        -5103.0 / 18656.0, //
        35.0 / 384.0,
        0.0,
        500.0 / 1113.0,
        125.0 / 192.0,
        -2187.0 / 6784.0,
        11.0 / 84.0,
    ],
    b: &[
        35.0 / 384.0,
        0.0,
        500.0 / 1113.0,
        125.0 / 192.0,
        -2187.0 / 6784.0,
        11.0 / 84.0,
        0.0,
    ],
    // b̂ = [5179/57600, 0, 7571/16695, 393/640, -92097/339200, 187/2100, 1/40]
    b_err: &[
        71.0 / 57600.0,
        0.0,
        -71.0 / 16695.0,
        71.0 / 1920.0,
        -17253.0 / 339200.0,
        22.0 / 525.0,
        -1.0 / 40.0,
    ],
    c: &[0.0, 0.2, 0.3, 0.8, 8.0 / 9.0, 1.0, 1.0],
    diag: &[],
    fsal: true,
    dense: DenseOutput::Dopri5,
};

/// Dopri5 dense-output `d` coefficients (Hairer, Nørsett & Wanner, DOPRI5).
pub static DOPRI5_D: [f64; 7] = [
    -12715105075.0 / 11282082432.0,
    0.0,
    87487479700.0 / 32700410799.0,
    -10690763975.0 / 1880347072.0,
    701980252875.0 / 199316789632.0,
    -1453857185.0 / 822651844.0,
    69997945.0 / 29380423.0,
];

// --- Tsitouras 5(4), FSAL -------------------------------------------------------
pub static TSIT5: Tableau = Tableau {
    name: "tsit5",
    stages: 7,
    order: 5,
    err_order: 4,
    a: &[
        0.161, //
        -0.008480655492356989,
        0.335480655492357, //
        2.8971530571054935,
        -6.359448489975075,
        4.3622954328695815, //
        5.325864828439257,
        -11.748883564062828,
        7.4955393428898365,
        -0.09249506636175525, //
        5.86145544294642,
        -12.92096931784711,
        8.159367898576159,
        -0.071584973281401,
        -0.028269050394068383, //
        0.09646076681806523,
        0.01,
        0.4798896504144996,
        1.379008574103742,
        -3.290069515436081,
        2.324710524099774,
    ],
    b: &[
        0.09646076681806523,
        0.01,
        0.4798896504144996,
        1.379008574103742,
        -3.290069515436081,
        2.324710524099774,
        0.0,
    ],
    // b_err = b - b̂ (Tsitouras 2011, as used by OrdinaryDiffEq.jl/diffrax)
    b_err: &[
        -0.00178001105222577714,
        -0.0008164344596567469,
        0.007880878010261995,
        -0.1447110071732629,
        0.5823571654525552,
        -0.45808210592918697,
        0.015151515151515152,
    ],
    c: &[0.0, 0.161, 0.327, 0.9, 0.9800255409045097, 1.0, 1.0],
    diag: &[],
    fsal: true,
    dense: DenseOutput::Hermite,
};

// --- TR-BDF2 2(3), stiffly-accurate ESDIRK -----------------------------------
//
// One trapezoidal substage to t + γh followed by a BDF2-like substage to
// t + h, with γ = 2 − √2 (Bank et al. 1985; embedded 3rd-order companion
// per Hosea & Shampine 1996). Stage 0 is explicit (c₀ = 0, diag₀ = 0);
// stages 1 and 2 share the diagonal d = γ/2 = 1 − √2/2, so one LU of
// `I − h·d·J` serves the whole step. The last stage row equals `b`
// (stiffly accurate): the propagated 2nd-order solution is the last
// stage value, which is what makes the method L-stable. The embedded
// weights b̂ = [(1−w)/3, (3w+1)/3, d/3] (w = √2/4) are the 3rd-order
// companion; the raw difference `b − b̂` behaves like the 2nd-order
// method's O(h³) local error, so `err_order = 2`.
//
// NOT FSAL in the loop's hand-off sense: k₂ is recovered *algebraically*
// from the stage equation (k₂ = (z₂ − rhs)/(h·d)), which equals
// f(t+h, y_new) only up to the Newton tolerance — reusing it as the next
// step's k₀ would inject O(tol/h) slope error. With `fsal: false` the
// loops refresh k₀ = f(t_new, y_new) exactly on acceptance (also the
// Hermite dense-output end slope).
const TRBDF2_GAMMA: f64 = 2.0 - std::f64::consts::SQRT_2;
const TRBDF2_D: f64 = TRBDF2_GAMMA / 2.0;
const TRBDF2_W: f64 = std::f64::consts::SQRT_2 / 4.0;

pub static TRBDF2: Tableau = Tableau {
    name: "trbdf2",
    stages: 3,
    order: 2,
    err_order: 2,
    // Strictly lower-triangular part; the diagonal lives in `diag`.
    a: &[
        TRBDF2_D, //
        TRBDF2_W, TRBDF2_W,
    ],
    b: &[TRBDF2_W, TRBDF2_W, TRBDF2_D],
    // b̂ = [(1 − w)/3, (3w + 1)/3, d/3]  =>  b_err = b − b̂
    b_err: &[
        TRBDF2_W - (1.0 - TRBDF2_W) / 3.0,
        TRBDF2_W - (3.0 * TRBDF2_W + 1.0) / 3.0,
        TRBDF2_D - TRBDF2_D / 3.0,
    ],
    c: &[0.0, TRBDF2_GAMMA, 1.0],
    diag: &[0.0, TRBDF2_D, TRBDF2_D],
    fsal: false,
    dense: DenseOutput::Hermite,
};

// --- Kvaerno 4(3), stiffly-accurate ESDIRK ----------------------------------
//
// Kværnø's 5-stage ESDIRK 4(3) pair (Kværnø 2004, "Singly diagonally
// implicit Runge–Kutta methods with an explicit first stage"). Stage 0
// is explicit; stages 1–4 share the diagonal γ, the relevant root of
// γ³ − 3γ² + 3γ/2 − 1/6 = 0 (L-stability of the 4th-order solution).
// Both the solution row and the embedded 3rd-order companion are
// stiffly accurate — b is stage row 4, b̂ is stage row 3 — so the error
// estimate stays bounded in the stiff limit even before the
// Hosea–Shampine filter. The coefficients here are re-derived to full
// f64 precision from the order conditions (stage order 2 for every
// implicit stage; b̂ solves the order-3 quadrature system; b solves the
// order-4 quadrature system; c₃ is pinned by the one non-automatic
// 4th-order condition Σᵢ bᵢ(Ac²)ᵢ = 1/12) — the commonly published
// 10-digit values miss this module's 1e-12 consistency checks.
//
// Like TR-BDF2, NOT FSAL in the hand-off sense: the last slope is
// recovered algebraically from the stage equation, so the loops refresh
// k₀ = f(t_new, y_new) exactly on acceptance.
const KV43_GAMMA: f64 = 0.4358665215084592;
const KV43_C3: f64 = 0.4682387448518447;
const KV43_A31: f64 = 0.14073777472470633;
const KV43_A32: f64 = -0.10836555138132084;
const KV43_A41: f64 = 0.10239940061991126;
const KV43_A42: f64 = -0.3768784522555564;
const KV43_A43: f64 = 0.838612530127186;
const KV43_B1: f64 = 0.15702489786032495;
const KV43_B2: f64 = 0.11733044137043755;
const KV43_B3: f64 = 0.6166780303921222;
const KV43_B4: f64 = -0.32689989113134393;

pub static KVAERNO43: Tableau = Tableau {
    name: "kvaerno43",
    stages: 5,
    order: 4,
    err_order: 3,
    // Strictly lower-triangular part; the diagonal lives in `diag`.
    a: &[
        KV43_GAMMA, //
        KV43_A31, KV43_A32, //
        KV43_A41, KV43_A42, KV43_A43, //
        KV43_B1, KV43_B2, KV43_B3, KV43_B4,
    ],
    b: &[KV43_B1, KV43_B2, KV43_B3, KV43_B4, KV43_GAMMA],
    // b̂ = stage row 3 = [a41, a42, a43, γ, 0]  =>  b_err = b − b̂
    b_err: &[
        KV43_B1 - KV43_A41,
        KV43_B2 - KV43_A42,
        KV43_B3 - KV43_A43,
        KV43_B4 - KV43_GAMMA,
        KV43_GAMMA,
    ],
    c: &[0.0, 2.0 * KV43_GAMMA, KV43_C3, 1.0, 1.0],
    diag: &[0.0, KV43_GAMMA, KV43_GAMMA, KV43_GAMMA, KV43_GAMMA],
    fsal: false,
    dense: DenseOutput::Hermite,
};

/// All built-in tableaus, in the registration order of the method
/// registry ([`crate::solver::MethodId::BUILTINS`] indexes this table).
pub static ALL: &[&Tableau] = &[
    &EULER, &MIDPOINT, &HEUN21, &RALSTON2, &BOSH3, &RK4, &FEHLBERG45, &CASHKARP45, &DOPRI5, &TSIT5,
    &TRBDF2, &KVAERNO43,
];

#[cfg(test)]
mod tests {
    use super::*;

    /// Row sums of `a` (plus the implicit diagonal, where present) must
    /// equal the nodes `c` (stage consistency).
    #[test]
    fn stage_consistency() {
        for t in ALL {
            for i in 1..t.stages {
                let diag = t.diag.get(i).copied().unwrap_or(0.0);
                let s: f64 = t.a_row(i).iter().sum::<f64>() + diag;
                assert!(
                    (s - t.c[i]).abs() < 1e-12,
                    "{}: row {} sums to {} but c = {}",
                    t.name,
                    i,
                    s,
                    t.c[i]
                );
            }
        }
    }

    /// ESDIRK structure of every implicit tableau: explicit first
    /// stage, one shared positive diagonal, stiffly-accurate last row
    /// (`a_row(last) + diag[last] == b`), and the 2nd/3rd-order
    /// conditions of the embedded companion b̂ = b − b_err.
    #[test]
    fn esdirk_structure() {
        let implicit: Vec<&&Tableau> = ALL.iter().filter(|t| !t.diag.is_empty()).collect();
        assert!(implicit.len() >= 2, "TR-BDF2 and Kvaerno 4(3) should be here");
        for t in implicit {
            assert_eq!(t.diag.len(), t.stages, "{}", t.name);
            assert_eq!(t.diag[0], 0.0, "{}: ESDIRK first stage explicit", t.name);
            let gamma = t.diag[1];
            assert!(gamma > 0.0, "{}", t.name);
            for (s, &d) in t.diag.iter().enumerate().skip(1) {
                assert!(d == gamma, "{}: single-γ diagonal violated at stage {s}", t.name);
            }
            // Stiffly accurate: the last stage value is the solution.
            for j in 0..t.stages - 1 {
                assert!(
                    (t.a_row(t.stages - 1)[j] - t.b[j]).abs() < 1e-15,
                    "{}: j={j}",
                    t.name
                );
            }
            assert!((t.diag[t.stages - 1] - t.b[t.stages - 1]).abs() < 1e-15, "{}", t.name);
            // The embedded companion b̂ is (at least) 3rd order:
            // Σb̂ = 1, Σb̂c = 1/2, Σb̂c² = 1/3 (the diagonal enters only
            // the stage equations, not the quadrature conditions on b̂
            // and c).
            let bhat: Vec<f64> = t.b.iter().zip(t.b_err).map(|(b, e)| b - e).collect();
            let s0: f64 = bhat.iter().sum();
            let s1: f64 = bhat.iter().zip(t.c).map(|(b, c)| b * c).sum();
            let s2: f64 = bhat.iter().zip(t.c).map(|(b, c)| b * c * c).sum();
            assert!((s0 - 1.0).abs() < 1e-14, "{}: Σb̂ = {s0}", t.name);
            assert!((s1 - 0.5).abs() < 1e-14, "{}: Σb̂c = {s1}", t.name);
            assert!((s2 - 1.0 / 3.0).abs() < 1e-14, "{}: Σb̂c² = {s2}", t.name);
            assert!(!t.fsal, "{}: k_last is algebraic, not f(t_new, y_new)", t.name);
        }
    }

    /// Solution weights must sum to 1 (first order condition).
    #[test]
    fn b_sums_to_one() {
        for t in ALL {
            let s: f64 = t.b.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "{}: Σb = {}", t.name, s);
        }
    }

    /// Error weights must sum to 0 (the two embedded methods agree at order 1).
    #[test]
    fn b_err_sums_to_zero() {
        for t in ALL {
            if t.adaptive() {
                let s: f64 = t.b_err.iter().sum();
                assert!(s.abs() < 1e-12, "{}: Σb_err = {}", t.name, s);
            }
        }
    }

    /// Second-order condition Σ b_i c_i = 1/2 for methods of order ≥ 2.
    #[test]
    fn second_order_condition() {
        for t in ALL {
            if t.order >= 2 {
                let s: f64 = t.b.iter().zip(t.c).map(|(b, c)| b * c).sum();
                assert!((s - 0.5).abs() < 1e-9, "{}: Σ b_i c_i = {}", t.name, s);
            }
        }
    }

    /// `(A·v)_i` including the implicit diagonal (empty for explicit
    /// tableaus) — the full stage matrix the order conditions see.
    fn a_dot(t: &Tableau, v: &[f64]) -> Vec<f64> {
        (0..t.stages)
            .map(|i| {
                let strict: f64 = t.a_row(i).iter().zip(v).map(|(a, x)| a * x).sum();
                strict + t.diag.get(i).copied().unwrap_or(0.0) * v[i]
            })
            .collect()
    }

    /// Third-order conditions for methods of order ≥ 3.
    #[test]
    fn third_order_conditions() {
        for t in ALL {
            if t.order >= 3 {
                let s1: f64 = t.b.iter().zip(t.c).map(|(b, c)| b * c * c).sum();
                assert!((s1 - 1.0 / 3.0).abs() < 1e-9, "{}: Σ b c² = {}", t.name, s1);
                // Σ_i b_i (A c)_i = 1/6, with the implicit diagonal part
                // of A included where present.
                let ac = a_dot(t, t.c);
                let s2: f64 = t.b.iter().zip(&ac).map(|(b, x)| b * x).sum();
                assert!((s2 - 1.0 / 6.0).abs() < 1e-9, "{}: Σ b A c = {}", t.name, s2);
            }
        }
    }

    /// Fourth-order conditions for methods of order ≥ 4 — all four
    /// order-4 trees, with the implicit diagonal part of A included.
    #[test]
    fn fourth_order_conditions() {
        for t in ALL {
            if t.order >= 4 {
                let s: f64 = t.b.iter().zip(t.c).map(|(b, c)| b * c * c * c).sum();
                assert!((s - 0.25).abs() < 1e-9, "{}: Σ b c³ = {}", t.name, s);
                let c2: Vec<f64> = t.c.iter().map(|c| c * c).collect();
                let ac = a_dot(t, t.c);
                let s2: f64 =
                    t.b.iter().zip(t.c).zip(&ac).map(|((b, c), x)| b * c * x).sum();
                assert!((s2 - 0.125).abs() < 1e-9, "{}: Σ b c (A c) = {}", t.name, s2);
                let ac2 = a_dot(t, &c2);
                let s3: f64 = t.b.iter().zip(&ac2).map(|(b, x)| b * x).sum();
                assert!((s3 - 1.0 / 12.0).abs() < 1e-9, "{}: Σ b A c² = {}", t.name, s3);
                let aac = a_dot(t, &ac);
                let s4: f64 = t.b.iter().zip(&aac).map(|(b, x)| b * x).sum();
                assert!((s4 - 1.0 / 24.0).abs() < 1e-9, "{}: Σ b A A c = {}", t.name, s4);
            }
        }
    }

    /// FSAL tableaus: last stage row must equal b, last node must be 1.
    #[test]
    fn fsal_structure() {
        for t in ALL {
            if t.fsal {
                let last = t.stages - 1;
                assert!((t.c[last] - 1.0).abs() < 1e-12, "{}: FSAL c", t.name);
                for (j, &a) in t.a_row(last).iter().enumerate() {
                    assert!(
                        (a - t.b[j]).abs() < 1e-12,
                        "{}: FSAL row mismatch at {}",
                        t.name,
                        j
                    );
                }
                assert_eq!(t.b[last], 0.0, "{}: FSAL b[last]", t.name);
            }
        }
    }

    /// Flattened `a` has the right triangular length and accessor agrees.
    #[test]
    fn a_indexing() {
        for t in ALL {
            assert_eq!(t.a.len(), t.stages * (t.stages - 1) / 2, "{}", t.name);
            for i in 1..t.stages {
                for j in 0..i {
                    assert_eq!(t.a(i, j), t.a_row(i)[j], "{}", t.name);
                }
            }
            assert_eq!(t.b.len(), t.stages);
            assert_eq!(t.c.len(), t.stages);
            if t.adaptive() {
                assert_eq!(t.b_err.len(), t.stages);
            }
            // diag is empty (explicit) or exactly one entry per stage.
            assert!(t.diag.is_empty() || t.diag.len() == t.stages, "{}", t.name);
        }
    }
}
