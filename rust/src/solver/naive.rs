//! The naive solve loop — the implementation-efficiency baseline.
//!
//! Semantics are identical to [`super::solve_ivp_joint`] (shared step
//! size, joint error norm), but the implementation deliberately mirrors
//! the cost model of an eager, generic, op-by-op solver such as
//! torchdiffeq: **every arithmetic operation is a separate pass over
//! freshly allocated memory** — one "kernel launch" per op — and
//! polynomials are evaluated naively (computing θ, θ², θ³ as separate
//! power ops) instead of via Horner's rule. On a CPU, each pass + alloc
//! plays the role of a GPU kernel launch; the loop-time ratio between
//! this engine and the fused ones is the reproduction target of Table 2.
//!
//! Nothing here is *algorithmically* worse: steps, accepts and outputs
//! match the joint loop to floating-point reordering.

use super::controller::ControllerState;
use super::{SolveOptions, Solution, Status, TimeGrid};
use crate::problems::OdeSystem;
use crate::tensor::BatchVec;
use std::cell::Cell;

thread_local! {
    /// "Kernel launches" of the most recent naive solve: one per op_* call
    /// plus one per dynamics evaluation. Used by the Table 3 harness to
    /// drive the simulated GPU launch-overhead model (EXPERIMENTS.md §T3).
    static OP_COUNT: Cell<u64> = const { Cell::new(0) };
}

/// Launch count of the last [`solve_ivp_naive`] call on this thread.
pub fn last_op_count() -> u64 {
    OP_COUNT.with(|c| c.get())
}

#[inline]
fn bump() {
    OP_COUNT.with(|c| c.set(c.get() + 1));
}

// --- op-by-op "tensor library": every op allocates its output ---------------

fn op_scale(x: &[f64], s: f64) -> Vec<f64> {
    bump();
    x.iter().map(|v| v * s).collect()
}

fn op_add(a: &[f64], b: &[f64]) -> Vec<f64> {
    bump();
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

fn op_sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    bump();
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

fn op_div(a: &[f64], b: &[f64]) -> Vec<f64> {
    bump();
    a.iter().zip(b).map(|(x, y)| x / y).collect()
}

fn op_abs(a: &[f64]) -> Vec<f64> {
    bump();
    a.iter().map(|v| v.abs()).collect()
}

fn op_max(a: &[f64], b: &[f64]) -> Vec<f64> {
    bump();
    a.iter().zip(b).map(|(x, y)| x.max(*y)).collect()
}

fn op_add_scalar(a: &[f64], s: f64) -> Vec<f64> {
    bump();
    a.iter().map(|v| v + s).collect()
}

fn op_mul(a: &[f64], b: &[f64]) -> Vec<f64> {
    bump();
    a.iter().zip(b).map(|(x, y)| x * y).collect()
}

fn op_square(a: &[f64]) -> Vec<f64> {
    bump();
    a.iter().map(|v| v * v).collect()
}

fn op_mean(a: &[f64]) -> f64 {
    bump();
    a.iter().sum::<f64>() / a.len() as f64
}

/// Solve with joint semantics, per-op implementation. See module docs.
pub fn solve_ivp_naive(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> Solution {
    let batch = y0.batch();
    let dim = y0.dim();
    opts.tols.validate(batch);
    let n = batch * dim;
    let n_eval = grid.n_eval();
    let t0 = grid.t0(0);
    let t1 = grid.t1(0);
    for i in 1..batch {
        assert!(
            (grid.t0(i) - t0).abs() < 1e-12 && (grid.t1(i) - t1).abs() < 1e-12,
            "joint solving requires a shared integration range"
        );
    }
    let tab = opts.method.tableau();
    assert!(
        tab.diag.is_empty(),
        "the naive per-op baseline only implements explicit methods; \
         use solve_ivp_parallel/solve_ivp_joint for {}",
        tab.name
    );
    let adaptive = tab.adaptive() && opts.fixed_dt.is_none();

    let mut sol = Solution::new_buffer(batch, n_eval, dim);
    let mut y: Vec<f64> = y0.flat().to_vec();
    let mut t = t0;
    let mut ctrl = ControllerState::default();
    let mut next_eval = vec![0usize; batch];

    for i in 0..batch {
        sol.y_mut(i, 0).copy_from_slice(&y[i * dim..(i + 1) * dim]);
        sol.stats[i].n_initialized += 1;
        next_eval[i] = 1;
    }
    if n_eval == 1 || t1 <= t0 {
        for i in 0..batch {
            sol.status[i] = Status::Success;
        }
        return sol;
    }

    // Helper: batched dynamics evaluation through a freshly allocated
    // BatchVec each time (torchdiffeq-style: no buffer reuse).
    OP_COUNT.with(|c| c.set(0));
    let eval = |t: f64, y: &[f64], sol: &mut Solution| -> Vec<f64> {
        bump();
        let yb = BatchVec::from_flat(y.to_vec(), batch, dim);
        let mut out = BatchVec::zeros(batch, dim);
        sys.f_batch(&vec![t; batch], &yb, &mut out, None);
        for st in sol.stats.iter_mut() {
            st.n_f_evals += 1;
        }
        out.flat().to_vec()
    };

    let mut f0 = eval(t, &y, &mut sol);

    // Initial dt: same heuristic as the optimized loops but written per-op.
    let mut dt = if let Some(h) = opts.fixed_dt.or(opts.dt0) {
        h
    } else {
        // d0/d1 heuristic with separate passes.
        let scale = op_add_scalar(&op_scale(&op_abs(&y), opts.tols.rtol(0)), opts.tols.atol(0));
        let d0 = op_mean(&op_square(&op_div(&y, &scale))).sqrt();
        let d1 = op_mean(&op_square(&op_div(&f0, &scale))).sqrt();
        let h0 = if d0 < 1e-5 || d1 < 1e-5 { 1e-6 } else { 0.01 * d0 / d1 };
        let y1 = op_add(&y, &op_scale(&f0, h0));
        let f1 = eval(t + h0, &y1, &mut sol);
        let d2 = op_mean(&op_square(&op_div(&op_sub(&f1, &f0), &scale))).sqrt() / h0;
        let dmax = d1.max(d2);
        let h1 = if dmax <= 1e-15 {
            (h0 * 1e-3).max(1e-6)
        } else {
            (0.01 / dmax).powf(1.0 / (tab.order as f64 + 1.0))
        };
        (100.0 * h0).min(h1).min(t1 - t0)
    };

    let min_dt = (t1 - t0) * opts.min_dt_rel;
    let mut steps = 0usize;
    let mut status = Status::MaxStepsReached;
    let mut trace: Vec<(f64, f64)> = Vec::new();

    'outer: loop {
        steps += 1;
        if steps > opts.max_steps {
            break;
        }
        let mut clamped = false;
        if dt >= t1 - t {
            dt = t1 - t;
            clamped = true;
        }

        // Stages, one op per coefficient (the torchdiffeq pattern:
        // yi = y + dt*a1*k1 + dt*a2*k2 + ... each as separate kernels).
        let mut k: Vec<Vec<f64>> = Vec::with_capacity(tab.stages);
        k.push(f0.clone());
        for s in 1..tab.stages {
            let mut ytmp = y.clone();
            for (j, &a) in tab.a_row(s).iter().enumerate() {
                if a != 0.0 {
                    ytmp = op_add(&ytmp, &op_scale(&op_scale(&k[j], a), dt));
                }
            }
            k.push(eval(t + tab.c[s] * dt, &ytmp, &mut sol));
        }
        for st in sol.stats.iter_mut() {
            st.n_steps += 1;
        }

        // Solution and error, one pass per weight.
        let mut y_new = y.clone();
        for (j, &b) in tab.b.iter().enumerate() {
            if b != 0.0 {
                y_new = op_add(&y_new, &op_scale(&op_scale(&k[j], b), dt));
            }
        }
        let mut err = vec![0.0; n];
        for (j, &b) in tab.b_err.iter().enumerate() {
            if b != 0.0 {
                err = op_add(&err, &op_scale(&op_scale(&k[j], b), dt));
            }
        }

        if y_new.iter().any(|v| !v.is_finite()) {
            status = Status::NonFinite;
            break;
        }

        let (accept, factor) = if adaptive {
            // Error norm with separate abs/max/scale/div/square/mean/sqrt
            // passes.
            let scale = op_add_scalar(
                &op_scale(&op_max(&op_abs(&y), &op_abs(&y_new)), opts.tols.rtol(0)),
                opts.tols.atol(0),
            );
            let en = op_mean(&op_square(&op_div(&err, &scale))).sqrt();
            let d = opts.controller.decide(en, tab.err_order, &ctrl);
            if d.accept {
                ctrl.push(en);
            }
            (d.accept, d.factor)
        } else {
            (true, 1.0)
        };

        if accept {
            for st in sol.stats.iter_mut() {
                st.n_accepted += 1;
            }
            let t_new = if clamped { t1 } else { t + dt };
            if opts.record_trace {
                trace.push((t, dt));
            }

            // Dense output via *naive* cubic Hermite evaluation, batched per
            // evaluation-point index (one set of whole-batch tensor ops per
            // eval time — the torchdiffeq pattern), with powers of θ as
            // separate ops (no Horner — that is the point).
            let f_end = if tab.fsal { k[tab.stages - 1].clone() } else { eval_no_count(&k[0]) };
            let e_lo = *next_eval.iter().min().unwrap();
            for e in e_lo..n_eval {
                // Which instances want this point now?
                let wants: Vec<bool> = (0..batch)
                    .map(|i| next_eval[i] <= e && grid.row(i)[e] <= t_new)
                    .collect();
                if !wants.iter().any(|&w| w) {
                    break;
                }
                // Per-instance θ, expanded over dim (a broadcast "kernel").
                bump();
                let theta_full: Vec<f64> = (0..n)
                    .map(|idx| {
                        let i = idx / dim;
                        ((grid.row(i)[e] - t) / dt).clamp(0.0, 1.0)
                    })
                    .collect();
                let th2 = op_square(&theta_full);
                let th3 = op_mul(&th2, &theta_full);
                // h00 = 2θ³ − 3θ² + 1, h10 = θ³ − 2θ² + θ,
                // h01 = −2θ³ + 3θ², h11 = θ³ − θ².
                let h00 = op_add_scalar(&op_sub(&op_scale(&th3, 2.0), &op_scale(&th2, 3.0)), 1.0);
                let h10 = op_add(&op_sub(&th3, &op_scale(&th2, 2.0)), &theta_full);
                let h01 = op_add(&op_scale(&th3, -2.0), &op_scale(&th2, 3.0));
                let h11 = op_sub(&th3, &th2);
                let part = op_add(
                    &op_add(&op_mul(&h00, &y), &op_scale(&op_mul(&h10, &k[0]), dt)),
                    &op_add(&op_mul(&h01, &y_new), &op_scale(&op_mul(&h11, &f_end), dt)),
                );
                bump(); // masked scatter into the output buffer
                for i in 0..batch {
                    if wants[i] {
                        sol.y_mut(i, e).copy_from_slice(&part[i * dim..(i + 1) * dim]);
                        sol.stats[i].n_initialized += 1;
                        next_eval[i] = e + 1;
                    }
                }
            }

            y = y_new;
            t = t_new;
            f0 = if tab.fsal { k[tab.stages - 1].clone() } else { eval(t, &y, &mut sol) };

            if next_eval.iter().all(|&e| e >= n_eval) {
                status = Status::Success;
                break 'outer;
            }
        }

        dt *= factor;
        if adaptive && dt < min_dt {
            status = Status::DtUnderflow;
            break;
        }
    }

    for i in 0..batch {
        sol.status[i] = status;
    }
    if opts.record_trace {
        let mut traces = vec![Vec::new(); batch];
        traces[0] = trace;
        sol.trace = Some(traces);
    }
    sol
}

/// Clone helper for the non-FSAL Hermite endpoint (no feval counted — the
/// slope is stale by one step). The fused loops evaluate the true end
/// slope since the stale-Hermite fix; the naive loop deliberately keeps
/// the torchdiffeq-era shortcut because it only ever benchmarks FSAL
/// methods, whose endpoint slope is the last stage anyway.
fn eval_no_count(k0: &[f64]) -> Vec<f64> {
    k0.to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, VdP};
    use crate::solver::{solve_ivp_joint, MethodId};

    #[test]
    fn op_count_tracks_work() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::broadcast(&[1.0], 2);
        let grid = TimeGrid::linspace_shared(2, 0.0, 1.0, 3);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6);
        let sol = solve_ivp_naive(&sys, &y0, &grid, &opts);
        let ops = last_op_count();
        // At least ~30 ops per step (6 evals + per-coefficient passes).
        assert!(
            ops > 30 * sol.stats[0].n_steps,
            "ops {ops} for {} steps",
            sol.stats[0].n_steps
        );
    }

    #[test]
    fn naive_accuracy() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::broadcast(&[1.0], 3);
        let grid = TimeGrid::linspace_shared(3, 0.0, 1.0, 5);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8);
        let sol = solve_ivp_naive(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        for i in 0..3 {
            assert!((sol.y_final(i)[0] - (-1.0f64).exp()).abs() < 1e-6);
        }
    }

    #[test]
    fn naive_matches_joint_step_counts() {
        // Same semantics => identical accept/reject trajectory (up to FP
        // reordering; VdP at modest tolerance keeps them in lockstep).
        let sys = VdP::new(vec![2.0, 8.0]);
        let y0 = BatchVec::broadcast(&[2.0, 0.0], 2);
        let grid = TimeGrid::linspace_shared(2, 0.0, 5.0, 10);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6);
        let a = solve_ivp_naive(&sys, &y0, &grid, &opts);
        let b = solve_ivp_joint(&sys, &y0, &grid, &opts);
        assert!(a.all_success() && b.all_success());
        let (sa, sb) = (a.stats[0].n_steps as f64, b.stats[0].n_steps as f64);
        assert!((sa - sb).abs() / sb < 0.1, "naive {sa} vs joint {sb}");
        for d in 0..2 {
            assert!((a.y_final(0)[d] - b.y_final(0)[d]).abs() < 1e-4);
        }
    }

    #[test]
    fn naive_tsit5_works() {
        let sys = ExponentialDecay::new(vec![2.0], 1);
        let y0 = BatchVec::broadcast(&[1.0], 1);
        let grid = TimeGrid::linspace_shared(1, 0.0, 1.0, 3);
        let opts = SolveOptions::new(MethodId::TSIT5).with_tols(1e-8, 1e-8);
        let sol = solve_ivp_naive(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        assert!((sol.y_final(0)[0] - (-2.0f64).exp()).abs() < 1e-6);
    }
}
