//! The frozen pre-active-set parallel loop, kept verbatim as a **bitwise
//! reference** and benchmark baseline.
//!
//! This is the mask-based implementation the active-set loop in
//! [`super::parallel`] replaced: every pass sweeps the full batch and
//! checks a `finished` flag per row, the stage kernel receives a
//! `Vec<bool>` activity mask, and finished rows keep paying O(dim)
//! keep-alive work per stage. It exists so that
//!
//! - `tests/compaction.rs` can assert that the active-set loop (with and
//!   without compaction, serial and pooled) reproduces this loop
//!   **bitwise** — solutions, stats, statuses and traces — across the
//!   whole method matrix, and
//! - the straggler benchmark (`benches/coordinator_bench.rs`) can report
//!   the active-set speedup against the real predecessor instead of a
//!   synthetic stand-in, recorded in `BENCH_solver.json`.
//!
//! Do not "improve" this module; its value is that it does not change.

use super::controller::ControllerState;
use super::init::initial_step_batch;
use super::interp::{self, DOPRI5_NCOEFF};
use super::norm::{scaled_norm, NormKind};
use super::step::{rk_attempt, CompiledTableau, RkWorkspace};
use super::tableau::DenseOutput;
use super::{SolveOptions, Solution, Status, TimeGrid};
use crate::problems::OdeSystem;
use crate::tensor::BatchVec;

/// The historical mask-based parallel loop. Ignores
/// [`SolveOptions::compact_threshold`] (it predates compaction); honors
/// everything else, including `eval_inactive`.
pub fn solve_ivp_parallel_reference(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> Solution {
    let batch = y0.batch();
    let dim = y0.dim();
    assert_eq!(grid.batch(), batch, "grid/initial-state batch mismatch");
    assert_eq!(sys.dim(), dim, "system/initial-state dim mismatch");
    opts.tols.validate(batch);
    let n_eval = grid.n_eval();
    let tab = opts.method.tableau();
    // Guard, not behavior: the frozen loop predates implicit methods
    // and must fail loudly rather than panic deep in the stage kernel.
    assert!(
        tab.diag.is_empty(),
        "the frozen reference loop only implements explicit methods; \
         use solve_ivp_parallel for {}",
        tab.name
    );
    let ct = CompiledTableau::new(tab);
    let adaptive = tab.adaptive() && opts.fixed_dt.is_none();

    let mut sol = Solution::new_buffer(batch, n_eval, dim);
    let mut trace: Vec<Vec<(f64, f64)>> = if opts.record_trace {
        vec![Vec::new(); batch]
    } else {
        Vec::new()
    };

    let mut y = y0.clone();
    let mut t: Vec<f64> = (0..batch).map(|i| grid.t0(i)).collect();
    let mut finished = vec![false; batch];
    let mut k0_ready = vec![false; batch];
    let mut ctrl = vec![ControllerState::default(); batch];
    let mut next_eval = vec![0usize; batch];
    let span: Vec<f64> = (0..batch).map(|i| grid.t1(i) - grid.t0(i)).collect();

    let mut ws = RkWorkspace::new(tab.stages, batch, dim);
    let mut f_start = BatchVec::zeros(batch, dim);
    let mut interp_coeffs = vec![0.0; DOPRI5_NCOEFF * dim];

    for i in 0..batch {
        sol.y_mut(i, 0).copy_from_slice(y.row(i));
        sol.stats[i].n_initialized += 1;
        next_eval[i] = 1;
        if n_eval == 1 || span[i] <= 0.0 {
            finished[i] = true;
            sol.status[i] = Status::Success;
        }
    }

    sys.f_batch(&t, &y, &mut ws.k[0], None);
    for s in sol.stats.iter_mut() {
        s.n_f_evals += 1;
    }
    f_start.copy_from(&ws.k[0]);
    for r in k0_ready.iter_mut() {
        *r = true;
    }

    let mut dt: Vec<f64> = match (opts.fixed_dt, opts.dt0) {
        (Some(h), _) => vec![h; batch],
        (None, Some(h)) => vec![h; batch],
        (None, None) => {
            let dt0 = initial_step_batch(
                sys,
                &t,
                &y,
                &ws.k[0],
                tab.order,
                &opts.tols,
                &span,
                &mut ws.ytmp,
                &mut ws.y_new,
            );
            for s in sol.stats.iter_mut() {
                s.n_f_evals += 1;
            }
            dt0
        }
    };

    let min_dt: Vec<f64> = span.iter().map(|s| s.abs() * opts.min_dt_rel).collect();

    let mut clamped = vec![false; batch];
    let mut active = vec![true; batch];
    let mut accepted = vec![false; batch];
    let mut factor = vec![1.0f64; batch];
    let mut t_new = vec![0.0f64; batch];
    let mut iter = 0usize;
    while finished.iter().any(|f| !f) {
        iter += 1;
        if iter > opts.max_steps {
            for i in 0..batch {
                if !finished[i] {
                    sol.status[i] = Status::MaxStepsReached;
                    finished[i] = true;
                }
            }
            break;
        }

        for i in 0..batch {
            clamped[i] = false;
            active[i] = !finished[i];
            if finished[i] {
                continue;
            }
            let remaining = grid.t1(i) - t[i];
            if dt[i] >= remaining {
                dt[i] = remaining;
                clamped[i] = true;
            }
        }
        let calls = rk_attempt(
            &ct,
            sys,
            &t,
            &dt,
            &y,
            &mut ws,
            &k0_ready,
            Some(&active),
            opts.eval_inactive,
        );
        for s in sol.stats.iter_mut() {
            s.n_f_evals += calls;
        }

        for i in 0..batch {
            accepted[i] = false;
            if finished[i] {
                continue;
            }
            sol.stats[i].n_steps += 1;

            let y_new = ws.y_new.row(i);
            if y_new.iter().any(|v| !v.is_finite()) {
                sol.status[i] = Status::NonFinite;
                finished[i] = true;
                continue;
            }

            let (accept, fac) = if adaptive {
                let en = scaled_norm(
                    NormKind::Rms,
                    ws.err.row(i),
                    y.row(i),
                    y_new,
                    opts.tols.atol(i),
                    opts.tols.rtol(i),
                );
                let d = opts.controller.decide(en, tab.err_order, &ctrl[i]);
                if d.accept {
                    ctrl[i].push(en);
                }
                (d.accept, d.factor)
            } else {
                (true, 1.0)
            };
            accepted[i] = accept;
            factor[i] = fac;
            if accept {
                t_new[i] = if clamped[i] { grid.t1(i) } else { t[i] + dt[i] };
            }
        }

        if !tab.fsal && accepted.iter().any(|&a| a) {
            for i in 0..batch {
                ws.t_stage[i] = if accepted[i] { t_new[i] } else { t[i] };
            }
            sys.f_batch(&ws.t_stage, &ws.y_new, &mut ws.k[0], Some(&accepted));
            for s in sol.stats.iter_mut() {
                s.n_f_evals += 1;
            }
        }

        for i in 0..batch {
            if finished[i] {
                continue;
            }
            if accepted[i] {
                sol.stats[i].n_accepted += 1;
                let tn = t_new[i];
                if opts.record_trace {
                    trace[i].push((t[i], dt[i]));
                }

                let h = dt[i];
                if next_eval[i] < n_eval {
                    let te_row = grid.row(i);
                    let mut e = next_eval[i];
                    let mut coeffs_ready = false;
                    while e < n_eval && te_row[e] <= tn {
                        let theta = ((te_row[e] - t[i]) / h).clamp(0.0, 1.0);
                        match tab.dense {
                            DenseOutput::Dopri5 => {
                                if !coeffs_ready {
                                    let krows: Vec<&[f64]> =
                                        ws.k.iter().map(|k| k.row(i)).collect();
                                    interp::dopri5_coeffs(
                                        h,
                                        y.row(i),
                                        ws.y_new.row(i),
                                        &krows,
                                        &mut interp_coeffs,
                                    );
                                    coeffs_ready = true;
                                }
                                interp::dopri5_eval(theta, &interp_coeffs, sol.y_mut(i, e));
                            }
                            DenseOutput::Hermite => {
                                let f_end = if tab.fsal {
                                    ws.k[tab.stages - 1].row(i)
                                } else {
                                    ws.k[0].row(i)
                                };
                                interp::hermite_eval(
                                    theta,
                                    h,
                                    y.row(i),
                                    f_start.row(i),
                                    ws.y_new.row(i),
                                    f_end,
                                    sol.y_mut(i, e),
                                );
                            }
                        }
                        sol.stats[i].n_initialized += 1;
                        e += 1;
                    }
                    next_eval[i] = e;
                }

                y.row_mut(i).copy_from_slice(ws.y_new.row(i));
                t[i] = tn;
                if tab.fsal {
                    let (head, tail) = ws.k.split_at_mut(tab.stages - 1);
                    let (first, _) = head.split_first_mut().unwrap();
                    first.row_mut(i).copy_from_slice(tail[0].row(i));
                    f_start.row_mut(i).copy_from_slice(tail[0].row(i));
                } else {
                    f_start.row_mut(i).copy_from_slice(ws.k[0].row(i));
                }
                k0_ready[i] = true;

                if next_eval[i] >= n_eval {
                    sol.status[i] = Status::Success;
                    finished[i] = true;
                }
            } else {
                k0_ready[i] = true;
            }

            dt[i] *= factor[i];
            if adaptive && !finished[i] && dt[i] < min_dt[i] {
                sol.status[i] = Status::DtUnderflow;
                finished[i] = true;
            }
        }
    }

    if opts.record_trace {
        sol.trace = Some(trace);
    }
    sol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::VdP;
    use crate::solver::{solve_ivp_parallel, MethodId};

    /// The reference loop still is what it claims to be: identical to the
    /// active-set loop on a mixed batch (the heavyweight matrix lives in
    /// `tests/compaction.rs`).
    #[test]
    fn reference_matches_active_set_loop() {
        let sys = VdP::new(vec![0.5, 12.0]);
        let y0 = BatchVec::from_rows(&[vec![1.0, 0.0], vec![2.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(2, 0.0, 5.0, 10);
        let opts =
            SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(100_000);
        let a = solve_ivp_parallel_reference(&sys, &y0, &grid, &opts);
        let b = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert_eq!(a.status, b.status);
        assert_eq!(a.stats, b.stats);
        for (x, z) in a.ys_flat().iter().zip(b.ys_flat()) {
            assert_eq!(x.to_bits(), z.to_bits());
        }
    }
}
