//! Lane-blocked stage kernels — the vectorized arithmetic core of the
//! RK attempt.
//!
//! Every hot arithmetic pass of an attempt (stage accumulation
//! `ytmp = y + h·Σ a_sj k_j`, the solution/error combination, and the
//! tolerance-scaled sum of squares behind the error norm) funnels
//! through this module. The kernels are **portable**: no intrinsics, no
//! nightly features — they present the optimizer with fixed-width
//! `chunks_exact`-style blocks plus a scalar tail, the shape LLVM
//! reliably auto-vectorizes. Width dispatch: the *elementwise* kernels
//! use width 8 once a row has at least one full 8-lane block
//! (`len >= 8`) and width 4 below that; the [`scaled_sumsq`]
//! *reduction* switches to the width-8 tree only at `len >= 16` (two
//! full blocks — an 8-accumulator tree over a single block buys
//! nothing), so rows of length 8–15 reduce with the width-4 tree. The
//! dispatch depends only on the row length, so it is deterministic per
//! `dim`.
//!
//! ## The bitwise contract
//!
//! The lane-blocked elementwise kernels ([`stage_row`], [`combine_row`],
//! [`combine_pair_row`], and the dim-major [`stage_lanes`] /
//! [`combine_lanes`] / [`combine_pair_lanes`]) compute, for every output
//! element, the **exact same floating-point expression in the exact same
//! order** as the straight-line scalar kernels they replaced (preserved
//! verbatim in [`scalar`]); blocking only regroups independent elements,
//! never an element's own arithmetic. That is what keeps the active-set
//! loop, the pooled loops and the dim-major layout bitwise-identical to
//! the frozen [`crate::solver::reference`] loop
//! (`tests/kernel_parity.rs`).
//!
//! The one genuine reduction — [`scaled_sumsq`] — instead uses a
//! **deterministic fixed-shape lane tree**: four (or eight) independent
//! accumulators over the blocked prefix, reduced in a fixed pairwise
//! tree, then the tail added in element order. The shape depends only on
//! the row length, never on where or when the row is computed, so
//! per-row partials remain position-independent (the property the fused
//! joint norm and every pool kind rely on) and
//! `scaled_norm(Rms, ..) == (scaled_sumsq(..) / len).sqrt()` stays a
//! bitwise identity. For rows shorter than one lane block the tree
//! degenerates to the historical sequential sum, bit for bit.

#![warn(missing_docs)]

/// Narrow lane width: one 256-bit f64 vector.
pub const LANES: usize = 4;
/// Wide lane width: one 512-bit f64 vector (or two 256-bit ops).
pub const LANES_WIDE: usize = 8;

/// Fixed pairwise reduction tree over `W` lane accumulators. The shape
/// is a compile-time constant per width — never data- or
/// schedule-dependent.
#[inline(always)]
fn tree_reduce<const W: usize>(acc: &[f64; W]) -> f64 {
    match W {
        4 => (acc[0] + acc[1]) + (acc[2] + acc[3]),
        8 => ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])),
        _ => {
            let mut s = 0.0;
            for &a in acc.iter() {
                s += a;
            }
            s
        }
    }
}

/// One row of the fused stage accumulation
/// `out[d] = y[d] + h · Σ_j w[j] · k[j][d]` over the pre-gathered
/// nonzero coefficients (`w[j]`, slope row `k[j]`), lane-blocked across
/// `d`. Per-element arithmetic (including the 1- and 2-term
/// specializations) is bit-identical to [`scalar::stage_row`].
#[inline(always)]
pub fn stage_row(out: &mut [f64], y: &[f64], h: f64, w: &[f64], k: &[&[f64]]) {
    if out.len() >= LANES_WIDE {
        stage_row_w::<LANES_WIDE>(out, y, h, w, k);
    } else {
        stage_row_w::<LANES>(out, y, h, w, k);
    }
}

#[inline(always)]
fn stage_row_w<const W: usize>(out: &mut [f64], y: &[f64], h: f64, w: &[f64], k: &[&[f64]]) {
    let n = out.len();
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(w.len(), k.len());
    match w.len() {
        1 => {
            let (w0, k0) = (w[0], k[0]);
            debug_assert_eq!(k0.len(), n);
            let nb = n / W * W;
            let mut c = 0;
            while c < nb {
                for l in 0..W {
                    out[c + l] = y[c + l] + h * w0 * k0[c + l];
                }
                c += W;
            }
            for i in nb..n {
                out[i] = y[i] + h * w0 * k0[i];
            }
        }
        2 => {
            let (w0, k0) = (w[0], k[0]);
            let (w1, k1) = (w[1], k[1]);
            let nb = n / W * W;
            let mut c = 0;
            while c < nb {
                for l in 0..W {
                    out[c + l] = y[c + l] + h * (w0 * k0[c + l] + w1 * k1[c + l]);
                }
                c += W;
            }
            for i in nb..n {
                out[i] = y[i] + h * (w0 * k0[i] + w1 * k1[i]);
            }
        }
        _ => {
            let nb = n / W * W;
            let mut c = 0;
            while c < nb {
                let mut acc = [0.0f64; W];
                for (j, &wj) in w.iter().enumerate() {
                    let kc = &k[j][c..c + W];
                    for l in 0..W {
                        acc[l] += wj * kc[l];
                    }
                }
                for l in 0..W {
                    out[c + l] = y[c + l] + h * acc[l];
                }
                c += W;
            }
            for i in nb..n {
                let mut acc = 0.0;
                for (j, &wj) in w.iter().enumerate() {
                    acc += wj * k[j][i];
                }
                out[i] = y[i] + h * acc;
            }
        }
    }
}

/// One row of the solution/error combination
/// `out[d] = base[d] + h · acc` (or `h · acc` without a base) where
/// `acc = Σ_j w[j] · k[j][d]` accumulated in `j` order — the exact
/// expression shape of [`scalar::combine_row`] (note: *no* 1-term
/// pre-multiplication; the historical kernel always went through the
/// accumulator, and `(h·w)·k` is not bitwise `h·(w·k)`).
#[inline(always)]
pub fn combine_row(out: &mut [f64], base: Option<&[f64]>, h: f64, w: &[f64], k: &[&[f64]]) {
    if out.len() >= LANES_WIDE {
        combine_row_w::<LANES_WIDE>(out, base, h, w, k);
    } else {
        combine_row_w::<LANES>(out, base, h, w, k);
    }
}

#[inline(always)]
fn combine_row_w<const W: usize>(
    out: &mut [f64],
    base: Option<&[f64]>,
    h: f64,
    w: &[f64],
    k: &[&[f64]],
) {
    let n = out.len();
    debug_assert_eq!(w.len(), k.len());
    let nb = n / W * W;
    let mut c = 0;
    while c < nb {
        let mut acc = [0.0f64; W];
        for (j, &wj) in w.iter().enumerate() {
            let kc = &k[j][c..c + W];
            for l in 0..W {
                acc[l] += wj * kc[l];
            }
        }
        match base {
            Some(y) => {
                for l in 0..W {
                    out[c + l] = y[c + l] + h * acc[l];
                }
            }
            None => {
                for l in 0..W {
                    out[c + l] = h * acc[l];
                }
            }
        }
        c += W;
    }
    for i in nb..n {
        let mut acc = 0.0;
        for (j, &wj) in w.iter().enumerate() {
            acc += wj * k[j][i];
        }
        out[i] = match base {
            Some(y) => y[i] + h * acc,
            None => h * acc,
        };
    }
}

/// The fused attempt tail: solution **and** embedded error in one
/// traversal of the slope rows —
/// `y_new[d] = y[d] + h·Σ bw[j]·bk[j][d]`,
/// `err[d] = h·Σ ew[j]·ek[j][d]` — instead of the historical two
/// separate passes. Per-element arithmetic of each output is unchanged
/// (each keeps its own accumulator in its own coefficient order), so
/// fusing is invisible bitwise; it exists purely so each `k` block is
/// pulled through cache once per attempt tail instead of twice.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub fn combine_pair_row(
    y_new: &mut [f64],
    err: &mut [f64],
    y: &[f64],
    h: f64,
    bw: &[f64],
    bk: &[&[f64]],
    ew: &[f64],
    ek: &[&[f64]],
) {
    if y_new.len() >= LANES_WIDE {
        combine_pair_row_w::<LANES_WIDE>(y_new, err, y, h, bw, bk, ew, ek);
    } else {
        combine_pair_row_w::<LANES>(y_new, err, y, h, bw, bk, ew, ek);
    }
}

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn combine_pair_row_w<const W: usize>(
    y_new: &mut [f64],
    err: &mut [f64],
    y: &[f64],
    h: f64,
    bw: &[f64],
    bk: &[&[f64]],
    ew: &[f64],
    ek: &[&[f64]],
) {
    let n = y_new.len();
    debug_assert_eq!(err.len(), n);
    debug_assert_eq!(y.len(), n);
    let nb = n / W * W;
    let mut c = 0;
    while c < nb {
        let mut acc_b = [0.0f64; W];
        for (j, &wj) in bw.iter().enumerate() {
            let kc = &bk[j][c..c + W];
            for l in 0..W {
                acc_b[l] += wj * kc[l];
            }
        }
        let mut acc_e = [0.0f64; W];
        for (j, &wj) in ew.iter().enumerate() {
            let kc = &ek[j][c..c + W];
            for l in 0..W {
                acc_e[l] += wj * kc[l];
            }
        }
        for l in 0..W {
            y_new[c + l] = y[c + l] + h * acc_b[l];
        }
        for l in 0..W {
            err[c + l] = h * acc_e[l];
        }
        c += W;
    }
    for i in nb..n {
        let mut acc_b = 0.0;
        for (j, &wj) in bw.iter().enumerate() {
            acc_b += wj * bk[j][i];
        }
        let mut acc_e = 0.0;
        for (j, &wj) in ew.iter().enumerate() {
            acc_e += wj * ek[j][i];
        }
        y_new[i] = y[i] + h * acc_b;
        err[i] = h * acc_e;
    }
}

/// Tolerance-scaled sum of squares
/// `Σ_i (err[i] / max(atol + rtol·max(|y0_i|, |y1_i|), MIN_POSITIVE))²`
/// with the deterministic fixed-shape lane-tree reduction described in
/// the module docs. This *is* the arithmetic of the solver's error norm
/// ([`crate::solver::norm::scaled_sumsq`] delegates here); the tree
/// shape depends only on `err.len()` — width-4 tree below 16 elements
/// (including lengths 8–15), width-8 tree from 16 up, sequential-sum
/// degeneration below one 4-block.
#[inline]
pub fn scaled_sumsq(err: &[f64], y0: &[f64], y1: &[f64], atol: f64, rtol: f64) -> f64 {
    if err.len() >= 2 * LANES_WIDE {
        scaled_sumsq_w::<LANES_WIDE>(err, y0, y1, atol, rtol)
    } else {
        scaled_sumsq_w::<LANES>(err, y0, y1, atol, rtol)
    }
}

#[inline(always)]
fn scaled_sumsq_w<const W: usize>(
    err: &[f64],
    y0: &[f64],
    y1: &[f64],
    atol: f64,
    rtol: f64,
) -> f64 {
    let n = err.len();
    debug_assert_eq!(y0.len(), n);
    debug_assert_eq!(y1.len(), n);
    let nb = n / W * W;
    let mut acc = [0.0f64; W];
    let mut c = 0;
    while c < nb {
        for l in 0..W {
            let i = c + l;
            let scale = (atol + rtol * y0[i].abs().max(y1[i].abs())).max(f64::MIN_POSITIVE);
            let r = err[i] / scale;
            acc[l] += r * r;
        }
        c += W;
    }
    let mut total = tree_reduce::<W>(&acc);
    for i in nb..n {
        let scale = (atol + rtol * y0[i].abs().max(y1[i].abs())).max(f64::MIN_POSITIVE);
        let r = err[i] / scale;
        total += r * r;
    }
    total
}

/// One dim-lane of the SoA stage accumulation: over rows `r`,
/// `out[r] = y[r] + dt[r] · Σ_j w[j] · k[j][r]`. Elementwise across the
/// *batch* with a per-row step size — the dim-major mirror of
/// [`stage_row`], same per-element expression shapes (1-/2-term
/// specializations included), so the two layouts are bitwise-identical.
#[inline(always)]
pub fn stage_lanes(out: &mut [f64], y: &[f64], dt: &[f64], w: &[f64], k: &[&[f64]]) {
    let n = out.len();
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(dt.len(), n);
    debug_assert_eq!(w.len(), k.len());
    match w.len() {
        1 => {
            let (w0, k0) = (w[0], k[0]);
            for r in 0..n {
                out[r] = y[r] + dt[r] * w0 * k0[r];
            }
        }
        2 => {
            let (w0, k0) = (w[0], k[0]);
            let (w1, k1) = (w[1], k[1]);
            for r in 0..n {
                out[r] = y[r] + dt[r] * (w0 * k0[r] + w1 * k1[r]);
            }
        }
        _ => {
            for r in 0..n {
                let mut acc = 0.0;
                for (j, &wj) in w.iter().enumerate() {
                    acc += wj * k[j][r];
                }
                out[r] = y[r] + dt[r] * acc;
            }
        }
    }
}

/// One dim-lane of the SoA combination: over rows `r`,
/// `out[r] = base[r] + dt[r] · acc` (or `dt[r] · acc`) with
/// `acc = Σ_j w[j] · k[j][r]` in `j` order — the dim-major mirror of
/// [`combine_row`].
#[inline(always)]
pub fn combine_lanes(out: &mut [f64], base: Option<&[f64]>, dt: &[f64], w: &[f64], k: &[&[f64]]) {
    let n = out.len();
    debug_assert_eq!(dt.len(), n);
    debug_assert_eq!(w.len(), k.len());
    for r in 0..n {
        let mut acc = 0.0;
        for (j, &wj) in w.iter().enumerate() {
            acc += wj * k[j][r];
        }
        out[r] = match base {
            Some(y) => y[r] + dt[r] * acc,
            None => dt[r] * acc,
        };
    }
}

/// The fused attempt tail in dim-major form: one dim-lane of solution
/// and error together (see [`combine_pair_row`]).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub fn combine_pair_lanes(
    y_new: &mut [f64],
    err: &mut [f64],
    y: &[f64],
    dt: &[f64],
    bw: &[f64],
    bk: &[&[f64]],
    ew: &[f64],
    ek: &[&[f64]],
) {
    let n = y_new.len();
    debug_assert_eq!(err.len(), n);
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(dt.len(), n);
    for r in 0..n {
        let mut acc_b = 0.0;
        for (j, &wj) in bw.iter().enumerate() {
            acc_b += wj * bk[j][r];
        }
        let mut acc_e = 0.0;
        for (j, &wj) in ew.iter().enumerate() {
            acc_e += wj * ek[j][r];
        }
        y_new[r] = y[r] + dt[r] * acc_b;
        err[r] = dt[r] * acc_e;
    }
}

/// The straight-line scalar kernels the lane-blocked versions replaced,
/// preserved **verbatim** as the parity oracle
/// (`tests/kernel_parity.rs` asserts bitwise agreement element by
/// element) and as the baseline of the dim-sweep benchmark
/// (`benches/solver_micro.rs -- dimsweep`, `speedup_vs_scalar` in
/// `BENCH_solver.json`). Do not optimize these; their value is that
/// they do not change.
pub mod scalar {
    /// Scalar stage accumulation — the pre-lane-blocking kernel body.
    pub fn stage_row(out: &mut [f64], y: &[f64], h: f64, w: &[f64], k: &[&[f64]]) {
        let dim = out.len();
        match w.len() {
            1 => {
                let (w0, k0) = (w[0], k[0]);
                for d in 0..dim {
                    out[d] = y[d] + h * w0 * k0[d];
                }
            }
            2 => {
                let (w0, k0) = (w[0], k[0]);
                let (w1, k1) = (w[1], k[1]);
                for d in 0..dim {
                    out[d] = y[d] + h * (w0 * k0[d] + w1 * k1[d]);
                }
            }
            _ => {
                for d in 0..dim {
                    let mut acc = 0.0;
                    for (j, &wj) in w.iter().enumerate() {
                        acc += wj * k[j][d];
                    }
                    out[d] = y[d] + h * acc;
                }
            }
        }
    }

    /// Scalar solution/error combination — the pre-lane-blocking kernel
    /// body (always through the accumulator, no term-count shortcuts).
    pub fn combine_row(out: &mut [f64], base: Option<&[f64]>, h: f64, w: &[f64], k: &[&[f64]]) {
        let dim = out.len();
        for d in 0..dim {
            let mut acc = 0.0;
            for (j, &wj) in w.iter().enumerate() {
                acc += wj * k[j][d];
            }
            out[d] = match base {
                Some(y) => y[d] + h * acc,
                None => h * acc,
            };
        }
    }

    /// Scalar sequential tolerance-scaled sum of squares — the
    /// pre-lane-tree reduction (loop-carried accumulator in element
    /// order).
    pub fn scaled_sumsq(err: &[f64], y0: &[f64], y1: &[f64], atol: f64, rtol: f64) -> f64 {
        let mut acc = 0.0;
        for i in 0..err.len() {
            let scale = (atol + rtol * y0[i].abs().max(y1[i].abs())).max(f64::MIN_POSITIVE);
            let r = err[i] / scale;
            acc += r * r;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random fill (no external RNG in unit tests).
    fn fill(seed: u64, n: usize) -> Vec<f64> {
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s >> 11) as f64 / (1u64 << 53) as f64 * 4.0 - 2.0
            })
            .collect()
    }

    /// Lane-blocked elementwise kernels are bitwise-identical to the
    /// preserved scalar bodies across odd and wide dims and term counts.
    #[test]
    fn lane_kernels_match_scalar_bitwise() {
        for &dim in &[1usize, 2, 3, 4, 5, 7, 8, 13, 16, 31, 64] {
            for &terms in &[1usize, 2, 3, 6] {
                let y = fill(dim as u64 * 31 + terms as u64, dim);
                let kdata: Vec<Vec<f64>> =
                    (0..terms).map(|j| fill(1000 + j as u64 * 7 + dim as u64, dim)).collect();
                let k: Vec<&[f64]> = kdata.iter().map(|v| v.as_slice()).collect();
                let w: Vec<f64> = (0..terms).map(|j| 0.37 * (j as f64 + 1.0) - 0.5).collect();
                let h = 0.0123;

                let mut a = vec![0.0; dim];
                let mut b = vec![0.0; dim];
                stage_row(&mut a, &y, h, &w, &k);
                scalar::stage_row(&mut b, &y, h, &w, &k);
                for d in 0..dim {
                    assert_eq!(a[d].to_bits(), b[d].to_bits(), "stage dim={dim} terms={terms}");
                }

                combine_row(&mut a, Some(&y), h, &w, &k);
                scalar::combine_row(&mut b, Some(&y), h, &w, &k);
                for d in 0..dim {
                    assert_eq!(a[d].to_bits(), b[d].to_bits(), "combine dim={dim}");
                }
                combine_row(&mut a, None, h, &w, &k);
                scalar::combine_row(&mut b, None, h, &w, &k);
                for d in 0..dim {
                    assert_eq!(a[d].to_bits(), b[d].to_bits(), "combine-nobase dim={dim}");
                }
            }
        }
    }

    /// The fused pair pass equals two independent combine passes.
    #[test]
    fn fused_pair_matches_two_passes() {
        for &dim in &[1usize, 3, 5, 8, 13, 64] {
            let y = fill(dim as u64, dim);
            let kdata: Vec<Vec<f64>> = (0..7).map(|j| fill(j as u64 * 13 + 5, dim)).collect();
            let k: Vec<&[f64]> = kdata.iter().map(|v| v.as_slice()).collect();
            let bw = [0.1, 0.2, 0.3, 0.15, 0.25];
            let bk = [k[0], k[2], k[3], k[4], k[5]];
            let ew = [0.01, -0.02, 0.005];
            let ek = [k[1], k[4], k[6]];
            let h = 0.077;

            let mut yn = vec![0.0; dim];
            let mut er = vec![0.0; dim];
            combine_pair_row(&mut yn, &mut er, &y, h, &bw, &bk, &ew, &ek);

            let mut yn2 = vec![0.0; dim];
            let mut er2 = vec![0.0; dim];
            scalar::combine_row(&mut yn2, Some(&y), h, &bw, &bk);
            scalar::combine_row(&mut er2, None, h, &ew, &ek);
            for d in 0..dim {
                assert_eq!(yn[d].to_bits(), yn2[d].to_bits(), "y_new dim={dim}");
                assert_eq!(er[d].to_bits(), er2[d].to_bits(), "err dim={dim}");
            }
        }
    }

    /// Dim-major lanes with a broadcast dt equal the row-major kernels
    /// element by element (the layout-parity property).
    #[test]
    fn lanes_match_rows_bitwise() {
        let n = 13;
        let y = fill(3, n);
        let kdata: Vec<Vec<f64>> = (0..3).map(|j| fill(50 + j as u64, n)).collect();
        let k: Vec<&[f64]> = kdata.iter().map(|v| v.as_slice()).collect();
        let w = [0.4, -0.7, 1.3];
        let h = 0.031;
        let dt = vec![h; n];

        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        stage_lanes(&mut a, &y, &dt, &w, &k);
        stage_row(&mut b, &y, h, &w, &k);
        for i in 0..n {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "stage lane {i}");
        }
        combine_lanes(&mut a, Some(&y), &dt, &w, &k);
        combine_row(&mut b, Some(&y), h, &w, &k);
        for i in 0..n {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "combine lane {i}");
        }
        let mut er_a = vec![0.0; n];
        let mut er_b = vec![0.0; n];
        combine_pair_lanes(&mut a, &mut er_a, &y, &dt, &w, &k, &w[..2], &k[..2]);
        combine_pair_row(&mut b, &mut er_b, &y, h, &w, &k, &w[..2], &k[..2]);
        for i in 0..n {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "pair y_new lane {i}");
            assert_eq!(er_a[i].to_bits(), er_b[i].to_bits(), "pair err lane {i}");
        }
    }

    /// The lane-tree sum of squares: degenerates to the sequential sum
    /// for short rows, and has a fixed shape (same bits whatever buffer
    /// the row lives in).
    #[test]
    fn sumsq_tree_properties() {
        // Short rows: bitwise the historical sequential reduction.
        for &dim in &[1usize, 2, 3] {
            let e = fill(7 + dim as u64, dim);
            let y0 = fill(8, dim);
            let y1 = fill(9, dim);
            let a = scaled_sumsq(&e, &y0, &y1, 1e-8, 1e-5);
            let b = scalar::scaled_sumsq(&e, &y0, &y1, 1e-8, 1e-5);
            assert_eq!(a.to_bits(), b.to_bits(), "dim={dim}");
        }
        // Position independence: identical row data => identical bits.
        for &dim in &[5usize, 16, 64] {
            let e = fill(100, dim);
            let y0 = fill(101, dim);
            let y1 = fill(102, dim);
            let a = scaled_sumsq(&e, &y0, &y1, 1e-8, 1e-5);
            let e2 = e.clone();
            let b = scaled_sumsq(&e2, &y0, &y1, 1e-8, 1e-5);
            assert_eq!(a.to_bits(), b.to_bits());
            // And it agrees with the scalar reduction to rounding noise.
            let s = scalar::scaled_sumsq(&e, &y0, &y1, 1e-8, 1e-5);
            assert!((a - s).abs() <= 1e-12 * s.abs().max(1.0), "dim={dim}: {a} vs {s}");
        }
        // The zero-scale floor carries over: exact steps score 0.
        assert_eq!(scaled_sumsq(&[0.0; 9], &[0.0; 9], &[0.0; 9], 0.0, 1e-6), 0.0);
    }
}
