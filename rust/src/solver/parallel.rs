//! The parallel solve loop — torchode's contribution.
//!
//! Every instance carries its own time, step size, controller history,
//! accept/reject decision, dense-output cursor and status. The dynamics
//! are still evaluated in one batched call per stage (with "overhanging"
//! evaluations for already-finished instances, unless
//! [`super::SolveOptions::eval_inactive`] is disabled), so a batch never
//! forces instances to share a step size — the failure mode of §4.1.
//!
//! ## Active set and compaction
//!
//! The loop is organized around a packed [`ActiveSet`]: an incrementally
//! maintained index list of unfinished rows. The clamp, controller,
//! dense-output and commit passes iterate only the live indices, and the
//! stage kernel ([`rk_attempt_active`]) evaluates the dynamics through
//! [`OdeSystem::f_rows_indexed`], so with `eval_inactive = false` a
//! finished row costs **zero** per-row work — no mask checks, no
//! keep-alive copies, no overhanging model evaluations. With
//! `eval_inactive = true` (torchode's exact semantics) finished rows keep
//! receiving the overhanging evaluations for as long as they stay
//! materialized.
//!
//! When the live fraction drops below
//! [`super::SolveOptions::compact_threshold`], the per-row solver state
//! (y, k\[..\], ytmp/y_new/err, t, dt, controller history, dense-output
//! cursors) is **compacted** into a dense prefix via in-place gathers so
//! the stage passes stay cache-dense; the [`ActiveSet`]'s slot → row map
//! keeps solution buffers, grids and tolerances on their original
//! indexing. Compaction moves state without changing any live row's
//! values, so trajectories, stats and statuses are bitwise-identical with
//! compaction on or off (`tests/compaction.rs` asserts this against the
//! frozen pre-active-set loop in [`super::reference`]). Its one semantic
//! effect: under `eval_inactive = true`, compacted-away rows stop
//! receiving overhanging evaluations (their results were discarded
//! anyway, and `n_f_evals` counts semantic batched calls, which are
//! unchanged).
//!
//! The loop is written so that the per-row state machine depends only on
//! that row's data: [`crate::exec::solve_ivp_parallel_pooled`] runs this
//! exact code over contiguous row ranges on a worker pool — one static
//! shard per worker (scoped pool) or many small work-stealing chunks
//! (persistent pool) — and merges the results bitwise-identically
//! whatever the partition. The [`CallLedger`] records the batched
//! dynamics calls per loop iteration so the merge can reconstruct
//! torchode's uniform `n_f_evals` accounting across ranges: each
//! iteration's entry is a per-row property (stage calls, plus the
//! non-FSAL refresh iff any row accepted), so the per-iteration max over
//! any partition equals the serial loop's count.

use super::active::ActiveSet;
use super::controller::ControllerState;
use super::implicit;
use super::init::initial_step_batch;
use super::interp::{self, DOPRI5_NCOEFF};
use super::norm::{scaled_norm, NormKind};
use super::step::{rk_attempt_active, CompiledTableau, RkWorkspace, MAX_STAGES};
use super::tableau::DenseOutput;
use super::{SolveOptions, Solution, Status, TimeGrid};
use crate::problems::OdeSystem;
use crate::tensor::BatchVec;

/// Batched-call ledger of one (shard-)solve: `n_f_evals` is uniform
/// across a torchode batch ("every instance experiences every call"), so
/// when a batch is split into shards the merged count is
/// `base + Σ_iter max over shards` — the calls the *global* loop would
/// have made. See `crate::exec::merge_sharded`.
#[derive(Debug, Clone, Default)]
pub(crate) struct CallLedger {
    /// Calls made before the main loop (initial slopes, dt0 heuristic).
    pub base: u64,
    /// Batched calls made during each main-loop iteration.
    pub per_iter: Vec<u64>,
}

/// Upper bound on the up-front `per_iter` reservation: enough that any
/// realistic solve records its ledger without a mid-loop reallocation
/// (the zero-allocation steady state), without committing megabytes when
/// `max_steps` is set astronomically.
const LEDGER_RESERVE: usize = 65_536;

/// Solve a batch of independent IVPs with fully per-instance solver state.
///
/// `y0` is `(batch, dim)`; `grid.row(i)` holds instance `i`'s evaluation
/// times (ascending; integration runs over `[grid.t0(i), grid.t1(i)]`).
pub fn solve_ivp_parallel(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> Solution {
    solve_ivp_parallel_core(sys, y0, grid, opts).0
}

/// The loop body shared by the serial entry point and the exec layer's
/// shard workers (which call it on row-range views with an offset
/// system). Within this function "row" means a row of the view it was
/// handed; after compaction the state buffers are indexed by *slot* and
/// the [`ActiveSet`] maps slots back to rows.
pub(crate) fn solve_ivp_parallel_core(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> (Solution, CallLedger) {
    let batch = y0.batch();
    let dim = y0.dim();
    assert_eq!(grid.batch(), batch, "grid/initial-state batch mismatch");
    assert_eq!(sys.dim(), dim, "system/initial-state dim mismatch");
    opts.tols.validate(batch);
    let n_eval = grid.n_eval();
    let tab = opts.method.tableau();
    let ct = CompiledTableau::cached(opts.method);
    let adaptive = tab.adaptive() && opts.fixed_dt.is_none();

    let mut sol = Solution::new_buffer(batch, n_eval, dim);
    let mut ledger = CallLedger::default();
    ledger.per_iter.reserve(opts.max_steps.min(LEDGER_RESERVE));
    let mut trace: Vec<Vec<(f64, f64)>> = if opts.record_trace {
        vec![Vec::new(); batch]
    } else {
        Vec::new()
    };

    // --- per-slot state (slot == row until the first compaction) ----------
    let mut y = y0.clone();
    let mut t: Vec<f64> = (0..batch).map(|i| grid.t0(i)).collect();
    let mut finished = vec![false; batch];
    let mut k0_ready = vec![false; batch];
    let mut ctrl = vec![ControllerState::default(); batch];
    let mut next_eval = vec![0usize; batch];
    let span: Vec<f64> = (0..batch).map(|i| grid.t1(i) - grid.t0(i)).collect();

    let jac = opts.jac_structure.unwrap_or_else(|| sys.jac_structure());
    let mut ws = RkWorkspace::new_for_tableau(ct, batch, dim, opts.layout, &opts.tols, jac);
    // Previous-step slopes for Hermite interpolation (f at step start).
    let mut f_start = BatchVec::zeros(batch, dim);
    let mut interp_coeffs = vec![0.0; DOPRI5_NCOEFF * dim];

    // First eval point == t0: emit y0 directly.
    for i in 0..batch {
        sol.y_mut(i, 0).copy_from_slice(y.row(i));
        sol.stats[i].n_initialized += 1;
        next_eval[i] = 1;
        if n_eval == 1 || span[i] <= 0.0 {
            finished[i] = true;
            sol.status[i] = Status::Success;
        }
    }

    // Initial slopes f(t0, y0): one batched call.
    sys.f_batch(&t, &y, &mut ws.k[0], None);
    let mut n_f_evals: u64 = 1;
    ledger.base += 1;
    f_start.copy_from(&ws.k[0]);
    for r in k0_ready.iter_mut() {
        *r = true;
    }

    // Initial step sizes.
    let mut dt: Vec<f64> = match (opts.fixed_dt, opts.dt0) {
        (Some(h), _) => vec![h; batch],
        (None, Some(h)) => vec![h; batch],
        (None, None) => {
            let dt0 = initial_step_batch(
                sys,
                &t,
                &y,
                &ws.k[0],
                tab.order,
                &opts.tols,
                &span,
                &mut ws.ytmp,
                &mut ws.y_new,
            );
            n_f_evals += 1;
            ledger.base += 1;
            dt0
        }
    };

    let mut min_dt: Vec<f64> = span.iter().map(|s| s.abs() * opts.min_dt_rel).collect();

    let mut act = ActiveSet::new(batch);
    act.retain(&finished);

    // --- main loop ---------------------------------------------------------
    // Per-iteration buffers hoisted out of the loop; together with the
    // workspace scratch this makes the steady state allocation-free
    // (`tests/alloc_regression.rs`).
    let mut clamped = vec![false; batch];
    let mut accepted = vec![false; batch];
    let mut factor = vec![1.0f64; batch];
    let mut t_new = vec![0.0f64; batch];
    let mut accepted_slots: Vec<usize> = Vec::with_capacity(batch);
    let mut iter = 0usize;
    while !act.is_empty() {
        iter += 1;
        if iter > opts.max_steps {
            for &r in act.live() {
                sol.status[act.inst(r)] = Status::MaxStepsReached;
                finished[r] = true;
            }
            break;
        }

        // Clamp step to the remaining span; remember who was clamped so the
        // final time is hit exactly.
        for &r in act.live() {
            clamped[r] = false;
            let remaining = grid.t1(act.inst(r)) - t[r];
            if dt[r] >= remaining {
                dt[r] = remaining;
                clamped[r] = true;
            }
        }
        let mut calls = rk_attempt_active(
            ct,
            sys,
            &act,
            &finished,
            &t,
            &dt,
            &y,
            &mut ws,
            &k0_ready,
            opts.eval_inactive,
        );

        // Pass 1: non-finite guards and controller decisions.
        accepted_slots.clear();
        for &r in act.live() {
            accepted[r] = false;
            let g = act.inst(r);
            sol.stats[g].n_steps += 1;

            // Implicit methods: fold this attempt's per-row Newton work
            // into the row's stats, and route a Newton divergence into
            // the rejection path — an adaptive row shrinks hard and
            // retries (pass 2's min-dt safeguard turns a never-recovering
            // Newton into DtUnderflow); a fixed-step row fails outright
            // below.
            if let Some(nw) = ws.newton.as_mut() {
                let (fe, je, lu) = nw.take_work(r);
                sol.stats[g].n_f_evals += fe;
                sol.stats[g].n_jac_evals += je;
                sol.stats[g].n_lu_factor += lu;
                if !nw.newton_ok(r) {
                    if adaptive {
                        factor[r] = implicit::NEWTON_REJECT_FACTOR;
                        continue;
                    }
                    // A fixed step that cannot be solved is a hard
                    // failure: with no controller to re-grow dt,
                    // silently shrinking would integrate a different
                    // grid than the one requested.
                    sol.status[g] = Status::NewtonDiverged;
                    finished[r] = true;
                    continue;
                }
            }

            let y_new = ws.y_new.row(r);
            if y_new.iter().any(|v| !v.is_finite()) {
                sol.status[g] = Status::NonFinite;
                finished[r] = true;
                continue;
            }

            let (accept, fac) = if adaptive {
                let en = scaled_norm(
                    NormKind::Rms,
                    ws.err.row(r),
                    y.row(r),
                    y_new,
                    opts.tols.atol(g),
                    opts.tols.rtol(g),
                );
                let d = opts.controller.decide(en, tab.err_order, &ctrl[r]);
                if d.accept {
                    ctrl[r].push(en);
                }
                (d.accept, d.factor)
            } else {
                (true, 1.0)
            };
            accepted[r] = accept;
            factor[r] = fac;
            if accept {
                t_new[r] = if clamped[r] { grid.t1(g) } else { t[r] + dt[r] };
                accepted_slots.push(r);
            }
        }

        // Non-FSAL: evaluate the true end slope f(t_new, y_new) for the
        // accepted rows *before* dense output, so Hermite interpolation
        // uses the step-end derivative (3rd order) instead of the stale
        // step-start slope — this is also the cold-row k[0] refresh for
        // the next iteration, so it costs no extra call.
        if !tab.fsal && !accepted_slots.is_empty() {
            for &r in &accepted_slots {
                ws.t_stage[r] = t_new[r];
            }
            sys.f_rows_indexed(
                0,
                act.inst_map(),
                &accepted_slots,
                &ws.t_stage,
                ws.y_new.flat(),
                ws.k[0].flat_mut(),
            );
            calls += 1;
        }

        // Pass 2: dense output, state commit, step-size update.
        for &r in act.live() {
            if finished[r] {
                continue; // went non-finite in pass 1
            }
            let g = act.inst(r);
            if accepted[r] {
                sol.stats[g].n_accepted += 1;
                let tn = t_new[r];
                if opts.record_trace {
                    trace[g].push((t[r], dt[r]));
                }

                // Dense output: fill every eval point in (t, t_new].
                let h = dt[r];
                if next_eval[r] < n_eval {
                    let te_row = grid.row(g);
                    let mut e = next_eval[r];
                    let mut coeffs_ready = false;
                    while e < n_eval && te_row[e] <= tn {
                        let theta = ((te_row[e] - t[r]) / h).clamp(0.0, 1.0);
                        match tab.dense {
                            DenseOutput::Dopri5 => {
                                if !coeffs_ready {
                                    let mut krows: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
                                    for (slot, k) in krows.iter_mut().zip(ws.k.iter()) {
                                        *slot = k.row(r);
                                    }
                                    interp::dopri5_coeffs(
                                        h,
                                        y.row(r),
                                        ws.y_new.row(r),
                                        &krows[..tab.stages],
                                        &mut interp_coeffs,
                                    );
                                    coeffs_ready = true;
                                }
                                interp::dopri5_eval(theta, &interp_coeffs, sol.y_mut(g, e));
                            }
                            DenseOutput::Hermite => {
                                // f at the step end: the FSAL stage, or the
                                // refreshed k[0] = f(t_new, y_new) computed
                                // above for non-FSAL methods.
                                let f_end = if tab.fsal {
                                    ws.k[tab.stages - 1].row(r)
                                } else {
                                    ws.k[0].row(r)
                                };
                                interp::hermite_eval(
                                    theta,
                                    h,
                                    y.row(r),
                                    f_start.row(r),
                                    ws.y_new.row(r),
                                    f_end,
                                    sol.y_mut(g, e),
                                );
                            }
                        }
                        sol.stats[g].n_initialized += 1;
                        e += 1;
                    }
                    next_eval[r] = e;
                }

                // Commit the step.
                y.row_mut(r).copy_from_slice(ws.y_new.row(r));
                t[r] = tn;
                if tab.fsal {
                    // k[last] is f(t_new, y_new): becomes next k[0].
                    let (head, tail) = ws.k.split_at_mut(tab.stages - 1);
                    let (first, _) = head.split_first_mut().unwrap();
                    first.row_mut(r).copy_from_slice(tail[0].row(r));
                    f_start.row_mut(r).copy_from_slice(tail[0].row(r));
                } else {
                    // k[0] already holds f(t_new, y_new) from the refresh.
                    f_start.row_mut(r).copy_from_slice(ws.k[0].row(r));
                }
                k0_ready[r] = true;

                if next_eval[r] >= n_eval {
                    sol.status[g] = Status::Success;
                    finished[r] = true;
                }
            } else {
                // Rejected: same (t, y), so k[0] stays valid for any method
                // that already computed it.
                k0_ready[r] = true;
            }

            // Rows that finished this iteration keep their dt and
            // controller state frozen: a dead slot's bookkeeping must
            // never change once it can be compacted away.
            if !finished[r] {
                dt[r] *= factor[r];
                if adaptive && dt[r] < min_dt[r] {
                    sol.status[g] = Status::DtUnderflow;
                    finished[r] = true;
                }
            }
        }

        ledger.per_iter.push(calls);
        n_f_evals += calls;

        // Retire finished slots; compact the state once the live fraction
        // drops below the configured threshold.
        act.retain(&finished);
        if act.should_compact(opts.compact_threshold) {
            compact_state(
                &mut act,
                dim,
                &mut y,
                &mut f_start,
                &mut ws,
                &mut t,
                &mut dt,
                &mut min_dt,
                &mut k0_ready,
                &mut finished,
                &mut ctrl,
                &mut next_eval,
            );
        }
    }

    // torchode semantics: every instance experiences every batched call.
    for s in sol.stats.iter_mut() {
        s.n_f_evals += n_f_evals;
    }

    if opts.record_trace {
        sol.trace = Some(trace);
    }
    (sol, ledger)
}

/// Gather every piece of per-slot solver state into the dense prefix the
/// [`ActiveSet`] prescribes. Pure in-place row moves (`dst <= src`), no
/// allocation, no value changes — only storage locations change.
#[allow(clippy::too_many_arguments)]
fn compact_state(
    act: &mut ActiveSet,
    dim: usize,
    y: &mut BatchVec,
    f_start: &mut BatchVec,
    ws: &mut RkWorkspace,
    t: &mut [f64],
    dt: &mut [f64],
    min_dt: &mut [f64],
    k0_ready: &mut [bool],
    finished: &mut [bool],
    ctrl: &mut [ControllerState],
    next_eval: &mut [usize],
) {
    act.compact_with(|dst, src| {
        let move_rows = |b: &mut BatchVec| {
            b.flat_mut().copy_within(src * dim..(src + 1) * dim, dst * dim);
        };
        move_rows(y);
        move_rows(f_start);
        for k in ws.k.iter_mut() {
            move_rows(k);
        }
        move_rows(&mut ws.ytmp);
        move_rows(&mut ws.y_new);
        move_rows(&mut ws.err);
        t[dst] = t[src];
        dt[dst] = dt[src];
        min_dt[dst] = min_dt[src];
        k0_ready[dst] = k0_ready[src];
        finished[dst] = finished[src];
        ctrl[dst] = ctrl[src];
        next_eval[dst] = next_eval[src];
        // Implicit methods: the per-slot Jacobian/LU reuse state moves
        // with the row, so compaction stays value-invariant.
        if let Some(nw) = ws.newton.as_mut() {
            nw.compact_move(dst, src);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, LinearSystem, LotkaVolterra, VdP};
    use crate::solver::MethodId;

    #[test]
    fn exponential_decay_accuracy() {
        let sys = ExponentialDecay::new(vec![1.0], 2);
        let y0 = BatchVec::from_rows(&[vec![1.0, -2.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 2.0, 21);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        for e in 0..21 {
            let t = grid.row(0)[e];
            let exact = (-t).exp();
            assert!((sol.y(0, e)[0] - exact).abs() < 1e-6, "e={e}");
            assert!((sol.y(0, e)[1] + 2.0 * exact).abs() < 1e-6, "e={e}");
        }
    }

    #[test]
    fn damped_rotation_accuracy_all_adaptive_methods() {
        let (decay, omega) = (0.2, 3.0);
        let sys = LinearSystem::damped_rotation(decay, omega);
        let y0 = BatchVec::from_rows(&[vec![1.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 3.0, 7);
        for m in [
            MethodId::HEUN,
            MethodId::BOSH3,
            MethodId::FEHLBERG45,
            MethodId::CASHKARP45,
            MethodId::DOPRI5,
            MethodId::TSIT5,
        ] {
            let opts = SolveOptions::new(m).with_tols(1e-7, 1e-7).with_max_steps(100_000);
            let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
            assert!(sol.all_success(), "{m:?}: {:?}", sol.status);
            let mut exact = [0.0; 2];
            LinearSystem::damped_rotation_exact(decay, omega, &[1.0, 0.0], 3.0, &mut exact);
            let got = sol.y_final(0);
            for d in 0..2 {
                assert!(
                    (got[d] - exact[d]).abs() < 1e-4,
                    "{m:?}: {got:?} vs {exact:?}"
                );
            }
        }
    }

    #[test]
    fn fixed_step_methods_converge() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::from_rows(&[vec![1.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 1.0, 2);
        for (m, tol) in
            [(MethodId::EULER, 5e-3), (MethodId::MIDPOINT, 1e-4), (MethodId::RK4, 1e-8)]
        {
            let opts = SolveOptions::new(m).with_fixed_dt(1e-3).with_max_steps(10_000);
            let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
            assert!(sol.all_success(), "{m:?}");
            let err = (sol.y_final(0)[0] - (-1.0f64).exp()).abs();
            assert!(err < tol, "{m:?}: err {err}");
        }
    }

    #[test]
    fn per_instance_integration_ranges() {
        // Instance 0: [0, 1]; instance 1: [5, 7] — no special handling.
        let sys = ExponentialDecay::new(vec![1.0, 0.5], 1);
        let y0 = BatchVec::from_rows(&[vec![1.0], vec![2.0]]);
        let grid = TimeGrid::from_rows(&[
            (0..11).map(|k| k as f64 / 10.0).collect(),
            (0..11).map(|k| 5.0 + 2.0 * k as f64 / 10.0).collect(),
        ]);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        assert!((sol.y_final(0)[0] - (-1.0f64).exp()).abs() < 1e-6);
        assert!((sol.y_final(1)[0] - 2.0 * (-0.5f64 * 2.0).exp()).abs() < 1e-6);
    }

    #[test]
    fn stats_are_consistent() {
        let sys = VdP::new(vec![2.0, 25.0]);
        let y0 = BatchVec::from_rows(&[vec![2.0, 0.0], vec![2.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(2, 0.0, 10.0, 50);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        for st in &sol.stats {
            assert!(st.n_accepted <= st.n_steps);
            assert_eq!(st.n_initialized, 50);
            assert!(st.n_f_evals > st.n_steps);
        }
        // n_f_evals is uniform across the batch (torchode semantics).
        assert_eq!(sol.stats[0].n_f_evals, sol.stats[1].n_f_evals);
        // The stiff instance needs more steps.
        assert!(sol.stats[1].n_steps > sol.stats[0].n_steps);
    }

    #[test]
    fn dense_output_matches_tight_solve() {
        // Solve once with 5 eval points and once with 41; shared points must
        // agree to interpolation accuracy.
        let sys = LotkaVolterra::uniform(1, 1.1, 0.4, 0.1, 0.4);
        let y0 = BatchVec::from_rows(&[vec![2.0, 1.0]]);
        let coarse = TimeGrid::linspace_shared(1, 0.0, 8.0, 5);
        let fine = TimeGrid::linspace_shared(1, 0.0, 8.0, 41);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-9, 1e-9);
        let sc = solve_ivp_parallel(&sys, &y0, &coarse, &opts);
        let sf = solve_ivp_parallel(&sys, &y0, &fine, &opts);
        assert!(sc.all_success() && sf.all_success());
        for e in 0..5 {
            let yc = sc.y(0, e);
            let yf = sf.y(0, e * 10);
            for d in 0..2 {
                assert!((yc[d] - yf[d]).abs() < 1e-6, "e={e} d={d}: {} vs {}", yc[d], yf[d]);
            }
        }
    }

    /// Non-FSAL Hermite dense output must use the true end slope
    /// f(t_new, y_new): with the stale step-start slope (the old bug) the
    /// mid-step error of rk4 at dt = 0.1 is ~1e-3; with the fix it is the
    /// cubic-Hermite O(h^4) bound, orders of magnitude below.
    #[test]
    fn hermite_dense_output_uses_end_slope() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::from_rows(&[vec![1.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 1.0, 41);
        let opts = SolveOptions::new(MethodId::RK4).with_fixed_dt(0.1).with_max_steps(1_000);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        let mut max_err = 0.0f64;
        for e in 0..41 {
            let t = grid.row(0)[e];
            max_err = max_err.max((sol.y(0, e)[0] - (-t).exp()).abs());
        }
        assert!(max_err < 1e-5, "dense-output error {max_err} (stale end slope?)");
    }

    #[test]
    fn max_steps_reported() {
        let sys = VdP::new(vec![1000.0]); // very stiff
        let y0 = BatchVec::from_rows(&[vec![2.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 100.0, 10);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8).with_max_steps(50);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert_eq!(sol.status[0], Status::MaxStepsReached);
    }

    #[test]
    fn batch_of_identical_problems_identical_answers() {
        let b = 8;
        let sys = VdP::uniform(b, 2.0);
        let y0 = BatchVec::broadcast(&[1.0, 0.5], b);
        let grid = TimeGrid::linspace_shared(b, 0.0, 5.0, 10);
        let opts = SolveOptions::new(MethodId::TSIT5).with_tols(1e-6, 1e-6);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        for i in 1..b {
            assert_eq!(sol.stats[i], sol.stats[0]);
            for e in 0..10 {
                assert_eq!(sol.y(i, e), sol.y(0, e));
            }
        }
    }

    #[test]
    fn heterogeneous_batch_isolated() {
        // A very stiff instance must not change the easy instance's answer
        // beyond tolerance (bitwise isolation isn't required because the
        // controller is per-instance anyway; check solution agreement
        // against a solo solve).
        let easy_solo = {
            let sys = VdP::new(vec![0.5]);
            let y0 = BatchVec::from_rows(&[vec![1.0, 0.0]]);
            let grid = TimeGrid::linspace_shared(1, 0.0, 5.0, 10);
            let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-7, 1e-7);
            solve_ivp_parallel(&sys, &y0, &grid, &opts)
        };
        let mixed = {
            let sys = VdP::new(vec![0.5, 40.0]);
            let y0 = BatchVec::from_rows(&[vec![1.0, 0.0], vec![2.0, 0.0]]);
            let grid = TimeGrid::linspace_shared(2, 0.0, 5.0, 10);
            let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-7, 1e-7);
            solve_ivp_parallel(&sys, &y0, &grid, &opts)
        };
        assert!(mixed.all_success());
        // Identical per-instance state machine => identical trajectory.
        for e in 0..10 {
            for d in 0..2 {
                assert_eq!(mixed.y(0, e)[d], easy_solo.y(0, e)[d]);
            }
        }
        assert_eq!(mixed.stats[0].n_steps, easy_solo.stats[0].n_steps);
    }

    #[test]
    fn trace_recorded_when_requested() {
        let sys = VdP::new(vec![5.0]);
        let y0 = BatchVec::from_rows(&[vec![2.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 10.0, 5);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5).with_trace();
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        let trace = sol.trace.as_ref().unwrap();
        assert_eq!(trace[0].len() as u64, sol.stats[0].n_accepted);
        // Times strictly increasing, dts positive.
        for w in trace[0].windows(2) {
            assert!(w[1].0 > w[0].0);
        }
        assert!(trace[0].iter().all(|&(_, dt)| dt > 0.0));
    }

    #[test]
    fn convergence_order_dopri5() {
        // Global error should scale ~dt^5 with fixed steps.
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::from_rows(&[vec![1.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 1.0, 2);
        let mut errs = Vec::new();
        for &h in &[0.1, 0.05] {
            let opts = SolveOptions::new(MethodId::DOPRI5).with_fixed_dt(h);
            let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
            errs.push((sol.y_final(0)[0] - (-1.0f64).exp()).abs());
        }
        let order = (errs[0] / errs[1]).log2();
        assert!(order > 4.5, "measured order {order}");
    }

    /// The ledger records the loop's call pattern: FSAL adaptive methods
    /// make stages-1 calls per iteration; non-FSAL methods add the
    /// end-slope refresh on iterations with an accepted row.
    #[test]
    fn call_ledger_matches_stats() {
        let sys = VdP::new(vec![2.0]);
        let y0 = BatchVec::from_rows(&[vec![2.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 5.0, 10);
        for m in [MethodId::DOPRI5, MethodId::FEHLBERG45] {
            let opts = SolveOptions::new(m).with_tols(1e-6, 1e-6).with_max_steps(100_000);
            let (sol, ledger) = solve_ivp_parallel_core(&sys, &y0, &grid, &opts);
            assert!(sol.all_success());
            let total: u64 = ledger.base + ledger.per_iter.iter().sum::<u64>();
            assert_eq!(total, sol.stats[0].n_f_evals, "{m:?}");
            assert_eq!(ledger.per_iter.len() as u64, sol.stats[0].n_steps, "{m:?}");
        }
    }

    /// The ledger (and therefore the pooled merge's `n_f_evals`) is
    /// unchanged by compaction: calls are counted per semantic batched
    /// call, not per materialized row.
    #[test]
    fn call_ledger_invariant_under_compaction() {
        let sys = VdP::new(vec![0.5, 30.0, 1.0]);
        let y0 = BatchVec::from_rows(&[vec![1.0, 0.0], vec![2.0, 0.0], vec![1.5, 0.2]]);
        let grid = TimeGrid::linspace_shared(3, 0.0, 5.0, 8);
        let base =
            SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(100_000);
        let (_, plain) = solve_ivp_parallel_core(&sys, &y0, &grid, &base);
        let compacting = base.with_compaction(1.0).skip_inactive();
        let (_, packed) = solve_ivp_parallel_core(&sys, &y0, &grid, &compacting);
        assert_eq!(plain.base, packed.base);
        assert_eq!(plain.per_iter, packed.per_iter);
    }

    #[test]
    #[should_panic(expected = "atol")]
    fn rejects_mismatched_tolerance_vector() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::broadcast(&[1.0], 3);
        let grid = TimeGrid::linspace_shared(3, 0.0, 1.0, 3);
        let mut opts = SolveOptions::new(MethodId::DOPRI5);
        opts.tols = crate::solver::Tolerances::per_instance(vec![1e-6; 2], vec![1e-6; 2]);
        solve_ivp_parallel(&sys, &y0, &grid, &opts);
    }
}
