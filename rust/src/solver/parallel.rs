//! The parallel solve loop — torchode's contribution.
//!
//! Every instance carries its own time, step size, controller history,
//! accept/reject decision, dense-output cursor and status. The dynamics
//! are still evaluated in one batched call per stage (with "overhanging"
//! evaluations for already-finished instances, unless
//! [`super::SolveOptions::eval_inactive`] is disabled), so a batch never
//! forces instances to share a step size — the failure mode of §4.1.
//!
//! The loop is written so that the per-row state machine depends only on
//! that row's data: [`crate::exec::solve_ivp_parallel_pooled`] runs this
//! exact code over contiguous row shards on a worker pool and merges the
//! results bitwise-identically. The [`CallLedger`] records the batched
//! dynamics calls per loop iteration so the merge can reconstruct
//! torchode's uniform `n_f_evals` accounting across shards.

use super::controller::ControllerState;
use super::init::initial_step_batch;
use super::interp::{self, DOPRI5_NCOEFF};
use super::norm::{scaled_norm, NormKind};
use super::step::{rk_attempt, CompiledTableau, RkWorkspace};
use super::tableau::DenseOutput;
use super::{SolveOptions, Solution, Status, TimeGrid};
use crate::problems::OdeSystem;
use crate::tensor::BatchVec;

/// Batched-call ledger of one (shard-)solve: `n_f_evals` is uniform
/// across a torchode batch ("every instance experiences every call"), so
/// when a batch is split into shards the merged count is
/// `base + Σ_iter max over shards` — the calls the *global* loop would
/// have made. See `crate::exec::merge_sharded`.
#[derive(Debug, Clone, Default)]
pub(crate) struct CallLedger {
    /// Calls made before the main loop (initial slopes, dt0 heuristic).
    pub base: u64,
    /// Batched calls made during each main-loop iteration.
    pub per_iter: Vec<u64>,
}

/// Solve a batch of independent IVPs with fully per-instance solver state.
///
/// `y0` is `(batch, dim)`; `grid.row(i)` holds instance `i`'s evaluation
/// times (ascending; integration runs over `[grid.t0(i), grid.t1(i)]`).
pub fn solve_ivp_parallel(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> Solution {
    solve_ivp_parallel_core(sys, y0, grid, opts).0
}

/// The loop body shared by the serial entry point and the exec layer's
/// shard workers (which call it on row-range views with an offset
/// system).
pub(crate) fn solve_ivp_parallel_core(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> (Solution, CallLedger) {
    let batch = y0.batch();
    let dim = y0.dim();
    assert_eq!(grid.batch(), batch, "grid/initial-state batch mismatch");
    assert_eq!(sys.dim(), dim, "system/initial-state dim mismatch");
    opts.tols.validate(batch);
    let n_eval = grid.n_eval();
    let tab = opts.method.tableau();
    let ct = CompiledTableau::new(tab);
    let adaptive = tab.adaptive() && opts.fixed_dt.is_none();

    let mut sol = Solution::new_buffer(batch, n_eval, dim);
    let mut ledger = CallLedger::default();
    let mut trace: Vec<Vec<(f64, f64)>> = if opts.record_trace {
        vec![Vec::new(); batch]
    } else {
        Vec::new()
    };

    // --- per-instance state ------------------------------------------------
    let mut y = y0.clone();
    let mut t: Vec<f64> = (0..batch).map(|i| grid.t0(i)).collect();
    let mut finished = vec![false; batch];
    let mut k0_ready = vec![false; batch];
    let mut ctrl = vec![ControllerState::default(); batch];
    let mut next_eval = vec![0usize; batch];
    let span: Vec<f64> = (0..batch).map(|i| grid.t1(i) - grid.t0(i)).collect();

    let mut ws = RkWorkspace::new(tab.stages, batch, dim);
    // Previous-step slopes for Hermite interpolation (f at step start).
    let mut f_start = BatchVec::zeros(batch, dim);
    let mut interp_coeffs = vec![0.0; DOPRI5_NCOEFF * dim];

    // First eval point == t0: emit y0 directly.
    for i in 0..batch {
        sol.y_mut(i, 0).copy_from_slice(y.row(i));
        sol.stats[i].n_initialized += 1;
        next_eval[i] = 1;
        if n_eval == 1 || span[i] <= 0.0 {
            finished[i] = true;
            sol.status[i] = Status::Success;
        }
    }

    // Initial slopes f(t0, y0): one batched call.
    sys.f_batch(&t, &y, &mut ws.k[0], None);
    for s in sol.stats.iter_mut() {
        s.n_f_evals += 1;
    }
    ledger.base += 1;
    f_start.copy_from(&ws.k[0]);
    for r in k0_ready.iter_mut() {
        *r = true;
    }

    // Initial step sizes.
    let mut dt: Vec<f64> = match (opts.fixed_dt, opts.dt0) {
        (Some(h), _) => vec![h; batch],
        (None, Some(h)) => vec![h; batch],
        (None, None) => {
            let dt0 = initial_step_batch(
                sys,
                &t,
                &y,
                &ws.k[0],
                tab.order,
                &opts.tols,
                &span,
                &mut ws.ytmp,
                &mut ws.y_new,
            );
            for s in sol.stats.iter_mut() {
                s.n_f_evals += 1;
            }
            ledger.base += 1;
            dt0
        }
    };

    let min_dt: Vec<f64> = span.iter().map(|s| s.abs() * opts.min_dt_rel).collect();

    // --- main loop -----------------------------------------------------------
    // Per-iteration buffers hoisted out of the loop (§Perf: allocation-free
    // steady state).
    let mut clamped = vec![false; batch];
    let mut active = vec![true; batch];
    let mut accepted = vec![false; batch];
    let mut factor = vec![1.0f64; batch];
    let mut t_new = vec![0.0f64; batch];
    let mut iter = 0usize;
    while finished.iter().any(|f| !f) {
        iter += 1;
        if iter > opts.max_steps {
            for i in 0..batch {
                if !finished[i] {
                    sol.status[i] = Status::MaxStepsReached;
                    finished[i] = true;
                }
            }
            break;
        }

        // Clamp step to the remaining span; remember who was clamped so the
        // final time is hit exactly.
        for i in 0..batch {
            clamped[i] = false;
            active[i] = !finished[i];
            if finished[i] {
                continue;
            }
            let remaining = grid.t1(i) - t[i];
            if dt[i] >= remaining {
                dt[i] = remaining;
                clamped[i] = true;
            }
        }
        let mut calls = rk_attempt(
            &ct,
            sys,
            &t,
            &dt,
            &y,
            &mut ws,
            &k0_ready,
            Some(&active),
            opts.eval_inactive,
        );
        // torchode semantics: every instance experiences every batched call
        // (the refresh below credits its own call separately).
        for s in sol.stats.iter_mut() {
            s.n_f_evals += calls;
        }

        // Pass 1: non-finite guards and controller decisions.
        for i in 0..batch {
            accepted[i] = false;
            if finished[i] {
                continue;
            }
            sol.stats[i].n_steps += 1;

            let y_new = ws.y_new.row(i);
            if y_new.iter().any(|v| !v.is_finite()) {
                sol.status[i] = Status::NonFinite;
                finished[i] = true;
                continue;
            }

            let (accept, fac) = if adaptive {
                let en = scaled_norm(
                    NormKind::Rms,
                    ws.err.row(i),
                    y.row(i),
                    y_new,
                    opts.tols.atol(i),
                    opts.tols.rtol(i),
                );
                let d = opts.controller.decide(en, tab.err_order, &ctrl[i]);
                if d.accept {
                    ctrl[i].push(en);
                }
                (d.accept, d.factor)
            } else {
                (true, 1.0)
            };
            accepted[i] = accept;
            factor[i] = fac;
            if accept {
                t_new[i] = if clamped[i] { grid.t1(i) } else { t[i] + dt[i] };
            }
        }

        // Non-FSAL: evaluate the true end slope f(t_new, y_new) for the
        // accepted rows *before* dense output, so Hermite interpolation
        // uses the step-end derivative (3rd order) instead of the stale
        // step-start slope — this is also the cold-row k[0] refresh for
        // the next iteration, so it costs no extra call.
        if !tab.fsal && accepted.iter().any(|&a| a) {
            for i in 0..batch {
                ws.t_stage[i] = if accepted[i] { t_new[i] } else { t[i] };
            }
            sys.f_batch(&ws.t_stage, &ws.y_new, &mut ws.k[0], Some(&accepted));
            for s in sol.stats.iter_mut() {
                s.n_f_evals += 1;
            }
            calls += 1;
        }

        // Pass 2: dense output, state commit, step-size update.
        for i in 0..batch {
            if finished[i] {
                continue;
            }
            if accepted[i] {
                sol.stats[i].n_accepted += 1;
                let tn = t_new[i];
                if opts.record_trace {
                    trace[i].push((t[i], dt[i]));
                }

                // Dense output: fill every eval point in (t, t_new].
                let h = dt[i];
                if next_eval[i] < n_eval {
                    let te_row = grid.row(i);
                    let mut e = next_eval[i];
                    let mut coeffs_ready = false;
                    while e < n_eval && te_row[e] <= tn {
                        let theta = ((te_row[e] - t[i]) / h).clamp(0.0, 1.0);
                        match tab.dense {
                            DenseOutput::Dopri5 => {
                                if !coeffs_ready {
                                    let krows: Vec<&[f64]> =
                                        ws.k.iter().map(|k| k.row(i)).collect();
                                    interp::dopri5_coeffs(
                                        h,
                                        y.row(i),
                                        ws.y_new.row(i),
                                        &krows,
                                        &mut interp_coeffs,
                                    );
                                    coeffs_ready = true;
                                }
                                interp::dopri5_eval(theta, &interp_coeffs, sol.y_mut(i, e));
                            }
                            DenseOutput::Hermite => {
                                // f at the step end: the FSAL stage, or the
                                // refreshed k[0] = f(t_new, y_new) computed
                                // above for non-FSAL methods.
                                let f_end = if tab.fsal {
                                    ws.k[tab.stages - 1].row(i)
                                } else {
                                    ws.k[0].row(i)
                                };
                                interp::hermite_eval(
                                    theta,
                                    h,
                                    y.row(i),
                                    f_start.row(i),
                                    ws.y_new.row(i),
                                    f_end,
                                    sol.y_mut(i, e),
                                );
                            }
                        }
                        sol.stats[i].n_initialized += 1;
                        e += 1;
                    }
                    next_eval[i] = e;
                }

                // Commit the step.
                y.row_mut(i).copy_from_slice(ws.y_new.row(i));
                t[i] = tn;
                if tab.fsal {
                    // k[last] is f(t_new, y_new): becomes next k[0].
                    let (head, tail) = ws.k.split_at_mut(tab.stages - 1);
                    let (first, _) = head.split_first_mut().unwrap();
                    first.row_mut(i).copy_from_slice(tail[0].row(i));
                    f_start.row_mut(i).copy_from_slice(tail[0].row(i));
                } else {
                    // k[0] already holds f(t_new, y_new) from the refresh.
                    f_start.row_mut(i).copy_from_slice(ws.k[0].row(i));
                }
                k0_ready[i] = true;

                if next_eval[i] >= n_eval {
                    sol.status[i] = Status::Success;
                    finished[i] = true;
                }
            } else {
                // Rejected: same (t, y), so k[0] stays valid for any method
                // that already computed it.
                k0_ready[i] = true;
            }

            dt[i] *= factor[i];
            if adaptive && !finished[i] && dt[i] < min_dt[i] {
                sol.status[i] = Status::DtUnderflow;
                finished[i] = true;
            }
        }

        ledger.per_iter.push(calls);
    }

    if opts.record_trace {
        sol.trace = Some(trace);
    }
    (sol, ledger)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, LinearSystem, LotkaVolterra, VdP};
    use crate::solver::Method;

    #[test]
    fn exponential_decay_accuracy() {
        let sys = ExponentialDecay::new(vec![1.0], 2);
        let y0 = BatchVec::from_rows(&[vec![1.0, -2.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 2.0, 21);
        let opts = SolveOptions::new(Method::Dopri5).with_tols(1e-8, 1e-8);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        for e in 0..21 {
            let t = grid.row(0)[e];
            let exact = (-t).exp();
            assert!((sol.y(0, e)[0] - exact).abs() < 1e-6, "e={e}");
            assert!((sol.y(0, e)[1] + 2.0 * exact).abs() < 1e-6, "e={e}");
        }
    }

    #[test]
    fn damped_rotation_accuracy_all_adaptive_methods() {
        let (decay, omega) = (0.2, 3.0);
        let sys = LinearSystem::damped_rotation(decay, omega);
        let y0 = BatchVec::from_rows(&[vec![1.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 3.0, 7);
        for m in [
            Method::Heun,
            Method::Bosh3,
            Method::Fehlberg45,
            Method::CashKarp45,
            Method::Dopri5,
            Method::Tsit5,
        ] {
            let opts = SolveOptions::new(m).with_tols(1e-7, 1e-7).with_max_steps(100_000);
            let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
            assert!(sol.all_success(), "{m:?}: {:?}", sol.status);
            let mut exact = [0.0; 2];
            LinearSystem::damped_rotation_exact(decay, omega, &[1.0, 0.0], 3.0, &mut exact);
            let got = sol.y_final(0);
            for d in 0..2 {
                assert!(
                    (got[d] - exact[d]).abs() < 1e-4,
                    "{m:?}: {got:?} vs {exact:?}"
                );
            }
        }
    }

    #[test]
    fn fixed_step_methods_converge() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::from_rows(&[vec![1.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 1.0, 2);
        for (m, tol) in [(Method::Euler, 5e-3), (Method::Midpoint, 1e-4), (Method::Rk4, 1e-8)] {
            let opts = SolveOptions::new(m).with_fixed_dt(1e-3).with_max_steps(10_000);
            let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
            assert!(sol.all_success(), "{m:?}");
            let err = (sol.y_final(0)[0] - (-1.0f64).exp()).abs();
            assert!(err < tol, "{m:?}: err {err}");
        }
    }

    #[test]
    fn per_instance_integration_ranges() {
        // Instance 0: [0, 1]; instance 1: [5, 7] — no special handling.
        let sys = ExponentialDecay::new(vec![1.0, 0.5], 1);
        let y0 = BatchVec::from_rows(&[vec![1.0], vec![2.0]]);
        let grid = TimeGrid::from_rows(&[
            (0..11).map(|k| k as f64 / 10.0).collect(),
            (0..11).map(|k| 5.0 + 2.0 * k as f64 / 10.0).collect(),
        ]);
        let opts = SolveOptions::new(Method::Dopri5).with_tols(1e-8, 1e-8);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        assert!((sol.y_final(0)[0] - (-1.0f64).exp()).abs() < 1e-6);
        assert!((sol.y_final(1)[0] - 2.0 * (-0.5f64 * 2.0).exp()).abs() < 1e-6);
    }

    #[test]
    fn stats_are_consistent() {
        let sys = VdP::new(vec![2.0, 25.0]);
        let y0 = BatchVec::from_rows(&[vec![2.0, 0.0], vec![2.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(2, 0.0, 10.0, 50);
        let opts = SolveOptions::new(Method::Dopri5).with_tols(1e-5, 1e-5);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        for st in &sol.stats {
            assert!(st.n_accepted <= st.n_steps);
            assert_eq!(st.n_initialized, 50);
            assert!(st.n_f_evals > st.n_steps);
        }
        // n_f_evals is uniform across the batch (torchode semantics).
        assert_eq!(sol.stats[0].n_f_evals, sol.stats[1].n_f_evals);
        // The stiff instance needs more steps.
        assert!(sol.stats[1].n_steps > sol.stats[0].n_steps);
    }

    #[test]
    fn dense_output_matches_tight_solve() {
        // Solve once with 5 eval points and once with 41; shared points must
        // agree to interpolation accuracy.
        let sys = LotkaVolterra::uniform(1, 1.1, 0.4, 0.1, 0.4);
        let y0 = BatchVec::from_rows(&[vec![2.0, 1.0]]);
        let coarse = TimeGrid::linspace_shared(1, 0.0, 8.0, 5);
        let fine = TimeGrid::linspace_shared(1, 0.0, 8.0, 41);
        let opts = SolveOptions::new(Method::Dopri5).with_tols(1e-9, 1e-9);
        let sc = solve_ivp_parallel(&sys, &y0, &coarse, &opts);
        let sf = solve_ivp_parallel(&sys, &y0, &fine, &opts);
        assert!(sc.all_success() && sf.all_success());
        for e in 0..5 {
            let yc = sc.y(0, e);
            let yf = sf.y(0, e * 10);
            for d in 0..2 {
                assert!((yc[d] - yf[d]).abs() < 1e-6, "e={e} d={d}: {} vs {}", yc[d], yf[d]);
            }
        }
    }

    /// Non-FSAL Hermite dense output must use the true end slope
    /// f(t_new, y_new): with the stale step-start slope (the old bug) the
    /// mid-step error of rk4 at dt = 0.1 is ~1e-3; with the fix it is the
    /// cubic-Hermite O(h^4) bound, orders of magnitude below.
    #[test]
    fn hermite_dense_output_uses_end_slope() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::from_rows(&[vec![1.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 1.0, 41);
        let opts = SolveOptions::new(Method::Rk4).with_fixed_dt(0.1).with_max_steps(1_000);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        let mut max_err = 0.0f64;
        for e in 0..41 {
            let t = grid.row(0)[e];
            max_err = max_err.max((sol.y(0, e)[0] - (-t).exp()).abs());
        }
        assert!(max_err < 1e-5, "dense-output error {max_err} (stale end slope?)");
    }

    #[test]
    fn max_steps_reported() {
        let sys = VdP::new(vec![1000.0]); // very stiff
        let y0 = BatchVec::from_rows(&[vec![2.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 100.0, 10);
        let opts = SolveOptions::new(Method::Dopri5).with_tols(1e-8, 1e-8).with_max_steps(50);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert_eq!(sol.status[0], Status::MaxStepsReached);
    }

    #[test]
    fn batch_of_identical_problems_identical_answers() {
        let b = 8;
        let sys = VdP::uniform(b, 2.0);
        let y0 = BatchVec::broadcast(&[1.0, 0.5], b);
        let grid = TimeGrid::linspace_shared(b, 0.0, 5.0, 10);
        let opts = SolveOptions::new(Method::Tsit5).with_tols(1e-6, 1e-6);
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        for i in 1..b {
            assert_eq!(sol.stats[i], sol.stats[0]);
            for e in 0..10 {
                assert_eq!(sol.y(i, e), sol.y(0, e));
            }
        }
    }

    #[test]
    fn heterogeneous_batch_isolated() {
        // A very stiff instance must not change the easy instance's answer
        // beyond tolerance (bitwise isolation isn't required because the
        // controller is per-instance anyway; check solution agreement
        // against a solo solve).
        let easy_solo = {
            let sys = VdP::new(vec![0.5]);
            let y0 = BatchVec::from_rows(&[vec![1.0, 0.0]]);
            let grid = TimeGrid::linspace_shared(1, 0.0, 5.0, 10);
            let opts = SolveOptions::new(Method::Dopri5).with_tols(1e-7, 1e-7);
            solve_ivp_parallel(&sys, &y0, &grid, &opts)
        };
        let mixed = {
            let sys = VdP::new(vec![0.5, 40.0]);
            let y0 = BatchVec::from_rows(&[vec![1.0, 0.0], vec![2.0, 0.0]]);
            let grid = TimeGrid::linspace_shared(2, 0.0, 5.0, 10);
            let opts = SolveOptions::new(Method::Dopri5).with_tols(1e-7, 1e-7);
            solve_ivp_parallel(&sys, &y0, &grid, &opts)
        };
        assert!(mixed.all_success());
        // Identical per-instance state machine => identical trajectory.
        for e in 0..10 {
            for d in 0..2 {
                assert_eq!(mixed.y(0, e)[d], easy_solo.y(0, e)[d]);
            }
        }
        assert_eq!(mixed.stats[0].n_steps, easy_solo.stats[0].n_steps);
    }

    #[test]
    fn trace_recorded_when_requested() {
        let sys = VdP::new(vec![5.0]);
        let y0 = BatchVec::from_rows(&[vec![2.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 10.0, 5);
        let opts = SolveOptions::new(Method::Dopri5).with_tols(1e-5, 1e-5).with_trace();
        let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        let trace = sol.trace.as_ref().unwrap();
        assert_eq!(trace[0].len() as u64, sol.stats[0].n_accepted);
        // Times strictly increasing, dts positive.
        for w in trace[0].windows(2) {
            assert!(w[1].0 > w[0].0);
        }
        assert!(trace[0].iter().all(|&(_, dt)| dt > 0.0));
    }

    #[test]
    fn convergence_order_dopri5() {
        // Global error should scale ~dt^5 with fixed steps.
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::from_rows(&[vec![1.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 1.0, 2);
        let mut errs = Vec::new();
        for &h in &[0.1, 0.05] {
            let opts = SolveOptions::new(Method::Dopri5).with_fixed_dt(h);
            let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
            errs.push((sol.y_final(0)[0] - (-1.0f64).exp()).abs());
        }
        let order = (errs[0] / errs[1]).log2();
        assert!(order > 4.5, "measured order {order}");
    }

    /// The ledger records the loop's call pattern: FSAL adaptive methods
    /// make stages-1 calls per iteration; non-FSAL methods add the
    /// end-slope refresh on iterations with an accepted row.
    #[test]
    fn call_ledger_matches_stats() {
        let sys = VdP::new(vec![2.0]);
        let y0 = BatchVec::from_rows(&[vec![2.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(1, 0.0, 5.0, 10);
        for m in [Method::Dopri5, Method::Fehlberg45] {
            let opts = SolveOptions::new(m).with_tols(1e-6, 1e-6).with_max_steps(100_000);
            let (sol, ledger) = solve_ivp_parallel_core(&sys, &y0, &grid, &opts);
            assert!(sol.all_success());
            let total: u64 = ledger.base + ledger.per_iter.iter().sum::<u64>();
            assert_eq!(total, sol.stats[0].n_f_evals, "{m:?}");
            assert_eq!(ledger.per_iter.len() as u64, sol.stats[0].n_steps, "{m:?}");
        }
    }

    #[test]
    #[should_panic(expected = "atol")]
    fn rejects_mismatched_tolerance_vector() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::broadcast(&[1.0], 3);
        let grid = TimeGrid::linspace_shared(3, 0.0, 1.0, 3);
        let mut opts = SolveOptions::new(Method::Dopri5);
        opts.tols = crate::solver::Tolerances::per_instance(vec![1e-6; 2], vec![1e-6; 2]);
        solve_ivp_parallel(&sys, &y0, &grid, &opts);
    }
}
