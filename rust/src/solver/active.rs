//! The packed active set driving the parallel solve loop.
//!
//! torchode tracks every instance's progress separately; the natural CPU
//! realization is an incrementally-maintained **index list of unfinished
//! rows** instead of a `Vec<bool>` mask scanned in every pass. The
//! [`ActiveSet`] owns two pieces of bookkeeping:
//!
//! - `live`: the *slots* (positions in the solver's state buffers) that
//!   still hold an unfinished instance, ascending. Every per-row pass of
//!   the loop iterates this list, so a finished row costs zero work.
//! - `inst`: the slot → original-row map. It is the identity until the
//!   first [`ActiveSet::compact_with`]; afterwards slot `r` of the state
//!   buffers belongs to original row `inst[r]`, which is how solution
//!   buffers, grids and per-instance tolerances keep their original
//!   indexing while the hot state is packed densely.
//!
//! **Compaction** gathers the live rows into a dense prefix of the state
//! buffers (callers supply the gather as a closure over `(dst, src)` slot
//! pairs; `dst <= src` always holds because `live` is ascending, so
//! in-place `copy_within` gathers are safe). Compacting never changes any
//! live row's values — only where they are stored — so trajectories are
//! bitwise-identical with compaction on or off.
//!
//! Under the sharded exec layer every worker owns an `ActiveSet` for its
//! own row range (parallel path), or the packed index list doubles as
//! the unit the work-stealing chunks are cut over (see [`crate::exec`]);
//! in both cases the bitwise-determinism contract above is what lets
//! chunks move freely between workers.

// The solver module predates the crate's missing-docs ratchet; this file
// opts back in (see `lib.rs`).
#![warn(missing_docs)]

/// Packed index bookkeeping for a batched solve. See the module docs.
#[derive(Debug, Clone)]
pub struct ActiveSet {
    /// Live slots, ascending.
    live: Vec<usize>,
    /// Slot → original row. Identity until the first compaction.
    inst: Vec<usize>,
    /// All materialized slots (`0..slots`), kept as a list so callers can
    /// drive index-list evals over every still-materialized row
    /// (torchode's "overhanging" evaluations under `eval_inactive`).
    all: Vec<usize>,
    /// Number of materialized slots: the meaningful prefix of the state
    /// buffers. Equals the original batch until the first compaction.
    slots: usize,
    compacted: bool,
}

impl ActiveSet {
    /// All `batch` rows live, slots in original order.
    pub fn new(batch: usize) -> Self {
        Self {
            live: (0..batch).collect(),
            inst: (0..batch).collect(),
            all: (0..batch).collect(),
            slots: batch,
            compacted: false,
        }
    }

    /// The live slots, ascending.
    #[inline]
    pub fn live(&self) -> &[usize] {
        &self.live
    }

    /// Every materialized slot (`0..slots()` as a list).
    #[inline]
    pub fn all_slots(&self) -> &[usize] {
        &self.all
    }

    /// The slot → original-row map (length [`ActiveSet::slots`]).
    #[inline]
    pub fn inst_map(&self) -> &[usize] {
        &self.inst
    }

    /// Original row stored in `slot`.
    #[inline]
    pub fn inst(&self, slot: usize) -> usize {
        self.inst[slot]
    }

    /// Number of materialized slots.
    #[inline]
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Number of live rows.
    #[inline]
    pub fn len(&self) -> usize {
        self.live.len()
    }

    /// Whether no live rows remain (the solve loop's exit condition).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// Whether any compaction has happened (`inst` is no longer the
    /// identity).
    #[inline]
    pub fn is_compacted(&self) -> bool {
        self.compacted
    }

    /// Drop finished slots from the live list (`finished` is indexed by
    /// slot). O(live), allocation-free.
    pub fn retain(&mut self, finished: &[bool]) {
        self.live.retain(|&r| !finished[r]);
    }

    /// Whether the live fraction has dropped below `threshold` (and there
    /// is anything to compact). `threshold = 0` disables compaction;
    /// `threshold = 1` compacts as soon as any row finishes.
    pub fn should_compact(&self, threshold: f64) -> bool {
        threshold > 0.0
            && self.live.len() < self.slots
            && (self.live.len() as f64) < threshold * self.slots as f64
    }

    /// Gather the live rows into the dense prefix `0..len()`. `gather` is
    /// called once per moved row with `(dst, src)` slot indices,
    /// `dst <= src`, ascending in `dst`; the caller moves every piece of
    /// per-slot solver state accordingly. Allocation-free.
    pub fn compact_with(&mut self, mut gather: impl FnMut(usize, usize)) {
        let n = self.live.len();
        for dst in 0..n {
            let src = self.live[dst];
            if src != dst {
                gather(dst, src);
                self.inst[dst] = self.inst[src];
            }
        }
        self.inst.truncate(n);
        self.all.truncate(n);
        self.slots = n;
        self.live.clear();
        self.live.extend(0..n);
        self.compacted = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_as_identity() {
        let a = ActiveSet::new(4);
        assert_eq!(a.live(), &[0, 1, 2, 3]);
        assert_eq!(a.inst_map(), &[0, 1, 2, 3]);
        assert_eq!(a.all_slots(), &[0, 1, 2, 3]);
        assert_eq!(a.slots(), 4);
        assert!(!a.is_compacted());
        assert!(!a.is_empty());
    }

    #[test]
    fn retain_drops_finished_slots() {
        let mut a = ActiveSet::new(5);
        a.retain(&[false, true, false, true, false]);
        assert_eq!(a.live(), &[0, 2, 4]);
        // Materialized slots are unchanged until compaction.
        assert_eq!(a.slots(), 5);
        assert_eq!(a.all_slots(), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn threshold_semantics() {
        let mut a = ActiveSet::new(4);
        assert!(!a.should_compact(0.5), "nothing finished yet");
        a.retain(&[false, true, true, false]);
        assert!(!a.should_compact(0.0), "0 disables compaction");
        assert!(!a.should_compact(0.5), "live fraction is exactly 0.5");
        assert!(a.should_compact(0.51));
        assert!(a.should_compact(1.0));
    }

    #[test]
    fn compaction_gathers_into_prefix() {
        let mut a = ActiveSet::new(6);
        let mut state: Vec<i32> = vec![10, 11, 12, 13, 14, 15];
        a.retain(&[true, false, true, true, false, false]);
        assert_eq!(a.live(), &[1, 4, 5]);
        let mut moves = Vec::new();
        a.compact_with(|dst, src| {
            state[dst] = state[src];
            moves.push((dst, src));
        });
        assert_eq!(moves, vec![(0, 1), (1, 4), (2, 5)]);
        assert_eq!(&state[..3], &[11, 14, 15]);
        assert_eq!(a.live(), &[0, 1, 2]);
        assert_eq!(a.inst_map(), &[1, 4, 5]);
        assert_eq!(a.all_slots(), &[0, 1, 2]);
        assert_eq!(a.slots(), 3);
        assert!(a.is_compacted());
    }

    #[test]
    fn second_compaction_composes_the_maps() {
        let mut a = ActiveSet::new(6);
        a.retain(&[true, false, true, false, false, true]);
        a.compact_with(|_, _| {}); // inst = [1, 3, 4]
        a.retain(&[false, true, false]);
        a.compact_with(|_, _| {});
        assert_eq!(a.inst_map(), &[1, 4]);
        assert_eq!(a.slots(), 2);
    }

    #[test]
    fn gather_never_moves_backwards() {
        // dst <= src is the contract that makes in-place copy_within
        // gathers safe.
        let mut a = ActiveSet::new(32);
        let finished: Vec<bool> = (0..32).map(|i| i % 3 == 0).collect();
        a.retain(&finished);
        a.compact_with(|dst, src| assert!(dst <= src));
    }

    #[test]
    fn compacting_everything_away_is_safe() {
        let mut a = ActiveSet::new(3);
        a.retain(&[true, true, true]);
        assert!(a.is_empty());
        a.compact_with(|_, _| panic!("no rows to gather"));
        assert_eq!(a.slots(), 0);
        assert!(a.live().is_empty());
    }
}
