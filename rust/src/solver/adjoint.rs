//! The adjoint backward pass (optimize-then-discretize).
//!
//! Given a terminal loss `L(y(t1))`, the adjoint ODE propagates
//! `a(t) = ∂L/∂y(t)` backwards while re-solving the state and accumulating
//! parameter gradients:
//!
//! ```text
//! dy/dt = f(t, y)
//! da/dt = −aᵀ ∂f/∂y
//! dg/dt = −aᵀ ∂f/∂θ
//! ```
//!
//! Two modes, reproducing the Table 5 comparison:
//!
//! - [`adjoint_backward_parallel`]: each instance solves its own augmented
//!   ODE of size `2f + p` with independent adaptive state — torchode's
//!   default, whose backward blows up to `b(2f+p)` total variables (the
//!   paper reports the `b(f+p)` scaling; the extra `f` is the state
//!   re-solve both libraries carry).
//! - [`adjoint_backward_joint`]: the whole batch forms one augmented ODE
//!   of size `b·2f + p` — parameter gradients are shared, the step size is
//!   common, and the backward loop is dramatically cheaper
//!   (torchode-joint).
//!
//! [`backsolve_adjoint_parallel`] / [`backsolve_adjoint_joint`] wrap these
//! as the **training-facing backsolve adjoint** (torchode's
//! `BacksolveAdjoint` / `JointBacksolveAdjoint`): O(1) memory in the
//! forward step count, with optional **checkpointing**
//! ([`AdjointOptions::with_checkpoints`]) — a forward re-solve stores the
//! state at `k+1` evenly spaced times, and the backward pass integrates
//! segment by segment, resetting the state block `y` to the stored
//! checkpoint at each boundary while carrying `(a, g)` across. The
//! reversal error that makes plain backsolve adjoints drift on long or
//! unstable trajectories is thereby confined to one segment. Memory is
//! O(checkpoints), independent of how many steps the forward solve took
//! (`tests/alloc_regression.rs` pins this).

use super::{solve_ivp_parallel, SolveOptions, Solution, Stats, Status, TimeGrid};
use crate::problems::OdeSystem;
use crate::tensor::BatchVec;
use std::cell::RefCell;

/// Options for the backward solve.
#[derive(Debug, Clone)]
pub struct AdjointOptions {
    /// Solver options for the backward integration (and for the forward
    /// checkpoint re-solve when `checkpoints ≥ 2`).
    pub solve: SolveOptions,
    /// Number of backward segments for the backsolve entry points
    /// ([`backsolve_adjoint_parallel`] / [`backsolve_adjoint_joint`]).
    /// `1` (the default) integrates the whole span in one backward solve;
    /// `k ≥ 2` stores `k+1` evenly spaced forward states and resets the
    /// re-solved `y` block at each segment boundary, confining reversal
    /// error to one segment. Ignored by the raw `adjoint_backward_*`
    /// passes.
    pub checkpoints: usize,
}

impl AdjointOptions {
    pub fn new(solve: SolveOptions) -> Self {
        Self { solve, checkpoints: 1 }
    }

    /// Set the number of backsolve segments (clamped to at least 1).
    pub fn with_checkpoints(mut self, k: usize) -> Self {
        self.checkpoints = k.max(1);
        self
    }
}

/// Gradients produced by an adjoint backward pass.
#[derive(Debug, Clone)]
pub struct AdjointResult {
    /// `∂L/∂y0`, `(batch, dim)`.
    pub dl_dy0: BatchVec,
    /// `∂L/∂θ`, summed over the batch.
    pub dl_dparams: Vec<f64>,
    /// State at `t0` recovered by the backward solve (diagnostic: compare
    /// with the true `y0` to gauge reversal error).
    pub y0_recovered: BatchVec,
    /// Backward-solve statistics (per backward instance).
    pub stats: Vec<Stats>,
    pub status: Vec<Status>,
}

/// Augmented reverse-time system for per-instance adjoint solves.
///
/// State layout per instance: `[y (f), a (f), g (p)]`; reverse time
/// `s ∈ [0, t1−t0]` maps to `t = t1 − s`.
struct AugmentedSystem<'a> {
    sys: &'a dyn OdeSystem,
    f: usize,
    p: usize,
    t1: Vec<f64>,
    scratch: RefCell<(Vec<f64>, Vec<f64>, Vec<f64>)>,
}

impl<'a> OdeSystem for AugmentedSystem<'a> {
    fn dim(&self) -> usize {
        2 * self.f + self.p
    }

    fn f_inst(&self, inst: usize, s: f64, z: &[f64], dz: &mut [f64]) {
        let (f, p) = (self.f, self.p);
        let t = self.t1[inst.min(self.t1.len() - 1)] - s;
        let y = &z[..f];
        let a = &z[f..2 * f];
        let mut sc = self.scratch.borrow_mut();
        let (fy, vy, vp) = &mut *sc;
        fy.resize(f, 0.0);
        vy.resize(f, 0.0);
        vp.resize(p, 0.0);
        self.sys.f_inst(inst, t, y, fy);
        vy.iter_mut().for_each(|v| *v = 0.0);
        vp.iter_mut().for_each(|v| *v = 0.0);
        self.sys.vjp_inst(inst, t, y, a, vy, vp);
        // ds = -dt: flip signs of the forward-time derivatives.
        for i in 0..f {
            dz[i] = -fy[i]; // dy/ds
            dz[f + i] = vy[i]; // da/ds = +aᵀ∂f/∂y
        }
        for j in 0..p {
            dz[2 * f + j] = vp[j]; // dg/ds = +aᵀ∂f/∂θ
        }
    }
}

/// Per-instance (torchode-default) adjoint backward pass.
///
/// `y1` is the state at `t1` (from the forward solve), `dl_dy1` the loss
/// gradient there. Each instance integrates its own augmented system with
/// independent adaptive state.
pub fn adjoint_backward_parallel(
    sys: &dyn OdeSystem,
    y1: &BatchVec,
    dl_dy1: &BatchVec,
    t0: &[f64],
    t1: &[f64],
    opts: &AdjointOptions,
) -> AdjointResult {
    let batch = y1.batch();
    let f = sys.dim();
    let p = sys.n_params();
    assert!(sys.has_vjp(), "adjoint requires system VJPs");
    let aug = AugmentedSystem {
        sys,
        f,
        p,
        t1: t1.to_vec(),
        scratch: RefCell::new((Vec::new(), Vec::new(), Vec::new())),
    };
    // Initial augmented state per instance: [y1, dL/dy1, 0].
    let mut z0 = BatchVec::zeros(batch, 2 * f + p);
    for i in 0..batch {
        let row = z0.row_mut(i);
        row[..f].copy_from_slice(y1.row(i));
        row[f..2 * f].copy_from_slice(dl_dy1.row(i));
    }
    let grid = TimeGrid::from_rows(
        &(0..batch).map(|i| vec![0.0, t1[i] - t0[i]]).collect::<Vec<_>>(),
    );
    let sol = solve_ivp_parallel(&aug, &z0, &grid, &opts.solve);
    collect_result(&sol, batch, f, p)
}

/// Joint reverse-time system: the whole batch plus one shared parameter-
/// gradient block as a single instance of size `b·2f + p`.
struct JointAugmentedSystem<'a> {
    sys: &'a dyn OdeSystem,
    batch: usize,
    f: usize,
    p: usize,
    t1: f64,
    scratch: RefCell<(Vec<f64>, Vec<f64>, Vec<f64>)>,
}

impl<'a> OdeSystem for JointAugmentedSystem<'a> {
    fn dim(&self) -> usize {
        self.batch * 2 * self.f + self.p
    }

    fn f_inst(&self, _inst: usize, s: f64, z: &[f64], dz: &mut [f64]) {
        let (b, f, p) = (self.batch, self.f, self.p);
        let t = self.t1 - s;
        let mut sc = self.scratch.borrow_mut();
        let (fy, vy, vp) = &mut *sc;
        fy.resize(f, 0.0);
        vy.resize(f, 0.0);
        vp.resize(p, 0.0);
        let g_out = &mut dz[2 * b * f..];
        g_out.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..b {
            let y = &z[i * f..(i + 1) * f];
            let a = &z[(b + i) * f..(b + i + 1) * f];
            self.sys.f_inst(i, t, y, fy);
            vy.iter_mut().for_each(|v| *v = 0.0);
            vp.iter_mut().for_each(|v| *v = 0.0);
            self.sys.vjp_inst(i, t, y, a, vy, vp);
            for d in 0..f {
                dz[i * f + d] = -fy[d];
                dz[(b + i) * f + d] = vy[d];
            }
            for j in 0..p {
                dz[2 * b * f + j] += vp[j];
            }
        }
    }
}

/// Joint (torchode-joint) adjoint backward pass: one augmented ODE of size
/// `b·2f + p` with a shared step size and shared parameter gradients.
/// Requires a common `[t0, t1]` across the batch.
pub fn adjoint_backward_joint(
    sys: &dyn OdeSystem,
    y1: &BatchVec,
    dl_dy1: &BatchVec,
    t0: f64,
    t1: f64,
    opts: &AdjointOptions,
) -> AdjointResult {
    let batch = y1.batch();
    let f = sys.dim();
    let p = sys.n_params();
    assert!(sys.has_vjp(), "adjoint requires system VJPs");
    let aug = JointAugmentedSystem {
        sys,
        batch,
        f,
        p,
        t1,
        scratch: RefCell::new((Vec::new(), Vec::new(), Vec::new())),
    };
    let dim = batch * 2 * f + p;
    let mut z0 = BatchVec::zeros(1, dim);
    {
        let row = z0.row_mut(0);
        for i in 0..batch {
            row[i * f..(i + 1) * f].copy_from_slice(y1.row(i));
            row[(batch + i) * f..(batch + i + 1) * f].copy_from_slice(dl_dy1.row(i));
        }
    }
    let grid = TimeGrid::from_rows(&[vec![0.0, t1 - t0]]);
    let sol = solve_ivp_parallel(&aug, &z0, &grid, &opts.solve);

    // Unpack the joint layout.
    let zf = sol.y_final(0);
    let mut y0_rec = BatchVec::zeros(batch, f);
    let mut dl_dy0 = BatchVec::zeros(batch, f);
    for i in 0..batch {
        y0_rec.row_mut(i).copy_from_slice(&zf[i * f..(i + 1) * f]);
        dl_dy0
            .row_mut(i)
            .copy_from_slice(&zf[(batch + i) * f..(batch + i + 1) * f]);
    }
    AdjointResult {
        dl_dy0,
        dl_dparams: zf[2 * batch * f..].to_vec(),
        y0_recovered: y0_rec,
        stats: sol.stats.clone(),
        status: sol.status.clone(),
    }
}

fn collect_result(sol: &Solution, batch: usize, f: usize, p: usize) -> AdjointResult {
    let mut y0_rec = BatchVec::zeros(batch, f);
    let mut dl_dy0 = BatchVec::zeros(batch, f);
    let mut dl_dparams = vec![0.0; p];
    for i in 0..batch {
        let z = sol.y_final(i);
        y0_rec.row_mut(i).copy_from_slice(&z[..f]);
        dl_dy0.row_mut(i).copy_from_slice(&z[f..2 * f]);
        for j in 0..p {
            dl_dparams[j] += z[2 * f + j];
        }
    }
    AdjointResult {
        dl_dy0,
        dl_dparams,
        y0_recovered: y0_rec,
        stats: sol.stats.clone(),
        status: sol.status.clone(),
    }
}

/// Field-wise accumulation of per-segment solve statistics.
fn add_stats(dst: &mut Stats, src: &Stats) {
    dst.n_steps += src.n_steps;
    dst.n_accepted += src.n_accepted;
    dst.n_f_evals += src.n_f_evals;
    dst.n_initialized += src.n_initialized;
    dst.n_jac_evals += src.n_jac_evals;
    dst.n_lu_factor += src.n_lu_factor;
}

/// Keep the first non-success status a segment reports for an instance.
fn merge_status(dst: &mut Status, src: Status) {
    if *dst == Status::Success && src != Status::Success {
        *dst = src;
    }
}

/// Per-instance (torchode `BacksolveAdjoint`) backsolve adjoint with
/// checkpointed state re-solve.
///
/// `y0` / `y1` are the forward states at `t0` / `t1` and `dl_dy1` the
/// loss gradient at `t1`. With `opts.checkpoints == 1` this is exactly
/// [`adjoint_backward_parallel`]; with `k ≥ 2` a forward re-solve over
/// the `k+1`-point checkpoint grid runs first (using `opts.solve`), and
/// the backward pass integrates the augmented system one segment at a
/// time, resetting the state block to the stored checkpoint at every
/// boundary while carrying the adjoint `a` and parameter gradient `g`.
/// Memory stays O(checkpoints), independent of the forward step count;
/// `stats` sums all segments (plus the checkpoint re-solve) per
/// instance, and `y0_recovered` reflects only the earliest segment's
/// reversal (that is the point of checkpointing).
pub fn backsolve_adjoint_parallel(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    y1: &BatchVec,
    dl_dy1: &BatchVec,
    t0: &[f64],
    t1: &[f64],
    opts: &AdjointOptions,
) -> AdjointResult {
    let batch = y1.batch();
    let f = sys.dim();
    let p = sys.n_params();
    assert!(sys.has_vjp(), "adjoint requires system VJPs");
    let k = opts.checkpoints.max(1);
    let t_at = |i: usize, e: usize| t0[i] + (t1[i] - t0[i]) * e as f64 / k as f64;

    let mut stats = vec![Stats::default(); batch];
    let mut status = vec![Status::Success; batch];
    let ckpt = if k >= 2 {
        let grid = TimeGrid::from_rows(
            &(0..batch)
                .map(|i| (0..=k).map(|e| t_at(i, e)).collect::<Vec<_>>())
                .collect::<Vec<_>>(),
        );
        let sol = solve_ivp_parallel(sys, y0, &grid, &opts.solve);
        for i in 0..batch {
            add_stats(&mut stats[i], &sol.stats[i]);
            merge_status(&mut status[i], sol.status[i]);
        }
        Some(sol)
    } else {
        None
    };

    // Carried augmented state: `y` is reset per segment, `(a, g)` carry.
    let mut z = BatchVec::zeros(batch, 2 * f + p);
    for i in 0..batch {
        let row = z.row_mut(i);
        row[..f].copy_from_slice(y1.row(i));
        row[f..2 * f].copy_from_slice(dl_dy1.row(i));
    }
    let mut y0_rec = BatchVec::zeros(batch, f);
    for e in (1..=k).rev() {
        let aug = AugmentedSystem {
            sys,
            f,
            p,
            t1: (0..batch).map(|i| t_at(i, e)).collect(),
            scratch: RefCell::new((Vec::new(), Vec::new(), Vec::new())),
        };
        let grid = TimeGrid::from_rows(
            &(0..batch)
                .map(|i| vec![0.0, (t1[i] - t0[i]) / k as f64])
                .collect::<Vec<_>>(),
        );
        let sol = solve_ivp_parallel(&aug, &z, &grid, &opts.solve);
        for i in 0..batch {
            let zf = sol.y_final(i);
            let row = z.row_mut(i);
            row[f..].copy_from_slice(&zf[f..]);
            if e > 1 {
                match &ckpt {
                    Some(ck) => row[..f].copy_from_slice(ck.y(i, e - 1)),
                    None => row[..f].copy_from_slice(&zf[..f]),
                }
            } else {
                y0_rec.row_mut(i).copy_from_slice(&zf[..f]);
            }
            add_stats(&mut stats[i], &sol.stats[i]);
            merge_status(&mut status[i], sol.status[i]);
        }
    }

    let mut dl_dy0 = BatchVec::zeros(batch, f);
    let mut dl_dparams = vec![0.0; p];
    for i in 0..batch {
        let row = z.row(i);
        dl_dy0.row_mut(i).copy_from_slice(&row[f..2 * f]);
        for j in 0..p {
            dl_dparams[j] += row[2 * f + j];
        }
    }
    AdjointResult { dl_dy0, dl_dparams, y0_recovered: y0_rec, stats, status }
}

/// Joint (torchode `JointBacksolveAdjoint`) backsolve adjoint with
/// checkpointed state re-solve: one augmented backward ODE of size
/// `b·2f + p` per segment, shared step size and parameter gradients.
/// Requires a common `[t0, t1]`; see [`backsolve_adjoint_parallel`] for
/// the checkpointing semantics. The checkpoint re-solve is the plain
/// state solve (the joint structure only applies to the augmented
/// backward system); its per-instance stats are summed into the single
/// backward-instance entry.
pub fn backsolve_adjoint_joint(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    y1: &BatchVec,
    dl_dy1: &BatchVec,
    t0: f64,
    t1: f64,
    opts: &AdjointOptions,
) -> AdjointResult {
    let batch = y1.batch();
    let f = sys.dim();
    let p = sys.n_params();
    assert!(sys.has_vjp(), "adjoint requires system VJPs");
    let k = opts.checkpoints.max(1);
    let t_at = |e: usize| t0 + (t1 - t0) * e as f64 / k as f64;

    let mut stats = vec![Stats::default()];
    let mut status = vec![Status::Success];
    let ckpt = if k >= 2 {
        let grid = TimeGrid::linspace_shared(batch, t0, t1, k + 1);
        let sol = solve_ivp_parallel(sys, y0, &grid, &opts.solve);
        for i in 0..batch {
            add_stats(&mut stats[0], &sol.stats[i]);
            merge_status(&mut status[0], sol.status[i]);
        }
        Some(sol)
    } else {
        None
    };

    let dim = batch * 2 * f + p;
    let mut z = BatchVec::zeros(1, dim);
    {
        let row = z.row_mut(0);
        for i in 0..batch {
            row[i * f..(i + 1) * f].copy_from_slice(y1.row(i));
            row[(batch + i) * f..(batch + i + 1) * f].copy_from_slice(dl_dy1.row(i));
        }
    }
    let mut y0_rec = BatchVec::zeros(batch, f);
    for e in (1..=k).rev() {
        let aug = JointAugmentedSystem {
            sys,
            batch,
            f,
            p,
            t1: t_at(e),
            scratch: RefCell::new((Vec::new(), Vec::new(), Vec::new())),
        };
        let grid = TimeGrid::from_rows(&[vec![0.0, (t1 - t0) / k as f64]]);
        let sol = solve_ivp_parallel(&aug, &z, &grid, &opts.solve);
        let zf = sol.y_final(0);
        let row = z.row_mut(0);
        row[batch * f..].copy_from_slice(&zf[batch * f..]);
        if e > 1 {
            match &ckpt {
                Some(ck) => {
                    for i in 0..batch {
                        row[i * f..(i + 1) * f].copy_from_slice(ck.y(i, e - 1));
                    }
                }
                None => row[..batch * f].copy_from_slice(&zf[..batch * f]),
            }
        } else {
            for i in 0..batch {
                y0_rec.row_mut(i).copy_from_slice(&zf[i * f..(i + 1) * f]);
            }
        }
        add_stats(&mut stats[0], &sol.stats[0]);
        merge_status(&mut status[0], sol.status[0]);
    }

    let zrow = z.row(0);
    let mut dl_dy0 = BatchVec::zeros(batch, f);
    for i in 0..batch {
        dl_dy0
            .row_mut(i)
            .copy_from_slice(&zrow[(batch + i) * f..(batch + i + 1) * f]);
    }
    AdjointResult {
        dl_dy0,
        dl_dparams: zrow[2 * batch * f..].to_vec(),
        y0_recovered: y0_rec,
        stats,
        status,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, VdP};
    use crate::solver::{MethodId, SolveOptions};

    fn solve_forward(
        sys: &dyn OdeSystem,
        y0: &BatchVec,
        t0: f64,
        t1: f64,
    ) -> BatchVec {
        let grid = TimeGrid::linspace_shared(y0.batch(), t0, t1, 2);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10);
        let sol = solve_ivp_parallel(sys, y0, &grid, &opts);
        assert!(sol.all_success());
        let mut y1 = BatchVec::zeros(y0.batch(), y0.dim());
        for i in 0..y0.batch() {
            y1.row_mut(i).copy_from_slice(sol.y_final(i));
        }
        y1
    }

    /// Analytic check: L = y(T) for ẏ = −λy has ∂L/∂y0 = e^(−λT) and
    /// ∂L/∂λ = −T y0 e^(−λT).
    #[test]
    fn adjoint_exponential_analytic() {
        let lam = 0.8;
        let tt = 1.5;
        let sys = ExponentialDecay::new(vec![lam], 1);
        let y0 = BatchVec::from_rows(&[vec![2.0]]);
        let y1 = solve_forward(&sys, &y0, 0.0, tt);
        let dl = BatchVec::from_rows(&[vec![1.0]]);
        let opts =
            AdjointOptions::new(SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10));
        let res = adjoint_backward_parallel(&sys, &y1, &dl, &[0.0], &[tt], &opts);
        assert!(res.status.iter().all(|s| *s == Status::Success));
        let expect_dy0 = (-lam * tt).exp();
        let expect_dlam = -tt * 2.0 * (-lam * tt).exp();
        assert!((res.dl_dy0.row(0)[0] - expect_dy0).abs() < 1e-6);
        assert!((res.dl_dparams[0] - expect_dlam).abs() < 1e-5);
        // State reversal recovers y0.
        assert!((res.y0_recovered.row(0)[0] - 2.0).abs() < 1e-6);
    }

    /// Nonlinear check against finite differences: L = x(T) of VdP w.r.t.
    /// the initial condition and μ.
    #[test]
    fn adjoint_vdp_matches_fd() {
        let mu = 1.3;
        let tt = 2.0;
        let y0v = [1.2, -0.4];
        let loss = |mu: f64, y0v: [f64; 2]| -> f64 {
            let sys = VdP::new(vec![mu]);
            let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
            let y1 = solve_forward(&sys, &y0, 0.0, tt);
            y1.row(0)[0]
        };
        let sys = VdP::new(vec![mu]);
        let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
        let y1 = solve_forward(&sys, &y0, 0.0, tt);
        let dl = BatchVec::from_rows(&[vec![1.0, 0.0]]);
        let opts =
            AdjointOptions::new(SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10));
        let res = adjoint_backward_parallel(&sys, &y1, &dl, &[0.0], &[tt], &opts);
        let h = 1e-5;
        for d in 0..2 {
            let mut yp = y0v;
            yp[d] += h;
            let mut ym = y0v;
            ym[d] -= h;
            let fd = (loss(mu, yp) - loss(mu, ym)) / (2.0 * h);
            assert!(
                (res.dl_dy0.row(0)[d] - fd).abs() < 1e-4,
                "d={d}: {} vs {fd}",
                res.dl_dy0.row(0)[d]
            );
        }
        let fd_mu = (loss(mu + h, y0v) - loss(mu - h, y0v)) / (2.0 * h);
        assert!((res.dl_dparams[0] - fd_mu).abs() < 1e-4, "{} vs {fd_mu}", res.dl_dparams[0]);
    }

    /// Joint and parallel adjoints agree on gradients.
    #[test]
    fn joint_matches_parallel() {
        let sys = VdP::new(vec![0.8, 2.0]);
        let y0 = BatchVec::from_rows(&[vec![1.0, 0.0], vec![0.5, 0.7]]);
        let tt = 1.5;
        let y1 = solve_forward(&sys, &y0, 0.0, tt);
        let dl = BatchVec::from_rows(&[vec![1.0, -0.5], vec![0.3, 1.0]]);
        let opts =
            AdjointOptions::new(SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10));
        let par = adjoint_backward_parallel(&sys, &y1, &dl, &[0.0, 0.0], &[tt, tt], &opts);
        let joint = adjoint_backward_joint(&sys, &y1, &dl, 0.0, tt, &opts);
        for i in 0..2 {
            for d in 0..2 {
                assert!(
                    (par.dl_dy0.row(i)[d] - joint.dl_dy0.row(i)[d]).abs() < 1e-6,
                    "i={i} d={d}"
                );
            }
        }
        assert!((par.dl_dparams[0] - joint.dl_dparams[0]).abs() < 1e-6);
    }

    /// Backsolve with one segment is the plain adjoint; with checkpoints
    /// it must produce the same gradients (the segments re-solve the same
    /// trajectory) while confining reversal error.
    #[test]
    fn backsolve_checkpointed_matches_plain() {
        let sys = VdP::new(vec![1.3]);
        let y0 = BatchVec::from_rows(&[vec![1.2, -0.4]]);
        let tt = 2.0;
        let y1 = solve_forward(&sys, &y0, 0.0, tt);
        let dl = BatchVec::from_rows(&[vec![1.0, 0.0]]);
        let base = AdjointOptions::new(SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10));
        let plain = backsolve_adjoint_parallel(&sys, &y0, &y1, &dl, &[0.0], &[tt], &base);
        let ck = base.clone().with_checkpoints(4);
        let seg = backsolve_adjoint_parallel(&sys, &y0, &y1, &dl, &[0.0], &[tt], &ck);
        for d in 0..2 {
            let (a, b) = (plain.dl_dy0.row(0)[d], seg.dl_dy0.row(0)[d]);
            assert!((a - b).abs() < 1e-6, "d={d}: {a} vs {b}");
        }
        assert!((plain.dl_dparams[0] - seg.dl_dparams[0]).abs() < 1e-6);
        // One-segment backsolve == the raw parallel adjoint seeded at y1.
        let raw = adjoint_backward_parallel(&sys, &y1, &dl, &[0.0], &[tt], &base);
        for d in 0..2 {
            assert_eq!(plain.dl_dy0.row(0)[d], raw.dl_dy0.row(0)[d]);
        }
        // Checkpointed reversal starts each segment from a stored state,
        // so the recovered y0 drifts at most one segment's worth.
        for d in 0..2 {
            assert!((seg.y0_recovered.row(0)[d] - y0.row(0)[d]).abs() < 1e-5);
        }
    }

    /// Joint and parallel backsolve agree, with and without checkpoints.
    #[test]
    fn backsolve_joint_matches_parallel() {
        let sys = VdP::new(vec![0.8, 2.0]);
        let y0 = BatchVec::from_rows(&[vec![1.0, 0.0], vec![0.5, 0.7]]);
        let tt = 1.5;
        let y1 = solve_forward(&sys, &y0, 0.0, tt);
        let dl = BatchVec::from_rows(&[vec![1.0, -0.5], vec![0.3, 1.0]]);
        for k in [1usize, 3] {
            let opts =
                AdjointOptions::new(SolveOptions::new(MethodId::DOPRI5).with_tols(1e-10, 1e-10))
                    .with_checkpoints(k);
            let par =
                backsolve_adjoint_parallel(&sys, &y0, &y1, &dl, &[0.0, 0.0], &[tt, tt], &opts);
            let joint = backsolve_adjoint_joint(&sys, &y0, &y1, &dl, 0.0, tt, &opts);
            for i in 0..2 {
                for d in 0..2 {
                    assert!(
                        (par.dl_dy0.row(i)[d] - joint.dl_dy0.row(i)[d]).abs() < 1e-6,
                        "k={k} i={i} d={d}"
                    );
                }
            }
            assert!((par.dl_dparams[0] - joint.dl_dparams[0]).abs() < 1e-6, "k={k}");
        }
    }

    /// The Table 5 size effect: the joint adjoint runs one instance of
    /// size b·2f+p and therefore takes far fewer *total* steps than the
    /// per-instance backward at equal tolerance.
    #[test]
    fn joint_backward_is_cheaper_in_total_steps() {
        let b = 6;
        let sys = VdP::new((0..b).map(|i| 0.5 + i as f64 * 0.5).collect());
        let y0 = BatchVec::broadcast(&[1.5, 0.0], b);
        let tt = 2.0;
        let y1 = solve_forward(&sys, &y0, 0.0, tt);
        let dl = BatchVec::broadcast(&[1.0, 0.0], b);
        let opts =
            AdjointOptions::new(SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8));
        let par = adjoint_backward_parallel(&sys, &y1, &dl, &vec![0.0; b], &vec![tt; b], &opts);
        let joint = adjoint_backward_joint(&sys, &y1, &dl, 0.0, tt, &opts);
        let par_total: u64 = par.stats.iter().map(|s| s.n_steps).sum();
        let joint_total: u64 = joint.stats.iter().map(|s| s.n_steps).sum();
        assert!(
            joint_total < par_total,
            "joint {joint_total} !< parallel {par_total}"
        );
    }
}
