//! The shared RK stage kernel.
//!
//! One "attempt" computes all stages, the 5th-order solution and the
//! embedded error for the whole batch with per-instance `(t, dt)`. The
//! dynamics are evaluated **once per stage for the entire batch** — the
//! same call pattern a GPU implementation uses, and the reason parallel
//! solving costs almost nothing extra (torchode §3).
//!
//! Implementation notes mirroring the paper's optimizations:
//!
//! - coefficients are pre-filtered for zeros ([`CompiledTableau`]), so the
//!   inner loops never multiply by 0 (torchode's `einsum` over a sparse b),
//! - stage accumulation, solution update and error estimate are each one
//!   fused pass over memory with no temporaries (`addcmul`-style),
//! - all buffers live in a pre-allocated [`RkWorkspace`] reused across
//!   steps ("pre-allocated buffers").

use super::tableau::Tableau;
use crate::problems::OdeSystem;
use crate::tensor::BatchVec;

/// A tableau with zero coefficients stripped, built once per solve.
#[derive(Debug, Clone)]
pub struct CompiledTableau {
    pub tab: &'static Tableau,
    /// Per stage `s`: the nonzero `(j, a_sj)` pairs.
    pub a_nz: Vec<Vec<(usize, f64)>>,
    /// Nonzero `(j, b_j)` pairs.
    pub b_nz: Vec<(usize, f64)>,
    /// Nonzero `(j, b_err_j)` pairs.
    pub berr_nz: Vec<(usize, f64)>,
}

impl CompiledTableau {
    pub fn new(tab: &'static Tableau) -> Self {
        let a_nz = (0..tab.stages)
            .map(|s| {
                if s == 0 {
                    Vec::new()
                } else {
                    tab.a_row(s)
                        .iter()
                        .enumerate()
                        .filter(|(_, &v)| v != 0.0)
                        .map(|(j, &v)| (j, v))
                        .collect()
                }
            })
            .collect();
        let b_nz = tab.b.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(j, &v)| (j, v)).collect();
        let berr_nz =
            tab.b_err.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(j, &v)| (j, v)).collect();
        Self { tab, a_nz, b_nz, berr_nz }
    }
}

/// Pre-allocated buffers for the RK attempt, reused across all steps of a
/// solve.
pub struct RkWorkspace {
    /// Stage slopes `k[s]`, each `(batch, dim)`.
    pub k: Vec<BatchVec>,
    /// Stage input `y + dt Σ a k`.
    pub ytmp: BatchVec,
    /// Proposed solution.
    pub y_new: BatchVec,
    /// Raw embedded error estimate.
    pub err: BatchVec,
    /// Per-instance stage times.
    pub t_stage: Vec<f64>,
}

impl RkWorkspace {
    pub fn new(stages: usize, batch: usize, dim: usize) -> Self {
        Self {
            k: (0..stages).map(|_| BatchVec::zeros(batch, dim)).collect(),
            ytmp: BatchVec::zeros(batch, dim),
            y_new: BatchVec::zeros(batch, dim),
            err: BatchVec::zeros(batch, dim),
            t_stage: vec![0.0; batch],
        }
    }
}

/// Compute one RK attempt for the whole batch.
///
/// - `k0_ready[i]`: instance `i`'s `k[0]` already holds `f(t_i, y_i)`
///   (FSAL cache, or an unchanged slope after a rejection).
/// - `active`: rows to update; inactive rows keep `ytmp = y` so the
///   batched dynamics evaluation still sees valid states (torchode's
///   "overhanging" model evaluations). If `eval_inactive` is false the
///   dynamics are told to skip inactive rows instead.
///
/// Returns the number of batched dynamics calls made.
#[allow(clippy::too_many_arguments)]
pub fn rk_attempt(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    t: &[f64],
    dt: &[f64],
    y: &BatchVec,
    ws: &mut RkWorkspace,
    k0_ready: &[bool],
    active: Option<&[bool]>,
    eval_inactive: bool,
) -> u64 {
    let tab = ct.tab;
    let batch = y.batch();
    let dim = y.dim();
    let mut n_calls = 0u64;

    let eval_mask = if eval_inactive { None } else { active };

    // Stage 0: evaluate only where the cache is cold. We still issue one
    // batched call if *any* row needs it (matching the GPU cost model).
    if k0_ready.iter().any(|r| !r) {
        // Rows with a warm cache must not be overwritten: evaluate into
        // ytmp-backed scratch via mask trickery — simplest correct scheme:
        // evaluate the full batch into a scratch and copy the cold rows.
        // To avoid an extra buffer we evaluate row-wise through f_batch
        // with an activity mask selecting the cold rows.
        let cold: Vec<bool> = k0_ready
            .iter()
            .enumerate()
            .map(|(i, &r)| !r && eval_mask.map_or(true, |m| m[i]))
            .collect();
        ws.t_stage.copy_from_slice(t);
        // Borrow juggling: evaluate into k[0] directly with the cold mask.
        let k0 = &mut ws.k[0];
        sys.f_batch(&ws.t_stage, y, k0, Some(&cold));
        n_calls += 1;
    }

    // Stages 1..S.
    for s in 1..tab.stages {
        // ytmp = y + dt * Σ_j a_sj k_j  (one fused pass; inner loop over
        // the nonzero coefficients only). Stage-slope rows are hoisted out
        // of the element loop (§Perf: per-element `row()` slicing cost
        // ~35 % of the attempt at dim 2).
        let nz = &ct.a_nz[s];
        for i in 0..batch {
            let act = active.map_or(true, |m| m[i]);
            let yrow = y.row(i);
            if !act {
                // Keep a valid state for the batched eval.
                ws.ytmp.row_mut(i).copy_from_slice(yrow);
                ws.t_stage[i] = t[i];
                continue;
            }
            let h = dt[i];
            ws.t_stage[i] = t[i] + tab.c[s] * h;
            let out = ws.ytmp.row_mut(i);
            match nz.len() {
                1 => {
                    let (j0, w0) = nz[0];
                    let k0 = ws.k[j0].row(i);
                    for d in 0..dim {
                        out[d] = yrow[d] + h * w0 * k0[d];
                    }
                }
                2 => {
                    let (j0, w0) = nz[0];
                    let (j1, w1) = nz[1];
                    let (k0, k1) = (ws.k[j0].row(i), ws.k[j1].row(i));
                    for d in 0..dim {
                        out[d] = yrow[d] + h * (w0 * k0[d] + w1 * k1[d]);
                    }
                }
                _ => {
                    // Hoist the row slices once per instance.
                    let mut krows: [&[f64]; 8] = [&[]; 8];
                    for (slot, &(j, _)) in krows.iter_mut().zip(nz.iter()) {
                        *slot = ws.k[j].row(i);
                    }
                    for d in 0..dim {
                        let mut acc = 0.0;
                        for (idx, &(_, w)) in nz.iter().enumerate() {
                            acc += w * krows[idx][d];
                        }
                        out[d] = yrow[d] + h * acc;
                    }
                }
            }
        }
        // One batched dynamics call for this stage.
        let (head, tail) = ws.k.split_at_mut(s);
        let _ = head;
        sys.f_batch(&ws.t_stage, &ws.ytmp, &mut tail[0], eval_mask);
        n_calls += 1;
    }

    // Solution + error in one fused pass per row, with hoisted slope rows.
    let has_err = !ct.berr_nz.is_empty();
    for i in 0..batch {
        if !active.map_or(true, |m| m[i]) {
            continue;
        }
        let h = dt[i];
        let yrow = y.row(i);
        let mut brows: [&[f64]; 8] = [&[]; 8];
        for (slot, &(j, _)) in brows.iter_mut().zip(ct.b_nz.iter()) {
            *slot = ws.k[j].row(i);
        }
        {
            let out = ws.y_new.row_mut(i);
            for d in 0..dim {
                let mut acc = 0.0;
                for (idx, &(_, w)) in ct.b_nz.iter().enumerate() {
                    acc += w * brows[idx][d];
                }
                out[d] = yrow[d] + h * acc;
            }
        }
        if has_err {
            let mut erows: [&[f64]; 8] = [&[]; 8];
            for (slot, &(j, _)) in erows.iter_mut().zip(ct.berr_nz.iter()) {
                *slot = ws.k[j].row(i);
            }
            let out = ws.err.row_mut(i);
            for d in 0..dim {
                let mut acc = 0.0;
                for (idx, &(_, w)) in ct.berr_nz.iter().enumerate() {
                    acc += w * erows[idx][d];
                }
                out[d] = h * acc;
            }
        }
    }

    n_calls
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, OdeSystem};
    use crate::solver::tableau;

    /// One dopri5 step on dy/dt = -y must be 5th-order accurate.
    #[test]
    fn dopri5_single_step_accuracy() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 1, 1);
        let y = BatchVec::from_rows(&[vec![1.0]]);
        let dt = 0.1;
        rk_attempt(&ct, &sys, &[0.0], &[dt], &y, &mut ws, &[false], None, true);
        let exact = (-dt_f64(dt)).exp();
        let got = ws.y_new.row(0)[0];
        assert!((got - exact).abs() < 1e-9, "{got} vs {exact}");
        // Error estimate should be small but nonzero.
        assert!(ws.err.row(0)[0].abs() > 0.0);
        assert!(ws.err.row(0)[0].abs() < 1e-6);
    }

    fn dt_f64(x: f64) -> f64 {
        x
    }

    /// Halving dt must reduce the one-step error by ~2^6 for dopri5
    /// (local error order = global order + 1).
    #[test]
    fn dopri5_local_order() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 1, 1);
        let y = BatchVec::from_rows(&[vec![1.0]]);
        let mut errs = Vec::new();
        for &dt in &[0.2, 0.1] {
            rk_attempt(&ct, &sys, &[0.0], &[dt], &y, &mut ws, &[false], None, true);
            errs.push((ws.y_new.row(0)[0] - (-dt).exp()).abs());
        }
        let ratio = errs[0] / errs[1];
        assert!(ratio > 40.0, "one-step error ratio {ratio} too small for order 5");
    }

    /// Per-instance dt: two instances stepped with different dt must land
    /// on their own exp(-dt).
    #[test]
    fn per_instance_dt() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 2, 1);
        let y = BatchVec::from_rows(&[vec![1.0], vec![1.0]]);
        rk_attempt(&ct, &sys, &[0.0, 0.0], &[0.05, 0.2], &y, &mut ws, &[false, false], None, true);
        assert!((ws.y_new.row(0)[0] - (-0.05f64).exp()).abs() < 1e-10);
        assert!((ws.y_new.row(1)[0] - (-0.2f64).exp()).abs() < 1e-6);
    }

    /// Inactive rows are not updated.
    #[test]
    fn inactive_rows_untouched() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 2, 1);
        ws.y_new.row_mut(0)[0] = 123.0;
        let y = BatchVec::from_rows(&[vec![1.0], vec![1.0]]);
        rk_attempt(
            &ct,
            &sys,
            &[0.0, 0.0],
            &[0.1, 0.1],
            &y,
            &mut ws,
            &[false, false],
            Some(&[false, true]),
            true,
        );
        assert_eq!(ws.y_new.row(0)[0], 123.0);
        assert!((ws.y_new.row(1)[0] - (-0.1f64).exp()).abs() < 1e-9);
    }

    /// FSAL reuse: priming k[0] with the exact slope and claiming
    /// `k0_ready` must give the same result as a cold start.
    #[test]
    fn fsal_cache_equivalence() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let y = BatchVec::from_rows(&[vec![2.0]]);

        let mut ws_cold = RkWorkspace::new(7, 1, 1);
        rk_attempt(&ct, &sys, &[0.0], &[0.1], &y, &mut ws_cold, &[false], None, true);

        let mut ws_warm = RkWorkspace::new(7, 1, 1);
        ws_warm.k[0].row_mut(0)[0] = -2.0; // f(0, 2) = -2
        rk_attempt(&ct, &sys, &[0.0], &[0.1], &y, &mut ws_warm, &[true], None, true);

        assert!((ws_cold.y_new.row(0)[0] - ws_warm.y_new.row(0)[0]).abs() < 1e-15);
    }

    /// Compiled tableau strips zeros.
    #[test]
    fn compiled_tableau_sparsity() {
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        // dopri5 b has zeros at positions 1 and 6.
        assert_eq!(ct.b_nz.len(), 5);
        assert!(ct.b_nz.iter().all(|&(j, _)| j != 1 && j != 6));
        // row 3 of a (stage 3) is fully dense (3 entries).
        assert_eq!(ct.a_nz[3].len(), 3);
    }
}
