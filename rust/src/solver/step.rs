//! The shared RK stage kernel.
//!
//! One "attempt" computes all stages, the 5th-order solution and the
//! embedded error for the whole batch with per-instance `(t, dt)`. The
//! dynamics are evaluated **once per stage for the entire batch** — the
//! same call pattern a GPU implementation uses, and the reason parallel
//! solving costs almost nothing extra (torchode §3).
//!
//! Implementation notes mirroring the paper's optimizations:
//!
//! - coefficients are pre-filtered for zeros ([`CompiledTableau`]), so the
//!   inner loops never multiply by 0 (torchode's `einsum` over a sparse b),
//! - stage accumulation, solution update and error estimate are each one
//!   fused pass over memory with no temporaries (`addcmul`-style),
//! - all buffers live in a pre-allocated [`RkWorkspace`] reused across
//!   steps ("pre-allocated buffers").
//!
//! The kernel is written against contiguous **row ranges**
//! ([`rk_attempt_rows`] over an [`RkRows`] view): [`rk_attempt`] is the
//! whole-batch case, and the exec layer ([`crate::exec`]) drives the same
//! code over disjoint shards of the workspace from a worker pool — which
//! is what makes sharded and serial solves bitwise-identical.

use super::active::ActiveSet;
use super::init::initial_step_batch;
use super::norm::scaled_sumsq_rows;
use super::tableau::Tableau;
use super::Tolerances;
use crate::problems::OdeSystem;
use crate::tensor::BatchVec;

/// Upper bound on tableau stages supported by the stack-allocated
/// row-slice hoists in the stage kernel. Sized to admit high-order
/// methods (Dopri8: 13 stages, Verner 9(8): 16); [`CompiledTableau::new`]
/// rejects anything larger instead of silently iterating empty slices.
pub const MAX_STAGES: usize = 16;

/// A tableau with zero coefficients stripped, built once per solve.
#[derive(Debug, Clone)]
pub struct CompiledTableau {
    pub tab: &'static Tableau,
    /// Per stage `s`: the nonzero `(j, a_sj)` pairs.
    pub a_nz: Vec<Vec<(usize, f64)>>,
    /// Nonzero `(j, b_j)` pairs.
    pub b_nz: Vec<(usize, f64)>,
    /// Nonzero `(j, b_err_j)` pairs.
    pub berr_nz: Vec<(usize, f64)>,
}

impl CompiledTableau {
    pub fn new(tab: &'static Tableau) -> Self {
        assert!(
            tab.stages <= MAX_STAGES,
            "tableau '{}' has {} stages but the stage kernel supports at most {MAX_STAGES} \
             (raise MAX_STAGES in solver/step.rs)",
            tab.name,
            tab.stages
        );
        let a_nz = (0..tab.stages)
            .map(|s| {
                if s == 0 {
                    Vec::new()
                } else {
                    tab.a_row(s)
                        .iter()
                        .enumerate()
                        .filter(|(_, &v)| v != 0.0)
                        .map(|(j, &v)| (j, v))
                        .collect()
                }
            })
            .collect();
        let b_nz =
            tab.b.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(j, &v)| (j, v)).collect();
        let berr_nz =
            tab.b_err.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(j, &v)| (j, v)).collect();
        Self { tab, a_nz, b_nz, berr_nz }
    }
}

/// Pre-allocated buffers for the RK attempt, reused across all steps of a
/// solve. Everything the kernel touches per attempt lives here, so the
/// steady state of a solve performs **zero heap allocations** (enforced
/// by `tests/alloc_regression.rs`).
pub struct RkWorkspace {
    /// Stage slopes `k[s]`, each `(batch, dim)`.
    pub k: Vec<BatchVec>,
    /// Stage input `y + dt Σ a k`.
    pub ytmp: BatchVec,
    /// Proposed solution.
    pub y_new: BatchVec,
    /// Raw embedded error estimate.
    pub err: BatchVec,
    /// Per-instance stage times.
    pub t_stage: Vec<f64>,
    /// Scratch: rows whose `k[0]` cache needs refreshing this attempt.
    pub cold: Vec<bool>,
    /// Scratch index list (cold-row gathers in the indexed kernel).
    pub idx: Vec<usize>,
}

impl RkWorkspace {
    pub fn new(stages: usize, batch: usize, dim: usize) -> Self {
        Self {
            k: (0..stages).map(|_| BatchVec::zeros(batch, dim)).collect(),
            ytmp: BatchVec::zeros(batch, dim),
            y_new: BatchVec::zeros(batch, dim),
            err: BatchVec::zeros(batch, dim),
            t_stage: vec![0.0; batch],
            cold: vec![false; batch],
            idx: Vec::with_capacity(batch),
        }
    }
}

/// A mutable row-range view of an [`RkWorkspace`]: the unit of work one
/// pool worker owns during a sharded attempt. `offset` maps local row `r`
/// to global instance `offset + r` for [`OdeSystem::f_rows`].
pub(crate) struct RkRows<'a> {
    pub offset: usize,
    pub rows: usize,
    pub dim: usize,
    /// Per stage: this range's rows of `k[s]`, flat `rows * dim`. Fixed
    /// capacity so building a view never allocates; only the first
    /// `tableau.stages` entries are populated, the rest are empty slices.
    pub k: [&'a mut [f64]; MAX_STAGES],
    pub ytmp: &'a mut [f64],
    pub y_new: &'a mut [f64],
    pub err: &'a mut [f64],
    pub t_stage: &'a mut [f64],
    pub cold: &'a mut [bool],
}

/// One row of the fused stage accumulation `out = y + h · Σ_j a_sj k_j`
/// (nonzero coefficients only, slope rows hoisted once per instance —
/// §Perf: per-element `row()` slicing cost ~35 % of the attempt at
/// dim 2). Shared by the masked ([`rk_attempt_rows`]) and active-set
/// ([`rk_attempt_active`]) kernels so their per-row arithmetic is
/// *structurally* bitwise-identical — the contract `tests/compaction.rs`
/// and the pooled merge depend on.
#[inline(always)]
fn accumulate_stage_row(
    nz: &[(usize, f64)],
    kprev: &[&mut [f64]],
    r: usize,
    dim: usize,
    h: f64,
    yrow: &[f64],
    out: &mut [f64],
) {
    match nz.len() {
        1 => {
            let (j0, w0) = nz[0];
            let k0 = &kprev[j0][r * dim..(r + 1) * dim];
            for d in 0..dim {
                out[d] = yrow[d] + h * w0 * k0[d];
            }
        }
        2 => {
            let (j0, w0) = nz[0];
            let (j1, w1) = nz[1];
            let k0 = &kprev[j0][r * dim..(r + 1) * dim];
            let k1 = &kprev[j1][r * dim..(r + 1) * dim];
            for d in 0..dim {
                out[d] = yrow[d] + h * (w0 * k0[d] + w1 * k1[d]);
            }
        }
        _ => {
            let mut krows: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
            for (slot, &(j, _)) in krows.iter_mut().zip(nz.iter()) {
                *slot = &kprev[j][r * dim..(r + 1) * dim];
            }
            for d in 0..dim {
                let mut acc = 0.0;
                for (idx, &(_, w)) in nz.iter().enumerate() {
                    acc += w * krows[idx][d];
                }
                out[d] = yrow[d] + h * acc;
            }
        }
    }
}

/// One row of the solution/error combination `out = base + h · Σ_j w_j k_j`
/// over the nonzero weights: `base = y` for the solution, absent for the
/// raw error estimate. Shared by both kernels (see
/// [`accumulate_stage_row`]).
#[inline(always)]
fn combine_row(
    wnz: &[(usize, f64)],
    k: &[&mut [f64]],
    r: usize,
    dim: usize,
    h: f64,
    base: Option<&[f64]>,
    out: &mut [f64],
) {
    let mut rows: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
    for (slot, &(j, _)) in rows.iter_mut().zip(wnz.iter()) {
        *slot = &k[j][r * dim..(r + 1) * dim];
    }
    for d in 0..dim {
        let mut acc = 0.0;
        for (idx, &(_, w)) in wnz.iter().enumerate() {
            acc += w * rows[idx][d];
        }
        out[d] = match base {
            Some(y) => y[d] + h * acc,
            None => h * acc,
        };
    }
}

/// Compute one RK attempt for a contiguous row range.
///
/// `t`, `dt`, `y` (flat `rows * dim`), `k0_ready` and `active` are local
/// slices aligned with the view. Semantics per row match the historical
/// whole-batch kernel exactly:
///
/// - `k0_ready[r]`: row `r`'s `k[0]` already holds `f(t_r, y_r)` (FSAL
///   cache, or an unchanged slope after a rejection).
/// - `active`: rows to update; inactive rows keep `ytmp = y` so the
///   batched dynamics evaluation still sees valid states (torchode's
///   "overhanging" model evaluations). If `eval_inactive` is false the
///   dynamics are told to skip inactive rows instead.
#[allow(clippy::too_many_arguments)]
pub(crate) fn rk_attempt_rows(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    t: &[f64],
    dt: &[f64],
    y: &[f64],
    rr: &mut RkRows<'_>,
    k0_ready: &[bool],
    active: Option<&[bool]>,
    eval_inactive: bool,
) {
    let tab = ct.tab;
    let rows = rr.rows;
    let dim = rr.dim;
    let eval_mask = if eval_inactive { None } else { active };

    // Stage 0: evaluate only where the cache is cold, leaving warm rows
    // untouched (the mask contract of `f_rows`). The mask lives in the
    // workspace view — no per-attempt allocation.
    let mut any_cold = false;
    for (r, &ready) in k0_ready.iter().enumerate() {
        let c = !ready && eval_mask.map_or(true, |m| m[r]);
        rr.cold[r] = c;
        any_cold |= c;
    }
    if any_cold {
        rr.t_stage.copy_from_slice(t);
        sys.f_rows(rr.offset, rows, &rr.t_stage[..], y, &mut rr.k[0][..], Some(&rr.cold[..]));
    }

    // Stages 1..S: ytmp = y + dt * Σ_j a_sj k_j, one fused pass per row.
    for s in 1..tab.stages {
        let nz = &ct.a_nz[s];
        let (kprev, krest) = rr.k.split_at_mut(s);
        for r in 0..rows {
            let act = active.map_or(true, |m| m[r]);
            let yrow = &y[r * dim..(r + 1) * dim];
            if !act {
                // Keep a valid state for the batched eval.
                rr.ytmp[r * dim..(r + 1) * dim].copy_from_slice(yrow);
                rr.t_stage[r] = t[r];
                continue;
            }
            let h = dt[r];
            rr.t_stage[r] = t[r] + tab.c[s] * h;
            let out = &mut rr.ytmp[r * dim..(r + 1) * dim];
            accumulate_stage_row(nz, kprev, r, dim, h, yrow, out);
        }
        // One batched dynamics call for this stage (this range's rows).
        sys.f_rows(rr.offset, rows, &rr.t_stage[..], &rr.ytmp[..], &mut krest[0][..], eval_mask);
    }

    // Solution + error in one fused pass per row, with hoisted slope rows.
    let has_err = !ct.berr_nz.is_empty();
    for r in 0..rows {
        if !active.map_or(true, |m| m[r]) {
            continue;
        }
        let h = dt[r];
        let yrow = &y[r * dim..(r + 1) * dim];
        let out = &mut rr.y_new[r * dim..(r + 1) * dim];
        combine_row(&ct.b_nz, &rr.k, r, dim, h, Some(yrow), out);
        if has_err {
            let out = &mut rr.err[r * dim..(r + 1) * dim];
            combine_row(&ct.berr_nz, &rr.k, r, dim, h, None, out);
        }
    }
}

/// Number of batched dynamics calls an attempt performs: one per stage
/// after the first, plus the stage-0 refresh iff any row's cache is cold.
/// Kept separate from the kernel so a sharded attempt (one physical call
/// per shard per stage) still counts one *semantic* batched call per
/// stage, matching torchode's accounting.
pub(crate) fn attempt_call_count(ct: &CompiledTableau, k0_ready: &[bool]) -> u64 {
    let stage0 = k0_ready.iter().any(|r| !r);
    u64::from(stage0) + (ct.tab.stages as u64 - 1)
}

/// Compute one RK attempt for the whole batch. See [`rk_attempt_rows`]
/// for the per-row semantics. Returns the number of batched dynamics
/// calls made.
#[allow(clippy::too_many_arguments)]
pub fn rk_attempt(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    t: &[f64],
    dt: &[f64],
    y: &BatchVec,
    ws: &mut RkWorkspace,
    k0_ready: &[bool],
    active: Option<&[bool]>,
    eval_inactive: bool,
) -> u64 {
    let batch = y.batch();
    let dim = y.dim();
    let mut k_it = ws.k.iter_mut();
    let mut rr = RkRows {
        offset: 0,
        rows: batch,
        dim,
        k: std::array::from_fn(|_| k_it.next().map_or_else(Default::default, |k| k.flat_mut())),
        ytmp: ws.ytmp.flat_mut(),
        y_new: ws.y_new.flat_mut(),
        err: ws.err.flat_mut(),
        t_stage: &mut ws.t_stage[..],
        cold: &mut ws.cold[..],
    };
    rk_attempt_rows(ct, sys, t, dt, y.flat(), &mut rr, k0_ready, active, eval_inactive);
    attempt_call_count(ct, k0_ready)
}

/// One RK attempt driven by the packed [`ActiveSet`]: stage accumulation
/// and the solution/error combination iterate **only the live slots**,
/// and the dynamics are evaluated through [`OdeSystem::f_rows_indexed`]
/// so a finished row costs literally zero per-row work when
/// `eval_inactive` is false. With `eval_inactive = true` every still
/// *materialized* slot keeps receiving torchode's overhanging model
/// evaluation (with the `ytmp = y` keep-alive); compaction retires slots
/// outright, which is the only point where the two modes' dynamics-call
/// row sets diverge — per-row results and the semantic batched-call
/// count (the return value) are identical either way.
#[allow(clippy::too_many_arguments)]
pub(crate) fn rk_attempt_active(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    act: &ActiveSet,
    finished: &[bool],
    t: &[f64],
    dt: &[f64],
    y: &BatchVec,
    ws: &mut RkWorkspace,
    k0_ready: &[bool],
    eval_inactive: bool,
) -> u64 {
    let tab = ct.tab;
    let dim = y.dim();
    let y_flat = y.flat();
    let live = act.live();
    let inst = act.inst_map();
    let eval_rows: &[usize] = if eval_inactive { act.all_slots() } else { live };

    // Stage 0: refresh cold slope caches among the rows the eval covers.
    // In the solve loops `k[0]` is always warm (FSAL hand-off or the
    // non-FSAL end-slope refresh), so this effectively never fires.
    let mut any_cold = false;
    for &r in eval_rows {
        let c = !k0_ready[r];
        ws.cold[r] = c;
        any_cold |= c;
    }
    let mut calls = tab.stages as u64 - 1;
    if any_cold {
        ws.idx.clear();
        for &r in eval_rows {
            if ws.cold[r] {
                ws.idx.push(r);
            }
        }
        for &r in &ws.idx {
            ws.t_stage[r] = t[r];
        }
        sys.f_rows_indexed(0, inst, &ws.idx, &ws.t_stage, y_flat, ws.k[0].flat_mut());
        calls += 1;
    }

    // Keep-alive for finished-but-materialized slots: the overhanging
    // evaluations below must see a valid (t, y). Their state never
    // changes between stages, so one copy per attempt suffices.
    if eval_inactive {
        for &r in act.all_slots() {
            if finished[r] {
                ws.ytmp.row_mut(r).copy_from_slice(&y_flat[r * dim..(r + 1) * dim]);
                ws.t_stage[r] = t[r];
            }
        }
    }

    let ytmp = ws.ytmp.flat_mut();
    let t_stage = &mut ws.t_stage[..];
    let mut k_it = ws.k.iter_mut();
    let mut k_bufs: [&mut [f64]; MAX_STAGES] =
        std::array::from_fn(|_| k_it.next().map_or_else(Default::default, |k| k.flat_mut()));

    // Stages 1..S over the live slots only. The per-row arithmetic is the
    // shared `accumulate_stage_row`, so bitwise identity with the masked
    // kernel is structural, not by convention.
    for s in 1..tab.stages {
        let nz = &ct.a_nz[s];
        let (kprev, krest) = k_bufs.split_at_mut(s);
        for &r in live {
            let h = dt[r];
            let yrow = &y_flat[r * dim..(r + 1) * dim];
            t_stage[r] = t[r] + tab.c[s] * h;
            let out = &mut ytmp[r * dim..(r + 1) * dim];
            accumulate_stage_row(nz, kprev, r, dim, h, yrow, out);
        }
        sys.f_rows_indexed(0, inst, eval_rows, t_stage, ytmp, &mut krest[0][..]);
    }

    // Solution + error for the live slots, one fused pass per row.
    let y_new = ws.y_new.flat_mut();
    let err = ws.err.flat_mut();
    let has_err = !ct.berr_nz.is_empty();
    for &r in live {
        let h = dt[r];
        let yrow = &y_flat[r * dim..(r + 1) * dim];
        let out = &mut y_new[r * dim..(r + 1) * dim];
        combine_row(&ct.b_nz, &k_bufs, r, dim, h, Some(yrow), out);
        if has_err {
            let out = &mut err[r * dim..(r + 1) * dim];
            combine_row(&ct.berr_nz, &k_bufs, r, dim, h, None, out);
        }
    }
    calls
}

/// Executes the batched pieces of the joint solve loop. [`InlineExec`]
/// runs them on the calling thread; `crate::exec::PooledExec` shards the
/// row-update passes across a scoped worker pool while the loop's shared
/// controller reduction stays on the coordinator. Implementations must be
/// bitwise row-equivalent to the inline path.
pub(crate) trait StageExec {
    /// State dimension of the underlying system.
    fn dim(&self) -> usize;

    /// One batched dynamics evaluation (initial slopes, non-FSAL refresh).
    fn eval(&self, t: &[f64], y: &BatchVec, dy: &mut BatchVec, active: Option<&[bool]>);

    /// One full RK attempt over the batch; returns the batched-call count.
    #[allow(clippy::too_many_arguments)]
    fn attempt(
        &self,
        ct: &CompiledTableau,
        t: &[f64],
        dt: &[f64],
        y: &BatchVec,
        ws: &mut RkWorkspace,
        k0_ready: &[bool],
        active: Option<&[bool]>,
        eval_inactive: bool,
    ) -> u64;

    /// The initial step-size heuristic (costs one extra batched eval).
    #[allow(clippy::too_many_arguments)]
    fn initial_step(
        &self,
        t0: &[f64],
        y0: &BatchVec,
        f0: &BatchVec,
        order: usize,
        tols: &Tolerances,
        span: &[f64],
        scratch_y: &mut BatchVec,
        scratch_f: &mut BatchVec,
    ) -> Vec<f64>;

    /// The fused joint error-norm pass: write each row's unreduced scaled
    /// sum of squares ([`crate::solver::norm::scaled_sumsq`] of `err`
    /// against `max(|y0|, |y1|)` under the row's tolerances) into
    /// `out[row]`. Rows may be computed by any worker in any order — the
    /// per-row arithmetic is position-independent and the joint loop
    /// reduces `out` on the coordinator in row order, so the final norm
    /// is bitwise-identical across executors.
    fn error_sumsq(
        &self,
        err: &BatchVec,
        y0: &BatchVec,
        y1: &BatchVec,
        tols: &Tolerances,
        out: &mut [f64],
    );
}

/// The serial [`StageExec`]: everything on the calling thread.
pub(crate) struct InlineExec<'a> {
    pub sys: &'a dyn OdeSystem,
}

impl StageExec for InlineExec<'_> {
    fn dim(&self) -> usize {
        self.sys.dim()
    }

    fn eval(&self, t: &[f64], y: &BatchVec, dy: &mut BatchVec, active: Option<&[bool]>) {
        self.sys.f_batch(t, y, dy, active);
    }

    fn attempt(
        &self,
        ct: &CompiledTableau,
        t: &[f64],
        dt: &[f64],
        y: &BatchVec,
        ws: &mut RkWorkspace,
        k0_ready: &[bool],
        active: Option<&[bool]>,
        eval_inactive: bool,
    ) -> u64 {
        rk_attempt(ct, self.sys, t, dt, y, ws, k0_ready, active, eval_inactive)
    }

    fn initial_step(
        &self,
        t0: &[f64],
        y0: &BatchVec,
        f0: &BatchVec,
        order: usize,
        tols: &Tolerances,
        span: &[f64],
        scratch_y: &mut BatchVec,
        scratch_f: &mut BatchVec,
    ) -> Vec<f64> {
        initial_step_batch(self.sys, t0, y0, f0, order, tols, span, scratch_y, scratch_f)
    }

    fn error_sumsq(
        &self,
        err: &BatchVec,
        y0: &BatchVec,
        y1: &BatchVec,
        tols: &Tolerances,
        out: &mut [f64],
    ) {
        scaled_sumsq_rows(err, y0, y1, tols, 0, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, OdeSystem};
    use crate::solver::tableau::{self, DenseOutput};

    /// One dopri5 step on dy/dt = -y must be 5th-order accurate.
    #[test]
    fn dopri5_single_step_accuracy() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 1, 1);
        let y = BatchVec::from_rows(&[vec![1.0]]);
        let dt = 0.1;
        rk_attempt(&ct, &sys, &[0.0], &[dt], &y, &mut ws, &[false], None, true);
        let exact = (-dt_f64(dt)).exp();
        let got = ws.y_new.row(0)[0];
        assert!((got - exact).abs() < 1e-9, "{got} vs {exact}");
        // Error estimate should be small but nonzero.
        assert!(ws.err.row(0)[0].abs() > 0.0);
        assert!(ws.err.row(0)[0].abs() < 1e-6);
    }

    fn dt_f64(x: f64) -> f64 {
        x
    }

    /// Halving dt must reduce the one-step error by ~2^6 for dopri5
    /// (local error order = global order + 1).
    #[test]
    fn dopri5_local_order() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 1, 1);
        let y = BatchVec::from_rows(&[vec![1.0]]);
        let mut errs = Vec::new();
        for &dt in &[0.2, 0.1] {
            rk_attempt(&ct, &sys, &[0.0], &[dt], &y, &mut ws, &[false], None, true);
            errs.push((ws.y_new.row(0)[0] - (-dt).exp()).abs());
        }
        let ratio = errs[0] / errs[1];
        assert!(ratio > 40.0, "one-step error ratio {ratio} too small for order 5");
    }

    /// Per-instance dt: two instances stepped with different dt must land
    /// on their own exp(-dt).
    #[test]
    fn per_instance_dt() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 2, 1);
        let y = BatchVec::from_rows(&[vec![1.0], vec![1.0]]);
        rk_attempt(&ct, &sys, &[0.0, 0.0], &[0.05, 0.2], &y, &mut ws, &[false, false], None, true);
        assert!((ws.y_new.row(0)[0] - (-0.05f64).exp()).abs() < 1e-10);
        assert!((ws.y_new.row(1)[0] - (-0.2f64).exp()).abs() < 1e-6);
    }

    /// Inactive rows are not updated.
    #[test]
    fn inactive_rows_untouched() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 2, 1);
        ws.y_new.row_mut(0)[0] = 123.0;
        let y = BatchVec::from_rows(&[vec![1.0], vec![1.0]]);
        rk_attempt(
            &ct,
            &sys,
            &[0.0, 0.0],
            &[0.1, 0.1],
            &y,
            &mut ws,
            &[false, false],
            Some(&[false, true]),
            true,
        );
        assert_eq!(ws.y_new.row(0)[0], 123.0);
        assert!((ws.y_new.row(1)[0] - (-0.1f64).exp()).abs() < 1e-9);
    }

    /// FSAL reuse: priming k[0] with the exact slope and claiming
    /// `k0_ready` must give the same result as a cold start.
    #[test]
    fn fsal_cache_equivalence() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let y = BatchVec::from_rows(&[vec![2.0]]);

        let mut ws_cold = RkWorkspace::new(7, 1, 1);
        rk_attempt(&ct, &sys, &[0.0], &[0.1], &y, &mut ws_cold, &[false], None, true);

        let mut ws_warm = RkWorkspace::new(7, 1, 1);
        ws_warm.k[0].row_mut(0)[0] = -2.0; // f(0, 2) = -2
        rk_attempt(&ct, &sys, &[0.0], &[0.1], &y, &mut ws_warm, &[true], None, true);

        assert!((ws_cold.y_new.row(0)[0] - ws_warm.y_new.row(0)[0]).abs() < 1e-15);
    }

    /// Compiled tableau strips zeros.
    #[test]
    fn compiled_tableau_sparsity() {
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        // dopri5 b has zeros at positions 1 and 6.
        assert_eq!(ct.b_nz.len(), 5);
        assert!(ct.b_nz.iter().all(|&(j, _)| j != 1 && j != 6));
        // row 3 of a (stage 3) is fully dense (3 entries).
        assert_eq!(ct.a_nz[3].len(), 3);
    }

    /// Every registered tableau fits the stage-kernel bound, and call
    /// counting matches the stage structure.
    #[test]
    fn all_tableaus_within_stage_bound() {
        for t in tableau::ALL {
            assert!(t.stages <= MAX_STAGES, "{}", t.name);
        }
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        assert_eq!(attempt_call_count(&ct, &[true, true]), 6);
        assert_eq!(attempt_call_count(&ct, &[true, false]), 7);
    }

    /// A tableau beyond the bound is rejected loudly instead of silently
    /// corrupting stage accumulation (the old fixed `[&[f64]; 8]` bug).
    #[test]
    #[should_panic(expected = "stages")]
    fn compiled_tableau_rejects_too_many_stages() {
        let stages = MAX_STAGES + 1;
        let a: &'static [f64] = Box::leak(vec![0.0; stages * (stages - 1) / 2].into_boxed_slice());
        let b: &'static [f64] = Box::leak(vec![0.0; stages].into_boxed_slice());
        let c: &'static [f64] = Box::leak(vec![0.0; stages].into_boxed_slice());
        let tab: &'static Tableau = Box::leak(Box::new(Tableau {
            name: "too-big",
            stages,
            order: 1,
            err_order: 0,
            a,
            b,
            b_err: &[],
            c,
            fsal: false,
            dense: DenseOutput::Hermite,
        }));
        CompiledTableau::new(tab);
    }

    /// A >8-nonzero stage row accumulates every slope (regression test for
    /// the silent 8-slot cap): a 10-stage method whose last stage sums 9
    /// previous slopes of f ≡ 1 must produce ytmp = y + dt·Σa.
    #[test]
    fn wide_stage_rows_accumulate_fully() {
        struct Constant;
        impl OdeSystem for Constant {
            fn dim(&self) -> usize {
                1
            }
            fn f_inst(&self, _i: usize, _t: f64, _y: &[f64], dy: &mut [f64]) {
                dy[0] = 1.0;
            }
        }
        let stages = 10;
        let mut a = Vec::new();
        for s in 1..stages {
            // Dense row: every coefficient 0.1.
            a.extend(vec![0.1; s]);
        }
        let mut b = vec![0.0; stages];
        b[stages - 1] = 1.0;
        let mut c = vec![0.0; stages];
        for (s, ci) in c.iter_mut().enumerate() {
            *ci = 0.1 * s as f64;
        }
        let tab: &'static Tableau = Box::leak(Box::new(Tableau {
            name: "wide",
            stages,
            order: 1,
            err_order: 0,
            a: Box::leak(a.into_boxed_slice()),
            b: Box::leak(b.into_boxed_slice()),
            b_err: &[],
            c: Box::leak(c.into_boxed_slice()),
            fsal: false,
            dense: DenseOutput::Hermite,
        }));
        let ct = CompiledTableau::new(tab);
        assert_eq!(ct.a_nz[stages - 1].len(), 9, "needs > 8 nonzero slots");
        let sys = Constant;
        let mut ws = RkWorkspace::new(stages, 1, 1);
        let y = BatchVec::from_rows(&[vec![0.0]]);
        rk_attempt(&ct, &sys, &[0.0], &[1.0], &y, &mut ws, &[false], None, true);
        // Last stage input: y + dt · Σ_j 0.1 · k_j = 0.9 (all k = 1); the
        // solution is y + dt · b_last · k_last = 1.0.
        assert!((ws.y_new.row(0)[0] - 1.0).abs() < 1e-15);
        // And the stage input actually saw all 9 slopes.
        assert!((ws.ytmp.row(0)[0] - 0.9).abs() < 1e-15);
    }
}
