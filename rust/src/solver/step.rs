//! The shared RK stage kernel.
//!
//! One "attempt" computes all stages, the 5th-order solution and the
//! embedded error for the whole batch with per-instance `(t, dt)`. The
//! dynamics are evaluated **once per stage for the entire batch** — the
//! same call pattern a GPU implementation uses, and the reason parallel
//! solving costs almost nothing extra (torchode §3).
//!
//! Implementation notes mirroring the paper's optimizations:
//!
//! - coefficients are pre-filtered for zeros ([`CompiledTableau`]), so the
//!   inner loops never multiply by 0 (torchode's `einsum` over a sparse b),
//! - stage accumulation, solution update and error estimate are each one
//!   fused pass over memory with no temporaries (`addcmul`-style),
//! - all buffers live in a pre-allocated [`RkWorkspace`] reused across
//!   steps ("pre-allocated buffers").
//!
//! The kernel is written against contiguous **row ranges**
//! ([`rk_attempt_rows`] over an [`RkRows`] view): [`rk_attempt`] is the
//! whole-batch case, and the exec layer ([`crate::exec`]) drives the same
//! code over disjoint shards of the workspace from a worker pool — which
//! is what makes sharded and serial solves bitwise-identical.
//!
//! The per-row arithmetic itself lives in [`super::kernels`]: lane-blocked
//! (width-4/width-8 `chunks_exact`) passes whose per-element expressions
//! are bit-identical to the straight-line scalar kernels they replaced,
//! with the solution and embedded-error combinations **fused into one
//! traversal** of the slope rows. With
//! [`crate::tensor::Layout::DimMajor`] the same arithmetic runs over a
//! dim-major (SoA) mirror of the workspace ([`RkWorkspace`] carries the
//! lanes), vectorizing across the batch instead of across `dim` — results
//! are bitwise-identical in both layouts (`tests/kernel_parity.rs`).
//!
//! Implicit (ESDIRK) tableaus dispatch from the same entry points to the
//! per-row Newton kernel in [`super::implicit`]: every attempt signature,
//! loop, pool kind and the active-set machinery work unchanged, only the
//! stage arithmetic differs. Implicit workspaces carry the Newton
//! scratch ([`RkWorkspace::new_for_tableau`]).

#![warn(missing_docs)]

use super::active::ActiveSet;
use super::implicit::{self, NewtonRows, NewtonWs};
use super::init::initial_step_batch;
use super::kernels;
use super::norm::scaled_sumsq_rows;
use super::tableau::Tableau;
use super::Tolerances;
use crate::problems::{JacStructure, OdeSystem};
use crate::tensor::{BatchVec, LaneStore, Layout};

/// Upper bound on tableau stages supported by the stack-allocated
/// row-slice hoists in the stage kernel. Sized to admit high-order
/// methods (Dopri8: 13 stages, Verner 9(8): 16); [`CompiledTableau::new`]
/// rejects anything larger instead of silently iterating empty slices.
pub const MAX_STAGES: usize = 16;

/// A tableau with zero coefficients stripped. Use
/// [`CompiledTableau::cached`] in solve loops — the sparsity analysis
/// runs **once per process per method**, not once per (sub-)solve, so
/// pooled per-shard sub-solves stop re-deriving it.
#[derive(Debug, Clone)]
pub struct CompiledTableau {
    /// The backing Butcher tableau.
    pub tab: &'static Tableau,
    /// Per stage `s`: the nonzero `(j, a_sj)` pairs.
    pub a_nz: Vec<Vec<(usize, f64)>>,
    /// Nonzero `(j, b_j)` pairs.
    pub b_nz: Vec<(usize, f64)>,
    /// Nonzero `(j, b_err_j)` pairs.
    pub berr_nz: Vec<(usize, f64)>,
    /// The shared implicit diagonal coefficient γ of an (ES)DIRK tableau
    /// (`0.0` for explicit methods). Derived from `Tableau::diag` with
    /// the single-γ structure checked, so one LU of `I − hγJ` per step
    /// serves every implicit stage ([`super::implicit`]); the same
    /// matrix, transposed, carries the implicit-function-theorem
    /// backward solves in [`super::backprop`].
    pub gamma: f64,
}

impl CompiledTableau {
    /// The cached compiled tableau for `method` — a thin delegate to the
    /// method registry ([`super::MethodId::compiled`]), which keys the
    /// cache on registry slots: one compile per registered method for
    /// the life of the process, shared by every per-solve and per-shard
    /// entry point (and valid for runtime-registered methods too).
    pub fn cached(method: super::MethodId) -> &'static CompiledTableau {
        method.compiled()
    }

    /// Compile `tab` directly (zero-stripping + stage-count check).
    /// Prefer [`CompiledTableau::cached`] for registered methods.
    pub fn new(tab: &'static Tableau) -> Self {
        assert!(
            tab.stages <= MAX_STAGES,
            "tableau '{}' has {} stages but the stage kernel supports at most {MAX_STAGES} \
             (raise MAX_STAGES in solver/step.rs)",
            tab.name,
            tab.stages
        );
        let a_nz = (0..tab.stages)
            .map(|s| {
                if s == 0 {
                    Vec::new()
                } else {
                    tab.a_row(s)
                        .iter()
                        .enumerate()
                        .filter(|(_, &v)| v != 0.0)
                        .map(|(j, &v)| (j, v))
                        .collect()
                }
            })
            .collect();
        let b_nz =
            tab.b.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(j, &v)| (j, v)).collect();
        let berr_nz =
            tab.b_err.iter().enumerate().filter(|(_, &v)| v != 0.0).map(|(j, &v)| (j, v)).collect();
        let gamma = if tab.diag.is_empty() {
            0.0
        } else {
            assert_eq!(
                tab.diag.len(),
                tab.stages,
                "tableau '{}': diag must have one entry per stage",
                tab.name
            );
            let g = tab.diag.iter().copied().find(|&d| d != 0.0).unwrap_or(0.0);
            assert!(g > 0.0, "tableau '{}': implicit diagonal must be positive", tab.name);
            for (s, &d) in tab.diag.iter().enumerate() {
                assert!(
                    d == 0.0 || d == g,
                    "tableau '{}' stage {s}: only single-γ (ES)DIRK diagonals are supported",
                    tab.name
                );
            }
            g
        };
        Self { tab, a_nz, b_nz, berr_nz, gamma }
    }

    /// Whether this tableau has implicit stages (dispatches the attempt
    /// to the Newton-based kernel in [`super::implicit`]).
    #[inline]
    pub fn is_implicit(&self) -> bool {
        self.gamma != 0.0
    }
}

/// The dim-major (SoA) mirrors of the attempt buffers — allocated once
/// per solve when [`Layout::DimMajor`] is selected, `None` otherwise.
/// The mirrors are pure per-attempt scratch: they are (re)filled by
/// transposes from the row-major sources at the attempt boundary, so
/// compaction and the FSAL hand-off never need to touch them.
pub(crate) struct DimScratch {
    /// Lanes of the committed state `y`.
    y: LaneStore,
    /// Lanes of the stage slopes `k[s]`.
    k: Vec<LaneStore>,
    /// Lanes of the stage input.
    ytmp: LaneStore,
    /// Lanes of the proposed solution.
    y_new: LaneStore,
    /// Lanes of the raw error estimate.
    err: LaneStore,
}

/// Pre-allocated buffers for the RK attempt, reused across all steps of a
/// solve. Everything the kernel touches per attempt lives here, so the
/// steady state of a solve performs **zero heap allocations** (enforced
/// by `tests/alloc_regression.rs`) — in either layout.
pub struct RkWorkspace {
    /// Stage slopes `k[s]`, each `(batch, dim)`.
    pub k: Vec<BatchVec>,
    /// Stage input `y + dt Σ a k`.
    pub ytmp: BatchVec,
    /// Proposed solution.
    pub y_new: BatchVec,
    /// Raw embedded error estimate.
    pub err: BatchVec,
    /// Per-instance stage times.
    pub t_stage: Vec<f64>,
    /// Scratch: rows whose `k[0]` cache needs refreshing this attempt.
    pub cold: Vec<bool>,
    /// Scratch index list (cold-row gathers in the indexed kernel).
    pub idx: Vec<usize>,
    /// Dim-major mirrors (`Some` iff the workspace was built with
    /// [`Layout::DimMajor`]).
    pub(crate) dm: Option<DimScratch>,
    /// Newton scratch + Jacobian/LU reuse state for implicit methods
    /// (`Some` iff the workspace was built via
    /// [`RkWorkspace::new_for_tableau`] with an implicit tableau).
    pub(crate) newton: Option<NewtonWs>,
}

impl RkWorkspace {
    /// Row-major workspace (the default layout).
    pub fn new(stages: usize, batch: usize, dim: usize) -> Self {
        Self::new_with_layout(stages, batch, dim, Layout::RowMajor)
    }

    /// Workspace in an explicit [`Layout`]; `DimMajor` additionally
    /// allocates the SoA mirrors the lane passes run over.
    pub fn new_with_layout(stages: usize, batch: usize, dim: usize, layout: Layout) -> Self {
        let dm = match layout {
            Layout::RowMajor => None,
            Layout::DimMajor => Some(DimScratch {
                y: LaneStore::new(batch, dim),
                k: (0..stages).map(|_| LaneStore::new(batch, dim)).collect(),
                ytmp: LaneStore::new(batch, dim),
                y_new: LaneStore::new(batch, dim),
                err: LaneStore::new(batch, dim),
            }),
        };
        Self {
            k: (0..stages).map(|_| BatchVec::zeros(batch, dim)).collect(),
            ytmp: BatchVec::zeros(batch, dim),
            y_new: BatchVec::zeros(batch, dim),
            err: BatchVec::zeros(batch, dim),
            t_stage: vec![0.0; batch],
            cold: vec![false; batch],
            idx: Vec::with_capacity(batch),
            dm,
            newton: None,
        }
    }

    /// Workspace sized for a compiled tableau: the explicit buffers in
    /// the requested [`Layout`], plus the per-slot Newton scratch
    /// ([`super::implicit`]) when the tableau is implicit — sized for
    /// the given Jacobian structure (`jac`), which selects dense or
    /// banded factorization storage (O(dim²) vs O(dim·bandwidth) per
    /// slot). Implicit attempts are layout-blind (the per-row Newton
    /// solves have no lane passes to transpose for), so an implicit
    /// workspace skips the SoA mirrors a `DimMajor` request would
    /// otherwise allocate — results are bitwise-identical in both
    /// layouts either way. This is the constructor the solve loops use.
    pub fn new_for_tableau(
        ct: &CompiledTableau,
        batch: usize,
        dim: usize,
        layout: Layout,
        tols: &Tolerances,
        jac: JacStructure,
    ) -> Self {
        let layout = if ct.is_implicit() { Layout::RowMajor } else { layout };
        let mut ws = Self::new_with_layout(ct.tab.stages, batch, dim, layout);
        if ct.is_implicit() {
            ws.newton = Some(NewtonWs::new(batch, dim, tols, jac));
        }
        ws
    }

    /// The layout this workspace was built with.
    pub fn layout(&self) -> Layout {
        if self.dm.is_some() {
            Layout::DimMajor
        } else {
            Layout::RowMajor
        }
    }
}

/// A mutable row-range view of an [`RkWorkspace`]: the unit of work one
/// pool worker owns during a sharded attempt. `offset` maps local row `r`
/// to global instance `offset + r` for [`OdeSystem::f_rows`].
pub(crate) struct RkRows<'a> {
    pub offset: usize,
    pub rows: usize,
    pub dim: usize,
    /// Per stage: this range's rows of `k[s]`, flat `rows * dim`. Fixed
    /// capacity so building a view never allocates; only the first
    /// `tableau.stages` entries are populated, the rest are empty slices.
    pub k: [&'a mut [f64]; MAX_STAGES],
    pub ytmp: &'a mut [f64],
    pub y_new: &'a mut [f64],
    pub err: &'a mut [f64],
    pub t_stage: &'a mut [f64],
    pub cold: &'a mut [bool],
    /// This range's view of the Newton scratch (`Some` iff the workspace
    /// carries implicit state; see [`RkWorkspace::new_for_tableau`]).
    pub newton: Option<NewtonRows<'a>>,
}

/// One row of the fused stage accumulation `out = y + h · Σ_j a_sj k_j`
/// (nonzero coefficients only, slope rows hoisted once per instance —
/// §Perf: per-element `row()` slicing cost ~35 % of the attempt at
/// dim 2). The arithmetic is the lane-blocked
/// [`kernels::stage_row`], bit-identical per element to the historical
/// scalar body ([`kernels::scalar::stage_row`]). Shared by the masked
/// ([`rk_attempt_rows`]) and active-set ([`rk_attempt_active`]) kernels
/// so their per-row arithmetic is *structurally* bitwise-identical — the
/// contract `tests/compaction.rs` and the pooled merge depend on.
#[inline(always)]
pub(crate) fn accumulate_stage_row(
    nz: &[(usize, f64)],
    kprev: &[&mut [f64]],
    r: usize,
    dim: usize,
    h: f64,
    yrow: &[f64],
    out: &mut [f64],
) {
    // 1- and 2-term rows skip the MAX_STAGES hoist arrays entirely (the
    // common dopri5/tsit5 early stages; per-row overhead matters at
    // dim 2).
    match nz.len() {
        1 => {
            let (j0, w0) = nz[0];
            kernels::stage_row(out, yrow, h, &[w0], &[&kprev[j0][r * dim..(r + 1) * dim]]);
        }
        2 => {
            let (j0, w0) = nz[0];
            let (j1, w1) = nz[1];
            kernels::stage_row(
                out,
                yrow,
                h,
                &[w0, w1],
                &[&kprev[j0][r * dim..(r + 1) * dim], &kprev[j1][r * dim..(r + 1) * dim]],
            );
        }
        _ => {
            let mut w: [f64; MAX_STAGES] = [0.0; MAX_STAGES];
            let mut kr: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
            for (i, &(j, wj)) in nz.iter().enumerate() {
                w[i] = wj;
                kr[i] = &kprev[j][r * dim..(r + 1) * dim];
            }
            kernels::stage_row(out, yrow, h, &w[..nz.len()], &kr[..nz.len()]);
        }
    }
}

/// One row of the **fused** attempt tail: the 5th-order solution and the
/// embedded error in a single traversal of the hoisted slope rows
/// ([`kernels::combine_pair_row`]) — one pass over memory where the
/// historical kernel made two. Falls back to the solution-only
/// combination for tableaus without an embedded error. Per-element
/// arithmetic of each output is unchanged (own accumulator, own
/// coefficient order), so the fusion is bitwise-invisible.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub(crate) fn combine_rows_fused(
    ct: &CompiledTableau,
    k: &[&mut [f64]],
    r: usize,
    dim: usize,
    h: f64,
    yrow: &[f64],
    y_new: &mut [f64],
    err: &mut [f64],
    has_err: bool,
) {
    let nb = ct.b_nz.len();
    let mut bw: [f64; MAX_STAGES] = [0.0; MAX_STAGES];
    let mut bk: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
    for (i, &(j, wj)) in ct.b_nz.iter().enumerate() {
        bw[i] = wj;
        bk[i] = &k[j][r * dim..(r + 1) * dim];
    }
    if has_err {
        let ne = ct.berr_nz.len();
        let mut ew: [f64; MAX_STAGES] = [0.0; MAX_STAGES];
        let mut ek: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
        for (i, &(j, wj)) in ct.berr_nz.iter().enumerate() {
            ew[i] = wj;
            ek[i] = &k[j][r * dim..(r + 1) * dim];
        }
        kernels::combine_pair_row(y_new, err, yrow, h, &bw[..nb], &bk[..nb], &ew[..ne], &ek[..ne]);
    } else {
        kernels::combine_row(y_new, Some(yrow), h, &bw[..nb], &bk[..nb]);
    }
}

/// Compute one RK attempt for a contiguous row range.
///
/// `t`, `dt`, `y` (flat `rows * dim`), `k0_ready` and `active` are local
/// slices aligned with the view. Semantics per row match the historical
/// whole-batch kernel exactly:
///
/// - `k0_ready[r]`: row `r`'s `k[0]` already holds `f(t_r, y_r)` (FSAL
///   cache, or an unchanged slope after a rejection).
/// - `active`: rows to update; inactive rows keep `ytmp = y` so the
///   batched dynamics evaluation still sees valid states (torchode's
///   "overhanging" model evaluations). If `eval_inactive` is false the
///   dynamics are told to skip inactive rows instead.
#[allow(clippy::too_many_arguments)]
pub(crate) fn rk_attempt_rows(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    t: &[f64],
    dt: &[f64],
    y: &[f64],
    rr: &mut RkRows<'_>,
    k0_ready: &[bool],
    active: Option<&[bool]>,
    eval_inactive: bool,
) {
    if ct.is_implicit() {
        // Implicit stages are solved per row by Newton iteration;
        // `eval_inactive` has no effect (there are no batched stage
        // evaluations to overhang onto inactive rows).
        implicit::implicit_attempt_rows(ct, sys, t, dt, y, rr, k0_ready, active);
        return;
    }
    let tab = ct.tab;
    let rows = rr.rows;
    let dim = rr.dim;
    let eval_mask = if eval_inactive { None } else { active };

    // Stage 0: evaluate only where the cache is cold, leaving warm rows
    // untouched (the mask contract of `f_rows`). The mask lives in the
    // workspace view — no per-attempt allocation.
    let mut any_cold = false;
    for (r, &ready) in k0_ready.iter().enumerate() {
        let c = !ready && eval_mask.map_or(true, |m| m[r]);
        rr.cold[r] = c;
        any_cold |= c;
    }
    if any_cold {
        rr.t_stage.copy_from_slice(t);
        sys.f_rows(rr.offset, rows, &rr.t_stage[..], y, &mut rr.k[0][..], Some(&rr.cold[..]));
    }

    // Stages 1..S: ytmp = y + dt * Σ_j a_sj k_j, one fused pass per row.
    for s in 1..tab.stages {
        let nz = &ct.a_nz[s];
        let (kprev, krest) = rr.k.split_at_mut(s);
        for r in 0..rows {
            let act = active.map_or(true, |m| m[r]);
            let yrow = &y[r * dim..(r + 1) * dim];
            if !act {
                // Keep a valid state for the batched eval.
                rr.ytmp[r * dim..(r + 1) * dim].copy_from_slice(yrow);
                rr.t_stage[r] = t[r];
                continue;
            }
            let h = dt[r];
            rr.t_stage[r] = t[r] + tab.c[s] * h;
            let out = &mut rr.ytmp[r * dim..(r + 1) * dim];
            accumulate_stage_row(nz, kprev, r, dim, h, yrow, out);
        }
        // One batched dynamics call for this stage (this range's rows).
        sys.f_rows(rr.offset, rows, &rr.t_stage[..], &rr.ytmp[..], &mut krest[0][..], eval_mask);
    }

    // Solution + error in one fused traversal per row, with hoisted
    // slope rows (the `k` blocks are pulled through cache once).
    let has_err = !ct.berr_nz.is_empty();
    for r in 0..rows {
        if !active.map_or(true, |m| m[r]) {
            continue;
        }
        let h = dt[r];
        let yrow = &y[r * dim..(r + 1) * dim];
        let y_new = &mut rr.y_new[r * dim..(r + 1) * dim];
        let err = &mut rr.err[r * dim..(r + 1) * dim];
        combine_rows_fused(ct, &rr.k, r, dim, h, yrow, y_new, err, has_err);
    }
}

/// Number of batched dynamics calls an attempt performs: one per stage
/// after the first, plus the stage-0 refresh iff any row's cache is cold.
/// Kept separate from the kernel so a sharded attempt (one physical call
/// per shard per stage) still counts one *semantic* batched call per
/// stage, matching torchode's accounting.
pub(crate) fn attempt_call_count(ct: &CompiledTableau, k0_ready: &[bool]) -> u64 {
    let stage0 = k0_ready.iter().any(|r| !r);
    u64::from(stage0) + (ct.tab.stages as u64 - 1)
}

/// Compute one RK attempt for the whole batch. See [`rk_attempt_rows`]
/// for the per-row semantics. Returns the number of batched dynamics
/// calls made.
///
/// With a [`Layout::DimMajor`] workspace and no activity mask (the
/// joint-loop shape) the attempt runs over the SoA lanes — bitwise the
/// same result, different traversal order. A masked attempt always takes
/// the row-major path regardless of workspace layout.
#[allow(clippy::too_many_arguments)]
pub fn rk_attempt(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    t: &[f64],
    dt: &[f64],
    y: &BatchVec,
    ws: &mut RkWorkspace,
    k0_ready: &[bool],
    active: Option<&[bool]>,
    eval_inactive: bool,
) -> u64 {
    if ws.dm.is_some() && active.is_none() && !ct.is_implicit() {
        // Every row is active, so the eval mask is None whatever
        // `eval_inactive` says — the dim-major attempt ignores it.
        return rk_attempt_dm(ct, sys, t, dt, y, ws, k0_ready);
    }
    let batch = y.batch();
    let dim = y.dim();
    let newton = ws.newton.as_mut().map(|nw| nw.view_mut());
    let mut k_it = ws.k.iter_mut();
    let mut rr = RkRows {
        offset: 0,
        rows: batch,
        dim,
        k: std::array::from_fn(|_| k_it.next().map_or_else(Default::default, |k| k.flat_mut())),
        ytmp: ws.ytmp.flat_mut(),
        y_new: ws.y_new.flat_mut(),
        err: ws.err.flat_mut(),
        t_stage: &mut ws.t_stage[..],
        cold: &mut ws.cold[..],
        newton,
    };
    rk_attempt_rows(ct, sys, t, dt, y.flat(), &mut rr, k0_ready, active, eval_inactive);
    attempt_call_count(ct, k0_ready)
}

/// Gather the nonzero weights and the `d`-lanes of their slope mirrors
/// into fixed stack arrays (no allocation; only the first `nz.len()`
/// slots are meaningful).
#[inline(always)]
fn gather_lanes<'a>(
    nz: &[(usize, f64)],
    k: &'a [LaneStore],
    d: usize,
    n: usize,
    w: &mut [f64; MAX_STAGES],
    kl: &mut [&'a [f64]; MAX_STAGES],
) {
    for (i, &(j, wj)) in nz.iter().enumerate() {
        w[i] = wj;
        kl[i] = &k[j].lane(d)[..n];
    }
}

/// One dim-major stage-accumulation pass: fill the first `n` slots of
/// every `ytmp` lane from the `y`/`k` lanes (`ytmp = y + dt·Σ a_sj k_j`,
/// per-row `dt`). Shared verbatim by the whole-batch and active-set
/// dim-major attempts so the two can never diverge.
fn dm_stage_pass(dm: &mut DimScratch, nz: &[(usize, f64)], dim: usize, n: usize, dt: &[f64]) {
    let mut w: [f64; MAX_STAGES] = [0.0; MAX_STAGES];
    let mut kl: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
    for d in 0..dim {
        gather_lanes(nz, &dm.k, d, n, &mut w, &mut kl);
        kernels::stage_lanes(
            &mut dm.ytmp.lane_mut(d)[..n],
            &dm.y.lane(d)[..n],
            &dt[..n],
            &w[..nz.len()],
            &kl[..nz.len()],
        );
    }
}

/// The fused dim-major attempt tail: fill the first `n` slots of the
/// `y_new` (and, when the tableau has an embedded error, `err`) lanes.
/// Shared by both dim-major attempts (see [`dm_stage_pass`]).
fn dm_combine_pass(dm: &mut DimScratch, ct: &CompiledTableau, dim: usize, n: usize, dt: &[f64]) {
    let has_err = !ct.berr_nz.is_empty();
    let nb = ct.b_nz.len();
    let ne = ct.berr_nz.len();
    let mut bw: [f64; MAX_STAGES] = [0.0; MAX_STAGES];
    let mut bk: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
    let mut ew: [f64; MAX_STAGES] = [0.0; MAX_STAGES];
    let mut ek: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
    for d in 0..dim {
        gather_lanes(&ct.b_nz, &dm.k, d, n, &mut bw, &mut bk);
        if has_err {
            gather_lanes(&ct.berr_nz, &dm.k, d, n, &mut ew, &mut ek);
            kernels::combine_pair_lanes(
                &mut dm.y_new.lane_mut(d)[..n],
                &mut dm.err.lane_mut(d)[..n],
                &dm.y.lane(d)[..n],
                &dt[..n],
                &bw[..nb],
                &bk[..nb],
                &ew[..ne],
                &ek[..ne],
            );
        } else {
            kernels::combine_lanes(
                &mut dm.y_new.lane_mut(d)[..n],
                Some(&dm.y.lane(d)[..n]),
                &dt[..n],
                &bw[..nb],
                &bk[..nb],
            );
        }
    }
}

/// The whole-batch, unmasked RK attempt over the dim-major lanes (the
/// joint-loop shape: every row active, broadcast eval). Semantics and
/// results are bit-for-bit those of the row-major [`rk_attempt_rows`];
/// only the traversal order differs — each arithmetic pass runs lane by
/// lane across the batch, and the stage inputs/outputs are transposed at
/// the dynamics boundary because `OdeSystem` is row-oriented.
fn rk_attempt_dm(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    t: &[f64],
    dt: &[f64],
    y: &BatchVec,
    ws: &mut RkWorkspace,
    k0_ready: &[bool],
) -> u64 {
    let tab = ct.tab;
    let batch = y.batch();
    let dim = y.dim();

    // Stage 0: refresh cold slope caches (identical to the row-major
    // path — the mask contract of `f_rows`).
    let mut any_cold = false;
    for (r, &ready) in k0_ready.iter().enumerate() {
        let c = !ready;
        ws.cold[r] = c;
        any_cold |= c;
    }
    if any_cold {
        ws.t_stage.copy_from_slice(t);
        sys.f_rows(0, batch, &ws.t_stage[..], y.flat(), ws.k[0].flat_mut(), Some(&ws.cold[..]));
    }

    // Transpose the committed state and the warm k[0] into the lanes.
    let dm = ws.dm.as_mut().expect("dim-major attempt needs the SoA scratch");
    dm.y.load(y.flat(), batch);
    dm.k[0].load(ws.k[0].flat(), batch);

    for s in 1..tab.stages {
        dm_stage_pass(dm, &ct.a_nz[s], dim, batch, dt);
        for r in 0..batch {
            ws.t_stage[r] = t[r] + tab.c[s] * dt[r];
        }
        // Row-major view for the batched dynamics call, slopes back in.
        dm.ytmp.store_rows(ws.ytmp.flat_mut(), batch);
        sys.f_rows(0, batch, &ws.t_stage[..], ws.ytmp.flat(), ws.k[s].flat_mut(), None);
        dm.k[s].load(ws.k[s].flat(), batch);
    }

    // Fused solution + error, lane by lane, then transpose back.
    dm_combine_pass(dm, ct, dim, batch, dt);
    dm.y_new.store_rows(ws.y_new.flat_mut(), batch);
    if !ct.berr_nz.is_empty() {
        dm.err.store_rows(ws.err.flat_mut(), batch);
    }
    attempt_call_count(ct, k0_ready)
}

/// One RK attempt driven by the packed [`ActiveSet`]: stage accumulation
/// and the solution/error combination iterate **only the live slots**,
/// and the dynamics are evaluated through [`OdeSystem::f_rows_indexed`]
/// so a finished row costs literally zero per-row work when
/// `eval_inactive` is false. With `eval_inactive = true` every still
/// *materialized* slot keeps receiving torchode's overhanging model
/// evaluation (with the `ytmp = y` keep-alive); compaction retires slots
/// outright, which is the only point where the two modes' dynamics-call
/// row sets diverge — per-row results and the semantic batched-call
/// count (the return value) are identical either way.
#[allow(clippy::too_many_arguments)]
pub(crate) fn rk_attempt_active(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    act: &ActiveSet,
    finished: &[bool],
    t: &[f64],
    dt: &[f64],
    y: &BatchVec,
    ws: &mut RkWorkspace,
    k0_ready: &[bool],
    eval_inactive: bool,
) -> u64 {
    if ct.is_implicit() {
        // Per-row Newton solves; `finished`/`eval_inactive` are
        // irrelevant (only live slots do any work, and there are no
        // batched stage evaluations to overhang).
        return implicit::implicit_attempt_active(ct, sys, act, t, dt, y, ws, k0_ready);
    }
    if ws.dm.is_some() {
        return rk_attempt_active_dm(ct, sys, act, finished, t, dt, y, ws, k0_ready, eval_inactive);
    }
    let tab = ct.tab;
    let dim = y.dim();
    let y_flat = y.flat();
    let live = act.live();
    let inst = act.inst_map();
    let eval_rows: &[usize] = if eval_inactive { act.all_slots() } else { live };

    // Stage 0: refresh cold slope caches among the rows the eval covers.
    // In the solve loops `k[0]` is always warm (FSAL hand-off or the
    // non-FSAL end-slope refresh), so this effectively never fires.
    let mut any_cold = false;
    for &r in eval_rows {
        let c = !k0_ready[r];
        ws.cold[r] = c;
        any_cold |= c;
    }
    let mut calls = tab.stages as u64 - 1;
    if any_cold {
        ws.idx.clear();
        for &r in eval_rows {
            if ws.cold[r] {
                ws.idx.push(r);
            }
        }
        for &r in &ws.idx {
            ws.t_stage[r] = t[r];
        }
        sys.f_rows_indexed(0, inst, &ws.idx, &ws.t_stage, y_flat, ws.k[0].flat_mut());
        calls += 1;
    }

    // Keep-alive for finished-but-materialized slots: the overhanging
    // evaluations below must see a valid (t, y). Their state never
    // changes between stages, so one copy per attempt suffices.
    if eval_inactive {
        for &r in act.all_slots() {
            if finished[r] {
                ws.ytmp.row_mut(r).copy_from_slice(&y_flat[r * dim..(r + 1) * dim]);
                ws.t_stage[r] = t[r];
            }
        }
    }

    let ytmp = ws.ytmp.flat_mut();
    let t_stage = &mut ws.t_stage[..];
    let mut k_it = ws.k.iter_mut();
    let mut k_bufs: [&mut [f64]; MAX_STAGES] =
        std::array::from_fn(|_| k_it.next().map_or_else(Default::default, |k| k.flat_mut()));

    // Stages 1..S over the live slots only. The per-row arithmetic is the
    // shared `accumulate_stage_row`, so bitwise identity with the masked
    // kernel is structural, not by convention.
    for s in 1..tab.stages {
        let nz = &ct.a_nz[s];
        let (kprev, krest) = k_bufs.split_at_mut(s);
        for &r in live {
            let h = dt[r];
            let yrow = &y_flat[r * dim..(r + 1) * dim];
            t_stage[r] = t[r] + tab.c[s] * h;
            let out = &mut ytmp[r * dim..(r + 1) * dim];
            accumulate_stage_row(nz, kprev, r, dim, h, yrow, out);
        }
        sys.f_rows_indexed(0, inst, eval_rows, t_stage, ytmp, &mut krest[0][..]);
    }

    // Solution + error for the live slots, one fused traversal per row.
    let y_new = ws.y_new.flat_mut();
    let err = ws.err.flat_mut();
    let has_err = !ct.berr_nz.is_empty();
    for &r in live {
        let h = dt[r];
        let yrow = &y_flat[r * dim..(r + 1) * dim];
        let yn = &mut y_new[r * dim..(r + 1) * dim];
        let er = &mut err[r * dim..(r + 1) * dim];
        combine_rows_fused(ct, &k_bufs, r, dim, h, yrow, yn, er, has_err);
    }
    calls
}

/// The active-set RK attempt over the dim-major lanes. Per-slot
/// semantics (stage-0 refresh, keep-alive copies, indexed evals, the
/// semantic call count) are identical to the row-major
/// [`rk_attempt_active`]; the arithmetic passes instead run **densely
/// over the live span** `0..=max(live)` — state compaction packs the
/// live slots into a dense prefix, which is what keeps this span tight
/// on straggler-heavy batches (pair `dim_major` with a nonzero
/// `compact_threshold`; without compaction a single high-index
/// straggler keeps the span wide) — and only the *live* slots are
/// transposed back into the row-major buffers (dead slots keep their
/// keep-alive `ytmp` and their frozen `y_new`/`err`, matching the
/// masked kernel's contract). The extra lane work on
/// finished-but-still-in-span slots operates on their frozen finite
/// state and is discarded at write-back, so results are bit-for-bit the
/// row-major kernel's.
#[allow(clippy::too_many_arguments)]
fn rk_attempt_active_dm(
    ct: &CompiledTableau,
    sys: &dyn OdeSystem,
    act: &ActiveSet,
    finished: &[bool],
    t: &[f64],
    dt: &[f64],
    y: &BatchVec,
    ws: &mut RkWorkspace,
    k0_ready: &[bool],
    eval_inactive: bool,
) -> u64 {
    let tab = ct.tab;
    let dim = y.dim();
    let y_flat = y.flat();
    let live = act.live();
    let inst = act.inst_map();
    let eval_rows: &[usize] = if eval_inactive { act.all_slots() } else { live };

    // Stage 0: refresh cold slope caches among the rows the eval covers
    // (identical to the row-major path; effectively never fires in the
    // solve loops).
    let mut any_cold = false;
    for &r in eval_rows {
        let c = !k0_ready[r];
        ws.cold[r] = c;
        any_cold |= c;
    }
    let mut calls = tab.stages as u64 - 1;
    if any_cold {
        ws.idx.clear();
        for &r in eval_rows {
            if ws.cold[r] {
                ws.idx.push(r);
            }
        }
        for &r in &ws.idx {
            ws.t_stage[r] = t[r];
        }
        sys.f_rows_indexed(0, inst, &ws.idx, &ws.t_stage, y_flat, ws.k[0].flat_mut());
        calls += 1;
    }

    // Keep-alive for finished-but-materialized slots (identical to the
    // row-major path): the overhanging evaluations must see a valid
    // (t, y) in the row-major `ytmp`, which the selective write-back
    // below never disturbs.
    if eval_inactive {
        for &r in act.all_slots() {
            if finished[r] {
                ws.ytmp.row_mut(r).copy_from_slice(&y_flat[r * dim..(r + 1) * dim]);
                ws.t_stage[r] = t[r];
            }
        }
    }

    // The dense lane span: everything up to the highest live slot. The
    // packed active set keeps live slots ascending, and compaction
    // gathers them into a prefix, so this is tight whenever compaction
    // runs; finished slots below the top live one ride along (their
    // lane results are discarded at write-back). `span == live.len()`
    // means the span is exactly the live prefix (fresh solve, or right
    // after a compaction) and the write-backs can be dense transposes.
    let span = live.last().map_or(0, |&r| r + 1);
    debug_assert!(span <= act.slots());
    let dense = live.len() == span;
    let dm = ws.dm.as_mut().expect("dim-major attempt needs the SoA scratch");
    dm.y.load(y_flat, span);
    dm.k[0].load(ws.k[0].flat(), span);

    for s in 1..tab.stages {
        dm_stage_pass(dm, &ct.a_nz[s], dim, span, dt);
        for &r in live {
            ws.t_stage[r] = t[r] + tab.c[s] * dt[r];
        }
        if dense {
            dm.ytmp.store_rows(ws.ytmp.flat_mut(), span);
        } else {
            dm.ytmp.store_indexed(ws.ytmp.flat_mut(), live);
        }
        sys.f_rows_indexed(0, inst, eval_rows, &ws.t_stage[..], ws.ytmp.flat(), ws.k[s].flat_mut());
        dm.k[s].load(ws.k[s].flat(), span);
    }

    // Fused solution + error, lane by lane over the live span, written
    // back for the live slots only.
    dm_combine_pass(dm, ct, dim, span, dt);
    let has_err = !ct.berr_nz.is_empty();
    if dense {
        dm.y_new.store_rows(ws.y_new.flat_mut(), span);
        if has_err {
            dm.err.store_rows(ws.err.flat_mut(), span);
        }
    } else {
        dm.y_new.store_indexed(ws.y_new.flat_mut(), live);
        if has_err {
            dm.err.store_indexed(ws.err.flat_mut(), live);
        }
    }
    calls
}

/// Executes the batched pieces of the joint solve loop. [`InlineExec`]
/// runs them on the calling thread; `crate::exec::PooledExec` shards the
/// row-update passes across a scoped worker pool while the loop's shared
/// controller reduction stays on the coordinator. Implementations must be
/// bitwise row-equivalent to the inline path.
pub(crate) trait StageExec {
    /// State dimension of the underlying system.
    fn dim(&self) -> usize;

    /// The workspace layout this executor will actually drive given the
    /// requested one. The pooled executors shard the row-range kernel
    /// (always row-major) over workspace views, so they downgrade a
    /// `DimMajor` request rather than allocate SoA mirrors no pass would
    /// touch; the inline executor honors the request. Results are
    /// bitwise-identical either way (`tests/kernel_parity.rs`).
    fn workspace_layout(&self, requested: Layout) -> Layout {
        requested
    }

    /// The Jacobian structure the underlying system declares
    /// ([`crate::problems::OdeSystem::jac_structure`]), used to size the
    /// Newton scratch when no per-solve override is given. Executors
    /// wrapping a concrete system forward its declaration.
    fn jac_structure(&self) -> JacStructure {
        JacStructure::Dense
    }

    /// One batched dynamics evaluation (initial slopes, non-FSAL refresh).
    fn eval(&self, t: &[f64], y: &BatchVec, dy: &mut BatchVec, active: Option<&[bool]>);

    /// One full RK attempt over the batch; returns the batched-call count.
    #[allow(clippy::too_many_arguments)]
    fn attempt(
        &self,
        ct: &CompiledTableau,
        t: &[f64],
        dt: &[f64],
        y: &BatchVec,
        ws: &mut RkWorkspace,
        k0_ready: &[bool],
        active: Option<&[bool]>,
        eval_inactive: bool,
    ) -> u64;

    /// The initial step-size heuristic (costs one extra batched eval).
    #[allow(clippy::too_many_arguments)]
    fn initial_step(
        &self,
        t0: &[f64],
        y0: &BatchVec,
        f0: &BatchVec,
        order: usize,
        tols: &Tolerances,
        span: &[f64],
        scratch_y: &mut BatchVec,
        scratch_f: &mut BatchVec,
    ) -> Vec<f64>;

    /// The fused joint error-norm pass: write each row's unreduced scaled
    /// sum of squares ([`crate::solver::norm::scaled_sumsq`] of `err`
    /// against `max(|y0|, |y1|)` under the row's tolerances) into
    /// `out[row]`. Rows may be computed by any worker in any order — the
    /// per-row arithmetic is position-independent and the joint loop
    /// reduces `out` on the coordinator in row order, so the final norm
    /// is bitwise-identical across executors.
    fn error_sumsq(
        &self,
        err: &BatchVec,
        y0: &BatchVec,
        y1: &BatchVec,
        tols: &Tolerances,
        out: &mut [f64],
    );
}

/// The serial [`StageExec`]: everything on the calling thread.
pub(crate) struct InlineExec<'a> {
    pub sys: &'a dyn OdeSystem,
}

impl StageExec for InlineExec<'_> {
    fn dim(&self) -> usize {
        self.sys.dim()
    }

    fn jac_structure(&self) -> JacStructure {
        self.sys.jac_structure()
    }

    fn eval(&self, t: &[f64], y: &BatchVec, dy: &mut BatchVec, active: Option<&[bool]>) {
        self.sys.f_batch(t, y, dy, active);
    }

    fn attempt(
        &self,
        ct: &CompiledTableau,
        t: &[f64],
        dt: &[f64],
        y: &BatchVec,
        ws: &mut RkWorkspace,
        k0_ready: &[bool],
        active: Option<&[bool]>,
        eval_inactive: bool,
    ) -> u64 {
        rk_attempt(ct, self.sys, t, dt, y, ws, k0_ready, active, eval_inactive)
    }

    fn initial_step(
        &self,
        t0: &[f64],
        y0: &BatchVec,
        f0: &BatchVec,
        order: usize,
        tols: &Tolerances,
        span: &[f64],
        scratch_y: &mut BatchVec,
        scratch_f: &mut BatchVec,
    ) -> Vec<f64> {
        initial_step_batch(self.sys, t0, y0, f0, order, tols, span, scratch_y, scratch_f)
    }

    fn error_sumsq(
        &self,
        err: &BatchVec,
        y0: &BatchVec,
        y1: &BatchVec,
        tols: &Tolerances,
        out: &mut [f64],
    ) {
        scaled_sumsq_rows(err, y0, y1, tols, 0, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, OdeSystem};
    use crate::solver::tableau::{self, DenseOutput};

    /// One dopri5 step on dy/dt = -y must be 5th-order accurate.
    #[test]
    fn dopri5_single_step_accuracy() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 1, 1);
        let y = BatchVec::from_rows(&[vec![1.0]]);
        let dt = 0.1;
        rk_attempt(&ct, &sys, &[0.0], &[dt], &y, &mut ws, &[false], None, true);
        let exact = (-dt_f64(dt)).exp();
        let got = ws.y_new.row(0)[0];
        assert!((got - exact).abs() < 1e-9, "{got} vs {exact}");
        // Error estimate should be small but nonzero.
        assert!(ws.err.row(0)[0].abs() > 0.0);
        assert!(ws.err.row(0)[0].abs() < 1e-6);
    }

    fn dt_f64(x: f64) -> f64 {
        x
    }

    /// Halving dt must reduce the one-step error by ~2^6 for dopri5
    /// (local error order = global order + 1).
    #[test]
    fn dopri5_local_order() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 1, 1);
        let y = BatchVec::from_rows(&[vec![1.0]]);
        let mut errs = Vec::new();
        for &dt in &[0.2, 0.1] {
            rk_attempt(&ct, &sys, &[0.0], &[dt], &y, &mut ws, &[false], None, true);
            errs.push((ws.y_new.row(0)[0] - (-dt).exp()).abs());
        }
        let ratio = errs[0] / errs[1];
        assert!(ratio > 40.0, "one-step error ratio {ratio} too small for order 5");
    }

    /// Per-instance dt: two instances stepped with different dt must land
    /// on their own exp(-dt).
    #[test]
    fn per_instance_dt() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 2, 1);
        let y = BatchVec::from_rows(&[vec![1.0], vec![1.0]]);
        rk_attempt(&ct, &sys, &[0.0, 0.0], &[0.05, 0.2], &y, &mut ws, &[false, false], None, true);
        assert!((ws.y_new.row(0)[0] - (-0.05f64).exp()).abs() < 1e-10);
        assert!((ws.y_new.row(1)[0] - (-0.2f64).exp()).abs() < 1e-6);
    }

    /// Inactive rows are not updated.
    #[test]
    fn inactive_rows_untouched() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let mut ws = RkWorkspace::new(7, 2, 1);
        ws.y_new.row_mut(0)[0] = 123.0;
        let y = BatchVec::from_rows(&[vec![1.0], vec![1.0]]);
        rk_attempt(
            &ct,
            &sys,
            &[0.0, 0.0],
            &[0.1, 0.1],
            &y,
            &mut ws,
            &[false, false],
            Some(&[false, true]),
            true,
        );
        assert_eq!(ws.y_new.row(0)[0], 123.0);
        assert!((ws.y_new.row(1)[0] - (-0.1f64).exp()).abs() < 1e-9);
    }

    /// FSAL reuse: priming k[0] with the exact slope and claiming
    /// `k0_ready` must give the same result as a cold start.
    #[test]
    fn fsal_cache_equivalence() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let y = BatchVec::from_rows(&[vec![2.0]]);

        let mut ws_cold = RkWorkspace::new(7, 1, 1);
        rk_attempt(&ct, &sys, &[0.0], &[0.1], &y, &mut ws_cold, &[false], None, true);

        let mut ws_warm = RkWorkspace::new(7, 1, 1);
        ws_warm.k[0].row_mut(0)[0] = -2.0; // f(0, 2) = -2
        rk_attempt(&ct, &sys, &[0.0], &[0.1], &y, &mut ws_warm, &[true], None, true);

        assert!((ws_cold.y_new.row(0)[0] - ws_warm.y_new.row(0)[0]).abs() < 1e-15);
    }

    /// The dim-major attempt path is bitwise-identical to the row-major
    /// path on the joint shape (no mask, odd dim, per-instance dt).
    #[test]
    fn dim_major_attempt_matches_row_major_bitwise() {
        let sys = ExponentialDecay::new(vec![1.0, 0.5], 3);
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        let y = BatchVec::from_rows(&[vec![1.0, -0.5, 2.0], vec![0.3, 0.7, -1.1]]);
        let (t, dt, k0) = ([0.0, 0.1], [0.05, 0.2], [false, false]);
        let mut ws_r = RkWorkspace::new(7, 2, 3);
        rk_attempt(&ct, &sys, &t, &dt, &y, &mut ws_r, &k0, None, true);
        let mut ws_d = RkWorkspace::new_with_layout(7, 2, 3, crate::tensor::Layout::DimMajor);
        assert_eq!(ws_d.layout(), crate::tensor::Layout::DimMajor);
        rk_attempt(&ct, &sys, &t, &dt, &y, &mut ws_d, &k0, None, true);
        for i in 0..2 {
            for d in 0..3 {
                assert_eq!(
                    ws_r.y_new.row(i)[d].to_bits(),
                    ws_d.y_new.row(i)[d].to_bits(),
                    "y_new i={i} d={d}"
                );
                assert_eq!(
                    ws_r.err.row(i)[d].to_bits(),
                    ws_d.err.row(i)[d].to_bits(),
                    "err i={i} d={d}"
                );
            }
        }
    }

    /// Compiled tableau strips zeros.
    #[test]
    fn compiled_tableau_sparsity() {
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        // dopri5 b has zeros at positions 1 and 6.
        assert_eq!(ct.b_nz.len(), 5);
        assert!(ct.b_nz.iter().all(|&(j, _)| j != 1 && j != 6));
        // row 3 of a (stage 3) is fully dense (3 entries).
        assert_eq!(ct.a_nz[3].len(), 3);
    }

    /// Every registered tableau fits the stage-kernel bound, and call
    /// counting matches the stage structure.
    #[test]
    fn all_tableaus_within_stage_bound() {
        for t in tableau::ALL {
            assert!(t.stages <= MAX_STAGES, "{}", t.name);
        }
        let ct = CompiledTableau::new(&tableau::DOPRI5);
        assert_eq!(attempt_call_count(&ct, &[true, true]), 6);
        assert_eq!(attempt_call_count(&ct, &[true, false]), 7);
    }

    /// A tableau beyond the bound is rejected loudly instead of silently
    /// corrupting stage accumulation (the old fixed `[&[f64]; 8]` bug).
    #[test]
    #[should_panic(expected = "stages")]
    fn compiled_tableau_rejects_too_many_stages() {
        let stages = MAX_STAGES + 1;
        let a: &'static [f64] = Box::leak(vec![0.0; stages * (stages - 1) / 2].into_boxed_slice());
        let b: &'static [f64] = Box::leak(vec![0.0; stages].into_boxed_slice());
        let c: &'static [f64] = Box::leak(vec![0.0; stages].into_boxed_slice());
        let tab: &'static Tableau = Box::leak(Box::new(Tableau {
            name: "too-big",
            stages,
            order: 1,
            err_order: 0,
            a,
            b,
            b_err: &[],
            c,
            diag: &[],
            fsal: false,
            dense: DenseOutput::Hermite,
        }));
        CompiledTableau::new(tab);
    }

    /// A >8-nonzero stage row accumulates every slope (regression test for
    /// the silent 8-slot cap): a 10-stage method whose last stage sums 9
    /// previous slopes of f ≡ 1 must produce ytmp = y + dt·Σa.
    #[test]
    fn wide_stage_rows_accumulate_fully() {
        struct Constant;
        impl OdeSystem for Constant {
            fn dim(&self) -> usize {
                1
            }
            fn f_inst(&self, _i: usize, _t: f64, _y: &[f64], dy: &mut [f64]) {
                dy[0] = 1.0;
            }
        }
        let stages = 10;
        let mut a = Vec::new();
        for s in 1..stages {
            // Dense row: every coefficient 0.1.
            a.extend(vec![0.1; s]);
        }
        let mut b = vec![0.0; stages];
        b[stages - 1] = 1.0;
        let mut c = vec![0.0; stages];
        for (s, ci) in c.iter_mut().enumerate() {
            *ci = 0.1 * s as f64;
        }
        let tab: &'static Tableau = Box::leak(Box::new(Tableau {
            name: "wide",
            stages,
            order: 1,
            err_order: 0,
            a: Box::leak(a.into_boxed_slice()),
            b: Box::leak(b.into_boxed_slice()),
            b_err: &[],
            c: Box::leak(c.into_boxed_slice()),
            diag: &[],
            fsal: false,
            dense: DenseOutput::Hermite,
        }));
        let ct = CompiledTableau::new(tab);
        assert_eq!(ct.a_nz[stages - 1].len(), 9, "needs > 8 nonzero slots");
        let sys = Constant;
        let mut ws = RkWorkspace::new(stages, 1, 1);
        let y = BatchVec::from_rows(&[vec![0.0]]);
        rk_attempt(&ct, &sys, &[0.0], &[1.0], &y, &mut ws, &[false], None, true);
        // Last stage input: y + dt · Σ_j 0.1 · k_j = 0.9 (all k = 1); the
        // solution is y + dt · b_last · k_last = 1.0.
        assert!((ws.y_new.row(0)[0] - 1.0).abs() < 1e-15);
        // And the stage input actually saw all 9 slopes.
        assert!((ws.ytmp.row(0)[0] - 0.9).abs() < 1e-15);
    }
}
