//! Step-size controllers (integral and PID), per-instance.
//!
//! Following Söderlind (2002, 2003) and the diffrax/torchode formulation:
//! with the tolerance-scaled error norm ε_n of the current step (accept iff
//! ε_n ≤ 1) the next step size is
//!
//! ```text
//! dt' = dt · clamp(safety · ε_n^(-β1) · ε_{n-1}^(-β2) · ε_{n-2}^(-β3))
//! ```
//!
//! where the β are derived from the proportional/integral/derivative
//! coefficients and the order `k = err_order + 1` of the embedded error
//! estimator:
//!
//! ```text
//! β1 = (P + I + D) / k,   β2 = -(P + 2D) / k,   β3 = D / k
//! ```
//!
//! An integral controller is the special case P = D = 0, I = 1 — exactly
//! what torchdiffeq and TorchDyn implement. The error history is only
//! advanced on accepted steps; after a rejection the growth factor is
//! additionally capped at 1.

/// A step-size controller configuration (shared across the batch; the
/// *state* is per instance, see [`ControllerState`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Controller {
    pub pcoeff: f64,
    pub icoeff: f64,
    pub dcoeff: f64,
    pub safety: f64,
    pub factor_min: f64,
    pub factor_max: f64,
}

impl Controller {
    /// The classic integral controller (torchdiffeq/TorchDyn default).
    pub fn integral() -> Self {
        Self::pid(0.0, 1.0, 0.0)
    }

    /// A PID controller with the given proportional/integral/derivative
    /// coefficients (diffrax convention).
    pub fn pid(pcoeff: f64, icoeff: f64, dcoeff: f64) -> Self {
        Self {
            pcoeff,
            icoeff,
            dcoeff,
            safety: 0.9,
            factor_min: 0.2,
            factor_max: 10.0,
        }
    }

    pub fn with_safety(mut self, s: f64) -> Self {
        self.safety = s;
        self
    }

    pub fn with_factor_bounds(mut self, lo: f64, hi: f64) -> Self {
        self.factor_min = lo;
        self.factor_max = hi;
        self
    }

    /// β exponents for error-estimator order `err_order`.
    #[inline]
    pub fn betas(&self, err_order: usize) -> (f64, f64, f64) {
        let k = (err_order + 1) as f64;
        (
            (self.pcoeff + self.icoeff + self.dcoeff) / k,
            -(self.pcoeff + 2.0 * self.dcoeff) / k,
            self.dcoeff / k,
        )
    }

    /// Decide accept/reject and the step-size factor for one instance.
    #[inline]
    pub fn decide(&self, err_norm: f64, err_order: usize, st: &ControllerState) -> StepDecision {
        if !err_norm.is_finite() {
            // Non-finite error: reject hard and shrink maximally.
            return StepDecision { accept: false, factor: self.factor_min };
        }
        let accept = err_norm <= 1.0;
        let (b1, b2, b3) = self.betas(err_order);
        // Floor the error to avoid factor blow-up on (near-)exact steps.
        let e0 = err_norm.max(1e-10);
        let mut factor =
            self.safety * e0.powf(-b1) * st.err_prev.powf(-b2) * st.err_prev2.powf(-b3);
        factor = factor.clamp(self.factor_min, self.factor_max);
        if !accept {
            factor = factor.min(1.0);
        }
        StepDecision { accept, factor }
    }
}

/// Per-instance controller memory: the last two accepted (floored) error
/// norms, initialized to 1 so the first step reduces to a pure I-step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerState {
    pub err_prev: f64,
    pub err_prev2: f64,
}

impl Default for ControllerState {
    fn default() -> Self {
        Self { err_prev: 1.0, err_prev2: 1.0 }
    }
}

impl ControllerState {
    /// Advance the history after an *accepted* step with error `err_norm`.
    #[inline]
    pub fn push(&mut self, err_norm: f64) {
        self.err_prev2 = self.err_prev;
        self.err_prev = err_norm.max(1e-10);
    }
}

/// The controller's verdict for one step of one instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepDecision {
    pub accept: bool,
    /// Multiplier on the step size for the next attempt.
    pub factor: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integral_matches_classic_formula() {
        let c = Controller::integral();
        let st = ControllerState::default();
        // dopri5: err_order 4, k = 5 => factor = 0.9 * err^(-1/5)
        let d = c.decide(0.5, 4, &st);
        assert!(d.accept);
        let expect = 0.9 * 0.5f64.powf(-0.2);
        assert!((d.factor - expect).abs() < 1e-12);
    }

    #[test]
    fn accepts_iff_err_le_one() {
        let c = Controller::integral();
        let st = ControllerState::default();
        assert!(c.decide(1.0, 4, &st).accept);
        assert!(!c.decide(1.0000001, 4, &st).accept);
    }

    #[test]
    fn rejection_never_grows_step() {
        let c = Controller::integral();
        let st = ControllerState::default();
        let d = c.decide(1.5, 4, &st);
        assert!(!d.accept);
        assert!(d.factor <= 1.0);
    }

    #[test]
    fn factor_clamped() {
        let c = Controller::integral();
        let st = ControllerState::default();
        // Tiny error => huge factor, clamped to factor_max.
        let d = c.decide(1e-16, 4, &st);
        assert_eq!(d.factor, c.factor_max);
        // Huge error => factor_min.
        let d = c.decide(1e12, 4, &st);
        assert_eq!(d.factor, c.factor_min);
    }

    #[test]
    fn pid_uses_history() {
        let c = Controller::pid(0.3, 0.3, 0.0);
        let mut st = ControllerState::default();
        let f_fresh = c.decide(0.5, 4, &st).factor;
        st.push(0.1); // previous step had small error
        let f_hist = c.decide(0.5, 4, &st).factor;
        // β2 < 0 for a PI controller, so a small previous error shrinks the
        // factor relative to fresh history.
        assert!(f_hist < f_fresh, "{f_hist} !< {f_fresh}");
    }

    #[test]
    fn betas_integral() {
        let c = Controller::integral();
        let (b1, b2, b3) = c.betas(4);
        assert!((b1 - 0.2).abs() < 1e-15);
        assert_eq!(b2, 0.0);
        assert_eq!(b3, 0.0);
    }

    #[test]
    fn non_finite_error_rejects_hard() {
        let c = Controller::integral();
        let st = ControllerState::default();
        let d = c.decide(f64::NAN, 4, &st);
        assert!(!d.accept);
        assert_eq!(d.factor, c.factor_min);
        let d = c.decide(f64::INFINITY, 4, &st);
        assert!(!d.accept);
    }

    #[test]
    fn history_push_floors() {
        let mut st = ControllerState::default();
        st.push(0.0);
        assert_eq!(st.err_prev, 1e-10);
        assert_eq!(st.err_prev2, 1.0);
    }
}
