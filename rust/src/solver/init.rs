//! Automatic initial step-size selection (Hairer, Nørsett & Wanner,
//! Algorithm 4.14), batched: one extra dynamics evaluation for the whole
//! batch, per-instance results.

use super::norm::{scaled_norm, NormKind};
use super::Tolerances;
use crate::problems::OdeSystem;
use crate::tensor::BatchVec;

/// Per-instance initial step sizes. `f0` must hold `f(t0, y0)` and stays
/// valid afterwards (so FSAL solvers can reuse it as their first `k[0]`).
/// Costs one batched dynamics evaluation (written into `scratch_f`).
pub fn initial_step_batch(
    sys: &dyn OdeSystem,
    t0: &[f64],
    y0: &BatchVec,
    f0: &BatchVec,
    order: usize,
    tols: &Tolerances,
    span: &[f64],
    scratch_y: &mut BatchVec,
    scratch_f: &mut BatchVec,
) -> Vec<f64> {
    let batch = y0.batch();
    let mut h0 = vec![0.0; batch];
    // d0 = ||y0||, d1 = ||f0|| in the tolerance-scaled norm.
    for i in 0..batch {
        let (atol, rtol) = (tols.atol(i), tols.rtol(i));
        let y = y0.row(i);
        let f = f0.row(i);
        let d0 = scaled_norm(NormKind::Rms, y, y, y, atol, rtol);
        let d1 = scaled_norm(NormKind::Rms, f, y, y, atol, rtol);
        let h = if d0 < 1e-5 || d1 < 1e-5 { 1e-6 } else { 0.01 * d0 / d1 };
        h0[i] = h.min(span[i].abs());
        // Explicit Euler probe state y1 = y0 + h0 f0.
        let out = scratch_y.row_mut(i);
        for d in 0..y.len() {
            out[d] = y[d] + h0[i] * f[d];
        }
    }
    // One batched evaluation at the probe states.
    let t_probe: Vec<f64> = t0.iter().zip(&h0).map(|(t, h)| t + h).collect();
    sys.f_batch(&t_probe, scratch_y, scratch_f, None);

    let mut dt0 = vec![0.0; batch];
    for i in 0..batch {
        let (atol, rtol) = (tols.atol(i), tols.rtol(i));
        let y = y0.row(i);
        let f_a = f0.row(i);
        let f_b = scratch_f.row(i);
        // d2 = ||f1 - f0|| / h0 — an estimate of the second derivative.
        let diff: Vec<f64> = f_a.iter().zip(f_b).map(|(a, b)| b - a).collect();
        let d2 = scaled_norm(NormKind::Rms, &diff, y, y, atol, rtol) / h0[i];
        let d1 = scaled_norm(NormKind::Rms, f_a, y, y, atol, rtol);
        let dmax = d1.max(d2);
        let h1 = if dmax <= 1e-15 {
            (h0[i] * 1e-3).max(1e-6)
        } else {
            (0.01 / dmax).powf(1.0 / (order as f64 + 1.0))
        };
        dt0[i] = (100.0 * h0[i]).min(h1).min(span[i].abs());
    }
    dt0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, OdeSystem};

    fn setup(lambda: Vec<f64>) -> (ExponentialDecay, BatchVec, BatchVec) {
        let b = lambda.len();
        let sys = ExponentialDecay::new(lambda, 1);
        let y0 = BatchVec::from_rows(&vec![vec![1.0]; b]);
        let mut f0 = BatchVec::zeros(b, 1);
        let t = vec![0.0; b];
        sys.f_batch(&t, &y0, &mut f0, None);
        (sys, y0, f0)
    }

    #[test]
    fn stiffer_instance_gets_smaller_dt0() {
        let (sys, y0, f0) = setup(vec![1.0, 100.0]);
        let tols = Tolerances::scalar(1e-6, 1e-5);
        let mut sy = BatchVec::zeros(2, 1);
        let mut sf = BatchVec::zeros(2, 1);
        let dt0 = initial_step_batch(
            &sys,
            &[0.0, 0.0],
            &y0,
            &f0,
            5,
            &tols,
            &[10.0, 10.0],
            &mut sy,
            &mut sf,
        );
        assert!(dt0[1] < dt0[0], "stiff: {dt0:?}");
        assert!(dt0.iter().all(|&h| h > 0.0));
    }

    #[test]
    fn dt0_clamped_by_span() {
        let (sys, y0, f0) = setup(vec![1e-8]);
        let tols = Tolerances::scalar(1e-6, 1e-5);
        let mut sy = BatchVec::zeros(1, 1);
        let mut sf = BatchVec::zeros(1, 1);
        let dt0 =
            initial_step_batch(&sys, &[0.0], &y0, &f0, 5, &tols, &[0.5], &mut sy, &mut sf);
        assert!(dt0[0] <= 0.5);
    }

    #[test]
    fn reasonable_magnitude_for_unit_problem() {
        let (sys, y0, f0) = setup(vec![1.0]);
        let tols = Tolerances::scalar(1e-6, 1e-5);
        let mut sy = BatchVec::zeros(1, 1);
        let mut sf = BatchVec::zeros(1, 1);
        let dt0 =
            initial_step_batch(&sys, &[0.0], &y0, &f0, 5, &tols, &[10.0], &mut sy, &mut sf);
        // For ẏ = -y at tolerance ~1e-5 the heuristic lands around 1e-2..1.
        assert!(dt0[0] > 1e-4 && dt0[0] < 2.0, "{}", dt0[0]);
    }
}
