//! Tolerance-scaled error norms.
//!
//! The scaled error of a step from `y0` to `y1` with raw embedded error
//! `err` is `err_i / (atol + rtol · max(|y0_i|, |y1_i|))`; a step is
//! acceptable iff the norm of that vector is ≤ 1. The default is the RMS
//! ("Hairer") norm; a max norm is provided as an alternative.
//!
//! Two entry points share one per-element arithmetic sequence:
//!
//! - [`scaled_norm`] — the finished per-instance norm used by the
//!   parallel loop's per-row controllers (and the frozen reference loop).
//! - [`scaled_sumsq`] — the *unreduced* sum of squares, the partial the
//!   joint loop's fused norm accumulates across rows. The joint error
//!   norm over a `batch × dim` state is
//!   `sqrt(Σ_rows scaled_sumsq(row) / (batch · dim))`: each row's partial
//!   can be produced by any worker (the per-row arithmetic is
//!   identical wherever it runs) and the scalar reduction happens on the
//!   coordinator **in row order**, which is what keeps joint solves
//!   bitwise-identical across pool kinds, thread counts and steal-chunk
//!   sizes.
//!
//! [`scaled_norm`]'s RMS arm is implemented *as* `scaled_sumsq` followed
//! by the mean/sqrt reduction, so the two can never drift apart.
//!
//! The reduction itself is the **deterministic fixed-shape lane tree**
//! of [`super::kernels::scaled_sumsq`]: independent lane accumulators
//! over the blocked prefix, a fixed pairwise reduction tree, then the
//! tail in element order. The shape depends only on the row length —
//! never on schedule, worker, or layout — which preserves both the
//! position-independence of per-row partials and the bitwise
//! `scaled_norm == (scaled_sumsq / len).sqrt()` identity.

#![warn(missing_docs)]

use super::kernels;
use super::Tolerances;
use crate::tensor::BatchVec;

/// Which reduction to apply to the scaled error vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    /// sqrt(mean(x²)) — the default in torchode, torchdiffeq and diffrax.
    Rms,
    /// max(|x|).
    Max,
}

/// Fused scaled-norm computation for one instance: a single pass over the
/// three input slices, no temporaries (the native analogue of the fused
/// `error_norm` Pallas kernel).
///
/// The scale is floored at [`f64::MIN_POSITIVE`]: with `atol = 0` and a
/// zero state the raw scale is 0, and an *exact* step (`err = 0`) would
/// otherwise produce `0/0 = NaN`, which the controller treats as a hard
/// rejection and rides into `DtUnderflow`. With the floor an exact step
/// on a zero state scores 0 and accepts; any genuine error over a zero
/// scale still scores astronomically and rejects. The floor is exact for
/// every normal scale, so results elsewhere are bitwise-unchanged.
#[inline]
pub fn scaled_norm(
    kind: NormKind,
    err: &[f64],
    y0: &[f64],
    y1: &[f64],
    atol: f64,
    rtol: f64,
) -> f64 {
    debug_assert_eq!(err.len(), y0.len());
    debug_assert_eq!(err.len(), y1.len());
    match kind {
        NormKind::Rms => (scaled_sumsq(err, y0, y1, atol, rtol) / err.len() as f64).sqrt(),
        NormKind::Max => {
            let mut m = 0.0f64;
            for i in 0..err.len() {
                let scale = (atol + rtol * y0[i].abs().max(y1[i].abs())).max(f64::MIN_POSITIVE);
                m = m.max((err[i] / scale).abs());
            }
            m
        }
    }
}

/// Unreduced scaled sum of squares `Σ_i (err_i / scale_i)²` for one
/// instance — the partial accumulator of the joint loop's fused error
/// norm (see the module docs). The per-element arithmetic (including the
/// [`f64::MIN_POSITIVE`] scale floor) is exactly [`scaled_norm`]'s RMS
/// arm, minus the final mean/sqrt reduction, so
/// `scaled_norm(Rms, ..) == (scaled_sumsq(..) / len).sqrt()` bitwise.
/// Reduced with the fixed-shape lane tree of
/// [`kernels::scaled_sumsq`]; for rows shorter than one lane block this
/// is bit-for-bit the historical sequential sum.
#[inline]
pub fn scaled_sumsq(err: &[f64], y0: &[f64], y1: &[f64], atol: f64, rtol: f64) -> f64 {
    debug_assert_eq!(err.len(), y0.len());
    debug_assert_eq!(err.len(), y1.len());
    kernels::scaled_sumsq(err, y0, y1, atol, rtol)
}

/// Fill `out[r] = scaled_sumsq(row lo + r)` for a contiguous row range
/// of a batched state — the single per-row fill behind every
/// `StageExec::error_sumsq` implementation (inline, scoped, stealing),
/// so the executors cannot drift apart arithmetically. Tolerances are
/// indexed by the *global* row `lo + r`.
pub fn scaled_sumsq_rows(
    err: &BatchVec,
    y0: &BatchVec,
    y1: &BatchVec,
    tols: &Tolerances,
    lo: usize,
    out: &mut [f64],
) {
    for (r, o) in out.iter_mut().enumerate() {
        let i = lo + r;
        *o = scaled_sumsq(err.row(i), y0.row(i), y1.row(i), tols.atol(i), tols.rtol(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rms_of_uniform_error() {
        // err = scale everywhere => norm 1.
        let y0 = [0.0, 0.0, 0.0];
        let y1 = [0.0, 0.0, 0.0];
        let err = [1e-6, 1e-6, 1e-6];
        let n = scaled_norm(NormKind::Rms, &err, &y0, &y1, 1e-6, 0.0);
        assert!((n - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rtol_uses_larger_state() {
        let y0 = [2.0];
        let y1 = [4.0];
        let err = [0.4];
        // scale = 0 + 0.1 * 4 = 0.4 => norm 1
        let n = scaled_norm(NormKind::Rms, &err, &y0, &y1, 0.0, 0.1);
        assert!((n - 1.0).abs() < 1e-12);
    }

    #[test]
    fn max_norm_dominates_rms() {
        let y0 = [0.0, 0.0];
        let y1 = [0.0, 0.0];
        let err = [1e-6, 0.0];
        let rms = scaled_norm(NormKind::Rms, &err, &y0, &y1, 1e-6, 0.0);
        let mx = scaled_norm(NormKind::Max, &err, &y0, &y1, 1e-6, 0.0);
        assert!(mx >= rms);
        assert!((mx - 1.0).abs() < 1e-12);
        assert!((rms - (0.5f64).sqrt()).abs() < 1e-12);
    }

    /// The 0/0 regression: an exact step (`err = 0`) on a zero state with
    /// `atol = 0` must score 0 (accept), not NaN (reject-hard).
    #[test]
    fn zero_error_zero_scale_is_zero_not_nan() {
        let y0 = [0.0, 0.0];
        let y1 = [0.0, 0.0];
        let err = [0.0, 0.0];
        for kind in [NormKind::Rms, NormKind::Max] {
            let n = scaled_norm(kind, &err, &y0, &y1, 0.0, 1e-6);
            assert_eq!(n, 0.0, "{kind:?}");
        }
        // A genuine error over a zero scale still rejects decisively.
        let n = scaled_norm(NormKind::Rms, &[1e-3, 0.0], &y0, &y1, 0.0, 1e-6);
        assert!(n > 1.0);
    }

    /// The fused-norm contract: the RMS norm is exactly the unreduced sum
    /// of squares followed by the mean/sqrt reduction, bit for bit.
    #[test]
    fn sumsq_is_unreduced_rms() {
        let y0 = [1.5, -2.0, 0.0, 1e-8];
        let y1 = [1.4, -2.5, 0.1, 0.0];
        let err = [1e-7, -3e-6, 2e-9, 5e-8];
        let (atol, rtol) = (1e-8, 1e-6);
        let s = scaled_sumsq(&err, &y0, &y1, atol, rtol);
        let n = scaled_norm(NormKind::Rms, &err, &y0, &y1, atol, rtol);
        assert_eq!(n.to_bits(), (s / err.len() as f64).sqrt().to_bits());
        // And the zero-scale floor carries over: exact steps score 0.
        assert_eq!(scaled_sumsq(&[0.0], &[0.0], &[0.0], 0.0, 1e-6), 0.0);
    }

    #[test]
    fn negative_components_scale_by_abs() {
        let y0 = [-10.0];
        let y1 = [1.0];
        let err = [1.0];
        // scale = 0 + 0.1 * 10 = 1
        let n = scaled_norm(NormKind::Rms, &err, &y0, &y1, 0.0, 0.1);
        assert!((n - 1.0).abs() < 1e-12);
    }
}
