//! Discretize-then-optimize: exact backpropagation through a Runge–Kutta
//! solve, fixed-step or adaptive, explicit or implicit.
//!
//! The paper's FEN benchmark trains "via backpropagation through the
//! solver". A Runge–Kutta solve is a finite composition of differentiable
//! maps, so the exact gradient is the chain rule over steps and stages —
//! no adjoint-ODE approximation involved:
//!
//! ```text
//! y_{n+1} = y_n + h Σ_s b_s k_s,   k_s = f(t_n + c_s h, x_s)
//! ```
//!
//! where `x_s` is the stage state: the explicit stage input
//! `y_n + h Σ_{j<s} a_sj k_j` for explicit stages, or the converged
//! Newton solution `z_s = rhs_s + h·γ_s·k_s` for DIRK stages. Three
//! entry points share the same per-row backward core:
//!
//! * [`rk_forward_tape`] / [`rk_backward`] — fixed step count and size,
//!   the original discretize-then-optimize path, now also accepting
//!   implicit tableaus (TR-BDF2, Kvaerno 4(3)).
//! * [`replay_tape`] / [`rk_backward_adaptive`] — *adaptive-step*
//!   discretize-then-optimize: the forward solve records its accepted
//!   `(t, dt)` sequence per row (`SolveOptions::with_trace`,
//!   compaction-aware — the trace is indexed by original instance), and
//!   the tape replays that exact sequence serially per row. Because the
//!   accepted-step trace is bitwise-identical across pool kinds, thread
//!   counts and layouts (the forward contract) and the replay is serial
//!   per row, the gradients inherit the same bitwise-determinism
//!   guarantee. [`rk_forward_tape_adaptive`] wraps solve + replay.
//! * Implicit stages differentiate through the Newton solve via the
//!   implicit-function theorem: `k_s = f(t_s, rhs_s + hγk_s)` gives
//!   `(I − hγJ)·dk_s = J·drhs_s + f_θ·dθ`, so a seed `u` on `k_s` costs
//!   one extra linear solve `w = (I − hγJ)⁻ᵀ·u` against the same matrix
//!   the forward Newton factors (dense LU via
//!   [`super::linalg::lu_solve_transposed`], banded by factoring the
//!   transpose with swapped bandwidths) followed by the ordinary VJP at
//!   the converged stage state.
//!
//! Tape memory is O(steps × stages × dim) per instance, the standard
//! discretize-then-optimize trade-off; [`super::adjoint`] has the O(1)
//! memory continuous alternative.
//!
//! The replayed implicit stages re-solve the stage equation to tight
//! tolerance rather than reproducing the forward Newton iterate bitwise;
//! the tape gradient is therefore the exact gradient of the *replayed*
//! discrete map, which agrees with the forward map to Newton tolerance
//! (the finite-difference suites in `tests/adjoint_gradients.rs` check
//! both). Replay determinism itself is exact: same trace in, same
//! gradient out, bitwise.

use super::linalg::{lu_factor, lu_solve, lu_solve_transposed, BandedMatrix};
use super::step::CompiledTableau;
use super::tableau::Tableau;
use super::{MethodId, Solution, SolveOptions, TimeGrid};
use crate::problems::{JacStructure, OdeSystem};
use crate::tensor::BatchVec;

/// Max Newton iterations when replaying an implicit stage. The forward
/// solver already accepted the step, so the stage equation is known to be
/// solvable at this exact `(t, dt)`; the replay just polishes to a much
/// tighter tolerance than the forward pass needs.
const REPLAY_MAX_ITERS: usize = 30;
/// Refresh the Jacobian/factorization every this many replay iterations.
const REPLAY_JAC_REFRESH: usize = 10;
/// Replay convergence: `max_d |δ_d| / (1 + |z_d|)` below this is done.
const REPLAY_TOL: f64 = 1e-12;
/// Stall guard: once below this, a non-decreasing update means the
/// iteration hit its roundoff floor — stop instead of cycling.
const REPLAY_STALL_TOL: f64 = 1e-9;

/// Per-row Newton/Jacobian workspace shared by implicit stage replay
/// (forward) and the implicit-function-theorem solve (backward).
///
/// Mirrors the conventions of [`super::implicit`]: analytic Jacobians via
/// `jac_inst` / `jac_band_inst` when [`OdeSystem::has_jac`] is true,
/// forward differences with `√ε·(1 + |y_j|)` perturbations otherwise,
/// and dense vs banded factorization chosen from the system's resolved
/// [`JacStructure`]. Large buffers (the dense `dim²` pair) are allocated
/// lazily so explicit replays of high-dimensional systems never pay for
/// them.
struct RowNewton {
    dim: usize,
    /// Resolved structure; `None` means dense (incl. bands too wide to pay).
    band_widths: Option<(usize, usize)>,
    analytic: bool,
    /// Jacobian: dense row-major `dim²`, or column-major band
    /// `dim·(kl+ku+1)` (the [`OdeSystem::jac_band_inst`] layout).
    jac: Vec<f64>,
    /// Dense LU of `M = I − hd·J` (row-major, factored in place).
    lu: Vec<f64>,
    /// Banded factor of `M` (plain orientation, for [`Self::solve`]).
    band_m: Option<BandedMatrix>,
    /// Banded factor of `Mᵀ` (for [`Self::solve_t`]): assembled with
    /// swapped bandwidths `(ku, kl)` and factored fresh.
    band_mt: Option<BandedMatrix>,
    piv: Vec<usize>,
    f0: Vec<f64>,
    f1: Vec<f64>,
    ypert: Vec<f64>,
    /// Newton update / residual scratch.
    resid: Vec<f64>,
    /// Stage right-hand side `y_n + h Σ_{j<s} a_sj k_j` for the row step
    /// currently being replayed (also used by explicit stages).
    rhs: Vec<f64>,
}

impl RowNewton {
    fn new(sys: &dyn OdeSystem) -> Self {
        let dim = sys.dim();
        let band_widths = match sys.jac_structure().resolved(dim) {
            JacStructure::Banded { lower, upper } if lower + upper + 1 < dim => {
                Some((lower, upper))
            }
            _ => None,
        };
        RowNewton {
            dim,
            band_widths,
            analytic: sys.has_jac(),
            jac: Vec::new(),
            lu: Vec::new(),
            band_m: None,
            band_mt: None,
            piv: vec![0; dim],
            f0: vec![0.0; dim],
            f1: vec![0.0; dim],
            ypert: vec![0.0; dim],
            resid: vec![0.0; dim],
            rhs: vec![0.0; dim],
        }
    }

    /// Fill `self.jac` with `∂f/∂y` of instance `inst` at `(t, y)`.
    fn jacobian(&mut self, sys: &dyn OdeSystem, inst: usize, t: f64, y: &[f64]) {
        let dim = self.dim;
        let eps = f64::EPSILON.sqrt();
        match self.band_widths {
            None => {
                if self.jac.len() < dim * dim {
                    self.jac.resize(dim * dim, 0.0);
                }
                if self.analytic {
                    sys.jac_inst(inst, t, y, &mut self.jac[..dim * dim]);
                } else {
                    sys.f_inst(inst, t, y, &mut self.f0);
                    for j in 0..dim {
                        self.ypert.copy_from_slice(y);
                        let h = eps * (1.0 + y[j].abs());
                        self.ypert[j] += h;
                        sys.f_inst(inst, t, &self.ypert, &mut self.f1);
                        for i in 0..dim {
                            self.jac[i * dim + j] = (self.f1[i] - self.f0[i]) / h;
                        }
                    }
                }
            }
            Some((kl, ku)) => {
                let w = kl + ku + 1;
                if self.jac.len() < dim * w {
                    self.jac.resize(dim * w, 0.0);
                }
                if self.analytic {
                    sys.jac_band_inst(inst, t, y, &mut self.jac[..dim * w]);
                } else {
                    // Plain column-at-a-time differences; the implicit
                    // solver's colored builds are a hot-path optimization
                    // this cold training path doesn't need.
                    self.jac[..dim * w].iter_mut().for_each(|v| *v = 0.0);
                    sys.f_inst(inst, t, y, &mut self.f0);
                    for j in 0..dim {
                        self.ypert.copy_from_slice(y);
                        let h = eps * (1.0 + y[j].abs());
                        self.ypert[j] += h;
                        sys.f_inst(inst, t, &self.ypert, &mut self.f1);
                        for i in j.saturating_sub(ku)..=(j + kl).min(dim - 1) {
                            self.jac[j * w + ku + i - j] = (self.f1[i] - self.f0[i]) / h;
                        }
                    }
                }
            }
        }
    }

    /// Build `J(t, y)` and factor `M = I − hd·J`. With `for_transpose`,
    /// the banded path assembles and factors `Mᵀ` instead (the dense LU
    /// serves both orientations via [`lu_solve_transposed`]). Returns
    /// `false` on a singular factorization.
    fn prepare(
        &mut self,
        sys: &dyn OdeSystem,
        inst: usize,
        t: f64,
        y: &[f64],
        hd: f64,
        for_transpose: bool,
    ) -> bool {
        self.jacobian(sys, inst, t, y);
        let dim = self.dim;
        match self.band_widths {
            None => {
                if self.lu.len() < dim * dim {
                    self.lu.resize(dim * dim, 0.0);
                }
                for i in 0..dim {
                    for j in 0..dim {
                        let delta = if i == j { 1.0 } else { 0.0 };
                        self.lu[i * dim + j] = delta - hd * self.jac[i * dim + j];
                    }
                }
                lu_factor(&mut self.lu, &mut self.piv, dim)
            }
            Some((kl, ku)) => {
                let w = kl + ku + 1;
                // M has J's bandwidths; Mᵀ swaps them. Band-layout entry
                // `J[r][c]` lives at `c·w + ku + r − c`.
                let (mkl, mku) = if for_transpose { (ku, kl) } else { (kl, ku) };
                let jac = &self.jac;
                let slot = if for_transpose { &mut self.band_mt } else { &mut self.band_m };
                let m = slot.get_or_insert_with(|| BandedMatrix::zeros(dim, mkl, mku));
                m.as_mut_slice().iter_mut().for_each(|v| *v = 0.0);
                for j in 0..dim {
                    for i in j.saturating_sub(mku)..=(j + mkl).min(dim - 1) {
                        let delta = if i == j { 1.0 } else { 0.0 };
                        // Entry of M at (i, j): Mᵀ[i][j] = M[j][i] needs
                        // J[j][i], plain M[i][j] needs J[i][j].
                        let (r, c) = if for_transpose { (j, i) } else { (i, j) };
                        let jij = jac[c * w + ku + r - c];
                        m.set(i, j, delta - hd * jij);
                    }
                }
                m.factor(&mut self.piv)
            }
        }
    }

    /// Solve `Mᵀ·x = b` in place. The dense path reuses the factors of
    /// `M` via [`lu_solve_transposed`]; the banded path requires
    /// `prepare(.., true)`.
    fn solve_t(&self, x: &mut [f64]) {
        match self.band_widths {
            None => lu_solve_transposed(&self.lu, &self.piv, self.dim, x),
            Some(_) => self.band_mt.as_ref().unwrap().solve(&self.piv, x),
        }
    }

    /// Re-solve the stage equation `z = rhs + hd·f(t, z)` (rhs in
    /// `self.rhs`, predictor in `z`) to replay tolerance.
    fn newton(&mut self, sys: &dyn OdeSystem, inst: usize, t: f64, hd: f64, z: &mut [f64]) {
        let dim = self.dim;
        let mut prev = f64::INFINITY;
        for iter in 0..REPLAY_MAX_ITERS {
            if iter % REPLAY_JAC_REFRESH == 0 {
                // Simplified Newton: freeze the factorization for a few
                // iterations — the predictor is close, so this converges
                // fast without a Jacobian per iteration.
                let ok = self.prepare(sys, inst, t, &*z, hd, false);
                assert!(ok, "singular (I − hγJ) while replaying an implicit stage");
            }
            sys.f_inst(inst, t, z, &mut self.f0);
            for d in 0..dim {
                self.resid[d] = self.rhs[d] + hd * self.f0[d] - z[d];
            }
            // Solve M·δ = −F in place (field-level borrows keep the
            // factors and the residual disjoint).
            match self.band_widths {
                None => lu_solve(&self.lu, &self.piv, dim, &mut self.resid),
                Some(_) => self.band_m.as_ref().unwrap().solve(&self.piv, &mut self.resid),
            }
            let mut dn = 0.0f64;
            for d in 0..dim {
                z[d] += self.resid[d];
                let rel = self.resid[d].abs() / (1.0 + z[d].abs());
                if rel > dn {
                    dn = rel;
                }
            }
            if dn <= REPLAY_TOL || (dn < REPLAY_STALL_TOL && dn >= prev) {
                break;
            }
            prev = dn;
        }
    }
}

/// Advance one row by one RK step, recording stage states and slopes.
///
/// `y` enters as `y_n` and leaves as `y_{n+1}`; `xs`/`ks` (both
/// `stages × dim`) receive the stage states (Newton solutions for DIRK
/// stages) and slopes `k_s = f(t_s, x_s)`. Serial and per-row by
/// construction, so replays are bitwise-deterministic regardless of how
/// the forward solve was scheduled.
fn forward_row_step(
    sys: &dyn OdeSystem,
    ct: &CompiledTableau,
    inst: usize,
    t: f64,
    dt: f64,
    y: &mut [f64],
    xs: &mut [f64],
    ks: &mut [f64],
    nw: &mut RowNewton,
) {
    let tab = ct.tab;
    let dim = y.len();
    for s in 0..tab.stages {
        let ts = t + tab.c[s] * dt;
        let d_s = if s < tab.diag.len() { tab.diag[s] } else { 0.0 };
        for d in 0..dim {
            let mut acc = 0.0;
            for &(j, w) in &ct.a_nz[s] {
                acc += w * ks[j * dim + d];
            }
            nw.rhs[d] = y[d] + dt * acc;
        }
        let x = &mut xs[s * dim..(s + 1) * dim];
        if d_s == 0.0 {
            x.copy_from_slice(&nw.rhs);
        } else {
            // Predictor: extrapolate with the previous slope (the
            // registry validates ESDIRK tableaus, so stage 0 is explicit
            // and `k_{s−1}` is always populated here).
            for d in 0..dim {
                let warm = if s > 0 { ks[(s - 1) * dim + d] } else { 0.0 };
                x[d] = nw.rhs[d] + dt * d_s * warm;
            }
            nw.newton(sys, inst, ts, dt * d_s, x);
        }
        sys.f_inst(inst, ts, x, &mut ks[s * dim..(s + 1) * dim]);
    }
    for d in 0..dim {
        let mut acc = 0.0;
        for &(j, w) in &ct.b_nz {
            acc += w * ks[j * dim + d];
        }
        y[d] += dt * acc;
    }
}

/// Per-row backward scratch for [`backward_step_row`].
struct IftWork {
    /// Stage adjoint seeds, `stages × dim`.
    dk: Vec<f64>,
    /// Copy of the current stage's seed (the IFT solve mutates it).
    seed: Vec<f64>,
    vjp_y: Vec<f64>,
    vjp_p: Vec<f64>,
    /// Present only for implicit tableaus.
    nw: Option<RowNewton>,
}

impl IftWork {
    fn new(sys: &dyn OdeSystem, tab: &'static Tableau) -> Self {
        let dim = sys.dim();
        IftWork {
            dk: vec![0.0; tab.stages * dim],
            seed: vec![0.0; dim],
            vjp_y: vec![0.0; dim],
            vjp_p: vec![0.0; sys.n_params()],
            nw: if tab.diag.is_empty() { None } else { Some(RowNewton::new(sys)) },
        }
    }
}

/// Reverse-sweep one accepted step of one row.
///
/// `xs` holds the row's recorded stage states (`stages × dim`); `dl_dy`
/// enters as `∂L/∂y_{n+1}` and leaves as `∂L/∂y_n`; parameter gradients
/// accumulate into `dl_dp`. Explicit stages apply the system VJP at the
/// stage input; DIRK stages first route the seed through
/// `w = (I − h·γ_s·J)⁻ᵀ·u` (implicit-function theorem), then apply the
/// VJP at the converged stage state — `Jᵀw` flows into `y_n` and earlier
/// stages exactly like an explicit stage's `vjp_y`, and `f_θᵀw` into θ.
fn backward_step_row(
    sys: &dyn OdeSystem,
    ct: &CompiledTableau,
    inst: usize,
    t: f64,
    dt: f64,
    xs: &[f64],
    dl_dy: &mut [f64],
    dl_dp: &mut [f64],
    w: &mut IftWork,
) {
    let tab = ct.tab;
    let dim = dl_dy.len();
    // Seeds: ∂L/∂k_s = dt · b_s · ∂L/∂y_{n+1} (then corrected by later
    // stages' dependencies during the reverse sweep).
    for s in 0..tab.stages {
        let g = &mut w.dk[s * dim..(s + 1) * dim];
        if tab.b[s] != 0.0 {
            for (gd, up) in g.iter_mut().zip(dl_dy.iter()) {
                *gd = dt * tab.b[s] * up;
            }
        } else {
            g.iter_mut().for_each(|v| *v = 0.0);
        }
    }
    for s in (0..tab.stages).rev() {
        // Skip all-zero seeds cheaply.
        if w.dk[s * dim..(s + 1) * dim].iter().all(|&v| v == 0.0) {
            continue;
        }
        w.seed.copy_from_slice(&w.dk[s * dim..(s + 1) * dim]);
        let ts = t + tab.c[s] * dt;
        let x = &xs[s * dim..(s + 1) * dim];
        let d_s = if s < tab.diag.len() { tab.diag[s] } else { 0.0 };
        if d_s != 0.0 {
            let nw = w.nw.as_mut().expect("implicit tableau requires Newton workspace");
            let ok = nw.prepare(sys, inst, ts, x, dt * d_s, true);
            assert!(ok, "singular (I − hγJ) in the implicit backward pass");
            nw.solve_t(&mut w.seed);
        }
        w.vjp_y.iter_mut().for_each(|v| *v = 0.0);
        w.vjp_p.iter_mut().for_each(|v| *v = 0.0);
        sys.vjp_inst(inst, ts, x, &w.seed, &mut w.vjp_y, &mut w.vjp_p);
        for (dst, v) in dl_dp.iter_mut().zip(&w.vjp_p) {
            *dst += v;
        }
        // ∂rhs_s/∂y_n = I → flows into dl_dy; ∂rhs_s/∂k_j = dt·a_sj.
        for (dst, v) in dl_dy.iter_mut().zip(&w.vjp_y) {
            *dst += v;
        }
        if s > 0 {
            for (j, &a) in tab.a_row(s).iter().enumerate() {
                if a != 0.0 {
                    let tgt = &mut w.dk[j * dim..(j + 1) * dim];
                    for (td, v) in tgt.iter_mut().zip(&w.vjp_y) {
                        *td += dt * a * v;
                    }
                }
            }
        }
    }
}

/// Tape of a fixed-step forward solve for one batch.
pub struct RkTape {
    ct: &'static CompiledTableau,
    dt: f64,
    t0: f64,
    n_steps: usize,
    batch: usize,
    dim: usize,
    /// `y` at the start of each step (+ final): `(n_steps+1) × batch × dim`.
    ys: Vec<f64>,
    /// Stage states per step (`n_steps × stages × batch × dim`): where
    /// `f` was evaluated — the explicit stage input, or the converged
    /// Newton solution for DIRK stages.
    stage_inputs: Vec<f64>,
    /// Stage slopes per step: same layout.
    ks: Vec<f64>,
}

impl RkTape {
    #[inline]
    fn tab(&self) -> &'static Tableau {
        self.ct.tab
    }

    #[inline]
    fn y_at(&self, step: usize) -> &[f64] {
        let n = self.batch * self.dim;
        &self.ys[step * n..(step + 1) * n]
    }

    #[inline]
    fn stage_input(&self, step: usize, s: usize, i: usize) -> &[f64] {
        let per_step = self.tab().stages * self.batch * self.dim;
        let lo = step * per_step + (s * self.batch + i) * self.dim;
        &self.stage_inputs[lo..lo + self.dim]
    }

    #[inline]
    fn k(&self, step: usize, s: usize, i: usize) -> &[f64] {
        let per_step = self.tab().stages * self.batch * self.dim;
        let lo = step * per_step + (s * self.batch + i) * self.dim;
        &self.ks[lo..lo + self.dim]
    }

    /// Final state `(batch, dim)`.
    pub fn y_final(&self) -> BatchVec {
        BatchVec::from_flat(self.y_at(self.n_steps).to_vec(), self.batch, self.dim)
    }

    /// State after `step` steps.
    pub fn y_step(&self, step: usize) -> BatchVec {
        BatchVec::from_flat(self.y_at(step).to_vec(), self.batch, self.dim)
    }

    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    pub fn t_at(&self, step: usize) -> f64 {
        self.t0 + step as f64 * self.dt
    }

    /// Resident tape size in bytes (the O(steps) memory the continuous
    /// adjoint avoids); benchmarked by the `adjointsweep` section.
    pub fn tape_bytes(&self) -> usize {
        (self.ys.capacity() + self.stage_inputs.capacity() + self.ks.capacity())
            * std::mem::size_of::<f64>()
    }
}

/// Fixed-step forward solve recording a tape for [`rk_backward`].
///
/// Explicit tableaus record the batched stage inputs directly; implicit
/// (ESDIRK) tableaus run a per-row Newton solve per diagonal stage and
/// record the converged stage states, so TR-BDF2 / Kvaerno 4(3) tapes
/// backpropagate exactly like explicit ones.
pub fn rk_forward_tape(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    t0: f64,
    dt: f64,
    n_steps: usize,
    method: MethodId,
) -> RkTape {
    let ct = method.compiled();
    let tab = ct.tab;
    let batch = y0.batch();
    let dim = y0.dim();
    let n = batch * dim;
    let per_step = tab.stages * n;

    let mut tape = RkTape {
        ct,
        dt,
        t0,
        n_steps,
        batch,
        dim,
        ys: vec![0.0; (n_steps + 1) * n],
        stage_inputs: vec![0.0; n_steps * per_step],
        ks: vec![0.0; n_steps * per_step],
    };
    tape.ys[..n].copy_from_slice(y0.flat());

    if !tab.diag.is_empty() {
        // Implicit path: per-row stage solves (each row's Newton is
        // independent, keeping rows bitwise-independent of batch order).
        let mut nw = RowNewton::new(sys);
        let mut y = vec![0.0; dim];
        let mut xs_row = vec![0.0; tab.stages * dim];
        let mut ks_row = vec![0.0; tab.stages * dim];
        for step in 0..n_steps {
            let t = t0 + step as f64 * dt;
            for i in 0..batch {
                y.copy_from_slice(&tape.y_at(step)[i * dim..(i + 1) * dim]);
                forward_row_step(sys, ct, i, t, dt, &mut y, &mut xs_row, &mut ks_row, &mut nw);
                for s in 0..tab.stages {
                    let lo = step * per_step + (s * batch + i) * dim;
                    tape.stage_inputs[lo..lo + dim].copy_from_slice(&xs_row[s * dim..(s + 1) * dim]);
                    tape.ks[lo..lo + dim].copy_from_slice(&ks_row[s * dim..(s + 1) * dim]);
                }
                let dest = (step + 1) * n + i * dim;
                tape.ys[dest..dest + dim].copy_from_slice(&y);
            }
        }
        return tape;
    }

    let mut y = y0.clone();
    let mut ytmp = BatchVec::zeros(batch, dim);
    let mut kbuf = BatchVec::zeros(batch, dim);
    for step in 0..n_steps {
        let t = t0 + step as f64 * dt;
        for s in 0..tab.stages {
            // Stage input.
            for i in 0..batch {
                let yrow = y.row(i);
                let out = ytmp.row_mut(i);
                if s == 0 {
                    out.copy_from_slice(yrow);
                } else {
                    for d in 0..dim {
                        let mut acc = 0.0;
                        for &(j, w) in &ct.a_nz[s] {
                            acc += w * tape.k(step, j, i)[d];
                        }
                        out[d] = yrow[d] + dt * acc;
                    }
                }
            }
            let ts = vec![t + tab.c[s] * dt; batch];
            sys.f_batch(&ts, &ytmp, &mut kbuf, None);
            // Record.
            let lo = step * per_step + s * n;
            tape.stage_inputs[lo..lo + n].copy_from_slice(ytmp.flat());
            tape.ks[lo..lo + n].copy_from_slice(kbuf.flat());
        }
        // Combine.
        for i in 0..batch {
            let dest_lo = (step + 1) * n + i * dim;
            for d in 0..dim {
                let mut acc = 0.0;
                for &(j, w) in &ct.b_nz {
                    acc += w * tape.k(step, j, i)[d];
                }
                tape.ys[dest_lo + d] = y.row(i)[d] + dt * acc;
            }
        }
        let (src, dst) = (tape.y_at(step + 1).to_vec(), y.flat_mut());
        dst.copy_from_slice(&src);
    }
    tape
}

/// Exact gradients through the taped solve: returns `(∂L/∂y0, ∂L/∂θ)`
/// given `∂L/∂y(T)`.
pub fn rk_backward(sys: &dyn OdeSystem, tape: &RkTape, dl_dy_t: &BatchVec) -> (BatchVec, Vec<f64>) {
    let tab = tape.tab();
    let (batch, dim) = (tape.batch, tape.dim);
    let mut dl_dy = dl_dy_t.clone();
    let mut dl_dp = vec![0.0; sys.n_params()];
    let mut work = IftWork::new(sys, tab);
    let mut xs = vec![0.0; tab.stages * dim];
    for i in 0..batch {
        let dl_row = dl_dy.row_mut(i);
        for step in (0..tape.n_steps).rev() {
            for s in 0..tab.stages {
                xs[s * dim..(s + 1) * dim].copy_from_slice(tape.stage_input(step, s, i));
            }
            backward_step_row(sys, tape.ct, i, tape.t_at(step), tape.dt, &xs, dl_row, &mut dl_dp, &mut work);
        }
    }
    (dl_dy, dl_dp)
}

/// Per-row tape row: the accepted `(t, dt)` sequence and the stage
/// states recorded while replaying it.
struct RowTape {
    steps: Vec<(f64, f64)>,
    /// `steps × stages × dim` stage states.
    xs: Vec<f64>,
}

/// Tape of an *adaptive-step* forward solve: each row's accepted step
/// sequence replayed exactly, with ragged per-row storage (stiff rows
/// keep more steps than easy ones).
pub struct AdaptiveTape {
    method: MethodId,
    batch: usize,
    dim: usize,
    rows: Vec<RowTape>,
    /// Replayed final states, `batch × dim`.
    yf: Vec<f64>,
}

impl AdaptiveTape {
    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn method(&self) -> MethodId {
        self.method
    }

    /// Accepted steps replayed for row `i`.
    pub fn n_steps(&self, i: usize) -> usize {
        self.rows[i].steps.len()
    }

    /// Total accepted steps across the batch.
    pub fn total_steps(&self) -> usize {
        self.rows.iter().map(|r| r.steps.len()).sum()
    }

    /// Replayed final state `(batch, dim)`.
    pub fn y_final(&self) -> BatchVec {
        BatchVec::from_flat(self.yf.clone(), self.batch, self.dim)
    }

    /// Resident tape size in bytes — scales with the accepted step count,
    /// which is the quantity the backsolve adjoint's O(1) memory avoids;
    /// benchmarked by the `adjointsweep` section.
    pub fn tape_bytes(&self) -> usize {
        let f = std::mem::size_of::<f64>();
        let per_row: usize = self
            .rows
            .iter()
            .map(|r| r.xs.capacity() * f + r.steps.capacity() * std::mem::size_of::<(f64, f64)>())
            .sum();
        per_row + self.yf.capacity() * f
    }
}

/// Build an [`AdaptiveTape`] by replaying a traced solve.
///
/// `sol` must come from a solve with [`SolveOptions::with_trace`] and the
/// same `method`; its trace holds each row's accepted `(t, dt)` sequence
/// indexed by *original* instance (compaction-aware). The joint loop
/// records one shared sequence in row 0 and leaves the rest empty — rows
/// with an empty trace reuse row 0's, matching that convention. Each row
/// is then re-integrated serially from `y0` through the exact recorded
/// steps, storing every stage state. The trace is bitwise-identical
/// across pool kinds / thread counts / layouts and the replay is serial,
/// so gradients from [`rk_backward_adaptive`] share the forward solves'
/// bitwise-determinism contract.
pub fn replay_tape(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    sol: &Solution,
    method: MethodId,
) -> AdaptiveTape {
    let trace = sol
        .trace
        .as_ref()
        .expect("adaptive tape needs a recorded step trace: solve with SolveOptions::with_trace()");
    let ct = method.compiled();
    let tab = ct.tab;
    let (batch, dim) = (y0.batch(), y0.dim());
    assert_eq!(trace.len(), batch, "trace rows must match the batch");

    let mut nw = RowNewton::new(sys);
    let mut y = vec![0.0; dim];
    let mut ks = vec![0.0; tab.stages * dim];
    let mut rows = Vec::with_capacity(batch);
    let mut yf = vec![0.0; batch * dim];
    for i in 0..batch {
        let tr: &[(f64, f64)] =
            if trace[i].is_empty() && i > 0 { &trace[0] } else { &trace[i] };
        y.copy_from_slice(y0.row(i));
        let per_step = tab.stages * dim;
        let mut xs = vec![0.0; tr.len() * per_step];
        for (si, &(t, dt)) in tr.iter().enumerate() {
            let xs_step = &mut xs[si * per_step..(si + 1) * per_step];
            forward_row_step(sys, ct, i, t, dt, &mut y, xs_step, &mut ks, &mut nw);
        }
        yf[i * dim..(i + 1) * dim].copy_from_slice(&y);
        rows.push(RowTape { steps: tr.to_vec(), xs });
    }
    AdaptiveTape { method, batch, dim, rows, yf }
}

/// Adaptive-step forward solve + tape in one call: runs the parallel
/// loop over `[t0, t1]` with trace recording forced on, then replays it
/// with [`replay_tape`]. The solve uses `opts.method` and all of its
/// tolerance / controller / layout settings.
pub fn rk_forward_tape_adaptive(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    t0: f64,
    t1: f64,
    opts: &SolveOptions,
) -> (Solution, AdaptiveTape) {
    let o = opts.clone().with_trace();
    let grid = TimeGrid::linspace_shared(y0.batch(), t0, t1, 2);
    let sol = super::solve_ivp_parallel(sys, y0, &grid, &o);
    let tape = replay_tape(sys, y0, &sol, o.method);
    (sol, tape)
}

/// Exact gradients through an adaptive tape: returns `(∂L/∂y0, ∂L/∂θ)`
/// given `∂L/∂y(T)` — the gradient of the replayed discrete map, i.e.
/// of the solver's actual accepted-step trajectory.
pub fn rk_backward_adaptive(
    sys: &dyn OdeSystem,
    tape: &AdaptiveTape,
    dl_dy_t: &BatchVec,
) -> (BatchVec, Vec<f64>) {
    let ct = tape.method.compiled();
    let tab = ct.tab;
    let (batch, dim) = (tape.batch, tape.dim);
    let mut dl_dy = dl_dy_t.clone();
    let mut dl_dp = vec![0.0; sys.n_params()];
    let mut work = IftWork::new(sys, tab);
    let per_step = tab.stages * dim;
    for i in 0..batch {
        let row = &tape.rows[i];
        let dl_row = dl_dy.row_mut(i);
        for si in (0..row.steps.len()).rev() {
            let (t, dt) = row.steps[si];
            let xs = &row.xs[si * per_step..(si + 1) * per_step];
            backward_step_row(sys, ct, i, t, dt, xs, dl_row, &mut dl_dp, &mut work);
        }
    }
    (dl_dy, dl_dp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, VdP};
    use crate::solver::MethodId;

    #[test]
    fn forward_tape_matches_solver() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::from_rows(&[vec![1.0]]);
        let tape = rk_forward_tape(&sys, &y0, 0.0, 0.01, 100, MethodId::RK4);
        let yf = tape.y_final();
        assert!((yf.row(0)[0] - (-1.0f64).exp()).abs() < 1e-9);
        assert_eq!(tape.n_steps(), 100);
        assert!((tape.t_at(100) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gradient_matches_analytic_exponential() {
        // L = y(T), ẏ = -λ y: ∂L/∂y0 = e^{-λT}, ∂L/∂λ = -T y0 e^{-λT}.
        let lam = 1.3;
        let sys = ExponentialDecay::new(vec![lam], 1);
        let y0 = BatchVec::from_rows(&[vec![2.0]]);
        let tt = 1.0;
        let tape = rk_forward_tape(&sys, &y0, 0.0, tt / 200.0, 200, MethodId::RK4);
        let dl = BatchVec::from_rows(&[vec![1.0]]);
        let (dy0, dp) = rk_backward(&sys, &tape, &dl);
        assert!((dy0.row(0)[0] - (-lam * tt).exp()).abs() < 1e-6);
        assert!((dp[0] - (-tt * 2.0 * (-lam * tt).exp())).abs() < 1e-5);
    }

    #[test]
    fn gradient_matches_fd_vdp() {
        let mu = 1.1;
        let tt = 1.0;
        let n = 100;
        let y0v = [1.0, -0.3];
        let run = |mu: f64, y0v: [f64; 2]| -> f64 {
            let sys = VdP::new(vec![mu]);
            let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
            let tape = rk_forward_tape(&sys, &y0, 0.0, tt / n as f64, n, MethodId::RK4);
            tape.y_final().row(0)[1] // L = v(T)
        };
        let sys = VdP::new(vec![mu]);
        let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
        let tape = rk_forward_tape(&sys, &y0, 0.0, tt / n as f64, n, MethodId::RK4);
        let dl = BatchVec::from_rows(&[vec![0.0, 1.0]]);
        let (dy0, dp) = rk_backward(&sys, &tape, &dl);
        let h = 1e-6;
        for d in 0..2 {
            let mut yp = y0v;
            yp[d] += h;
            let mut ym = y0v;
            ym[d] -= h;
            let fd = (run(mu, yp) - run(mu, ym)) / (2.0 * h);
            assert!((dy0.row(0)[d] - fd).abs() < 1e-6, "d={d}: {} vs {fd}", dy0.row(0)[d]);
        }
        let fd_mu = (run(mu + h, y0v) - run(mu - h, y0v)) / (2.0 * h);
        assert!((dp[0] - fd_mu).abs() < 1e-6, "{} vs {fd_mu}", dp[0]);
    }

    #[test]
    fn gradient_matches_fd_dopri5_fixed() {
        // Backprop works for any explicit tableau, not just rk4.
        let sys = ExponentialDecay::new(vec![0.7], 1);
        let y0 = BatchVec::from_rows(&[vec![1.5]]);
        let tape = rk_forward_tape(&sys, &y0, 0.0, 0.05, 20, MethodId::DOPRI5);
        let dl = BatchVec::from_rows(&[vec![1.0]]);
        let (dy0, _) = rk_backward(&sys, &tape, &dl);
        let expect = (-0.7f64).exp();
        assert!((dy0.row(0)[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn gradient_matches_fd_trbdf2_fixed() {
        // Implicit tableau through the IFT backward: the gradient must be
        // the gradient of the discrete TR-BDF2 map, checked against
        // central differences of the same fixed-step solve.
        let mu = 1.1;
        let tt = 0.8;
        let n = 40;
        let y0v = [1.0, -0.3];
        let run = |mu: f64, y0v: [f64; 2]| -> f64 {
            let sys = VdP::new(vec![mu]);
            let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
            let tape = rk_forward_tape(&sys, &y0, 0.0, tt / n as f64, n, MethodId::TRBDF2);
            tape.y_final().row(0)[1]
        };
        let sys = VdP::new(vec![mu]);
        let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
        let tape = rk_forward_tape(&sys, &y0, 0.0, tt / n as f64, n, MethodId::TRBDF2);
        let dl = BatchVec::from_rows(&[vec![0.0, 1.0]]);
        let (dy0, dp) = rk_backward(&sys, &tape, &dl);
        let h = 1e-5;
        for d in 0..2 {
            let mut yp = y0v;
            yp[d] += h;
            let mut ym = y0v;
            ym[d] -= h;
            let fd = (run(mu, yp) - run(mu, ym)) / (2.0 * h);
            assert!(
                (dy0.row(0)[d] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "d={d}: {} vs {fd}",
                dy0.row(0)[d]
            );
        }
        let fd_mu = (run(mu + h, y0v) - run(mu - h, y0v)) / (2.0 * h);
        assert!((dp[0] - fd_mu).abs() < 1e-4 * (1.0 + fd_mu.abs()), "{} vs {fd_mu}", dp[0]);
    }

    #[test]
    fn adaptive_tape_replays_forward_solve() {
        let sys = VdP::new(vec![1.0, 2.5]);
        let y0 = BatchVec::from_rows(&[vec![1.0, 0.0], vec![2.0, -0.5]]);
        let opts = SolveOptions::new(MethodId::DOPRI5);
        let (sol, tape) = rk_forward_tape_adaptive(&sys, &y0, 0.0, 2.0, &opts);
        assert!(sol.all_success());
        let yf = tape.y_final();
        for i in 0..2 {
            for d in 0..2 {
                let a = yf.row(i)[d];
                let b = sol.y_final(i)[d];
                // The replay retraces the exact accepted steps; explicit
                // stage arithmetic matches the solver's stage kernels to
                // rounding.
                assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "i={i} d={d}: {a} vs {b}");
            }
        }
        assert!(tape.n_steps(0) > 0 && tape.n_steps(1) > 0);
        assert!(tape.tape_bytes() > 0);
    }

    #[test]
    fn adaptive_gradient_matches_fixed_tape() {
        // With a forced fixed dt, the adaptive tape replays the same
        // discrete map as the fixed tape — gradients must agree closely.
        let sys = VdP::new(vec![0.9]);
        let y0 = BatchVec::from_rows(&[vec![1.2, -0.1]]);
        let (tt, n) = (1.0, 50);
        let dt = tt / n as f64;
        let fixed = rk_forward_tape(&sys, &y0, 0.0, dt, n, MethodId::RK4);
        let opts = SolveOptions::new(MethodId::RK4).with_fixed_dt(dt);
        let (_, adaptive) = rk_forward_tape_adaptive(&sys, &y0, 0.0, tt, &opts);
        let dl = BatchVec::from_rows(&[vec![1.0, 0.0]]);
        let (gf, pf) = rk_backward(&sys, &fixed, &dl);
        let (ga, pa) = rk_backward_adaptive(&sys, &adaptive, &dl);
        for d in 0..2 {
            let (a, b) = (ga.row(0)[d], gf.row(0)[d]);
            assert!((a - b).abs() < 1e-8 * (1.0 + b.abs()), "d={d}: {a} vs {b}");
        }
        assert!((pa[0] - pf[0]).abs() < 1e-8 * (1.0 + pf[0].abs()));
    }

    #[test]
    fn batch_gradients_independent() {
        let sys = VdP::new(vec![0.5, 2.0]);
        let y0 = BatchVec::from_rows(&[vec![1.0, 0.0], vec![1.0, 0.0]]);
        let tape = rk_forward_tape(&sys, &y0, 0.0, 0.01, 50, MethodId::RK4);
        let dl = BatchVec::from_rows(&[vec![1.0, 0.0], vec![0.0, 0.0]]);
        let (dy0, _) = rk_backward(&sys, &tape, &dl);
        // Zero seed on instance 1 => zero gradient there.
        assert_eq!(dy0.row(1), [0.0, 0.0]);
        assert!(dy0.row(0)[0].abs() > 0.0);
    }
}
