//! Discretize-then-optimize: exact backpropagation through a fixed-step
//! Runge–Kutta solve.
//!
//! The paper's FEN benchmark trains "via backpropagation through the
//! solver". For a fixed-step explicit RK method the solve is a finite
//! composition of differentiable maps, so the exact gradient is the chain
//! rule over steps and stages — no adjoint-ODE approximation involved.
//!
//! The forward pass records every stage input; the backward pass walks
//! steps in reverse, propagating `∂L/∂y` through
//!
//! ```text
//! y_{n+1} = y_n + h Σ_s b_s k_s,   k_s = f(t_n + c_s h, y_n + h Σ_j a_sj k_j)
//! ```
//!
//! using the system's VJPs, and accumulating parameter gradients.
//! Memory is O(steps × stages × dim) per instance, the standard
//! discretize-then-optimize trade-off.

use super::step::CompiledTableau;
use super::tableau::Tableau;
use crate::problems::OdeSystem;
use crate::tensor::BatchVec;

/// Tape of a fixed-step forward solve for one batch.
pub struct RkTape {
    tab: &'static Tableau,
    dt: f64,
    t0: f64,
    n_steps: usize,
    batch: usize,
    dim: usize,
    /// `y` at the start of each step (+ final): `(n_steps+1) × batch × dim`.
    ys: Vec<f64>,
    /// Stage inputs per step: `n_steps × stages × batch × dim`.
    stage_inputs: Vec<f64>,
    /// Stage slopes per step: same layout.
    ks: Vec<f64>,
}

impl RkTape {
    #[inline]
    fn y_at(&self, step: usize) -> &[f64] {
        let n = self.batch * self.dim;
        &self.ys[step * n..(step + 1) * n]
    }

    #[inline]
    fn stage_input(&self, step: usize, s: usize, i: usize) -> &[f64] {
        let per_step = self.tab.stages * self.batch * self.dim;
        let lo = step * per_step + (s * self.batch + i) * self.dim;
        &self.stage_inputs[lo..lo + self.dim]
    }

    #[inline]
    fn k(&self, step: usize, s: usize, i: usize) -> &[f64] {
        let per_step = self.tab.stages * self.batch * self.dim;
        let lo = step * per_step + (s * self.batch + i) * self.dim;
        &self.ks[lo..lo + self.dim]
    }

    /// Final state `(batch, dim)`.
    pub fn y_final(&self) -> BatchVec {
        BatchVec::from_flat(self.y_at(self.n_steps).to_vec(), self.batch, self.dim)
    }

    /// State after `step` steps.
    pub fn y_step(&self, step: usize) -> BatchVec {
        BatchVec::from_flat(self.y_at(step).to_vec(), self.batch, self.dim)
    }

    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    pub fn t_at(&self, step: usize) -> f64 {
        self.t0 + step as f64 * self.dt
    }
}

/// Fixed-step forward solve recording a tape for [`rk_backward`].
pub fn rk_forward_tape(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    t0: f64,
    dt: f64,
    n_steps: usize,
    method: super::MethodId,
) -> RkTape {
    let tab = method.tableau();
    assert!(
        tab.diag.is_empty(),
        "discretize-then-differentiate backprop only supports explicit methods, got {}",
        tab.name
    );
    let ct = CompiledTableau::cached(method);
    let batch = y0.batch();
    let dim = y0.dim();
    let n = batch * dim;
    let per_step = tab.stages * n;

    let mut tape = RkTape {
        tab,
        dt,
        t0,
        n_steps,
        batch,
        dim,
        ys: vec![0.0; (n_steps + 1) * n],
        stage_inputs: vec![0.0; n_steps * per_step],
        ks: vec![0.0; n_steps * per_step],
    };
    tape.ys[..n].copy_from_slice(y0.flat());

    let mut y = y0.clone();
    let mut ytmp = BatchVec::zeros(batch, dim);
    let mut kbuf = BatchVec::zeros(batch, dim);
    for step in 0..n_steps {
        let t = t0 + step as f64 * dt;
        for s in 0..tab.stages {
            // Stage input.
            for i in 0..batch {
                let yrow = y.row(i);
                let out = ytmp.row_mut(i);
                if s == 0 {
                    out.copy_from_slice(yrow);
                } else {
                    for d in 0..dim {
                        let mut acc = 0.0;
                        for &(j, w) in &ct.a_nz[s] {
                            acc += w * tape.k(step, j, i)[d];
                        }
                        out[d] = yrow[d] + dt * acc;
                    }
                }
            }
            let ts = vec![t + tab.c[s] * dt; batch];
            sys.f_batch(&ts, &ytmp, &mut kbuf, None);
            // Record.
            let lo = step * per_step + s * n;
            tape.stage_inputs[lo..lo + n].copy_from_slice(ytmp.flat());
            tape.ks[lo..lo + n].copy_from_slice(kbuf.flat());
        }
        // Combine.
        for i in 0..batch {
            let dest_lo = (step + 1) * n + i * dim;
            for d in 0..dim {
                let mut acc = 0.0;
                for &(j, w) in &ct.b_nz {
                    acc += w * tape.k(step, j, i)[d];
                }
                tape.ys[dest_lo + d] = y.row(i)[d] + dt * acc;
            }
        }
        let (src, dst) = (tape.y_at(step + 1).to_vec(), y.flat_mut());
        dst.copy_from_slice(&src);
    }
    tape
}

/// Exact gradients through the taped solve: returns `(∂L/∂y0, ∂L/∂θ)`
/// given `∂L/∂y(T)`.
pub fn rk_backward(
    sys: &dyn OdeSystem,
    tape: &RkTape,
    dl_dy_t: &BatchVec,
) -> (BatchVec, Vec<f64>) {
    let tab = tape.tab;
    let (batch, dim) = (tape.batch, tape.dim);
    let p = sys.n_params();
    let dt = tape.dt;
    let mut dl_dy = dl_dy_t.clone();
    let mut dl_dp = vec![0.0; p];
    // Per-stage adjoint seeds.
    let mut dk = vec![vec![0.0; batch * dim]; tab.stages];
    let mut vjp_y = vec![0.0; dim];
    let mut vjp_p = vec![0.0; p];

    for step in (0..tape.n_steps).rev() {
        let t = tape.t_at(step);
        // Seeds: ∂L/∂k_s = dt * b_s * ∂L/∂y_{n+1}  (then corrected by later
        // stages' dependencies during the reverse stage sweep).
        for s in 0..tab.stages {
            let g = &mut dk[s];
            if tab.b[s] != 0.0 {
                for (gd, up) in g.iter_mut().zip(dl_dy.flat()) {
                    *gd = dt * tab.b[s] * up;
                }
            } else {
                g.iter_mut().for_each(|v| *v = 0.0);
            }
        }
        // Reverse stage sweep: each stage's gradient flows into earlier
        // stages (via a_sj) and into y_n (directly).
        for s in (0..tab.stages).rev() {
            // Skip all-zero seeds cheaply.
            if dk[s].iter().all(|&v| v == 0.0) {
                continue;
            }
            let ts = t + tab.c[s] * dt;
            for i in 0..batch {
                let seed = &dk[s][i * dim..(i + 1) * dim];
                vjp_y.iter_mut().for_each(|v| *v = 0.0);
                vjp_p.iter_mut().for_each(|v| *v = 0.0);
                sys.vjp_inst(i, ts, tape.stage_input(step, s, i), seed, &mut vjp_y, &mut vjp_p);
                for j in 0..p {
                    dl_dp[j] += vjp_p[j];
                }
                // ∂stage_input/∂y_n = I → flows into dl_dy (accumulated
                // after the loop); ∂stage_input/∂k_j = dt·a_sj.
                let dl_dy_row = dl_dy.row_mut(i);
                for d in 0..dim {
                    dl_dy_row[d] += vjp_y[d];
                }
                if s > 0 {
                    for (j, &a) in tab.a_row(s).iter().enumerate() {
                        if a != 0.0 {
                            let tgt = &mut dk[j][i * dim..(i + 1) * dim];
                            for d in 0..dim {
                                tgt[d] += dt * a * vjp_y[d];
                            }
                        }
                    }
                }
            }
        }
        // NOTE: the direct identity path y_{n+1} = y_n + ... is already in
        // dl_dy (we accumulated into it), nothing more to do.
    }
    (dl_dy, dl_dp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, VdP};
    use crate::solver::MethodId;

    #[test]
    fn forward_tape_matches_solver() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::from_rows(&[vec![1.0]]);
        let tape = rk_forward_tape(&sys, &y0, 0.0, 0.01, 100, MethodId::RK4);
        let yf = tape.y_final();
        assert!((yf.row(0)[0] - (-1.0f64).exp()).abs() < 1e-9);
        assert_eq!(tape.n_steps(), 100);
        assert!((tape.t_at(100) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gradient_matches_analytic_exponential() {
        // L = y(T), ẏ = -λ y: ∂L/∂y0 = e^{-λT}, ∂L/∂λ = -T y0 e^{-λT}.
        let lam = 1.3;
        let sys = ExponentialDecay::new(vec![lam], 1);
        let y0 = BatchVec::from_rows(&[vec![2.0]]);
        let tt = 1.0;
        let tape = rk_forward_tape(&sys, &y0, 0.0, tt / 200.0, 200, MethodId::RK4);
        let dl = BatchVec::from_rows(&[vec![1.0]]);
        let (dy0, dp) = rk_backward(&sys, &tape, &dl);
        assert!((dy0.row(0)[0] - (-lam * tt).exp()).abs() < 1e-6);
        assert!((dp[0] - (-tt * 2.0 * (-lam * tt).exp())).abs() < 1e-5);
    }

    #[test]
    fn gradient_matches_fd_vdp() {
        let mu = 1.1;
        let tt = 1.0;
        let n = 100;
        let y0v = [1.0, -0.3];
        let run = |mu: f64, y0v: [f64; 2]| -> f64 {
            let sys = VdP::new(vec![mu]);
            let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
            let tape = rk_forward_tape(&sys, &y0, 0.0, tt / n as f64, n, MethodId::RK4);
            tape.y_final().row(0)[1] // L = v(T)
        };
        let sys = VdP::new(vec![mu]);
        let y0 = BatchVec::from_rows(&[y0v.to_vec()]);
        let tape = rk_forward_tape(&sys, &y0, 0.0, tt / n as f64, n, MethodId::RK4);
        let dl = BatchVec::from_rows(&[vec![0.0, 1.0]]);
        let (dy0, dp) = rk_backward(&sys, &tape, &dl);
        let h = 1e-6;
        for d in 0..2 {
            let mut yp = y0v;
            yp[d] += h;
            let mut ym = y0v;
            ym[d] -= h;
            let fd = (run(mu, yp) - run(mu, ym)) / (2.0 * h);
            assert!((dy0.row(0)[d] - fd).abs() < 1e-6, "d={d}: {} vs {fd}", dy0.row(0)[d]);
        }
        let fd_mu = (run(mu + h, y0v) - run(mu - h, y0v)) / (2.0 * h);
        assert!((dp[0] - fd_mu).abs() < 1e-6, "{} vs {fd_mu}", dp[0]);
    }

    #[test]
    fn gradient_matches_fd_dopri5_fixed() {
        // Backprop works for any explicit tableau, not just rk4.
        let sys = ExponentialDecay::new(vec![0.7], 1);
        let y0 = BatchVec::from_rows(&[vec![1.5]]);
        let tape = rk_forward_tape(&sys, &y0, 0.0, 0.05, 20, MethodId::DOPRI5);
        let dl = BatchVec::from_rows(&[vec![1.0]]);
        let (dy0, _) = rk_backward(&sys, &tape, &dl);
        let expect = (-0.7f64).exp();
        assert!((dy0.row(0)[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn batch_gradients_independent() {
        let sys = VdP::new(vec![0.5, 2.0]);
        let y0 = BatchVec::from_rows(&[vec![1.0, 0.0], vec![1.0, 0.0]]);
        let tape = rk_forward_tape(&sys, &y0, 0.0, 0.01, 50, MethodId::RK4);
        let dl = BatchVec::from_rows(&[vec![1.0, 0.0], vec![0.0, 0.0]]);
        let (dy0, _) = rk_backward(&sys, &tape, &dl);
        // Zero seed on instance 1 => zero gradient there.
        assert_eq!(dy0.row(1), [0.0, 0.0]);
        assert!(dy0.row(0)[0].abs() > 0.0);
    }
}
