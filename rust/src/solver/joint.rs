//! The joint solve loop — the torchdiffeq/TorchDyn baseline semantics.
//!
//! A batch of IVPs is concatenated into one problem of size `batch × dim`:
//! a single shared time, a single shared step size, one error norm over
//! the whole batch, and accept/reject decisions applied to everyone at
//! once. This is exactly the setting of the paper's §4.1 — the stiffest
//! instance dictates the common step size, and the solver takes up to 4×
//! as many steps as the parallel loop on heterogeneous batches.
//!
//! The loop body is written against the [`StageExec`] executor so the
//! row-update passes (stage accumulation, dynamics evaluation, solution
//! and error combination, and the fused error-norm partials) can be
//! sharded across a worker pool by
//! [`crate::exec::solve_ivp_joint_pooled`], while the shared controller
//! reduction below stays on the coordinator thread.
//!
//! The joint error norm is **fused** into the sharded passes: each row's
//! unreduced scaled sum of squares is produced by
//! [`StageExec::error_sumsq`] (one pass over `err`/`y`/`y_new` while they
//! are cache-hot from the attempt), and the coordinator reduces the
//! per-row partials in row order — never worker-arrival order — so the
//! shared norm `sqrt(Σ_rows sumsq / (batch · dim))` is bitwise-identical
//! whatever pool kind, thread count or steal-chunk size carried the
//! pass.
//!
//! Because every row shares one time and step size, the only per-row
//! progress in this loop is the dense-output cursor; a packed `pending`
//! index list (the joint loop's active set) keeps rows whose cursors are
//! exhausted out of the dense-output pass and turns the all-done check
//! into `pending.is_empty()`. All per-step buffers are hoisted out of the
//! loop, so the steady state performs zero heap allocations through the
//! inline executor (`tests/alloc_regression.rs`).

use super::controller::ControllerState;
use super::implicit;
use super::interp::{self, DOPRI5_NCOEFF};
use super::step::{CompiledTableau, InlineExec, RkWorkspace, StageExec, MAX_STAGES};
use super::tableau::DenseOutput;
use super::{SolveOptions, Solution, Status, TimeGrid};
use crate::problems::OdeSystem;
use crate::tensor::BatchVec;

/// Solve a batch of IVPs as one concatenated problem with shared solver
/// state. All instances must share their integration range
/// (`grid.t0(i)`/`grid.t1(i)` equal across `i`); per-instance evaluation
/// *points* inside the range are still allowed.
pub fn solve_ivp_joint(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> Solution {
    joint_core(&InlineExec { sys }, y0, grid, opts)
}

/// The joint loop over an executor (serial or pooled).
pub(crate) fn joint_core(
    exec: &dyn StageExec,
    y0: &BatchVec,
    grid: &TimeGrid,
    opts: &SolveOptions,
) -> Solution {
    let batch = y0.batch();
    let dim = y0.dim();
    assert_eq!(grid.batch(), batch);
    assert_eq!(exec.dim(), dim, "system/initial-state dim mismatch");
    opts.tols.validate(batch);
    let n_eval = grid.n_eval();
    let t0 = grid.t0(0);
    let t1 = grid.t1(0);
    for i in 1..batch {
        assert!(
            (grid.t0(i) - t0).abs() < 1e-12 && (grid.t1(i) - t1).abs() < 1e-12,
            "joint solving requires a shared integration range"
        );
    }
    let tab = opts.method.tableau();
    let ct = CompiledTableau::cached(opts.method);
    let adaptive = tab.adaptive() && opts.fixed_dt.is_none();

    let mut sol = Solution::new_buffer(batch, n_eval, dim);
    let mut trace: Vec<(f64, f64)> = Vec::new();

    let mut y = y0.clone();
    let mut t = t0;
    let mut ctrl = ControllerState::default();
    let mut next_eval = vec![0usize; batch];
    let span = t1 - t0;

    let mut ws = RkWorkspace::new_for_tableau(
        ct,
        batch,
        dim,
        exec.workspace_layout(opts.layout),
        &opts.tols,
        opts.jac_structure.unwrap_or_else(|| exec.jac_structure()),
    );
    let mut f_start = BatchVec::zeros(batch, dim);
    let mut interp_coeffs = vec![0.0; DOPRI5_NCOEFF * dim];

    for i in 0..batch {
        sol.y_mut(i, 0).copy_from_slice(y.row(i));
        sol.stats[i].n_initialized += 1;
        next_eval[i] = 1;
    }
    if n_eval == 1 || span <= 0.0 {
        for i in 0..batch {
            sol.status[i] = Status::Success;
        }
        return sol;
    }

    let mut t_vec = vec![t; batch];
    exec.eval(&t_vec, &y, &mut ws.k[0], None);
    let mut fevals: u64 = 1;
    f_start.copy_from(&ws.k[0]);

    // Shared initial step: minimum of the per-instance heuristics — the
    // same "stiffest member wins" effect the joint norm produces.
    let mut dt = match (opts.fixed_dt, opts.dt0) {
        (Some(h), _) => h,
        (None, Some(h)) => h,
        (None, None) => {
            let spans = vec![span; batch];
            let dt0 = exec.initial_step(
                &t_vec,
                &y,
                &ws.k[0],
                tab.order,
                &opts.tols,
                &spans,
                &mut ws.ytmp,
                &mut ws.y_new,
            );
            fevals += 1;
            dt0.into_iter().fold(f64::INFINITY, f64::min)
        }
    };

    let min_dt = span * opts.min_dt_rel;
    let mut k0_ready = true;
    let mut steps = 0usize;
    let mut done = false;
    let mut status = Status::MaxStepsReached;

    // The joint loop's active set: rows whose dense-output cursor still
    // has eval points to fill. Shared (t, dt) means this is the only
    // per-row progress to track.
    let mut pending: Vec<usize> = (0..batch).collect();
    // Per-step buffers hoisted out of the loop (zero-allocation steady
    // state; the shared scalars are broadcast by `fill`, not `vec!`).
    let mut dt_vec = vec![0.0f64; batch];
    let mut k0r = vec![true; batch];
    // Per-row partials of the fused joint error norm.
    let mut sumsq = vec![0.0f64; batch];

    while !done {
        steps += 1;
        if steps > opts.max_steps {
            status = Status::MaxStepsReached;
            break;
        }
        let mut clamped = false;
        if dt >= t1 - t {
            dt = t1 - t;
            clamped = true;
        }

        dt_vec.fill(dt);
        t_vec.fill(t);
        k0r.fill(k0_ready);
        let calls = exec.attempt(ct, &t_vec, &dt_vec, &y, &mut ws, &k0r, None, true);
        fevals += calls;
        for st in sol.stats.iter_mut() {
            st.n_steps += 1;
        }

        // Implicit methods: fold every row's Newton work into its stats
        // (rows pay for their own iterations on top of the shared
        // batched-call count), and remember whether any row's Newton
        // diverged — the shared step is then rejected outright below.
        let mut newton_failed = false;
        if let Some(nw) = ws.newton.as_mut() {
            for (i, st) in sol.stats.iter_mut().enumerate() {
                let (fe, je, lu) = nw.take_work(i);
                st.n_f_evals += fe;
                st.n_jac_evals += je;
                st.n_lu_factor += lu;
            }
            newton_failed = nw.any_failed();
        }

        if ws.y_new.flat().iter().any(|v| !v.is_finite()) {
            status = Status::NonFinite;
            break;
        }

        // One error norm over the concatenated state: RMS over batch × dim.
        // The per-row sum-of-squares partials are fused into the sharded
        // error pass (`error_sumsq`); only this scalar reduction — in row
        // order, never worker-arrival order — and the controller decision
        // run on the coordinator thread, so the joint loop's defining
        // coupling stays deterministic under any executor.
        if newton_failed && !adaptive {
            // A fixed step that cannot be solved is a hard failure:
            // with no controller to re-grow dt, silently shrinking
            // would integrate a different grid than requested.
            status = Status::NewtonDiverged;
            break;
        }
        let (accept, factor) = if newton_failed {
            // Divergence feeds the rejection path: shrink hard and retry
            // at the same (t, y). The min-dt safeguard below still turns
            // a never-converging Newton into DtUnderflow.
            (false, implicit::NEWTON_REJECT_FACTOR)
        } else if adaptive {
            exec.error_sumsq(&ws.err, &y, &ws.y_new, &opts.tols, &mut sumsq);
            let acc: f64 = sumsq.iter().sum();
            let en = (acc / (batch * dim) as f64).sqrt();
            let d = opts.controller.decide(en, tab.err_order, &ctrl);
            if d.accept {
                ctrl.push(en);
            }
            (d.accept, d.factor)
        } else {
            (true, 1.0)
        };

        if accept {
            for st in sol.stats.iter_mut() {
                st.n_accepted += 1;
            }
            let t_new = if clamped { t1 } else { t + dt };
            if opts.record_trace {
                trace.push((t, dt));
            }

            // Non-FSAL: evaluate the true end slope f(t_new, y_new) before
            // dense output (the stale-Hermite fix); it doubles as the k[0]
            // refresh for the next iteration.
            if !tab.fsal {
                t_vec.fill(t_new);
                exec.eval(&t_vec, &ws.y_new, &mut ws.k[0], None);
                fevals += 1;
            }

            // Dense output: only rows with unfilled eval points (the
            // packed `pending` list) are visited at all.
            for &i in &pending {
                let te_row = grid.row(i);
                let mut e = next_eval[i];
                let mut coeffs_ready = false;
                while e < n_eval && te_row[e] <= t_new {
                    let theta = ((te_row[e] - t) / dt).clamp(0.0, 1.0);
                    match tab.dense {
                        DenseOutput::Dopri5 => {
                            if !coeffs_ready {
                                let mut krows: [&[f64]; MAX_STAGES] = [&[]; MAX_STAGES];
                                for (slot, k) in krows.iter_mut().zip(ws.k.iter()) {
                                    *slot = k.row(i);
                                }
                                interp::dopri5_coeffs(
                                    dt,
                                    y.row(i),
                                    ws.y_new.row(i),
                                    &krows[..tab.stages],
                                    &mut interp_coeffs,
                                );
                                coeffs_ready = true;
                            }
                            interp::dopri5_eval(theta, &interp_coeffs, sol.y_mut(i, e));
                        }
                        DenseOutput::Hermite => {
                            // FSAL stage or the refreshed k[0] (both hold
                            // f(t_new, y_new)).
                            let f_end = if tab.fsal {
                                ws.k[tab.stages - 1].row(i)
                            } else {
                                ws.k[0].row(i)
                            };
                            interp::hermite_eval(
                                theta,
                                dt,
                                y.row(i),
                                f_start.row(i),
                                ws.y_new.row(i),
                                f_end,
                                sol.y_mut(i, e),
                            );
                        }
                    }
                    sol.stats[i].n_initialized += 1;
                    e += 1;
                }
                next_eval[i] = e;
            }
            pending.retain(|&i| next_eval[i] < n_eval);

            y.copy_from(&ws.y_new);
            t = t_new;
            if tab.fsal {
                let (head, tail) = ws.k.split_at_mut(tab.stages - 1);
                let (first, _) = head.split_first_mut().unwrap();
                first.copy_from(&tail[0]);
                f_start.copy_from(&tail[0]);
            } else {
                // k[0] already holds f(t_new, y_new) from the refresh.
                f_start.copy_from(&ws.k[0]);
            }
            k0_ready = true;

            if pending.is_empty() {
                status = Status::Success;
                done = true;
            }
        } else {
            k0_ready = true;
        }

        dt *= factor;
        if adaptive && !done && dt < min_dt {
            status = Status::DtUnderflow;
            break;
        }
    }

    // torchode semantics: every instance experiences every batched call.
    for st in sol.stats.iter_mut() {
        st.n_f_evals += fevals;
    }
    for i in 0..batch {
        sol.status[i] = status;
    }
    if opts.record_trace {
        let tail = (1..batch).map(|_| Vec::new());
        sol.trace = Some(vec![trace; 1].into_iter().chain(tail).collect());
    }
    sol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::{ExponentialDecay, VdP};
    use crate::solver::{solve_ivp_parallel, MethodId};

    #[test]
    fn joint_accuracy_on_homogeneous_batch() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::broadcast(&[1.0], 4);
        let grid = TimeGrid::linspace_shared(4, 0.0, 1.0, 11);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8);
        let sol = solve_ivp_joint(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        for i in 0..4 {
            assert!((sol.y_final(i)[0] - (-1.0f64).exp()).abs() < 1e-6);
        }
    }

    #[test]
    fn joint_shares_step_count() {
        let sys = VdP::new(vec![1.0, 20.0]);
        let y0 = BatchVec::from_rows(&[vec![2.0, 0.0], vec![2.0, 0.0]]);
        let grid = TimeGrid::linspace_shared(2, 0.0, 10.0, 20);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5);
        let sol = solve_ivp_joint(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        assert_eq!(sol.stats[0].n_steps, sol.stats[1].n_steps);
        assert_eq!(sol.stats[0].n_accepted, sol.stats[1].n_accepted);
    }

    /// The §4.1 effect: joint solving of a heterogeneous batch takes more
    /// steps than the slowest member needs, parallel solving does not.
    #[test]
    fn joint_pays_for_heterogeneity() {
        let mus = vec![1.0, 5.0, 10.0, 20.0];
        let b = mus.len();
        let sys = VdP::new(mus);
        let y0 = BatchVec::broadcast(&[2.0, 0.0], b);
        let grid = TimeGrid::linspace_shared(b, 0.0, 15.0, 30);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5);
        let joint = solve_ivp_joint(&sys, &y0, &grid, &opts);
        let par = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        assert!(joint.all_success() && par.all_success());
        // Joint steps ≥ the hardest instance's parallel steps.
        let max_par = par.stats.iter().map(|s| s.n_steps).max().unwrap();
        assert!(
            joint.stats[0].n_steps >= max_par,
            "joint {} < max parallel {max_par}",
            joint.stats[0].n_steps
        );
        // And the easy instance pays for the stiff one under joint batching.
        assert!(joint.stats[0].n_steps > 2 * par.stats[0].n_steps);
    }

    #[test]
    #[should_panic(expected = "shared integration range")]
    fn joint_rejects_heterogeneous_ranges() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::broadcast(&[1.0], 2);
        let grid = TimeGrid::from_rows(&[vec![0.0, 1.0], vec![0.0, 2.0]]);
        let opts = SolveOptions::new(MethodId::DOPRI5);
        solve_ivp_joint(&sys, &y0, &grid, &opts);
    }

    #[test]
    fn joint_matches_parallel_on_homogeneous_batch() {
        // With identical instances the two loops must produce near-identical
        // trajectories (controller decisions coincide).
        let sys = VdP::uniform(3, 2.0);
        let y0 = BatchVec::broadcast(&[1.0, 0.0], 3);
        let grid = TimeGrid::linspace_shared(3, 0.0, 5.0, 10);
        let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-7, 1e-7);
        let j = solve_ivp_joint(&sys, &y0, &grid, &opts);
        let p = solve_ivp_parallel(&sys, &y0, &grid, &opts);
        for e in 0..10 {
            for d in 0..2 {
                assert!((j.y(0, e)[d] - p.y(0, e)[d]).abs() < 1e-5);
            }
        }
    }

    /// Non-FSAL Hermite dense output through the joint loop also uses the
    /// true end slope (the same fix as in the parallel loop).
    #[test]
    fn joint_hermite_dense_output_uses_end_slope() {
        let sys = ExponentialDecay::new(vec![1.0], 1);
        let y0 = BatchVec::broadcast(&[1.0], 2);
        let grid = TimeGrid::linspace_shared(2, 0.0, 1.0, 41);
        let opts = SolveOptions::new(MethodId::RK4).with_fixed_dt(0.1).with_max_steps(1_000);
        let sol = solve_ivp_joint(&sys, &y0, &grid, &opts);
        assert!(sol.all_success());
        let mut max_err = 0.0f64;
        for e in 0..41 {
            let t = grid.row(0)[e];
            max_err = max_err.max((sol.y(0, e)[0] - (-t).exp()).abs());
        }
        assert!(max_err < 1e-5, "dense-output error {max_err} (stale end slope?)");
    }
}
