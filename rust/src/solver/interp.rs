//! Dense-output interpolation between accepted steps.
//!
//! All polynomials are evaluated via **Horner's rule** — the paper calls
//! this optimization out explicitly ("fast polynomial evaluation via
//! Horner's rule that saves half of the multiplications over the naive
//! evaluation method").
//!
//! Two interpolants are implemented:
//!
//! - [`hermite_eval`]: 3rd-order cubic Hermite from the step endpoints and
//!   slopes. Valid for any RK method (diffrax uses the same fallback).
//! - Dopri5's dedicated 4th-order interpolant (Hairer's `rcont` form),
//!   split into [`dopri5_coeffs`] (once per accepted step) and
//!   [`dopri5_eval`] (once per evaluation point).

use super::tableau::DOPRI5_D;

/// Cubic Hermite interpolation at normalized position `theta ∈ [0, 1]`
/// within a step from `(y0, f0)` to `(y1, f1)` of size `dt`, written in
/// Horner form: y(θ) = y0 + θ·(h00' + θ·(h10' + θ·h20')) per component.
#[inline]
pub fn hermite_eval(
    theta: f64,
    dt: f64,
    y0: &[f64],
    f0: &[f64],
    y1: &[f64],
    f1: &[f64],
    out: &mut [f64],
) {
    // Standard cubic Hermite basis regrouped by powers of θ:
    //   y(θ) = y0 + θ·a + θ²·b + θ³·c
    //   a = dt·f0
    //   b = 3Δ − dt·(2f0 + f1)
    //   c = −2Δ + dt·(f0 + f1),   Δ = y1 − y0
    for i in 0..out.len() {
        let d = y1[i] - y0[i];
        let a = dt * f0[i];
        let b = 3.0 * d - dt * (2.0 * f0[i] + f1[i]);
        let c = -2.0 * d + dt * (f0[i] + f1[i]);
        out[i] = y0[i] + theta * (a + theta * (b + theta * c));
    }
}

/// Number of `rcont` coefficient vectors for the dopri5 interpolant.
pub const DOPRI5_NCOEFF: usize = 5;

/// Compute the five dopri5 `rcont` coefficient vectors for one accepted
/// step. `k` holds the 7 stage slopes, each of length `dim`; `coeffs` is a
/// `5 * dim` scratch buffer filled as `[rcont1, rcont2, rcont3, rcont4,
/// rcont5]`.
pub fn dopri5_coeffs(dt: f64, y0: &[f64], y1: &[f64], k: &[&[f64]], coeffs: &mut [f64]) {
    let dim = y0.len();
    debug_assert_eq!(k.len(), 7);
    debug_assert_eq!(coeffs.len(), DOPRI5_NCOEFF * dim);
    let (r1, rest) = coeffs.split_at_mut(dim);
    let (r2, rest) = rest.split_at_mut(dim);
    let (r3, rest) = rest.split_at_mut(dim);
    let (r4, r5) = rest.split_at_mut(dim);
    for i in 0..dim {
        let ydiff = y1[i] - y0[i];
        let bspl = dt * k[0][i] - ydiff;
        r1[i] = y0[i];
        r2[i] = ydiff;
        r3[i] = bspl;
        r4[i] = ydiff - dt * k[6][i] - bspl;
        let mut acc = 0.0;
        for (s, d) in DOPRI5_D.iter().enumerate() {
            if *d != 0.0 {
                acc += d * k[s][i];
            }
        }
        r5[i] = dt * acc;
    }
}

/// Evaluate the dopri5 interpolant at `theta ∈ [0, 1]` from precomputed
/// `rcont` coefficients (Horner-style nesting as in Hairer's CONTD5):
/// y(θ) = r1 + θ·(r2 + (1−θ)·(r3 + θ·(r4 + (1−θ)·r5))).
#[inline]
pub fn dopri5_eval(theta: f64, coeffs: &[f64], out: &mut [f64]) {
    let dim = out.len();
    debug_assert_eq!(coeffs.len(), DOPRI5_NCOEFF * dim);
    let theta1 = 1.0 - theta;
    let r1 = &coeffs[0..dim];
    let r2 = &coeffs[dim..2 * dim];
    let r3 = &coeffs[2 * dim..3 * dim];
    let r4 = &coeffs[3 * dim..4 * dim];
    let r5 = &coeffs[4 * dim..5 * dim];
    for i in 0..dim {
        out[i] = r1[i] + theta * (r2[i] + theta1 * (r3[i] + theta * (r4[i] + theta1 * r5[i])));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hermite_matches_endpoints() {
        let y0 = [1.0, -2.0];
        let y1 = [3.0, 0.5];
        let f0 = [0.3, 1.0];
        let f1 = [-0.2, 2.0];
        let dt = 0.7;
        let mut out = [0.0; 2];
        hermite_eval(0.0, dt, &y0, &f0, &y1, &f1, &mut out);
        assert!((out[0] - y0[0]).abs() < 1e-14 && (out[1] - y0[1]).abs() < 1e-14);
        hermite_eval(1.0, dt, &y0, &f0, &y1, &f1, &mut out);
        assert!((out[0] - y1[0]).abs() < 1e-12 && (out[1] - y1[1]).abs() < 1e-12);
    }

    #[test]
    fn hermite_matches_endpoint_slopes() {
        // Numerical derivative of the interpolant at θ=0 must equal dt·f0.
        let y0 = [0.5];
        let y1 = [1.7];
        let f0 = [2.0];
        let f1 = [-1.0];
        let dt = 0.25;
        let h = 1e-6;
        let (mut a, mut b) = ([0.0], [0.0]);
        hermite_eval(0.0, dt, &y0, &f0, &y1, &f1, &mut a);
        hermite_eval(h, dt, &y0, &f0, &y1, &f1, &mut b);
        let dydtheta = (b[0] - a[0]) / h;
        assert!((dydtheta - dt * f0[0]).abs() < 1e-4);
        hermite_eval(1.0 - h, dt, &y0, &f0, &y1, &f1, &mut a);
        hermite_eval(1.0, dt, &y0, &f0, &y1, &f1, &mut b);
        let dydtheta = (b[0] - a[0]) / h;
        assert!((dydtheta - dt * f1[0]).abs() < 1e-4);
    }

    #[test]
    fn hermite_exact_for_cubic_in_disguise() {
        // For a linear function the interpolant must be exact everywhere.
        let dt = 2.0;
        let y0 = [1.0];
        let y1 = [5.0]; // slope 2 over dt=2
        let f0 = [2.0];
        let f1 = [2.0];
        let mut out = [0.0];
        for k in 0..=10 {
            let th = k as f64 / 10.0;
            hermite_eval(th, dt, &y0, &f0, &y1, &f1, &mut out);
            assert!((out[0] - (1.0 + 4.0 * th)).abs() < 1e-12);
        }
    }

    #[test]
    fn dopri5_interp_endpoints() {
        // Fabricate a plausible step; the interpolant must hit y0 at θ=0 and
        // y1 at θ=1 regardless of k (r-coefficients are constructed so).
        let dim = 3;
        let y0 = [1.0, 2.0, 3.0];
        let y1 = [1.5, 1.8, 3.3];
        let kdata: Vec<Vec<f64>> = (0..7).map(|s| vec![0.1 * s as f64; dim]).collect();
        let k: Vec<&[f64]> = kdata.iter().map(|v| v.as_slice()).collect();
        let mut coeffs = vec![0.0; DOPRI5_NCOEFF * dim];
        dopri5_coeffs(0.5, &y0, &y1, &k, &mut coeffs);
        let mut out = [0.0; 3];
        dopri5_eval(0.0, &coeffs, &mut out);
        for i in 0..dim {
            assert!((out[i] - y0[i]).abs() < 1e-14);
        }
        dopri5_eval(1.0, &coeffs, &mut out);
        for i in 0..dim {
            assert!((out[i] - y1[i]).abs() < 1e-14);
        }
    }
}
