//! The native batched Runge–Kutta core.
//!
//! This module is the Rust re-implementation of torchode's contribution:
//! an explicit RK solver that holds a **separate solver state for every
//! instance in a batch** — initial condition, integration bounds, step
//! size, accept/reject decision, controller history and status — while
//! still evaluating the (possibly learned) dynamics in one batched call
//! per stage, exactly as a GPU implementation would.
//!
//! Three solve loops share the same stage kernel ([`step`]):
//!
//! - [`solve_ivp_parallel`] — per-instance state (torchode).
//! - [`solve_ivp_joint`] — one shared step size / error norm for the whole
//!   batch (the torchdiffeq/TorchDyn baseline semantics).
//! - [`solve_ivp_naive`] — joint semantics with a deliberately per-op,
//!   allocation-heavy implementation: every arithmetic pass allocates and
//!   touches memory separately, emulating the one-kernel-per-op cost model
//!   of an eager GPU solver. Used as the implementation-efficiency baseline
//!   in the loop-time benchmarks.

pub mod active;
pub mod adjoint;
pub mod backprop;
pub mod controller;
pub mod implicit;
pub mod init;
pub mod interp;
pub mod joint;
pub mod kernels;
pub mod linalg;
pub mod method;
pub mod naive;
pub mod norm;
pub mod parallel;
pub mod reference;
pub mod step;
pub mod tableau;

pub use active::ActiveSet;
pub use adjoint::{
    adjoint_backward_joint, adjoint_backward_parallel, backsolve_adjoint_joint,
    backsolve_adjoint_parallel, AdjointOptions, AdjointResult,
};
pub use backprop::{
    replay_tape, rk_backward, rk_backward_adaptive, rk_forward_tape, rk_forward_tape_adaptive,
    AdaptiveTape, RkTape,
};
pub use controller::{Controller, ControllerState, StepDecision};
pub use joint::solve_ivp_joint;
pub use method::{register_method, register_method_with_aliases, MethodId, RegisterError};
pub use naive::solve_ivp_naive;
pub use parallel::solve_ivp_parallel;
pub use tableau::{DenseOutput, Tableau};

pub use crate::config::{ExecPolicy, PoolKind};
pub use crate::tensor::Layout;

use crate::tensor::BatchVec;

/// Per-instance termination status, mirroring torchode's `Status` enum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    /// All evaluation points were produced within tolerance.
    Success = 0,
    /// The step budget was exhausted before reaching the final time.
    MaxStepsReached = 1,
    /// The step size underflowed (problem too stiff for the method).
    DtUnderflow = 2,
    /// A non-finite value appeared in the state or error estimate.
    NonFinite = 3,
    /// An implicit method's Newton iteration failed to converge at the
    /// prescribed fixed step size (`SolveOptions::fixed_dt`). Adaptive
    /// solves never report this — a divergence there feeds the
    /// rejection path and, if Newton never recovers, ends in
    /// [`Status::DtUnderflow`] once dt hits the floor.
    NewtonDiverged = 4,
}

/// Per-instance evaluation grid: row `i` holds the (ascending) times at
/// which instance `i`'s solution is requested. Integration runs from
/// `t[i][0]` to `t[i][E-1]`; rows may cover completely different ranges —
/// no special handling is needed (torchode §3).
#[derive(Debug, Clone)]
pub struct TimeGrid {
    t: BatchVec,
}

impl TimeGrid {
    /// Same `linspace(t0, t1, n)` grid for every instance.
    pub fn linspace_shared(batch: usize, t0: f64, t1: f64, n: usize) -> Self {
        assert!(n >= 2, "need at least start and end point");
        let mut row = Vec::with_capacity(n);
        for k in 0..n {
            row.push(t0 + (t1 - t0) * k as f64 / (n - 1) as f64);
        }
        Self { t: BatchVec::broadcast(&row, batch) }
    }

    /// Per-instance grids; all rows must have the same number of points but
    /// may span different ranges.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let t = BatchVec::from_rows(rows);
        for i in 0..t.batch() {
            let r = t.row(i);
            for w in r.windows(2) {
                assert!(w[1] > w[0], "eval times must be strictly ascending");
            }
        }
        Self { t }
    }

    pub fn batch(&self) -> usize {
        self.t.batch()
    }

    /// Number of evaluation points per instance.
    pub fn n_eval(&self) -> usize {
        self.t.dim()
    }

    /// Evaluation times of instance `i`.
    pub fn row(&self, i: usize) -> &[f64] {
        self.t.row(i)
    }

    /// Integration start of instance `i`.
    pub fn t0(&self, i: usize) -> f64 {
        self.t.row(i)[0]
    }

    /// Integration end of instance `i`.
    pub fn t1(&self, i: usize) -> f64 {
        *self.t.row(i).last().unwrap()
    }

    /// Copy of the contiguous instance range `[lo, hi)` — the shard
    /// boundary of the exec layer.
    pub fn rows_range(&self, lo: usize, hi: usize) -> TimeGrid {
        TimeGrid { t: self.t.rows_range(lo, hi) }
    }
}

/// Tolerances, broadcastable per instance (torchode: "even parameters such
/// as tolerances could be specified separately for each problem").
#[derive(Debug, Clone)]
pub struct Tolerances {
    atol: Vec<f64>,
    rtol: Vec<f64>,
}

impl Tolerances {
    pub fn scalar(atol: f64, rtol: f64) -> Self {
        Self { atol: vec![atol], rtol: vec![rtol] }
    }

    pub fn per_instance(atol: Vec<f64>, rtol: Vec<f64>) -> Self {
        assert_eq!(atol.len(), rtol.len());
        Self { atol, rtol }
    }

    #[inline]
    pub fn atol(&self, i: usize) -> f64 {
        self.atol[i.min(self.atol.len() - 1)]
    }

    #[inline]
    pub fn rtol(&self, i: usize) -> f64 {
        self.rtol[i.min(self.rtol.len() - 1)]
    }

    /// Check the broadcast contract at solve entry: tolerances are either
    /// one scalar or exactly one entry per instance. Anything else would
    /// silently reuse the last entry through the clamped accessors above.
    pub fn validate(&self, batch: usize) {
        assert!(
            self.atol.len() == 1 || self.atol.len() == batch,
            "atol must have 1 or batch (= {batch}) entries, got {}",
            self.atol.len()
        );
        assert!(
            self.rtol.len() == 1 || self.rtol.len() == batch,
            "rtol must have 1 or batch (= {batch}) entries, got {}",
            self.rtol.len()
        );
    }

    /// Tolerances of the instance range `[lo, hi)` (scalars broadcast).
    pub(crate) fn shard_rows(&self, lo: usize, hi: usize) -> Tolerances {
        let slice = |v: &Vec<f64>| if v.len() == 1 { v.clone() } else { v[lo..hi].to_vec() };
        Tolerances { atol: slice(&self.atol), rtol: slice(&self.rtol) }
    }
}

/// Options shared by all solve loops.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    pub method: MethodId,
    pub tols: Tolerances,
    pub controller: Controller,
    /// Per-instance step budget.
    pub max_steps: usize,
    /// Abort threshold for the step size (relative to the span).
    pub min_dt_rel: f64,
    /// Explicit initial step size; `None` selects Hairer's heuristic.
    pub dt0: Option<f64>,
    /// Fixed step size for non-adaptive methods (rk4, euler, ...).
    pub fixed_dt: Option<f64>,
    /// Record a (t, dt) trace per instance (Fig. 1 of the paper).
    pub record_trace: bool,
    /// Evaluate the dynamics on already-finished instances too. `true`
    /// mirrors torchode exactly (the model "will continue to be evaluated
    /// ... until all problems in the batch have been solved", App. B);
    /// `false` is a rode extension that skips finished rows on CPU — with
    /// the active-set loop a finished row then costs literally zero
    /// per-row work.
    pub eval_inactive: bool,
    /// Active-set compaction threshold for the parallel loop: when the
    /// fraction of unfinished rows drops below this value, the per-row
    /// solver state is gathered into a dense prefix so the stage passes
    /// stay cache-dense on straggler-heavy batches. `0.0` (the default)
    /// disables compaction; `1.0` compacts as soon as any row finishes.
    /// Trajectories, stats and statuses are bitwise-identical either
    /// way; under `eval_inactive = true` compacted-away rows stop
    /// receiving torchode's overhanging (discarded) model evaluations.
    pub compact_threshold: f64,
    /// Worker-pool policy for the sharded entry points
    /// ([`crate::exec::solve_ivp_parallel_pooled`] /
    /// [`crate::exec::solve_ivp_joint_pooled`]); the plain `solve_ivp_*`
    /// functions always run serially (a `&dyn OdeSystem` cannot be shared
    /// across threads).
    pub exec: ExecPolicy,
    /// Workspace memory layout for the stage-kernel arithmetic
    /// ([`Layout`]). `RowMajor` (the default) keeps each instance's
    /// components contiguous; `DimMajor` runs the stage passes over a
    /// dim-major (SoA) mirror, vectorizing across the batch. Results are
    /// **bitwise-identical** in both layouts; only wall time differs.
    /// The process default honors the `RODE_LAYOUT` environment variable
    /// (how CI runs the suite in both layouts).
    pub layout: Layout,
    /// Jacobian-structure override for the implicit Newton path. `None`
    /// (the default) trusts the system's own declaration
    /// ([`crate::problems::OdeSystem::jac_structure`]); `Some(Dense)`
    /// forces the dense factorization on a banded system (the
    /// banded-vs-dense comparisons in `benches/coordinator_bench.rs`
    /// lean on this). Results are bitwise-identical for any structure
    /// that covers the system's true nonzeros; only cost differs.
    pub jac_structure: Option<crate::problems::JacStructure>,
}

impl SolveOptions {
    pub fn new(method: MethodId) -> Self {
        Self {
            method,
            tols: Tolerances::scalar(1e-6, 1e-5),
            controller: Controller::integral(),
            max_steps: 10_000,
            min_dt_rel: 1e-12,
            dt0: None,
            fixed_dt: None,
            record_trace: false,
            eval_inactive: true,
            compact_threshold: 0.0,
            exec: ExecPolicy::default(),
            layout: Layout::default_from_env(),
            jac_structure: None,
        }
    }

    pub fn with_tols(mut self, atol: f64, rtol: f64) -> Self {
        self.tols = Tolerances::scalar(atol, rtol);
        self
    }

    pub fn with_controller(mut self, c: Controller) -> Self {
        self.controller = c;
        self
    }

    pub fn with_max_steps(mut self, n: usize) -> Self {
        self.max_steps = n;
        self
    }

    pub fn with_dt0(mut self, dt0: f64) -> Self {
        self.dt0 = Some(dt0);
        self
    }

    pub fn with_fixed_dt(mut self, dt: f64) -> Self {
        self.fixed_dt = Some(dt);
        self
    }

    pub fn with_trace(mut self) -> Self {
        self.record_trace = true;
        self
    }

    /// Select the worker-pool implementation for the pooled entry points
    /// (see [`PoolKind`]); results are bitwise-identical across kinds.
    pub fn with_pool(mut self, kind: PoolKind) -> Self {
        self.exec.pool = kind;
        self
    }

    /// Rows per work-stealing chunk for [`PoolKind::Persistent`]
    /// (`0` = heuristic). Scheduling only — never affects results.
    pub fn with_steal_chunk(mut self, rows: usize) -> Self {
        self.exec.steal_chunk = rows;
        self
    }

    /// Select the workspace memory layout for the stage kernels (see
    /// [`SolveOptions::layout`]); results are bitwise-identical either
    /// way.
    pub fn with_layout(mut self, layout: Layout) -> Self {
        self.layout = layout;
        self
    }

    /// Override the Jacobian structure used by the implicit Newton path
    /// (see [`SolveOptions::jac_structure`]); results are
    /// bitwise-identical for any structure covering the true nonzeros.
    pub fn with_jac_structure(mut self, jac: crate::problems::JacStructure) -> Self {
        self.jac_structure = Some(jac);
        self
    }

    pub fn skip_inactive(mut self) -> Self {
        self.eval_inactive = false;
        self
    }

    /// Enable active-set state compaction at the given live-fraction
    /// threshold (see [`SolveOptions::compact_threshold`]). `frac` must
    /// lie in `[0, 1]`; `0` disables compaction.
    pub fn with_compaction(mut self, frac: f64) -> Self {
        assert!(
            (0.0..=1.0).contains(&frac),
            "compaction threshold must be a live fraction in [0, 1], got {frac}"
        );
        self.compact_threshold = frac;
        self
    }

    /// Shard the batched solve across `n` CPU workers (0 = one per core)
    /// when run through the pooled entry points in [`crate::exec`]. The
    /// pool kind and steal-chunk settings are left untouched.
    pub fn with_threads(mut self, n: usize) -> Self {
        self.exec.threads = n;
        self
    }

    /// Options for the instance range `[lo, hi)` of a sharded solve:
    /// per-instance tolerances are sliced and the shard itself runs
    /// serially.
    pub(crate) fn shard_rows(&self, lo: usize, hi: usize) -> SolveOptions {
        let mut o = self.clone();
        o.tols = self.tols.shard_rows(lo, hi);
        o.exec = ExecPolicy::serial();
        o
    }
}

/// Per-instance solver statistics, mirroring torchode's `sol.stats`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Stats {
    /// Total steps attempted (accepted + rejected).
    pub n_steps: u64,
    /// Accepted steps.
    pub n_accepted: u64,
    /// Dynamics evaluations *experienced by this instance*. For explicit
    /// methods this is uniform across the batch (torchode semantics: the
    /// model is always evaluated on the full batch). Under an implicit
    /// method each instance additionally pays for its **own** Newton
    /// residual and finite-difference-Jacobian evaluations, so the count
    /// is per-instance — the uniform batched-call part is still
    /// reconstructed exactly by the pooled merges, and the per-row
    /// Newton part rides along unchanged (see
    /// [`crate::exec::solve_ivp_parallel_pooled`]).
    pub n_f_evals: u64,
    /// Dense-output evaluation points produced.
    pub n_initialized: u64,
    /// Jacobian builds performed for this instance (implicit methods
    /// only; analytic and finite-difference builds both count one — an
    /// FD build's per-column dynamics evaluations land in `n_f_evals`).
    pub n_jac_evals: u64,
    /// LU factorizations of the Newton matrix `I − hγJ` performed for
    /// this instance (implicit methods only; smaller than `n_jac_evals +
    /// step count` whenever the factor-reuse window holds).
    pub n_lu_factor: u64,
}

/// How a solve was actually executed — the observability counterpart of
/// the per-instance [`Stats`]. Deliberately **not** part of the
/// bitwise-determinism contract: two runs that differ only in
/// `ExecStats` (pool kind, worker count, steal activity) still produce
/// identical trajectories, stats, statuses and traces.
///
/// The `pool_kind` field records what really ran, so a pooled entry
/// point quietly degrading to the serial path (`threads = 1`, a one-row
/// batch, a `Serial` policy) is visible instead of silent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecStats {
    /// The pool implementation that actually carried the solve.
    pub pool_kind: PoolKind,
    /// Workers used (1 for the serial path).
    pub threads: usize,
    /// Shards (scoped) or work-stealing chunks (persistent) the batch was
    /// split into; 1 for the serial path.
    pub shards: usize,
    /// Steal operations performed by the persistent pool (0 elsewhere).
    /// Scheduling noise: may vary run to run while results do not.
    pub steal_count: u64,
}

impl Default for ExecStats {
    fn default() -> Self {
        Self { pool_kind: PoolKind::Serial, threads: 1, shards: 1, steal_count: 0 }
    }
}

/// The result of a batched solve.
#[derive(Debug, Clone)]
pub struct Solution {
    /// Dense outputs, `(batch, n_eval, dim)` row-major.
    ys: Vec<f64>,
    batch: usize,
    n_eval: usize,
    dim: usize,
    /// Per-instance termination status.
    pub status: Vec<Status>,
    /// Per-instance statistics.
    pub stats: Vec<Stats>,
    /// How the solve was executed (pool kind, workers, steal activity).
    pub exec_stats: ExecStats,
    /// Optional per-instance `(t, dt_accepted)` traces (Fig. 1).
    pub trace: Option<Vec<Vec<(f64, f64)>>>,
}

impl Solution {
    pub(crate) fn new_buffer(batch: usize, n_eval: usize, dim: usize) -> Self {
        Self {
            ys: vec![f64::NAN; batch * n_eval * dim],
            batch,
            n_eval,
            dim,
            status: vec![Status::MaxStepsReached; batch],
            stats: vec![Stats::default(); batch],
            exec_stats: ExecStats::default(),
            trace: None,
        }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn n_eval(&self) -> usize {
        self.n_eval
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Solution of instance `i` at evaluation point `e`.
    #[inline]
    pub fn y(&self, i: usize, e: usize) -> &[f64] {
        let lo = (i * self.n_eval + e) * self.dim;
        &self.ys[lo..lo + self.dim]
    }

    #[inline]
    pub(crate) fn y_mut(&mut self, i: usize, e: usize) -> &mut [f64] {
        let lo = (i * self.n_eval + e) * self.dim;
        &mut self.ys[lo..lo + self.dim]
    }

    /// Final state of instance `i`.
    pub fn y_final(&self, i: usize) -> &[f64] {
        self.y(i, self.n_eval - 1)
    }

    /// Whole buffer, `(batch, n_eval, dim)` row-major.
    pub fn ys_flat(&self) -> &[f64] {
        &self.ys
    }

    pub fn all_success(&self) -> bool {
        self.status.iter().all(|s| *s == Status::Success)
    }

    /// Total steps across the batch (joint loops report the shared count
    /// for every instance).
    pub fn total_steps(&self) -> u64 {
        self.stats.iter().map(|s| s.n_steps).sum()
    }

    /// Maximum per-instance step count.
    pub fn max_steps(&self) -> u64 {
        self.stats.iter().map(|s| s.n_steps).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in MethodId::BUILTINS {
            assert_eq!(MethodId::parse(m.name()), Some(m));
        }
        assert_eq!(MethodId::parse("tr-bdf2"), Some(MethodId::TRBDF2));
        assert_eq!(MethodId::parse("nope"), None);
    }

    #[test]
    fn implicit_flag_matches_tableau() {
        assert!(MethodId::TRBDF2.is_implicit());
        assert!(MethodId::KVAERNO43.is_implicit());
        for m in MethodId::BUILTINS {
            assert_eq!(m.is_implicit(), !m.tableau().diag.is_empty(), "{m:?}");
            assert_eq!(
                step::CompiledTableau::cached(m).is_implicit(),
                m.is_implicit(),
                "{m:?}"
            );
        }
    }

    #[test]
    fn timegrid_linspace() {
        let g = TimeGrid::linspace_shared(3, 0.0, 1.0, 5);
        assert_eq!(g.batch(), 3);
        assert_eq!(g.n_eval(), 5);
        assert_eq!(g.t0(1), 0.0);
        assert_eq!(g.t1(2), 1.0);
        assert!((g.row(0)[1] - 0.25).abs() < 1e-15);
    }

    #[test]
    fn timegrid_per_instance_ranges() {
        let g = TimeGrid::from_rows(&[vec![0.0, 1.0, 2.0], vec![5.0, 5.5, 9.0]]);
        assert_eq!(g.t0(1), 5.0);
        assert_eq!(g.t1(1), 9.0);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn timegrid_rejects_unsorted() {
        TimeGrid::from_rows(&[vec![0.0, 2.0, 1.0]]);
    }

    #[test]
    fn tolerance_broadcast() {
        let t = Tolerances::scalar(1e-6, 1e-3);
        assert_eq!(t.atol(0), 1e-6);
        assert_eq!(t.atol(7), 1e-6);
        let t = Tolerances::per_instance(vec![1e-6, 1e-8], vec![1e-3, 1e-5]);
        assert_eq!(t.rtol(1), 1e-5);
    }

    #[test]
    fn tolerance_validation_accepts_scalar_and_per_instance() {
        Tolerances::scalar(1e-6, 1e-3).validate(7);
        Tolerances::per_instance(vec![1e-6; 4], vec![1e-3; 4]).validate(4);
        let sharded =
            Tolerances::per_instance(vec![1.0, 2.0, 3.0, 4.0], vec![0.1; 4]).shard_rows(1, 3);
        assert_eq!(sharded.atol(0), 2.0);
        assert_eq!(sharded.atol(1), 3.0);
        // Scalars broadcast through sharding.
        let sharded = Tolerances::scalar(1e-6, 1e-3).shard_rows(2, 5);
        assert_eq!(sharded.rtol(2), 1e-3);
    }

    #[test]
    #[should_panic(expected = "atol")]
    fn tolerance_validation_rejects_wrong_length() {
        Tolerances::per_instance(vec![1e-6; 2], vec![1e-3; 2]).validate(3);
    }

    #[test]
    fn timegrid_rows_range() {
        let g = TimeGrid::from_rows(&[vec![0.0, 1.0], vec![2.0, 3.0], vec![4.0, 5.0]]);
        let s = g.rows_range(1, 3);
        assert_eq!(s.batch(), 2);
        assert_eq!(s.t0(0), 2.0);
        assert_eq!(s.t1(1), 5.0);
    }

    /// The built-in handles must occupy registry slots 0..N in
    /// `tableau::ALL` order — the slot is the compiled-tableau cache
    /// key, so this pins the append-only pre-registration contract.
    #[test]
    fn builtin_slots_key_the_compiled_cache() {
        for (i, &m) in MethodId::BUILTINS.iter().enumerate() {
            assert_eq!(m.index(), i, "{m:?}");
        }
        // And the cache hands back the right (and the same) tableau.
        for &m in MethodId::BUILTINS.iter() {
            let ct = step::CompiledTableau::cached(m);
            assert_eq!(ct.tab.name, m.tableau().name);
            let again = step::CompiledTableau::cached(m);
            assert!(std::ptr::eq(ct, again), "cache must return one instance");
        }
    }

    #[test]
    fn layout_builder_and_shards() {
        let o = SolveOptions::new(MethodId::DOPRI5);
        // Without RODE_LAYOUT set the default is row-major; either way
        // the builder overrides it.
        let o = o.with_layout(Layout::DimMajor);
        assert_eq!(o.layout, Layout::DimMajor);
        // Shard options inherit the layout (each shard worker runs the
        // same lane passes over its own workspace).
        assert_eq!(o.shard_rows(0, 1).layout, Layout::DimMajor);
        assert_eq!(o.with_layout(Layout::RowMajor).layout, Layout::RowMajor);
    }

    #[test]
    fn compaction_threshold_builder() {
        let o = SolveOptions::new(MethodId::DOPRI5);
        assert_eq!(o.compact_threshold, 0.0, "compaction is opt-in");
        let o = o.with_compaction(0.4);
        assert_eq!(o.compact_threshold, 0.4);
        // Shard options inherit the threshold (each shard compacts its
        // own state independently).
        assert_eq!(o.shard_rows(0, 1).compact_threshold, 0.4);
    }

    #[test]
    #[should_panic(expected = "compaction threshold")]
    fn compaction_threshold_rejects_out_of_range() {
        SolveOptions::new(MethodId::DOPRI5).with_compaction(1.5);
    }

    #[test]
    fn exec_builders_compose() {
        let o = SolveOptions::new(MethodId::DOPRI5)
            .with_pool(PoolKind::Persistent)
            .with_steal_chunk(8)
            .with_threads(4);
        // with_threads leaves the pool selection untouched.
        assert_eq!(o.exec.pool, PoolKind::Persistent);
        assert_eq!(o.exec.steal_chunk, 8);
        assert_eq!(o.exec.threads, 4);
        // Shard options always run serially inside a worker.
        assert_eq!(o.shard_rows(0, 1).exec, ExecPolicy::serial());
        // A fresh Solution reports the serial path until an exec layer
        // stamps it.
        assert_eq!(Solution::new_buffer(2, 3, 1).exec_stats, ExecStats::default());
    }

    #[test]
    fn solution_indexing() {
        let mut s = Solution::new_buffer(2, 3, 2);
        s.y_mut(1, 2).copy_from_slice(&[7.0, 8.0]);
        assert_eq!(s.y(1, 2), &[7.0, 8.0]);
        assert_eq!(s.y_final(1), &[7.0, 8.0]);
        assert!(s.y(0, 0)[0].is_nan());
    }
}
