//! The runtime method registry: methods as data, not enum arms.
//!
//! torchode's public surface registers methods by name
//! (`register_method("tsit5", Tsit5)`) precisely so new integrators can
//! be added without touching the solver. This module is the Rust
//! counterpart: a [`MethodId`] is a copyable handle into an append-only,
//! process-wide registry of [`Tableau`]s. The built-in methods are
//! pre-registered from [`tableau::ALL`] (their slots are the stable
//! [`MethodId::BUILTINS`] constants); user tableaus join at runtime via
//! [`register_method`] and are then first-class everywhere — name lookup
//! ([`MethodId::parse`]), the compiled-tableau cache
//! ([`MethodId::compiled`]), implicit dispatch
//! ([`MethodId::is_implicit`]), every solve loop, and per-request
//! routing in the coordinator.
//!
//! ## Slot keying and determinism
//!
//! A `MethodId` wraps the method's **registration index**. Registration
//! is append-only: a slot, once assigned, never changes or disappears,
//! and a name can never be re-bound to a different tableau. That makes
//! the handle a stable cache key for the process lifetime — the
//! compiled tableau is built exactly once per slot, so two solves
//! naming the same method always share one `CompiledTableau` (pointer
//! identity, which the bitwise-determinism tests assert) — and it makes
//! method resolution deterministic: the same sequence of registrations
//! yields the same ids, independent of lookup order or thread timing.
//!
//! Records are leaked (`Box::leak`) into `'static` storage so accessors
//! hand out `&'static` references without holding any lock. The
//! registry lock only guards the slot vector and the name map; it is
//! never held across user code.

#![warn(missing_docs)]

use super::step::{CompiledTableau, MAX_STAGES};
use super::tableau::{self, Tableau};
use std::collections::HashMap;
use std::fmt;
use std::sync::{Mutex, OnceLock};

/// A handle to a registered Runge–Kutta method: the method's slot in
/// the process-wide registry.
///
/// Copyable, comparable and hashable — it is the method key of
/// [`SolveOptions`](super::SolveOptions), the coordinator's batch
/// buckets, and the compiled-tableau cache. Built-in methods are the
/// associated constants ([`MethodId::DOPRI5`], [`MethodId::TRBDF2`],
/// ...); runtime-registered methods get the next free slot from
/// [`register_method`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MethodId(u32);

/// One registered method: its identity, its lookup names, its tableau
/// and the zero-stripped compiled form. Leaked into `'static` storage
/// at registration, so every accessor returns `'static` data.
struct MethodRecord {
    id: MethodId,
    name: &'static str,
    aliases: &'static [&'static str],
    tab: &'static Tableau,
    compiled: CompiledTableau,
}

struct Registry {
    /// Slot-indexed records; `MethodId(i)` resolves to `records[i]`.
    records: Vec<&'static MethodRecord>,
    /// Lowercased name and alias → id.
    by_name: HashMap<&'static str, MethodId>,
}

static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();

/// Aliases of the built-in methods, index-aligned with [`tableau::ALL`].
const BUILTIN_ALIASES: [&[&str]; 12] = [
    &[],          // euler
    &[],          // midpoint
    &[],          // heun
    &[],          // ralston
    &[],          // bosh3
    &[],          // rk4
    &["rkf45"],   // fehlberg45
    &["ck45"],    // cashkarp45
    &[],          // dopri5
    &[],          // tsit5
    &["tr-bdf2"], // trbdf2
    &["kv43"],    // kvaerno43
];

fn registry() -> &'static Mutex<Registry> {
    REGISTRY.get_or_init(|| {
        assert_eq!(
            tableau::ALL.len(),
            BUILTIN_ALIASES.len(),
            "tableau::ALL and BUILTIN_ALIASES drifted apart"
        );
        let mut reg =
            Registry { records: Vec::with_capacity(tableau::ALL.len()), by_name: HashMap::new() };
        for (i, (tab, aliases)) in tableau::ALL.iter().zip(BUILTIN_ALIASES.iter()).enumerate() {
            let tab: &'static Tableau = tab;
            let aliases: &'static [&'static str] = aliases;
            let id = MethodId(i as u32);
            let rec: &'static MethodRecord = Box::leak(Box::new(MethodRecord {
                id,
                name: tab.name,
                aliases,
                tab,
                compiled: CompiledTableau::new(tab),
            }));
            reg.records.push(rec);
            let prev = reg.by_name.insert(rec.name, id);
            assert!(prev.is_none(), "duplicate built-in method name '{}'", rec.name);
            for &al in rec.aliases {
                let prev = reg.by_name.insert(al, id);
                assert!(prev.is_none(), "duplicate built-in method alias '{al}'");
            }
        }
        Mutex::new(reg)
    })
}

impl MethodId {
    /// Euler (1st order, fixed step).
    pub const EULER: MethodId = MethodId(0);
    /// Explicit midpoint (2nd order, fixed step).
    pub const MIDPOINT: MethodId = MethodId(1);
    /// Heun 2(1) (trapezoid with embedded Euler).
    pub const HEUN: MethodId = MethodId(2);
    /// Ralston 2nd order (fixed step, minimal truncation error).
    pub const RALSTON: MethodId = MethodId(3);
    /// Bogacki–Shampine 3(2), FSAL.
    pub const BOSH3: MethodId = MethodId(4);
    /// Classic RK4 (fixed step).
    pub const RK4: MethodId = MethodId(5);
    /// Fehlberg 4(5).
    pub const FEHLBERG45: MethodId = MethodId(6);
    /// Cash–Karp 4(5).
    pub const CASHKARP45: MethodId = MethodId(7);
    /// Dormand–Prince 5(4), FSAL, with dedicated dense output.
    pub const DOPRI5: MethodId = MethodId(8);
    /// Tsitouras 5(4), FSAL.
    pub const TSIT5: MethodId = MethodId(9);
    /// TR-BDF2 2(3): stiffly-accurate, L-stable ESDIRK pair with
    /// simplified-Newton stage solves — the workhorse stiff method
    /// (Van der Pol at μ ≫ 100, Robertson kinetics).
    pub const TRBDF2: MethodId = MethodId(10);
    /// Kvaerno 4(3): stiffly-accurate, L-stable 5-stage ESDIRK pair —
    /// the higher-order stiff method, fewer accepted steps than TR-BDF2
    /// at tight tolerances. Registered as pure tableau data; the Newton
    /// machinery is shared with TR-BDF2.
    pub const KVAERNO43: MethodId = MethodId(11);

    /// The built-in methods, in registration (slot) order — index `i`
    /// of this table is `MethodId(i)` backed by `tableau::ALL[i]`.
    pub const BUILTINS: [MethodId; 12] = [
        MethodId::EULER,
        MethodId::MIDPOINT,
        MethodId::HEUN,
        MethodId::RALSTON,
        MethodId::BOSH3,
        MethodId::RK4,
        MethodId::FEHLBERG45,
        MethodId::CASHKARP45,
        MethodId::DOPRI5,
        MethodId::TSIT5,
        MethodId::TRBDF2,
        MethodId::KVAERNO43,
    ];

    /// Resolve a method name or alias (case-insensitive), as used on
    /// the CLI, in configs, and for runtime-registered methods.
    pub fn parse(s: &str) -> Option<MethodId> {
        let key = s.to_ascii_lowercase();
        registry().lock().unwrap().by_name.get(key.as_str()).copied()
    }

    /// Snapshot of every registered method (built-ins first, then
    /// runtime registrations), in slot order.
    pub fn all() -> Vec<MethodId> {
        registry().lock().unwrap().records.iter().map(|r| r.id).collect()
    }

    /// This method's registry record; panics on a forged id (the only
    /// way to hold a `MethodId` outside the registry's range).
    fn record(self) -> &'static MethodRecord {
        let reg = registry().lock().unwrap();
        reg.records
            .get(self.0 as usize)
            .copied()
            .unwrap_or_else(|| panic!("MethodId({}) is not a registered method", self.0))
    }

    /// The Butcher tableau backing this method.
    pub fn tableau(self) -> &'static Tableau {
        self.record().tab
    }

    /// The zero-stripped compiled tableau — built once per slot for the
    /// process lifetime, so repeated calls return the **same** instance
    /// (pointer identity; the cache key is the registry slot).
    pub fn compiled(self) -> &'static CompiledTableau {
        &self.record().compiled
    }

    /// The registered (lookup) name — `parse(self.name())` round-trips.
    pub fn name(self) -> &'static str {
        self.record().name
    }

    /// Alternate lookup names (e.g. `tr-bdf2` for `trbdf2`).
    pub fn aliases(self) -> &'static [&'static str] {
        self.record().aliases
    }

    /// Whether this method has implicit stages (Newton-based stage
    /// solves; supported by the parallel and joint loops, every pooled
    /// entry point, and the training paths — [`super::backprop`]
    /// differentiates through the Newton solve via the implicit-function
    /// theorem and [`super::adjoint`] only needs the forward solve — but
    /// not by the frozen reference loop or the naive baseline).
    pub fn is_implicit(self) -> bool {
        self.record().compiled.is_implicit()
    }

    /// The registry slot index (stable for the process lifetime).
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for MethodId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Why a [`register_method`] call was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegisterError {
    /// The name (or an alias) is empty or contains whitespace.
    InvalidName(String),
    /// The name (or an alias) is already bound — names are never
    /// re-bound, so existing `MethodId`s stay deterministic.
    NameTaken(String),
    /// The tableau fails a structural invariant (shape, single-γ
    /// diagonal, stage consistency, Σb = 1, ...); the message names it.
    InvalidTableau(String),
}

impl fmt::Display for RegisterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegisterError::InvalidName(n) => write!(f, "invalid method name {n:?}"),
            RegisterError::NameTaken(n) => write!(f, "method name '{n}' is already registered"),
            RegisterError::InvalidTableau(why) => write!(f, "invalid tableau: {why}"),
        }
    }
}

impl std::error::Error for RegisterError {}

fn validate_name(s: &str) -> Result<String, RegisterError> {
    if s.is_empty() || s.chars().any(|c| c.is_whitespace()) {
        return Err(RegisterError::InvalidName(s.to_string()));
    }
    Ok(s.to_ascii_lowercase())
}

/// Structural validation mirroring (and preceding) the assertions in
/// [`CompiledTableau::new`], so user registrations fail with an `Err`
/// instead of a panic. Uses 1e-9 tolerances — looser than the 1e-12 the
/// built-in suite holds itself to, since user coefficients are often
/// truncated decimals.
fn validate_tableau(tab: &Tableau) -> Result<(), RegisterError> {
    let fail = |why: String| Err(RegisterError::InvalidTableau(why));
    if tab.stages == 0 {
        return fail("zero stages".into());
    }
    if tab.stages > MAX_STAGES {
        return fail(format!("{} stages exceeds the kernel bound {MAX_STAGES}", tab.stages));
    }
    let tri = tab.stages * (tab.stages - 1) / 2;
    if tab.a.len() != tri {
        return fail(format!("a has {} entries, expected {tri}", tab.a.len()));
    }
    if tab.b.len() != tab.stages || tab.c.len() != tab.stages {
        return fail(format!(
            "b/c have {}/{} entries, expected {}",
            tab.b.len(),
            tab.c.len(),
            tab.stages
        ));
    }
    if !tab.b_err.is_empty() && tab.b_err.len() != tab.stages {
        return fail(format!("b_err has {} entries, expected 0 or {}", tab.b_err.len(), tab.stages));
    }
    let mut all = tab.a.iter().chain(tab.b).chain(tab.b_err).chain(tab.c).chain(tab.diag);
    if all.any(|v| !v.is_finite()) {
        return fail("non-finite coefficient".into());
    }
    if tab.c[0] != 0.0 {
        return fail(format!("c[0] = {} (first node must be 0)", tab.c[0]));
    }
    if !tab.diag.is_empty() {
        if tab.diag.len() != tab.stages {
            return fail(format!(
                "diag has {} entries, expected 0 or {}",
                tab.diag.len(),
                tab.stages
            ));
        }
        if tab.diag[0] != 0.0 {
            return fail("diag[0] must be 0 (ESDIRK: explicit first stage)".into());
        }
        let g = tab.diag.iter().copied().find(|&d| d != 0.0).unwrap_or(0.0);
        if g <= 0.0 {
            return fail("implicit diagonal must have a positive γ (or be empty)".into());
        }
        for (s, &d) in tab.diag.iter().enumerate() {
            if d != 0.0 && d != g {
                return fail(format!("stage {s}: only single-γ (ES)DIRK diagonals are supported"));
            }
        }
    }
    let sum_b: f64 = tab.b.iter().sum();
    if (sum_b - 1.0).abs() > 1e-9 {
        return fail(format!("Σb = {sum_b}, expected 1"));
    }
    if tab.adaptive() {
        let sum_e: f64 = tab.b_err.iter().sum();
        if sum_e.abs() > 1e-9 {
            return fail(format!("Σb_err = {sum_e}, expected 0"));
        }
    }
    for i in 1..tab.stages {
        let diag = tab.diag.get(i).copied().unwrap_or(0.0);
        let s: f64 = tab.a_row(i).iter().sum::<f64>() + diag;
        if (s - tab.c[i]).abs() > 1e-9 {
            return fail(format!("row {i} sums to {s} but c = {} (stage consistency)", tab.c[i]));
        }
    }
    Ok(())
}

/// Register a user tableau under `name`, returning its fresh
/// [`MethodId`]. The tableau must have `'static` lifetime (leak it with
/// `Box::leak` if built at runtime) and pass the structural checks —
/// shape, stage consistency, Σb = 1, and the single-γ ESDIRK diagonal
/// structure if implicit. Registration is append-only: the returned id
/// is valid (and resolves to this exact tableau) for the rest of the
/// process, and `name` can never be re-bound.
pub fn register_method(name: &str, tab: &'static Tableau) -> Result<MethodId, RegisterError> {
    register_method_with_aliases(name, &[], tab)
}

/// [`register_method`] with alternate lookup names. Name and aliases
/// are matched case-insensitively and must all be unused.
pub fn register_method_with_aliases(
    name: &str,
    aliases: &[&str],
    tab: &'static Tableau,
) -> Result<MethodId, RegisterError> {
    let name = validate_name(name)?;
    let mut alias_keys = Vec::with_capacity(aliases.len());
    for a in aliases {
        let a = validate_name(a)?;
        if a == name || alias_keys.contains(&a) {
            return Err(RegisterError::NameTaken(a));
        }
        alias_keys.push(a);
    }
    validate_tableau(tab)?;
    // Validation guarantees the constructor's assertions hold, so the
    // compile runs outside the lock and cannot poison it.
    let compiled = CompiledTableau::new(tab);
    let mut reg = registry().lock().unwrap();
    if reg.by_name.contains_key(name.as_str()) {
        return Err(RegisterError::NameTaken(name));
    }
    for a in &alias_keys {
        if reg.by_name.contains_key(a.as_str()) {
            return Err(RegisterError::NameTaken(a.clone()));
        }
    }
    let id = MethodId(reg.records.len() as u32);
    let name: &'static str = Box::leak(name.into_boxed_str());
    let alias_refs: Vec<&'static str> =
        alias_keys.into_iter().map(|a| &*Box::leak(a.into_boxed_str())).collect();
    let aliases: &'static [&'static str] = Box::leak(alias_refs.into_boxed_slice());
    let rec: &'static MethodRecord =
        Box::leak(Box::new(MethodRecord { id, name, aliases, tab, compiled }));
    reg.records.push(rec);
    reg.by_name.insert(rec.name, id);
    for &a in rec.aliases {
        reg.by_name.insert(a, id);
    }
    Ok(id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_map_to_tableau_all_in_slot_order() {
        for (i, &m) in MethodId::BUILTINS.iter().enumerate() {
            assert_eq!(m.index(), i, "{m:?}");
            assert!(std::ptr::eq(m.tableau(), tableau::ALL[i]), "{m:?}");
            assert_eq!(m.name(), tableau::ALL[i].name);
        }
        assert_eq!(MethodId::all()[..MethodId::BUILTINS.len()], MethodId::BUILTINS);
    }

    #[test]
    fn parse_resolves_names_and_aliases() {
        for m in MethodId::BUILTINS {
            assert_eq!(MethodId::parse(m.name()), Some(m));
            for al in m.aliases() {
                assert_eq!(MethodId::parse(al), Some(m), "{al}");
            }
        }
        assert_eq!(MethodId::parse("TR-BDF2"), Some(MethodId::TRBDF2));
        assert_eq!(MethodId::parse("kv43"), Some(MethodId::KVAERNO43));
        assert_eq!(MethodId::parse("nope"), None);
    }

    #[test]
    fn compiled_is_slot_cached() {
        for m in MethodId::BUILTINS {
            let ct = m.compiled();
            assert_eq!(ct.tab.name, m.tableau().name);
            assert!(std::ptr::eq(ct, m.compiled()), "{m:?}: cache must return one instance");
        }
    }

    #[test]
    fn display_is_the_registered_name() {
        assert_eq!(MethodId::KVAERNO43.to_string(), "kvaerno43");
    }

    #[test]
    fn runtime_registration_appends_and_resolves() {
        // A valid 2-stage explicit midpoint clone under a private name.
        let tab: &'static Tableau = Box::leak(Box::new(Tableau {
            name: "unit-test-midpoint",
            stages: 2,
            order: 2,
            err_order: 0,
            a: &[0.5],
            diag: &[],
            b: &[0.0, 1.0],
            b_err: &[],
            c: &[0.0, 0.5],
            fsal: false,
            dense: tableau::DenseOutput::Hermite,
        }));
        let id = register_method_with_aliases("unit_mid2", &["unit_mid2_alias"], tab).unwrap();
        assert!(id.index() >= MethodId::BUILTINS.len(), "slots append after the built-ins");
        assert_eq!(MethodId::parse("unit_mid2"), Some(id));
        assert_eq!(MethodId::parse("UNIT_MID2_ALIAS"), Some(id));
        assert_eq!(id.name(), "unit_mid2");
        assert!(std::ptr::eq(id.tableau(), tab));
        assert!(std::ptr::eq(id.compiled(), id.compiled()), "stable cache slot");
        assert!(!id.is_implicit());
        assert!(MethodId::all().contains(&id));
        // Names are never re-bound.
        assert_eq!(
            register_method("unit_mid2", tab),
            Err(RegisterError::NameTaken("unit_mid2".into()))
        );
        // Built-in names are protected too.
        assert_eq!(
            register_method("dopri5", tab),
            Err(RegisterError::NameTaken("dopri5".into()))
        );
    }

    #[test]
    fn registration_rejects_bad_names_and_tableaus() {
        let tab: &'static Tableau = &tableau::MIDPOINT;
        assert!(matches!(register_method("", tab), Err(RegisterError::InvalidName(_))));
        assert!(matches!(register_method("has space", tab), Err(RegisterError::InvalidName(_))));
        // Broken shape: b too short.
        let bad: &'static Tableau = Box::leak(Box::new(Tableau {
            name: "unit-test-bad",
            stages: 2,
            order: 2,
            err_order: 0,
            a: &[0.5],
            diag: &[],
            b: &[1.0],
            b_err: &[],
            c: &[0.0, 0.5],
            fsal: false,
            dense: tableau::DenseOutput::Hermite,
        }));
        assert!(matches!(
            register_method("unit_bad_shape", bad),
            Err(RegisterError::InvalidTableau(_))
        ));
        // Broken quadrature: Σb ≠ 1.
        let bad_b: &'static Tableau = Box::leak(Box::new(Tableau {
            name: "unit-test-bad-b",
            stages: 2,
            order: 2,
            err_order: 0,
            a: &[0.5],
            diag: &[],
            b: &[0.0, 0.5],
            b_err: &[],
            c: &[0.0, 0.5],
            fsal: false,
            dense: tableau::DenseOutput::Hermite,
        }));
        assert!(matches!(
            register_method("unit_bad_b", bad_b),
            Err(RegisterError::InvalidTableau(_))
        ));
        // Broken diagonal: two distinct γ values.
        let bad_diag: &'static Tableau = Box::leak(Box::new(Tableau {
            name: "unit-test-bad-diag",
            stages: 3,
            order: 2,
            err_order: 0,
            a: &[0.25, 0.25, 0.35],
            diag: &[0.0, 0.25, 0.4],
            b: &[0.25, 0.35, 0.4],
            b_err: &[],
            c: &[0.0, 0.5, 1.0],
            fsal: false,
            dense: tableau::DenseOutput::Hermite,
        }));
        assert!(matches!(
            register_method("unit_bad_diag", bad_diag),
            Err(RegisterError::InvalidTableau(_))
        ));
    }
}
