//! Tiny property-based-testing helpers (proptest is not vendored in this
//! offline environment). A property is checked over `n` seeded random
//! cases; failures report the seed for replay.

use crate::nn::Rng64;

/// Run `prop` over `n` cases derived from `base_seed`. The closure
/// receives a fresh deterministic RNG per case; panics are augmented with
/// the failing case index so the case can be replayed.
pub fn check<F: Fn(&mut Rng64)>(name: &str, n: usize, base_seed: u64, prop: F) {
    for case in 0..n {
        let seed = base_seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = Rng64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {case} (seed {seed}): {e:?}");
        }
    }
}

/// Random f64 vector with entries in [lo, hi).
pub fn vec_in(rng: &mut Rng64, n: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..n).map(|_| rng.range(lo, hi)).collect()
}

/// Random strictly ascending time grid of `n` points starting at `t0` with
/// gaps in `(0, max_gap]`.
pub fn ascending_times(rng: &mut Rng64, n: usize, t0: f64, max_gap: f64) -> Vec<f64> {
    let mut t = t0;
    let mut out = Vec::with_capacity(n);
    out.push(t);
    for _ in 1..n {
        t += rng.range(1e-3, max_gap);
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("counts", 17, 1, |_| {
            counter.set(counter.get() + 1);
        });
        count += counter.get();
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 10, 2, |rng| {
            assert!(rng.uniform() < 0.5, "too big");
        });
    }

    #[test]
    fn ascending_times_ascend() {
        let mut rng = Rng64::new(5);
        let t = ascending_times(&mut rng, 50, -3.0, 0.7);
        assert_eq!(t.len(), 50);
        assert_eq!(t[0], -3.0);
        for w in t.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn vec_in_bounds() {
        let mut rng = Rng64::new(6);
        let v = vec_in(&mut rng, 100, -2.0, 2.0);
        assert!(v.iter().all(|x| (-2.0..2.0).contains(x)));
    }
}
