//! Self-contained benchmark harness (criterion is not available in this
//! offline build, and the paper's *loop time* metric needs bespoke
//! instrumentation anyway).
//!
//! The central metric follows Appendix A of the paper exactly:
//!
//! > "we measured the total time, the model time and the solver time per
//! > step ... The solver time divided by the number of solver steps is our
//! > main quantity of interest and we call it loop time."
//!
//! [`TimedSystem`] wraps any [`OdeSystem`] and accumulates the wall time
//! spent inside the dynamics ("model time"); the harness subtracts it from
//! the total to get solver time, then divides by steps.

use crate::problems::OdeSystem;
use crate::tensor::BatchVec;
use std::cell::Cell;
use std::time::{Duration, Instant};

/// Mean/std/min/max over repeated measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Summary {
    pub fn from_samples(xs: &[f64]) -> Self {
        assert!(!xs.is_empty());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Self {
            mean,
            std: var.sqrt(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            n,
        }
    }

    /// `mean ± std` with the paper's precision rule (first significant
    /// digit of the std; one extra digit if it is 1).
    pub fn format_ms(&self) -> String {
        format!("{:.4} ± {:.4}", self.mean, self.std)
    }
}

/// Run `f` for `warmup` unmeasured and `reps` measured repetitions,
/// returning the per-repetition wall times in milliseconds.
pub fn time_repeats<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64() * 1e3);
    }
    out
}

/// Measure `run` once per worker-thread count — the exec layer's
/// threads-sweep harness. Returns one `(threads, Summary)` row per entry
/// of `counts`; `run` receives the thread count and performs one full
/// solve (e.g. through `exec::solve_ivp_parallel_pooled` with
/// `SolveOptions::with_threads`).
pub fn threads_sweep<F: FnMut(usize)>(
    counts: &[usize],
    warmup: usize,
    reps: usize,
    mut run: F,
) -> Vec<(usize, Summary)> {
    counts
        .iter()
        .map(|&n| {
            let xs = time_repeats(warmup, reps, || run(n));
            (n, Summary::from_samples(&xs))
        })
        .collect()
}

/// Wraps a system and accumulates time spent in the dynamics — the
/// paper's "model time".
pub struct TimedSystem<'a> {
    pub inner: &'a dyn OdeSystem,
    model_time: Cell<Duration>,
    calls: Cell<u64>,
}

impl<'a> TimedSystem<'a> {
    pub fn new(inner: &'a dyn OdeSystem) -> Self {
        Self { inner, model_time: Cell::new(Duration::ZERO), calls: Cell::new(0) }
    }

    /// Accumulated model time in milliseconds.
    pub fn model_time_ms(&self) -> f64 {
        self.model_time.get().as_secs_f64() * 1e3
    }

    pub fn calls(&self) -> u64 {
        self.calls.get()
    }

    pub fn reset(&self) {
        self.model_time.set(Duration::ZERO);
        self.calls.set(0);
    }
}

impl<'a> OdeSystem for TimedSystem<'a> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn n_params(&self) -> usize {
        self.inner.n_params()
    }

    fn f_inst(&self, inst: usize, t: f64, y: &[f64], dy: &mut [f64]) {
        let start = Instant::now();
        self.inner.f_inst(inst, t, y, dy);
        self.model_time.set(self.model_time.get() + start.elapsed());
        self.calls.set(self.calls.get() + 1);
    }

    fn f_rows(
        &self,
        offset: usize,
        n: usize,
        t: &[f64],
        y: &[f64],
        dy: &mut [f64],
        active: Option<&[bool]>,
    ) {
        let start = Instant::now();
        self.inner.f_rows(offset, n, t, y, dy, active);
        self.model_time.set(self.model_time.get() + start.elapsed());
        self.calls.set(self.calls.get() + 1);
    }

    fn f_rows_indexed(
        &self,
        offset: usize,
        inst: &[usize],
        rows: &[usize],
        t: &[f64],
        y: &[f64],
        dy: &mut [f64],
    ) {
        let start = Instant::now();
        self.inner.f_rows_indexed(offset, inst, rows, t, y, dy);
        self.model_time.set(self.model_time.get() + start.elapsed());
        self.calls.set(self.calls.get() + 1);
    }

    fn f_batch(&self, t: &[f64], y: &BatchVec, dy: &mut BatchVec, active: Option<&[bool]>) {
        let start = Instant::now();
        self.inner.f_batch(t, y, dy, active);
        self.model_time.set(self.model_time.get() + start.elapsed());
        self.calls.set(self.calls.get() + 1);
    }

    fn vjp_inst(
        &self,
        inst: usize,
        t: f64,
        y: &[f64],
        a: &[f64],
        out_y: &mut [f64],
        out_p: &mut [f64],
    ) {
        let start = Instant::now();
        self.inner.vjp_inst(inst, t, y, a, out_y, out_p);
        self.model_time.set(self.model_time.get() + start.elapsed());
    }

    fn has_vjp(&self) -> bool {
        self.inner.has_vjp()
    }

    fn has_jac(&self) -> bool {
        self.inner.has_jac()
    }

    fn jac_inst(&self, inst: usize, t: f64, y: &[f64], jac: &mut [f64]) {
        let start = Instant::now();
        self.inner.jac_inst(inst, t, y, jac);
        self.model_time.set(self.model_time.get() + start.elapsed());
    }

    fn jac_rows(
        &self,
        offset: usize,
        n: usize,
        t: &[f64],
        y: &[f64],
        jac: &mut [f64],
        rows: Option<&[usize]>,
    ) {
        let start = Instant::now();
        self.inner.jac_rows(offset, n, t, y, jac, rows);
        self.model_time.set(self.model_time.get() + start.elapsed());
    }

    fn jac_structure(&self) -> crate::problems::JacStructure {
        self.inner.jac_structure()
    }

    fn jac_band_inst(&self, inst: usize, t: f64, y: &[f64], jac: &mut [f64]) {
        let start = Instant::now();
        self.inner.jac_band_inst(inst, t, y, jac);
        self.model_time.set(self.model_time.get() + start.elapsed());
    }

    fn jac_band_rows(
        &self,
        offset: usize,
        n: usize,
        t: &[f64],
        y: &[f64],
        jac: &mut [f64],
        rows: Option<&[usize]>,
    ) {
        let start = Instant::now();
        self.inner.jac_band_rows(offset, n, t, y, jac, rows);
        self.model_time.set(self.model_time.get() + start.elapsed());
    }
}

/// One solve measured the paper's way.
#[derive(Debug, Clone, Copy)]
pub struct LoopTimeMeasurement {
    /// Total wall time of the solve (ms) — the paper's "total time".
    pub total_ms: f64,
    /// Time inside the dynamics (ms) — "model time".
    pub model_ms: f64,
    /// (total − model) / steps (ms) — "loop time", the headline metric.
    pub loop_time_ms: f64,
    /// Steps taken (max across the batch for parallel loops, shared count
    /// for joint loops).
    pub steps: u64,
}

/// Measure a solve: `run` executes one full solve against `sys` and
/// returns the step count to normalize with.
pub fn measure_loop_time<F>(sys: &TimedSystem<'_>, mut run: F) -> LoopTimeMeasurement
where
    F: FnMut() -> u64,
{
    sys.reset();
    let start = Instant::now();
    let steps = run();
    let total_ms = start.elapsed().as_secs_f64() * 1e3;
    let model_ms = sys.model_time_ms();
    let solver_ms = (total_ms - model_ms).max(0.0);
    LoopTimeMeasurement {
        total_ms,
        model_ms,
        loop_time_ms: if steps > 0 { solver_ms / steps as f64 } else { 0.0 },
        steps,
    }
}

/// The straggler workload of the active-set/compaction benchmark (and
/// the §4.1 regime): one stiff Van der Pol row at index 0 plus
/// `batch - 1` easy rows that finish long before it. Once the easy rows
/// are done, a solver that still sweeps the full batch pays
/// O(batch · dim · stages) per step for one live row.
pub fn straggler_workload(
    batch: usize,
    stiff_mu: f64,
    easy_mu: f64,
    t1: f64,
    n_eval: usize,
) -> (crate::problems::VdP, BatchVec, crate::solver::TimeGrid) {
    assert!(batch >= 1);
    let mut mus = vec![easy_mu; batch];
    mus[0] = stiff_mu;
    let sys = crate::problems::VdP::new(mus);
    let y0 = BatchVec::broadcast(&[2.0, 0.0], batch);
    let grid = crate::solver::TimeGrid::linspace_shared(batch, 0.0, t1, n_eval);
    (sys, y0, grid)
}

/// Integration span for a stiff Van der Pol workload starting at
/// y0 = (2, 0): `0.4·μ`, clamped to `[4, 400]`. The first fast
/// relaxation jump happens near `t ≈ μ(3/2 − ln 2) ≈ 0.81μ`, so this
/// keeps the endpoint on the smooth slow branch where final-state
/// comparisons are well-conditioned. Shared by the `stiffsweep` bench
/// and `tests/stiff_regression.rs`, so the committed stiffness floors
/// and the regression suite always measure the same window.
pub fn vdp_stiff_span(mu: f64) -> f64 {
    (0.4 * mu).clamp(4.0, 400.0)
}

/// One machine-readable benchmark record for `BENCH_solver.json`.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub mean_ms: f64,
    pub std_ms: f64,
    /// Free-form numeric facts (batch size, threshold, speedup, ...).
    pub fields: Vec<(String, f64)>,
}

impl BenchRecord {
    pub fn new(name: &str, s: &Summary) -> Self {
        Self { name: name.to_string(), mean_ms: s.mean, std_ms: s.std, fields: Vec::new() }
    }

    pub fn field(mut self, key: &str, value: f64) -> Self {
        self.fields.push((key.to_string(), value));
        self
    }
}

fn record_json(r: &BenchRecord) -> String {
    fn num(v: f64) -> String {
        if v.is_finite() {
            v.to_string()
        } else {
            "null".to_string()
        }
    }
    let mut s = format!(
        "  {{\"name\": \"{}\", \"mean_ms\": {}, \"std_ms\": {}",
        r.name,
        num(r.mean_ms),
        num(r.std_ms)
    );
    for (k, v) in &r.fields {
        s.push_str(&format!(", \"{k}\": {}", num(*v)));
    }
    s.push('}');
    s
}

/// Write benchmark records as a JSON array (hand-rolled: the vendored
/// crate set has no serde). Non-finite values are emitted as `null` to
/// keep the file parseable.
pub fn write_bench_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&record_json(r));
        if i + 1 < records.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("]\n");
    std::fs::write(path, s)
}

/// Append records to an existing `write_bench_json` file (so several
/// bench binaries can contribute to one `BENCH_solver.json` in a single
/// CI run). If the file is missing or does not end in a JSON array, a
/// fresh array is written instead.
pub fn append_bench_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let trimmed = existing.trim_end();
    let head = match trimmed.strip_suffix(']') {
        Some(h) if trimmed.starts_with('[') => h.trim_end().to_string(),
        _ => return write_bench_json(path, records),
    };
    let mut s = head;
    for r in records {
        if !s.trim_end().ends_with('[') {
            s.push(',');
        }
        s.push('\n');
        s.push_str(&record_json(r));
    }
    s.push_str("\n]\n");
    std::fs::write(path, s)
}

/// Emit a markdown table of (row label, per-column summaries).
pub fn markdown_table(title: &str, columns: &[&str], rows: &[(String, Vec<String>)]) -> String {
    let mut s = format!("### {title}\n\n| |");
    for c in columns {
        s.push_str(&format!(" {c} |"));
    }
    s.push_str("\n|---|");
    for _ in columns {
        s.push_str("---|");
    }
    s.push('\n');
    for (label, cells) in rows {
        s.push_str(&format!("| {label} |"));
        for c in cells {
            s.push_str(&format!(" {c} |"));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::VdP;

    #[test]
    fn summary_stats() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn timed_system_accumulates() {
        let inner = VdP::uniform(2, 1.0);
        let timed = TimedSystem::new(&inner);
        let y = BatchVec::broadcast(&[1.0, 0.0], 2);
        let mut dy = BatchVec::zeros(2, 2);
        timed.f_batch(&[0.0, 0.0], &y, &mut dy, None);
        assert_eq!(timed.calls(), 1);
        assert!(timed.model_time_ms() >= 0.0);
        timed.reset();
        assert_eq!(timed.calls(), 0);
    }

    #[test]
    fn loop_time_subtracts_model_time() {
        let inner = VdP::uniform(1, 1.0);
        let timed = TimedSystem::new(&inner);
        let m = measure_loop_time(&timed, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            10
        });
        assert_eq!(m.steps, 10);
        assert!(m.total_ms >= 2.0);
        assert!(m.loop_time_ms > 0.0);
    }

    #[test]
    fn markdown_shape() {
        let md = markdown_table(
            "T",
            &["a", "b"],
            &[("r".to_string(), vec!["1".to_string(), "2".to_string()])],
        );
        assert!(md.contains("| r | 1 | 2 |"));
    }

    #[test]
    fn threads_sweep_shape() {
        let mut seen = Vec::new();
        let rows = threads_sweep(&[1, 2], 0, 3, |n| seen.push(n));
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].0, rows[1].0), (1, 2));
        assert_eq!(seen, vec![1, 1, 1, 2, 2, 2]);
        assert_eq!(rows[0].1.n, 3);
    }

    #[test]
    fn time_repeats_counts() {
        let mut n = 0;
        let xs = time_repeats(2, 5, || n += 1);
        assert_eq!(xs.len(), 5);
        assert_eq!(n, 7);
    }

    #[test]
    fn straggler_workload_shape() {
        let (sys, y0, grid) = straggler_workload(8, 50.0, 0.5, 10.0, 20);
        assert_eq!(sys.mu(0), 50.0);
        assert_eq!(sys.mu(7), 0.5);
        assert_eq!(y0.batch(), 8);
        assert_eq!(grid.n_eval(), 20);
        assert_eq!(grid.t1(3), 10.0);
    }

    #[test]
    fn bench_json_is_valid_shape() {
        let s = Summary::from_samples(&[1.0, 2.0]);
        let recs = vec![
            BenchRecord::new("a", &s).field("batch", 256.0).field("speedup", 2.5),
            BenchRecord::new("b", &s),
        ];
        let dir = std::env::temp_dir().join("rode_bench_json_test.json");
        let path = dir.to_str().unwrap();
        write_bench_json(path, &recs).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        std::fs::remove_file(path).ok();
        assert!(text.starts_with("[\n"));
        assert!(text.contains("\"name\": \"a\""));
        assert!(text.contains("\"batch\": 256"));
        assert!(text.contains("\"speedup\": 2.5"));
        assert!(text.trim_end().ends_with(']'));
        // Exactly one comma between the two records.
        assert_eq!(text.matches("},").count(), 1);
    }

    #[test]
    fn bench_json_append_extends_array() {
        let s = Summary::from_samples(&[1.0, 2.0]);
        let dir = std::env::temp_dir().join("rode_bench_json_append_test.json");
        let path = dir.to_str().unwrap();
        std::fs::remove_file(path).ok();
        // Appending to a missing file writes a fresh array.
        append_bench_json(path, &[BenchRecord::new("first", &s)]).unwrap();
        // Appending again extends it.
        append_bench_json(path, &[BenchRecord::new("second", &s).field("dim", 16.0)]).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        std::fs::remove_file(path).ok();
        assert!(text.starts_with("[\n"));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\"name\": \"first\""));
        assert!(text.contains("\"name\": \"second\""));
        assert!(text.contains("\"dim\": 16"));
        assert_eq!(text.matches("},").count(), 1);
    }
}
