//! `rode` — CLI for the solver service and the paper-reproduction harness.
//!
//! Subcommands:
//!   solve            one-shot native solve demo (prints Listing-1 style output)
//!   serve            run the coordinator on a synthetic workload, print metrics
//!   methods          list every registered method (built-ins + runtime)
//!   check-artifacts  compile + smoke-run every AOT artifact
//!   tables <which>   regenerate the paper's tables/figures (see EXPERIMENTS.md)
//!
//! Flag parsing is hand-rolled (`--key value`); the vendored crate set has
//! no clap.

use anyhow::{anyhow, Result};
use rode::config::PoolKind;
use rode::coordinator::{Coordinator, NativeEngine, ProblemSpec, ServiceConfig, SolveRequest};
use rode::prelude::*;
use rode::runtime::Runtime;
use std::collections::HashMap;
use std::time::Duration;

mod tables;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn flag_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> f64 {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn flag_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> usize {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Parse `--pool serial|scoped|persistent`; `None` when absent, so each
/// command keeps its own default (scoped for `solve`, config for
/// `serve`).
fn flag_pool(flags: &HashMap<String, String>) -> Result<Option<PoolKind>> {
    flags
        .get("pool")
        .map(|s| {
            PoolKind::parse(s)
                .ok_or_else(|| anyhow!("unknown pool kind {s} (serial|scoped|persistent)"))
        })
        .transpose()
}

/// Parse `--layout row_major|dim_major`; `None` when absent, so each
/// command keeps its default (the `RODE_LAYOUT` env var, else
/// row-major).
fn flag_layout(flags: &HashMap<String, String>) -> Result<Option<rode::solver::Layout>> {
    flags
        .get("layout")
        .map(|s| {
            rode::solver::Layout::parse(s)
                .ok_or_else(|| anyhow!("unknown layout {s} (row_major|dim_major)"))
        })
        .transpose()
}

/// Like `flag_usize`, but a present-and-unparsable value is an error
/// instead of a silent fallback (used for knobs where a typo would
/// silently change what is being measured).
fn flag_usize_strict(flags: &HashMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow!("bad integer for --{key}: {v}")),
    }
}

/// Parse `--jac auto|dense|banded:KL,KU`; `None` (and `auto`) trusts the
/// system's own [`JacStructure`] declaration.
fn flag_jac(flags: &HashMap<String, String>) -> Result<Option<JacStructure>> {
    match flags.get("jac") {
        None => Ok(None),
        Some(s) if s.eq_ignore_ascii_case("auto") => Ok(None),
        Some(s) => JacStructure::parse(s)
            .map(Some)
            .ok_or_else(|| anyhow!("bad --jac {s} (auto|dense|banded:KL,KU)")),
    }
}

fn cmd_solve(flags: &HashMap<String, String>) -> Result<()> {
    let batch = flag_usize(flags, "batch", 5);
    let mu = flag_f64(flags, "mu", 10.0);
    let t1 = flag_f64(flags, "t1", 10.0);
    let n_eval = flag_usize(flags, "points", 50);
    let threads = flag_usize(flags, "threads", 1);
    let pool = flag_pool(flags)?.unwrap_or(PoolKind::Scoped);
    let steal_chunk = flag_usize_strict(flags, "steal-chunk", 0)?;
    let compact = flag_f64(flags, "compact-threshold", 0.0);
    anyhow::ensure!(
        (0.0..=1.0).contains(&compact),
        "--compact-threshold must be in [0, 1], got {compact}"
    );
    let method = flags
        .get("method")
        .map(|m| MethodId::parse(m).ok_or_else(|| anyhow!("unknown method {m}")))
        .transpose()?
        .unwrap_or(MethodId::TSIT5);

    let mut opts = SolveOptions::new(method)
        .with_tols(1e-6, 1e-5)
        .with_threads(threads)
        .with_pool(pool)
        .with_steal_chunk(steal_chunk)
        .with_compaction(compact);
    if let Some(l) = flag_layout(flags)? {
        opts = opts.with_layout(l);
    }
    if let Some(j) = flag_jac(flags)? {
        opts = opts.with_jac_structure(j);
    }

    let problem = flags.get("problem").map(String::as_str).unwrap_or("vdp");
    let sol = match problem {
        "vdp" => {
            // Mirrors the paper's Listing 1.
            let sys = rode::problems::VdP::uniform(batch, mu);
            let mut rng = rode::nn::Rng64::new(0);
            let y0 = BatchVec::from_rows(
                &(0..batch)
                    .map(|_| vec![rng.normal(), rng.normal()])
                    .collect::<Vec<_>>(),
            );
            let grid = TimeGrid::linspace_shared(batch, 0.0, t1, n_eval);
            solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts)
        }
        "reaction-diffusion" | "rd" => {
            // Fisher–KPP method of lines: per-instance diffusion sweep,
            // tridiagonal Jacobian — the banded Newton showcase.
            let dim = flag_usize_strict(flags, "dim", 64)?;
            anyhow::ensure!(dim >= 3, "--dim must be at least 3, got {dim}");
            let sys = rode::problems::ReactionDiffusion::sweep(batch, dim);
            let y0 = BatchVec::from_rows(&sys.front_y0(batch));
            let grid = TimeGrid::linspace_shared(batch, 0.0, t1, n_eval);
            solve_ivp_parallel_pooled(&sys, &y0, &grid, &opts)
        }
        other => {
            return Err(anyhow!("unknown problem {other} (vdp|reaction-diffusion)"));
        }
    };

    println!("status: {:?}", sol.status);
    println!(
        "exec:   pool={} threads={} shards={} steals={}",
        sol.exec_stats.pool_kind.name(),
        sol.exec_stats.threads,
        sol.exec_stats.shards,
        sol.exec_stats.steal_count
    );
    println!(
        "n_f_evals:     {:?}",
        sol.stats.iter().map(|s| s.n_f_evals).collect::<Vec<_>>()
    );
    println!(
        "n_steps:       {:?}",
        sol.stats.iter().map(|s| s.n_steps).collect::<Vec<_>>()
    );
    println!(
        "n_accepted:    {:?}",
        sol.stats.iter().map(|s| s.n_accepted).collect::<Vec<_>>()
    );
    println!(
        "n_initialized: {:?}",
        sol.stats.iter().map(|s| s.n_initialized).collect::<Vec<_>>()
    );
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    // Config file first (--config rode.toml), CLI flags override.
    let mut cfg = match flags.get("config") {
        Some(path) => rode::config::RodeConfig::load(path)?,
        None => rode::config::RodeConfig::default(),
    };
    let n_requests = flag_usize(flags, "requests", 200);
    cfg.max_batch = flag_usize(flags, "max-batch", cfg.max_batch);
    cfg.threads = flag_usize(flags, "threads", cfg.threads);
    if let Some(p) = flag_pool(flags)? {
        cfg.pool = p;
    }
    cfg.steal_chunk = flag_usize_strict(flags, "steal-chunk", cfg.steal_chunk)?;
    if let Some(l) = flag_layout(flags)? {
        cfg.layout = l;
    }
    cfg.compact_threshold = flag_f64(flags, "compact-threshold", cfg.compact_threshold);
    anyhow::ensure!(
        (0.0..=1.0).contains(&cfg.compact_threshold),
        "--compact-threshold must be in [0, 1], got {}",
        cfg.compact_threshold
    );
    if let Some(w) = flags.get("max-wait-ms").and_then(|v| v.parse::<f64>().ok()) {
        cfg.max_wait = Duration::from_secs_f64(w / 1e3);
    }
    cfg.max_queue = flag_usize_strict(flags, "max-queue", cfg.max_queue)?;
    if let Some(d) = flags.get("deadline-ms") {
        let ms: f64 = d.parse().map_err(|_| anyhow!("bad float for --deadline-ms: {d}"))?;
        anyhow::ensure!(ms > 0.0, "--deadline-ms must be positive, got {ms}");
        cfg.deadline = Some(Duration::from_secs_f64(ms / 1e3));
    }
    if let Some(m) = flags.get("retry-method") {
        cfg.retry_method = match m.to_ascii_lowercase().as_str() {
            "off" | "none" => None,
            name => Some(
                MethodId::parse(name)
                    .ok_or_else(|| anyhow!("unknown --retry-method {name} (or off|none)"))?,
            ),
        };
    }
    if flags.contains_key("jac") {
        cfg.jac = flag_jac(flags)?; // `--jac auto` resets a config-file override
    }
    cfg.workers = flag_usize_strict(flags, "workers", cfg.workers)?;
    if let Some(v) = flags.get("classifier") {
        cfg.classifier = match v.to_ascii_lowercase().as_str() {
            "true" | "on" => true, // bare `--classifier` parses as "true"
            "false" | "off" => false,
            other => return Err(anyhow!("bad --classifier {other} (on|off)")),
        };
    }
    let engine_kind = flags.get("engine").cloned().unwrap_or(cfg.engine.clone());
    let artifacts_dir = cfg.artifacts_dir.clone();
    let mut solve_opts = rode::solver::SolveOptions::new(cfg.method)
        .with_tols(cfg.atol, cfg.rtol)
        .with_threads(cfg.threads)
        .with_pool(cfg.pool)
        .with_steal_chunk(cfg.steal_chunk)
        .with_compaction(cfg.compact_threshold)
        .with_layout(cfg.layout);
    if let Some(j) = cfg.jac {
        solve_opts = solve_opts.with_jac_structure(j);
    }

    let coord = Coordinator::spawn(
        ServiceConfig {
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            max_queue: cfg.max_queue,
            workers: cfg.workers,
            retry: rode::coordinator::RetryPolicy {
                method: cfg.retry_method,
                max_retries: cfg.max_retries,
            },
            classifier: if cfg.classifier {
                rode::coordinator::ClassifierPolicy::enabled()
            } else {
                rode::coordinator::ClassifierPolicy::default()
            },
        },
        // FnMut: called again to rebuild the engine if it panics, so it
        // only borrows what it can hand out repeatedly.
        move || -> Box<dyn rode::coordinator::SolveEngine> {
            match engine_kind.as_str() {
                "aot" => Box::new(
                    rode::coordinator::AotEngine::open(&artifacts_dir)
                        .expect("open AOT engine (run `make artifacts`)"),
                ),
                "joint" => Box::new(rode::coordinator::JointEngine { opts: solve_opts.clone() }),
                _ => Box::new(NativeEngine::new(solve_opts.clone())),
            }
        },
    );

    let mut rng = rode::nn::Rng64::new(7);
    let mut rxs = Vec::new();
    for _ in 0..n_requests {
        let mu = rng.range(0.5, 15.0);
        let n_eval = [10, 20, 50][rng.below(3)];
        let t1 = rng.range(2.0, 10.0);
        let mut req = SolveRequest::new(
            ProblemSpec::Vdp { mu },
            vec![rng.normal(), rng.normal()],
            (0..n_eval).map(|k| t1 * k as f64 / (n_eval - 1) as f64).collect(),
        );
        req.deadline = cfg.deadline;
        rxs.push(coord.submit(req));
    }
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv()?;
        if resp.is_success() {
            ok += 1;
        }
    }
    println!("{}/{} requests succeeded", ok, n_requests);
    println!("{}", coord.metrics().summary());
    Ok(())
}

/// `rode train` — run a real training workload (CNF or FEN) with a
/// selectable adjoint mode; the CI training-smoke job drives this.
fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    use rode::experiments::{train_cnf, train_fen, AdjointMode, TrainConfig};
    let model = flags.get("model").map(String::as_str).unwrap_or("cnf");
    let mode = match flags.get("adjoint") {
        None => AdjointMode::FixedTape,
        Some(s) => AdjointMode::parse(s)
            .ok_or_else(|| anyhow!("unknown --adjoint {s} (fixed|tape|backsolve)"))?,
    };
    let cfg = TrainConfig {
        steps: flag_usize(flags, "steps", 20),
        batch: flag_usize(flags, "batch", 8),
        hidden: vec![flag_usize(flags, "hidden", 16)],
        lr: flag_f64(flags, "lr", 1e-2),
        t1: flag_f64(flags, "t1", 1.0),
        mode,
        checkpoints: flag_usize_strict(flags, "checkpoints", 1)?,
        n_rk: flag_usize_strict(flags, "n-rk", 12)?,
        n_nodes: flag_usize(flags, "nodes", 12),
        seed: flag_usize(flags, "seed", 7) as u64,
    };
    let rep = match model {
        "cnf" => train_cnf(&cfg),
        "fen" => train_fen(&cfg),
        other => return Err(anyhow!("unknown --model {other} (cnf|fen)")),
    };
    println!("model: {model}  adjoint: {}  steps: {}", rep.mode.name(), cfg.steps);
    for (i, l) in rep.losses.iter().enumerate() {
        println!("  step {i:>3}  loss {l:.6}");
    }
    println!("final loss: {:.6}", rep.final_loss);
    println!("peak tape:  {} bytes", rep.tape_bytes);
    println!("wall time:  {:.1} ms", rep.wall_ms);
    anyhow::ensure!(
        rep.final_loss.is_finite() && rep.losses.iter().all(|l| l.is_finite()),
        "training produced a non-finite loss"
    );
    if cfg.steps >= 5 {
        anyhow::ensure!(
            rep.final_loss < rep.losses[0],
            "loss did not decrease: {} -> {}",
            rep.losses[0],
            rep.final_loss
        );
    }
    Ok(())
}

/// `rode methods` — dump the method registry as a table. Everything the
/// process can route to is listed, so a runtime-registered method would
/// appear here too.
fn cmd_methods() -> Result<()> {
    println!(
        "{:<12} {:<18} {:>6} {:>5} {:>8}  {}",
        "name", "aliases", "stages", "order", "implicit", "error est."
    );
    for m in MethodId::all() {
        let t = m.tableau();
        let aliases =
            if m.aliases().is_empty() { "-".to_string() } else { m.aliases().join(", ") };
        let err = if t.b_err.is_empty() {
            "none (fixed step)".to_string()
        } else {
            format!("order {}", t.err_order)
        };
        println!(
            "{:<12} {:<18} {:>6} {:>5} {:>8}  {}",
            m.name(),
            aliases,
            t.stages,
            t.order,
            if m.is_implicit() { "yes" } else { "no" },
            err,
        );
    }
    Ok(())
}

fn cmd_check_artifacts(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
    let mut rt = Runtime::open(&dir)?;
    println!("platform: {}", rt.platform());
    let names = rt.artifact_names();
    for name in names {
        let art = rt.load(&name)?;
        // Build synthetic inputs matching the manifest and run once.
        let mut bufs: Vec<Vec<f32>> = art
            .meta
            .inputs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let n: usize = spec.shape.iter().product();
                match i {
                    0 => vec![1.0; n],                              // y0 / state
                    1 => vec![2.0; n],                              // mu / dt
                    _ => (0..n).map(|k| 0.01 * k as f32).collect(), // grids
                }
            })
            .collect();
        // For solve artifacts the last input is the eval grid — make it
        // ascending per row.
        if art.meta.kind == "solve" {
            let grid_idx = bufs.len() - 1;
            let e = art.meta.n_eval;
            let b = art.meta.batch;
            bufs[grid_idx] = (0..b)
                .flat_map(|_| (0..e).map(|k| k as f32 * 0.05))
                .collect();
        }
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let out = art.run_f32(&refs)?;
        let finite = out[0].iter().all(|v| v.is_finite());
        println!(
            "  {name}: ok ({} outputs, first has {} values, finite={finite})",
            out.len(),
            out[0].len()
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "solve" => cmd_solve(&flags),
        "serve" => cmd_serve(&flags),
        "train" => cmd_train(&flags),
        "methods" => cmd_methods(),
        "check-artifacts" => cmd_check_artifacts(&flags),
        "tables" => tables::run(&args[1.min(args.len())..], &flags),
        _ => {
            println!(
                "rode — parallel ODE solver stack (torchode reproduction)\n\n\
                 usage: rode <solve|serve|train|methods|check-artifacts|tables> [--flags]\n\
                 \n  solve            one-shot native solve (Listing 1 demo)\
                 \n                   (--method <name> — any registered method, see `rode methods`;\
                 \n                    trbdf2 and kvaerno43 are the implicit (stiff) methods;\
                 \n                    --problem vdp|reaction-diffusion selects the workload,\
                 \n                    default vdp; reaction-diffusion is the Fisher-KPP method\
                 \n                    of lines with a per-instance diffusion sweep;\
                 \n                    --dim N sets the reaction-diffusion grid size, default 64;\
                 \n                    --jac auto|dense|banded:KL,KU overrides the Newton\
                 \n                    factorization structure, default auto (trust the problem);\
                 \n                    --threads N shards the batch over N workers; 0 = all cores;\
                 \n                    --pool serial|scoped|persistent selects the worker pool;\
                 \n                    --steal-chunk R sets the work-stealing chunk size in rows,\
                 \n                    0 = heuristic (persistent pool only);\
                 \n                    --compact-threshold F packs solver state once the live\
                 \n                    fraction drops below F, 0 = off;\
                 \n                    --layout row_major|dim_major selects the stage-kernel\
                 \n                    memory layout, bitwise-identical results)\
                 \n  serve            coordinator + synthetic workload (also honors --threads,\
                 \n                   --pool, --steal-chunk, --compact-threshold, --layout\
                 \n                   and --jac;\
                 \n                    --max-queue N bounds in-flight requests, excess is shed,\
                 \n                    0 = unbounded;\
                 \n                    --deadline-ms D drops requests not dispatched within D;\
                 \n                    --retry-method <name>|off re-routes stiffness failures\
                 \n                    to an implicit method, default trbdf2;\
                 \n                    --workers N runs N supervised coordinator workers, each\
                 \n                    with its own engine; 0 = one per core (the default);\
                 \n                    --classifier on|off probes each request's dominant\
                 \n                    eigenvalue and routes stiff ones straight to the implicit\
                 \n                    fallback before the first solve, default off)\
                 \n  train            run a training workload end to end\
                 \n                   (--model cnf|fen selects the workload, default cnf;\
                 \n                    --adjoint fixed|tape|backsolve selects how gradients\
                 \n                    flow through the solve, default fixed;\
                 \n                    --checkpoints K segments the backsolve state re-solve;\
                 \n                    --steps N optimizer steps, --batch B, --lr F, --t1 F,\
                 \n                    --hidden W, --n-rk N fixed-tape substeps,\
                 \n                    --nodes N FEN mesh size, --seed S)\
                 \n  methods          list registered methods (name, aliases, stages, order)\
                 \n  check-artifacts  compile & smoke-run AOT artifacts\
                 \n  tables <which>   regenerate paper tables/figures\
                 \n                   (t3 | t4 | t5 | sec41 | fig1 | fig2 | all)"
            );
            Ok(())
        }
    }
}
