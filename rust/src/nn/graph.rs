//! Graph aggregation substrate for the FEN stand-in: sparse neighborhood
//! difference-aggregation on a fixed mesh graph, with a VJP.

/// A fixed undirected graph with per-edge weights, stored as a directed
/// edge list (both directions present) in CSR-like form.
#[derive(Debug, Clone)]
pub struct GraphAgg {
    pub n_nodes: usize,
    /// CSR offsets, len `n_nodes + 1`.
    offsets: Vec<usize>,
    /// Neighbor indices.
    nbrs: Vec<usize>,
    /// Edge weights aligned with `nbrs`.
    weights: Vec<f64>,
}

impl GraphAgg {
    /// Build from an undirected edge list with weights; each `(i, j, w)`
    /// inserts both directions with weight `w`.
    pub fn from_edges(n_nodes: usize, edges: &[(usize, usize, f64)]) -> Self {
        let mut deg = vec![0usize; n_nodes];
        for &(i, j, _) in edges {
            assert!(i < n_nodes && j < n_nodes && i != j);
            deg[i] += 1;
            deg[j] += 1;
        }
        let mut offsets = vec![0usize; n_nodes + 1];
        for i in 0..n_nodes {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut cursor = offsets.clone();
        let mut nbrs = vec![0usize; offsets[n_nodes]];
        let mut weights = vec![0.0; offsets[n_nodes]];
        for &(i, j, w) in edges {
            nbrs[cursor[i]] = j;
            weights[cursor[i]] = w;
            cursor[i] += 1;
            nbrs[cursor[j]] = i;
            weights[cursor[j]] = w;
            cursor[j] += 1;
        }
        Self { n_nodes, offsets, nbrs, weights }
    }

    pub fn n_edges_directed(&self) -> usize {
        self.nbrs.len()
    }

    /// Difference aggregation per feature channel:
    /// `out[i, f] = Σ_{j ∈ N(i)} w_ij (x[j, f] − x[i, f])`.
    /// `x` and `out` are `(n_nodes, n_feat)` row-major.
    pub fn aggregate(&self, x: &[f64], n_feat: usize, out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n_nodes * n_feat);
        debug_assert_eq!(out.len(), x.len());
        out.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.n_nodes {
            let xi = &x[i * n_feat..(i + 1) * n_feat];
            let oi = i * n_feat;
            for e in self.offsets[i]..self.offsets[i + 1] {
                let j = self.nbrs[e];
                let w = self.weights[e];
                let xj = &x[j * n_feat..(j + 1) * n_feat];
                for f in 0..n_feat {
                    out[oi + f] += w * (xj[f] - xi[f]);
                }
            }
        }
    }

    /// VJP of [`GraphAgg::aggregate`]: given `a = dL/d out`, accumulate
    /// `dx += (∂out/∂x)ᵀ a`. The operator is linear and symmetric up to
    /// sign structure: `dx[j] += w_ij a[i]`, `dx[i] -= w_ij a[i]` for every
    /// directed edge `(i → j)`.
    pub fn aggregate_vjp(&self, a: &[f64], n_feat: usize, dx: &mut [f64]) {
        debug_assert_eq!(a.len(), self.n_nodes * n_feat);
        debug_assert_eq!(dx.len(), a.len());
        for i in 0..self.n_nodes {
            let ai = &a[i * n_feat..(i + 1) * n_feat];
            for e in self.offsets[i]..self.offsets[i + 1] {
                let j = self.nbrs[e];
                let w = self.weights[e];
                for f in 0..n_feat {
                    dx[j * n_feat + f] += w * ai[f];
                    dx[i * n_feat + f] -= w * ai[f];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> GraphAgg {
        GraphAgg::from_edges(3, &[(0, 1, 1.0), (1, 2, 0.5), (0, 2, 2.0)])
    }

    #[test]
    fn aggregation_is_zero_on_constant_field() {
        let g = triangle();
        let x = vec![7.0; 6]; // 3 nodes × 2 features, constant
        let mut out = vec![1.0; 6];
        g.aggregate(&x, 2, &mut out);
        assert!(out.iter().all(|v| v.abs() < 1e-14));
    }

    #[test]
    fn aggregation_explicit_value() {
        let g = triangle();
        // 1 feature, x = [0, 1, 2]
        let x = [0.0, 1.0, 2.0];
        let mut out = [0.0; 3];
        g.aggregate(&x, 1, &mut out);
        // node 0: 1.0*(1-0) + 2.0*(2-0) = 5
        assert!((out[0] - 5.0).abs() < 1e-14);
        // node 1: 1.0*(0-1) + 0.5*(2-1) = -0.5
        assert!((out[1] + 0.5).abs() < 1e-14);
        // node 2: 0.5*(1-2) + 2.0*(0-2) = -4.5
        assert!((out[2] + 4.5).abs() < 1e-14);
    }

    #[test]
    fn aggregation_conserves_weighted_total() {
        // Σ_i out_i = 0 for a symmetric difference operator.
        let g = triangle();
        let x = [0.3, -1.2, 2.5];
        let mut out = [0.0; 3];
        g.aggregate(&x, 1, &mut out);
        assert!(out.iter().sum::<f64>().abs() < 1e-13);
    }

    #[test]
    fn vjp_matches_fd() {
        let g = triangle();
        let x = [0.1, 0.5, -0.7];
        let a = [1.0, -2.0, 0.3];
        let mut dx = [0.0; 3];
        g.aggregate_vjp(&a, 1, &mut dx);
        let h = 1e-6;
        for j in 0..3 {
            let (mut xp, mut xm) = (x, x);
            xp[j] += h;
            xm[j] -= h;
            let (mut op, mut om) = ([0.0; 3], [0.0; 3]);
            g.aggregate(&xp, 1, &mut op);
            g.aggregate(&xm, 1, &mut om);
            let fd: f64 = (0..3).map(|i| a[i] * (op[i] - om[i]) / (2.0 * h)).sum();
            assert!((dx[j] - fd).abs() < 1e-8);
        }
    }

    #[test]
    fn csr_structure() {
        let g = triangle();
        assert_eq!(g.n_edges_directed(), 6);
    }
}
