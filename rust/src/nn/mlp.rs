//! A tanh MLP with cached forward and full manual backprop — the learned
//! dynamics of the CNF and FEN stand-ins.

use super::{Linear, Parameterized, Rng64};

/// Multi-layer perceptron: linear → tanh → … → linear (no final activation).
#[derive(Debug, Clone)]
pub struct Mlp {
    pub layers: Vec<Linear>,
}

/// Per-evaluation scratch holding post-activation values of every layer
/// input (needed by backprop). Reusable across calls of the same shape.
#[derive(Debug, Clone, Default)]
pub struct MlpCache {
    /// `acts[0]` is the network input, `acts[l]` the input of layer `l`.
    pub acts: Vec<Vec<f64>>,
    /// Pre-activation outputs of each hidden layer (for tanh').
    pub pre: Vec<Vec<f64>>,
}

impl Mlp {
    /// `sizes = [in, h1, ..., out]`.
    pub fn new(sizes: &[usize], rng: &mut Rng64) -> Self {
        assert!(sizes.len() >= 2);
        let layers = sizes.windows(2).map(|w| Linear::new(w[0], w[1], rng)).collect();
        Self { layers }
    }

    pub fn n_in(&self) -> usize {
        self.layers[0].n_in
    }

    pub fn n_out(&self) -> usize {
        self.layers.last().unwrap().n_out
    }

    fn ensure_cache(&self, c: &mut MlpCache) {
        if c.acts.len() != self.layers.len() + 1 {
            c.acts = self
                .layers
                .iter()
                .map(|l| vec![0.0; l.n_in])
                .chain(std::iter::once(vec![0.0; self.n_out()]))
                .collect();
            c.pre = self.layers.iter().map(|l| vec![0.0; l.n_out]).collect();
        }
    }

    /// Forward pass, caching activations for a later [`Mlp::backward`].
    pub fn forward_cached(&self, x: &[f64], c: &mut MlpCache, out: &mut [f64]) {
        self.ensure_cache(c);
        c.acts[0].copy_from_slice(x);
        let n = self.layers.len();
        for (l, layer) in self.layers.iter().enumerate() {
            // Split borrow: read acts[l], write pre[l].
            let (input, pre) = (&c.acts[l], &mut c.pre[l]);
            layer.forward(input, pre);
            if l + 1 < n {
                for (a, p) in c.acts[l + 1].iter_mut().zip(c.pre[l].iter()) {
                    *a = p.tanh();
                }
            } else {
                c.acts[n].copy_from_slice(&c.pre[l]);
            }
        }
        out.copy_from_slice(&c.acts[n]);
    }

    /// Forward without a cache (allocation-free if `scratch` is reused).
    pub fn forward(&self, x: &[f64], c: &mut MlpCache, out: &mut [f64]) {
        self.forward_cached(x, c, out);
    }

    /// Backprop from upstream gradient `dy`. Accumulates parameter
    /// gradients into `dparams` (flat layout matching [`Parameterized`])
    /// and the input gradient into `dx`. Requires the cache of the
    /// matching forward pass.
    pub fn backward(&self, c: &MlpCache, dy: &[f64], dx: &mut [f64], dparams: &mut [f64]) {
        let n = self.layers.len();
        let mut grad = dy.to_vec();
        let mut offsets = Vec::with_capacity(n);
        let mut off = 0;
        for l in &self.layers {
            offsets.push(off);
            off += l.n_params();
        }
        debug_assert_eq!(dparams.len(), off);
        for l in (0..n).rev() {
            let layer = &self.layers[l];
            let (dw, db) = {
                let seg = &mut dparams[offsets[l]..offsets[l] + layer.n_params()];
                let (dw, db) = seg.split_at_mut(layer.w.len());
                (dw as *mut [f64], db as *mut [f64])
            };
            let mut dinput = vec![0.0; layer.n_in];
            // SAFETY: dw/db are disjoint sub-slices of dparams.
            unsafe {
                layer.backward(&c.acts[l], &grad, &mut dinput, &mut *dw, &mut *db);
            }
            if l > 0 {
                // Through the tanh of the previous layer: g *= 1 - tanh².
                for (g, a) in dinput.iter_mut().zip(c.acts[l].iter()) {
                    *g *= 1.0 - a * a; // acts[l] already holds tanh(pre)
                }
            }
            grad = dinput;
        }
        for (o, g) in dx.iter_mut().zip(grad.iter()) {
            *o += g;
        }
    }

    /// Input-only VJP (no parameter gradients).
    pub fn vjp_input(&self, c: &MlpCache, dy: &[f64], dx: &mut [f64]) {
        let n = self.layers.len();
        let mut grad = dy.to_vec();
        for l in (0..n).rev() {
            let layer = &self.layers[l];
            let mut dinput = vec![0.0; layer.n_in];
            layer.vjp_input(&grad, &mut dinput);
            if l > 0 {
                for (g, a) in dinput.iter_mut().zip(c.acts[l].iter()) {
                    *g *= 1.0 - a * a;
                }
            }
            grad = dinput;
        }
        for (o, g) in dx.iter_mut().zip(grad.iter()) {
            *o += g;
        }
    }
}

impl Parameterized for Mlp {
    fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_params()).sum()
    }

    fn params(&self, out: &mut [f64]) {
        let mut off = 0;
        for l in &self.layers {
            l.params(&mut out[off..off + l.n_params()]);
            off += l.n_params();
        }
    }

    fn set_params(&mut self, p: &[f64]) {
        let mut off = 0;
        for l in &mut self.layers {
            let n = l.n_params();
            l.set_params(&p[off..off + n]);
            off += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mlp() -> Mlp {
        let mut rng = Rng64::new(5);
        Mlp::new(&[3, 8, 2], &mut rng)
    }

    #[test]
    fn shapes() {
        let m = mlp();
        assert_eq!(m.n_in(), 3);
        assert_eq!(m.n_out(), 2);
        assert_eq!(m.n_params(), 3 * 8 + 8 + 8 * 2 + 2);
    }

    #[test]
    fn forward_deterministic() {
        let m = mlp();
        let mut c = MlpCache::default();
        let (mut a, mut b) = ([0.0; 2], [0.0; 2]);
        m.forward_cached(&[0.1, -0.2, 0.3], &mut c, &mut a);
        m.forward_cached(&[0.1, -0.2, 0.3], &mut c, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn backward_input_grad_matches_fd() {
        let m = mlp();
        let x = [0.4, -0.1, 0.9];
        let dy = [1.0, -0.7];
        let mut c = MlpCache::default();
        let mut out = [0.0; 2];
        m.forward_cached(&x, &mut c, &mut out);
        let mut dx = [0.0; 3];
        let mut dp = vec![0.0; m.n_params()];
        m.backward(&c, &dy, &mut dx, &mut dp);

        let h = 1e-6;
        for i in 0..3 {
            let (mut xp, mut xm) = (x, x);
            xp[i] += h;
            xm[i] -= h;
            let (mut yp, mut ym) = ([0.0; 2], [0.0; 2]);
            m.forward_cached(&xp, &mut c, &mut yp);
            m.forward_cached(&xm, &mut c, &mut ym);
            let fd: f64 = (0..2).map(|o| dy[o] * (yp[o] - ym[o]) / (2.0 * h)).sum();
            assert!((dx[i] - fd).abs() < 1e-6, "dx[{i}]={} fd={fd}", dx[i]);
        }
    }

    #[test]
    fn backward_param_grad_matches_fd() {
        let mut m = mlp();
        let x = [0.4, -0.1, 0.9];
        let dy = [0.3, 1.1];
        let mut c = MlpCache::default();
        let mut out = [0.0; 2];
        m.forward_cached(&x, &mut c, &mut out);
        let mut dx = [0.0; 3];
        let mut dp = vec![0.0; m.n_params()];
        m.backward(&c, &dy, &mut dx, &mut dp);

        let mut p = vec![0.0; m.n_params()];
        m.params(&mut p);
        let h = 1e-6;
        // Spot-check a spread of parameter indices.
        for &j in &[0usize, 5, 11, 26, 33, m.n_params() - 1] {
            let orig = p[j];
            p[j] = orig + h;
            m.set_params(&p);
            let mut yp = [0.0; 2];
            m.forward_cached(&x, &mut c, &mut yp);
            p[j] = orig - h;
            m.set_params(&p);
            let mut ym = [0.0; 2];
            m.forward_cached(&x, &mut c, &mut ym);
            p[j] = orig;
            m.set_params(&p);
            let fd: f64 = (0..2).map(|o| dy[o] * (yp[o] - ym[o]) / (2.0 * h)).sum();
            assert!((dp[j] - fd).abs() < 1e-6, "dp[{j}]={} fd={fd}", dp[j]);
        }
    }

    #[test]
    fn vjp_input_agrees_with_backward() {
        let m = mlp();
        let x = [-0.2, 0.8, 0.1];
        let dy = [0.5, 0.5];
        let mut c = MlpCache::default();
        let mut out = [0.0; 2];
        m.forward_cached(&x, &mut c, &mut out);
        let mut dx1 = [0.0; 3];
        m.vjp_input(&c, &dy, &mut dx1);
        let mut dx2 = [0.0; 3];
        let mut dp = vec![0.0; m.n_params()];
        m.backward(&c, &dy, &mut dx2, &mut dp);
        for i in 0..3 {
            assert!((dx1[i] - dx2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn params_roundtrip() {
        let mut m = mlp();
        let mut p = vec![0.0; m.n_params()];
        m.params(&mut p);
        let p2: Vec<f64> = p.iter().map(|x| x * 2.0).collect();
        m.set_params(&p2);
        let mut p3 = vec![0.0; m.n_params()];
        m.params(&mut p3);
        for (a, b) in p2.iter().zip(p3.iter()) {
            assert_eq!(a, b);
        }
    }
}
