//! A dense layer with manual forward/backward passes.

use super::{Parameterized, Rng64};

/// `y = W x + b` with `W: (n_out, n_in)` row-major.
#[derive(Debug, Clone)]
pub struct Linear {
    pub w: Vec<f64>,
    pub b: Vec<f64>,
    pub n_in: usize,
    pub n_out: usize,
}

impl Linear {
    /// Glorot-uniform initialization.
    pub fn new(n_in: usize, n_out: usize, rng: &mut Rng64) -> Self {
        let lim = (6.0 / (n_in + n_out) as f64).sqrt();
        let w = (0..n_in * n_out).map(|_| rng.range(-lim, lim)).collect();
        Self { w, b: vec![0.0; n_out], n_in, n_out }
    }

    pub fn zeros(n_in: usize, n_out: usize) -> Self {
        Self { w: vec![0.0; n_in * n_out], b: vec![0.0; n_out], n_in, n_out }
    }

    /// `out = W x + b`.
    #[inline]
    pub fn forward(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(out.len(), self.n_out);
        for o in 0..self.n_out {
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let mut acc = self.b[o];
            for i in 0..self.n_in {
                acc += row[i] * x[i];
            }
            out[o] = acc;
        }
    }

    /// Given upstream gradient `dy` and the input `x` of the forward pass:
    /// `dx += Wᵀ dy`, `dw += dy xᵀ`, `db += dy`.
    pub fn backward(&self, x: &[f64], dy: &[f64], dx: &mut [f64], dw: &mut [f64], db: &mut [f64]) {
        debug_assert_eq!(dw.len(), self.w.len());
        debug_assert_eq!(db.len(), self.b.len());
        for o in 0..self.n_out {
            let g = dy[o];
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let drow = &mut dw[o * self.n_in..(o + 1) * self.n_in];
            for i in 0..self.n_in {
                dx[i] += row[i] * g;
                drow[i] += g * x[i];
            }
            db[o] += g;
        }
    }

    /// Input gradient only: `dx += Wᵀ dy` (adjoint hot path when parameter
    /// gradients are not needed).
    pub fn vjp_input(&self, dy: &[f64], dx: &mut [f64]) {
        for o in 0..self.n_out {
            let g = dy[o];
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            for i in 0..self.n_in {
                dx[i] += row[i] * g;
            }
        }
    }
}

impl Parameterized for Linear {
    fn n_params(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn params(&self, out: &mut [f64]) {
        out[..self.w.len()].copy_from_slice(&self.w);
        out[self.w.len()..].copy_from_slice(&self.b);
    }

    fn set_params(&mut self, p: &[f64]) {
        let nw = self.w.len();
        self.w.copy_from_slice(&p[..nw]);
        self.b.copy_from_slice(&p[nw..]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> Linear {
        let mut l = Linear::zeros(2, 3);
        l.w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        l.b = vec![0.1, 0.2, 0.3];
        l
    }

    #[test]
    fn forward_matvec() {
        let l = layer();
        let mut out = [0.0; 3];
        l.forward(&[1.0, -1.0], &mut out);
        let expect = [1.0 - 2.0 + 0.1, 3.0 - 4.0 + 0.2, 5.0 - 6.0 + 0.3];
        for (o, e) in out.iter().zip(expect) {
            assert!((o - e).abs() < 1e-12, "{o} vs {e}");
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let l = layer();
        let x = [0.7, -0.3];
        let dy = [1.0, -2.0, 0.5];
        let mut dx = [0.0; 2];
        let mut dw = vec![0.0; 6];
        let mut db = vec![0.0; 3];
        l.backward(&x, &dy, &mut dx, &mut dw, &mut db);

        let h = 1e-6;
        // d(dy·y)/dx via FD
        for i in 0..2 {
            let mut xp = x;
            xp[i] += h;
            let mut xm = x;
            xm[i] -= h;
            let (mut yp, mut ym) = ([0.0; 3], [0.0; 3]);
            l.forward(&xp, &mut yp);
            l.forward(&xm, &mut ym);
            let fd: f64 = (0..3).map(|o| dy[o] * (yp[o] - ym[o]) / (2.0 * h)).sum();
            assert!((dx[i] - fd).abs() < 1e-8);
        }
        // dw, db
        assert!((dw[0] - dy[0] * x[0]).abs() < 1e-12);
        assert!((dw[5] - dy[2] * x[1]).abs() < 1e-12);
        assert_eq!(db, dy.to_vec());
    }

    #[test]
    fn vjp_input_equals_backward_dx() {
        let l = layer();
        let dy = [0.3, 0.9, -1.1];
        let mut dx1 = [0.0; 2];
        l.vjp_input(&dy, &mut dx1);
        let mut dx2 = [0.0; 2];
        let mut dw = vec![0.0; 6];
        let mut db = vec![0.0; 3];
        l.backward(&[0.0, 0.0], &dy, &mut dx2, &mut dw, &mut db);
        assert_eq!(dx1, dx2);
    }

    #[test]
    fn params_roundtrip() {
        let mut l = layer();
        let mut p = vec![0.0; l.n_params()];
        l.params(&mut p);
        assert_eq!(p.len(), 9);
        p[0] = 42.0;
        l.set_params(&p);
        assert_eq!(l.w[0], 42.0);
    }

    #[test]
    fn glorot_bounds() {
        let mut rng = Rng64::new(1);
        let l = Linear::new(10, 10, &mut rng);
        let lim = (6.0 / 20.0f64).sqrt();
        assert!(l.w.iter().all(|w| w.abs() <= lim));
        assert!(l.b.iter().all(|&b| b == 0.0));
    }
}
