//! Minimal neural-network substrate.
//!
//! The paper's learning benchmarks (FEN, CNF) need *trainable* dynamics.
//! rode cannot depend on PyTorch — in this reproduction rode *is* the
//! framework — so this module provides exactly what the experiments need:
//! dense layers with manual backprop, a tanh MLP, a flat-parameter view
//! (required by the adjoint equation, whose state appends one variable per
//! model parameter), and an Adam optimizer.

mod adam;
mod graph;
mod linear;
mod mlp;
mod rng;

pub use adam::Adam;
pub use graph::GraphAgg;
pub use linear::Linear;
pub use mlp::{Mlp, MlpCache};
pub use rng::Rng64;

/// Anything with a flat parameter vector (used by the adjoint solver and
/// the optimizer).
pub trait Parameterized {
    fn n_params(&self) -> usize;
    /// Copy parameters into `out` (len = `n_params`).
    fn params(&self, out: &mut [f64]);
    /// Overwrite parameters from `p`.
    fn set_params(&mut self, p: &[f64]);
}
