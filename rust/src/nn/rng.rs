//! A tiny, deterministic PRNG (xoshiro256**). The vendored crate set has no
//! `rand`, and the experiments only need reproducible initialization and
//! synthetic-data sampling, so we keep our own 40-line generator.

/// xoshiro256** seeded from a single u64 via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

impl Rng64 {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to spread the seed over the state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// ±1 with equal probability (Rademacher, for Hutchinson estimators).
    pub fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng64::new(1);
        let mut b = Rng64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng64::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::new(3);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn rademacher_is_pm_one() {
        let mut r = Rng64::new(9);
        let mut sum = 0.0;
        for _ in 0..1000 {
            let x = r.rademacher();
            assert!(x == 1.0 || x == -1.0);
            sum += x;
        }
        assert!(sum.abs() < 120.0);
    }
}
