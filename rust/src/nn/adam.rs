//! Adam optimizer over a flat parameter vector.

/// Standard Adam (Kingma & Ba) with bias correction.
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(n_params: usize, lr: f64) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
            t: 0,
        }
    }

    /// One update step: `params -= lr * m̂ / (sqrt(v̂) + eps)`.
    pub fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grads[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grads[i] * grads[i];
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = Σ (x - target)², gradient 2(x - target).
        let target = [3.0, -1.0, 0.5];
        let mut x = vec![0.0; 3];
        let mut opt = Adam::new(3, 0.05);
        for _ in 0..2000 {
            let g: Vec<f64> = x.iter().zip(target.iter()).map(|(x, t)| 2.0 * (x - t)).collect();
            opt.step(&mut x, &g);
        }
        for (xi, ti) in x.iter().zip(target.iter()) {
            assert!((xi - ti).abs() < 1e-3, "{xi} vs {ti}");
        }
    }

    #[test]
    fn first_step_size_is_lr() {
        // With bias correction, |Δx| of the first step ≈ lr regardless of
        // gradient scale.
        let mut x = vec![0.0];
        let mut opt = Adam::new(1, 0.1);
        opt.step(&mut x, &[1234.5]);
        assert!((x[0].abs() - 0.1).abs() < 1e-6);
    }
}
