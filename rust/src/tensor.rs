//! Minimal batched dense storage used throughout the native solver.
//!
//! The solver state is a `(batch, dim)` matrix of `f64`. We deliberately do
//! not pull in a tensor library: the native engine's entire point (mirroring
//! torchode's "minimize the number of kernels launched") is that the hot
//! loop is a handful of fused, allocation-free passes over flat memory.

/// Memory layout of the solver workspace (`SolveOptions::layout`,
/// config key `layout`, CLI `--layout`).
///
/// - [`Layout::RowMajor`]: state is `(batch, dim)` row-major — each
///   instance's components are contiguous. The default; every per-row
///   pass (controller, dense output, compaction gathers) works on
///   contiguous rows, and the lane-blocked kernels vectorize across
///   `dim`.
/// - [`Layout::DimMajor`]: the stage-kernel arithmetic additionally runs
///   over a dim-major (SoA) mirror of the workspace ([`LaneStore`]),
///   where component `d` of every row is contiguous and the kernels
///   vectorize across the *batch* — the layout of torchode's stacked
///   GPU tensors. State is transposed into the mirror at the attempt
///   boundary and results are transposed back, because the dynamics API
///   (`OdeSystem::f_inst`) is row-oriented. Results are
///   **bitwise-identical** in both layouts (`tests/kernel_parity.rs`);
///   only the wall time differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// `(batch, dim)` row-major (the default).
    RowMajor,
    /// Dim-major (SoA) stage-kernel mirror; opt-in experiment.
    DimMajor,
}

impl Layout {
    /// Parse a layout as spelled on the CLI and in configs:
    /// `row_major` / `row-major` or `dim_major` / `dim-major`.
    pub fn parse(s: &str) -> Option<Layout> {
        Some(match s.to_ascii_lowercase().as_str() {
            "row_major" | "row-major" | "rowmajor" => Layout::RowMajor,
            "dim_major" | "dim-major" | "dimmajor" => Layout::DimMajor,
            _ => return None,
        })
    }

    /// The CLI/config spelling of this layout.
    pub fn name(&self) -> &'static str {
        match self {
            Layout::RowMajor => "row_major",
            Layout::DimMajor => "dim_major",
        }
    }

    /// The process-wide default layout: the `RODE_LAYOUT` environment
    /// variable if set to a valid spelling, else [`Layout::RowMajor`].
    /// Read once and cached — this is how CI runs the whole test suite
    /// in both layouts without touching every call site.
    pub fn default_from_env() -> Layout {
        static CACHED: std::sync::OnceLock<Layout> = std::sync::OnceLock::new();
        *CACHED.get_or_init(|| {
            std::env::var("RODE_LAYOUT")
                .ok()
                .and_then(|s| Layout::parse(&s))
                .unwrap_or(Layout::RowMajor)
        })
    }
}

/// A `(batch, dim)` row-major matrix of `f64`.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchVec {
    data: Vec<f64>,
    batch: usize,
    dim: usize,
}

impl BatchVec {
    /// Zero-filled `(batch, dim)` matrix.
    pub fn zeros(batch: usize, dim: usize) -> Self {
        Self { data: vec![0.0; batch * dim], batch, dim }
    }

    /// Build from a flat row-major buffer. Panics if `data.len() != batch*dim`.
    pub fn from_flat(data: Vec<f64>, batch: usize, dim: usize) -> Self {
        assert_eq!(data.len(), batch * dim, "flat buffer size mismatch");
        Self { data, batch, dim }
    }

    /// Build from per-instance rows; all rows must share a length.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty(), "need at least one row");
        let dim = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * dim);
        for r in rows {
            assert_eq!(r.len(), dim, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { data, batch: rows.len(), dim }
    }

    /// Broadcast a single state to `batch` identical rows.
    pub fn broadcast(row: &[f64], batch: usize) -> Self {
        let mut data = Vec::with_capacity(batch * row.len());
        for _ in 0..batch {
            data.extend_from_slice(row);
        }
        Self { data, batch, dim: row.len() }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// The whole buffer, row-major.
    #[inline]
    pub fn flat(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn flat_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Copy of the contiguous row range `[lo, hi)` as its own matrix —
    /// the shard boundary of the exec layer.
    pub fn rows_range(&self, lo: usize, hi: usize) -> BatchVec {
        assert!(
            lo <= hi && hi <= self.batch,
            "row range {lo}..{hi} out of bounds for batch {}",
            self.batch
        );
        BatchVec::from_flat(self.data[lo * self.dim..hi * self.dim].to_vec(), hi - lo, self.dim)
    }

    /// Copy another matrix of identical shape into `self` (no allocation).
    pub fn copy_from(&mut self, other: &BatchVec) {
        debug_assert_eq!(self.batch, other.batch);
        debug_assert_eq!(self.dim, other.dim);
        self.data.copy_from_slice(&other.data);
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: f64) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Max absolute element (useful in tests).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }
}

/// The dim-major (SoA) mirror of a `(batch, dim)` row-major matrix:
/// lane `d` holds component `d` of every row, contiguously across the
/// batch. This is the storage behind [`Layout::DimMajor`] — the stage
/// kernels iterate lanes (vectorizing across rows, with a per-row `dt`)
/// instead of rows.
///
/// Lanes are allocated at full batch capacity once; solves that compact
/// their state simply use a shorter prefix of every lane, which is why
/// the packed active set's dense prefix makes the lane passes fully
/// contiguous. Loads/stores are plain element copies, so round-tripping
/// through a `LaneStore` is bitwise-exact.
#[derive(Debug, Clone)]
pub struct LaneStore {
    /// Flat `(dim, batch)` storage: lane `d` is `data[d*batch .. d*batch+batch]`.
    data: Vec<f64>,
    batch: usize,
    dim: usize,
}

impl LaneStore {
    /// Zero-filled lane store with `dim` lanes of capacity `batch`.
    pub fn new(batch: usize, dim: usize) -> Self {
        Self { data: vec![0.0; batch * dim], batch, dim }
    }

    /// Number of lanes (the row-major `dim`).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Lane capacity (the row-major `batch`).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Lane `d`, full capacity; callers slice the live prefix.
    #[inline]
    pub fn lane(&self, d: usize) -> &[f64] {
        &self.data[d * self.batch..(d + 1) * self.batch]
    }

    /// Lane `d`, mutable.
    #[inline]
    pub fn lane_mut(&mut self, d: usize) -> &mut [f64] {
        &mut self.data[d * self.batch..(d + 1) * self.batch]
    }

    /// Transpose in: fill the first `rows` entries of every lane from a
    /// row-major flat buffer (`src[r*dim + d]`, at least `rows * dim`
    /// long). No allocation. Panics (release builds included) when
    /// `rows` exceeds the lane capacity — an oversized prefix would
    /// otherwise silently write into neighboring lanes.
    pub fn load(&mut self, src: &[f64], rows: usize) {
        assert!(rows <= self.batch, "lane prefix {rows} exceeds capacity {}", self.batch);
        for r in 0..rows {
            let row = &src[r * self.dim..(r + 1) * self.dim];
            for (d, &v) in row.iter().enumerate() {
                self.data[d * self.batch + r] = v;
            }
        }
    }

    /// Transpose out: write the first `rows` entries of every lane into
    /// a row-major flat buffer. No allocation; same hard capacity check
    /// as [`LaneStore::load`].
    pub fn store_rows(&self, dst: &mut [f64], rows: usize) {
        assert!(rows <= self.batch, "lane prefix {rows} exceeds capacity {}", self.batch);
        for r in 0..rows {
            let row = &mut dst[r * self.dim..(r + 1) * self.dim];
            for (d, v) in row.iter_mut().enumerate() {
                *v = self.data[d * self.batch + r];
            }
        }
    }

    /// Transpose out a scattered subset: write only the listed rows
    /// (indices into the lane prefix) into the row-major buffer, leaving
    /// every other row untouched — how the active-set attempt writes
    /// back live slots without disturbing keep-alive rows. Out-of-range
    /// indices panic (release builds included) — they would otherwise
    /// silently read the next lane's storage.
    pub fn store_indexed(&self, dst: &mut [f64], rows: &[usize]) {
        for &r in rows {
            assert!(r < self.batch, "lane index {r} exceeds capacity {}", self.batch);
            let row = &mut dst[r * self.dim..(r + 1) * self.dim];
            for (d, v) in row.iter_mut().enumerate() {
                *v = self.data[d * self.batch + r];
            }
        }
    }
}

/// Elementwise `out = a + s * b` over flat slices (single fused pass —
/// the native analogue of torchode's `addcmul` usage).
#[inline]
pub fn axpy(out: &mut [f64], a: &[f64], s: f64, b: &[f64]) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    for i in 0..out.len() {
        out[i] = a[i] + s * b[i];
    }
}

/// In-place `y += s * x`.
#[inline]
pub fn axpy_inplace(y: &mut [f64], s: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for i in 0..y.len() {
        y[i] += s * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip() {
        let m = BatchVec::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.batch(), 2);
        assert_eq!(m.dim(), 2);
        assert_eq!(m.row(0), &[1.0, 2.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.flat(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn broadcast_repeats_rows() {
        let m = BatchVec::broadcast(&[5.0, 6.0], 3);
        for i in 0..3 {
            assert_eq!(m.row(i), &[5.0, 6.0]);
        }
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_panic() {
        BatchVec::from_rows(&[vec![1.0], vec![2.0, 3.0]]);
    }

    #[test]
    fn axpy_fused() {
        let a = [1.0, 2.0];
        let b = [10.0, 20.0];
        let mut out = [0.0; 2];
        axpy(&mut out, &a, 0.5, &b);
        assert_eq!(out, [6.0, 12.0]);
        let mut y = [1.0, 1.0];
        axpy_inplace(&mut y, 2.0, &b);
        assert_eq!(y, [21.0, 41.0]);
    }

    #[test]
    fn max_abs_works() {
        let m = BatchVec::from_rows(&[vec![-3.0, 2.0]]);
        assert_eq!(m.max_abs(), 3.0);
    }

    #[test]
    fn layout_parse_roundtrip() {
        for l in [Layout::RowMajor, Layout::DimMajor] {
            assert_eq!(Layout::parse(l.name()), Some(l));
        }
        assert_eq!(Layout::parse("dim-major"), Some(Layout::DimMajor));
        assert_eq!(Layout::parse("ROW_MAJOR"), Some(Layout::RowMajor));
        assert_eq!(Layout::parse("column"), None);
        // The env default is a valid layout whatever the environment.
        let _ = Layout::default_from_env();
    }

    #[test]
    fn lane_store_roundtrip() {
        // (batch=3, dim=2) rows -> lanes -> rows is exact.
        let src = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut ls = LaneStore::new(3, 2);
        ls.load(&src, 3);
        assert_eq!(ls.lane(0), &[1.0, 3.0, 5.0]);
        assert_eq!(ls.lane(1), &[2.0, 4.0, 6.0]);
        let mut dst = [0.0; 6];
        ls.store_rows(&mut dst, 3);
        assert_eq!(dst, src);
        // Prefix loads leave the lane tail alone.
        let mut ls = LaneStore::new(3, 2);
        ls.lane_mut(0)[2] = 99.0;
        ls.load(&src, 2);
        assert_eq!(ls.lane(0), &[1.0, 3.0, 99.0]);
    }

    #[test]
    fn lane_store_indexed_store_is_selective() {
        let src = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut ls = LaneStore::new(3, 2);
        ls.load(&src, 3);
        let mut dst = [0.0; 6];
        ls.store_indexed(&mut dst, &[0, 2]);
        assert_eq!(dst, [1.0, 2.0, 0.0, 0.0, 5.0, 6.0]);
    }
}
