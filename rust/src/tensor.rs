//! Minimal batched dense storage used throughout the native solver.
//!
//! The solver state is a `(batch, dim)` matrix of `f64`. We deliberately do
//! not pull in a tensor library: the native engine's entire point (mirroring
//! torchode's "minimize the number of kernels launched") is that the hot
//! loop is a handful of fused, allocation-free passes over flat memory.

/// A `(batch, dim)` row-major matrix of `f64`.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchVec {
    data: Vec<f64>,
    batch: usize,
    dim: usize,
}

impl BatchVec {
    /// Zero-filled `(batch, dim)` matrix.
    pub fn zeros(batch: usize, dim: usize) -> Self {
        Self { data: vec![0.0; batch * dim], batch, dim }
    }

    /// Build from a flat row-major buffer. Panics if `data.len() != batch*dim`.
    pub fn from_flat(data: Vec<f64>, batch: usize, dim: usize) -> Self {
        assert_eq!(data.len(), batch * dim, "flat buffer size mismatch");
        Self { data, batch, dim }
    }

    /// Build from per-instance rows; all rows must share a length.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty(), "need at least one row");
        let dim = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * dim);
        for r in rows {
            assert_eq!(r.len(), dim, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { data, batch: rows.len(), dim }
    }

    /// Broadcast a single state to `batch` identical rows.
    pub fn broadcast(row: &[f64], batch: usize) -> Self {
        let mut data = Vec::with_capacity(batch * row.len());
        for _ in 0..batch {
            data.extend_from_slice(row);
        }
        Self { data, batch, dim: row.len() }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// The whole buffer, row-major.
    #[inline]
    pub fn flat(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn flat_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Copy of the contiguous row range `[lo, hi)` as its own matrix —
    /// the shard boundary of the exec layer.
    pub fn rows_range(&self, lo: usize, hi: usize) -> BatchVec {
        assert!(
            lo <= hi && hi <= self.batch,
            "row range {lo}..{hi} out of bounds for batch {}",
            self.batch
        );
        BatchVec::from_flat(self.data[lo * self.dim..hi * self.dim].to_vec(), hi - lo, self.dim)
    }

    /// Copy another matrix of identical shape into `self` (no allocation).
    pub fn copy_from(&mut self, other: &BatchVec) {
        debug_assert_eq!(self.batch, other.batch);
        debug_assert_eq!(self.dim, other.dim);
        self.data.copy_from_slice(&other.data);
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: f64) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Max absolute element (useful in tests).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }
}

/// Elementwise `out = a + s * b` over flat slices (single fused pass —
/// the native analogue of torchode's `addcmul` usage).
#[inline]
pub fn axpy(out: &mut [f64], a: &[f64], s: f64, b: &[f64]) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    for i in 0..out.len() {
        out[i] = a[i] + s * b[i];
    }
}

/// In-place `y += s * x`.
#[inline]
pub fn axpy_inplace(y: &mut [f64], s: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for i in 0..y.len() {
        y[i] += s * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip() {
        let m = BatchVec::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.batch(), 2);
        assert_eq!(m.dim(), 2);
        assert_eq!(m.row(0), &[1.0, 2.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.flat(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn broadcast_repeats_rows() {
        let m = BatchVec::broadcast(&[5.0, 6.0], 3);
        for i in 0..3 {
            assert_eq!(m.row(i), &[5.0, 6.0]);
        }
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_panic() {
        BatchVec::from_rows(&[vec![1.0], vec![2.0, 3.0]]);
    }

    #[test]
    fn axpy_fused() {
        let a = [1.0, 2.0];
        let b = [10.0, 20.0];
        let mut out = [0.0; 2];
        axpy(&mut out, &a, 0.5, &b);
        assert_eq!(out, [6.0, 12.0]);
        let mut y = [1.0, 1.0];
        axpy_inplace(&mut y, 2.0, &b);
        assert_eq!(y, [21.0, 41.0]);
    }

    #[test]
    fn max_abs_works() {
        let m = BatchVec::from_rows(&[vec![-3.0, 2.0]]);
        assert_eq!(m.max_abs(), 3.0);
    }
}
