//! `rode tables <which>` — regenerate the paper's tables and figures.
//!
//! Writes markdown + CSV into `results/` and prints the tables. Absolute
//! times are testbed-specific; the comparison targets are the ratios (see
//! EXPERIMENTS.md).

use anyhow::Result;
use rode::experiments::{
    cnf_table5, fen_table4, pid_fig2, sec41_steps, vdp_table3, CnfT5Config, FenT4Config,
    PidFig2Config, VdpT3Config,
};
use std::collections::HashMap;
use std::fs;
use std::io::Write;

fn out(name: &str, content: &str) -> Result<()> {
    fs::create_dir_all("results")?;
    fs::write(format!("results/{name}"), content)?;
    println!("{content}");
    println!("→ results/{name}\n");
    Ok(())
}

fn t3(quick: bool) -> Result<()> {
    let cfg = VdpT3Config {
        reps: if quick { 3 } else { 10 },
        warmup: if quick { 1 } else { 3 },
        ..Default::default()
    };
    println!(
        "Table 2/3 — VdP loop time (batch {}, μ = {}, {} eval points, dopri5, tol 1e-5)\n",
        cfg.batch, cfg.mu, cfg.n_eval
    );
    let rows = vdp_table3(&cfg);
    let mut md = String::from(
        "### Table 3 — VdP benchmark (loop time incl. model, ms/step)\n\n\
         | engine | loop time (ms/step) | total (ms) | steps | launches/step | sim GPU loop (ms/step) | sim speedup vs naive |\n\
         |---|---|---|---|---|---|---|\n",
    );
    use rode::experiments::SIM_LAUNCH_MS;
    let naive_sim = rows[0].launches_per_step * SIM_LAUNCH_MS;
    for r in &rows {
        let sim = r.launches_per_step * SIM_LAUNCH_MS;
        let (sim_s, speedup_s) = if r.launches_per_step < 1.0 {
            // Whole loop compiled: one dispatch per *solve* — per-step
            // dispatch cost vanishes and compute becomes the bound.
            ("≈0 (1/solve)".to_string(), "dispatch-free".to_string())
        } else {
            (format!("{sim:.3}"), format!("×{:.1}", naive_sim / sim))
        };
        md.push_str(&format!(
            "| {} | {} | {} | {} | {:.1} | {} | {} |\n",
            r.engine,
            r.loop_time_ms.format_ms(),
            r.total_ms.format_ms(),
            r.steps,
            r.launches_per_step,
            sim_s,
            speedup_s
        ));
    }
    md.push_str(
        "\nThe *sim GPU loop* column applies the launch-overhead cost model \
         (20 µs per device dispatch, EXPERIMENTS.md §T3) to the measured \
         dispatch counts — the regime the paper's GPU numbers live in; the \
         measured CPU column shows the same engines when dispatch is free.\n",
    );
    out("table3.md", &md)
}

fn t4(quick: bool) -> Result<()> {
    let cfg = FenT4Config {
        train_steps: if quick { 30 } else { 120 },
        reps: if quick { 3 } else { 8 },
        ..Default::default()
    };
    println!(
        "Table 4 — FEN stand-in (batch {}, {} nodes, {} eval points)\n",
        cfg.batch, cfg.n_nodes, cfg.n_eval
    );
    let rows = fen_table4(&cfg);
    let mut md = String::from(
        "### Table 4 — FEN benchmark (forward pass)\n\n\
         | engine | loop time (ms/step) | total/step (ms) | model/step (ms) | steps | MAE |\n\
         |---|---|---|---|---|---|\n",
    );
    for r in &rows {
        md.push_str(&format!(
            "| {} | {} | {} | {} | {:.1} | {:.4} |\n",
            r.engine,
            r.loop_time_ms.format_ms(),
            r.total_per_step_ms.format_ms(),
            r.model_per_step_ms.format_ms(),
            r.steps.mean,
            r.mae
        ));
    }
    out("table4.md", &md)
}

fn t5(quick: bool) -> Result<()> {
    let cfg = CnfT5Config {
        reps: if quick { 2 } else { 5 },
        warmup: if quick { 0 } else { 1 },
        ..Default::default()
    };
    println!(
        "Table 5 — CNF stand-in (batch {}, d = {}, hidden {:?})\n",
        cfg.batch, cfg.d, cfg.hidden
    );
    let rows = cnf_table5(&cfg);
    let mut md = String::from(
        "### Table 5 — CNF benchmark (adjoint variants)\n\n\
         | variant | fw loop (ms/step) | bw loop (ms/step) | fw steps | bw steps | bw state size |\n\
         |---|---|---|---|---|---|\n",
    );
    for r in &rows {
        md.push_str(&format!(
            "| {} | {} | {} | {:.0} | {:.0} | {} |\n",
            r.variant,
            r.fw_loop_ms.format_ms(),
            r.bw_loop_ms.format_ms(),
            r.fw_steps,
            r.bw_steps,
            r.bw_state_size
        ));
    }
    out("table5.md", &md)
}

fn sec41() -> Result<()> {
    println!("§4.1 — joint-batching step blow-up (VdP μ = 25)\n");
    let pts = sec41_steps(25.0, 1e-5, &[1, 2, 4, 8, 16, 32, 64, 128]);
    let mut md = String::from(
        "### §4.1 — steps(joint) vs steps(parallel), VdP μ=25\n\n\
         | batch | joint steps | parallel max steps | ratio |\n|---|---|---|---|\n",
    );
    let mut csv = String::from("batch,joint_steps,parallel_max_steps,ratio\n");
    for p in &pts {
        md.push_str(&format!(
            "| {} | {} | {} | ×{:.2} |\n",
            p.batch, p.joint_steps, p.parallel_max_steps, p.ratio
        ));
        csv.push_str(&format!(
            "{},{},{},{}\n",
            p.batch, p.joint_steps, p.parallel_max_steps, p.ratio
        ));
    }
    fs::create_dir_all("results")?;
    fs::write("results/sec41_steps.csv", csv)?;
    out("sec41.md", &md)
}

fn fig2() -> Result<()> {
    println!("Figure 2 — PID vs integral controller\n");
    let cfg = PidFig2Config::default();
    let pts = pid_fig2(&cfg);
    let mut md =
        String::from("### Figure 2 — solver steps vs integral controller\n\n| μ | integral |");
    for (name, ..) in &cfg.pid_sets {
        md.push_str(&format!(" {name} |"));
    }
    md.push_str("\n|---|---|");
    for _ in &cfg.pid_sets {
        md.push_str("---|");
    }
    md.push('\n');
    let mut csv = String::from("mu,integral");
    for (name, ..) in &cfg.pid_sets {
        csv.push_str(&format!(",{name}"));
    }
    csv.push('\n');
    for p in &pts {
        md.push_str(&format!("| {} | {} |", p.mu, p.integral_steps));
        csv.push_str(&format!("{},{}", p.mu, p.integral_steps));
        for s in &p.pid_steps {
            let rel = 100.0 * (1.0 - *s as f64 / p.integral_steps as f64);
            md.push_str(&format!(" {s} ({rel:+.1}%) |"));
            csv.push_str(&format!(",{s}"));
        }
        md.push('\n');
        csv.push('\n');
    }
    fs::create_dir_all("results")?;
    fs::write("results/fig2_pid_sweep.csv", csv)?;
    out("fig2.md", &md)
}

fn fig1() -> Result<()> {
    println!("Figure 1 — step-size traces\n");
    use rode::prelude::*;
    let mu = 25.0;
    let batch = 4;
    let t1 = rode::problems::VdP::approx_period(mu);
    let mut rng = rode::nn::Rng64::new(1);
    let y0 = BatchVec::from_rows(
        &(0..batch)
            .map(|_| vec![rng.range(-2.0, 2.0), rng.range(-1.0, 1.0)])
            .collect::<Vec<_>>(),
    );
    let grid = TimeGrid::linspace_shared(batch, 0.0, t1, 200);
    let opts = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(1e-5, 1e-5)
        .with_max_steps(100_000)
        .with_trace();
    let sys = rode::problems::VdP::uniform(batch, mu);
    let par = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    let joint = solve_ivp_joint(&sys, &y0, &grid, &opts);

    fs::create_dir_all("results")?;
    let mut f = fs::File::create("results/fig1_parallel.csv")?;
    writeln!(f, "instance,t,dt")?;
    for (i, trace) in par.trace.as_ref().unwrap().iter().enumerate() {
        for (t, dt) in trace {
            writeln!(f, "{i},{t},{dt}")?;
        }
    }
    let mut f = fs::File::create("results/fig1_joint.csv")?;
    writeln!(f, "instance,t,dt")?;
    for (t, dt) in &joint.trace.as_ref().unwrap()[0] {
        writeln!(f, "shared,{t},{dt}")?;
    }
    let md = format!(
        "### Figure 1 — VdP step sizes (μ=25, one cycle)\n\n\
         parallel steps per instance: {:?}\n\n\
         joint (shared) steps: {} — the joint trace follows the minimum of\n\
         the individual step sizes; CSV traces in results/fig1_*.csv\n",
        par.stats.iter().map(|s| s.n_steps).collect::<Vec<_>>(),
        joint.stats[0].n_steps
    );
    out("fig1.md", &md)
}

pub fn run(args: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let which = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .map(|s| s.as_str())
        .unwrap_or("all");
    let quick = flags.contains_key("quick");
    match which {
        "t3" => t3(quick),
        "t4" => t4(quick),
        "t5" => t5(quick),
        "sec41" => sec41(),
        "fig1" => fig1(),
        "fig2" => fig2(),
        "all" => {
            t3(quick)?;
            t4(quick)?;
            t5(quick)?;
            sec41()?;
            fig1()?;
            fig2()
        }
        other => anyhow::bail!("unknown table '{other}' (t3|t4|t5|sec41|fig1|fig2|all)"),
    }
}
