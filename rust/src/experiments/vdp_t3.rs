//! Table 2 (VdP column) / Table 3: loop time on a batch of Van der Pol
//! problems, and the §4.1 step-count blow-up.
//!
//! Paper setup (App. A): batch of 256 VdP problems, one cycle, μ = 2,
//! atol = rtol = 1e-5, 200 evenly spaced evaluation points, dopri5.
//! "Because evaluating the dynamics is so cheap in this case ... the loop
//! time in Table 3 mostly measures how fast the solver can drive the GPU"
//! — model time is *included* for this benchmark, as in the paper.

use crate::bench::{time_repeats, Summary};
use crate::prelude::*;
use crate::problems::VdP;
use crate::runtime::Runtime;

/// Configuration mirroring the paper's VdP benchmark.
#[derive(Debug, Clone)]
pub struct VdpT3Config {
    pub batch: usize,
    pub mu: f64,
    pub n_eval: usize,
    pub tol: f64,
    pub reps: usize,
    pub warmup: usize,
    /// Artifact directory for the AOT row; `None` skips it.
    pub artifacts: Option<String>,
}

impl Default for VdpT3Config {
    fn default() -> Self {
        Self {
            batch: 256,
            mu: 2.0,
            n_eval: 200,
            tol: 1e-5,
            reps: 10,
            warmup: 3,
            artifacts: Some("artifacts".to_string()),
        }
    }
}

/// One engine row of Table 3.
#[derive(Debug, Clone)]
pub struct VdpT3Row {
    pub engine: &'static str,
    /// Per-step solver+model time, ms (the paper's Table 3 "loop time").
    pub loop_time_ms: Summary,
    /// Total solve wall time, ms.
    pub total_ms: Summary,
    pub steps: u64,
    /// Device dispatches ("kernel launches") per solver step: measured for
    /// the naive engine, analytic for the fused loops, amortized for AOT
    /// (one launch per *solve*). Drives the simulated GPU column.
    pub launches_per_step: f64,
}

/// Per-launch overhead for the simulated-GPU loop-time column, in ms. The
/// paper's testbed (GTX 1080 Ti + Python dispatch) pays 10–40 µs per
/// launched kernel; 20 µs is the model's midpoint (EXPERIMENTS.md §T3).
pub const SIM_LAUNCH_MS: f64 = 0.02;

/// Analytic dispatch count per step of the fused native loops: one per
/// stage eval + one per stage accumulation + combine/err/norm + dense
/// output (2) + state commit. `extra` adds the per-instance bookkeeping
/// passes of the parallel loop.
pub fn fused_launches_per_step(stages: usize, extra: f64) -> f64 {
    2.0 * (stages as f64 - 1.0) + 6.0 + extra
}

fn phase_y0(batch: usize) -> BatchVec {
    let mut rng = crate::nn::Rng64::new(2024);
    BatchVec::from_rows(
        &(0..batch)
            .map(|_| vec![rng.range(-2.0, 2.0), rng.range(-1.0, 1.0)])
            .collect::<Vec<_>>(),
    )
}

/// Run the Table 3 benchmark. Returns one row per engine.
pub fn vdp_table3(cfg: &VdpT3Config) -> Vec<VdpT3Row> {
    let sys = VdP::uniform(cfg.batch, cfg.mu);
    let y0 = phase_y0(cfg.batch);
    let t1 = VdP::approx_period(cfg.mu);
    let grid = TimeGrid::linspace_shared(cfg.batch, 0.0, t1, cfg.n_eval);
    let opts = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(cfg.tol, cfg.tol)
        .with_max_steps(1_000_000);

    let mut rows = Vec::new();
    let mut measure = |engine: &'static str,
                       launches: &mut dyn FnMut(u64) -> f64,
                       f: &mut dyn FnMut() -> u64| {
        let mut steps = 0;
        let samples = time_repeats(cfg.warmup, cfg.reps, || {
            steps = f();
        });
        let per_step: Vec<f64> = samples.iter().map(|ms| ms / steps as f64).collect();
        rows.push(VdpT3Row {
            engine,
            loop_time_ms: Summary::from_samples(&per_step),
            total_ms: Summary::from_samples(&samples),
            steps,
            launches_per_step: launches(steps),
        });
    };

    let stages = MethodId::DOPRI5.tableau().stages;
    measure(
        "naive (torchdiffeq-like)",
        &mut |steps| crate::solver::naive::last_op_count() as f64 / steps as f64,
        &mut || {
            let sol = solve_ivp_naive(&sys, &y0, &grid, &opts);
            assert!(sol.all_success());
            sol.stats[0].n_steps
        },
    );
    measure(
        "joint (TorchDyn-like)",
        &mut |_| fused_launches_per_step(stages, 0.0),
        &mut || {
            let sol = solve_ivp_joint(&sys, &y0, &grid, &opts);
            assert!(sol.all_success());
            sol.stats[0].n_steps
        },
    );
    measure(
        "parallel (torchode)",
        &mut |_| fused_launches_per_step(stages, 2.0),
        &mut || {
            let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
            assert!(sol.all_success());
            // Loop iterations = the max over instances (each iteration
            // advances every unfinished instance at once, like one GPU step).
            sol.max_steps()
        },
    );

    if let Some(dir) = &cfg.artifacts {
        if let Ok(mut rt) = Runtime::open(dir) {
            if let Some(name) = rt.pick_vdp_solve(cfg.batch, cfg.n_eval) {
                let art = rt.load(&name).expect("compile artifact");
                let (b_art, e_art) = (art.meta.batch, art.meta.n_eval);
                let mut y0f = vec![0f32; b_art * 2];
                for i in 0..b_art {
                    let r = y0.row(i % cfg.batch);
                    y0f[i * 2] = r[0] as f32;
                    y0f[i * 2 + 1] = r[1] as f32;
                }
                let muf = vec![cfg.mu as f32; b_art];
                let tef: Vec<f32> = (0..b_art)
                    .flat_map(|_| {
                        (0..e_art).map(move |k| (t1 * k as f64 / (e_art - 1) as f64) as f32)
                    })
                    .collect();
                measure(
                    "aot (torchode-JIT)",
                    // One device dispatch for the whole solve.
                    &mut |steps| 1.0 / steps as f64,
                    &mut || {
                        let out = art.run_f32(&[&y0f, &muf, &tef]).expect("run artifact");
                        out[1].iter().fold(0f32, |m, &s| m.max(s)) as u64
                    },
                );
            }
        }
    }

    rows
}

/// §4.1: steps(joint)/steps(parallel) over batch size.
#[derive(Debug, Clone)]
pub struct Sec41Point {
    pub batch: usize,
    pub joint_steps: u64,
    pub parallel_max_steps: u64,
    pub ratio: f64,
}

pub fn sec41_steps(mu: f64, tol: f64, batches: &[usize]) -> Vec<Sec41Point> {
    let t1 = VdP::approx_period(mu);
    batches
        .iter()
        .map(|&batch| {
            let sys = VdP::uniform(batch, mu);
            let y0 = phase_y0(batch);
            let grid = TimeGrid::linspace_shared(batch, 0.0, t1, 200);
            let opts = SolveOptions::new(MethodId::DOPRI5)
                .with_tols(tol, tol)
                .with_max_steps(1_000_000);
            let joint = solve_ivp_joint(&sys, &y0, &grid, &opts);
            let par = solve_ivp_parallel(&sys, &y0, &grid, &opts);
            assert!(joint.all_success() && par.all_success());
            let joint_steps = joint.stats[0].n_steps;
            let parallel_max_steps = par.stats.iter().map(|s| s.n_steps).max().unwrap();
            Sec41Point {
                batch,
                joint_steps,
                parallel_max_steps,
                ratio: joint_steps as f64 / parallel_max_steps as f64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_small_run_has_expected_shape() {
        let cfg = VdpT3Config {
            batch: 8,
            n_eval: 20,
            reps: 2,
            warmup: 0,
            artifacts: None,
            ..Default::default()
        };
        let rows = vdp_table3(&cfg);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.loop_time_ms.mean > 0.0);
            assert!(r.steps > 0);
        }
        // The implementation-efficiency claim: fused joint beats the
        // naive per-op loop per step.
        let naive = rows[0].loop_time_ms.mean;
        let joint = rows[1].loop_time_ms.mean;
        assert!(joint < naive, "joint {joint} !< naive {naive}");
    }

    #[test]
    fn sec41_ratio_grows() {
        let pts = sec41_steps(25.0, 1e-5, &[1, 8]);
        assert_eq!(pts.len(), 2);
        assert!(pts[1].ratio > pts[0].ratio);
        assert!((pts[0].ratio - 1.0).abs() < 0.05);
    }
}
