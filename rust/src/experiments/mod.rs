//! The paper-reproduction harness: one function per table/figure of the
//! evaluation section. Shared by `rode tables` and `cargo bench`.
//!
//! Engine naming maps to the paper's columns (DESIGN.md §3):
//!
//! | paper column  | rode engine                                    |
//! |---------------|------------------------------------------------|
//! | torchdiffeq   | `naive` (joint semantics, per-op implementation)|
//! | TorchDyn      | `joint` (joint semantics, fused implementation) |
//! | torchode      | `parallel` (per-instance state, fused)          |
//! | torchode-JIT  | `aot` (whole loop compiled via PJRT)            |
//!
//! Absolute times differ from the paper (CPU PJRT vs a GTX 1080 Ti); the
//! reproduction target is the *shape*: who wins, by what factor, where the
//! crossovers are.

mod cnf_t5;
mod fen_t4;
mod pid_fig2;
mod train;
mod vdp_t3;

pub use cnf_t5::{cnf_table5, CnfT5Config, CnfT5Row};
pub use fen_t4::{fen_table4, FenT4Config, FenT4Row};
pub use pid_fig2::{pid_fig2, PidFig2Config, PidFig2Point};
pub use train::{train_cnf, train_fen, AdjointMode, TrainConfig, TrainReport};
pub use vdp_t3::{
    fused_launches_per_step, sec41_steps, vdp_table3, Sec41Point, VdpT3Config, VdpT3Row,
    SIM_LAUNCH_MS,
};

use crate::bench::Summary;

/// A generic measured row: label + per-metric summaries.
#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub metrics: Vec<(String, Summary)>,
}

/// Render rows as a markdown table (one column per metric).
pub fn rows_to_markdown(title: &str, rows: &[Row]) -> String {
    if rows.is_empty() {
        return format!("### {title}\n\n(no data)\n");
    }
    let cols: Vec<&str> = rows[0].metrics.iter().map(|(n, _)| n.as_str()).collect();
    let body: Vec<(String, Vec<String>)> = rows
        .iter()
        .map(|r| {
            (
                r.label.clone(),
                r.metrics.iter().map(|(_, s)| s.format_ms()).collect(),
            )
        })
        .collect();
    crate::bench::markdown_table(title, &cols, &body)
}
