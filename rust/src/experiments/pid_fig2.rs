//! Figure 2 / Appendix C: PID vs integral controller step counts over the
//! Van der Pol damping sweep.

use crate::prelude::*;
use crate::problems::VdP;

#[derive(Debug, Clone)]
pub struct PidFig2Config {
    pub mus: Vec<f64>,
    pub tol: f64,
    /// (label, pcoeff, icoeff, dcoeff) sets; defaults from diffrax docs.
    pub pid_sets: Vec<(String, f64, f64, f64)>,
}

impl Default for PidFig2Config {
    fn default() -> Self {
        Self {
            mus: (0..=25).map(|k| 2.0 * k as f64).collect(),
            tol: 1e-5,
            pid_sets: vec![
                ("0.4/0.3/0".into(), 0.4, 0.3, 0.0),
                ("0.3/0.3/0".into(), 0.3, 0.3, 0.0),
                ("0.2/0.4/0".into(), 0.2, 0.4, 0.0),
                ("H211PI".into(), 1.0 / 6.0, 1.0 / 6.0, 0.0),
                ("H312PID".into(), 1.0 / 18.0, 1.0 / 9.0, 1.0 / 18.0),
            ],
        }
    }
}

#[derive(Debug, Clone)]
pub struct PidFig2Point {
    pub mu: f64,
    pub integral_steps: u64,
    /// Steps per PID set, aligned with `cfg.pid_sets`.
    pub pid_steps: Vec<u64>,
}

fn steps_for(mu: f64, tol: f64, controller: Controller) -> u64 {
    let sys = VdP::uniform(1, mu);
    let y0 = crate::tensor::BatchVec::from_rows(&[vec![2.0, 0.0]]);
    let t1 = VdP::approx_period(mu.max(0.1));
    let grid = TimeGrid::linspace_shared(1, 0.0, t1, 100);
    let opts = SolveOptions::new(MethodId::DOPRI5)
        .with_tols(tol, tol)
        .with_controller(controller)
        .with_max_steps(1_000_000);
    let sol = solve_ivp_parallel(&sys, &y0, &grid, &opts);
    assert!(sol.all_success(), "mu={mu}");
    sol.stats[0].n_steps
}

pub fn pid_fig2(cfg: &PidFig2Config) -> Vec<PidFig2Point> {
    cfg.mus
        .iter()
        .map(|&mu| PidFig2Point {
            mu,
            integral_steps: steps_for(mu, cfg.tol, Controller::integral()),
            pid_steps: cfg
                .pid_sets
                .iter()
                .map(|&(_, p, i, d)| steps_for(mu, cfg.tol, Controller::pid(p, i, d)))
                .collect(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_tradeoff_shape() {
        let cfg = PidFig2Config {
            mus: vec![5.0, 40.0],
            tol: 1e-5,
            pid_sets: vec![("0.2/0.4/0".into(), 0.2, 0.4, 0.0)],
        };
        let pts = pid_fig2(&cfg);
        assert_eq!(pts.len(), 2);
        // At high stiffness the PID controller saves steps (App. C: 3–5%).
        let hi = &pts[1];
        assert!(
            (hi.pid_steps[0] as f64) < hi.integral_steps as f64 * 1.02,
            "PID should not be much worse at high mu"
        );
        // Step counts grow with stiffness.
        assert!(pts[1].integral_steps > pts[0].integral_steps);
    }
}
