//! Training workloads: CNF and FEN promoted from timing demos to
//! first-class batched training runs with a selectable adjoint mode.
//!
//! Three ways to get `∂L/∂θ` through the solve, all producing gradients
//! that agree with finite differences (`tests/adjoint_gradients.rs`):
//!
//! - [`AdjointMode::FixedTape`] — discretize-then-optimize on a fixed
//!   `n_rk`-step RK grid ([`rk_forward_tape`] / [`rk_backward`]): exact
//!   gradient of the discrete map, memory O(steps · stages · batch · f).
//! - [`AdjointMode::AdaptiveTape`] — the forward solve picks its own
//!   steps, the recorded per-row step trace is replayed into a tape and
//!   differentiated exactly ([`rk_forward_tape_adaptive`] /
//!   [`rk_backward_adaptive`]): adaptive accuracy, still O(steps) memory.
//! - [`AdjointMode::Backsolve`] — the continuous backsolve adjoint
//!   ([`backsolve_adjoint_parallel`]): O(checkpoints) memory regardless
//!   of how many steps the forward solve took, at the price of a
//!   reversal-error-controlled (not exact-discrete) gradient.
//!
//! The CNF workload trains a continuous normalizing flow on a two-mode
//! mixture (negative log-likelihood under a standard-normal base, the
//! trace coordinate carrying the log-determinant). The FEN workload
//! trains a graph network to imitate an advection–diffusion teacher on a
//! random geometric mesh (terminal-state MSE). Both are the models the
//! Table 4/5 benchmarks measure; here they actually optimize.

use crate::nn::{Adam, Parameterized, Rng64};
use crate::prelude::*;
use crate::problems::{CnfDynamics, FenDynamics, Mesh};
use crate::solver::{
    backsolve_adjoint_parallel, rk_backward, rk_backward_adaptive, rk_forward_tape,
    rk_forward_tape_adaptive, AdjointOptions,
};
use std::time::Instant;

/// How gradients flow backwards through the ODE solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdjointMode {
    /// Fixed-step discretize-then-optimize (exact discrete gradient).
    FixedTape,
    /// Adaptive-step discretize-then-optimize via trace replay.
    AdaptiveTape,
    /// Continuous backsolve adjoint with checkpointed state re-solve.
    Backsolve,
}

impl AdjointMode {
    /// Parse a CLI spelling (`fixed`, `tape`/`adaptive`, `backsolve`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fixed" | "fixed-tape" => Some(Self::FixedTape),
            "tape" | "adaptive" | "adaptive-tape" => Some(Self::AdaptiveTape),
            "backsolve" | "adjoint" => Some(Self::Backsolve),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::FixedTape => "fixed-tape",
            Self::AdaptiveTape => "adaptive-tape",
            Self::Backsolve => "backsolve",
        }
    }
}

/// Configuration shared by both training workloads.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Optimizer steps to run.
    pub steps: usize,
    pub batch: usize,
    /// Hidden layer widths (the FEN MLP uses `hidden[0]`).
    pub hidden: Vec<usize>,
    pub lr: f64,
    /// Integration horizon `[0, t1]`.
    pub t1: f64,
    pub mode: AdjointMode,
    /// Backsolve segments (only read by [`AdjointMode::Backsolve`]).
    pub checkpoints: usize,
    /// Fixed-tape substeps (only read by [`AdjointMode::FixedTape`]).
    pub n_rk: usize,
    /// Mesh size for the FEN workload.
    pub n_nodes: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 20,
            batch: 8,
            hidden: vec![16],
            lr: 1e-2,
            t1: 1.0,
            mode: AdjointMode::FixedTape,
            checkpoints: 1,
            n_rk: 12,
            n_nodes: 12,
            seed: 7,
        }
    }
}

/// What a training run produced.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub mode: AdjointMode,
    /// Loss evaluated at the start of each optimizer step.
    pub losses: Vec<f64>,
    /// Loss after the final update.
    pub final_loss: f64,
    /// Peak tape size across steps (0 for the backsolve mode — that is
    /// the point of it).
    pub tape_bytes: usize,
    pub wall_ms: f64,
}

struct GradStep {
    loss: f64,
    grad: Vec<f64>,
    tape_bytes: usize,
}

/// One forward + backward pass under `cfg.mode`. `loss_and_seed` maps
/// the terminal state to the scalar loss and fills `∂L/∂y(t1)`.
fn grad_step(
    sys: &dyn OdeSystem,
    y0: &BatchVec,
    cfg: &TrainConfig,
    loss_and_seed: &dyn Fn(&BatchVec, &mut BatchVec) -> f64,
) -> GradStep {
    let b = y0.batch();
    let f = y0.dim();
    let mut dl = BatchVec::zeros(b, f);
    match cfg.mode {
        AdjointMode::FixedTape => {
            let dt = cfg.t1 / cfg.n_rk as f64;
            let tape = rk_forward_tape(sys, y0, 0.0, dt, cfg.n_rk, MethodId::RK4);
            let loss = loss_and_seed(&tape.y_final(), &mut dl);
            let (_, grad) = rk_backward(sys, &tape, &dl);
            GradStep { loss, grad, tape_bytes: tape.tape_bytes() }
        }
        AdjointMode::AdaptiveTape => {
            let opts =
                SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(50_000);
            let (sol, tape) = rk_forward_tape_adaptive(sys, y0, 0.0, cfg.t1, &opts);
            assert!(sol.all_success(), "adaptive-tape forward solve failed");
            let loss = loss_and_seed(&tape.y_final(), &mut dl);
            let (_, grad) = rk_backward_adaptive(sys, &tape, &dl);
            GradStep { loss, grad, tape_bytes: tape.tape_bytes() }
        }
        AdjointMode::Backsolve => {
            let grid = TimeGrid::linspace_shared(b, 0.0, cfg.t1, 2);
            let opts =
                SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(50_000);
            let sol = solve_ivp_parallel(sys, y0, &grid, &opts);
            assert!(sol.all_success(), "backsolve forward solve failed");
            let mut y1 = BatchVec::zeros(b, f);
            for i in 0..b {
                y1.row_mut(i).copy_from_slice(sol.y_final(i));
            }
            let loss = loss_and_seed(&y1, &mut dl);
            let adj = AdjointOptions::new(opts).with_checkpoints(cfg.checkpoints);
            let res = backsolve_adjoint_parallel(
                sys,
                y0,
                &y1,
                &dl,
                &vec![0.0; b],
                &vec![cfg.t1; b],
                &adj,
            );
            GradStep { loss, grad: res.dl_dparams, tape_bytes: 0 }
        }
    }
}

/// Shared optimizer loop: Adam over whatever `grad_step` returns.
fn run_training<M: OdeSystem + Parameterized>(
    model: &mut M,
    y0: &BatchVec,
    cfg: &TrainConfig,
    loss_and_seed: &dyn Fn(&BatchVec, &mut BatchVec) -> f64,
) -> TrainReport {
    let n_params = Parameterized::n_params(model);
    let mut params = vec![0.0; n_params];
    model.params(&mut params);
    let mut opt = Adam::new(n_params, cfg.lr);
    let mut losses = Vec::with_capacity(cfg.steps);
    let mut peak_tape = 0usize;
    let start = Instant::now();
    for _ in 0..cfg.steps {
        let gs = grad_step(&*model, y0, cfg, loss_and_seed);
        losses.push(gs.loss);
        peak_tape = peak_tape.max(gs.tape_bytes);
        opt.step(&mut params, &gs.grad);
        model.set_params(&params);
    }
    // Post-update loss (forward only would do; reuse the same path).
    let final_loss = grad_step(&*model, y0, cfg, loss_and_seed).loss;
    TrainReport {
        mode: cfg.mode,
        losses,
        final_loss,
        tape_bytes: peak_tape,
        wall_ms: start.elapsed().as_secs_f64() * 1e3,
    }
}

/// Train a continuous normalizing flow on a two-mode mixture.
///
/// State is `[x (2), ℓ (1)]` with `ℓ` the accumulated log-determinant;
/// the loss is the mean negative log-likelihood under a standard-normal
/// base (up to the additive constant): `L = mean_i(½|x_i(T)|² + ℓ_i(T))`.
pub fn train_cnf(cfg: &TrainConfig) -> TrainReport {
    let d = 2;
    let mut rng = Rng64::new(cfg.seed);
    let mut model = CnfDynamics::new(d, &cfg.hidden, &mut rng);
    let f = d + 1;
    let b = cfg.batch;
    let mut y0 = BatchVec::zeros(b, f);
    for i in 0..b {
        let c = if rng.uniform() < 0.5 { -1.5 } else { 1.5 };
        y0.row_mut(i)[0] = c + 0.4 * rng.normal();
        y0.row_mut(i)[1] = 0.4 * rng.normal();
    }
    let loss_and_seed = move |yf: &BatchVec, dl: &mut BatchVec| -> f64 {
        let mut loss = 0.0;
        for i in 0..b {
            let row = yf.row(i);
            let out = dl.row_mut(i);
            for k in 0..d {
                loss += 0.5 * row[k] * row[k];
                out[k] = row[k] / b as f64;
            }
            loss += row[d];
            out[d] = 1.0 / b as f64;
        }
        loss / b as f64
    };
    run_training(&mut model, &y0, cfg, &loss_and_seed)
}

/// Train a FEN-style graph network to imitate an advection–diffusion
/// teacher: terminal-state MSE against the teacher's reference solve.
pub fn train_fen(cfg: &TrainConfig) -> TrainReport {
    let mut rng = Rng64::new(cfg.seed);
    let mesh = Mesh::random_geometric(cfg.n_nodes, 0.35, &mut rng);
    let teacher = FenDynamics::teacher(&mesh, 1, 0.8, 0.3);
    let dim = cfg.n_nodes;
    let b = cfg.batch;
    let y0 = BatchVec::from_rows(
        &(0..b)
            .map(|_| {
                let (cx, cy) = (rng.uniform(), rng.uniform());
                mesh.positions
                    .iter()
                    .map(|p| {
                        let d2 = (p[0] - cx).powi(2) + (p[1] - cy).powi(2);
                        2.0 * (-4.0 * d2).exp() + 0.3 * rng.normal()
                    })
                    .collect()
            })
            .collect::<Vec<_>>(),
    );
    let grid = TimeGrid::linspace_shared(b, 0.0, cfg.t1, 2);
    let opts_ref = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8);
    let truth = solve_ivp_parallel(&teacher, &y0, &grid, &opts_ref);
    assert!(truth.all_success());
    let target = {
        let mut t = BatchVec::zeros(b, dim);
        for i in 0..b {
            t.row_mut(i).copy_from_slice(truth.y_final(i));
        }
        t
    };
    let mut model = FenDynamics::new(mesh.clone(), 1, cfg.hidden[0], &mut rng);
    let loss_and_seed = move |yf: &BatchVec, dl: &mut BatchVec| -> f64 {
        let mut loss = 0.0;
        let n = (b * dim) as f64;
        for i in 0..b {
            let (row, tgt) = (yf.row(i), target.row(i));
            let out = dl.row_mut(i);
            for k in 0..dim {
                let e = row[k] - tgt[k];
                loss += e * e;
                out[k] = 2.0 * e / n;
            }
        }
        loss / n
    };
    run_training(&mut model, &y0, cfg, &loss_and_seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(mode: AdjointMode) -> TrainConfig {
        TrainConfig {
            steps: 8,
            batch: 4,
            hidden: vec![8],
            lr: 2e-2,
            t1: 0.5,
            mode,
            checkpoints: if mode == AdjointMode::Backsolve { 2 } else { 1 },
            n_rk: 8,
            n_nodes: 8,
            seed: 11,
        }
    }

    #[test]
    fn cnf_loss_decreases_all_modes() {
        for mode in [AdjointMode::FixedTape, AdjointMode::AdaptiveTape, AdjointMode::Backsolve] {
            let rep = train_cnf(&tiny(mode));
            assert_eq!(rep.losses.len(), 8);
            assert!(rep.losses.iter().all(|l| l.is_finite()), "{mode:?}: {:?}", rep.losses);
            assert!(
                rep.final_loss < rep.losses[0],
                "{mode:?}: {} !< {}",
                rep.final_loss,
                rep.losses[0]
            );
        }
    }

    #[test]
    fn fen_loss_decreases_all_modes() {
        for mode in [AdjointMode::FixedTape, AdjointMode::AdaptiveTape, AdjointMode::Backsolve] {
            let rep = train_fen(&tiny(mode));
            assert!(rep.losses.iter().all(|l| l.is_finite()), "{mode:?}: {:?}", rep.losses);
            assert!(
                rep.final_loss < rep.losses[0],
                "{mode:?}: {} !< {}",
                rep.final_loss,
                rep.losses[0]
            );
        }
    }

    /// The tape modes record; the backsolve does not — the memory story
    /// the adjointsweep bench quantifies.
    #[test]
    fn tape_bytes_reported_per_mode() {
        let fixed = train_cnf(&TrainConfig { steps: 1, ..tiny(AdjointMode::FixedTape) });
        let adaptive = train_cnf(&TrainConfig { steps: 1, ..tiny(AdjointMode::AdaptiveTape) });
        let backsolve = train_cnf(&TrainConfig { steps: 1, ..tiny(AdjointMode::Backsolve) });
        assert!(fixed.tape_bytes > 0);
        assert!(adaptive.tape_bytes > 0);
        assert_eq!(backsolve.tape_bytes, 0);
    }

    /// All three modes descend the same objective: first-step losses are
    /// identical up to solver accuracy (same init, same forward ODE).
    #[test]
    fn modes_agree_on_initial_loss() {
        let a = train_cnf(&TrainConfig { steps: 1, ..tiny(AdjointMode::FixedTape) });
        let b = train_cnf(&TrainConfig { steps: 1, ..tiny(AdjointMode::AdaptiveTape) });
        let c = train_cnf(&TrainConfig { steps: 1, ..tiny(AdjointMode::Backsolve) });
        let l0 = a.losses[0];
        assert!((b.losses[0] - l0).abs() < 1e-3 * (1.0 + l0.abs()), "{} vs {l0}", b.losses[0]);
        assert!((c.losses[0] - l0).abs() < 1e-3 * (1.0 + l0.abs()), "{} vs {l0}", c.losses[0]);
    }
}
