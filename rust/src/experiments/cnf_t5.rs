//! Table 5: the CNF benchmark — forward and backward loop times for the
//! adjoint variants.
//!
//! Paper rows → rode rows:
//!
//! | paper          | forward loop        | backward (adjoint)             |
//! |----------------|---------------------|--------------------------------|
//! | torchode       | parallel            | per-instance, size b(2f+p)     |
//! | torchode-joint | parallel            | joint, size b·2f+p             |
//! | torchdiffeq    | naive (joint sem.)  | joint, size b·2f+p             |
//! | TorchDyn       | joint               | joint, size b·2f+p             |
//!
//! The headline effect: the per-instance backward is more than an order
//! of magnitude slower than the joint backward because the adjoint state
//! carries the parameter block per instance.

use crate::bench::{measure_loop_time, Summary, TimedSystem};
use crate::nn::Rng64;
use crate::prelude::*;
use crate::problems::CnfDynamics;
use crate::solver::{
    adjoint_backward_joint, adjoint_backward_parallel, AdjointOptions,
};
use crate::tensor::BatchVec;

#[derive(Debug, Clone)]
pub struct CnfT5Config {
    pub batch: usize,
    pub d: usize,
    pub hidden: Vec<usize>,
    pub t1: f64,
    pub reps: usize,
    pub warmup: usize,
}

impl Default for CnfT5Config {
    fn default() -> Self {
        Self { batch: 16, d: 2, hidden: vec![32, 32], t1: 1.0, reps: 5, warmup: 1 }
    }
}

#[derive(Debug, Clone)]
pub struct CnfT5Row {
    pub variant: &'static str,
    pub fw_loop_ms: Summary,
    pub bw_loop_ms: Summary,
    pub fw_steps: f64,
    pub bw_steps: f64,
    /// Augmented backward state size (the paper's b(f+p) vs bf+p point).
    pub bw_state_size: usize,
}

pub fn cnf_table5(cfg: &CnfT5Config) -> Vec<CnfT5Row> {
    let mut rng = Rng64::new(3);
    let model = CnfDynamics::new(cfg.d, &cfg.hidden, &mut rng);
    let p = crate::problems::OdeSystem::n_params(&model);
    let f = cfg.d + 1;
    let b = cfg.batch;

    // Data: mixture samples.
    let mut y0 = BatchVec::zeros(b, f);
    for i in 0..b {
        let c = if rng.uniform() < 0.5 { -1.5 } else { 1.5 };
        y0.row_mut(i)[0] = c + 0.4 * rng.normal();
        y0.row_mut(i)[1] = 0.4 * rng.normal();
    }
    let grid = TimeGrid::linspace_shared(b, 0.0, cfg.t1, 2);
    let fw_opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5).with_max_steps(10_000);
    let adj_opts = AdjointOptions::new(
        SolveOptions::new(MethodId::DOPRI5).with_tols(1e-6, 1e-6).with_max_steps(50_000),
    );

    // Shared forward solve to get y1 + seed.
    let sol = solve_ivp_parallel(&model, &y0, &grid, &fw_opts);
    assert!(sol.all_success());
    let mut y1 = BatchVec::zeros(b, f);
    let mut dl = BatchVec::zeros(b, f);
    for i in 0..b {
        y1.row_mut(i).copy_from_slice(sol.y_final(i));
        let row = dl.row_mut(i);
        for d in 0..cfg.d {
            row[d] = sol.y_final(i)[d] / b as f64;
        }
        row[cfg.d] = 1.0 / b as f64;
    }

    let timed = TimedSystem::new(&model);
    let t0s = vec![0.0; b];
    let t1s = vec![cfg.t1; b];

    // Forward measurements per engine.
    let fw = |kind: &str| -> (Summary, f64) {
        let mut loops = Vec::new();
        let mut steps = 0u64;
        for rep in 0..cfg.warmup + cfg.reps {
            let m = measure_loop_time(&timed, || match kind {
                "parallel" => {
                    let s = solve_ivp_parallel(&timed, &y0, &grid, &fw_opts);
                    s.max_steps()
                }
                "joint" => {
                    let s = solve_ivp_joint(&timed, &y0, &grid, &fw_opts);
                    s.stats[0].n_steps
                }
                _ => {
                    let s = solve_ivp_naive(&timed, &y0, &grid, &fw_opts);
                    s.stats[0].n_steps
                }
            });
            if rep >= cfg.warmup {
                loops.push(m.loop_time_ms);
                steps = m.steps;
            }
        }
        (Summary::from_samples(&loops), steps as f64)
    };

    // Backward measurements per adjoint variant.
    let bw = |joint: bool| -> (Summary, f64) {
        let mut loops = Vec::new();
        let mut steps = 0f64;
        for rep in 0..cfg.warmup + cfg.reps {
            let m = measure_loop_time(&timed, || {
                if joint {
                    let r = adjoint_backward_joint(&timed, &y1, &dl, 0.0, cfg.t1, &adj_opts);
                    r.stats.iter().map(|s| s.n_steps).sum()
                } else {
                    let r =
                        adjoint_backward_parallel(&timed, &y1, &dl, &t0s, &t1s, &adj_opts);
                    r.stats.iter().map(|s| s.n_steps).max().unwrap_or(0)
                }
            });
            if rep >= cfg.warmup {
                loops.push(m.loop_time_ms);
                steps = m.steps as f64;
            }
        }
        (Summary::from_samples(&loops), steps)
    };

    let (fw_par, fw_par_steps) = fw("parallel");
    let (fw_joint, fw_joint_steps) = fw("joint");
    let (fw_naive, fw_naive_steps) = fw("naive");
    let (bw_inst, bw_inst_steps) = bw(false);
    let (bw_joint, bw_joint_steps) = bw(true);

    vec![
        CnfT5Row {
            variant: "torchode (parallel fw, per-instance bw)",
            fw_loop_ms: fw_par.clone(),
            bw_loop_ms: bw_inst,
            fw_steps: fw_par_steps,
            bw_steps: bw_inst_steps,
            bw_state_size: b * (2 * f + p),
        },
        CnfT5Row {
            variant: "torchode-joint (parallel fw, joint bw)",
            fw_loop_ms: fw_par,
            bw_loop_ms: bw_joint.clone(),
            fw_steps: fw_par_steps,
            bw_steps: bw_joint_steps,
            bw_state_size: b * 2 * f + p,
        },
        CnfT5Row {
            variant: "torchdiffeq-like (naive fw, joint bw)",
            fw_loop_ms: fw_naive,
            bw_loop_ms: bw_joint.clone(),
            fw_steps: fw_naive_steps,
            bw_steps: bw_joint_steps,
            bw_state_size: b * 2 * f + p,
        },
        CnfT5Row {
            variant: "TorchDyn-like (joint fw, joint bw)",
            fw_loop_ms: fw_joint,
            bw_loop_ms: bw_joint,
            fw_steps: fw_joint_steps,
            bw_steps: bw_joint_steps,
            bw_state_size: b * 2 * f + p,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cnf_table5_smoke() {
        let cfg = CnfT5Config {
            batch: 4,
            d: 2,
            hidden: vec![8],
            t1: 0.5,
            reps: 1,
            warmup: 0,
        };
        let rows = cnf_table5(&cfg);
        assert_eq!(rows.len(), 4);
        // The Table 5 headline: per-instance backward total time exceeds
        // the joint backward (state size b(2f+p) vs b·2f+p).
        let per_inst_total = rows[0].bw_loop_ms.mean * rows[0].bw_steps;
        let joint_total = rows[1].bw_loop_ms.mean * rows[1].bw_steps;
        assert!(
            per_inst_total > joint_total,
            "per-instance {per_inst_total} !> joint {joint_total}"
        );
        assert!(rows[0].bw_state_size > rows[1].bw_state_size);
    }
}
