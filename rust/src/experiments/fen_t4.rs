//! Table 4: the FEN benchmark — loop time, total/model time per step,
//! steps and MAE for a learned graph-ODE on a mesh.
//!
//! Paper setup (App. A): FEN trained on the Black Sea dataset, batch 8,
//! 10 evaluation points, dopri5, forward pass only. Our stand-in trains a
//! graph network on a synthetic advection–diffusion field first (identical
//! code path; see DESIGN.md §3 substitutions) and then measures the
//! forward pass per engine.

use crate::bench::{measure_loop_time, Summary, TimedSystem};
use crate::nn::{Adam, Parameterized, Rng64};
use crate::prelude::*;
use crate::problems::{FenDynamics, Mesh};
use crate::solver::backprop::{rk_backward, rk_forward_tape};

#[derive(Debug, Clone)]
pub struct FenT4Config {
    pub batch: usize,
    pub n_nodes: usize,
    pub n_eval: usize,
    pub hidden: usize,
    pub train_steps: usize,
    pub reps: usize,
    pub warmup: usize,
}

impl Default for FenT4Config {
    fn default() -> Self {
        Self {
            batch: 8,
            n_nodes: 24,
            n_eval: 10,
            hidden: 32,
            train_steps: 120,
            reps: 8,
            warmup: 2,
        }
    }
}

#[derive(Debug, Clone)]
pub struct FenT4Row {
    pub engine: &'static str,
    /// (total − model) / steps, ms — the paper's headline loop time.
    pub loop_time_ms: Summary,
    /// total / steps, ms.
    pub total_per_step_ms: Summary,
    /// model / steps, ms.
    pub model_per_step_ms: Summary,
    pub steps: Summary,
    pub mae: f64,
}

/// Train the stand-in model and measure the Table-4 rows.
pub fn fen_table4(cfg: &FenT4Config) -> Vec<FenT4Row> {
    let mut rng = Rng64::new(5);
    let mesh = Mesh::random_geometric(cfg.n_nodes, 0.35, &mut rng);
    let teacher = FenDynamics::teacher(&mesh, 1, 0.8, 0.3);
    let dim = cfg.n_nodes;
    let horizon = 1.0;

    let make_fields = |rng: &mut Rng64, n: usize| -> BatchVec {
        BatchVec::from_rows(
            &(0..n)
                .map(|_| {
                    let (cx, cy) = (rng.uniform(), rng.uniform());
                    mesh.positions
                        .iter()
                        .map(|p| {
                            let d2 = (p[0] - cx).powi(2) + (p[1] - cy).powi(2);
                            2.0 * (-4.0 * d2).exp() + 0.3 * rng.normal()
                        })
                        .collect()
                })
                .collect::<Vec<_>>(),
        )
    };

    // --- data + quick training (discretize-then-optimize) -------------------
    let y0_train = make_fields(&mut rng, cfg.batch);
    let y0_test = make_fields(&mut rng, cfg.batch);
    let grid = TimeGrid::linspace_shared(cfg.batch, 0.0, horizon, cfg.n_eval);
    let opts_ref = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-8, 1e-8);
    let truth_train = solve_ivp_parallel(&teacher, &y0_train, &grid, &opts_ref);
    let truth_test = solve_ivp_parallel(&teacher, &y0_test, &grid, &opts_ref);

    let mut model = FenDynamics::new(mesh.clone(), 1, cfg.hidden, &mut rng);
    let n_params = Parameterized::n_params(&model);
    let mut params = vec![0.0; n_params];
    model.params(&mut params);
    let mut opt = Adam::new(n_params, 3e-3);
    let n_rk = 12;
    let dt = horizon / n_rk as f64;
    for _ in 0..cfg.train_steps {
        let tape = rk_forward_tape(&model, &y0_train, 0.0, dt, n_rk, MethodId::RK4);
        let yf = tape.y_final();
        let mut seed = BatchVec::zeros(cfg.batch, dim);
        for i in 0..cfg.batch {
            let target = truth_train.y(i, cfg.n_eval - 1);
            for d in 0..dim {
                seed.row_mut(i)[d] =
                    2.0 * (yf.row(i)[d] - target[d]) / (cfg.batch * dim) as f64;
            }
        }
        let (_, grad) = rk_backward(&model, &tape, &seed);
        opt.step(&mut params, &grad);
        model.set_params(&params);
    }

    // --- measurement ----------------------------------------------------------
    let opts = SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5);
    let timed = TimedSystem::new(&model);

    let mae_of = |sol: &Solution| -> f64 {
        let mut mae = 0.0;
        let mut n = 0.0;
        for i in 0..cfg.batch {
            for e in 0..cfg.n_eval {
                for d in 0..dim {
                    mae += (sol.y(i, e)[d] - truth_test.y(i, e)[d]).abs();
                    n += 1.0;
                }
            }
        }
        mae / n
    };

    let mut rows = Vec::new();
    let mut run_engine = |engine: &'static str,
                          f: &mut dyn FnMut(&TimedSystem<'_>) -> (u64, f64)| {
        let mut loops = Vec::new();
        let mut totals = Vec::new();
        let mut models = Vec::new();
        let mut steps = Vec::new();
        let mut mae = 0.0;
        for rep in 0..cfg.warmup + cfg.reps {
            let mut got_steps = 0;
            let m = measure_loop_time(&timed, || {
                let (s, m) = f(&timed);
                got_steps = s;
                mae = m;
                s
            });
            if rep >= cfg.warmup {
                loops.push(m.loop_time_ms);
                totals.push(m.total_ms / got_steps as f64);
                models.push(m.model_ms / got_steps as f64);
                steps.push(got_steps as f64);
            }
        }
        rows.push(FenT4Row {
            engine,
            loop_time_ms: Summary::from_samples(&loops),
            total_per_step_ms: Summary::from_samples(&totals),
            model_per_step_ms: Summary::from_samples(&models),
            steps: Summary::from_samples(&steps),
            mae,
        });
    };

    run_engine("naive (torchdiffeq-like)", &mut |sys| {
        let sol = solve_ivp_naive(sys, &y0_test, &grid, &opts);
        assert!(sol.all_success());
        (sol.stats[0].n_steps, mae_of(&sol))
    });
    run_engine("joint (TorchDyn-like)", &mut |sys| {
        let sol = solve_ivp_joint(sys, &y0_test, &grid, &opts);
        assert!(sol.all_success());
        (sol.stats[0].n_steps, mae_of(&sol))
    });
    run_engine("parallel (torchode)", &mut |sys| {
        let sol = solve_ivp_parallel(sys, &y0_test, &grid, &opts);
        assert!(sol.all_success());
        (sol.max_steps(), mae_of(&sol))
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fen_table4_smoke() {
        let cfg = FenT4Config {
            batch: 2,
            n_nodes: 8,
            n_eval: 5,
            hidden: 8,
            train_steps: 5,
            reps: 1,
            warmup: 0,
        };
        let rows = fen_table4(&cfg);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.loop_time_ms.mean >= 0.0);
            assert!(r.model_per_step_ms.mean > 0.0);
            assert!(r.mae.is_finite());
            assert!(r.steps.mean > 0.0);
        }
        // MAE identical problem => all engines close.
        let maes: Vec<f64> = rows.iter().map(|r| r.mae).collect();
        for w in maes.windows(2) {
            assert!((w[0] - w[1]).abs() < 0.1, "{maes:?}");
        }
    }
}
