//! The solver service: a worker thread owning an engine, fed through a
//! channel, with dynamic batching, per-request response delivery — and
//! fault tolerance.
//!
//! Threads instead of async: the vendored crate set has no tokio, and a
//! single dedicated worker matches the execution model anyway (one PJRT
//! client / one native solve at a time per device).
//!
//! # Failure domains
//!
//! The unit of failure is the **batch**, never the service:
//!
//! - An engine panic is caught ([`std::panic::catch_unwind`]), fails only
//!   that batch's requests with [`ServiceError::WorkerPanic`], and the
//!   engine is discarded and rebuilt from the factory — the worker keeps
//!   serving every other bucket. If the *factory* panics, the worker
//!   degrades to a tombstone loop that fails every request immediately
//!   with [`ServiceError::WorkerUnavailable`] instead of stranding
//!   callers on a channel that never fires.
//! - An engine `Err` fails the batch with [`ServiceError::EngineError`] —
//!   structurally distinct from a genuine solver-level failure such as
//!   [`Status::NonFinite`].
//!
//! # Degraded-mode serving
//!
//! Requests that die of stiffness on an explicit method
//! (`DtUnderflow` / `NonFinite` / `NewtonDiverged`) are re-enqueued once
//! into an implicit-method bucket ([`RetryPolicy`], `trbdf2` by default)
//! via the per-request method routing; the final response records the
//! escalation in [`SolveResponse::escalated_from`]. Admission is bounded:
//! beyond `max_queue` in-flight requests, new submissions are shed with
//! [`ServiceError::Overloaded`] (low-priority traffic first — see
//! [`Priority`]), and a request whose [`SolveRequest::deadline`] passes
//! while it waits is dropped at dispatch time with
//! [`ServiceError::DeadlineExpired`] instead of occupying a batch slot.
//! See `docs/architecture.md` § "Failure domains & degraded-mode serving".

use super::batcher::{Batch, DynamicBatcher};
use super::engine::SolveEngine;
use super::metrics::Metrics;
use super::request::{Priority, ServiceError, SolveRequest, SolveResponse};
use crate::solver::{MethodId, Status};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How often the worker wakes to poll deadlines when the batcher is empty.
const IDLE_POLL: Duration = Duration::from_millis(50);

/// Stiffness-escalation policy: when a request fails on an explicit
/// method with a stiffness-shaped status (`DtUnderflow`, `NonFinite`,
/// `NewtonDiverged`), the service re-enqueues it on `method` — an
/// implicit, L-stable fallback — up to `max_retries` times, instead of
/// returning the failure to the caller. The response records the
/// escalation in [`SolveResponse::escalated_from`]. Failures on implicit
/// methods (or on engines that don't route methods, like AOT) are
/// returned as-is.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// The fallback method; `None` disables escalation entirely.
    pub method: Option<MethodId>,
    /// Re-enqueues allowed per request (1 = the single escalation).
    pub max_retries: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { method: Some(MethodId::TRBDF2), max_retries: 1 }
    }
}

impl RetryPolicy {
    /// No escalation: solver failures go straight back to the caller.
    pub fn disabled() -> Self {
        Self { method: None, max_retries: 0 }
    }
}

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Dynamic-batcher flush size.
    pub max_batch: usize,
    /// Dynamic-batcher flush deadline.
    pub max_wait: Duration,
    /// Bound on admitted-but-unresolved requests; submissions beyond it
    /// are shed with [`ServiceError::Overloaded`] (priority-tiered — see
    /// [`Priority`]). `0` = unbounded (the pre-fault-tolerance behavior).
    pub max_queue: usize,
    /// Stiffness-escalation policy.
    pub retry: RetryPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
            retry: RetryPolicy::default(),
        }
    }
}

/// The in-flight bound for a priority class: `Low` may fill half the
/// queue, `Normal` all but a reserved eighth, `High` everything — so
/// high-priority traffic still gets in when normal traffic has filled
/// the queue. (For `max_queue < 8` the Normal and High limits coincide.)
fn admission_limit(max_queue: usize, p: Priority) -> usize {
    match p {
        Priority::Low => (max_queue / 2).max(1),
        Priority::Normal => (max_queue - max_queue / 8).max(1),
        Priority::High => max_queue.max(1),
    }
}

enum Msg {
    Solve(SolveRequest, Sender<SolveResponse>, Instant),
    Shutdown,
}

/// Handle to a running solver service.
pub struct Coordinator {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    /// Cleared by the worker when it can no longer solve (factory panic)
    /// or has shut down; lets `submit` fail fast without a round-trip.
    alive: Arc<AtomicBool>,
    max_queue: usize,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Spawn the worker. `make_engine` runs *inside* the worker thread so
    /// engines holding non-`Send` resources (PJRT client) work; it is
    /// called again to rebuild the engine after a panic, so it must be
    /// re-invocable (`FnMut`).
    pub fn spawn<F>(cfg: ServiceConfig, make_engine: F) -> Self
    where
        F: FnMut() -> Box<dyn SolveEngine> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let alive = Arc::new(AtomicBool::new(true));
        let max_queue = cfg.max_queue;
        let worker_metrics = metrics.clone();
        let worker_alive = alive.clone();
        let worker = std::thread::Builder::new()
            .name("rode-worker".into())
            .spawn(move || {
                let batcher = DynamicBatcher::new(cfg.max_batch, cfg.max_wait);
                Worker {
                    cfg,
                    make_engine: Box::new(make_engine),
                    engine: None,
                    metrics: worker_metrics,
                    alive: worker_alive,
                    batcher,
                    waiters: Waiters::new(),
                }
                .run(rx)
            })
            .expect("spawn worker");
        Self {
            tx,
            worker: Some(worker),
            metrics,
            alive,
            max_queue,
            next_id: AtomicU64::new(1),
        }
    }

    /// Submit a request; the returned receiver yields exactly one
    /// response. Requests shed at admission, and requests submitted to a
    /// dead worker, receive an immediate [`SolveResponse::failure`] — the
    /// receiver never hangs forever.
    pub fn submit(&self, mut req: SolveRequest) -> Receiver<SolveResponse> {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        // Admission control: a bounded in-flight gauge with priority-
        // tiered limits; shedding happens here, before any buffering.
        if self.max_queue > 0 {
            let limit = admission_limit(self.max_queue, req.priority) as u64;
            let prev = self.metrics.requests_inflight.fetch_add(1, Ordering::AcqRel);
            if prev >= limit {
                self.metrics.requests_inflight.fetch_sub(1, Ordering::AcqRel);
                self.metrics.requests_shed.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(SolveResponse::failure(
                    req.id,
                    ServiceError::Overloaded {
                        inflight: prev as usize,
                        max_queue: self.max_queue,
                    },
                ));
                return rx;
            }
        } else {
            self.metrics.requests_inflight.fetch_add(1, Ordering::AcqRel);
        }
        if !self.alive.load(Ordering::Acquire) {
            // Fast path: the worker is known-dead; don't bother queueing.
            // (The tombstone loop also answers anything that races past
            // this check, so correctness never depends on the flag.)
            self.fail_unqueued(&tx, req.id);
            return rx;
        }
        if let Err(mpsc::SendError(Msg::Solve(req, tx, _))) =
            self.tx.send(Msg::Solve(req, tx, Instant::now()))
        {
            // The worker thread is gone entirely: fail immediately instead
            // of handing back a receiver that never fires.
            self.fail_unqueued(&tx, req.id);
        }
        rx
    }

    fn fail_unqueued(&self, tx: &Sender<SolveResponse>, id: u64) {
        self.metrics.requests_inflight.fetch_sub(1, Ordering::AcqRel);
        self.metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
        let _ = tx.send(SolveResponse::failure(id, ServiceError::WorkerUnavailable));
    }

    /// Convenience: submit and wait. Service-level failures surface as
    /// [`SolveResponse::error`], not as `None` — `None` is reserved for
    /// the (not expected in practice) case of a response channel dropped
    /// without a send.
    pub fn solve_blocking(&self, req: SolveRequest) -> Option<SolveResponse> {
        self.submit(req).recv().ok()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Per-request worker-side state: the response channel plus everything
/// needed for deadlines and retry accounting.
struct Waiter {
    tx: Sender<SolveResponse>,
    t_submit: Instant,
    /// Escalation retries already consumed.
    attempts: u32,
    /// The explicit method this request first failed on, when it was
    /// re-enqueued onto the implicit fallback.
    escalated_from: Option<MethodId>,
}

type Waiters = std::collections::HashMap<u64, Waiter>;

/// The worker thread's state machine. One instance lives for the whole
/// thread; `engine` is `None` only between a panic and the completed
/// rebuild (or permanently, in the tombstone state).
struct Worker {
    cfg: ServiceConfig,
    make_engine: Box<dyn FnMut() -> Box<dyn SolveEngine> + Send>,
    engine: Option<Box<dyn SolveEngine>>,
    metrics: Arc<Metrics>,
    alive: Arc<AtomicBool>,
    batcher: DynamicBatcher,
    waiters: Waiters,
}

impl Worker {
    fn run(mut self, rx: Receiver<Msg>) {
        if !self.rebuild_engine() {
            // The very first engine build panicked: nothing can ever be
            // solved. Serve immediate failures until shutdown.
            return self.tombstone(&rx);
        }
        loop {
            // Wait bounded by the next deadline flush.
            let timeout = self.batcher.next_deadline(Instant::now()).unwrap_or(IDLE_POLL);
            match rx.recv_timeout(timeout) {
                Ok(Msg::Solve(req, tx, t_submit)) => {
                    self.waiters.insert(
                        req.id,
                        Waiter { tx, t_submit, attempts: 0, escalated_from: None },
                    );
                    self.enqueue(req);
                }
                Ok(Msg::Shutdown) => break,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
            for batch in self.batcher.poll_expired(Instant::now()) {
                self.dispatch(batch);
            }
            if self.engine.is_none() {
                // A panic was absorbed but the rebuild also panicked:
                // degrade instead of stranding waiters.
                return self.tombstone(&rx);
            }
        }
        // Drain remaining work — including retries enqueued while
        // draining — before exiting.
        while self.engine.is_some() && self.batcher.pending() > 0 {
            for batch in self.batcher.drain(Instant::now()) {
                self.dispatch(batch);
            }
        }
        let ids: Vec<u64> = self.waiters.keys().copied().collect();
        for id in ids {
            self.respond(SolveResponse::failure(id, ServiceError::ShuttingDown));
        }
        self.alive.store(false, Ordering::Release);
    }

    /// Terminal degraded state: no engine exists and none can be built.
    /// Every waiter and every future submission gets an immediate
    /// `WorkerUnavailable` failure; the thread stays alive to answer
    /// until the coordinator shuts down, so no receiver ever hangs.
    fn tombstone(mut self, rx: &Receiver<Msg>) {
        self.alive.store(false, Ordering::Release);
        // Requests parked in the batcher fail through their waiters.
        let _ = self.batcher.drain(Instant::now());
        let ids: Vec<u64> = self.waiters.keys().copied().collect();
        for id in ids {
            self.respond(SolveResponse::failure(id, ServiceError::WorkerUnavailable));
        }
        loop {
            match rx.recv() {
                Ok(Msg::Solve(req, tx, _)) => {
                    self.metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
                    self.metrics.requests_inflight.fetch_sub(1, Ordering::AcqRel);
                    let _ =
                        tx.send(SolveResponse::failure(req.id, ServiceError::WorkerUnavailable));
                }
                Ok(Msg::Shutdown) | Err(_) => return,
            }
        }
    }

    /// (Re)build the engine from the factory, absorbing a factory panic.
    fn rebuild_engine(&mut self) -> bool {
        match catch_unwind(AssertUnwindSafe(|| (self.make_engine)())) {
            Ok(engine) => {
                self.engine = Some(engine);
                true
            }
            Err(payload) => {
                eprintln!("[rode] engine factory panicked: {}", panic_message(&payload));
                self.metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                self.engine = None;
                false
            }
        }
    }

    fn enqueue(&mut self, req: SolveRequest) {
        if let Some(batch) = self.batcher.push(req, Instant::now()) {
            self.dispatch(batch);
        }
    }

    /// Has this request's deadline passed? (Measured against its original
    /// submission time, so escalation retries share the same budget.)
    fn expired(&self, req: &SolveRequest, now: Instant) -> bool {
        match (req.deadline, self.waiters.get(&req.id)) {
            (Some(d), Some(w)) => now.duration_since(w.t_submit) > d,
            _ => false,
        }
    }

    fn dispatch(&mut self, batch: Batch) {
        // Deadline check at dispatch time: a request that expired while
        // waiting in the batcher never occupies a batch slot.
        let now = Instant::now();
        let Batch { key, requests, oldest_wait } = batch;
        let mut live = Vec::with_capacity(requests.len());
        for r in requests {
            if self.expired(&r, now) {
                self.respond(SolveResponse::failure(r.id, ServiceError::DeadlineExpired));
            } else {
                live.push(r);
            }
        }
        if live.is_empty() {
            return;
        }
        let batch = Batch { key, requests: live, oldest_wait };
        self.metrics.batches_dispatched.fetch_add(1, Ordering::Relaxed);
        self.metrics.batch_size_sum.fetch_add(batch.requests.len() as u64, Ordering::Relaxed);
        let Some(engine) = self.engine.as_mut() else {
            // Only reachable while a dispatch chain is unwinding toward
            // the tombstone state.
            self.fail_batch(&batch, ServiceError::WorkerUnavailable);
            return;
        };
        let name = engine.name();
        match catch_unwind(AssertUnwindSafe(|| engine.solve(&batch))) {
            Ok(Ok(responses)) => self.deliver(&batch, responses),
            Ok(Err(e)) => {
                eprintln!("[rode] batch failed on {name}: {e}");
                self.fail_batch(&batch, ServiceError::EngineError { detail: e.to_string() });
            }
            Err(payload) => {
                // Failure domain boundary: the panic takes down this
                // batch's requests and the engine instance — nothing
                // else. The engine may be in an arbitrary state
                // mid-unwind, so discard it and rebuild before the next
                // batch.
                let detail = panic_message(&payload);
                eprintln!(
                    "[rode] engine {name} panicked on a {}-request batch: {detail}",
                    batch.requests.len()
                );
                self.metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                self.engine = None;
                self.fail_batch(&batch, ServiceError::WorkerPanic { detail });
                self.rebuild_engine();
            }
        }
    }

    fn fail_batch(&mut self, batch: &Batch, err: ServiceError) {
        for r in &batch.requests {
            self.respond(SolveResponse::failure(r.id, err.clone()));
        }
    }

    /// Route each engine response: escalate stiffness failures that the
    /// retry policy covers, deliver everything else.
    fn deliver(&mut self, batch: &Batch, responses: Vec<SolveResponse>) {
        for resp in responses {
            if let Some(target) = self.retry_method_for(&resp) {
                if let Some(orig) = batch.requests.iter().find(|r| r.id == resp.id) {
                    self.escalate(orig.clone(), resp.method, target);
                    continue;
                }
            }
            self.respond(resp);
        }
    }

    /// The fallback method to escalate `resp` onto, if the policy covers
    /// this failure: a stiffness-shaped solver status, on a routable
    /// explicit method, with retry budget left.
    fn retry_method_for(&self, resp: &SolveResponse) -> Option<MethodId> {
        if resp.error.is_some() {
            return None;
        }
        let target = self.cfg.retry.method?;
        let status = resp.status?;
        if !matches!(status, Status::DtUnderflow | Status::NonFinite | Status::NewtonDiverged) {
            return None;
        }
        // Only explicit failures escalate; a response without a resolved
        // method (AOT — its artifacts bake the method in) can't be
        // re-routed at all.
        let current = resp.method?;
        if current.is_implicit() || current == target {
            return None;
        }
        let w = self.waiters.get(&resp.id)?;
        (w.attempts < self.cfg.retry.max_retries).then_some(target)
    }

    /// Re-enqueue a stiffness casualty into the implicit-method bucket.
    fn escalate(&mut self, mut req: SolveRequest, failed_on: Option<MethodId>, target: MethodId) {
        if self.expired(&req, Instant::now()) {
            // The deadline died with the first attempt; don't burn a
            // batch slot on a retry nobody is waiting for.
            self.respond(SolveResponse::failure(req.id, ServiceError::DeadlineExpired));
            return;
        }
        if let Some(w) = self.waiters.get_mut(&req.id) {
            w.attempts += 1;
            w.escalated_from = failed_on;
        }
        self.metrics.requests_retried.fetch_add(1, Ordering::Relaxed);
        req.method = Some(target);
        self.enqueue(req);
    }

    /// Deliver a terminal response: stamp escalation provenance, settle
    /// the metrics taxonomy, release the in-flight slot.
    fn respond(&mut self, mut resp: SolveResponse) {
        let Some(w) = self.waiters.remove(&resp.id) else { return };
        resp.escalated_from = w.escalated_from;
        match &resp.error {
            None => {
                self.metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
                self.metrics.solver_steps_sum.fetch_add(resp.stats.n_steps, Ordering::Relaxed);
                self.metrics.record_latency(w.t_submit.elapsed());
            }
            Some(ServiceError::DeadlineExpired) => {
                self.metrics.requests_deadline_expired.fetch_add(1, Ordering::Relaxed);
            }
            Some(_) => {
                self.metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.metrics.requests_inflight.fetch_sub(1, Ordering::AcqRel);
        let _ = w.tx.send(resp);
    }
}

/// Best-effort panic payload extraction for logs and `ServiceError`.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::coordinator::request::ProblemSpec;

    fn service(max_batch: usize, wait_ms: u64) -> Coordinator {
        Coordinator::spawn(
            ServiceConfig {
                max_batch,
                max_wait: Duration::from_millis(wait_ms),
                ..ServiceConfig::default()
            },
            || Box::new(NativeEngine::default()),
        )
    }

    fn vdp_req(mu: f64) -> SolveRequest {
        SolveRequest::new(
            ProblemSpec::Vdp { mu },
            vec![2.0, 0.0],
            (0..10).map(|k| k as f64 * 0.5).collect(),
        )
    }

    #[test]
    fn single_request_roundtrip() {
        let c = service(8, 1);
        let resp = c.solve_blocking(vdp_req(2.0)).unwrap();
        assert!(resp.is_success());
        assert_eq!(resp.error, None);
        assert_eq!(resp.escalated_from, None);
        assert_eq!(resp.ys.len(), 20);
        assert!(resp.stats.n_steps > 0);
    }

    #[test]
    fn many_requests_all_complete_with_batching() {
        let c = service(4, 1);
        let rxs: Vec<_> = (0..10).map(|i| c.submit(vdp_req(1.0 + i as f64))).collect();
        let mut ok = 0;
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.is_success());
            ok += 1;
        }
        assert_eq!(ok, 10);
        let m = c.metrics();
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 10);
        // All in-flight slots were released.
        assert_eq!(m.requests_inflight.load(Ordering::Relaxed), 0);
        // max_batch 4 over 10 requests => at least 3 batches.
        assert!(m.batches_dispatched.load(Ordering::Relaxed) >= 3);
        assert!(m.mean_batch_size() > 1.0);
    }

    #[test]
    fn heterogeneous_shapes_complete() {
        let c = service(16, 1);
        let mut reqs = Vec::new();
        for i in 0..6 {
            let mut r = vdp_req(2.0);
            if i % 2 == 0 {
                r.t_eval = (0..5).map(|k| k as f64 * 0.3).collect();
            }
            reqs.push(c.submit(r));
        }
        for rx in reqs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.is_success());
        }
    }

    #[test]
    fn shutdown_drains_pending() {
        let c = service(1000, 60_000); // nothing flushes by itself
        let rx = c.submit(vdp_req(1.5));
        drop(c); // shutdown drains the batcher
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.is_success());
    }

    #[test]
    fn per_instance_params_preserved_through_batching() {
        // Two very different μ in one batch must give different step counts
        // (the parallel engine keeps per-instance state).
        let c = service(2, 1);
        let rx1 = c.submit(vdp_req(1.0));
        let rx2 = c.submit(vdp_req(20.0));
        let r1 = rx1.recv_timeout(Duration::from_secs(30)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r2.stats.n_steps > r1.stats.n_steps);
    }

    #[test]
    fn admission_limits_are_tiered() {
        assert_eq!(admission_limit(16, Priority::Low), 8);
        assert_eq!(admission_limit(16, Priority::Normal), 14);
        assert_eq!(admission_limit(16, Priority::High), 16);
        // Tiny queues never degenerate to zero.
        assert_eq!(admission_limit(1, Priority::Low), 1);
        assert_eq!(admission_limit(1, Priority::Normal), 1);
        assert_eq!(admission_limit(1, Priority::High), 1);
        // Below 8, Normal and High coincide.
        assert_eq!(admission_limit(4, Priority::Normal), 4);
        assert_eq!(admission_limit(4, Priority::High), 4);
    }

    #[test]
    fn unbounded_queue_never_sheds() {
        let c = Coordinator::spawn(
            ServiceConfig { max_queue: 0, ..ServiceConfig::default() },
            || Box::new(NativeEngine::default()),
        );
        let rxs: Vec<_> = (0..64).map(|_| c.submit(vdp_req(1.0))).collect();
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().is_success());
        }
        assert_eq!(c.metrics().requests_shed.load(Ordering::Relaxed), 0);
    }
}
