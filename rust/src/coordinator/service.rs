//! The solver service: a supervised fleet of worker threads, each owning
//! an engine, fed through per-worker channels with bucket-affinity
//! routing, dynamic batching, per-request response delivery — and fault
//! tolerance.
//!
//! Threads instead of async: the vendored crate set has no tokio, and
//! dedicated workers match the execution model anyway (one PJRT client /
//! one native solve at a time per engine). One worker per core by
//! default ([`ServiceConfig::workers`]).
//!
//! # Failure domains
//!
//! The unit of failure is the **batch**, then the **worker**, never the
//! service:
//!
//! - An engine panic is caught ([`std::panic::catch_unwind`]), fails only
//!   that batch's requests with [`ServiceError::WorkerPanic`], and the
//!   engine is discarded and rebuilt from the factory (with bounded
//!   exponential backoff after repeated panics) — the worker keeps
//!   serving every other bucket, and sibling workers never notice.
//! - If the *factory* panics, that worker tombstones: it forwards its
//!   parked queue to the surviving workers ("drains onto survivors") and
//!   keeps forwarding anything that still arrives. Routing drops it from
//!   the affinity set, so its buckets remap to healthy peers.
//!   [`ServiceError::WorkerUnavailable`] is returned only when the whole
//!   fleet is tombstoned.
//! - An engine `Err` fails the batch with [`ServiceError::EngineError`] —
//!   structurally distinct from a genuine solver-level failure such as
//!   [`Status::NonFinite`].
//!
//! # Degraded-mode serving
//!
//! Stiff traffic is handled *proactively* when the
//! [`ClassifierPolicy`](super::classifier::ClassifierPolicy) is enabled:
//! a few FD Jacobian–vector power iterations at `(t0, y0)` bound the
//! dominant eigenvalue against the explicit method's stability radius,
//! and predicted-stiff requests are routed to the implicit fallback
//! *before* their first solve (`coordinator/classifier.rs`). The
//! *reactive* path remains as the safety net: requests that die of
//! stiffness on an explicit method (`DtUnderflow` / `NonFinite` /
//! `NewtonDiverged`) are re-enqueued once into an implicit-method bucket
//! ([`RetryPolicy`], `trbdf2` by default), and the final response records
//! the escalation in [`SolveResponse::escalated_from`]. Admission is
//! bounded: beyond `max_queue` in-flight requests, new submissions are
//! shed with [`ServiceError::Overloaded`] (low-priority traffic first —
//! see [`Priority`]), and a request whose [`SolveRequest::deadline`]
//! passes while it waits is dropped at dispatch time with
//! [`ServiceError::DeadlineExpired`] instead of occupying a batch slot.
//! See `docs/architecture.md` § "Fleet supervision & proactive
//! classification".

use super::batcher::{Batch, BucketKey, DynamicBatcher};
use super::classifier::{Classified, Classifier, ClassifierPolicy};
use super::engine::SolveEngine;
use super::fleet::{bucket_hash, Envelope, EnvelopeInner, FleetShared, Msg, WorkerHealth};
use super::metrics::Metrics;
use super::request::{Priority, ServiceError, SolveRequest, SolveResponse};
use crate::solver::{MethodId, Status};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How often a worker wakes to poll deadlines when its batcher is empty.
const IDLE_POLL: Duration = Duration::from_millis(50);

/// Base delay before re-running the engine factory after *consecutive*
/// panics (the first rebuild is immediate); doubles per panic in the
/// streak, capped at [`REBUILD_BACKOFF_MAX`]. Bounds how fast a
/// crash-looping engine can spin the factory without ever delaying the
/// common single-panic recovery.
const REBUILD_BACKOFF_BASE: Duration = Duration::from_millis(10);
const REBUILD_BACKOFF_MAX: Duration = Duration::from_millis(250);

/// Stiffness-escalation policy: when a request fails on an explicit
/// method with a stiffness-shaped status (`DtUnderflow`, `NonFinite`,
/// `NewtonDiverged`), the service re-enqueues it on `method` — an
/// implicit, L-stable fallback — up to `max_retries` times, instead of
/// returning the failure to the caller. The response records the
/// escalation in [`SolveResponse::escalated_from`]. Failures on implicit
/// methods (or on engines that don't route methods, like AOT) are
/// returned as-is.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// The fallback method; `None` disables escalation entirely.
    pub method: Option<MethodId>,
    /// Re-enqueues allowed per request (1 = the single escalation).
    pub max_retries: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { method: Some(MethodId::TRBDF2), max_retries: 1 }
    }
}

impl RetryPolicy {
    /// No escalation: solver failures go straight back to the caller.
    pub fn disabled() -> Self {
        Self { method: None, max_retries: 0 }
    }
}

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Dynamic-batcher flush size (per worker).
    pub max_batch: usize,
    /// Dynamic-batcher flush deadline.
    pub max_wait: Duration,
    /// Bound on admitted-but-unresolved requests across the whole fleet;
    /// submissions beyond it are shed with [`ServiceError::Overloaded`]
    /// (priority-tiered — see [`Priority`]). `0` = unbounded (the
    /// pre-fault-tolerance behavior).
    pub max_queue: usize,
    /// Worker fleet size; `0` = one worker per available core. Each
    /// worker owns its own engine (built by the shared factory) and its
    /// own batcher; requests route to workers by bucket affinity.
    pub workers: usize,
    /// Reactive stiffness-escalation policy (the safety net).
    pub retry: RetryPolicy,
    /// Proactive stiffness classification (disabled by default).
    pub classifier: ClassifierPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
            workers: 0,
            retry: RetryPolicy::default(),
            classifier: ClassifierPolicy::default(),
        }
    }
}

/// The in-flight bound for a priority class: `Low` may fill half the
/// queue, `Normal` all but a reserved eighth, `High` everything — so
/// high-priority traffic still gets in when normal traffic has filled
/// the queue. (For `max_queue < 8` the Normal and High limits coincide.)
fn admission_limit(max_queue: usize, p: Priority) -> usize {
    match p {
        Priority::Low => (max_queue / 2).max(1),
        Priority::Normal => (max_queue - max_queue / 8).max(1),
        Priority::High => max_queue.max(1),
    }
}

/// Resolve `ServiceConfig::workers`: `0` means one per available core.
fn resolve_workers(cfg: usize) -> usize {
    if cfg > 0 {
        cfg
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }
}

/// The engine factory, shared by every worker so each can (re)build its
/// own engine instance. `FnMut` behind a mutex: factories carry state
/// (fault-injection scripts, artifact handles); the lock serializes
/// builds, and a poisoned lock (factory panicked mid-build on another
/// worker) is cleared rather than cascading the panic fleet-wide.
type SharedFactory = Arc<Mutex<Box<dyn FnMut() -> Box<dyn SolveEngine> + Send>>>;

/// Handle to a running solver service.
pub struct Coordinator {
    shared: Arc<FleetShared>,
    handles: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    max_queue: usize,
    next_id: AtomicU64,
    classifier: Classifier,
    /// Where classified-stiff requests are routed: the retry fallback
    /// method (so proactive and reactive paths agree), `trbdf2` if
    /// retries are disabled.
    fallback: MethodId,
}

impl Coordinator {
    /// Spawn the worker fleet. `make_engine` runs *inside* worker threads
    /// so engines holding non-`Send` resources (PJRT client) work; it is
    /// called once per worker and again to rebuild an engine after a
    /// panic, so it must be re-invocable (`FnMut`).
    pub fn spawn<F>(cfg: ServiceConfig, make_engine: F) -> Self
    where
        F: FnMut() -> Box<dyn SolveEngine> + Send + 'static,
    {
        let n = resolve_workers(cfg.workers);
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..n).map(|_| mpsc::channel::<Msg>()).unzip();
        let shared = Arc::new(FleetShared::new(txs));
        let metrics = Arc::new(Metrics::for_workers(n));
        let factory: SharedFactory = Arc::new(Mutex::new(Box::new(make_engine)));
        let mut handles = Vec::with_capacity(n);
        for (idx, rx) in rxs.into_iter().enumerate() {
            let cfg = cfg.clone();
            let make_engine = factory.clone();
            let metrics = metrics.clone();
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("rode-worker-{idx}"))
                .spawn(move || {
                    let batcher = DynamicBatcher::new(cfg.max_batch, cfg.max_wait);
                    Worker {
                        idx,
                        cfg,
                        make_engine,
                        engine: None,
                        metrics,
                        shared,
                        batcher,
                        waiters: Waiters::new(),
                        panic_streak: 0,
                    }
                    .run(rx)
                })
                .expect("spawn worker");
            handles.push(handle);
        }
        let classifier = Classifier::new(cfg.classifier.clone());
        let fallback = cfg.retry.method.unwrap_or(MethodId::TRBDF2);
        Self {
            shared,
            handles,
            metrics,
            max_queue: cfg.max_queue,
            next_id: AtomicU64::new(1),
            classifier,
            fallback,
        }
    }

    /// Submit a request; the returned receiver yields exactly one
    /// response. Requests shed at admission, and requests submitted to a
    /// fully-dead fleet, receive an immediate [`SolveResponse::failure`] —
    /// the receiver never hangs forever.
    pub fn submit(&self, mut req: SolveRequest) -> Receiver<SolveResponse> {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        // Admission control: a bounded in-flight gauge with priority-
        // tiered limits; shedding happens here, before any buffering.
        if self.max_queue > 0 {
            let limit = admission_limit(self.max_queue, req.priority) as u64;
            let prev = self.metrics.requests_inflight.fetch_add(1, Ordering::AcqRel);
            if prev >= limit {
                self.metrics.requests_inflight.fetch_sub(1, Ordering::AcqRel);
                self.metrics.requests_shed.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(SolveResponse::failure(
                    req.id,
                    ServiceError::Overloaded {
                        inflight: prev as usize,
                        max_queue: self.max_queue,
                    },
                ));
                return rx;
            }
        } else {
            self.metrics.requests_inflight.fetch_add(1, Ordering::AcqRel);
        }
        // Proactive classification — admitted requests only, so shed
        // traffic never pays the FD probes and the hit/miss counters
        // denominate over requests that actually ran.
        let classified = self.classifier.classify(&req);
        if classified == Classified::Stiff {
            req.method = Some(self.fallback);
            self.metrics.classified_stiff.fetch_add(1, Ordering::Relaxed);
        }
        // Bucket-affinity routing (hash *after* classification: the
        // routed method is part of the bucket).
        let hash = bucket_hash(&BucketKey::of(&req));
        let mut env = Envelope::new(req, tx, classified, self.metrics.clone());
        loop {
            let Some(i) = self.shared.route(hash) else {
                // The whole fleet is tombstoned — the only path to an
                // unavailability failure at submit.
                env.fail(ServiceError::WorkerUnavailable);
                return rx;
            };
            match self.shared.send(i, Msg::Solve(env)) {
                Ok(()) => return rx,
                Err(Msg::Solve(back)) => {
                    // That worker's thread is gone entirely (shutdown
                    // race); record it dead and reroute.
                    self.shared.set_health(i, WorkerHealth::Tombstoned);
                    env = back;
                }
                Err(Msg::Shutdown) => unreachable!("solve send returned a shutdown message"),
            }
        }
    }

    /// Convenience: submit and wait. Service-level failures surface as
    /// [`SolveResponse::error`], not as `None` — `None` is reserved for
    /// the (not expected in practice) case of a response channel dropped
    /// without a send.
    pub fn solve_blocking(&self, req: SolveRequest) -> Option<SolveResponse> {
        self.submit(req).recv().ok()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Number of workers in the fleet.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Health of worker `i` (see [`WorkerHealth`]).
    pub fn worker_health(&self, i: usize) -> WorkerHealth {
        self.shared.health(i)
    }

    /// Workers not currently tombstoned.
    pub fn alive_workers(&self) -> usize {
        self.shared.alive_count()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.shutdown_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Per-request worker-side state: the response channel plus everything
/// needed for deadlines, retry accounting and classifier bookkeeping.
struct Waiter {
    tx: Sender<SolveResponse>,
    t_submit: Instant,
    /// Escalation retries already consumed.
    attempts: u32,
    /// The explicit method this request first failed on, when it was
    /// re-enqueued onto the implicit fallback.
    escalated_from: Option<MethodId>,
    /// What the proactive classifier said at submit time.
    classified: Classified,
}

type Waiters = std::collections::HashMap<u64, Waiter>;

/// A worker thread's state machine. One instance lives for the whole
/// thread; `engine` is `None` only between a panic and the completed
/// rebuild (or permanently, in the tombstone state). The worker's
/// position in the fleet health array mirrors this: `Healthy` while
/// serving, `Rebuilding` between panic and rebuild, `Tombstoned` when
/// the factory is dead or the worker has shut down.
struct Worker {
    idx: usize,
    cfg: ServiceConfig,
    make_engine: SharedFactory,
    engine: Option<Box<dyn SolveEngine>>,
    metrics: Arc<Metrics>,
    shared: Arc<FleetShared>,
    batcher: DynamicBatcher,
    waiters: Waiters,
    /// Consecutive engine panics without an intervening successful batch;
    /// drives the rebuild backoff.
    panic_streak: u32,
}

impl Worker {
    fn run(mut self, rx: Receiver<Msg>) {
        if !self.rebuild_engine(false) {
            // The very first engine build panicked: nothing can ever be
            // solved here. Hand everything to the survivors.
            return self.tombstone(&rx);
        }
        loop {
            // Wait bounded by the next deadline flush.
            let timeout = self.batcher.next_deadline(Instant::now()).unwrap_or(IDLE_POLL);
            match rx.recv_timeout(timeout) {
                Ok(Msg::Solve(env)) => self.accept(env),
                Ok(Msg::Shutdown) => break,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
            for batch in self.batcher.poll_expired(Instant::now()) {
                self.dispatch(batch);
            }
            if self.engine.is_none() {
                // A panic was absorbed but the rebuild also panicked:
                // degrade instead of stranding waiters.
                return self.tombstone(&rx);
            }
        }
        // Drain remaining work — including retries enqueued while
        // draining — before exiting.
        while self.engine.is_some() && self.batcher.pending() > 0 {
            for batch in self.batcher.drain(Instant::now()) {
                self.dispatch(batch);
            }
        }
        let ids: Vec<u64> = self.waiters.keys().copied().collect();
        for id in ids {
            self.respond(SolveResponse::failure(id, ServiceError::ShuttingDown));
        }
        self.shared.set_health(self.idx, WorkerHealth::Tombstoned);
        // Anything a racing peer still forwards here lands in a channel
        // whose receiver is about to drop; the envelope drop guard
        // answers those callers with `ShuttingDown`.
    }

    /// Take ownership of an envelope: park its response state and batch
    /// its request.
    fn accept(&mut self, env: Envelope) {
        let EnvelopeInner { req, tx, t_submit, attempts, escalated_from, classified } =
            env.claim();
        self.waiters.insert(
            req.id,
            Waiter { tx, t_submit, attempts, escalated_from, classified },
        );
        self.enqueue(req);
    }

    /// Terminal degraded state: no engine exists and none can be built.
    /// The parked queue fails over to surviving workers — with their
    /// original submit times, retry budgets and classifier verdicts —
    /// and everything that keeps arriving is forwarded the same way, so
    /// no receiver ever hangs. Only when no survivor exists do requests
    /// fail with `WorkerUnavailable`.
    fn tombstone(mut self, rx: &Receiver<Msg>) {
        self.shared.set_health(self.idx, WorkerHealth::Tombstoned);
        for batch in self.batcher.drain(Instant::now()) {
            for req in batch.requests {
                self.fail_over(req);
            }
        }
        // Waiters without a parked request (none expected) can't be
        // forwarded — fail them rather than strand them.
        let ids: Vec<u64> = self.waiters.keys().copied().collect();
        for id in ids {
            self.respond(SolveResponse::failure(id, ServiceError::WorkerUnavailable));
        }
        loop {
            match rx.recv() {
                Ok(Msg::Solve(env)) => self.forward(env),
                Ok(Msg::Shutdown) | Err(_) => return,
            }
        }
    }

    /// Re-wrap a parked request (plus its waiter state) for a survivor.
    fn fail_over(&mut self, req: SolveRequest) {
        let Some(w) = self.waiters.remove(&req.id) else { return };
        let env = Envelope::from_parts(
            EnvelopeInner {
                req,
                tx: w.tx,
                t_submit: w.t_submit,
                attempts: w.attempts,
                escalated_from: w.escalated_from,
                classified: w.classified,
            },
            self.metrics.clone(),
        );
        self.forward(env);
    }

    /// Send an envelope to a surviving peer, walking the fleet as peers
    /// die under us; `WorkerUnavailable` only when none is left.
    fn forward(&self, mut env: Envelope) {
        let hash = bucket_hash(&BucketKey::of(env.req()));
        loop {
            let Some(j) = self.shared.failover_target(self.idx, hash) else {
                return env.fail(ServiceError::WorkerUnavailable);
            };
            match self.shared.send(j, Msg::Solve(env)) {
                Ok(()) => return,
                Err(Msg::Solve(back)) => {
                    self.shared.set_health(j, WorkerHealth::Tombstoned);
                    env = back;
                }
                Err(Msg::Shutdown) => return,
            }
        }
    }

    /// (Re)build the engine from the shared factory, absorbing a factory
    /// panic. `is_rebuild` distinguishes post-panic recovery (counted in
    /// `worker_rebuilds`) from the initial build.
    fn rebuild_engine(&mut self, is_rebuild: bool) -> bool {
        let factory = self.make_engine.clone();
        match catch_unwind(AssertUnwindSafe(move || {
            // A factory that panicked on another worker poisons the lock;
            // clearing it keeps one dead build from cascading fleet-wide.
            let mut make = factory.lock().unwrap_or_else(|p| p.into_inner());
            (make)()
        })) {
            Ok(engine) => {
                self.engine = Some(engine);
                if is_rebuild {
                    self.metrics.record_worker_rebuild(self.idx);
                }
                self.shared.set_health(self.idx, WorkerHealth::Healthy);
                true
            }
            Err(payload) => {
                eprintln!(
                    "[rode] engine factory panicked on worker {}: {}",
                    self.idx,
                    panic_message(&payload)
                );
                self.metrics.record_worker_panic(self.idx);
                self.engine = None;
                false
            }
        }
    }

    fn enqueue(&mut self, req: SolveRequest) {
        if let Some(batch) = self.batcher.push(req, Instant::now()) {
            self.dispatch(batch);
        }
    }

    /// Has this request's deadline passed? (Measured against its original
    /// submission time, so escalation retries and failover hops share the
    /// same budget.)
    fn expired(&self, req: &SolveRequest, now: Instant) -> bool {
        match (req.deadline, self.waiters.get(&req.id)) {
            (Some(d), Some(w)) => now.duration_since(w.t_submit) > d,
            _ => false,
        }
    }

    fn dispatch(&mut self, batch: Batch) {
        // Deadline check at dispatch time: a request that expired while
        // waiting in the batcher never occupies a batch slot.
        let now = Instant::now();
        let Batch { key, requests, oldest_wait } = batch;
        let mut live = Vec::with_capacity(requests.len());
        for r in requests {
            if self.expired(&r, now) {
                self.respond(SolveResponse::failure(r.id, ServiceError::DeadlineExpired));
            } else {
                live.push(r);
            }
        }
        if live.is_empty() {
            return;
        }
        let batch = Batch { key, requests: live, oldest_wait };
        self.metrics.batches_dispatched.fetch_add(1, Ordering::Relaxed);
        self.metrics.batch_size_sum.fetch_add(batch.requests.len() as u64, Ordering::Relaxed);
        let Some(engine) = self.engine.as_mut() else {
            // Only reachable while a dispatch chain is unwinding toward
            // the tombstone state.
            self.fail_batch(&batch, ServiceError::WorkerUnavailable);
            return;
        };
        let name = engine.name();
        match catch_unwind(AssertUnwindSafe(|| engine.solve(&batch))) {
            Ok(Ok(responses)) => {
                self.panic_streak = 0;
                self.deliver(&batch, responses);
            }
            Ok(Err(e)) => {
                eprintln!("[rode] batch failed on {name}: {e}");
                self.fail_batch(&batch, ServiceError::EngineError { detail: e.to_string() });
            }
            Err(payload) => {
                // Failure domain boundary: the panic takes down this
                // batch's requests and the engine instance — nothing
                // else. The engine may be in an arbitrary state
                // mid-unwind, so discard it and rebuild before the next
                // batch.
                let detail = panic_message(&payload);
                eprintln!(
                    "[rode] engine {name} panicked on worker {} ({}-request batch): {detail}",
                    self.idx,
                    batch.requests.len()
                );
                self.metrics.record_worker_panic(self.idx);
                self.engine = None;
                self.fail_batch(&batch, ServiceError::WorkerPanic { detail });
                self.panic_streak += 1;
                self.shared.set_health(self.idx, WorkerHealth::Rebuilding);
                if let Some(delay) = rebuild_backoff(self.panic_streak) {
                    std::thread::sleep(delay);
                }
                self.rebuild_engine(true);
            }
        }
    }

    fn fail_batch(&mut self, batch: &Batch, err: ServiceError) {
        for r in &batch.requests {
            self.respond(SolveResponse::failure(r.id, err.clone()));
        }
    }

    /// Route each engine response: escalate stiffness failures that the
    /// retry policy covers, deliver everything else.
    fn deliver(&mut self, batch: &Batch, responses: Vec<SolveResponse>) {
        for resp in responses {
            if let Some(target) = self.retry_method_for(&resp) {
                if let Some(orig) = batch.requests.iter().find(|r| r.id == resp.id) {
                    self.escalate(orig.clone(), resp.method, target);
                    continue;
                }
            }
            self.respond(resp);
        }
    }

    /// The fallback method to escalate `resp` onto, if the policy covers
    /// this failure: a stiffness-shaped solver status, on a routable
    /// explicit method, with retry budget left.
    fn retry_method_for(&self, resp: &SolveResponse) -> Option<MethodId> {
        if resp.error.is_some() {
            return None;
        }
        let target = self.cfg.retry.method?;
        let status = resp.status?;
        if !matches!(status, Status::DtUnderflow | Status::NonFinite | Status::NewtonDiverged) {
            return None;
        }
        // Only explicit failures escalate; a response without a resolved
        // method (AOT — its artifacts bake the method in) can't be
        // re-routed at all.
        let current = resp.method?;
        if current.is_implicit() || current == target {
            return None;
        }
        let w = self.waiters.get(&resp.id)?;
        (w.attempts < self.cfg.retry.max_retries).then_some(target)
    }

    /// Re-enqueue a stiffness casualty into the implicit-method bucket.
    /// (Locally — the waiter lives here, and moving buckets between
    /// workers mid-request would buy nothing.)
    fn escalate(&mut self, mut req: SolveRequest, failed_on: Option<MethodId>, target: MethodId) {
        if self.expired(&req, Instant::now()) {
            // The deadline died with the first attempt; don't burn a
            // batch slot on a retry nobody is waiting for.
            self.respond(SolveResponse::failure(req.id, ServiceError::DeadlineExpired));
            return;
        }
        if let Some(w) = self.waiters.get_mut(&req.id) {
            w.attempts += 1;
            w.escalated_from = failed_on;
            if w.attempts == 1 && w.classified == Classified::Explicit {
                // The proactive classifier said "explicit" and the solve
                // still died of stiffness: a miss, caught by the reactive
                // safety net.
                self.metrics.classifier_misses.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.metrics.requests_retried.fetch_add(1, Ordering::Relaxed);
        req.method = Some(target);
        self.enqueue(req);
    }

    /// Deliver a terminal response: stamp escalation/classifier
    /// provenance, settle the metrics taxonomy, release the in-flight
    /// slot.
    fn respond(&mut self, mut resp: SolveResponse) {
        let Some(w) = self.waiters.remove(&resp.id) else { return };
        resp.escalated_from = w.escalated_from;
        resp.classified_stiff = w.classified == Classified::Stiff;
        match &resp.error {
            None => {
                self.metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
                self.metrics.solver_steps_sum.fetch_add(resp.stats.n_steps, Ordering::Relaxed);
                self.metrics.record_latency(w.t_submit.elapsed());
                if resp.classified_stiff && resp.status == Some(Status::Success) {
                    // A proactive routing that solved first try on the
                    // implicit method: the classifier saved a failed
                    // explicit attempt.
                    self.metrics.classifier_hits.fetch_add(1, Ordering::Relaxed);
                }
            }
            Some(ServiceError::DeadlineExpired) => {
                self.metrics.requests_deadline_expired.fetch_add(1, Ordering::Relaxed);
            }
            Some(_) => {
                self.metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.metrics.requests_inflight.fetch_sub(1, Ordering::AcqRel);
        let _ = w.tx.send(resp);
    }
}

/// Backoff before the next factory run after `panic_streak` consecutive
/// engine panics: the first panic in a streak rebuilds immediately;
/// consecutive panics double the delay from [`REBUILD_BACKOFF_BASE`] up
/// to [`REBUILD_BACKOFF_MAX`].
fn rebuild_backoff(panic_streak: u32) -> Option<Duration> {
    if panic_streak <= 1 {
        return None;
    }
    let doublings = (panic_streak - 2).min(10);
    let delay = REBUILD_BACKOFF_BASE.saturating_mul(1u32 << doublings);
    Some(delay.min(REBUILD_BACKOFF_MAX))
}

/// Best-effort panic payload extraction for logs and `ServiceError`.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::coordinator::request::ProblemSpec;

    fn service(max_batch: usize, wait_ms: u64) -> Coordinator {
        Coordinator::spawn(
            ServiceConfig {
                max_batch,
                max_wait: Duration::from_millis(wait_ms),
                ..ServiceConfig::default()
            },
            || Box::new(NativeEngine::default()),
        )
    }

    fn vdp_req(mu: f64) -> SolveRequest {
        SolveRequest::new(
            ProblemSpec::Vdp { mu },
            vec![2.0, 0.0],
            (0..10).map(|k| k as f64 * 0.5).collect(),
        )
    }

    #[test]
    fn single_request_roundtrip() {
        let c = service(8, 1);
        let resp = c.solve_blocking(vdp_req(2.0)).unwrap();
        assert!(resp.is_success());
        assert_eq!(resp.error, None);
        assert_eq!(resp.escalated_from, None);
        assert!(!resp.classified_stiff);
        assert_eq!(resp.ys.len(), 20);
        assert!(resp.stats.n_steps > 0);
    }

    #[test]
    fn many_requests_all_complete_with_batching() {
        let c = service(4, 1);
        let rxs: Vec<_> = (0..10).map(|i| c.submit(vdp_req(1.0 + i as f64))).collect();
        let mut ok = 0;
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.is_success());
            ok += 1;
        }
        assert_eq!(ok, 10);
        let m = c.metrics();
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 10);
        // All in-flight slots were released.
        assert_eq!(m.requests_inflight.load(Ordering::Relaxed), 0);
        // max_batch 4 over 10 same-bucket requests => at least 3 batches
        // (bucket affinity keeps one shape on one worker, so batching is
        // as tight as the single-worker service).
        assert!(m.batches_dispatched.load(Ordering::Relaxed) >= 3);
        assert!(m.mean_batch_size() > 1.0);
    }

    #[test]
    fn heterogeneous_shapes_complete() {
        let c = service(16, 1);
        let mut reqs = Vec::new();
        for i in 0..6 {
            let mut r = vdp_req(2.0);
            if i % 2 == 0 {
                r.t_eval = (0..5).map(|k| k as f64 * 0.3).collect();
            }
            reqs.push(c.submit(r));
        }
        for rx in reqs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.is_success());
        }
    }

    #[test]
    fn shutdown_drains_pending() {
        let c = service(1000, 60_000); // nothing flushes by itself
        let rx = c.submit(vdp_req(1.5));
        drop(c); // shutdown drains the batcher
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.is_success());
    }

    #[test]
    fn per_instance_params_preserved_through_batching() {
        // Two very different μ in one batch must give different step counts
        // (the parallel engine keeps per-instance state).
        let c = service(2, 1);
        let rx1 = c.submit(vdp_req(1.0));
        let rx2 = c.submit(vdp_req(20.0));
        let r1 = rx1.recv_timeout(Duration::from_secs(30)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r2.stats.n_steps > r1.stats.n_steps);
    }

    #[test]
    fn admission_limits_are_tiered() {
        assert_eq!(admission_limit(16, Priority::Low), 8);
        assert_eq!(admission_limit(16, Priority::Normal), 14);
        assert_eq!(admission_limit(16, Priority::High), 16);
        // Tiny queues never degenerate to zero.
        assert_eq!(admission_limit(1, Priority::Low), 1);
        assert_eq!(admission_limit(1, Priority::Normal), 1);
        assert_eq!(admission_limit(1, Priority::High), 1);
        // Below 8, Normal and High coincide.
        assert_eq!(admission_limit(4, Priority::Normal), 4);
        assert_eq!(admission_limit(4, Priority::High), 4);
    }

    #[test]
    fn unbounded_queue_never_sheds() {
        let c = Coordinator::spawn(
            ServiceConfig { max_queue: 0, ..ServiceConfig::default() },
            || Box::new(NativeEngine::default()),
        );
        let rxs: Vec<_> = (0..64).map(|_| c.submit(vdp_req(1.0))).collect();
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().is_success());
        }
        assert_eq!(c.metrics().requests_shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn worker_count_resolution() {
        assert_eq!(resolve_workers(3), 3);
        // 0 = one per core, and there is always at least one core.
        assert!(resolve_workers(0) >= 1);
    }

    #[test]
    fn fleet_size_follows_config() {
        let c = Coordinator::spawn(
            ServiceConfig { workers: 3, ..ServiceConfig::default() },
            || Box::new(NativeEngine::default()),
        );
        assert_eq!(c.workers(), 3);
        assert_eq!(c.alive_workers(), 3);
        for i in 0..3 {
            assert_ne!(c.worker_health(i), WorkerHealth::Tombstoned);
        }
        // The fleet solves; affinity routes same-bucket traffic together.
        let rxs: Vec<_> = (0..8).map(|_| c.submit(vdp_req(2.0))).collect();
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().is_success());
        }
        assert_eq!(c.metrics().requests_inflight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn rebuild_backoff_is_bounded_and_skips_first_panic() {
        assert_eq!(rebuild_backoff(0), None);
        assert_eq!(rebuild_backoff(1), None); // first panic: rebuild immediately
        assert_eq!(rebuild_backoff(2), Some(REBUILD_BACKOFF_BASE));
        assert_eq!(rebuild_backoff(3), Some(REBUILD_BACKOFF_BASE * 2));
        // The cap holds even for absurd streaks.
        assert_eq!(rebuild_backoff(40), Some(REBUILD_BACKOFF_MAX));
    }

    #[test]
    fn proactive_classifier_routes_before_first_solve() {
        // Classifier on, reactive retry off: if the stiff request solves,
        // it solved implicit on the *first* attempt.
        let c = Coordinator::spawn(
            ServiceConfig {
                workers: 1,
                retry: RetryPolicy::disabled(),
                classifier: ClassifierPolicy::enabled(),
                ..ServiceConfig::default()
            },
            || {
                Box::new(NativeEngine::new(
                    crate::solver::SolveOptions::new(MethodId::DOPRI5)
                        .with_tols(1e-6, 1e-4)
                        .with_max_steps(500_000),
                ))
            },
        );
        let stiff = SolveRequest::new(
            ProblemSpec::Vdp { mu: 1000.0 },
            vec![2.0, 0.0],
            (0..5).map(|k| k as f64 * 100.0).collect(),
        );
        let resp = c.solve_blocking(stiff).unwrap();
        assert!(resp.is_success(), "status {:?} error {:?}", resp.status, resp.error);
        assert!(resp.classified_stiff);
        assert_eq!(resp.method, Some(MethodId::TRBDF2));
        assert_eq!(resp.escalated_from, None); // no reactive retry happened
        let m = c.metrics();
        assert_eq!(m.classified_stiff.load(Ordering::Relaxed), 1);
        assert_eq!(m.classifier_hits.load(Ordering::Relaxed), 1);
        assert_eq!(m.requests_retried.load(Ordering::Relaxed), 0);
    }
}
