//! The solver service: a worker thread owning an engine, fed through a
//! channel, with dynamic batching and per-request response delivery.
//!
//! Threads instead of async: the vendored crate set has no tokio, and a
//! single dedicated worker matches the execution model anyway (one PJRT
//! client / one native solve at a time per device).

use super::batcher::DynamicBatcher;
use super::engine::SolveEngine;
use super::metrics::Metrics;
use super::request::{SolveRequest, SolveResponse};
use crate::solver::{Stats, Status};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

enum Msg {
    Solve(SolveRequest, Sender<SolveResponse>, Instant),
    Shutdown,
}

/// Handle to a running solver service.
pub struct Coordinator {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    /// Spawn the worker. `make_engine` runs *inside* the worker thread so
    /// engines holding non-`Send` resources (PJRT client) work.
    pub fn spawn<F>(cfg: ServiceConfig, make_engine: F) -> Self
    where
        F: FnOnce() -> Box<dyn SolveEngine> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::new());
        let worker_metrics = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("rode-worker".into())
            .spawn(move || worker_loop(rx, cfg, make_engine(), worker_metrics))
            .expect("spawn worker");
        Self {
            tx,
            worker: Some(worker),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Submit a request; the returned receiver yields the response.
    pub fn submit(&self, mut req: SolveRequest) -> Receiver<SolveResponse> {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        // A send failure means the worker is gone; the caller will see a
        // disconnected receiver.
        let _ = self.tx.send(Msg::Solve(req, tx, Instant::now()));
        rx
    }

    /// Convenience: submit and wait.
    pub fn solve_blocking(&self, req: SolveRequest) -> Option<SolveResponse> {
        self.submit(req).recv().ok()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Response channels + submit times keyed by request id.
type Waiters = std::collections::HashMap<u64, (Sender<SolveResponse>, Instant)>;

fn worker_loop(
    rx: Receiver<Msg>,
    cfg: ServiceConfig,
    mut engine: Box<dyn SolveEngine>,
    metrics: Arc<Metrics>,
) {
    let mut batcher = DynamicBatcher::new(cfg.max_batch, cfg.max_wait);
    let mut waiters: Waiters = Waiters::new();

    let dispatch = |batch: super::batcher::Batch,
                    engine: &mut Box<dyn SolveEngine>,
                    waiters: &mut Waiters| {
        metrics.batches_dispatched.fetch_add(1, Ordering::Relaxed);
        metrics
            .batch_size_sum
            .fetch_add(batch.requests.len() as u64, Ordering::Relaxed);
        match engine.solve(&batch) {
            Ok(responses) => {
                for resp in responses {
                    metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
                    metrics
                        .solver_steps_sum
                        .fetch_add(resp.stats.n_steps, Ordering::Relaxed);
                    if let Some((tx, t_submit)) = waiters.remove(&resp.id) {
                        metrics.record_latency(t_submit.elapsed());
                        let _ = tx.send(resp);
                    }
                }
            }
            Err(e) => {
                // Fail every request in the batch with a DtUnderflow-free
                // explicit status; the error text goes to the log.
                eprintln!("[rode] batch failed on {}: {e}", engine.name());
                for r in &batch.requests {
                    metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
                    if let Some((tx, _)) = waiters.remove(&r.id) {
                        let _ = tx.send(SolveResponse {
                            id: r.id,
                            ys: Vec::new(),
                            stats: Stats::default(),
                            status: Status::NonFinite,
                            engine: "failed",
                            method: batch.key.method,
                        });
                    }
                }
            }
        }
    };

    loop {
        // Wait bounded by the next deadline flush.
        let timeout = batcher.next_deadline(Instant::now()).unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Solve(req, resp_tx, t_submit)) => {
                waiters.insert(req.id, (resp_tx, t_submit));
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    dispatch(batch, &mut engine, &mut waiters);
                }
            }
            Ok(Msg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        for batch in batcher.poll_expired(Instant::now()) {
            dispatch(batch, &mut engine, &mut waiters);
        }
    }
    // Drain remaining work before exiting.
    for batch in batcher.drain(Instant::now()) {
        dispatch(batch, &mut engine, &mut waiters);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::coordinator::request::ProblemSpec;

    fn service(max_batch: usize, wait_ms: u64) -> Coordinator {
        Coordinator::spawn(
            ServiceConfig { max_batch, max_wait: Duration::from_millis(wait_ms) },
            || Box::new(NativeEngine::default()),
        )
    }

    fn vdp_req(mu: f64) -> SolveRequest {
        SolveRequest {
            id: 0,
            problem: ProblemSpec::Vdp { mu },
            y0: vec![2.0, 0.0],
            t_eval: (0..10).map(|k| k as f64 * 0.5).collect(),
            method: None,
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let c = service(8, 1);
        let resp = c.solve_blocking(vdp_req(2.0)).unwrap();
        assert_eq!(resp.status, Status::Success);
        assert_eq!(resp.ys.len(), 20);
        assert!(resp.stats.n_steps > 0);
    }

    #[test]
    fn many_requests_all_complete_with_batching() {
        let c = service(4, 1);
        let rxs: Vec<_> = (0..10).map(|i| c.submit(vdp_req(1.0 + i as f64))).collect();
        let mut ok = 0;
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.status, Status::Success);
            ok += 1;
        }
        assert_eq!(ok, 10);
        let m = c.metrics();
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 10);
        // max_batch 4 over 10 requests => at least 3 batches.
        assert!(m.batches_dispatched.load(Ordering::Relaxed) >= 3);
        assert!(m.mean_batch_size() > 1.0);
    }

    #[test]
    fn heterogeneous_shapes_complete() {
        let c = service(16, 1);
        let mut reqs = Vec::new();
        for i in 0..6 {
            let mut r = vdp_req(2.0);
            if i % 2 == 0 {
                r.t_eval = (0..5).map(|k| k as f64 * 0.3).collect();
            }
            reqs.push(c.submit(r));
        }
        for rx in reqs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.status, Status::Success);
        }
    }

    #[test]
    fn shutdown_drains_pending() {
        let c = service(1000, 60_000); // nothing flushes by itself
        let rx = c.submit(vdp_req(1.5));
        drop(c); // shutdown drains the batcher
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.status, Status::Success);
    }

    #[test]
    fn per_instance_params_preserved_through_batching() {
        // Two very different μ in one batch must give different step counts
        // (the parallel engine keeps per-instance state).
        let c = service(2, 1);
        let rx1 = c.submit(vdp_req(1.0));
        let rx2 = c.submit(vdp_req(20.0));
        let r1 = rx1.recv_timeout(Duration::from_secs(30)).unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r2.stats.n_steps > r1.stats.n_steps);
    }
}
