//! Layer 3: the solver service — request router, dynamic batcher, engines
//! and metrics. See DESIGN.md §1.

pub mod batcher;
pub mod classifier;
pub mod engine;
pub mod fleet;
pub mod metrics;
pub mod request;
pub mod service;

pub use batcher::{Batch, BucketKey, DynamicBatcher};
pub use classifier::{Classified, Classifier, ClassifierPolicy};
pub use engine::{AotEngine, JointEngine, NativeEngine, SolveEngine};
pub use fleet::WorkerHealth;
pub use metrics::Metrics;
pub use request::{Priority, ProblemSpec, ServiceError, SolveRequest, SolveResponse};
pub use service::{Coordinator, RetryPolicy, ServiceConfig};
