//! Dynamic batching: group compatible requests, flush on size or deadline.
//!
//! The §4.1 lesson shapes the policy: batching is *free* under parallel
//! solving (each instance keeps its own solver state), so the batcher
//! groups aggressively by *shape* — (problem kind, dim, n_eval) — plus the
//! per-request method override, never by stiffness or time range. The
//! method joins the key because one batch is compiled and stepped with one
//! tableau; two requests asking for different methods can never share a
//! stage loop. A joint-batching engine would additionally need
//! stiffness-aware admission; the parallel engines do not.

use super::request::SolveRequest;
use crate::solver::MethodId;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Requests batch together iff these agree (the lowered artifacts and the
/// native engine both need rectangular batches).
///
/// The key's `Hash` also drives fleet dispatch: the coordinator routes a
/// request to `hash(key) % alive_workers` (`coordinator/fleet.rs`), so
/// every request that *could* share a batch lands on the same worker's
/// batcher and fleet parallelism never fragments batches.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BucketKey {
    pub kind: &'static str,
    pub dim: usize,
    pub n_eval: usize,
    /// Per-request method override; `None` = engine default. Part of the
    /// key so each bucket maps to exactly one compiled tableau.
    pub method: Option<MethodId>,
}

impl BucketKey {
    pub fn of(req: &SolveRequest) -> Self {
        Self {
            kind: req.problem.kind(),
            dim: req.dim(),
            n_eval: req.n_eval(),
            method: req.method,
        }
    }
}

/// A flushed batch ready for an engine.
#[derive(Debug)]
pub struct Batch {
    pub key: BucketKey,
    pub requests: Vec<SolveRequest>,
    /// Age of the oldest request at flush time.
    pub oldest_wait: Duration,
}

struct Bucket {
    requests: Vec<SolveRequest>,
    oldest: Instant,
}

/// Size- and deadline-triggered batcher.
pub struct DynamicBatcher {
    max_batch: usize,
    max_wait: Duration,
    buckets: HashMap<BucketKey, Bucket>,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        Self { max_batch, max_wait, buckets: HashMap::new() }
    }

    /// Add a request; returns a full batch if the bucket reached
    /// `max_batch`.
    pub fn push(&mut self, req: SolveRequest, now: Instant) -> Option<Batch> {
        let key = BucketKey::of(&req);
        let bucket = self
            .buckets
            .entry(key.clone())
            .or_insert_with(|| Bucket { requests: Vec::new(), oldest: now });
        if bucket.requests.is_empty() {
            bucket.oldest = now;
        }
        bucket.requests.push(req);
        if bucket.requests.len() >= self.max_batch {
            let bucket = self.buckets.remove(&key).unwrap();
            Some(Batch {
                key,
                oldest_wait: now.duration_since(bucket.oldest),
                requests: bucket.requests,
            })
        } else {
            None
        }
    }

    /// Flush every bucket whose oldest request has waited ≥ `max_wait`.
    pub fn poll_expired(&mut self, now: Instant) -> Vec<Batch> {
        let expired: Vec<BucketKey> = self
            .buckets
            .iter()
            .filter(|(_, b)| now.duration_since(b.oldest) >= self.max_wait)
            .map(|(k, _)| k.clone())
            .collect();
        expired
            .into_iter()
            .map(|key| {
                let b = self.buckets.remove(&key).unwrap();
                Batch {
                    key,
                    oldest_wait: now.duration_since(b.oldest),
                    requests: b.requests,
                }
            })
            .collect()
    }

    /// Flush everything (shutdown path).
    pub fn drain(&mut self, now: Instant) -> Vec<Batch> {
        let keys: Vec<BucketKey> = self.buckets.keys().cloned().collect();
        keys.into_iter()
            .map(|key| {
                let b = self.buckets.remove(&key).unwrap();
                Batch {
                    key,
                    oldest_wait: now.duration_since(b.oldest),
                    requests: b.requests,
                }
            })
            .collect()
    }

    /// Requests currently waiting.
    pub fn pending(&self) -> usize {
        self.buckets.values().map(|b| b.requests.len()).sum()
    }

    /// Time until the next deadline flush, if any bucket is non-empty.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.buckets
            .values()
            .map(|b| {
                self.max_wait
                    .saturating_sub(now.duration_since(b.oldest))
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ProblemSpec;

    fn req(id: u64, kind: u8, n_eval: usize) -> SolveRequest {
        let mut r = SolveRequest::new(
            match kind {
                0 => ProblemSpec::Vdp { mu: 1.0 },
                _ => ProblemSpec::ExpDecay { lambda: 1.0 },
            },
            vec![1.0, 0.0],
            (0..n_eval).map(|k| k as f64).collect(),
        );
        r.id = id;
        r
    }

    #[test]
    fn flushes_on_max_batch() {
        let mut b = DynamicBatcher::new(3, Duration::from_secs(60));
        let t = Instant::now();
        assert!(b.push(req(1, 0, 5), t).is_none());
        assert!(b.push(req(2, 0, 5), t).is_none());
        let batch = b.push(req(3, 0, 5), t).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn incompatible_shapes_do_not_mix() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        assert!(b.push(req(1, 0, 5), t).is_none());
        assert!(b.push(req(2, 0, 6), t).is_none()); // different n_eval
        assert!(b.push(req(3, 1, 5), t).is_none()); // different kind
        assert_eq!(b.pending(), 3);
        let batch = b.push(req(4, 0, 5), t).unwrap();
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 4]);
    }

    #[test]
    fn method_overrides_do_not_mix() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(60));
        let t = Instant::now();
        let stiff = |id| {
            let mut r = req(id, 0, 5);
            r.method = Some(MethodId::TRBDF2);
            r
        };
        assert!(b.push(req(1, 0, 5), t).is_none()); // default method
        assert!(b.push(stiff(2), t).is_none()); // trbdf2 bucket
        assert_eq!(b.pending(), 2);
        // Same shape + same method flushes; the default bucket stays put.
        let batch = b.push(stiff(3), t).unwrap();
        assert_eq!(batch.key.method, Some(MethodId::TRBDF2));
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn deadline_flush() {
        let mut b = DynamicBatcher::new(100, Duration::from_millis(10));
        let t0 = Instant::now();
        b.push(req(1, 0, 5), t0);
        assert!(b.poll_expired(t0).is_empty());
        let later = t0 + Duration::from_millis(11);
        let batches = b.poll_expired(later);
        assert_eq!(batches.len(), 1);
        assert!(batches[0].oldest_wait >= Duration::from_millis(11));
    }

    #[test]
    fn drain_empties_everything() {
        let mut b = DynamicBatcher::new(100, Duration::from_secs(60));
        let t = Instant::now();
        b.push(req(1, 0, 5), t);
        b.push(req(2, 1, 5), t);
        let batches = b.drain(t);
        assert_eq!(batches.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = DynamicBatcher::new(100, Duration::from_millis(50));
        let t0 = Instant::now();
        assert!(b.next_deadline(t0).is_none());
        b.push(req(1, 0, 5), t0);
        let d = b.next_deadline(t0 + Duration::from_millis(20)).unwrap();
        assert!(d <= Duration::from_millis(30));
    }

    /// Property: every pushed request comes back exactly once, whatever the
    /// interleaving of pushes and deadline polls.
    #[test]
    fn no_request_lost_or_duplicated() {
        crate::prop::check("batcher-conservation", 50, 42, |rng| {
            let mut b = DynamicBatcher::new(1 + rng.below(5), Duration::from_millis(5));
            let t0 = Instant::now();
            let n = 1 + rng.below(40);
            let mut seen = Vec::new();
            for id in 0..n as u64 {
                let kind = (rng.below(2)) as u8;
                let n_eval = 3 + rng.below(3);
                if let Some(batch) = b.push(req(id, kind, n_eval), t0) {
                    seen.extend(batch.requests.iter().map(|r| r.id));
                }
                if rng.below(4) == 0 {
                    for batch in b.poll_expired(t0 + Duration::from_millis(10)) {
                        seen.extend(batch.requests.iter().map(|r| r.id));
                    }
                }
            }
            for batch in b.drain(t0) {
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
            seen.sort_unstable();
            let expect: Vec<u64> = (0..n as u64).collect();
            assert_eq!(seen, expect, "requests lost or duplicated");
        });
    }

    /// Property: batches are always shape-homogeneous and within max size.
    #[test]
    fn batches_homogeneous_and_bounded() {
        crate::prop::check("batcher-homogeneous", 50, 7, |rng| {
            let max = 1 + rng.below(6);
            let mut b = DynamicBatcher::new(max, Duration::from_secs(1));
            let t = Instant::now();
            let mut check = |batch: &Batch| {
                assert!(batch.requests.len() <= max);
                for r in &batch.requests {
                    assert_eq!(BucketKey::of(r), batch.key);
                }
            };
            for id in 0..60 {
                let kind = (rng.below(2)) as u8;
                let n_eval = 3 + rng.below(4);
                if let Some(batch) = b.push(req(id, kind, n_eval), t) {
                    check(&batch);
                }
            }
            for batch in b.drain(t) {
                check(&batch);
            }
        });
    }
}
