//! Fleet plumbing: the shared routing/health state between the
//! coordinator front-end and its N worker threads.
//!
//! # Health state machine
//!
//! Each worker is `Healthy → Rebuilding → (Healthy | Tombstoned)`:
//!
//! - `Healthy` — owns a live engine and serves its share of buckets.
//! - `Rebuilding` — absorbed an engine panic and is re-running the
//!   factory (with bounded exponential backoff after repeated panics);
//!   new traffic still routes to it and queues in its channel.
//! - `Tombstoned` — terminal: the factory itself panicked, so no engine
//!   can ever be built. The worker forwards its parked queue to healthy
//!   peers ("drains onto survivors") and keeps forwarding anything that
//!   still arrives, so no receiver is ever stranded.
//!
//! # Routing
//!
//! Dispatch is by **bucket affinity**: a request's [`BucketKey`] hashes
//! to one worker among the non-tombstoned set, so same-shaped traffic
//! lands on one batcher and batches as well as it did with a single
//! worker. When a worker tombstones, the healthy set shrinks and the
//! same hash remaps its buckets onto survivors — failover is just the
//! modulus changing. [`ServiceError::WorkerUnavailable`] is reachable
//! only when the whole fleet is tombstoned.

use super::batcher::BucketKey;
use super::classifier::Classified;
use super::metrics::Metrics;
use super::request::{ServiceError, SolveRequest, SolveResponse};
use crate::solver::MethodId;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

/// One worker's position in the health state machine. Stored as a u8
/// atomic in [`FleetShared`] so the submit path and sibling workers can
/// read it without locks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerHealth {
    /// Engine live, serving traffic.
    Healthy,
    /// Engine lost to a panic; the factory is rebuilding it.
    Rebuilding,
    /// Terminal: the factory panicked, no engine can be built. The
    /// worker's queue has drained onto survivors.
    Tombstoned,
}

impl WorkerHealth {
    fn from_u8(v: u8) -> Self {
        match v {
            0 => WorkerHealth::Healthy,
            1 => WorkerHealth::Rebuilding,
            _ => WorkerHealth::Tombstoned,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            WorkerHealth::Healthy => 0,
            WorkerHealth::Rebuilding => 1,
            WorkerHealth::Tombstoned => 2,
        }
    }
}

/// Everything a request needs to travel between workers: the original
/// request plus the response channel and the retry/classifier state that
/// must survive a failover hop.
pub(crate) struct EnvelopeInner {
    pub req: SolveRequest,
    pub tx: Sender<SolveResponse>,
    pub t_submit: Instant,
    /// Escalation retries already consumed (failover preserves the
    /// once-per-request budget).
    pub attempts: u32,
    /// The explicit method this request first failed on, if it was
    /// escalated before the hop.
    pub escalated_from: Option<MethodId>,
    /// What the proactive classifier said at submit time.
    pub classified: Classified,
}

/// A drop-guarded [`EnvelopeInner`]: if the envelope is destroyed without
/// being claimed by a worker — e.g. it was sitting in a channel that a
/// shutting-down worker dropped while a tombstoned peer was failing over
/// onto it — the guard settles the metrics taxonomy and answers the
/// caller with [`ServiceError::ShuttingDown`]. This is what makes "no
/// submitted receiver is ever stranded" a structural property instead of
/// a property of every individual race.
pub(crate) struct Envelope {
    inner: Option<EnvelopeInner>,
    metrics: Arc<Metrics>,
}

impl Envelope {
    pub fn new(
        req: SolveRequest,
        tx: Sender<SolveResponse>,
        classified: Classified,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self {
            inner: Some(EnvelopeInner {
                req,
                tx,
                t_submit: Instant::now(),
                attempts: 0,
                escalated_from: None,
                classified,
            }),
            metrics,
        }
    }

    /// Re-wrap in-flight state for a failover hop.
    pub fn from_parts(inner: EnvelopeInner, metrics: Arc<Metrics>) -> Self {
        Self { inner: Some(inner), metrics }
    }

    pub fn req(&self) -> &SolveRequest {
        &self.inner.as_ref().expect("claimed envelope").req
    }

    /// Take ownership of the contents, disarming the drop guard. The
    /// claimer is now responsible for answering the caller exactly once.
    pub fn claim(mut self) -> EnvelopeInner {
        self.inner.take().expect("claimed envelope")
    }

    /// Answer the caller with a terminal service failure and settle the
    /// metrics taxonomy (failed + in-flight release).
    pub fn fail(mut self, err: ServiceError) {
        let metrics = self.metrics.clone();
        let inner = self.inner.take().expect("claimed envelope");
        metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
        metrics.requests_inflight.fetch_sub(1, Ordering::AcqRel);
        let _ = inner.tx.send(SolveResponse::failure(inner.req.id, err));
    }
}

impl Drop for Envelope {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            self.metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            self.metrics.requests_inflight.fetch_sub(1, Ordering::AcqRel);
            let _ = inner.tx.send(SolveResponse::failure(inner.req.id, ServiceError::ShuttingDown));
        }
    }
}

pub(crate) enum Msg {
    Solve(Envelope),
    Shutdown,
}

/// Shared fleet state: one channel and one health slot per worker.
pub(crate) struct FleetShared {
    txs: Vec<Sender<Msg>>,
    health: Vec<AtomicU8>,
}

impl FleetShared {
    pub fn new(txs: Vec<Sender<Msg>>) -> Self {
        let health = txs.iter().map(|_| AtomicU8::new(WorkerHealth::Healthy.as_u8())).collect();
        Self { txs, health }
    }

    pub fn len(&self) -> usize {
        self.txs.len()
    }

    pub fn health(&self, i: usize) -> WorkerHealth {
        WorkerHealth::from_u8(self.health[i].load(Ordering::Acquire))
    }

    pub fn set_health(&self, i: usize, h: WorkerHealth) {
        self.health[i].store(h.as_u8(), Ordering::Release);
    }

    pub fn alive_count(&self) -> usize {
        (0..self.len()).filter(|&i| self.health(i) != WorkerHealth::Tombstoned).count()
    }

    /// The affinity target for a bucket hash: position `hash % alive`
    /// within the non-tombstoned set. `None` iff the whole fleet is dead.
    /// Allocation-free — this sits on the zero-alloc submit path.
    pub fn route(&self, hash: u64) -> Option<usize> {
        self.pick(hash, usize::MAX)
    }

    /// The failover target for work stranded on worker `exclude`: the
    /// affinity choice among the surviving peers.
    pub fn failover_target(&self, exclude: usize, hash: u64) -> Option<usize> {
        self.pick(hash, exclude)
    }

    fn pick(&self, hash: u64, exclude: usize) -> Option<usize> {
        // Count-then-scan can race a concurrent tombstone; retry, then
        // settle for any live worker rather than reporting a dead fleet.
        for _ in 0..2 {
            let alive =
                (0..self.len()).filter(|&i| i != exclude && self.health(i) != WorkerHealth::Tombstoned).count();
            if alive == 0 {
                return None;
            }
            let target = (hash % alive as u64) as usize;
            let mut seen = 0;
            for i in 0..self.len() {
                if i != exclude && self.health(i) != WorkerHealth::Tombstoned {
                    if seen == target {
                        return Some(i);
                    }
                    seen += 1;
                }
            }
        }
        (0..self.len()).find(|&i| i != exclude && self.health(i) != WorkerHealth::Tombstoned)
    }

    /// Send to worker `i`; on failure (its thread is gone — a shutdown
    /// race) the message comes back to the caller for the next candidate.
    pub fn send(&self, i: usize, msg: Msg) -> Result<(), Msg> {
        self.txs[i].send(msg).map_err(|e| e.0)
    }

    /// Broadcast shutdown (best-effort: exited workers are fine).
    pub fn shutdown_all(&self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
    }
}

/// Stable affinity hash of a bucket key.
pub(crate) fn bucket_hash(key: &BucketKey) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn shared(n: usize) -> (FleetShared, Vec<mpsc::Receiver<Msg>>) {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..n).map(|_| mpsc::channel()).unzip();
        (FleetShared::new(txs), rxs)
    }

    #[test]
    fn routing_is_stable_and_skips_tombstones() {
        let (s, _rxs) = shared(4);
        let h = 12345u64;
        let first = s.route(h).unwrap();
        // Affinity: the same hash keeps landing on the same worker.
        assert_eq!(s.route(h), Some(first));
        // Tombstoning the target remaps the hash onto a survivor.
        s.set_health(first, WorkerHealth::Tombstoned);
        let second = s.route(h).unwrap();
        assert_ne!(second, first);
        // Rebuilding workers still receive traffic (their queue holds it).
        s.set_health(second, WorkerHealth::Rebuilding);
        assert_eq!(s.route(h), Some(second));
        assert_eq!(s.alive_count(), 3);
    }

    #[test]
    fn whole_fleet_dead_routes_nowhere() {
        let (s, _rxs) = shared(2);
        s.set_health(0, WorkerHealth::Tombstoned);
        s.set_health(1, WorkerHealth::Tombstoned);
        assert_eq!(s.route(7), None);
        assert_eq!(s.alive_count(), 0);
    }

    #[test]
    fn failover_excludes_the_dying_worker() {
        let (s, _rxs) = shared(3);
        for hash in 0..64u64 {
            for w in 0..3 {
                if let Some(t) = s.failover_target(w, hash) {
                    assert_ne!(t, w);
                }
            }
        }
        // A one-worker fleet has nowhere to fail over to.
        let (solo, _r) = shared(1);
        assert_eq!(solo.failover_target(0, 9), None);
    }

    #[test]
    fn send_returns_message_when_worker_gone() {
        let (s, rxs) = shared(2);
        let mut rxs = rxs.into_iter();
        drop(rxs.next().unwrap()); // kill worker 0's receiver
        let _rx1 = rxs.next().unwrap(); // keep worker 1's alive
        match s.send(0, Msg::Shutdown) {
            Err(Msg::Shutdown) => {}
            _ => panic!("expected the message back from a dead channel"),
        }
        assert!(s.send(1, Msg::Shutdown).is_ok());
    }

    #[test]
    fn bucket_hash_is_deterministic_per_key() {
        let k1 = BucketKey { kind: "vdp", dim: 2, n_eval: 10, method: None };
        let k2 = BucketKey { kind: "vdp", dim: 2, n_eval: 10, method: None };
        let k3 = BucketKey { kind: "vdp", dim: 2, n_eval: 10, method: Some(MethodId::TRBDF2) };
        assert_eq!(bucket_hash(&k1), bucket_hash(&k2));
        // Not required, but overwhelmingly expected: the method changes the hash.
        assert_ne!(bucket_hash(&k1), bucket_hash(&k3));
    }
}
