//! Solve engines: the backends a batch can be dispatched to.
//!
//! - [`NativeEngine`] — the Rust parallel solver (torchode re-implemented).
//! - [`JointEngine`] — the joint baseline (torchdiffeq semantics); exists
//!   so the service can demonstrate §4.1 end to end.
//! - [`AotEngine`] — the PJRT full-solve artifacts (torchode-JIT): pads
//!   the batch to the artifact's static shape, executes, slices results.

use super::batcher::Batch;
use super::request::{ProblemSpec, SolveResponse};
use crate::exec::{solve_ivp_joint_pooled, solve_ivp_parallel_pooled};
use crate::problems::{ExponentialDecay, VdP};
use crate::runtime::Runtime;
use crate::solver::{MethodId, SolveOptions, Solution, Stats, Status, TimeGrid};
use crate::tensor::BatchVec;
use anyhow::{anyhow, Result};

/// A batch solver backend.
pub trait SolveEngine {
    fn name(&self) -> &'static str;
    fn solve(&mut self, batch: &Batch) -> Result<Vec<SolveResponse>>;
}

fn build_grid(batch: &Batch) -> TimeGrid {
    TimeGrid::from_rows(
        &batch.requests.iter().map(|r| r.t_eval.clone()).collect::<Vec<_>>(),
    )
}

fn build_y0(batch: &Batch) -> BatchVec {
    BatchVec::from_rows(&batch.requests.iter().map(|r| r.y0.clone()).collect::<Vec<_>>())
}

/// Clone the engine's default options, applying the bucket's method
/// override. Buckets are method-homogeneous (the method is part of
/// [`super::batcher::BucketKey`]), so one resolved method covers the batch.
fn routed_opts(opts: &SolveOptions, batch: &Batch) -> SolveOptions {
    let mut opts = opts.clone();
    if let Some(m) = batch.key.method {
        opts.method = m;
    }
    opts
}

fn to_responses(
    batch: &Batch,
    sol: &Solution,
    engine: &'static str,
    method: Option<MethodId>,
) -> Vec<SolveResponse> {
    batch
        .requests
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let mut ys = Vec::with_capacity(sol.n_eval() * sol.dim());
            for e in 0..sol.n_eval() {
                ys.extend_from_slice(sol.y(i, e));
            }
            SolveResponse {
                id: r.id,
                ys,
                stats: sol.stats[i].clone(),
                status: Some(sol.status[i]),
                error: None,
                engine,
                method,
                escalated_from: None,
                classified_stiff: false,
            }
        })
        .collect()
}

fn solve_native(batch: &Batch, opts: &SolveOptions, joint: bool) -> Result<Solution> {
    let y0 = build_y0(batch);
    let grid = build_grid(batch);
    match batch.key.kind {
        "vdp" => {
            let mu = batch
                .requests
                .iter()
                .map(|r| match r.problem {
                    ProblemSpec::Vdp { mu } => mu,
                    _ => unreachable!("bucket homogeneity"),
                })
                .collect();
            let sys = VdP::new(mu);
            Ok(if joint {
                solve_ivp_joint_pooled(&sys, &y0, &grid, opts)
            } else {
                solve_ivp_parallel_pooled(&sys, &y0, &grid, opts)
            })
        }
        "expdecay" => {
            let lam = batch
                .requests
                .iter()
                .map(|r| match r.problem {
                    ProblemSpec::ExpDecay { lambda } => lambda,
                    _ => unreachable!("bucket homogeneity"),
                })
                .collect();
            let sys = ExponentialDecay::new(lam, batch.key.dim);
            Ok(if joint {
                solve_ivp_joint_pooled(&sys, &y0, &grid, opts)
            } else {
                solve_ivp_parallel_pooled(&sys, &y0, &grid, opts)
            })
        }
        other => Err(anyhow!("native engine has no dynamics for kind '{other}'")),
    }
}

/// The parallel native engine (the default backend).
pub struct NativeEngine {
    pub opts: SolveOptions,
}

impl NativeEngine {
    pub fn new(opts: SolveOptions) -> Self {
        Self { opts }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new(SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5))
    }
}

impl SolveEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native-parallel"
    }

    fn solve(&mut self, batch: &Batch) -> Result<Vec<SolveResponse>> {
        let opts = routed_opts(&self.opts, batch);
        let sol = solve_native(batch, &opts, false)?;
        Ok(to_responses(batch, &sol, self.name(), Some(opts.method)))
    }
}

/// The joint baseline engine (shared step size — torchdiffeq semantics).
/// Requires a common integration range inside each batch; the batcher does
/// not enforce that, so this engine rejects mixed-range batches.
pub struct JointEngine {
    pub opts: SolveOptions,
}

impl SolveEngine for JointEngine {
    fn name(&self) -> &'static str {
        "native-joint"
    }

    fn solve(&mut self, batch: &Batch) -> Result<Vec<SolveResponse>> {
        let t0 = batch.requests[0].t_eval[0];
        let t1 = *batch.requests[0].t_eval.last().unwrap();
        for r in &batch.requests {
            if (r.t_eval[0] - t0).abs() > 1e-12
                || (r.t_eval.last().unwrap() - t1).abs() > 1e-12
            {
                return Err(anyhow!("joint engine requires a shared integration range"));
            }
        }
        let opts = routed_opts(&self.opts, batch);
        let sol = solve_native(batch, &opts, true)?;
        Ok(to_responses(batch, &sol, self.name(), Some(opts.method)))
    }
}

/// The AOT (PJRT) engine: executes the full-solve artifacts. VdP only —
/// artifacts bake the dynamics in.
pub struct AotEngine {
    runtime: Runtime,
}

impl AotEngine {
    pub fn new(runtime: Runtime) -> Self {
        Self { runtime }
    }

    pub fn open(artifacts_dir: &str) -> Result<Self> {
        Ok(Self { runtime: Runtime::open(artifacts_dir)? })
    }
}

impl SolveEngine for AotEngine {
    fn name(&self) -> &'static str {
        "aot-pjrt"
    }

    fn solve(&mut self, batch: &Batch) -> Result<Vec<SolveResponse>> {
        if batch.key.kind != "vdp" {
            return Err(anyhow!("no AOT artifact for kind '{}'", batch.key.kind));
        }
        if let Some(m) = batch.key.method {
            // Artifacts bake their method at lowering time; a per-request
            // override cannot be honored, so fail loudly instead of
            // silently solving with the wrong tableau.
            return Err(anyhow!(
                "aot engine cannot route method '{m}'; artifacts bake the method in"
            ));
        }
        let n = batch.requests.len();
        let e_req = batch.key.n_eval;
        let name = self
            .runtime
            .pick_vdp_solve(n, e_req)
            .ok_or_else(|| anyhow!("no artifact fits batch={n}, n_eval={e_req}"))?;
        let art = self.runtime.load(&name)?;
        let (b_art, e_art) = (art.meta.batch, art.meta.n_eval);

        // Pad the batch to the artifact's static shape: repeat the last
        // request's data (extra rows are solved and discarded — the AOT
        // equivalent of torchode's overhanging evaluations).
        let mut y0 = vec![0f32; b_art * 2];
        let mut mu = vec![0f32; b_art];
        let mut te = vec![0f32; b_art * e_art];
        for i in 0..b_art {
            let r = &batch.requests[i.min(n - 1)];
            y0[i * 2] = r.y0[0] as f32;
            y0[i * 2 + 1] = r.y0[1] as f32;
            mu[i] = match r.problem {
                ProblemSpec::Vdp { mu } => mu as f32,
                _ => unreachable!(),
            };
            // Pad the eval grid by linearly extending past t1 (extra points
            // are sliced off; keeping them ascending keeps the artifact's
            // invariants intact).
            let t1 = *r.t_eval.last().unwrap();
            let dt_pad = (t1 - r.t_eval[0]).max(1e-6) / e_req.max(1) as f64;
            for e in 0..e_art {
                te[i * e_art + e] = if e < e_req {
                    r.t_eval[e] as f32
                } else {
                    (t1 + dt_pad * (e - e_req + 1) as f64) as f32
                };
            }
        }
        let out = art.run_f32(&[&y0, &mu, &te])?;
        let (ys, n_steps, n_accepted, n_f_evals, status) =
            (&out[0], &out[1], &out[2], &out[3], &out[4]);

        Ok(batch
            .requests
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let mut ys_i = Vec::with_capacity(e_req * 2);
                for e in 0..e_req {
                    let lo = (i * e_art + e) * 2;
                    ys_i.push(ys[lo] as f64);
                    ys_i.push(ys[lo + 1] as f64);
                }
                SolveResponse {
                    id: r.id,
                    ys: ys_i,
                    stats: Stats {
                        n_steps: n_steps[i] as u64,
                        n_accepted: n_accepted[i] as u64,
                        n_f_evals: n_f_evals[i] as u64,
                        n_initialized: e_req as u64,
                        ..Default::default()
                    },
                    status: Some(if status[i] == 0.0 {
                        Status::Success
                    } else {
                        Status::MaxStepsReached
                    }),
                    error: None,
                    engine: "aot-pjrt",
                    method: None,
                    escalated_from: None,
                    classified_stiff: false,
                }
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BucketKey;
    use crate::coordinator::SolveRequest;
    use std::time::Duration;

    fn vdp_batch(mus: &[f64], n_eval: usize, t1: f64) -> Batch {
        let requests: Vec<SolveRequest> = mus
            .iter()
            .enumerate()
            .map(|(i, &mu)| {
                let mut r = SolveRequest::new(
                    ProblemSpec::Vdp { mu },
                    vec![2.0, 0.0],
                    (0..n_eval).map(|k| t1 * k as f64 / (n_eval - 1) as f64).collect(),
                );
                r.id = i as u64;
                r
            })
            .collect();
        Batch {
            key: BucketKey::of(&requests[0]),
            requests,
            oldest_wait: Duration::ZERO,
        }
    }

    #[test]
    fn native_engine_solves_batch() {
        let mut eng = NativeEngine::default();
        let batch = vdp_batch(&[1.0, 5.0], 10, 5.0);
        let rs = eng.solve(&batch).unwrap();
        assert_eq!(rs.len(), 2);
        assert!(rs.iter().all(|r| r.is_success()));
        assert_eq!(rs[0].ys.len(), 20);
        // Stiffer instance takes more steps.
        assert!(rs[1].stats.n_steps > rs[0].stats.n_steps);
        // Responses keep request ids.
        assert_eq!(rs[0].id, 0);
        assert_eq!(rs[1].id, 1);
    }

    #[test]
    fn native_engine_sharded_matches_serial() {
        let batch = vdp_batch(&[1.0, 5.0, 0.7, 12.0], 10, 5.0);
        let mut serial = NativeEngine::default();
        let mut sharded = NativeEngine::new(
            SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5).with_threads(2),
        );
        let rs = serial.solve(&batch).unwrap();
        let rp = sharded.solve(&batch).unwrap();
        for (a, b) in rs.iter().zip(&rp) {
            assert_eq!(a.status, b.status);
            assert_eq!(a.stats, b.stats);
            assert_eq!(a.ys, b.ys);
        }
    }

    #[test]
    fn method_override_routes_the_whole_batch() {
        let mut eng = NativeEngine::default(); // dopri5 default
        let mut batch = vdp_batch(&[1.0, 5.0], 10, 5.0);
        for r in batch.requests.iter_mut() {
            r.method = Some(MethodId::TRBDF2);
        }
        batch.key = BucketKey::of(&batch.requests[0]);
        let rs = eng.solve(&batch).unwrap();
        assert!(rs.iter().all(|r| r.is_success()));
        // The response reports the routed method, and the implicit path
        // actually ran (Jacobian builds happened).
        assert!(rs.iter().all(|r| r.method == Some(MethodId::TRBDF2)));
        assert!(rs.iter().all(|r| r.stats.n_jac_evals > 0));
        // A default-method batch on the same engine stays explicit.
        let plain = vdp_batch(&[1.0], 10, 5.0);
        let rp = eng.solve(&plain).unwrap();
        assert_eq!(rp[0].method, Some(MethodId::DOPRI5));
        assert_eq!(rp[0].stats.n_jac_evals, 0);
    }

    #[test]
    fn joint_engine_shares_steps() {
        let mut eng =
            JointEngine { opts: SolveOptions::new(MethodId::DOPRI5).with_tols(1e-5, 1e-5) };
        let batch = vdp_batch(&[1.0, 10.0], 10, 5.0);
        let rs = eng.solve(&batch).unwrap();
        assert_eq!(rs[0].stats.n_steps, rs[1].stats.n_steps);
    }

    #[test]
    fn joint_engine_rejects_mixed_ranges() {
        let mut eng = JointEngine { opts: SolveOptions::new(MethodId::DOPRI5) };
        let mut batch = vdp_batch(&[1.0, 2.0], 5, 5.0);
        for t in batch.requests[1].t_eval.iter_mut() {
            *t += 1.0;
        }
        assert!(eng.solve(&batch).is_err());
    }

    #[test]
    fn native_and_joint_agree_on_solution() {
        let mut a = NativeEngine::default();
        let mut b = JointEngine { opts: SolveOptions::new(MethodId::DOPRI5).with_tols(1e-7, 1e-7) };
        let batch = vdp_batch(&[2.0, 2.0], 8, 4.0);
        let ra = a.solve(&batch).unwrap();
        let rb = b.solve(&batch).unwrap();
        for (x, y) in ra[0].ys.iter().zip(&rb[0].ys) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }
}
