//! Service metrics: lock-free counters + coarse latency histogram,
//! shareable across the submitter and worker threads.
//!
//! Counter taxonomy — every submitted request ends in exactly one of:
//!
//! - `requests_completed` — the solver ran; `SolveResponse::status` holds
//!   its outcome (which may be a solver-level failure like `DtUnderflow`).
//! - `requests_failed` — a *service-level* failure: the engine panicked
//!   or returned an error, or the worker was unavailable. Disjoint from
//!   solver-level failures, which count as completed.
//! - `requests_shed` — rejected at admission (bounded queue full).
//! - `requests_deadline_expired` — dropped at dispatch: the deadline
//!   passed while the request waited in the batcher.
//!
//! `requests_retried` counts stiffness-escalation retries (a retried
//! request is still terminal exactly once) and `worker_panics` counts
//! engine panics the workers absorbed; `requests_inflight` is a gauge of
//! admitted-but-unresolved requests, used by admission control.
//!
//! With the worker fleet the taxonomy is updated from N threads
//! concurrently, but stays *exact*, not approximate: every admitted
//! request increments the in-flight gauge once and is settled into
//! exactly one terminal counter by whichever thread answers it (worker,
//! failover peer, or envelope drop guard), so
//!
//! ```text
//!   submitted = completed + failed + shed + expired + inflight
//! ```
//!
//! holds at every quiescent point. `tests/fault_tolerance.rs` asserts it
//! after concurrent multi-worker runs. Per-worker panic/rebuild
//! breakdowns (sized by [`Metrics::for_workers`]) and the proactive-
//! classifier counters (`classified_stiff` / `classifier_hits` /
//! `classifier_misses`) ride alongside the taxonomy.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Histogram bucket upper bounds in microseconds.
const LAT_BOUNDS_US: [u64; 8] = [100, 300, 1_000, 3_000, 10_000, 30_000, 100_000, 300_000];

/// Shared service metrics (all atomics; `Arc<Metrics>` in practice).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    /// Service-level failures (panic / engine error / worker unavailable /
    /// shutdown) — disjoint from solver-level failures, which land in
    /// `requests_completed` with a non-success status.
    pub requests_failed: AtomicU64,
    /// Requests shed at admission by the bounded queue.
    pub requests_shed: AtomicU64,
    /// Requests dropped at dispatch because their deadline had passed.
    pub requests_deadline_expired: AtomicU64,
    /// Stiffness-escalation retries performed (re-enqueues, not requests).
    pub requests_retried: AtomicU64,
    /// Engine panics absorbed across the fleet (engine *and* factory
    /// panics; each engine panic also triggers a rebuild attempt).
    pub worker_panics: AtomicU64,
    /// Successful engine rebuilds after a panic, across the fleet.
    pub worker_rebuilds: AtomicU64,
    /// Requests the proactive classifier routed to the implicit fallback
    /// before their first solve.
    pub classified_stiff: AtomicU64,
    /// Classified-stiff requests that then solved successfully on the
    /// implicit method — zero failed explicit attempts paid.
    pub classifier_hits: AtomicU64,
    /// Classified-explicit requests that still escalated reactively: the
    /// classifier was wrong and the PR 7 retry safety net caught it.
    pub classifier_misses: AtomicU64,
    /// Gauge: admitted requests not yet resolved (queued, batched or
    /// solving). Admission control sheds against this.
    pub requests_inflight: AtomicU64,
    pub batches_dispatched: AtomicU64,
    pub batch_size_sum: AtomicU64,
    pub solver_steps_sum: AtomicU64,
    /// Per-worker panic/rebuild breakdowns; empty unless built with
    /// [`Metrics::for_workers`].
    per_worker: Vec<WorkerMetrics>,
    latency_buckets: [AtomicU64; 9],
    latency_sum_us: AtomicU64,
}

/// One worker's share of the fleet-wide panic/rebuild counters.
#[derive(Debug, Default)]
struct WorkerMetrics {
    panics: AtomicU64,
    rebuilds: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Metrics with per-worker breakdown slots for an `n`-worker fleet.
    pub fn for_workers(n: usize) -> Self {
        Self {
            per_worker: (0..n).map(|_| WorkerMetrics::default()).collect(),
            ..Self::default()
        }
    }

    /// Record an absorbed panic on worker `idx` (fleet total + breakdown).
    pub fn record_worker_panic(&self, idx: usize) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
        if let Some(w) = self.per_worker.get(idx) {
            w.panics.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a successful post-panic engine rebuild on worker `idx`.
    pub fn record_worker_rebuild(&self, idx: usize) {
        self.worker_rebuilds.fetch_add(1, Ordering::Relaxed);
        if let Some(w) = self.per_worker.get(idx) {
            w.rebuilds.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Panics absorbed by worker `idx` (0 when out of range).
    pub fn worker_panics_of(&self, idx: usize) -> u64 {
        self.per_worker.get(idx).map_or(0, |w| w.panics.load(Ordering::Relaxed))
    }

    /// Successful rebuilds on worker `idx` (0 when out of range).
    pub fn worker_rebuilds_of(&self, idx: usize) -> u64 {
        self.per_worker.get(idx).map_or(0, |w| w.rebuilds.load(Ordering::Relaxed))
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = LAT_BOUNDS_US.iter().position(|&b| us <= b).unwrap_or(LAT_BOUNDS_US.len());
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.requests_completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches_dispatched.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Approximate latency percentile from the histogram (upper bound of
    /// the bucket containing the percentile).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.latency_buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return LAT_BOUNDS_US.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    /// One-line summary for logs and the serve example. Multi-worker
    /// metrics append a per-worker `panics/rebuilds` breakdown.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "submitted={} completed={} failed={} shed={} expired={} retried={} panics={} \
             rebuilds={} classified={} cls_hits={} cls_misses={} \
             batches={} mean_batch={:.1} mean_lat={:.0}us p50={}us p90={}us p99={}us",
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_failed.load(Ordering::Relaxed),
            self.requests_shed.load(Ordering::Relaxed),
            self.requests_deadline_expired.load(Ordering::Relaxed),
            self.requests_retried.load(Ordering::Relaxed),
            self.worker_panics.load(Ordering::Relaxed),
            self.worker_rebuilds.load(Ordering::Relaxed),
            self.classified_stiff.load(Ordering::Relaxed),
            self.classifier_hits.load(Ordering::Relaxed),
            self.classifier_misses.load(Ordering::Relaxed),
            self.batches_dispatched.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.latency_percentile_us(0.5),
            self.latency_percentile_us(0.9),
            self.latency_percentile_us(0.99),
        );
        if self.per_worker.len() > 1 {
            s.push_str(" workers=[");
            for (i, w) in self.per_worker.iter().enumerate() {
                if i > 0 {
                    s.push(' ');
                }
                s.push_str(&format!(
                    "{i}:p{}/r{}",
                    w.panics.load(Ordering::Relaxed),
                    w.rebuilds.load(Ordering::Relaxed)
                ));
            }
            s.push(']');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_percentiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(Duration::from_micros(50));
        }
        for _ in 0..10 {
            m.record_latency(Duration::from_micros(50_000));
        }
        assert_eq!(m.latency_percentile_us(0.5), 100);
        assert_eq!(m.latency_percentile_us(0.95), 100_000);
        // p99 lands in the bucket holding the slowest decile.
        assert_eq!(m.latency_percentile_us(0.99), 100_000);
    }

    #[test]
    fn p50_p99_track_distinct_buckets() {
        let m = Metrics::new();
        for _ in 0..98 {
            m.record_latency(Duration::from_micros(200));
        }
        m.record_latency(Duration::from_micros(200_000));
        m.record_latency(Duration::from_micros(200_000));
        assert_eq!(m.latency_percentile_us(0.5), 300);
        assert_eq!(m.latency_percentile_us(0.99), 300_000);
    }

    #[test]
    fn mean_batch_size() {
        let m = Metrics::new();
        m.batches_dispatched.store(2, Ordering::Relaxed);
        m.batch_size_sum.store(10, Ordering::Relaxed);
        assert_eq!(m.mean_batch_size(), 5.0);
    }

    #[test]
    fn summary_contains_counts() {
        let m = Metrics::new();
        m.requests_submitted.store(7, Ordering::Relaxed);
        m.requests_shed.store(2, Ordering::Relaxed);
        m.requests_retried.store(1, Ordering::Relaxed);
        m.requests_deadline_expired.store(3, Ordering::Relaxed);
        m.worker_panics.store(4, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("submitted=7"));
        assert!(s.contains("shed=2"));
        assert!(s.contains("retried=1"));
        assert!(s.contains("expired=3"));
        assert!(s.contains("panics=4"));
        assert!(s.contains("p50="));
        assert!(s.contains("p99="));
    }

    #[test]
    fn per_worker_breakdown_tracks_fleet_totals() {
        let m = Metrics::for_workers(3);
        m.record_worker_panic(0);
        m.record_worker_panic(0);
        m.record_worker_panic(2);
        m.record_worker_rebuild(0);
        assert_eq!(m.worker_panics.load(Ordering::Relaxed), 3);
        assert_eq!(m.worker_rebuilds.load(Ordering::Relaxed), 1);
        assert_eq!(m.worker_panics_of(0), 2);
        assert_eq!(m.worker_panics_of(1), 0);
        assert_eq!(m.worker_panics_of(2), 1);
        assert_eq!(m.worker_rebuilds_of(0), 1);
        // Totals = sum of the breakdown.
        let sum: u64 = (0..3).map(|i| m.worker_panics_of(i)).sum();
        assert_eq!(sum, m.worker_panics.load(Ordering::Relaxed));
        let s = m.summary();
        assert!(s.contains("workers=[0:p2/r1 1:p0/r0 2:p1/r0]"), "{s}");
        assert!(s.contains("rebuilds=1"));
    }

    #[test]
    fn out_of_range_worker_still_counts_fleet_total() {
        // Metrics::new() has no breakdown slots; fleet totals still work.
        let m = Metrics::new();
        m.record_worker_panic(7);
        assert_eq!(m.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(m.worker_panics_of(7), 0);
        assert!(!m.summary().contains("workers=["));
    }

    #[test]
    fn classifier_counters_render() {
        let m = Metrics::new();
        m.classified_stiff.store(5, Ordering::Relaxed);
        m.classifier_hits.store(4, Ordering::Relaxed);
        m.classifier_misses.store(1, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("classified=5"));
        assert!(s.contains("cls_hits=4"));
        assert!(s.contains("cls_misses=1"));
    }
}
