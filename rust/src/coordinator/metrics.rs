//! Service metrics: lock-free counters + coarse latency histogram,
//! shareable across the submitter and worker threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Histogram bucket upper bounds in microseconds.
const LAT_BOUNDS_US: [u64; 8] = [100, 300, 1_000, 3_000, 10_000, 30_000, 100_000, 300_000];

/// Shared service metrics (all atomics; `Arc<Metrics>` in practice).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_failed: AtomicU64,
    pub batches_dispatched: AtomicU64,
    pub batch_size_sum: AtomicU64,
    pub solver_steps_sum: AtomicU64,
    latency_buckets: [AtomicU64; 9],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = LAT_BOUNDS_US.iter().position(|&b| us <= b).unwrap_or(LAT_BOUNDS_US.len());
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.requests_completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches_dispatched.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Approximate latency percentile from the histogram (upper bound of
    /// the bucket containing the percentile).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.latency_buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return LAT_BOUNDS_US.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    /// One-line summary for logs and the serve example.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} failed={} batches={} mean_batch={:.1} mean_lat={:.0}us p90={}us",
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_failed.load(Ordering::Relaxed),
            self.batches_dispatched.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.latency_percentile_us(0.9),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_percentiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(Duration::from_micros(50));
        }
        for _ in 0..10 {
            m.record_latency(Duration::from_micros(50_000));
        }
        assert_eq!(m.latency_percentile_us(0.5), 100);
        assert_eq!(m.latency_percentile_us(0.95), 100_000);
    }

    #[test]
    fn mean_batch_size() {
        let m = Metrics::new();
        m.batches_dispatched.store(2, Ordering::Relaxed);
        m.batch_size_sum.store(10, Ordering::Relaxed);
        assert_eq!(m.mean_batch_size(), 5.0);
    }

    #[test]
    fn summary_contains_counts() {
        let m = Metrics::new();
        m.requests_submitted.store(7, Ordering::Relaxed);
        assert!(m.summary().contains("submitted=7"));
    }
}
