//! Service metrics: lock-free counters + coarse latency histogram,
//! shareable across the submitter and worker threads.
//!
//! Counter taxonomy — every submitted request ends in exactly one of:
//!
//! - `requests_completed` — the solver ran; `SolveResponse::status` holds
//!   its outcome (which may be a solver-level failure like `DtUnderflow`).
//! - `requests_failed` — a *service-level* failure: the engine panicked
//!   or returned an error, or the worker was unavailable. Disjoint from
//!   solver-level failures, which count as completed.
//! - `requests_shed` — rejected at admission (bounded queue full).
//! - `requests_deadline_expired` — dropped at dispatch: the deadline
//!   passed while the request waited in the batcher.
//!
//! `requests_retried` counts stiffness-escalation retries (a retried
//! request is still terminal exactly once) and `worker_panics` counts
//! engine panics the worker absorbed; `requests_inflight` is a gauge of
//! admitted-but-unresolved requests, used by admission control.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Histogram bucket upper bounds in microseconds.
const LAT_BOUNDS_US: [u64; 8] = [100, 300, 1_000, 3_000, 10_000, 30_000, 100_000, 300_000];

/// Shared service metrics (all atomics; `Arc<Metrics>` in practice).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    /// Service-level failures (panic / engine error / worker unavailable /
    /// shutdown) — disjoint from solver-level failures, which land in
    /// `requests_completed` with a non-success status.
    pub requests_failed: AtomicU64,
    /// Requests shed at admission by the bounded queue.
    pub requests_shed: AtomicU64,
    /// Requests dropped at dispatch because their deadline had passed.
    pub requests_deadline_expired: AtomicU64,
    /// Stiffness-escalation retries performed (re-enqueues, not requests).
    pub requests_retried: AtomicU64,
    /// Engine panics absorbed by the worker (each also rebuilds the engine).
    pub worker_panics: AtomicU64,
    /// Gauge: admitted requests not yet resolved (queued, batched or
    /// solving). Admission control sheds against this.
    pub requests_inflight: AtomicU64,
    pub batches_dispatched: AtomicU64,
    pub batch_size_sum: AtomicU64,
    pub solver_steps_sum: AtomicU64,
    latency_buckets: [AtomicU64; 9],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = LAT_BOUNDS_US.iter().position(|&b| us <= b).unwrap_or(LAT_BOUNDS_US.len());
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.requests_completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches_dispatched.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Approximate latency percentile from the histogram (upper bound of
    /// the bucket containing the percentile).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.latency_buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return LAT_BOUNDS_US.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    /// One-line summary for logs and the serve example.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} failed={} shed={} expired={} retried={} panics={} \
             batches={} mean_batch={:.1} mean_lat={:.0}us p50={}us p90={}us p99={}us",
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_failed.load(Ordering::Relaxed),
            self.requests_shed.load(Ordering::Relaxed),
            self.requests_deadline_expired.load(Ordering::Relaxed),
            self.requests_retried.load(Ordering::Relaxed),
            self.worker_panics.load(Ordering::Relaxed),
            self.batches_dispatched.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.latency_percentile_us(0.5),
            self.latency_percentile_us(0.9),
            self.latency_percentile_us(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_percentiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(Duration::from_micros(50));
        }
        for _ in 0..10 {
            m.record_latency(Duration::from_micros(50_000));
        }
        assert_eq!(m.latency_percentile_us(0.5), 100);
        assert_eq!(m.latency_percentile_us(0.95), 100_000);
        // p99 lands in the bucket holding the slowest decile.
        assert_eq!(m.latency_percentile_us(0.99), 100_000);
    }

    #[test]
    fn p50_p99_track_distinct_buckets() {
        let m = Metrics::new();
        for _ in 0..98 {
            m.record_latency(Duration::from_micros(200));
        }
        m.record_latency(Duration::from_micros(200_000));
        m.record_latency(Duration::from_micros(200_000));
        assert_eq!(m.latency_percentile_us(0.5), 300);
        assert_eq!(m.latency_percentile_us(0.99), 300_000);
    }

    #[test]
    fn mean_batch_size() {
        let m = Metrics::new();
        m.batches_dispatched.store(2, Ordering::Relaxed);
        m.batch_size_sum.store(10, Ordering::Relaxed);
        assert_eq!(m.mean_batch_size(), 5.0);
    }

    #[test]
    fn summary_contains_counts() {
        let m = Metrics::new();
        m.requests_submitted.store(7, Ordering::Relaxed);
        m.requests_shed.store(2, Ordering::Relaxed);
        m.requests_retried.store(1, Ordering::Relaxed);
        m.requests_deadline_expired.store(3, Ordering::Relaxed);
        m.worker_panics.store(4, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("submitted=7"));
        assert!(s.contains("shed=2"));
        assert!(s.contains("retried=1"));
        assert!(s.contains("expired=3"));
        assert!(s.contains("panics=4"));
        assert!(s.contains("p50="));
        assert!(s.contains("p99="));
    }
}
