//! Proactive stiffness classification: route a request to the implicit
//! fallback *before* the first solve, instead of paying a failed explicit
//! attempt and escalating afterwards.
//!
//! # Decision rule
//!
//! An explicit Runge–Kutta pair is stability-limited to steps with
//! `|λ_max| · h` inside its stability region, where `λ_max` is the
//! dominant eigenvalue of the Jacobian. The classifier estimates
//! `|λ_max|` at `(t0, y0)` with a few finite-difference Jacobian–vector
//! power iterations (the same `sqrt(ε)·(1+|y|)` perturbation convention
//! as `solver/implicit.rs`'s FD Jacobians, but directional — O(iters)
//! `f` evaluations, never a full Jacobian), and compares the implied
//! stability-limited step count
//!
//! ```text
//!   n_explicit ≈ |λ_max| · (t1 − t0) / radius(explicit method)
//! ```
//!
//! against a budget. Above the budget, an accuracy-adequate explicit
//! solve would spend almost all of its steps fighting stability — the
//! defining symptom of stiffness — so the request is routed to the
//! implicit fallback up front. The stability radius is derived from the
//! tableau itself: the stability polynomial of an explicit RK method is
//! `R(z) = 1 + Σ_k z^k · bᵀA^{k−1}𝟙`, and the radius is the extent of
//! `|R(z)| ≤ 1` along the negative real axis (Dopri5 ≈ 3.3, Euler = 2).
//!
//! # Cost model
//!
//! Classification costs `iters + 1` dynamics evaluations on a *single*
//! instance — microseconds, versus the milliseconds-to-seconds of a
//! doomed explicit attempt across a whole batch. The estimate is local
//! to `(t0, y0)`, so a problem that only becomes stiff later can be
//! misclassified as explicit; the PR 7 escalation retry remains in place
//! as the safety net for exactly that case (counted as a
//! `classifier_miss` in [`super::Metrics`]).

use super::request::{ProblemSpec, SolveRequest};
use crate::problems::{ExponentialDecay, OdeSystem, VdP};
use crate::solver::MethodId;

/// Classifier outcome for one request, carried on its envelope so the
/// hit/miss counters can be settled when the request turns terminal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Classified {
    /// Classifier disabled, request carried an explicit method override,
    /// or the estimate was unusable (empty span, non-finite state).
    NotRun,
    /// Predicted comfortably explicit; left on the engine default.
    Explicit,
    /// Predicted stiff; `SolveRequest::method` was set to the implicit
    /// fallback before the first solve.
    Stiff,
}

/// Tuning knobs for the proactive classifier. Disabled by default: the
/// reactive escalation retry alone is the PR 7 behavior, and several
/// tests pin it.
#[derive(Debug, Clone)]
pub struct ClassifierPolicy {
    pub enabled: bool,
    /// The explicit method whose stability radius bounds the step — the
    /// method a default-routed request would actually run on.
    pub explicit: MethodId,
    /// Stability-limited explicit step count above which the implicit
    /// fallback is predicted cheaper. The default is deliberately high:
    /// a false `Stiff` costs one implicit solve (always succeeds, merely
    /// slower on easy problems), but the budget should still dwarf the
    /// accuracy-limited step count of any reasonable explicit solve.
    pub step_budget: f64,
    /// Power-iteration count; each costs one `f` evaluation. Four is
    /// enough to separate |λ| = 10 from |λ| = 1000 by orders of magnitude.
    pub iters: usize,
}

impl Default for ClassifierPolicy {
    fn default() -> Self {
        Self { enabled: false, explicit: MethodId::DOPRI5, step_budget: 2e4, iters: 4 }
    }
}

impl ClassifierPolicy {
    /// The default policy with classification switched on.
    pub fn enabled() -> Self {
        Self { enabled: true, ..Self::default() }
    }
}

/// A policy with its explicit method's stability radius precomputed
/// (the radius scan is per-method, not per-request).
#[derive(Debug, Clone)]
pub struct Classifier {
    policy: ClassifierPolicy,
    radius: f64,
}

impl Classifier {
    pub fn new(policy: ClassifierPolicy) -> Self {
        let radius = stability_radius(policy.explicit);
        Self { policy, radius }
    }

    /// The negative-real-axis stability radius of the policy's explicit
    /// method.
    pub fn radius(&self) -> f64 {
        self.radius
    }

    /// Classify a request at `(t0, y0)`. Never touches requests that
    /// already carry a method override — the caller chose, explicitly.
    pub fn classify(&self, req: &SolveRequest) -> Classified {
        if !self.policy.enabled || req.method.is_some() {
            return Classified::NotRun;
        }
        let (Some(&t0), Some(&t1)) = (req.t_eval.first(), req.t_eval.last()) else {
            return Classified::NotRun;
        };
        let span = t1 - t0;
        if !span.is_finite() || span <= 0.0 || self.radius <= 0.0 {
            return Classified::NotRun;
        }
        let Some(lambda) = dominant_eigenvalue(&req.problem, t0, &req.y0, self.policy.iters)
        else {
            return Classified::NotRun;
        };
        if lambda * span / self.radius > self.policy.step_budget {
            Classified::Stiff
        } else {
            Classified::Explicit
        }
    }
}

/// Estimate `|λ_max|` of `∂f/∂y` at `(t0, y0)` by forward-difference
/// Jacobian–vector power iteration. Returns `None` when the state or the
/// dynamics are non-finite (the solve itself will report `NonFinite`
/// soon enough) — a classifier must never panic on garbage input.
fn dominant_eigenvalue(
    problem: &ProblemSpec,
    t0: f64,
    y0: &[f64],
    iters: usize,
) -> Option<f64> {
    match problem {
        ProblemSpec::Vdp { mu } => power_iteration(&VdP::new(vec![*mu]), t0, y0, iters),
        ProblemSpec::ExpDecay { lambda } => {
            power_iteration(&ExponentialDecay::new(vec![*lambda], y0.len()), t0, y0, iters)
        }
    }
}

fn power_iteration<S: OdeSystem>(sys: &S, t0: f64, y0: &[f64], iters: usize) -> Option<f64> {
    let dim = y0.len();
    if dim == 0 || !t0.is_finite() || y0.iter().any(|v| !v.is_finite()) {
        return None;
    }
    let mut f0 = vec![0.0; dim];
    sys.f_inst(0, t0, y0, &mut f0);
    if f0.iter().any(|v| !v.is_finite()) {
        return None;
    }
    // Directional FD with the implicit.rs perturbation convention.
    let ynorm = y0.iter().map(|v| v * v).sum::<f64>().sqrt();
    let eps = f64::EPSILON.sqrt() * (1.0 + ynorm);
    // Deterministic start vector with unequal, sign-alternating entries so
    // it is not orthogonal to the dominant eigenvector of common Jacobians.
    let mut v: Vec<f64> = (0..dim)
        .map(|i| {
            let sign = if i % 2 == 0 { 1.0 } else { -1.0 };
            sign / (1.0 + i as f64)
        })
        .collect();
    let norm0 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    for x in v.iter_mut() {
        *x /= norm0;
    }
    let mut yp = vec![0.0; dim];
    let mut fp = vec![0.0; dim];
    let mut lambda = 0.0;
    for _ in 0..iters.max(1) {
        for i in 0..dim {
            yp[i] = y0[i] + eps * v[i];
        }
        sys.f_inst(0, t0, &yp, &mut fp);
        let mut norm_sq = 0.0;
        for i in 0..dim {
            let w = (fp[i] - f0[i]) / eps; // ≈ (J v)[i]
            v[i] = w;
            norm_sq += w * w;
        }
        let norm = norm_sq.sqrt();
        if !norm.is_finite() {
            return None;
        }
        if norm == 0.0 {
            return Some(0.0); // constant dynamics: nothing is stiff
        }
        lambda = norm;
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    Some(lambda)
}

/// Extent of the stability region of an explicit RK method along the
/// negative real axis, derived from its tableau: scan for the largest
/// `x` with `|R(−x)| ≤ 1` where `R(z) = 1 + Σ_k z^k · bᵀA^{k−1}𝟙`.
/// Implicit (A-/L-stable) methods report `f64::INFINITY`.
pub fn stability_radius(m: MethodId) -> f64 {
    if m.is_implicit() {
        return f64::INFINITY;
    }
    let t = m.tableau();
    let s = t.stages;
    // coeff[k] = bᵀ A^{k−1} 𝟙 for k ≥ 1; coeff[0] = 1.
    let mut coeff = vec![0.0; s + 1];
    coeff[0] = 1.0;
    let mut w = vec![1.0; s]; // A^{k−1} 𝟙, starting at k = 1
    for k in 1..=s {
        coeff[k] = t.b.iter().zip(&w).map(|(bi, wi)| bi * wi).sum();
        let mut nw = vec![0.0; s];
        for i in 1..s {
            let mut acc = 0.0;
            for j in 0..i {
                acc += t.a(i, j) * w[j];
            }
            nw[i] = acc;
        }
        w = nw;
    }
    // Walk out from the origin; the real-axis stability interval of every
    // explicit RK tableau in the registry is connected, so stop once the
    // scan has left it decisively.
    let dx = 1e-2;
    let mut radius = 0.0;
    let mut x = 0.0;
    while x < 50.0 {
        x += dx;
        let z = -x;
        let mut r = 0.0;
        let mut zk = 1.0;
        for &c in &coeff {
            r += c * zk;
            zk *= z;
        }
        if r.abs() <= 1.0 + 1e-12 {
            radius = x;
        } else if x > radius + 1.0 {
            break;
        }
    }
    radius
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(problem: ProblemSpec, y0: Vec<f64>, t1: f64) -> SolveRequest {
        SolveRequest::new(problem, y0, vec![0.0, t1 / 2.0, t1])
    }

    #[test]
    fn stability_radii_match_theory() {
        // Euler: R(z) = 1 + z, stable on [−2, 0].
        let euler = stability_radius(MethodId::EULER);
        assert!((euler - 2.0).abs() < 0.05, "euler radius {euler}");
        // Classical RK4: real-axis radius ≈ 2.785.
        let rk4 = stability_radius(MethodId::RK4);
        assert!((rk4 - 2.785).abs() < 0.05, "rk4 radius {rk4}");
        // Dopri5: real-axis radius ≈ 3.3.
        let dopri5 = stability_radius(MethodId::DOPRI5);
        assert!(dopri5 > 3.0 && dopri5 < 3.6, "dopri5 radius {dopri5}");
        // Implicit methods have no real-axis limit.
        assert_eq!(stability_radius(MethodId::TRBDF2), f64::INFINITY);
        assert_eq!(stability_radius(MethodId::KVAERNO43), f64::INFINITY);
    }

    #[test]
    fn power_iteration_recovers_linear_eigenvalue() {
        // ẏ = −λy has J = −λI: the dominant eigenvalue is exactly λ.
        let sys = ExponentialDecay::new(vec![50.0], 3);
        let lam = power_iteration(&sys, 0.0, &[1.0, 2.0, 3.0], 4).unwrap();
        assert!((lam - 50.0).abs() / 50.0 < 1e-2, "estimated {lam}");
    }

    #[test]
    fn power_iteration_sees_vdp_stiffness() {
        // VdP at (2, 0): J = [[0, 1], [−2μ·x·v − 1, μ(1 − x²)]], so the
        // dominant eigenvalue is ≈ 3μ for large μ.
        let sys = VdP::new(vec![1000.0]);
        let lam = power_iteration(&sys, 0.0, &[2.0, 0.0], 4).unwrap();
        assert!(lam > 2000.0 && lam < 4000.0, "estimated {lam}");
    }

    #[test]
    fn classifies_stiff_vdp_and_easy_vdp_apart() {
        let c = Classifier::new(ClassifierPolicy::enabled());
        // μ = 1000 over a relaxation period: hundreds of thousands of
        // stability-limited steps.
        let stiff = req(ProblemSpec::Vdp { mu: 1000.0 }, vec![2.0, 0.0], 400.0);
        assert_eq!(c.classify(&stiff), Classified::Stiff);
        // μ = 2 over a few periods: comfortably explicit.
        let easy = req(ProblemSpec::Vdp { mu: 2.0 }, vec![2.0, 0.0], 5.0);
        assert_eq!(c.classify(&easy), Classified::Explicit);
        // Fast linear decay over a long horizon is also stiff.
        let decay = req(ProblemSpec::ExpDecay { lambda: 1e6 }, vec![1.0], 100.0);
        assert_eq!(c.classify(&decay), Classified::Stiff);
    }

    #[test]
    fn disabled_or_overridden_requests_are_not_run() {
        let off = Classifier::new(ClassifierPolicy::default());
        let stiff = req(ProblemSpec::Vdp { mu: 1000.0 }, vec![2.0, 0.0], 400.0);
        assert_eq!(off.classify(&stiff), Classified::NotRun);
        let on = Classifier::new(ClassifierPolicy::enabled());
        let routed = stiff.clone().with_method(MethodId::DOPRI5);
        assert_eq!(on.classify(&routed), Classified::NotRun);
    }

    #[test]
    fn garbage_input_degrades_to_not_run() {
        let c = Classifier::new(ClassifierPolicy::enabled());
        // Non-finite state.
        let nan = req(ProblemSpec::Vdp { mu: 1.0 }, vec![f64::NAN, 0.0], 5.0);
        assert_eq!(c.classify(&nan), Classified::NotRun);
        // Empty time grid / empty span.
        let mut empty = req(ProblemSpec::Vdp { mu: 1.0 }, vec![2.0, 0.0], 5.0);
        empty.t_eval.clear();
        assert_eq!(c.classify(&empty), Classified::NotRun);
        let zero_span = SolveRequest::new(
            ProblemSpec::Vdp { mu: 1.0 },
            vec![2.0, 0.0],
            vec![1.0, 1.0],
        );
        assert_eq!(c.classify(&zero_span), Classified::NotRun);
        // Empty state vector.
        let hollow = SolveRequest::new(ProblemSpec::ExpDecay { lambda: 1.0 }, vec![], vec![0.0, 1.0]);
        assert_eq!(c.classify(&hollow), Classified::NotRun);
    }
}
