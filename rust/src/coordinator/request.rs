//! Request/response types of the solver service.

use crate::solver::{MethodId, Stats, Status};
use std::time::Duration;

/// Which dynamics a request wants solved. The coordinator buckets
/// compatible problems together; per-instance parameters (e.g. μ) ride
/// along inside the batch.
#[derive(Debug, Clone, PartialEq)]
pub enum ProblemSpec {
    /// Van der Pol with damping μ.
    Vdp { mu: f64 },
    /// Exponential decay ẏ = −λy (any dim).
    ExpDecay { lambda: f64 },
}

impl ProblemSpec {
    /// Bucketing kind — requests only batch with the same kind.
    pub fn kind(&self) -> &'static str {
        match self {
            ProblemSpec::Vdp { .. } => "vdp",
            ProblemSpec::ExpDecay { .. } => "expdecay",
        }
    }
}

/// Admission-control priority of a request. Under load the service sheds
/// low-priority traffic first: each class is admitted only while the
/// in-flight count stays below its share of `ServiceConfig::max_queue`
/// (half for `Low`, 7/8 for `Normal`, all of it for `High` — the top
/// eighth is reserved headroom so high-priority requests still get in
/// when normal traffic has filled the queue). Priorities never reorder
/// dispatch; they only decide who gets shed at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Shed first: admitted only while the queue is under half full.
    Low,
    /// The default class.
    #[default]
    Normal,
    /// Shed last: may use the reserved headroom above the normal limit.
    High,
}

/// One independent IVP submitted to the service.
#[derive(Debug, Clone)]
pub struct SolveRequest {
    pub id: u64,
    pub problem: ProblemSpec,
    /// Initial state (length = problem dim).
    pub y0: Vec<f64>,
    /// Ascending evaluation times; integration runs over
    /// `[t_eval[0], t_eval[last]]`.
    pub t_eval: Vec<f64>,
    /// Optional per-request method override. `None` uses the engine's
    /// default; `Some(m)` routes this request into a bucket that is solved
    /// with `m` — any [`MethodId`], including runtime-registered ones. The
    /// batcher never mixes methods inside one batch, so a stiff request can
    /// ask for `trbdf2`/`kvaerno43` while easy traffic stays on the
    /// engine's explicit default.
    pub method: Option<MethodId>,
    /// Optional deadline, measured from submission. A request whose
    /// deadline has passed by the time its batch is dispatched is failed
    /// with [`ServiceError::DeadlineExpired`] instead of occupying a
    /// batch slot; a stiffness-escalation retry is likewise abandoned if
    /// the deadline passes first. `None` = wait forever.
    pub deadline: Option<Duration>,
    /// Admission-control class (see [`Priority`]).
    pub priority: Priority,
}

impl SolveRequest {
    /// A request with the common defaults: auto-assigned id, engine
    /// default method, no deadline, normal priority.
    pub fn new(problem: ProblemSpec, y0: Vec<f64>, t_eval: Vec<f64>) -> Self {
        Self { id: 0, problem, y0, t_eval, method: None, deadline: None, priority: Priority::Normal }
    }

    /// Route this request to a specific method (its own batch bucket).
    pub fn with_method(mut self, method: MethodId) -> Self {
        self.method = Some(method);
        self
    }

    /// Fail this request with [`ServiceError::DeadlineExpired`] if it has
    /// not reached an engine within `d` of submission.
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Set the admission-control class.
    pub fn with_priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    pub fn dim(&self) -> usize {
        self.y0.len()
    }

    pub fn n_eval(&self) -> usize {
        self.t_eval.len()
    }
}

/// A structured service-level failure. Carried in
/// [`SolveResponse::error`], so callers can tell *why* a request produced
/// no trajectory — and in particular can distinguish infrastructure
/// failures (a panicking batch, an overloaded queue) from genuine solver
/// outcomes like [`Status::NonFinite`], which earlier versions of the
/// service conflated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The engine panicked while solving the batch containing this
    /// request. The panic was confined to that one batch: the worker
    /// rebuilt its engine and kept serving.
    WorkerPanic {
        /// The panic payload (message), for logs and debugging.
        detail: String,
    },
    /// The engine returned an error for the whole batch (e.g. no dynamics
    /// registered for the problem kind, or an AOT artifact mismatch).
    EngineError {
        /// The engine's error text.
        detail: String,
    },
    /// The bounded submission queue was full for this request's priority
    /// class; the request was shed at admission and never queued.
    Overloaded {
        /// In-flight requests at the moment of shedding.
        inflight: usize,
        /// The configured queue bound (`ServiceConfig::max_queue`).
        max_queue: usize,
    },
    /// The request's deadline passed before its batch was dispatched (or
    /// before its escalation retry ran); it was dropped without solving.
    DeadlineExpired,
    /// The worker thread has no engine (its engine factory panicked) or
    /// is gone; the request was failed immediately instead of waiting on
    /// a response that would never arrive.
    WorkerUnavailable,
    /// The service is shutting down and will not solve this request.
    ShuttingDown,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::WorkerPanic { detail } => write!(f, "engine panicked: {detail}"),
            ServiceError::EngineError { detail } => write!(f, "engine error: {detail}"),
            ServiceError::Overloaded { inflight, max_queue } => {
                write!(f, "overloaded: {inflight} in flight (max_queue {max_queue})")
            }
            ServiceError::DeadlineExpired => write!(f, "deadline expired before dispatch"),
            ServiceError::WorkerUnavailable => write!(f, "worker unavailable"),
            ServiceError::ShuttingDown => write!(f, "service shutting down"),
        }
    }
}

/// The solved trajectory + per-instance solver metadata — or, when
/// [`SolveResponse::error`] is set, a structured account of why the
/// service could not solve the request.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    pub id: u64,
    /// `(n_eval, dim)` row-major. Empty when `error` is set.
    pub ys: Vec<f64>,
    pub stats: Stats,
    /// The solver's per-instance termination status. `None` when the
    /// request never completed a solve (panic, shed, expired, engine
    /// error) — see `error` for the reason.
    pub status: Option<Status>,
    /// Service-level failure, if any. `None` means the solver ran and
    /// `status`/`stats`/`ys` describe its outcome (which may still be a
    /// solver-level failure such as [`Status::DtUnderflow`]).
    pub error: Option<ServiceError>,
    /// Which engine produced this (diagnostics); `"service"` for
    /// responses synthesized by the coordinator itself.
    pub engine: &'static str,
    /// The method that actually solved the bucket: the request's override
    /// if set, else the engine default. `None` when the engine does not
    /// route through the registry (the AOT artifacts bake their method in)
    /// or the batch failed before a method was resolved.
    pub method: Option<MethodId>,
    /// Set when this response came from a stiffness-escalation retry:
    /// the method the request *first* failed on (e.g. `dopri5`) before
    /// the service re-enqueued it on the configured implicit fallback.
    /// Callers can use this to detect degraded-mode service.
    pub escalated_from: Option<MethodId>,
    /// `true` when the proactive stiffness classifier routed this request
    /// to the implicit fallback *before* its first solve (so no failed
    /// explicit attempt was paid — contrast with `escalated_from`, the
    /// reactive path). Always `false` when the classifier is disabled.
    pub classified_stiff: bool,
}

impl SolveResponse {
    /// A response synthesized by the service for a request that never
    /// completed a solve.
    pub fn failure(id: u64, error: ServiceError) -> Self {
        Self {
            id,
            ys: Vec::new(),
            stats: Stats::default(),
            status: None,
            error: Some(error),
            engine: "service",
            method: None,
            escalated_from: None,
            classified_stiff: false,
        }
    }

    /// `true` iff the solver ran and reported [`Status::Success`].
    pub fn is_success(&self) -> bool {
        self.error.is_none() && self.status == Some(Status::Success)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_distinguish_problems() {
        assert_ne!(
            ProblemSpec::Vdp { mu: 1.0 }.kind(),
            ProblemSpec::ExpDecay { lambda: 1.0 }.kind()
        );
        // Same kind regardless of parameters (parameters batch together).
        assert_eq!(
            ProblemSpec::Vdp { mu: 1.0 }.kind(),
            ProblemSpec::Vdp { mu: 99.0 }.kind()
        );
    }

    #[test]
    fn request_shape_accessors() {
        let r = SolveRequest::new(
            ProblemSpec::Vdp { mu: 2.0 },
            vec![1.0, 0.0],
            vec![0.0, 0.5, 1.0],
        );
        assert_eq!(r.dim(), 2);
        assert_eq!(r.n_eval(), 3);
        assert_eq!(r.priority, Priority::Normal);
        assert_eq!(r.deadline, None);
        assert_eq!(r.method, None);
    }

    #[test]
    fn request_builders() {
        let r = SolveRequest::new(ProblemSpec::Vdp { mu: 2.0 }, vec![1.0, 0.0], vec![0.0, 1.0])
            .with_method(MethodId::TRBDF2)
            .with_deadline(Duration::from_millis(5))
            .with_priority(Priority::High);
        assert_eq!(r.method, Some(MethodId::TRBDF2));
        assert_eq!(r.deadline, Some(Duration::from_millis(5)));
        assert_eq!(r.priority, Priority::High);
    }

    #[test]
    fn failure_response_is_not_success() {
        let r = SolveResponse::failure(7, ServiceError::WorkerUnavailable);
        assert!(!r.is_success());
        assert_eq!(r.status, None);
        assert!(r.ys.is_empty());
        assert_eq!(r.engine, "service");
        // Errors render human-readably for logs.
        assert!(r.error.unwrap().to_string().contains("worker unavailable"));
    }

    #[test]
    fn priority_orders_low_to_high() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
    }
}
