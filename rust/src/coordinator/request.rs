//! Request/response types of the solver service.

use crate::solver::{MethodId, Stats, Status};

/// Which dynamics a request wants solved. The coordinator buckets
/// compatible problems together; per-instance parameters (e.g. μ) ride
/// along inside the batch.
#[derive(Debug, Clone, PartialEq)]
pub enum ProblemSpec {
    /// Van der Pol with damping μ.
    Vdp { mu: f64 },
    /// Exponential decay ẏ = −λy (any dim).
    ExpDecay { lambda: f64 },
}

impl ProblemSpec {
    /// Bucketing kind — requests only batch with the same kind.
    pub fn kind(&self) -> &'static str {
        match self {
            ProblemSpec::Vdp { .. } => "vdp",
            ProblemSpec::ExpDecay { .. } => "expdecay",
        }
    }
}

/// One independent IVP submitted to the service.
#[derive(Debug, Clone)]
pub struct SolveRequest {
    pub id: u64,
    pub problem: ProblemSpec,
    /// Initial state (length = problem dim).
    pub y0: Vec<f64>,
    /// Ascending evaluation times; integration runs over
    /// `[t_eval[0], t_eval[last]]`.
    pub t_eval: Vec<f64>,
    /// Optional per-request method override. `None` uses the engine's
    /// default; `Some(m)` routes this request into a bucket that is solved
    /// with `m` — any [`MethodId`], including runtime-registered ones. The
    /// batcher never mixes methods inside one batch, so a stiff request can
    /// ask for `trbdf2`/`kvaerno43` while easy traffic stays on the
    /// engine's explicit default.
    pub method: Option<MethodId>,
}

impl SolveRequest {
    pub fn dim(&self) -> usize {
        self.y0.len()
    }

    pub fn n_eval(&self) -> usize {
        self.t_eval.len()
    }
}

/// The solved trajectory + per-instance solver metadata.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    pub id: u64,
    /// `(n_eval, dim)` row-major.
    pub ys: Vec<f64>,
    pub stats: Stats,
    pub status: Status,
    /// Which engine produced this (diagnostics).
    pub engine: &'static str,
    /// The method that actually solved the bucket: the request's override
    /// if set, else the engine default. `None` when the engine does not
    /// route through the registry (the AOT artifacts bake their method in)
    /// or the batch failed before a method was resolved.
    pub method: Option<MethodId>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_distinguish_problems() {
        assert_ne!(
            ProblemSpec::Vdp { mu: 1.0 }.kind(),
            ProblemSpec::ExpDecay { lambda: 1.0 }.kind()
        );
        // Same kind regardless of parameters (parameters batch together).
        assert_eq!(
            ProblemSpec::Vdp { mu: 1.0 }.kind(),
            ProblemSpec::Vdp { mu: 99.0 }.kind()
        );
    }

    #[test]
    fn request_shape_accessors() {
        let r = SolveRequest {
            id: 1,
            problem: ProblemSpec::Vdp { mu: 2.0 },
            y0: vec![1.0, 0.0],
            t_eval: vec![0.0, 0.5, 1.0],
            method: None,
        };
        assert_eq!(r.dim(), 2);
        assert_eq!(r.n_eval(), 3);
    }
}
